"""CGS sweep tests: invariants, exactness, convergence (paper §2.1/§3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cgs, likelihood
from repro.core.alias_lda import sweep_alias_lda
from repro.core.sparse_lda import sweep_sparse_lda
from repro.data import synthetic
from repro.data.corpus import Corpus


@pytest.fixture(scope="module")
def tiny():
    corpus, _, _ = synthetic.make_corpus(
        num_docs=40, vocab_size=64, num_topics=8, mean_doc_len=25.0, seed=0)
    T = 8
    state = cgs.init_state(corpus, T, jax.random.key(0))
    return corpus, T, state


def _arrs(corpus):
    return jnp.asarray(corpus.doc_ids), jnp.asarray(corpus.word_ids)


ALPHA, BETA = 50.0 / 8, 0.01


class TestInvariants:
    def test_init_consistent(self, tiny):
        corpus, T, state = tiny
        v = cgs.check_invariants(state, corpus)
        assert all(x == 0 for x in v.values()), v

    @pytest.mark.parametrize("sweep_name", [
        "reference", "fplda_word", "fplda_doc", "sparse", "alias"])
    def test_sweep_preserves_invariants(self, tiny, sweep_name):
        corpus, T, state = tiny
        doc_ids, word_ids = _arrs(corpus)
        state2 = _run_sweep(sweep_name, state, corpus, doc_ids, word_ids)
        v = cgs.check_invariants(state2, corpus)
        assert all(x == 0 for x in v.values()), (sweep_name, v)
        # totals conserved
        assert int(state2.n_t.sum()) == corpus.num_tokens


def _run_sweep(name, state, corpus, doc_ids, word_ids):
    if name == "reference":
        order = jnp.asarray(corpus.doc_order())
        return cgs.sweep_reference(state, doc_ids, word_ids, order, ALPHA, BETA)
    if name == "fplda_word":
        order_np = corpus.word_order()
        boundary = jnp.asarray(corpus.word_boundary(order_np))
        return cgs.sweep_fplda_word(state, doc_ids, word_ids,
                                    jnp.asarray(order_np), boundary,
                                    ALPHA, BETA)
    if name == "fplda_doc":
        order_np = corpus.doc_order()
        d = corpus.doc_ids[order_np]
        boundary = jnp.asarray(np.concatenate([[True], d[1:] != d[:-1]]))
        return cgs.sweep_fplda_doc(state, doc_ids, word_ids,
                                   jnp.asarray(order_np), boundary,
                                   ALPHA, BETA)
    if name == "sparse":
        order = jnp.asarray(corpus.doc_order())
        return sweep_sparse_lda(state, doc_ids, word_ids, order, ALPHA, BETA)
    if name == "alias":
        order = jnp.asarray(corpus.doc_order())
        return sweep_alias_lda(state, doc_ids, word_ids, order, ALPHA, BETA)
    raise ValueError(name)


class TestConvergence:
    """All exact samplers should improve LL from random init (Fig. 4a/4b)."""

    @pytest.mark.parametrize("sweep_name", [
        "reference", "fplda_word", "fplda_doc", "sparse", "alias"])
    def test_ll_improves(self, tiny, sweep_name):
        corpus, T, state = tiny
        doc_ids, word_ids = _arrs(corpus)
        ll0 = likelihood.log_likelihood(state, ALPHA, BETA)
        for _ in range(3):
            state = _run_sweep(sweep_name, state, corpus, doc_ids, word_ids)
        ll1 = likelihood.log_likelihood(state, ALPHA, BETA)
        assert ll1 > ll0, (sweep_name, ll0, ll1)

    def test_exact_sweeps_converge_to_similar_ll(self, tiny):
        """Fig. 4: exact samplers have the same per-iteration convergence."""
        corpus, T, _ = tiny
        doc_ids, word_ids = _arrs(corpus)
        lls = {}
        for name in ["reference", "fplda_word", "fplda_doc", "sparse"]:
            state = cgs.init_state(corpus, T, jax.random.key(1))
            for _ in range(10):
                state = _run_sweep(name, state, corpus, doc_ids, word_ids)
            lls[name] = likelihood.per_token_ll(state, ALPHA, BETA)
        vals = np.array(list(lls.values()))
        # Same chain family → same plateau (stochastic: generous tolerance).
        assert vals.max() - vals.min() < 0.45, lls


class TestSingleStepExactness:
    """The q/r two-level draw must induce exactly the conditional (2)."""

    def test_two_level_partition_matches_conditional(self):
        # Build a miniature state by hand and check that the interval
        # partition of u-space induced by the fplda draw has measure p_t/Σp.
        T = 8
        rng = np.random.default_rng(5)
        n_wt_row = rng.integers(0, 5, T).astype(np.float32)
        n_td_row = rng.integers(0, 4, T).astype(np.float32)
        n_t = (n_wt_row + rng.integers(0, 10, T)).astype(np.float32)
        alpha, beta, beta_bar = 0.3, 0.01, 0.01 * 64
        q = (n_wt_row + beta) / (n_t + beta_bar)
        r = n_td_row * q
        p = (n_td_row + alpha) * q
        np.testing.assert_allclose(alpha * q + r, p, rtol=1e-5)

        # emulate the two-level draw on a dense u grid
        norm = alpha * q.sum() + r.sum()
        us = np.linspace(0, norm * (1 - 1e-7), 200_001)
        c_r = np.cumsum(r)
        c_q = np.cumsum(q)
        in_r = us < r.sum()
        t_r = np.searchsorted(c_r, us, side="right")
        uq = (us - r.sum()) / alpha
        t_q = np.searchsorted(c_q, np.clip(uq, 0, c_q[-1] - 1e-9),
                              side="right")
        t = np.where(in_r, t_r, t_q)
        hist = np.bincount(t, minlength=T) / len(us)
        np.testing.assert_allclose(hist, p / p.sum(), atol=2e-3)


class TestCorpus:
    def test_orders_cover_all_tokens(self, tiny):
        corpus, _, _ = tiny
        for order in [corpus.doc_order(), corpus.word_order()]:
            assert sorted(order.tolist()) == list(range(corpus.num_tokens))

    def test_word_boundary_counts_vocab(self, tiny):
        corpus, _, _ = tiny
        b = corpus.word_boundary()
        present = np.unique(corpus.word_ids).shape[0]
        assert int(b.sum()) == present

    def test_from_dense_roundtrip(self):
        counts = np.array([[2, 0, 1], [0, 3, 0]])
        c = Corpus.from_dense(counts)
        assert c.num_tokens == 6
        back = np.zeros_like(counts)
        np.add.at(back, (c.doc_ids, c.word_ids), 1)
        np.testing.assert_array_equal(back, counts)
