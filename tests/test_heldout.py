"""Held-out document-completion perplexity tests."""
import jax
import numpy as np
import pytest

from repro.core import cgs, heldout
from repro.data import synthetic


@pytest.fixture(scope="module")
def trained():
    T = 8
    alpha, beta = 50.0 / T, 0.01
    corpus, _, _ = synthetic.make_corpus(
        num_docs=150, vocab_size=128, num_topics=T, mean_doc_len=40.0,
        seed=0)
    train = corpus.subset(corpus.doc_ids % 5 != 0)
    held = corpus.subset(corpus.doc_ids % 5 == 0)
    state = cgs.init_state(train, T, jax.random.key(0))
    import jax.numpy as jnp
    order = jnp.asarray(train.doc_order())
    doc_ids = jnp.asarray(train.doc_ids)
    word_ids = jnp.asarray(train.word_ids)
    sweep = jax.jit(lambda s: cgs.sweep_reference(
        s, doc_ids, word_ids, order, alpha, beta))
    for _ in range(10):
        state = sweep(state)
    return T, alpha, beta, state, held


class TestDocumentCompletion:
    def test_perplexity_bounded_by_vocab(self, trained):
        T, alpha, beta, state, held = trained
        ppl = heldout.document_completion_perplexity(
            held, state.n_wt, state.n_t, alpha=alpha, beta=beta,
            fold_sweeps=10)
        assert 1.0 < ppl < 128.0  # better than uniform over the vocab

    def test_trained_model_beats_untrained(self, trained):
        T, alpha, beta, state, held = trained
        ppl_trained = heldout.document_completion_perplexity(
            held, state.n_wt, state.n_t, alpha=alpha, beta=beta,
            fold_sweeps=10)
        # untrained: uniform counts
        import jax.numpy as jnp
        n_wt0 = jnp.ones_like(state.n_wt)
        n_t0 = n_wt0.sum(0)
        ppl_untrained = heldout.document_completion_perplexity(
            held, n_wt0, n_t0, alpha=alpha, beta=beta, fold_sweeps=10)
        assert ppl_trained < ppl_untrained


class TestFoldInValidation:
    """fold_in inputs arrive from serving requests and held-out splits —
    they must fail loudly (mirroring data/corpus.py), not fold garbage."""

    def _phi(self):
        import jax.numpy as jnp
        return jnp.ones((16, 4), jnp.float32) / 4

    def test_empty_token_list_raises(self):
        with pytest.raises(ValueError, match="empty token list"):
            heldout.fold_in(np.zeros(0, np.int32), np.zeros(0, np.int32),
                            1, self._phi(), 0.1, jax.random.key(0))

    def test_empty_corpus_raises(self):
        with pytest.raises(ValueError, match="num_docs >= 1"):
            heldout.fold_in(np.array([1], np.int32),
                            np.array([0], np.int32), 0, self._phi(), 0.1,
                            jax.random.key(0))

    def test_out_of_range_ids_raise(self):
        with pytest.raises(ValueError, match="doc_ids out of range"):
            heldout.fold_in(np.array([1], np.int32),
                            np.array([5], np.int32), 2, self._phi(), 0.1,
                            jax.random.key(0))
        with pytest.raises(ValueError, match="word_ids out of range"):
            heldout.fold_in(np.array([16], np.int32),
                            np.array([0], np.int32), 1, self._phi(), 0.1,
                            jax.random.key(0))

    def test_mismatched_shapes_raise(self):
        with pytest.raises(ValueError, match="parallel arrays"):
            heldout.fold_in(np.array([1, 2], np.int32),
                            np.array([0], np.int32), 1, self._phi(), 0.1,
                            jax.random.key(0))


class TestServeEngine:
    def test_generate_batched_variable_lengths(self):
        from repro.configs import get_config
        from repro.serve.engine import generate
        from repro.train.train_step import init_train_state
        cfg = get_config("granite-3-2b").smoke()
        params = init_train_state(cfg, jax.random.key(0)).params
        prompts = [[1, 2, 3], [4, 5, 6, 7, 8], [9]]
        out = generate(params, cfg, prompts, max_new_tokens=4)
        assert len(out) == 3
        assert all(len(o) == 4 for o in out)
        assert all(0 <= t < cfg.vocab_size for o in out for t in o)

    def test_generate_matches_single_sequence(self):
        """Batched generation must equal running each prompt alone."""
        from repro.configs import get_config
        from repro.serve.engine import generate
        from repro.train.train_step import init_train_state
        cfg = get_config("granite-3-2b").smoke()
        params = init_train_state(cfg, jax.random.key(0)).params
        prompts = [[1, 2, 3, 4], [7, 8]]
        both = generate(params, cfg, prompts, max_new_tokens=3)
        solo0 = generate(params, cfg, [prompts[0]], max_new_tokens=3)
        solo1 = generate(params, cfg, [prompts[1]], max_new_tokens=3)
        assert both[0] == solo0[0]
        assert both[1] == solo1[0]
