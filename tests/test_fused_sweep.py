"""Fused F+LDA sweep kernel: chain-exact parity + invariants.

The fused kernel must reproduce the ``lax.scan`` sweep bit-for-bit: same
``z``, same count tables, same final F+tree as its ``ref.py`` oracle —
across topic counts, non-power-of-two vocab/doc shapes, and token-tile
boundaries (small ``n_blk`` forces the chain to cross grid programs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cgs
from repro.data import synthetic
from repro.kernels.fused_sweep import fused_sweep_tokens
from repro.kernels.fused_sweep.ref import fused_sweep_ref


def _setup(T, num_docs, vocab, mean_len, seed):
    corpus, _, _ = synthetic.make_corpus(
        num_docs=num_docs, vocab_size=vocab, num_topics=min(T, 32),
        mean_doc_len=mean_len, seed=seed)
    state = cgs.init_state(corpus, T, jax.random.key(seed))
    doc_ids = jnp.asarray(corpus.doc_ids)
    word_ids = jnp.asarray(corpus.word_ids)
    order = jnp.asarray(corpus.word_order())
    boundary = jnp.asarray(corpus.word_boundary())
    return corpus, state, doc_ids, word_ids, order, boundary


def _fused_inputs(state, doc_ids, word_ids, order, boundary):
    """Same uniforms the scan sweep derives from the chain key."""
    _, sweep_key = jax.random.split(state.key)
    u = jax.random.uniform(sweep_key, (order.shape[0],))
    valid = jnp.ones(order.shape[0], jnp.int32)
    return (doc_ids[order], word_ids[order], valid,
            boundary.astype(jnp.int32), state.z[order], u)


class TestChainExactParity:
    # Non-power-of-two I and J throughout; T must be a power of two.
    @pytest.mark.parametrize("T,num_docs,vocab,mean_len", [
        (4, 13, 37, 9.0),
        (64, 21, 150, 15.0),
        (1024, 11, 97, 10.0),
    ])
    def test_fused_matches_scan_and_ref(self, T, num_docs, vocab, mean_len):
        corpus, state, doc_ids, word_ids, order, boundary = _setup(
            T, num_docs, vocab, mean_len, seed=T)
        alpha, beta = 50.0 / T, 0.01
        beta_bar = beta * corpus.num_words

        s_scan = cgs.sweep_fplda_word(state, doc_ids, word_ids, order,
                                      boundary, alpha, beta)
        s_fused = cgs.sweep_fplda_word(state, doc_ids, word_ids, order,
                                       boundary, alpha, beta,
                                       backend="fused")
        # identical chain: z and all three count tables bit-equal
        np.testing.assert_array_equal(np.asarray(s_scan.z),
                                      np.asarray(s_fused.z))
        np.testing.assert_array_equal(np.asarray(s_scan.n_td),
                                      np.asarray(s_fused.n_td))
        np.testing.assert_array_equal(np.asarray(s_scan.n_wt),
                                      np.asarray(s_fused.n_wt))
        np.testing.assert_array_equal(np.asarray(s_scan.n_t),
                                      np.asarray(s_fused.n_t))

        # kernel vs its oracle: z, counts AND the final F+tree, bit-equal
        tok = _fused_inputs(state, doc_ids, word_ids, order, boundary)
        kw = dict(alpha=alpha, beta=beta, beta_bar=beta_bar)
        z_k, ntd_k, nwt_k, nt_k, F_k = fused_sweep_tokens(
            *tok, state.n_td, state.n_wt, state.n_t, **kw)
        z_r, ntd_r, nwt_r, nt_r, F_r = fused_sweep_ref(
            *tok, state.n_td, state.n_wt, state.n_t, **kw)
        np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_r))
        np.testing.assert_array_equal(np.asarray(ntd_k), np.asarray(ntd_r))
        np.testing.assert_array_equal(np.asarray(nwt_k), np.asarray(nwt_r))
        np.testing.assert_array_equal(np.asarray(nt_k), np.asarray(nt_r))
        np.testing.assert_array_equal(np.asarray(F_k), np.asarray(F_r))

    def test_chain_crosses_tile_boundaries(self):
        """n_blk smaller than N: state must persist across grid programs."""
        T = 16
        corpus, state, doc_ids, word_ids, order, boundary = _setup(
            T, 25, 60, 18.0, seed=7)
        alpha, beta = 50.0 / T, 0.01
        beta_bar = beta * corpus.num_words
        tok = _fused_inputs(state, doc_ids, word_ids, order, boundary)
        kw = dict(alpha=alpha, beta=beta, beta_bar=beta_bar)
        base = fused_sweep_tokens(*tok, state.n_td, state.n_wt, state.n_t,
                                  **kw)
        assert corpus.num_tokens > 32  # actually exercises >1 tile
        tiled = fused_sweep_tokens(*tok, state.n_td, state.n_wt, state.n_t,
                                   n_blk=32, **kw)
        for a, b in zip(base, tiled):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_invariants_after_fused_sweeps(self):
        T = 32
        corpus, state, doc_ids, word_ids, order, boundary = _setup(
            T, 30, 70, 14.0, seed=2)
        alpha, beta = 50.0 / T, 0.01
        for _ in range(2):
            state = cgs.sweep_fplda_word(state, doc_ids, word_ids, order,
                                         boundary, alpha, beta,
                                         backend="fused")
        v = cgs.check_invariants(state, corpus)
        assert all(x == 0 for x in v.values()), v
        assert int(state.n_t.sum()) == corpus.num_tokens


class TestMaskingAndEdges:
    def test_invalid_tokens_are_noops(self):
        """Interleaved valid=0 tokens must not perturb the chain."""
        T = 16
        corpus, state, doc_ids, word_ids, order, boundary = _setup(
            T, 12, 40, 10.0, seed=4)
        alpha, beta = 50.0 / T, 0.01
        beta_bar = beta * corpus.num_words
        tok_doc, tok_wrd, valid, bound, z0, u = _fused_inputs(
            state, doc_ids, word_ids, order, boundary)
        kw = dict(alpha=alpha, beta=beta, beta_bar=beta_bar)
        base = fused_sweep_tokens(tok_doc, tok_wrd, valid, bound, z0, u,
                                  state.n_td, state.n_wt, state.n_t, **kw)

        # duplicate every token, mark the copies invalid (boundary=0)
        n = tok_doc.shape[0]
        ileave = lambda a, pad: jnp.stack(
            [a, jnp.full_like(a, pad)], axis=1).reshape(2 * n)
        got = fused_sweep_tokens(
            ileave(tok_doc, 0), ileave(tok_wrd, 0), ileave(valid, 0),
            ileave(bound, 0), ileave(z0, 0), ileave(u, 0.5),
            state.n_td, state.n_wt, state.n_t, **kw)
        z2, ntd2, nwt2, nt2, F2 = got
        np.testing.assert_array_equal(np.asarray(z2[0::2]),
                                      np.asarray(base[0]))
        np.testing.assert_array_equal(np.asarray(ntd2), np.asarray(base[1]))
        np.testing.assert_array_equal(np.asarray(nwt2), np.asarray(base[2]))
        np.testing.assert_array_equal(np.asarray(nt2), np.asarray(base[3]))
        np.testing.assert_array_equal(np.asarray(F2), np.asarray(base[4]))

    def test_empty_stream(self):
        T = 8
        z, ntd, nwt, nt, F = fused_sweep_tokens(
            jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
            jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
            jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32),
            jnp.zeros((3, T), jnp.int32), jnp.zeros((5, T), jnp.int32),
            jnp.zeros((T,), jnp.int32),
            alpha=0.5, beta=0.01, beta_bar=0.05)
        assert z.shape == (0,)
        assert int(jnp.abs(ntd).sum()) == 0

    def test_non_pow2_T_rejected(self):
        T = 12
        with pytest.raises(ValueError, match="power-of-two"):
            fused_sweep_tokens(
                jnp.zeros((4,), jnp.int32), jnp.zeros((4,), jnp.int32),
                jnp.ones((4,), jnp.int32), jnp.ones((4,), jnp.int32),
                jnp.zeros((4,), jnp.int32), jnp.zeros((4,), jnp.float32),
                jnp.zeros((3, T), jnp.int32), jnp.zeros((5, T), jnp.int32),
                jnp.zeros((T,), jnp.int32),
                alpha=0.5, beta=0.01, beta_bar=0.05)


class TestCellBatchKernel:
    """One pallas_call over a whole k-cell block queue (nomad hot path)."""

    def _queue_setup(self, T=16, W=1, B=4, seed=11):
        from repro.data.sharding import build_layout
        corpus, _, _ = synthetic.make_corpus(
            num_docs=18, vocab_size=60, num_topics=8, mean_doc_len=12.0,
            seed=seed)
        lay = build_layout(corpus, n_workers=W, T=T, n_blocks=B)
        rng = np.random.default_rng(seed)
        z = np.where(lay.tok_valid,
                     rng.integers(0, T, lay.tok_valid.shape), 0)
        n_td = np.zeros((lay.I_max, T), np.int32)
        n_wt = np.zeros((B, lay.J_max, T), np.int32)
        n_t = np.zeros((T,), np.int32)
        w_i, b_i, l_i = np.nonzero(lay.tok_valid)
        zz = z[w_i, b_i, l_i]
        np.add.at(n_td, (lay.tok_doc[w_i, b_i, l_i], zz), 1)
        np.add.at(n_wt, (b_i, lay.tok_wrd[w_i, b_i, l_i], zz), 1)
        np.add.at(n_t, zz, 1)
        i32 = lambda a: jnp.asarray(a, jnp.int32)
        u = jnp.asarray(rng.random((B, lay.L)).astype(np.float32))
        return (i32(lay.tok_doc[0]), i32(lay.tok_wrd[0]),
                i32(lay.tok_valid[0]), i32(lay.tok_bound[0]),
                i32(z[0]), u, i32(n_td), i32(n_wt), i32(n_t))

    def test_cells_match_ref_and_sequential_calls(self):
        from repro.kernels.fused_sweep import (fused_sweep_cells,
                                               fused_sweep_tokens)
        from repro.kernels.fused_sweep.ref import fused_sweep_cells_ref
        T = 16
        args = self._queue_setup(T=T, B=4)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60)

        got = fused_sweep_cells(*args, **kw)
        ref = fused_sweep_cells_ref(*args, **kw)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # ... and one fused_sweep_tokens call per cell, chain carried by
        # hand, must be the identical chain the batched grid runs.
        tok_doc, tok_wrd, tok_valid, tok_bound, z, u, n_td, n_wt, n_t = args
        z_rows, nwt_rows = [], []
        for c in range(tok_doc.shape[0]):
            z_c, n_td, nwt_c, n_t, _ = fused_sweep_tokens(
                tok_doc[c], tok_wrd[c], tok_valid[c], tok_bound[c],
                z[c], u[c], n_td, n_wt[c], n_t, **kw)
            z_rows.append(z_c)
            nwt_rows.append(nwt_c)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(jnp.stack(z_rows)))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(n_td))
        np.testing.assert_array_equal(np.asarray(got[2]),
                                      np.asarray(jnp.stack(nwt_rows)))
        np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(n_t))

    def test_cells_cross_tile_boundaries(self):
        """Small n_blk: every cell spans several grid programs and the block
        page-in must still happen exactly once per cell."""
        from repro.kernels.fused_sweep import fused_sweep_cells
        T = 16
        args = self._queue_setup(T=T, B=2, seed=13)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60)
        base = fused_sweep_cells(*args, **kw)
        tiled = fused_sweep_cells(*args, n_blk=8, **kw)
        for a, b in zip(base, tiled):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("split", [1, 2, 3])
    def test_sub_queue_calls_chain_like_whole_queue(self, split):
        """cell_start/num_cells (the pipelined ring's half-queues): sweeping
        [0, split) then [split, k) in two calls must reproduce the whole-
        queue call bit-for-bit — the boundary rebuild makes the split free."""
        from repro.kernels.fused_sweep import fused_sweep_cells
        T = 16
        args = self._queue_setup(T=T, B=4)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60)
        whole = fused_sweep_cells(*args, **kw)

        tok_doc, tok_wrd, tok_valid, tok_bound, z, u, n_td, n_wt, n_t = args
        k = tok_doc.shape[0]
        z0, n_td0, nwt0, n_t0, _ = fused_sweep_cells(
            *args, cell_start=0, num_cells=split, **kw)
        assert z0.shape[0] == split and nwt0.shape[0] == split
        z1, n_td1, nwt1, n_t1, _ = fused_sweep_cells(
            tok_doc, tok_wrd, tok_valid, tok_bound, z, u,
            n_td0, n_wt, n_t0, cell_start=split, num_cells=k - split, **kw)
        got = (jnp.concatenate([z0, z1]), n_td1,
               jnp.concatenate([nwt0, nwt1]), n_t1)
        for a, b in zip(got, whole[:4]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_sub_queue_matches_ref_oracle(self):
        from repro.kernels.fused_sweep import fused_sweep_cells
        from repro.kernels.fused_sweep.ref import fused_sweep_cells_ref
        T = 16
        args = self._queue_setup(T=T, B=4, seed=17)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60,
                  cell_start=1, num_cells=2)
        got = fused_sweep_cells(*args, **kw)
        ref = fused_sweep_cells_ref(*args, **kw)
        assert got[0].shape[0] == 2
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bad_cell_range_rejected(self):
        from repro.kernels.fused_sweep import fused_sweep_cells
        T = 16
        args = self._queue_setup(T=T, B=4)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60)
        for cell_start, num_cells in ((-1, 2), (3, 2), (0, 5)):
            with pytest.raises(ValueError, match="cell range"):
                fused_sweep_cells(*args, cell_start=cell_start,
                                  num_cells=num_cells, **kw)

    def test_queue_length_mismatch_rejected(self):
        from repro.kernels.fused_sweep import fused_sweep_cells
        T = 8
        zeros = lambda *s: jnp.zeros(s, jnp.int32)
        with pytest.raises(ValueError, match="queue length"):
            fused_sweep_cells(
                zeros(2, 4), zeros(2, 4), zeros(2, 4), zeros(2, 4),
                zeros(2, 4), jnp.zeros((2, 4), jnp.float32),
                zeros(3, T), zeros(3, 5, T), zeros(T),
                alpha=0.5, beta=0.01, beta_bar=0.05)

    def test_all_empty_cells_queue_is_noop(self):
        """A queue whose every cell is pure padding (valid=0 throughout,
        the layout's empty-cell convention): blocks still page through
        the kernel once each, and everything comes back bit-unchanged —
        the pad/ds no-op path doc tiling reuses."""
        from repro.kernels.fused_sweep import fused_sweep_cells
        from repro.kernels.fused_sweep.ref import fused_sweep_cells_ref
        T, k, L, J = 16, 3, 8, 5
        rng = np.random.default_rng(23)
        zeros = lambda *s: jnp.zeros(s, jnp.int32)
        n_td = jnp.asarray(rng.integers(0, 4, (7, T)), jnp.int32)
        n_wt = jnp.asarray(rng.integers(0, 4, (k, J, T)), jnp.int32)
        n_t = jnp.asarray(rng.integers(1, 40, (T,)), jnp.int32)
        args = (zeros(k, L), zeros(k, L), zeros(k, L), zeros(k, L),
                zeros(k, L), jnp.full((k, L), 0.5, jnp.float32),
                n_td, n_wt, n_t)
        kw = dict(alpha=0.5, beta=0.01, beta_bar=0.05)
        got = fused_sweep_cells(*args, **kw)
        ref = fused_sweep_cells_ref(*args, **kw)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(n_td))
        np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(n_wt))
        np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(n_t))

    def test_jmax_one_blocks(self):
        """J_max == 1: every block holds a single word, so every n_wt row
        access is the degenerate pl.ds(0, 1) and the whole cell is one
        word run (a single boundary rebuild) — the narrowest block page
        the kernel supports."""
        from repro.kernels.fused_sweep import fused_sweep_cells
        from repro.kernels.fused_sweep.ref import fused_sweep_cells_ref
        T, k, L, I, n_valid = 16, 3, 12, 5, 9
        rng = np.random.default_rng(29)
        tok_doc = rng.integers(0, I, (k, L)).astype(np.int32)
        tok_wrd = np.zeros((k, L), np.int32)           # one word per block
        tok_valid = np.zeros((k, L), np.int32)
        tok_valid[:, :n_valid] = 1
        tok_bound = np.zeros((k, L), np.int32)
        tok_bound[:, 0] = 1                            # single word run
        z = np.where(tok_valid, rng.integers(0, T, (k, L)), 0)
        u = rng.random((k, L)).astype(np.float32)
        n_td = np.zeros((I, T), np.int32)
        n_wt = np.zeros((k, 1, T), np.int32)
        n_t = np.zeros((T,), np.int32)
        c_i, l_i = np.nonzero(tok_valid)
        zz = z[c_i, l_i]
        np.add.at(n_td, (tok_doc[c_i, l_i], zz), 1)
        np.add.at(n_wt, (c_i, 0, zz), 1)
        np.add.at(n_t, zz, 1)
        i32 = lambda a: jnp.asarray(a, jnp.int32)
        args = (i32(tok_doc), i32(tok_wrd), i32(tok_valid), i32(tok_bound),
                i32(z), jnp.asarray(u), i32(n_td), i32(n_wt), i32(n_t))
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * k)
        got = fused_sweep_cells(*args, **kw)
        ref = fused_sweep_cells_ref(*args, **kw)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the sweep really did move counts (not vacuously empty)
        assert int(np.abs(np.asarray(got[2]) - np.asarray(n_wt)).sum()) > 0


class TestRaggedStreamKernel:
    """Flat-grid ragged stream (scalar-prefetch block paging): the same
    queue as TestCellBatchKernel, stored CSR-style — must run the chain
    bit-identically to the dense cell-batch grid and to its oracle."""

    def _stream_setup(self, T=16, B=4, seed=11, tile=None):
        from repro.data.sharding import build_layout
        corpus, _, _ = synthetic.make_corpus(
            num_docs=18, vocab_size=60, num_topics=8, mean_doc_len=12.0,
            seed=seed)
        dense = build_layout(corpus, n_workers=1, T=T, n_blocks=B)
        rag = build_layout(corpus, n_workers=1, T=T, n_blocks=B,
                           layout="ragged", tile=tile)
        rng = np.random.default_rng(seed)
        N = corpus.num_tokens
        z_c = rng.integers(0, T, N).astype(np.int32)
        u_c = rng.random(N).astype(np.float32)
        n_td = np.zeros((rag.I_max, T), np.int32)
        n_wt = np.zeros((B, rag.J_max, T), np.int32)
        n_t = np.zeros((T,), np.int32)
        _, b_i, d_i, j_i = rag.token_coords()
        np.add.at(n_td, (d_i, z_c), 1)
        np.add.at(n_wt, (b_i, j_i, z_c), 1)
        np.add.at(n_t, z_c, 1)
        i32 = lambda a: jnp.asarray(a, jnp.int32)

        def mk(lay):
            # W = 1: the dense queue is tok[0] (k, L); the ragged stream is
            # tok[0, 0] (S,) — chunk 0 holds all k cells.
            sel = (lambda a: a[0, 0]) if lay.kind == "ragged" \
                else (lambda a: a[0])
            return (i32(sel(lay.tok_doc)), i32(sel(lay.tok_wrd)),
                    i32(sel(lay.tok_valid)), i32(sel(lay.tok_bound)),
                    i32(sel(lay.place_canonical(z_c))),
                    jnp.asarray(sel(lay.place_canonical(u_c))))
        counts = (i32(n_td), i32(n_wt), i32(n_t))
        return dense, rag, mk(dense), mk(rag), counts

    def test_ragged_matches_ref_and_dense_cells(self):
        from repro.kernels.fused_sweep import (fused_sweep_cells,
                                               fused_sweep_ragged)
        from repro.kernels.fused_sweep.ref import fused_sweep_ragged_ref
        T = 16
        dense, rag, dense_tok, rag_tok, counts = self._stream_setup(T=T)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60)
        cot = jnp.asarray(rag.cell_of_tile[0, 0])

        got = fused_sweep_ragged(*rag_tok, cot, *counts,
                                 n_blk=rag.tile, **kw)
        ref = fused_sweep_ragged_ref(*rag_tok, cot, *counts,
                                     n_blk=rag.tile, **kw)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # vs the dense cell-batch kernel: per-token z and all tables equal
        dense_out = fused_sweep_cells(*dense_tok, *counts, **kw)
        np.testing.assert_array_equal(
            dense.extract_canonical(np.asarray(dense_out[0])[None, :]),
            rag.extract_canonical(np.asarray(got[0])[None, None, :]))
        for a, b in zip(dense_out[1:4], got[1:4]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_tile_split_chains_like_whole_stream(self):
        """The pipelined ring's halves: tiles [0, tile_split) over cells
        [0, k0) then the rest must reproduce the whole-stream call."""
        from repro.data.sharding import half_queue_split
        from repro.kernels.fused_sweep import fused_sweep_ragged
        T = 16
        _, rag, _, rag_tok, counts = self._stream_setup(T=T, seed=13)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60)
        cot = jnp.asarray(rag.cell_of_tile[0, 0])
        n_td, n_wt, n_t = counts
        whole = fused_sweep_ragged(*rag_tok, cot, *counts,
                                   n_blk=rag.tile, **kw)
        k0, r0 = half_queue_split(rag.k), rag.tile_split
        assert 0 < r0 < rag.n_tiles
        z0, n_td0, nwt0, n_t0, _ = fused_sweep_ragged(
            *rag_tok, cot, *counts, n_blk=rag.tile,
            tile_start=0, num_tiles=r0, cell_start=0, num_cells=k0, **kw)
        assert nwt0.shape[0] == k0
        z1, n_td1, nwt1, n_t1, _ = fused_sweep_ragged(
            *rag_tok, cot, n_td0, n_wt, n_t0, n_blk=rag.tile,
            tile_start=r0, num_tiles=rag.n_tiles - r0,
            cell_start=k0, num_cells=rag.k - k0, **kw)
        got = (jnp.concatenate([z0, z1]), n_td1,
               jnp.concatenate([nwt0, nwt1]), n_t1)
        for a, b in zip(got, whole[:4]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_tiny_tile_crosses_cell_and_tile_boundaries(self):
        """tile=8 on word-sized cells: many grid steps per cell, page-in
        exactly at cell starts — still bit-equal to the oracle."""
        from repro.kernels.fused_sweep import fused_sweep_ragged
        from repro.kernels.fused_sweep.ref import fused_sweep_ragged_ref
        T = 16
        _, rag, _, rag_tok, counts = self._stream_setup(T=T, seed=17,
                                                        tile=8)
        assert rag.tile == 8 and rag.n_tiles > rag.k
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60)
        cot = jnp.asarray(rag.cell_of_tile[0, 0])
        got = fused_sweep_ragged(*rag_tok, cot, *counts, n_blk=8, **kw)
        ref = fused_sweep_ragged_ref(*rag_tok, cot, *counts, n_blk=8, **kw)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bad_ranges_rejected(self):
        from repro.kernels.fused_sweep import fused_sweep_ragged
        T = 16
        _, rag, _, rag_tok, counts = self._stream_setup(T=T)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60,
                  n_blk=rag.tile)
        cot = jnp.asarray(rag.cell_of_tile[0, 0])
        with pytest.raises(ValueError, match="tile range"):
            fused_sweep_ragged(*rag_tok, cot, *counts,
                               tile_start=0, num_tiles=rag.n_tiles + 1, **kw)
        with pytest.raises(ValueError, match="cell range"):
            fused_sweep_ragged(*rag_tok, cot, *counts,
                               cell_start=rag.k, num_cells=1, **kw)
        with pytest.raises(ValueError, match="does not tile"):
            fused_sweep_ragged(*rag_tok, cot, *counts,
                               alpha=kw["alpha"], beta=kw["beta"],
                               beta_bar=kw["beta_bar"], n_blk=rag.tile + 1)


class TestSparseRBucket:
    """Doc-sparse r-bucket (DESIGN.md §7a): ``r_mode="sparse"`` walks the
    per-doc compacted side tables instead of recompacting the dense
    ``n_td`` row per token.  Both modes draw from the same capacity-``cap``
    compacted vector, so every kernel variant must stay bit-identical to
    its dense twin — and the returned side tables must equal a fresh
    compaction of the final ``n_td``."""

    @staticmethod
    def _tables_ok(topics, counts, n_td, cap):
        from repro.kernels.fused_sweep import rbucket
        ref_t, ref_c = rbucket.build_side_table(jnp.asarray(n_td), cap)
        return (bool(jnp.array_equal(topics, ref_t))
                and bool(jnp.array_equal(counts, ref_c)))

    @pytest.mark.parametrize("T", [16, 64])
    def test_sparse_tokens_match_dense_and_ref(self, T):
        corpus, state, doc_ids, word_ids, order, boundary = _setup(
            T, 15, 48, 11.0, seed=T + 1)
        kw = dict(alpha=50.0 / T, beta=0.01,
                  beta_bar=0.01 * corpus.num_words)
        tok = _fused_inputs(state, doc_ids, word_ids, order, boundary)
        dense = fused_sweep_tokens(*tok, state.n_td, state.n_wt,
                                   state.n_t, **kw)
        sparse = fused_sweep_tokens(*tok, state.n_td, state.n_wt,
                                    state.n_t, r_mode="sparse", **kw)
        assert len(sparse) == 7
        for a, b in zip(dense, sparse[:5]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        sref = fused_sweep_ref(*tok, state.n_td, state.n_wt, state.n_t,
                               r_mode="sparse", **kw)
        for a, b in zip(sparse, sref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert self._tables_ok(sparse[5], sparse[6], sparse[1], T)

    def test_sparse_cells_and_ragged_match_dense(self):
        from repro.data.sharding import build_layout
        from repro.kernels.fused_sweep import (fused_sweep_cells,
                                               fused_sweep_ragged)
        T = 16
        helper = TestCellBatchKernel()
        args = helper._queue_setup(T=T, B=4, seed=19)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60)
        dense = fused_sweep_cells(*args, **kw)
        sparse = fused_sweep_cells(*args, r_mode="sparse", **kw)
        assert len(sparse) == 7
        for a, b in zip(dense, sparse[:5]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert self._tables_ok(sparse[5], sparse[6], sparse[1], T)

        rhelper = TestRaggedStreamKernel()
        _, rag, _, rag_tok, counts = rhelper._stream_setup(T=T, seed=19)
        cot = jnp.asarray(rag.cell_of_tile[0, 0])
        rdense = fused_sweep_ragged(*rag_tok, cot, *counts,
                                    n_blk=rag.tile, **kw)
        rsparse = fused_sweep_ragged(*rag_tok, cot, *counts,
                                     n_blk=rag.tile, r_mode="sparse", **kw)
        for a, b in zip(rdense, rsparse[:5]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert self._tables_ok(rsparse[5], rsparse[6], rsparse[1], T)

    def test_sub_T_cap_exact_when_valid(self):
        """A capacity below T is exact as long as no doc ever holds more
        than ``cap`` distinct topics mid-sweep; both modes share the cap,
        so the sparse run must still equal the dense run at the same cap."""
        T = 64
        corpus, state, doc_ids, word_ids, order, boundary = _setup(
            T, 15, 48, 6.0, seed=3)
        # distinct-topics-per-doc is bounded by doc length, +1 headroom
        # for the transient insert-before-remove inside a token update
        cap = min(T, int(np.bincount(np.asarray(corpus.doc_ids)).max()) + 1)
        assert cap < T
        kw = dict(alpha=50.0 / T, beta=0.01,
                  beta_bar=0.01 * corpus.num_words)
        tok = _fused_inputs(state, doc_ids, word_ids, order, boundary)
        dense = fused_sweep_tokens(*tok, state.n_td, state.n_wt,
                                   state.n_t, r_cap=cap, **kw)
        sparse = fused_sweep_tokens(*tok, state.n_td, state.n_wt,
                                    state.n_t, r_mode="sparse", r_cap=cap,
                                    **kw)
        for a, b in zip(dense, sparse[:5]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert self._tables_ok(sparse[5], sparse[6], sparse[1], cap)

    def test_bad_args_rejected(self):
        from repro.kernels.fused_sweep import fused_vmem_bytes
        T = 8
        zeros = lambda *s: jnp.zeros(s, jnp.int32)
        base = (zeros(4), zeros(4), jnp.ones((4,), jnp.int32),
                jnp.ones((4,), jnp.int32), zeros(4),
                jnp.zeros((4,), jnp.float32),
                zeros(3, T), zeros(5, T), zeros(T))
        kw = dict(alpha=0.5, beta=0.01, beta_bar=0.05)
        with pytest.raises(ValueError, match="r_mode"):
            fused_sweep_tokens(*base, r_mode="compact", **kw)
        with pytest.raises(ValueError, match="r_cap"):
            fused_sweep_tokens(*base, r_mode="sparse", r_cap=T + 1, **kw)
        with pytest.raises(ValueError, match="side tables"):
            fused_sweep_tokens(*base, topics=zeros(3, T),
                               counts=zeros(3, T), **kw)
        # VMEM model: sparse adds exactly the two (I, cap) i32 tables
        # (double-buffered), monotone in cap
        a = fused_vmem_bytes(100, 10, T, r_cap=4)
        b = fused_vmem_bytes(100, 10, T, r_cap=8)
        assert b > a > fused_vmem_bytes(100, 10, T)


class TestNomadFusedInnerMode:
    def test_single_device_ring_matches_scan(self):
        from repro.core.nomad import NomadLDA
        from repro.data.sharding import build_layout
        T = 16
        corpus, _, _ = synthetic.make_corpus(
            num_docs=20, vocab_size=50, num_topics=8, mean_doc_len=12.0,
            seed=9)
        layout = build_layout(corpus, n_workers=1, T=T)
        mesh = jax.make_mesh((1,), ("worker",))
        results = {}
        for mode in ("scan", "fused"):
            lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=layout,
                           alpha=50.0 / T, beta=0.01, sync_mode="stoken",
                           inner_mode=mode)
            arrays = lda.init_arrays(seed=0)
            for it in range(2):
                arrays = lda.sweep(arrays, seed=it)
            results[mode] = (*lda.global_counts(arrays),
                             np.asarray(arrays["z"]))
        for a, b in zip(results["scan"], results["fused"]):
            np.testing.assert_array_equal(a, b)
