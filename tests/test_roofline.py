"""Roofline machinery tests: the while-aware HLO cost analyzer must agree
with analytic flop counts on controlled programs (the reason it exists:
XLA's cost_analysis counts scan bodies once)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import analyze_hlo
from repro.roofline.analysis import model_flops, roofline_terms


def _cost_of(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo(txt)


class TestHloCost:
    def test_plain_matmul(self):
        N = 128
        c = _cost_of(lambda a, b: a @ b,
                     jnp.zeros((N, N)), jnp.zeros((N, N)))
        assert c.flops == 2 * N ** 3

    def test_scan_scales_by_trip_count(self):
        N, L = 128, 12
        w = jnp.zeros((L, N, N))
        x = jnp.zeros((N, N))

        def f(w, x):
            return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0].sum()
        c = _cost_of(f, w, x)
        np.testing.assert_allclose(c.flops, L * 2 * N ** 3, rtol=0.02)

    def test_nested_scans(self):
        N, L1, L2 = 64, 3, 5
        w = jnp.zeros((L1, L2, N, N))
        x = jnp.zeros((N, N))

        def inner(x, ws):
            return jax.lax.scan(lambda c, wi: (c @ wi, None), x, ws)[0]

        def f(w, x):
            return jax.lax.scan(lambda c, ws: (inner(c, ws), None),
                                x, w)[0].sum()
        c = _cost_of(f, w, x)
        np.testing.assert_allclose(c.flops, L1 * L2 * 2 * N ** 3, rtol=0.05)

    def test_batched_dot(self):
        B, M, K, N = 4, 32, 64, 16
        c = _cost_of(lambda a, b: jnp.einsum("bmk,bkn->bmn", a, b),
                     jnp.zeros((B, M, K)), jnp.zeros((B, K, N)))
        assert c.flops == 2 * B * M * K * N

    def test_forward_matches_analytic(self):
        """Whole-model check: smoke forward ≈ 2·N·D."""
        from repro.configs import get_config
        from repro.models import transformer
        cfg = get_config("granite-3-2b").smoke()
        params = transformer.init_params(cfg, jax.random.key(0))
        tok = jnp.zeros((2, 64), jnp.int32)
        c = _cost_of(lambda p, t: transformer.forward(
            p, cfg, {"tokens": t})[0], params, tok)
        n = sum(x.size for x in jax.tree_util.tree_leaves(params))
        est = 2 * n * 2 * 64
        assert 0.8 < c.flops / est < 1.4, (c.flops, est)

    def test_bytes_nonzero_and_plausible(self):
        N = 256
        c = _cost_of(lambda a, b: a @ b,
                     jnp.zeros((N, N)), jnp.zeros((N, N)))
        # at least operands + result once
        assert c.bytes >= 3 * N * N * 4


class TestRooflineTerms:
    def test_terms_and_bottleneck(self):
        t = roofline_terms(197e12, 819e9, 50e9)   # exactly 1 second each
        assert all(abs(v - 1.0) < 1e-9 for v in t.values())

    def test_model_flops_moe_uses_active(self):
        dense = model_flops("qwen3-8b", "train_4k")
        moe = model_flops("kimi-k2-1t-a32b", "train_4k")
        # kimi has ~32B active vs qwen 8B: ratio ≈ 4, not 125 (1T/8B)
        assert 2 < moe / dense < 8

    def test_decode_counts_one_token(self):
        d = model_flops("qwen3-8b", "decode_32k")
        p = model_flops("qwen3-8b", "prefill_32k")
        assert p / d > 1000   # prefill processes 32k×32 tokens, decode 128
