"""Likelihood + synthetic-data property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cgs, likelihood
from repro.data import synthetic
from repro.data.corpus import Corpus


class TestLikelihood:
    def _state(self, seed=0, T=8):
        corpus, _, _ = synthetic.make_corpus(
            num_docs=30, vocab_size=64, num_topics=T, mean_doc_len=20.0,
            seed=seed)
        return corpus, cgs.init_state(corpus, T, jax.random.key(seed))

    def test_finite_and_negative(self):
        corpus, state = self._state()
        ll = likelihood.log_likelihood(state, 0.5, 0.01)
        assert np.isfinite(ll) and ll < 0

    def test_concentrated_beats_random(self):
        """A topic-concentrated assignment must have higher LL than a
        random one (the quantity CGS climbs)."""
        corpus, state = self._state(seed=3)
        T = state.n_t.shape[0]
        # concentrated: all tokens of a word get the same topic
        z_conc = jnp.asarray(corpus.word_ids % T, jnp.int32)
        n_td, n_wt, n_t = cgs.counts_from_assignments(
            jnp.asarray(corpus.doc_ids), jnp.asarray(corpus.word_ids),
            z_conc, corpus.num_docs, corpus.num_words, T)
        conc = cgs.LDAState(z=z_conc, n_td=n_td, n_wt=n_wt, n_t=n_t,
                            key=state.key)
        assert likelihood.log_likelihood(conc, 0.5, 0.01) > \
            likelihood.log_likelihood(state, 0.5, 0.01)

    @given(seed=st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_invariant_under_token_relabeling(self, seed):
        """LL depends only on the count tables, not token order."""
        corpus, state = self._state(seed=seed)
        ll1 = likelihood.log_likelihood(state, 0.3, 0.02)
        # permute occurrences (z permuted consistently) — counts unchanged
        perm = np.random.default_rng(seed).permutation(corpus.num_tokens)
        state2 = state._replace(z=state.z[perm])
        # counts were computed from the original z; rebuild from permuted
        # arrays to confirm identical tables
        n_td, n_wt, n_t = cgs.counts_from_assignments(
            jnp.asarray(corpus.doc_ids[perm]),
            jnp.asarray(corpus.word_ids[perm]),
            state2.z, corpus.num_docs, corpus.num_words,
            state.n_t.shape[0])
        np.testing.assert_array_equal(np.asarray(n_td),
                                      np.asarray(state.n_td))
        ll2 = likelihood.log_likelihood(
            cgs.LDAState(z=state2.z, n_td=n_td, n_wt=n_wt, n_t=n_t,
                         key=state.key), 0.3, 0.02)
        assert ll1 == pytest.approx(ll2, rel=1e-6)


class TestSynthetic:
    @given(seed=st.integers(0, 100))
    @settings(max_examples=15, deadline=None)
    def test_corpus_well_formed(self, seed):
        corpus, theta, phi = synthetic.make_corpus(
            num_docs=20, vocab_size=50, num_topics=4, mean_doc_len=10.0,
            seed=seed)
        assert (corpus.doc_ids >= 0).all()
        assert (corpus.doc_ids < corpus.num_docs).all()
        assert (corpus.word_ids >= 0).all()
        assert (corpus.word_ids < corpus.num_words).all()
        np.testing.assert_allclose(theta.sum(1), 1.0, rtol=1e-6)
        np.testing.assert_allclose(phi.sum(1), 1.0, rtol=1e-6)
        # doc ids are contiguous runs (generator emits per-doc tokens)
        assert (np.diff(corpus.doc_ids) >= 0).all()

    def test_topic_structure_recoverable(self):
        """Words drawn from distinct topics should co-occur by topic —
        tokens of the dominant topic use that topic's high-mass words."""
        corpus, theta, phi = synthetic.make_corpus(
            num_docs=100, vocab_size=200, num_topics=2, mean_doc_len=50.0,
            alpha=0.05, seed=1)
        # doc-dominant topic from theta; word-dominant topic from phi
        doc_topic = theta.argmax(1)[corpus.doc_ids]
        word_topic = phi.argmax(0)[corpus.word_ids]
        agreement = (doc_topic == word_topic).mean()
        assert agreement > 0.6, agreement
