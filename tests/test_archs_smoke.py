"""Per-architecture smoke tests (spec requirement f).

Each assigned arch instantiates a REDUCED same-family variant (2 layers,
d_model ≤ 512, ≤ 4 experts) and runs one forward + one train step on CPU,
asserting output shapes and finiteness.  Decode correctness: prefill+decode
must match the full-context forward at the decoded position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer
from repro.serve.serve_step import decode_step, init_cache, prefill
from repro.train.train_step import init_train_state, loss_fn, make_train_step

# Model-zoo coverage is minutes-long; excluded from the fast signal via
# `pytest -m "not slow"` (tier-1 still runs everything).
pytestmark = pytest.mark.slow

ARCH_NAMES = sorted(ARCHS.keys())
B, S = 2, 32


def _batch(cfg, key):
    kt, kp, kf, kl = jax.random.split(key, 4)
    if cfg.modality == "audio_frames":
        return {
            "frames": jax.random.normal(kf, (B, S, cfg.frontend_dim)),
            "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
        }
    if cfg.modality == "image_patches":
        return {
            "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
            "patches": jax.random.normal(
                kp, (B, cfg.frontend_tokens, cfg.frontend_dim)),
        }
    return {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size)}


@pytest.fixture(scope="module")
def states():
    return {}


def _get_state(states, name):
    if name not in states:
        cfg = get_config(name).smoke()
        states[name] = (cfg, init_train_state(cfg, jax.random.key(0)))
    return states[name]


class TestForward:
    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_forward_shapes_finite(self, states, name):
        cfg, state = _get_state(states, name)
        batch = _batch(cfg, jax.random.key(1))
        logits, _, aux = jax.jit(
            lambda p, b: transformer.forward(p, cfg, b)
        )(state.params, batch)
        S_out = S + (cfg.frontend_tokens if cfg.modality == "image_patches"
                     else 0)
        assert logits.shape == (B, S_out, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), name
        if cfg.num_experts:
            assert bool(jnp.isfinite(aux)), name

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_train_step_improves_nothing_nan(self, states, name):
        cfg, state = _get_state(states, name)
        batch = _batch(cfg, jax.random.key(2))
        step = jax.jit(make_train_step(cfg, lr=1e-3, remat=False))
        state2, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"])), (name, metrics)
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        # params actually moved
        moved = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a - b).max()),
            state.params, state2.params)
        assert max(jax.tree_util.tree_leaves(moved)) > 0

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_train_step_with_remat(self, states, name):
        cfg, state = _get_state(states, name)
        batch = _batch(cfg, jax.random.key(3))
        step = jax.jit(make_train_step(cfg, lr=1e-3, remat=True))
        _, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"])), name


class TestDecode:
    """prefill + decode must reproduce the full forward (decoder archs)."""

    @pytest.mark.parametrize("name", [
        n for n in ARCH_NAMES if get_config(n).causal
        and get_config(n).modality == "text"])
    def test_decode_matches_forward(self, states, name):
        cfg, state = _get_state(states, name)
        params = state.params
        key = jax.random.key(4)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

        # full forward
        logits_full, _, _ = jax.jit(
            lambda p, t: transformer.forward(p, cfg, {"tokens": t})
        )(params, tokens)

        # prefill first S-1, then decode token S-1
        cache = init_cache(cfg, B, S + 8)
        _, cache = prefill(params, cfg, {"tokens": tokens[:, :S - 1],
                                         "pos": jnp.zeros((B,), jnp.int32)},
                           cache)
        _, logits_dec, _ = decode_step(
            params, cfg, tokens[:, S - 1:S],
            jnp.full((B,), S - 1, jnp.int32), cache)
        np.testing.assert_allclose(
            np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]),
            rtol=2e-3, atol=2e-3)


class TestConfigs:
    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_full_config_matches_assignment(self, name):
        cfg = get_config(name)
        spec = {
            "kimi-k2-1t-a32b": (61, 7168, 64, 8, 163840),
            "gemma2-27b": (46, 4608, 32, 16, 256000),
            "hubert-xlarge": (48, 1280, 16, 16, 504),
            "zamba2-2.7b": (54, 2560, 32, 32, 32000),
            "internvl2-1b": (24, 896, 14, 2, 151655),
            "mamba2-1.3b": (48, 2048, 0, 0, 50280),
            "phi4-mini-3.8b": (32, 3072, 24, 8, 200064),
            "deepseek-moe-16b": (28, 2048, 16, 16, 102400),
            "granite-3-2b": (40, 2048, 32, 8, 49155),
            "qwen3-8b": (36, 4096, 32, 8, 151936),
        }[name]
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, cfg.vocab_size) == spec

    def test_param_counts_plausible(self):
        """Analytic sizes should be in the advertised ballpark."""
        expect = {
            "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
            "gemma2-27b": (20e9, 32e9),
            "mamba2-1.3b": (1.0e9, 1.7e9),
            "phi4-mini-3.8b": (3.0e9, 4.8e9),
            "deepseek-moe-16b": (13e9, 20e9),
            "granite-3-2b": (2.0e9, 3.3e9),
            "qwen3-8b": (6.5e9, 9.5e9),
            "zamba2-2.7b": (2.0e9, 3.6e9),
        }
        for name, (lo, hi) in expect.items():
            n = get_config(name).param_count()
            assert lo <= n <= hi, (name, f"{n:.3e}")

    def test_smoke_configs_are_small(self):
        for name in ARCH_NAMES:
            s = get_config(name).smoke()
            assert s.num_layers == 2 and s.d_model <= 512
            assert s.num_experts <= 4

    def test_moe_active_params(self):
        cfg = get_config("kimi-k2-1t-a32b")
        active = cfg.active_param_count()
        assert active < 0.1 * cfg.param_count()  # a32b of 1t
        assert 20e9 < active < 60e9
