"""Shared test configuration.

Installs a minimal ``hypothesis`` stand-in when the real package is absent
so the property-based test modules collect and run everywhere (the container
image does not ship hypothesis).  The shim implements exactly the API
surface the suite uses — ``given``, ``settings``, ``strategies.integers``,
``strategies.floats`` (plus a few obvious neighbours) — with deterministic
draws: bound values first, then seeded pseudo-random examples.  With the
real hypothesis installed the shim is inert.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types

# Cap shim example counts so `@settings(max_examples=60)` style requests do
# not dominate wall-clock; override with REPRO_SHIM_MAX_EXAMPLES.
_SHIM_MAX_EXAMPLES = int(os.environ.get("REPRO_SHIM_MAX_EXAMPLES", "10"))
_SHIM_DEFAULT_EXAMPLES = 8


class _Strategy:
    """A draw function plus the interesting boundary examples."""

    def __init__(self, draw, boundary=()):
        self._draw = draw
        self.boundary = tuple(boundary)

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def example(self, rng: random.Random, i: int):
        if i < len(self.boundary):
            return self.boundary[i]
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)),
                         tuple(fn(b) for b in self.boundary))


def _build_shim() -> types.ModuleType:
    st = types.ModuleType("hypothesis.strategies")

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         (min_value, max_value))

    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         (min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5, (False, True))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))],
                         (seq[0],) if seq else ())

    def lists(elements, min_size=0, max_size=8, **_kw):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def just(value):
        return _Strategy(lambda rng: value, (value,))

    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.lists = lists
    st.just = just

    class settings:  # noqa: N801 — mirrors hypothesis' lowercase class
        def __init__(self, max_examples=_SHIM_DEFAULT_EXAMPLES,
                     deadline=None, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._shim_settings = self
            return fn

    class _UnsatisfiedAssumption(Exception):
        """Raised by assume(False): skip this example, like real hypothesis."""

    def assume(condition):
        if not condition:
            raise _UnsatisfiedAssumption()
        return True

    def given(*arg_strategies, **kw_strategies):
        if arg_strategies:
            raise TypeError("hypothesis shim supports keyword strategies only")

        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = (getattr(wrapper, "_shim_settings", None)
                       or getattr(fn, "_shim_settings", None))
                requested = cfg.max_examples if cfg else _SHIM_DEFAULT_EXAMPLES
                n = max(1, min(requested, _SHIM_MAX_EXAMPLES))
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for i in range(n):
                    drawn = {name: strat.example(rng, i)
                             for name, strat in kw_strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except _UnsatisfiedAssumption:
                        continue

            # Hide the strategy-filled parameters from pytest's fixture
            # resolution (real hypothesis does the same bookkeeping).
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in kw_strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper

        return decorate

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.assume = assume
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    mod.__shim__ = True
    return mod


def _install_hypothesis_shim() -> None:
    try:
        import hypothesis  # noqa: F401  — real package present
        return
    except ImportError:
        pass
    mod = _build_shim()
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies


_install_hypothesis_shim()
