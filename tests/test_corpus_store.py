"""Out-of-core corpus store, incremental layout update, and bit-exact
chain checkpoint/resume (ISSUE 7, DESIGN.md §9).

Store/update tests are pure-numpy; checkpoint round-trips run a real
``NomadLDA`` on a degenerate W=1 ring in-process (per the dry-run
isolation rule), and the full {dense, ragged} × {barrier, pipelined} ×
r_mode kill-at-round-r resume matrix runs ``launch/resume_check.py`` in
a subprocess with faked devices.
"""
import json
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.nomad import NomadLDA
from repro.data import synthetic
from repro.data.corpus_store import (CorpusStore, build_layout_from_store,
                                     carry_assignments, remap_canonical,
                                     update_layout)
from repro.data.sharding import build_layout, counts_from_layout
from repro.train import checkpoint

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _corpus(num_docs=40, vocab=96, seed=0, mean_len=15.0):
    corpus, _, _ = synthetic.make_corpus(
        num_docs=num_docs, vocab_size=vocab, num_topics=8,
        mean_doc_len=mean_len, seed=seed)
    return corpus


class TestCorpusStore:
    def test_create_open_append(self, tmp_path):
        p = str(tmp_path / "s")
        store = CorpusStore.create(p, num_words=50)
        assert store.num_docs == 0 and store.num_tokens == 0
        store.append(np.array([0, 0, 1], np.int32),
                     np.array([3, 4, 3], np.int32), num_docs=2)
        store.append(np.array([2, 2], np.int32),
                     np.array([10, 11], np.int32), num_docs=3)
        again = CorpusStore.open(p)
        assert again.num_docs == 3
        assert again.num_tokens == 5
        assert again.num_shards == 2
        np.testing.assert_array_equal(again.doc_lengths(), [2, 1, 2])
        c = again.to_corpus()
        np.testing.assert_array_equal(c.doc_ids, [0, 0, 1, 2, 2])

    def test_create_refuses_existing(self, tmp_path):
        p = str(tmp_path / "s")
        CorpusStore.create(p, num_words=10)
        with pytest.raises(FileExistsError):
            CorpusStore.create(p, num_words=10)

    def test_append_validates(self, tmp_path):
        store = CorpusStore.create(str(tmp_path / "s"), num_words=10)
        with pytest.raises(ValueError, match="range"):
            store.append(np.array([0], np.int32),
                         np.array([99], np.int32), num_docs=1)
        with pytest.raises(ValueError, match="1-D"):
            store.append(np.zeros((2, 2), np.int32),
                         np.zeros((2, 2), np.int32), num_docs=1)

    def test_retire_updates_stats_and_stream(self, tmp_path):
        corpus = _corpus(seed=4)
        store = CorpusStore.from_corpus(corpus, str(tmp_path / "s"),
                                        tokens_per_shard=64)
        store.retire(np.array([1, 7], np.int32))
        live = corpus.subset(~np.isin(np.arange(corpus.num_docs), [1, 7]))
        back = store.to_corpus()
        np.testing.assert_array_equal(back.doc_ids, live.doc_ids)
        np.testing.assert_array_equal(back.word_ids, live.word_ids)
        np.testing.assert_array_equal(store.doc_lengths(),
                                      live.doc_lengths())
        np.testing.assert_array_equal(store.word_freqs(),
                                      live.word_freqs())
        with pytest.raises(ValueError, match="retired"):
            store.retire(np.array([1], np.int32))
        with pytest.raises(ValueError, match="retired"):
            store.append(np.array([1], np.int32), np.array([0], np.int32))

    def test_chunked_build_matches_monolithic_after_retire(self, tmp_path):
        corpus = _corpus(seed=2)
        store = CorpusStore.from_corpus(corpus, str(tmp_path / "s"),
                                        tokens_per_shard=100)
        store.retire(np.array([0, 13], np.int32))
        live = corpus.subset(~np.isin(np.arange(corpus.num_docs), [0, 13]))
        mono = build_layout(live, n_workers=2, T=8, n_blocks=4, doc_tile=4)
        chunk = build_layout_from_store(store, n_workers=2, T=8,
                                        n_blocks=4, doc_tile=4)
        np.testing.assert_array_equal(mono.tok_doc, chunk.tok_doc)
        np.testing.assert_array_equal(mono.tok_gwrd, chunk.tok_gwrd)
        np.testing.assert_array_equal(mono.canon_idx, chunk.canon_idx)


class TestUpdateLayout:
    def _setup(self, kind="dense", seed=3):
        corpus = _corpus(num_docs=60, vocab=96, seed=seed, mean_len=20.0)
        lay = build_layout(corpus, n_workers=4, T=8, n_blocks=8,
                           layout=kind, doc_tile=4)
        return corpus, lay

    @pytest.mark.parametrize("kind", ["dense", "ragged"])
    def test_survivors_keep_uid_and_order(self, kind):
        corpus, lay = self._setup(kind)
        rng = np.random.default_rng(5)
        ad = np.repeat(np.arange(60, 64, dtype=np.int32), 15)
        aw = rng.integers(0, 96, ad.size).astype(np.int32)
        new_lay, o2n = update_layout(lay, add_doc_ids=ad, add_word_ids=aw,
                                     retire=[2, 30], num_new_docs=4)
        ow, ob, odl, _ = lay.token_coords()
        oslot = lay.extract_canonical(lay.tok_slot)
        ogd = lay.doc_of_worker[ow, odl]
        surv = o2n >= 0
        # dropped tokens are exactly the retired docs'
        np.testing.assert_array_equal(surv, ~np.isin(ogd, [2, 30]))
        tgt = o2n[surv]
        assert np.unique(tgt).size == tgt.size
        nw, nb, _, _ = new_lay.token_coords()
        nslot = new_lay.extract_canonical(new_lay.tok_slot)
        # every survivor keeps its (worker, block, slot) → same RNG uid,
        # and the surviving canonical order is preserved verbatim
        np.testing.assert_array_equal(ow[surv], nw[tgt])
        np.testing.assert_array_equal(ob[surv], nb[tgt])
        np.testing.assert_array_equal(oslot[surv], nslot[tgt])
        assert (np.diff(tgt) > 0).all()
        assert new_lay.L == lay.L
        # uid uniqueness per worker
        uid = nb.astype(np.int64) * new_lay.L + nslot.astype(np.int64)
        keyed = nw.astype(np.int64) * (int(uid.max()) + 1) + uid
        assert np.unique(keyed).size == keyed.size
        # carried z: survivors keep topics, counts stay consistent
        z_old = np.random.default_rng(0).integers(
            0, 8, lay.canon_idx.shape[0]).astype(np.int32)
        z_new = carry_assignments(z_old, o2n, new_lay, seed=1)
        np.testing.assert_array_equal(z_old[surv], z_new[tgt])
        n_td, n_wt, n_t = counts_from_layout(
            new_lay, new_lay.place_canonical(z_new), 8)
        assert int(n_t.sum()) == new_lay.canon_idx.shape[0]
        assert int(n_td[[2, 30]].sum()) == 0

    @pytest.mark.parametrize("kind", ["dense", "ragged"])
    def test_overflowing_cell_routes_to_free_uid_region(self, kind):
        corpus, lay = self._setup(kind)
        B = lay.B
        # flood one block's vocabulary with more tokens than the frozen
        # stride L can hold in-cell: slots must land past B·L, not alias
        words = lay.word_of_block[0]
        words = words[words >= 0]
        n = int(lay.L) + 8
        ad = np.full(n, 60, np.int32)
        aw = np.resize(words, n).astype(np.int32)
        new_lay, o2n = update_layout(lay, add_doc_ids=ad, add_word_ids=aw,
                                     num_new_docs=1)
        nw, nb, _, _ = new_lay.token_coords()
        nslot = new_lay.extract_canonical(new_lay.tok_slot).astype(np.int64)
        uid = nb.astype(np.int64) * new_lay.L + nslot
        keyed = nw.astype(np.int64) * (int(uid.max()) + 1) + uid
        assert np.unique(keyed).size == keyed.size
        over = uid[nslot >= lay.L]
        assert over.size > 0 and int(over.min()) >= B * lay.L

    def test_rejects_ungrouped_and_bad_ids(self):
        corpus = _corpus()
        flat = build_layout(corpus, n_workers=2, T=8)
        with pytest.raises(ValueError, match="doc_tile"):
            update_layout(flat, add_doc_ids=np.array([40], np.int32),
                          add_word_ids=np.array([0], np.int32))
        lay = build_layout(corpus, n_workers=2, T=8, doc_tile=4)
        with pytest.raises(ValueError, match="fresh"):
            update_layout(lay, add_doc_ids=np.array([0], np.int32),
                          add_word_ids=np.array([0], np.int32))
        with pytest.raises(ValueError, match="range"):
            update_layout(lay, retire=[999])

    def test_remap_canonical(self):
        o2n = np.array([2, -1, 0, 1])
        out = remap_canonical(np.array([10, 11, 12, 13]), o2n, 3, fill=-5)
        np.testing.assert_array_equal(out, [12, 13, 10])


def _w1_lda(tmp=None, r_mode="dense", **kw):
    corpus = _corpus(num_docs=30, vocab=64, seed=1)
    lay = build_layout(corpus, n_workers=1, T=8, n_blocks=2, doc_tile=4)
    mesh = jax.make_mesh((1,), ("worker",))
    r_cap = lay.r_cap if r_mode == "sparse" else 0
    return NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                    alpha=0.5, beta=0.01, doc_tile=4, r_mode=r_mode,
                    r_cap=r_cap, **kw)


class TestChainCheckpoint:
    @settings(max_examples=6, deadline=None)
    @given(sweeps=st.integers(1, 4), r_mode=st.sampled_from(
        ["dense", "sparse"]))
    def test_save_restore_roundtrip_identity(self, sweeps, r_mode):
        """save→restore is the identity on every chain field, including
        the RNG counter, through an actual on-disk npz."""
        lda = _w1_lda(r_mode=r_mode)
        arrays = lda.init_arrays(seed=0)
        for s in range(sweeps):
            arrays = lda.sweep(arrays, seed=s)
        with tempfile.TemporaryDirectory() as td:
            path = td + "/chain.npz"
            lda.save_checkpoint(path, arrays, next_seed=sweeps)
            restored, next_seed = lda.load_checkpoint(path)
        assert next_seed == sweeps
        fields = ["z", "n_td", "n_wt", "n_t", "tok_doc", "tok_wrd",
                  "tok_valid", "tok_bound"]
        if r_mode == "sparse":
            fields += ["rb_topics", "rb_counts"]
        for f in fields:
            a, b = np.asarray(arrays[f]), np.asarray(restored[f])
            assert a.dtype == b.dtype, f
            np.testing.assert_array_equal(a, b, err_msg=f)
        # ...and the resumed chain continues bit-identically
        cont = lda.sweep(restored, seed=next_seed)
        ref = lda.sweep(arrays, seed=next_seed)
        np.testing.assert_array_equal(np.asarray(cont["z"]),
                                      np.asarray(ref["z"]))
        np.testing.assert_array_equal(np.asarray(cont["n_t"]),
                                      np.asarray(ref["n_t"]))

    def test_run_checkpoints_and_resumes(self, tmp_path):
        path = str(tmp_path / "c.npz")
        lda = _w1_lda(checkpoint_every=2, checkpoint_path=path)
        arrays, done = lda.run(4, init_seed=0)
        assert done == 4 and os.path.exists(path)
        straight, _ = _w1_lda().run(6, init_seed=0)
        resumed, done = _w1_lda(resume_from=path).run(6)
        assert done == 6
        np.testing.assert_array_equal(np.asarray(straight["z"]),
                                      np.asarray(resumed["z"]))
        np.testing.assert_array_equal(np.asarray(straight["n_td"]),
                                      np.asarray(resumed["n_td"]))

    def test_mismatched_chain_refused(self, tmp_path):
        path = str(tmp_path / "c.npz")
        lda = _w1_lda()
        arrays = lda.init_arrays(seed=0)
        lda.save_checkpoint(path, arrays, next_seed=0)
        other = _w1_lda(r_mode="sparse")
        with pytest.raises(ValueError, match="fork"):
            other.load_checkpoint(path)

    def test_format_version_gate(self, tmp_path):
        path = str(tmp_path / "c.npz")
        checkpoint.save_chain(path, {"x": np.zeros(3)}, {"next_seed": 0})
        state, meta = checkpoint.load_chain(path)
        np.testing.assert_array_equal(state["x"], np.zeros(3))
        assert meta["format_version"] == checkpoint.CHAIN_FORMAT_VERSION
        # corrupt the version and the loader must refuse
        data = dict(np.load(path))
        m = json.loads(bytes(data["__chain_meta__"].tobytes()).decode())
        m["format_version"] = 999
        data["__chain_meta__"] = np.frombuffer(
            json.dumps(m).encode(), np.uint8)
        np.savez(path, **data)
        with pytest.raises(ValueError, match="format"):
            checkpoint.load_chain(path)

    def test_serial_cgs_state_roundtrip(self, tmp_path):
        import jax.numpy as jnp

        from repro.core import cgs
        corpus = _corpus(num_docs=12, vocab=32, seed=0)
        state = cgs.init_state(corpus, T=4, key=jax.random.key(0))
        state = cgs.sweep_reference(
            state, jnp.asarray(corpus.doc_ids), jnp.asarray(corpus.word_ids),
            jnp.asarray(corpus.doc_order()), 0.5, 0.01)
        path = str(tmp_path / "serial.npz")
        checkpoint.save_chain(path, cgs.state_to_checkpoint(state),
                              {"T": 4})
        got, _ = checkpoint.load_chain(path)
        back = cgs.state_from_checkpoint(got)
        iskey = lambda x: jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key)
        for a, b in zip(state, back):
            np.testing.assert_array_equal(
                np.asarray(jax.random.key_data(a)) if iskey(a)
                else np.asarray(a),
                np.asarray(jax.random.key_data(b)) if iskey(b)
                else np.asarray(b))


@pytest.mark.slow
class TestResumeMatrix:
    """Kill-at-round-r bit-equality across {dense, ragged} × {barrier,
    pipelined} × r_mode — the acceptance matrix, via the same harness
    ``tools/ci.sh --resume-smoke`` gates on."""

    def test_matrix_all_exact(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.resume_check",
             "--phase", "matrix", "--sweeps", "4", "--checkpoint-at", "2",
             "--doc-tile", "4"],
            capture_output=True, text=True, env=env, timeout=900)
        assert out.returncode == 0, out.stderr[-3000:]
        rep = json.loads(out.stdout.strip().splitlines()[-1])
        assert rep["all_exact"], rep
        assert len(rep["combos"]) == 8
