"""Train-substrate tests: optimizer, schedules, chunked CE, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.train import checkpoint
from repro.train.optimizer import adamw_init, adamw_update, cosine_schedule
from repro.train.train_step import init_train_state, loss_fn


class TestAdamW:
    def test_descends_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(params, grads, state, lr=0.05,
                                            weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_grad_clipping(self):
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params)
        _, _, gnorm = adamw_update(params, {"w": jnp.full(3, 1e6)}, state,
                                   lr=0.0)
        assert float(gnorm) > 1e5  # reported norm is pre-clip

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=100)
        assert float(lr(jnp.asarray(5))) == pytest.approx(0.5)
        assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
        assert float(lr(jnp.asarray(100))) < 0.01


class TestChunkedCE:
    def test_matches_plain_ce(self):
        cfg = get_config("qwen3-8b").smoke()
        state = init_train_state(cfg, jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(1), (2, 64), 0,
                                    cfg.vocab_size)
        l1, m1 = loss_fn(state.params, cfg, {"tokens": tokens})
        l2, m2 = loss_fn(state.params, cfg, {"tokens": tokens},
                         chunked_ce=True)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)

    def test_matches_with_softcap_and_tied(self):
        cfg = get_config("gemma2-27b").smoke()   # tied embeddings + softcap
        state = init_train_state(cfg, jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(2), (2, 32), 0,
                                    cfg.vocab_size)
        l1, _ = loss_fn(state.params, cfg, {"tokens": tokens})
        l2, _ = loss_fn(state.params, cfg, {"tokens": tokens},
                        chunked_ce=True)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)

    def test_grads_match(self):
        cfg = get_config("granite-3-2b").smoke()
        state = init_train_state(cfg, jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(3), (2, 32), 0,
                                    cfg.vocab_size)
        g1 = jax.grad(lambda p: loss_fn(p, cfg, {"tokens": tokens})[0])(
            state.params)
        g2 = jax.grad(lambda p: loss_fn(p, cfg, {"tokens": tokens},
                                        chunked_ce=True)[0])(state.params)
        a = jax.tree_util.tree_leaves(g1)
        b = jax.tree_util.tree_leaves(g2)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-4, atol=1e-6)


class TestCheckpoint:
    def test_roundtrip(self):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones(4, jnp.int32)}}
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ck.npz")
            checkpoint.save(path, tree)
            back = checkpoint.restore(path, tree)
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(back["b"]["c"]),
                                      np.asarray(tree["b"]["c"]))

    def test_bf16_params_roundtrip(self):
        cfg = get_config("mamba2-1.3b").smoke()
        state = init_train_state(cfg, jax.random.key(0), jnp.bfloat16)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ck.npz")
            checkpoint.save(path, state.params)
            back = checkpoint.restore(path, state.params)
        for a, b in zip(jax.tree_util.tree_leaves(back),
                        jax.tree_util.tree_leaves(state.params)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))
