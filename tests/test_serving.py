"""Serving subsystem tests (DESIGN.md §10).

Three pillars, mirroring the ISSUE's acceptance list:

* **Parity** — the batched padded fold-in (`fold_in_batch`, the serving
  hot path) is bit-identical per document to the serial `fold_in`
  reference, across doc lengths including empty and single-token docs,
  and padded positions are provably inert.
* **Snapshot publish** — concurrent publishes never tear a reader's
  answer: every θ is attributable to exactly one published generation;
  format-version / geometry / digest mismatches are refused at both the
  store (`save_phi`/`load_phi`) and the engine.
* **Perplexity through the engine** — `document_completion_perplexity`
  recomputed from engine answers matches the direct call within f32
  tolerance (the regression pin the quality-harness ROADMAP item
  builds on).
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.heldout import (doc_fold_key, fold_in, fold_in_batch,
                                theta_from_counts)
from repro.serve.lda_engine import (LdaEngine, PhiSnapshot, TopicQuery,
                                    pack_docs, snapshot_from_counts)
from repro.train import checkpoint

J, T = 29, 7
ALPHA = 0.4
SWEEPS = 3


@pytest.fixture(scope="module")
def snap():
    rng = np.random.default_rng(5)
    n_wt = rng.integers(0, 40, (J, T))
    return snapshot_from_counts(n_wt, n_wt.sum(0), alpha=ALPHA, beta=0.01)


def _mk_docs(seed, lengths):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, J, n).astype(np.int32) for n in lengths]


def _batched(docs, phi, key, L=16, sweeps=SWEEPS):
    """fold_in_batch over ``docs`` at a fixed padded width, row d keyed
    as serial document d under ``key``."""
    D = len(docs)
    w = np.zeros((D, L), np.int32)
    v = np.zeros((D, L), bool)
    for i, d in enumerate(docs):
        w[i, :d.size] = d
        v[i, :d.size] = True
    keys = jax.vmap(doc_fold_key, in_axes=(None, 0))(
        key, jnp.arange(D, dtype=jnp.int32))
    return np.asarray(fold_in_batch(jnp.asarray(w), jnp.asarray(v),
                                    phi, ALPHA, keys, sweeps))


def _serial(docs, phi, key, sweeps=SWEEPS):
    """Serial multi-doc reference: one flat token list, doc ids = list
    position (empty docs contribute no tokens; their rows stay zero)."""
    w = np.concatenate([d for d in docs]).astype(np.int32)
    d = np.concatenate([np.full(x.size, i, np.int32)
                        for i, x in enumerate(docs)])
    return np.asarray(fold_in(jnp.asarray(w), jnp.asarray(d), len(docs),
                              phi, ALPHA, key, sweeps))


class TestFoldInParity:
    @settings(max_examples=8, deadline=None)
    @given(lengths=st.lists(st.integers(0, 12), min_size=1, max_size=5),
           seed=st.integers(0, 3))
    def test_batched_matches_serial_bitexact(self, snap, lengths, seed):
        if not any(lengths):
            lengths = lengths + [1]      # serial path refuses all-empty
        docs = _mk_docs(seed, lengths)
        phi = jnp.asarray(snap.phi)
        key = jax.random.key(100 + seed)
        got = _batched(docs, phi, key)
        ref = _serial(docs, phi, key)
        for i, d in enumerate(docs):
            if d.size == 0:
                assert got[i].sum() == 0    # empty doc: zero counts
            else:
                np.testing.assert_array_equal(got[i], ref[i])

    def test_empty_and_single_token_docs(self, snap):
        docs = _mk_docs(0, [0, 1, 1, 0, 5])
        phi = jnp.asarray(snap.phi)
        key = jax.random.key(9)
        got = _batched(docs, phi, key)
        ref = _serial(docs, phi, key)
        for i, d in enumerate(docs):
            if d.size:
                np.testing.assert_array_equal(got[i], ref[i])
            else:
                assert got[i].sum() == 0
        th = np.asarray(theta_from_counts(jnp.asarray(got), ALPHA))
        np.testing.assert_allclose(th.sum(1), 1.0, atol=1e-5)
        np.testing.assert_allclose(th[0], 1.0 / T, atol=1e-6)  # uniform

    def test_padding_provably_inert(self, snap):
        """Growing L and writing garbage into padded word slots cannot
        perturb any row — the counter-mode RNG contract."""
        docs = _mk_docs(1, [3, 7, 1])
        phi = jnp.asarray(snap.phi)
        key = jax.random.key(4)
        base = _batched(docs, phi, key, L=8)
        wider = _batched(docs, phi, key, L=32)
        np.testing.assert_array_equal(base, wider)

        D, L = len(docs), 32
        w = np.full((D, L), J - 1, np.int32)        # garbage everywhere
        v = np.zeros((D, L), bool)
        for i, d in enumerate(docs):
            w[i, :d.size] = d
            v[i, :d.size] = True
        keys = jax.vmap(doc_fold_key, in_axes=(None, 0))(
            key, jnp.arange(D, dtype=jnp.int32))
        garbage = np.asarray(fold_in_batch(
            jnp.asarray(w), jnp.asarray(v), phi, ALPHA, keys, SWEEPS))
        np.testing.assert_array_equal(base, garbage)

    def test_row_independent_of_batch_neighbours(self, snap):
        """A document's chain depends only on its own stream key — not
        on which batch it rides in."""
        phi = jnp.asarray(snap.phi)
        key = jax.random.key(2)
        docs = _mk_docs(2, [6, 4, 9])
        full = _batched(docs, phi, key)
        # same doc 1 alone, keyed with its original stream
        alone_key = doc_fold_key(key, 1)
        w = np.zeros((1, 16), np.int32)
        v = np.zeros((1, 16), bool)
        w[0, :4], v[0, :4] = docs[1], True
        alone = np.asarray(fold_in_batch(
            jnp.asarray(w), jnp.asarray(v), phi, ALPHA,
            alone_key[None], SWEEPS))
        np.testing.assert_array_equal(full[1], alone[0])

    def test_pack_docs_buckets_shapes(self):
        docs = _mk_docs(3, [1, 5, 11])
        w, v, n = pack_docs(docs, tile=8)
        assert n == 3
        assert w.shape == (4, 16)          # pow2 rows, pow2 tile count
        assert v.sum() == 1 + 5 + 11
        assert not v[3].any()              # padded row inert
        with pytest.raises(ValueError):
            pack_docs([])


class TestFusedInnerMode:
    """`LdaEngine(inner_mode="fused")` — the Pallas fold-in kernel on
    the serving path (DESIGN.md §10a) — answers bit-identically to
    `inner_mode="scan"` and to the serial reference."""

    def _engines(self, snap, **kw):
        return (LdaEngine(snap, sweeps=SWEEPS, tile=4, max_batch=4,
                          inner_mode="scan", **kw),
                LdaEngine(snap, sweeps=SWEEPS, tile=4, max_batch=4,
                          inner_mode="fused", **kw))

    def test_fused_matches_scan_and_serial(self, snap):
        e_scan, e_fused = self._engines(snap)
        docs = _mk_docs(4, [0, 1, 21, 5, 3, 17, 2])
        key = jax.random.key(77)
        rs = e_scan.query(TopicQuery(docs=tuple(docs), key=key))
        rf = e_fused.query(TopicQuery(docs=tuple(docs), key=key))
        np.testing.assert_array_equal(rs.n_td, rf.n_td)
        np.testing.assert_array_equal(rs.theta, rf.theta)
        assert rs.batch_shape == rf.batch_shape
        ref = _serial(docs, jnp.asarray(snap.phi), key)
        for i, d in enumerate(docs):
            if d.size:
                np.testing.assert_array_equal(rf.n_td[i], ref[i])
            else:
                assert rf.n_td[i].sum() == 0

    def test_fused_across_generations(self, snap):
        e_scan, e_fused = self._engines(snap)
        rng = np.random.default_rng(23)
        docs = tuple(_mk_docs(5, [2, 9, 0, 6]))
        for _ in range(2):
            n_wt = rng.integers(0, 40, (J, T))
            s = snapshot_from_counts(n_wt, n_wt.sum(0), alpha=ALPHA,
                                     beta=0.01)
            e_scan.publish(s)
            e_fused.publish(s)
            rs = e_scan.query(TopicQuery(docs=docs, key=jax.random.key(3)))
            rf = e_fused.query(TopicQuery(docs=docs, key=jax.random.key(3)))
            assert rs.generation == rf.generation
            np.testing.assert_array_equal(rs.n_td, rf.n_td)

    def test_inner_mode_validation(self, snap):
        with pytest.raises(ValueError, match="inner_mode"):
            LdaEngine(snap, inner_mode="alias")


class TestLengthBucketing:
    """The pack_docs outlier-padding fix: `query` splits off docs whose
    pow-2 length bucket exceeds 4x the batch's median bucket, so one
    long document cannot inflate every co-batched row's padded width —
    while ordinary mixed-length batches stay a single dispatch; per-doc
    bit-exactness is preserved (row RNG is batch-independent by
    contract)."""

    def test_outlier_does_not_inflate_short_docs(self, snap):
        eng = LdaEngine(snap, sweeps=2, tile=4, max_batch=8)
        docs = _mk_docs(7, [1, 2, 3, 300])
        res = eng.query(TopicQuery(docs=tuple(docs), key=jax.random.key(1)))
        shapes = (res.batch_shape if isinstance(res.batch_shape[0], tuple)
                  else (res.batch_shape,))
        assert len(shapes) == 2                       # two buckets
        short, long = sorted(shapes, key=lambda s: s[1])
        assert short == (4, 4)                        # 3 short docs, L=4
        assert long[0] == 1 and long[1] >= 300        # outlier alone
        padded = sum(D * L for D, L in shapes)
        naive_D, naive_L = 4, 512                     # one-pack shape
        assert padded < naive_D * naive_L / 3         # >3x padding saved

    def test_no_outlier_single_dispatch(self, snap):
        """Mixed lengths within 4x of the median bucket run as ONE
        sub-batch — every group is its own kernel dispatch, so the rule
        must not shred ordinary traffic into per-bucket launches."""
        eng = LdaEngine(snap, sweeps=2, tile=4, max_batch=8)
        res = eng.query(TopicQuery(docs=tuple(_mk_docs(3, [2, 7, 12, 15])),
                                   key=jax.random.key(4)))
        assert isinstance(res.batch_shape[0], int)    # one (D, L) pack

    @pytest.mark.parametrize("inner_mode", ["scan", "fused"])
    def test_mixed_length_parity(self, snap, inner_mode):
        """Bucketed (reordered, split) batches answer bit-identically to
        the serial reference, for both inner modes."""
        eng = LdaEngine(snap, sweeps=SWEEPS, tile=4, max_batch=2,
                        inner_mode=inner_mode)
        docs = _mk_docs(8, [40, 1, 0, 6, 2, 33, 5])
        key = jax.random.key(19)
        res = eng.query(TopicQuery(docs=tuple(docs), key=key))
        ref = _serial(docs, jnp.asarray(snap.phi), key)
        for i, d in enumerate(docs):
            if d.size:
                np.testing.assert_array_equal(res.n_td[i], ref[i], err_msg=f"doc {i}")
            else:
                assert res.n_td[i].sum() == 0
        np.testing.assert_allclose(res.theta.sum(1), 1.0, atol=1e-5)

    def test_bucketing_invariant_to_doc_order(self, snap):
        """The same doc at the same query index answers identically no
        matter how its neighbours shuffle it between sub-batches."""
        eng = LdaEngine(snap, sweeps=2, tile=4, max_batch=4)
        key = jax.random.key(11)
        docs = _mk_docs(9, [5, 60, 2])
        full = eng.query(TopicQuery(docs=tuple(docs), key=key))
        # doc 1 alone under its original stream: bit-equal counts
        w = np.zeros((1, 64), np.int32)
        v = np.zeros((1, 64), bool)
        w[0, :60], v[0, :60] = docs[1], True
        alone = np.asarray(fold_in_batch(
            jnp.asarray(w), jnp.asarray(v), jnp.asarray(snap.phi), ALPHA,
            doc_fold_key(key, 1)[None], 2))
        np.testing.assert_array_equal(full.n_td[1], alone[0])


class TestThetaKernelCache:
    def test_same_shape_bucket_no_retrace(self, snap):
        """Repeat queries with the same (D_pad, L, sweeps) bucket reuse
        the jit cache — the bucketing exists so serving never compiles
        per request."""
        from repro.serve.lda_engine import _theta_kernel
        eng = LdaEngine(snap, sweeps=2, tile=4, max_batch=8)
        lengths = [3, 5, 2]
        for i in range(2):                   # warm the bucket
            eng.query(TopicQuery(docs=tuple(_mk_docs(i, lengths)),
                                 key=jax.random.key(i)))
        warm = _theta_kernel._cache_size()
        for i in range(3):                   # same bucket, new data/keys
            eng.query(TopicQuery(docs=tuple(_mk_docs(10 + i, lengths)),
                                 key=jax.random.key(50 + i)))
        assert _theta_kernel._cache_size() == warm
        # a genuinely new length bucket does retrace; the cache is
        # process-global, so probe with a bucket (L=256) no other test
        # in this module touches
        eng.query(TopicQuery(docs=tuple(_mk_docs(0, [133])),
                             key=jax.random.key(0)))
        assert _theta_kernel._cache_size() > warm


class TestHeldoutEdgeCases:
    def test_theta_from_counts_all_zero_rows_uniform(self):
        n_td = jnp.zeros((3, T), jnp.int32)
        th = np.asarray(theta_from_counts(n_td, ALPHA))
        np.testing.assert_allclose(th, 1.0 / T, atol=1e-7)
        np.testing.assert_allclose(th.sum(1), 1.0, atol=1e-6)
        # mixed: a zero row next to a populated one
        n_td = n_td.at[1, 2].set(5)
        th = np.asarray(theta_from_counts(n_td, ALPHA))
        np.testing.assert_allclose(th[0], 1.0 / T, atol=1e-7)
        assert th[1, 2] > th[1, 0]

    def test_single_token_docs_perplexity_is_one(self):
        """Every token lands in the estimation half, the score half is
        empty: perplexity must be exactly 1.0 (exp(-0/1)), not a raise
        through fold_in's empty-token ValueError (pinned non-bug,
        ISSUE 10)."""
        from repro.core.heldout import document_completion_perplexity
        from repro.data.corpus import Corpus
        c = Corpus(doc_ids=np.arange(6, dtype=np.int32),
                   word_ids=(np.arange(6, dtype=np.int32) % J),
                   num_docs=6, num_words=J)
        rng = np.random.default_rng(2)
        n_wt = rng.integers(0, 40, (J, T))
        ppl = document_completion_perplexity(
            c, n_wt, n_wt.sum(0), alpha=ALPHA, beta=0.01, fold_sweeps=2)
        assert ppl == 1.0


class TestSnapshotPublish:
    def test_concurrent_publish_no_torn_reads(self, snap):
        """Interleave publishes with reader queries from two threads;
        every answer must be attributable to exactly one published
        generation (generation ↔ digest match)."""
        eng = LdaEngine(snap, sweeps=2, tile=4, max_batch=8)
        published = {1: snap.digest}
        pub_lock = threading.Lock()
        stop = threading.Event()

        def publisher():
            rng = np.random.default_rng(17)
            for _ in range(6):
                n_wt = rng.integers(0, 40, (J, T))
                s = snapshot_from_counts(n_wt, n_wt.sum(0), alpha=ALPHA,
                                         beta=0.01)
                gen = eng.publish(s)
                with pub_lock:
                    published[gen] = s.digest
            stop.set()

        answers = []
        ans_lock = threading.Lock()
        docs = tuple(_mk_docs(6, [4, 0, 7, 2]))

        def reader(tid):
            i = 0
            while not stop.is_set() or i < 10:
                res = eng.query(TopicQuery(
                    docs=docs, key=jax.random.key(tid * 100 + i % 3)))
                with ans_lock:
                    answers.append((res.generation, res.digest))
                i += 1

        th_p = threading.Thread(target=publisher)
        readers = [threading.Thread(target=reader, args=(t,))
                   for t in range(2)]
        for t in readers:
            t.start()
        th_p.start()
        th_p.join()
        for t in readers:
            t.join()

        assert len(published) == 7          # initial + 6 publishes
        assert answers
        for gen, digest in answers:
            assert published.get(gen) == digest, (
                f"torn read: generation {gen} answered with a digest "
                f"belonging to no single published snapshot")

    def test_refuses_format_version_mismatch(self, snap):
        eng = LdaEngine(snap)
        bad = PhiSnapshot(phi=snap.phi,
                          meta={**snap.meta, "format_version": 99})
        with pytest.raises(ValueError, match="format"):
            eng.publish(bad)
        assert eng.generation == 1          # still serving gen 1

    def test_refuses_geometry_change_and_corrupt_digest(self, snap):
        eng = LdaEngine(snap)
        resized = snapshot_from_counts(np.ones((J + 1, T)), np.ones(T),
                                       alpha=ALPHA, beta=0.01)
        with pytest.raises(ValueError, match="geometry"):
            eng.publish(resized)
        corrupt = PhiSnapshot(phi=snap.phi + 1.0, meta=dict(snap.meta))
        with pytest.raises(ValueError, match="digest"):
            eng.publish(corrupt)

    def test_query_before_publish_raises(self):
        with pytest.raises(RuntimeError):
            LdaEngine().query(TopicQuery(docs=(np.arange(3),)))

    def test_save_load_round_trip(self, snap, tmp_path):
        p = str(tmp_path / "phi")
        snap.save(p)
        back = PhiSnapshot.load(p)
        np.testing.assert_array_equal(back.phi, snap.phi)
        assert back.digest == snap.digest
        assert back.alpha == snap.alpha and back.beta == snap.beta
        # a loaded snapshot publishes cleanly
        assert LdaEngine(back).generation == 1

    def test_load_refuses_version_and_digest_tampering(self, snap,
                                                       tmp_path):
        p = str(tmp_path / "phi_bad")
        meta = dict(snap.meta, format_version=99)
        checkpoint._atomic_savez(p, {"phi": snap.phi}, meta,
                                 checkpoint._PHI_META_KEY)
        with pytest.raises(ValueError, match="format"):
            checkpoint.load_phi(p)
        p2 = str(tmp_path / "phi_corrupt")
        meta = dict(snap.meta, digest="0" * 64)
        checkpoint._atomic_savez(p2, {"phi": snap.phi}, meta,
                                 checkpoint._PHI_META_KEY)
        with pytest.raises(ValueError, match="digest"):
            checkpoint.load_phi(p2)
        # a chain checkpoint is not a φ snapshot
        checkpoint.save_chain(str(tmp_path / "chain"),
                              {"z": np.arange(4)}, {})
        with pytest.raises(ValueError, match="not a φ snapshot"):
            checkpoint.load_phi(str(tmp_path / "chain"))


class TestEnginePerplexity:
    def test_engine_matches_direct_perplexity(self):
        """`document_completion_perplexity` recomputed from engine
        answers equals the direct call within f32 tolerance: the engine
        keys doc i as `doc_fold_key(key, i)`, exactly the stream the
        direct path's internal fold_in derives for doc id i."""
        from repro.core.heldout import (_phi_hat, _positions_in_doc,
                                        document_completion_perplexity)
        from repro.data import synthetic

        corpus, _, _ = synthetic.make_corpus(
            num_docs=20, vocab_size=J, num_topics=T, mean_doc_len=12.0,
            seed=2)
        rng = np.random.default_rng(8)
        n_wt = rng.integers(0, 40, (J, T))
        n_t = n_wt.sum(0)
        key = jax.random.key(31)
        direct = document_completion_perplexity(
            corpus, n_wt, n_t, alpha=ALPHA, beta=0.01, key=key,
            fold_sweeps=SWEEPS)

        # replicate the split, fold the estimation halves via the engine
        order = corpus.doc_order()
        pos = _positions_in_doc(corpus.doc_ids[order])
        first = pos % 2 == 0
        est_idx, score_idx = order[first], order[~first]
        docs = [corpus.word_ids[est_idx][
                    corpus.doc_ids[est_idx] == d].astype(np.int32)
                for d in range(corpus.num_docs)]
        snap = snapshot_from_counts(n_wt, n_t, alpha=ALPHA, beta=0.01)
        eng = LdaEngine(snap, sweeps=SWEEPS, tile=4, max_batch=8)
        res = eng.query(TopicQuery(docs=tuple(docs), key=key))

        phi = np.asarray(_phi_hat(jnp.asarray(n_wt), jnp.asarray(n_t),
                                  0.01))
        w, d = corpus.word_ids[score_idx], corpus.doc_ids[score_idx]
        p_tok = np.einsum("nt,nt->n", res.theta[d], phi[w])
        ppl = float(np.exp(-np.log(np.maximum(p_tok, 1e-30)).sum()
                           / max(len(score_idx), 1)))
        assert ppl == pytest.approx(direct, rel=1e-4)
