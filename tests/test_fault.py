"""Fault-tolerance tests (DESIGN.md §11).

Four pillars, mirroring ISSUE 9's acceptance list:

* **Deterministic fault injection** — a seeded :class:`FaultPlan` replays
  the same damage bit-for-bit; the install/fire hooks are no-ops when no
  plan is installed and re-entrant when one is.
* **Durable, self-verifying checkpoints** — ``_atomic_savez`` fsyncs the
  file and its directory; every payload carries a sha256 verified on
  load; ``load_chain``/``load_phi`` surface every damage shape (truncated
  archive, flipped payload byte, missing meta, digest mismatch) as
  :class:`SnapshotCorruptError` and version skew as
  :class:`FormatVersionError`.
* **Self-healing rotation** — :class:`CheckpointRotation` keeps the last
  K slots + a LAST_GOOD pointer, prunes, and ``load_latest_valid`` walks
  past damaged slots; end to end, a killed-and-corrupted
  :class:`NomadLDA` run resumes from the previous valid slot
  bit-exactly.
* **Hardened serving** — ``publish`` refuses corrupt / stale-generation /
  format-skewed snapshots with typed errors while the live buffer keeps
  serving; admission control sheds past ``max_pending`` and degrades
  (capped sweeps) past ``degrade_pending``; ``fetch_snapshot`` retries
  transient damage with bounded backoff and never retries version skew.
"""
import json
import os
import zipfile

import numpy as np
import pytest

from repro import fault
from repro.fault import (EngineOverloadedError, FaultPlan, FaultSpec,
                         FormatVersionError, InjectedKill,
                         SnapshotCorruptError, StaleGenerationError)
from repro.train import checkpoint
from repro.train.checkpoint import CheckpointRotation


def _chain_path(tmp_path, name="chain"):
    return str(tmp_path / name)


def _write_chain(tmp_path, name="chain", n=16, seed=0):
    rng = np.random.default_rng(seed)
    state = {"z": rng.integers(0, 7, n).astype(np.int32),
             "n_t": rng.integers(0, 50, 8).astype(np.int32)}
    path = checkpoint.save_chain(_chain_path(tmp_path, name), state,
                                 {"next_seed": 3})
    return path, state


# ---------------------------------------------------------------------------
# FaultPlan / FaultSpec
# ---------------------------------------------------------------------------
class TestFaultPlan:
    def test_spec_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec("explode", "site", at=0)
        with pytest.raises(ValueError, match="count"):
            FaultSpec("kill", "site", at=0, count=0)
        with pytest.raises(ValueError, match="frac"):
            FaultSpec("truncate", "site", at=0, frac=1.0)

    def test_corruption_is_deterministic(self, tmp_path):
        """Same seed → identical damaged bytes; different seed → not."""
        damaged = {}
        for run, seed in (("a", 5), ("b", 5), ("c", 6)):
            p = tmp_path / f"{run}.bin"
            p.write_bytes(bytes(range(256)) * 8)
            plan = FaultPlan([FaultSpec("corrupt", "w", at=0, nbytes=6)],
                             seed=seed)
            plan.fire("w", path=str(p))
            damaged[run] = p.read_bytes()
        assert damaged["a"] == damaged["b"]
        assert damaged["a"] != damaged["c"]
        assert damaged["a"] != bytes(range(256)) * 8

    def test_window_and_counters(self, tmp_path):
        plan = FaultPlan([FaultSpec("fail", "s", at=2, count=2)])
        assert plan.fire("s") == ()            # index 0
        assert plan.fire("s") == ()            # index 1
        with pytest.raises(SnapshotCorruptError, match=r"s\[2\]"):
            plan.fire("s")
        with pytest.raises(SnapshotCorruptError, match=r"s\[3\]"):
            plan.fire("s")
        assert plan.fire("s") == ()            # index 4: window closed
        # unmentioned sites still advance their own counter
        plan.fire("other")
        assert plan._counters["other"] == 1

    def test_soft_kill_carries_site_and_index(self):
        plan = FaultPlan([FaultSpec("kill", "trainer.sweep", at=1)])
        plan.fire("trainer.sweep", index=0)
        with pytest.raises(InjectedKill) as ei:
            plan.fire("trainer.sweep", index=1)
        assert (ei.value.site, ei.value.index) == ("trainer.sweep", 1)
        assert plan.log == [("trainer.sweep", 1, "kill")]

    def test_install_is_reentrant_and_fire_is_noop_uninstalled(self):
        assert fault.fire("anything", path="/nope") == ()
        outer, inner = FaultPlan(), FaultPlan()
        with fault.install(outer):
            assert fault.active() is outer
            with fault.install(inner):
                assert fault.active() is inner
            assert fault.active() is outer
        assert fault.active() is None


# ---------------------------------------------------------------------------
# Durability + typed load errors (satellites a, c)
# ---------------------------------------------------------------------------
class TestDurability:
    def test_atomic_savez_fsyncs_file_and_dir(self, tmp_path, monkeypatch):
        """The satellite-a durability fix: a host crash after the rename
        must not lose the entry, so both the temp file and the directory
        must be fsynced."""
        synced = []
        real_fsync = os.fsync
        monkeypatch.setattr(os, "fsync", lambda fd: (synced.append(fd),
                                                     real_fsync(fd))[1])
        _write_chain(tmp_path)
        assert len(synced) >= 2   # the npz temp file + its directory

    def test_truncated_write_injector_round_trip(self, tmp_path):
        """The fault layer's truncated-write injector produces a file the
        loader rejects as corrupt — the torn-write story end to end."""
        plan = FaultPlan([FaultSpec("truncate", "chain.write", at=0,
                                    frac=0.5)])
        with fault.install(plan):
            path, _ = _write_chain(tmp_path)
        assert plan.log == [("chain.write", 0, "truncate")]
        with pytest.raises(SnapshotCorruptError):
            checkpoint.load_chain(path)

    def test_save_returns_path_and_round_trips(self, tmp_path):
        path, state = _write_chain(tmp_path)
        assert path.endswith(".npz") and os.path.exists(path)
        got, meta = checkpoint.load_chain(path)
        np.testing.assert_array_equal(got["z"], state["z"])
        assert meta["next_seed"] == 3
        assert set(meta["payload_sha256"]) == {"z", "n_t"}


class TestLoadChainErrors:
    def test_missing_file(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            checkpoint.load_chain(str(tmp_path / "nope"))

    def test_truncated_npz(self, tmp_path):
        path, _ = _write_chain(tmp_path)
        os.truncate(path, os.path.getsize(path) // 3)
        with pytest.raises(SnapshotCorruptError):
            checkpoint.load_chain(path)

    def test_flipped_payload_byte(self, tmp_path):
        """One flipped byte inside a stored array: the zip layer may not
        notice, the per-payload sha256 must."""
        path, _ = _write_chain(tmp_path)
        with zipfile.ZipFile(path) as z:
            names = z.namelist()
            blobs = {n: bytearray(z.read(n)) for n in names}
        victim = "z.npy"
        assert victim in blobs
        blobs[victim][-1] ^= 0xFF             # flip a data byte (not header)
        with zipfile.ZipFile(path, "w") as z:
            for n in names:
                z.writestr(n, bytes(blobs[n]))
        with pytest.raises(SnapshotCorruptError,
                           match="digest mismatch|unreadable"):
            checkpoint.load_chain(path)

    def test_missing_chain_meta(self, tmp_path):
        p = str(tmp_path / "bare.npz")
        np.savez(p, z=np.arange(4, dtype=np.int32))
        with pytest.raises(SnapshotCorruptError,
                           match="is not a chain checkpoint"):
            checkpoint.load_chain(p)

    def test_digest_mismatch_in_meta(self, tmp_path):
        state = {"z": np.arange(8, dtype=np.int32)}
        meta = {"format_version": checkpoint.CHAIN_FORMAT_VERSION,
                "payload_sha256": {"z": "0" * 64}}
        p = str(tmp_path / "bad")
        payload = dict(state)
        payload[checkpoint._META_KEY] = np.frombuffer(
            json.dumps(meta).encode(), np.uint8)
        np.savez(p + ".npz", **payload)
        with pytest.raises(SnapshotCorruptError, match="digest mismatch"):
            checkpoint.load_chain(p)

    def test_format_version_is_typed_and_keeps_message(self, tmp_path):
        path, _ = _write_chain(tmp_path)
        with np.load(path) as data:
            payload = {k: data[k] for k in data.files}
        meta = json.loads(bytes(
            payload[checkpoint._META_KEY].tobytes()).decode())
        meta["format_version"] = 999
        payload[checkpoint._META_KEY] = np.frombuffer(
            json.dumps(meta).encode(), np.uint8)
        np.savez(path, **{k: v for k, v in payload.items()})
        with pytest.raises(FormatVersionError, match="format"):
            checkpoint.load_chain(path)
        # and the typed error still satisfies pre-§11 ValueError catchers
        with pytest.raises(ValueError, match="format"):
            checkpoint.load_chain(path)

    def test_load_phi_truncated_and_missing_meta(self, tmp_path):
        p = str(tmp_path / "phi")
        checkpoint.save_phi(p, np.ones((4, 3), np.float32), {})
        os.truncate(p + ".npz", os.path.getsize(p + ".npz") // 3)
        with pytest.raises(SnapshotCorruptError):
            checkpoint.load_phi(p)
        p2 = str(tmp_path / "bare.npz")
        np.savez(p2, phi=np.ones((4, 3), np.float32))
        with pytest.raises(SnapshotCorruptError, match="not a φ snapshot"):
            checkpoint.load_phi(p2)
        with pytest.raises(FileNotFoundError):
            checkpoint.load_phi(str(tmp_path / "ghost"))


# ---------------------------------------------------------------------------
# CheckpointRotation
# ---------------------------------------------------------------------------
class TestCheckpointRotation:
    def _save_steps(self, rot, steps, seed=0):
        for step in steps:
            rng = np.random.default_rng(seed + step)
            rot.save({"z": rng.integers(0, 5, 12).astype(np.int32)},
                     {"next_seed": step}, step=step)

    def test_keep_prune_and_pointer(self, tmp_path):
        rot = CheckpointRotation(str(tmp_path / "rot"), keep=3)
        self._save_steps(rot, [1, 2, 3, 4, 5])
        assert [s for s, _ in rot.slots()] == [3, 4, 5]
        assert rot.last_good() == 5
        state, meta, step = rot.load_latest_valid()
        assert step == 5 and meta["next_seed"] == 5

    def test_fallback_skips_damaged_newest(self, tmp_path):
        rot = CheckpointRotation(str(tmp_path / "rot"), keep=3)
        self._save_steps(rot, [1, 2, 3])
        # damage the newest slot *after* its durable write (bit rot);
        # the LAST_GOOD pointer still names it — and must not be trusted
        plan = FaultPlan([FaultSpec("corrupt", "x", at=0, nbytes=8)])
        plan.fire("x", path=rot.slot_path(3))
        assert rot.last_good() == 3
        _, meta, step = rot.load_latest_valid()
        assert step == 2 and meta["next_seed"] == 2

    def test_all_damaged_raises_listing_slots(self, tmp_path):
        rot = CheckpointRotation(str(tmp_path / "rot"), keep=2)
        self._save_steps(rot, [1, 2])
        for step, path in rot.slots():
            os.truncate(path, 10)
        with pytest.raises(SnapshotCorruptError, match="every checkpoint"):
            rot.load_latest_valid()

    def test_format_version_skew_propagates(self, tmp_path):
        """A version skew is a build problem, not slot damage — fallback
        must not silently resurrect an older slot."""
        rot = CheckpointRotation(str(tmp_path / "rot"), keep=2)
        self._save_steps(rot, [1, 2])
        path = rot.slot_path(2)
        with np.load(path) as data:
            payload = {k: data[k] for k in data.files}
        meta = json.loads(bytes(
            payload[checkpoint._META_KEY].tobytes()).decode())
        meta["format_version"] = 999
        payload[checkpoint._META_KEY] = np.frombuffer(
            json.dumps(meta).encode(), np.uint8)
        np.savez(path, **payload)
        with pytest.raises(FormatVersionError):
            rot.load_latest_valid()

    def test_empty_dir_raises_file_not_found(self, tmp_path):
        rot = CheckpointRotation(str(tmp_path / "rot"))
        with pytest.raises(FileNotFoundError):
            rot.load_latest_valid()

    def test_keep_validation(self, tmp_path):
        with pytest.raises(ValueError, match="keep"):
            CheckpointRotation(str(tmp_path), keep=0)


# ---------------------------------------------------------------------------
# Hardened serving
# ---------------------------------------------------------------------------
J, T = 19, 5


@pytest.fixture()
def snaps():
    from repro.serve.lda_engine import snapshot_from_counts
    out = []
    for sweep in (1, 2, 3):
        rng = np.random.default_rng(sweep)
        n_wt = rng.integers(0, 40, (J, T))
        out.append(snapshot_from_counts(
            n_wt, n_wt.sum(0), alpha=0.4, beta=0.01,
            extra_meta={"sweep": sweep}))
    return out


class TestEngineHardening:
    def test_publish_typed_errors_keep_live_buffer(self, snaps):
        import dataclasses

        from repro.serve.lda_engine import LdaEngine, PhiSnapshot
        eng = LdaEngine(snaps[0], sweeps=2, tile=4, max_batch=8)
        gen = eng.generation
        bad_phi = np.array(snaps[1].phi)
        bad_phi[0, 0] += 1.0
        with pytest.raises(SnapshotCorruptError, match="digest"):
            eng.publish(PhiSnapshot(phi=bad_phi,
                                    meta=dict(snaps[1].meta)))
        skew = dict(snaps[1].meta)
        skew["format_version"] += 1
        with pytest.raises(FormatVersionError, match="format"):
            eng.publish(dataclasses.replace(snaps[1], meta=skew))
        assert eng.generation == gen           # live buffer untouched
        assert eng.stats()["rejected_publishes"] == 2

    def test_generation_regression_refused(self, snaps):
        from repro.serve.lda_engine import LdaEngine
        eng = LdaEngine(snaps[1], sweeps=2, tile=4, max_batch=8)
        with pytest.raises(StaleGenerationError, match="regress"):
            eng.publish(snaps[0])              # sweep 1 after sweep 2
        with pytest.raises(StaleGenerationError):
            eng.publish(snaps[1])              # equal sweep also refused
        assert eng.publish(snaps[2]) == 2      # forward still fine
        # snapshots without a source ordinal stay unguarded (pre-§11)
        from repro.serve.lda_engine import snapshot_from_counts
        rng = np.random.default_rng(9)
        n_wt = rng.integers(0, 40, (J, T))
        free = snapshot_from_counts(n_wt, n_wt.sum(0), alpha=0.4,
                                    beta=0.01)
        assert eng.publish(free) == 3

    def test_shed_and_degrade(self, snaps):
        from repro.serve.lda_engine import LdaEngine, TopicQuery
        eng = LdaEngine(snaps[0], sweeps=6, tile=4, max_batch=8,
                        max_pending=2, degrade_pending=1,
                        degraded_sweeps=2)
        docs = (np.arange(5, dtype=np.int32),)
        res = eng.query(TopicQuery(docs=docs))
        assert not res.degraded and res.sweeps_used == 6

        # simulate concurrent load: one query already in flight
        with eng._stats_lock:
            eng._pending = 1
        res = eng.query(TopicQuery(docs=docs))
        assert res.degraded and res.sweeps_used == 2
        assert res.degraded_total == 1

        # at the hard bound: shed with the typed error
        with eng._stats_lock:
            eng._pending = 2
        with pytest.raises(EngineOverloadedError, match="shed"):
            eng.query(TopicQuery(docs=docs))
        with eng._stats_lock:
            eng._pending = 0
        stats = eng.stats()
        assert stats["shed"] == 1 and stats["degraded"] == 1
        assert stats["max_pending_seen"] == 2
        res = eng.query(TopicQuery(docs=docs))   # healthy again
        assert not res.degraded and res.shed_total == 1

    def test_degraded_answers_match_capped_sweeps(self, snaps):
        """A degraded answer is exactly the answer a sweeps-capped query
        would give — degradation changes quality, never correctness."""
        from repro.serve.lda_engine import LdaEngine, TopicQuery
        docs = (np.arange(6, dtype=np.int32) % J,
                np.array([3, 1], np.int32))
        eng = LdaEngine(snaps[0], sweeps=6, tile=4, max_batch=8,
                        degrade_pending=1, degraded_sweeps=2)
        ref = eng.query(TopicQuery(docs=docs, sweeps=2))
        with eng._stats_lock:
            eng._pending = 1
        got = eng.query(TopicQuery(docs=docs))
        with eng._stats_lock:
            eng._pending = 0
        assert got.degraded
        np.testing.assert_array_equal(ref.n_td, got.n_td)

    def test_admission_param_validation(self, snaps):
        from repro.serve.lda_engine import LdaEngine
        with pytest.raises(ValueError, match="max_pending"):
            LdaEngine(max_pending=0)
        with pytest.raises(ValueError, match="degrade_pending"):
            LdaEngine(degrade_pending=0)
        with pytest.raises(ValueError, match="degraded_sweeps"):
            LdaEngine(degraded_sweeps=0)


class TestFetchSnapshot:
    def test_retries_transient_then_succeeds(self, tmp_path, snaps):
        from repro.serve.lda_engine import fetch_snapshot
        p = str(tmp_path / "phi.npz")
        snaps[0].save(p)
        plan = FaultPlan([FaultSpec("fail", "serve.fetch", at=0, count=2)])
        slept = []
        with fault.install(plan):
            snap = fetch_snapshot(p, retries=3, backoff_s=0.01,
                                  sleep=slept.append)
        assert snap.digest == snaps[0].digest
        assert slept == [0.01, 0.02]           # exponential backoff
        assert len(plan.log) == 2

    def test_exhausted_retries_raise(self, tmp_path, snaps):
        from repro.serve.lda_engine import fetch_snapshot
        p = str(tmp_path / "phi.npz")
        snaps[0].save(p)
        plan = FaultPlan([FaultSpec("fail", "serve.fetch", at=0, count=5)])
        with fault.install(plan), \
                pytest.raises(SnapshotCorruptError, match="injected"):
            fetch_snapshot(p, retries=2, backoff_s=0.0,
                           sleep=lambda _: None)

    def test_version_skew_never_retried(self, tmp_path, snaps):
        from repro.serve.lda_engine import fetch_snapshot
        p = str(tmp_path / "phi.npz")
        snaps[0].save(p)
        with np.load(p) as data:
            payload = {k: data[k] for k in data.files}
        meta = json.loads(bytes(
            payload[checkpoint._PHI_META_KEY].tobytes()).decode())
        meta["format_version"] = 999
        payload[checkpoint._PHI_META_KEY] = np.frombuffer(
            json.dumps(meta).encode(), np.uint8)
        np.savez(p, **payload)
        plan = FaultPlan()                     # counts fetch attempts
        with fault.install(plan), pytest.raises(FormatVersionError):
            fetch_snapshot(p, retries=5, backoff_s=0.0,
                           sleep=lambda _: None)
        assert plan._counters["serve.fetch"] == 1

    def test_digest_mismatch_never_retried(self, tmp_path, snaps):
        # The retry-taxonomy bug (ISSUE 10): a digest mismatch on an
        # atomically-renamed, fully-parsed file is permanent damage, yet
        # fetch_snapshot used to burn its whole backoff budget on it.
        from repro.fault.errors import SnapshotDigestError
        from repro.serve.lda_engine import fetch_snapshot
        p = str(tmp_path / "phi.npz")
        snaps[0].save(p)
        with np.load(p) as data:
            payload = {k: data[k] for k in data.files}
        meta = json.loads(bytes(
            payload[checkpoint._PHI_META_KEY].tobytes()).decode())
        meta["digest"] = "0" * 64
        payload[checkpoint._PHI_META_KEY] = np.frombuffer(
            json.dumps(meta).encode(), np.uint8)
        np.savez(p, **payload)
        plan = FaultPlan()                     # counts fetch attempts
        slept = []
        with fault.install(plan), pytest.raises(SnapshotDigestError):
            fetch_snapshot(p, retries=5, backoff_s=0.01,
                           sleep=slept.append)
        assert plan._counters["serve.fetch"] == 1   # failed fast
        assert slept == []                     # no backoff budget burned

    def test_meta_shape_skew_never_retried(self, tmp_path, snaps):
        # Same taxonomy for the other proven-permanent damage: a table
        # whose shape contradicts its own meta after a complete parse.
        from repro.fault.errors import SnapshotDigestError
        from repro.serve.lda_engine import fetch_snapshot
        p = str(tmp_path / "phi.npz")
        snaps[0].save(p)
        with np.load(p) as data:
            payload = {k: data[k] for k in data.files}
        payload["phi"] = payload["phi"][:-1]   # drop a row; meta J stale
        np.savez(p, **payload)
        plan = FaultPlan()
        with fault.install(plan), pytest.raises(SnapshotDigestError):
            fetch_snapshot(p, retries=5, backoff_s=0.0,
                           sleep=lambda _: None)
        assert plan._counters["serve.fetch"] == 1

    def test_injected_failures_stay_retryable(self, tmp_path, snaps):
        # The chaos harness's injected "fail" faults model transient
        # fetch damage (plain SnapshotCorruptError) and must keep
        # consuming retries — the fail-fast path is only for the
        # proven-permanent SnapshotDigestError subclass.
        from repro.fault.errors import SnapshotDigestError
        from repro.serve.lda_engine import fetch_snapshot
        p = str(tmp_path / "phi.npz")
        snaps[0].save(p)
        plan = FaultPlan([FaultSpec("fail", "serve.fetch", at=0, count=1)])
        with fault.install(plan):
            snap = fetch_snapshot(p, retries=1, backoff_s=0.0,
                                  sleep=lambda _: None)
        assert snap.digest == snaps[0].digest
        assert issubclass(SnapshotDigestError, SnapshotCorruptError)

    def test_missing_file_retried_until_it_appears(self, tmp_path, snaps):
        from repro.serve.lda_engine import fetch_snapshot
        p = str(tmp_path / "late.npz")
        attempts = []

        def sleep_then_publish(delay):
            attempts.append(delay)
            if len(attempts) == 2:
                snaps[0].save(p)

        snap = fetch_snapshot(p, retries=4, backoff_s=0.01,
                              sleep=sleep_then_publish)
        assert snap.digest == snaps[0].digest and len(attempts) == 2


# ---------------------------------------------------------------------------
# End to end: kill + corrupt → rotation fallback → bit-exact resume
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestNomadFaultRecovery:
    def _build(self, tmp_path=None, resume=None):
        import jax

        from repro.core.nomad import NomadLDA
        from repro.data import synthetic
        from repro.data.sharding import build_layout
        T = 4
        corpus, _, _ = synthetic.make_corpus(
            num_docs=24, vocab_size=48, num_topics=T, mean_doc_len=10.0,
            seed=11)
        mesh = jax.make_mesh((1,), ("worker",))
        lay = build_layout(corpus, n_workers=1, T=T, n_blocks=2)
        kw = {}
        if tmp_path is not None:
            kw = dict(checkpoint_every=1, checkpoint_path=str(tmp_path),
                      checkpoint_keep=3)
        return NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                        alpha=50.0 / T, beta=0.01, resume_from=resume, **kw)

    def _digest(self, lda, arrays):
        import hashlib
        h = hashlib.sha256()
        h.update(np.ascontiguousarray(lda.layout.extract_canonical(
            np.asarray(arrays["z"]))).tobytes())
        for part in lda.global_counts(arrays):
            h.update(np.ascontiguousarray(part).tobytes())
        return h.hexdigest()

    def test_kill_corrupt_fallback_bitexact(self, tmp_path):
        sweeps, kill_at = 5, 3
        ref_lda = self._build()
        arrays, _ = ref_lda.run(sweeps, init_seed=0)
        ref = self._digest(ref_lda, arrays)

        rot_dir = tmp_path / "rot"
        plan = FaultPlan([
            FaultSpec("corrupt", "chain.write", at=kill_at - 1, nbytes=4),
            FaultSpec("kill", "trainer.sweep", at=kill_at - 1),
        ], seed=7)
        lda = self._build(tmp_path=rot_dir)
        with pytest.raises(InjectedKill):
            lda.run(sweeps, init_seed=0, fault_plan=plan)
        assert plan.log[0][2] == "corrupt"

        rot = CheckpointRotation(str(rot_dir), keep=3)
        _, _, step = rot.load_latest_valid()
        assert step == kill_at - 1             # fell back past the damage
        lda2 = self._build(resume=str(rot_dir))
        arrays2, done = lda2.run(sweeps)
        assert done == sweeps
        assert self._digest(lda2, arrays2) == ref

    def test_dropped_publish_fault(self, tmp_path):
        """A dropped publish skips the snapshot but never the chain."""
        published = []
        plan = FaultPlan([FaultSpec("drop", "trainer.publish", at=1)])
        lda = self._build()
        arrays, _ = lda.run(3, init_seed=0, publish_every=1,
                            on_publish=lambda s: published.append(
                                s.meta["sweep"]),
                            fault_plan=plan)
        assert published == [1, 3]             # sweep 2's publish dropped
        ref_lda = self._build()
        ref_arrays, _ = ref_lda.run(3, init_seed=0)
        assert self._digest(lda, arrays) == self._digest(ref_lda,
                                                         ref_arrays)
