"""Doc-axis tiling of the doc-topic shard (DESIGN.md §7).

Three layers under test:

* the **partition**: ``build_layout(doc_tile=...)`` groups each worker's
  local doc rows into slabs of ``doc_tile`` consecutive rows — every doc
  row lands in exactly one slab, slabs never exceed ``doc_tile`` rows
  (the last may be short when ``I_max`` is not a multiple), and the
  grouped token order guarantees every aligned token tile addresses one
  slab only (``doc_tile_of`` consistency);
* the **kernels**: the doc-tiled fused kernels (one ``(doc_tile, T)``
  slab VMEM-resident, explicit DMA paging) are bit-equal to the shared
  oracle and to whole-shard execution over the same token stream —
  including across slab switches and slab *revisits*;
* the **ceiling**: a doc-topic shard too large for the whole-shard VMEM
  budget is rejected by the untiled compiled-path guard but sweeps
  successfully (and exactly) with ``doc_tile`` set.

Property tests run under real ``hypothesis`` when installed, else the
deterministic shim in ``tests/conftest.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import synthetic
from repro.data.sharding import build_layout

i32 = lambda a: jnp.asarray(a, jnp.int32)


def _corpus(num_docs, vocab, seed):
    corpus, _, _ = synthetic.make_corpus(
        num_docs=num_docs, vocab_size=vocab, num_topics=8,
        mean_doc_len=12.0, seed=seed)
    return corpus


def _counts(lay, z_c, T):
    n_td = np.zeros((lay.I_max, T), np.int32)
    n_wt = np.zeros((lay.B, lay.J_max, T), np.int32)
    n_t = np.zeros((T,), np.int32)
    _, b_i, d_i, j_i = lay.token_coords()
    np.add.at(n_td, (d_i, z_c), 1)
    np.add.at(n_wt, (b_i, j_i, z_c), 1)
    np.add.at(n_t, z_c, 1)
    return i32(n_td), i32(n_wt), i32(n_t)


class TestDocTilePartition:
    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(1, 4), mult=st.integers(1, 3),
           dt=st.integers(1, 9), num_docs=st.integers(8, 50),
           vocab=st.integers(24, 96), seed=st.integers(0, 6),
           kind=st.sampled_from(["dense", "ragged"]))
    def test_every_doc_row_in_exactly_one_slab(self, W, mult, dt, num_docs,
                                               vocab, seed, kind):
        corpus = _corpus(num_docs, vocab, seed)
        kw = dict(doc_blk=8) if kind == "dense" else {}
        lay = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W,
                           layout=kind, doc_tile=dt, **kw)
        # slab count covers I_max (non-multiple I_max ⇒ short last slab)
        assert lay.doc_tile == dt
        assert lay.n_doc_tiles == -(-lay.I_max // dt)
        groups = np.arange(lay.I_max) // dt
        # partition: every row in exactly one slab, none above doc_tile
        assert groups.min() == 0 and groups.max() == lay.n_doc_tiles - 1
        assert np.bincount(groups).max() <= dt
        # layout places every token exactly once
        assert int(lay.tok_valid.sum()) == corpus.num_tokens
        assert lay.word_map_mismatches() == 0

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(1, 4), mult=st.integers(1, 3),
           dt=st.integers(1, 9), num_docs=st.integers(8, 50),
           vocab=st.integers(24, 96), seed=st.integers(0, 6),
           kind=st.sampled_from(["dense", "ragged"]))
    def test_every_token_tile_touches_one_slab(self, W, mult, dt, num_docs,
                                               vocab, seed, kind):
        corpus = _corpus(num_docs, vocab, seed)
        kw = dict(doc_blk=8) if kind == "dense" else {}
        lay = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W,
                           layout=kind, doc_tile=dt, **kw)
        gran = lay.doc_blk if kind == "dense" else lay.tile
        assert gran == lay.doc_blk            # ragged records doc_blk=tile
        # the tile each token physically lands in must be mapped to the
        # token's own doc slab — the invariant the kernel paging rests on
        dto_flat = np.asarray(lay.doc_tile_of).reshape(-1)
        _, _, d, _ = lay.token_coords()
        np.testing.assert_array_equal(dto_flat[lay.canon_idx // gran],
                                      d // dt)
        assert dto_flat.min() >= 0
        assert dto_flat.max() < lay.n_doc_tiles
        # rows are whole tile multiples so the grid divides evenly
        assert lay.tok_doc.shape[-1] % gran == 0

    @settings(max_examples=15, deadline=None)
    @given(W=st.integers(1, 3), dt=st.integers(1, 6),
           num_docs=st.integers(8, 40), vocab=st.integers(24, 64),
           seed=st.integers(0, 6))
    def test_grouped_canonical_order_is_shared_and_complete(
            self, W, dt, num_docs, vocab, seed):
        """Dense and ragged grouped layouts carry the identical canonical
        token sequence (the cross-layout bit-equality precondition), and
        grouping only permutes the ungrouped sequence."""
        corpus = _corpus(num_docs, vocab, seed)
        dense = build_layout(corpus, n_workers=W, T=8, n_blocks=W,
                             doc_tile=dt, doc_blk=8)
        rag = build_layout(corpus, n_workers=W, T=8, n_blocks=W,
                           layout="ragged", doc_tile=dt)
        base = build_layout(corpus, n_workers=W, T=8, n_blocks=W)
        for a, b in ((dense, rag),):
            np.testing.assert_array_equal(a.extract_canonical(a.tok_gwrd),
                                          b.extract_canonical(b.tok_gwrd))
            np.testing.assert_array_equal(a.extract_canonical(a.tok_doc),
                                          b.extract_canonical(b.tok_doc))
        # same multiset of (global doc, global word) pairs as ungrouped
        def pairs(lay):
            gd, gw = lay.token_globals()
            return np.sort(gd.astype(np.int64) * corpus.num_words + gw)
        np.testing.assert_array_equal(pairs(dense), pairs(base))

    def test_single_doc_spans_many_tiles(self):
        """One document holding every token: a single slab spans the whole
        stream and tiling degenerates cleanly (doc_tile=1, I_max=1)."""
        corpus = _corpus(1, 24, 3)
        assert corpus.num_docs == 1
        for kind in ("dense", "ragged"):
            kw = dict(doc_blk=8) if kind == "dense" else dict(tile=8)
            lay = build_layout(corpus, n_workers=1, T=8, n_blocks=2,
                               layout=kind, doc_tile=1, **kw)
            assert lay.n_doc_tiles == 1
            assert int(lay.tok_valid.sum()) == corpus.num_tokens
            assert (np.asarray(lay.doc_tile_of) == 0).all()

    def test_doc_blk_without_doc_tile_rejected(self):
        corpus = _corpus(10, 32, 0)
        with pytest.raises(ValueError, match="doc_blk"):
            build_layout(corpus, n_workers=1, T=8, doc_blk=8)
        with pytest.raises(ValueError, match="doc_tile"):
            build_layout(corpus, n_workers=1, T=8, doc_tile=0)
        with pytest.raises(ValueError, match="tile"):
            build_layout(corpus, n_workers=1, T=8, layout="ragged",
                         doc_tile=2, doc_blk=8)


class TestDocTiledKernels:
    def _setup(self, T=16, B=4, dt=3, seed=11, tile=8):
        corpus = _corpus(18, 60, seed)
        lay = build_layout(corpus, n_workers=1, T=T, n_blocks=B,
                           layout="ragged", doc_tile=dt, tile=tile)
        rng = np.random.default_rng(seed)
        N = corpus.num_tokens
        z_c = rng.integers(0, T, N).astype(np.int32)
        u_c = rng.random(N).astype(np.float32)
        tok = tuple(i32(a[0, 0]) for a in (lay.tok_doc, lay.tok_wrd,
                                           lay.tok_valid, lay.tok_bound))
        z0 = i32(lay.place_canonical(z_c)[0, 0])
        u0 = jnp.asarray(lay.place_canonical(u_c)[0, 0])
        counts = _counts(lay, z_c, T)
        return lay, tok, z0, u0, counts

    def test_one_slab_switch_matches_ragged_ref(self):
        """The satellite's minimal case: a stream whose doc_tile_of map
        switches slab at least once (and revisits one) must be bit-equal
        to the whole-table oracle."""
        from repro.kernels.fused_sweep import fused_sweep_ragged
        from repro.kernels.fused_sweep.ref import fused_sweep_ragged_ref
        T = 16
        lay, tok, z0, u0, counts = self._setup(T=T, dt=3)
        cot = i32(lay.cell_of_tile[0, 0])
        dto = np.asarray(lay.doc_tile_of[0, 0])
        switches = int((dto[1:] != dto[:-1]).sum())
        assert switches >= 1                       # a slab switch happens
        assert len(np.unique(dto)) < switches + 1  # ... and a revisit too
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60)
        got = fused_sweep_ragged(*tok, z0, u0, cot, *counts,
                                 n_blk=lay.tile, doc_tile_of=i32(dto),
                                 doc_rows=lay.doc_tile, **kw)
        ref = fused_sweep_ragged_ref(*tok, z0, u0, cot, *counts,
                                     n_blk=lay.tile, **kw)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_tile_split_halves_chain_with_paging(self):
        """The pipelined ring's half-stream calls, both paged: slabs are
        re-paged per call and the chain still matches one whole call."""
        from repro.data.sharding import half_queue_split
        from repro.kernels.fused_sweep import fused_sweep_ragged
        T = 16
        lay, tok, z0, u0, counts = self._setup(T=T, dt=3, seed=13)
        cot = i32(lay.cell_of_tile[0, 0])
        dto = i32(lay.doc_tile_of[0, 0])
        n_td, n_wt, n_t = counts
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60,
                  n_blk=lay.tile, doc_tile_of=dto, doc_rows=lay.doc_tile)
        whole = fused_sweep_ragged(*tok, z0, u0, cot, *counts, **kw)
        k0, r0 = half_queue_split(lay.k), lay.tile_split
        assert 0 < r0 < lay.n_tiles
        z_h0, n_td0, nwt0, n_t0, _ = fused_sweep_ragged(
            *tok, z0, u0, cot, *counts,
            tile_start=0, num_tiles=r0, cell_start=0, num_cells=k0, **kw)
        z_h1, n_td1, nwt1, n_t1, _ = fused_sweep_ragged(
            *tok, z0, u0, cot, n_td0, n_wt, n_t0,
            tile_start=r0, num_tiles=lay.n_tiles - r0,
            cell_start=k0, num_cells=lay.k - k0, **kw)
        got = (jnp.concatenate([z_h0, z_h1]), n_td1,
               jnp.concatenate([nwt0, nwt1]), n_t1)
        for a, b in zip(got, whole[:4]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_dense_cells_paged_matches_untiled(self):
        from repro.kernels.fused_sweep import fused_sweep_cells
        T = 16
        corpus = _corpus(18, 60, 17)
        lay = build_layout(corpus, n_workers=1, T=T, n_blocks=4,
                           doc_tile=4, doc_blk=16)
        rng = np.random.default_rng(17)
        z_c = rng.integers(0, T, corpus.num_tokens).astype(np.int32)
        u_c = rng.random(corpus.num_tokens).astype(np.float32)
        tok = tuple(i32(a[0]) for a in (lay.tok_doc, lay.tok_wrd,
                                        lay.tok_valid, lay.tok_bound))
        z0 = i32(lay.place_canonical(z_c)[0])
        u0 = jnp.asarray(lay.place_canonical(u_c)[0])
        counts = _counts(lay, z_c, T)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60)
        base = fused_sweep_cells(*tok, z0, u0, *counts, **kw)
        paged = fused_sweep_cells(*tok, z0, u0, *counts,
                                  doc_tile_of=i32(lay.doc_tile_of[0]),
                                  doc_rows=lay.doc_tile,
                                  n_blk=lay.doc_blk, **kw)
        for a, b in zip(paged, base):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_doc_args_validated(self):
        from repro.kernels.fused_sweep import fused_sweep_ragged
        T = 16
        lay, tok, z0, u0, counts = self._setup(T=T)
        cot = i32(lay.cell_of_tile[0, 0])
        dto = i32(lay.doc_tile_of[0, 0])
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 60,
                  n_blk=lay.tile)
        with pytest.raises(ValueError, match="doc tiling"):
            fused_sweep_ragged(*tok, z0, u0, cot, *counts,
                               doc_tile_of=dto, **kw)      # no doc_rows
        with pytest.raises(ValueError, match="doc tiling"):
            fused_sweep_ragged(*tok, z0, u0, cot, *counts,
                               doc_rows=3, **kw)           # no map
        with pytest.raises(ValueError, match="doc_tile_of shape"):
            fused_sweep_ragged(*tok, z0, u0, cot, *counts,
                               doc_tile_of=dto[:-1], doc_rows=3, **kw)


class TestVmemCeiling:
    """The acceptance case: a doc-topic shard past the whole-shard VMEM
    budget sweeps successfully — and exactly — once doc-tiled."""

    def _big_stream(self, I=2000, T=1024, J=8, n_blk=32, n_tiles=6,
                    doc_rows=256, seed=5):
        """A hand-built grouped token stream over a doc shard whose
        whole-table VMEM footprint exceeds the budget: each tile's tokens
        live in one (doc_rows, T) slab, slab ids revisit."""
        rng = np.random.default_rng(seed)
        dto = np.array([0, 1, 0, 2, 1, 0])[:n_tiles].astype(np.int32)
        tok_doc = np.concatenate([
            rng.integers(g * doc_rows, min((g + 1) * doc_rows, I), n_blk)
            for g in dto]).astype(np.int32)
        # word-major within each tile so boundary flags stay word-change
        wrd = rng.integers(0, J, n_tiles * n_blk).astype(np.int32)
        order = np.concatenate([np.arange(n_blk)[np.argsort(
            wrd[t * n_blk:(t + 1) * n_blk], kind="stable")] + t * n_blk
            for t in range(n_tiles)])
        tok_doc, wrd = tok_doc[order], wrd[order]
        bound = np.ones(n_tiles * n_blk, np.int32)
        bound[1:] = wrd[1:] != wrd[:-1]
        bound[0] = 1
        valid = np.ones(n_tiles * n_blk, np.int32)
        z = rng.integers(0, T, n_tiles * n_blk).astype(np.int32)
        u = rng.random(n_tiles * n_blk).astype(np.float32)
        n_td = np.zeros((I, T), np.int32)
        n_wt = np.zeros((J, T), np.int32)
        n_t = np.zeros((T,), np.int32)
        np.add.at(n_td, (tok_doc, z), 1)
        np.add.at(n_wt, (wrd, z), 1)
        np.add.at(n_t, z, 1)
        return (i32(tok_doc), i32(wrd), i32(valid), i32(bound), i32(z),
                jnp.asarray(u), i32(n_td), i32(n_wt), i32(n_t), i32(dto))

    def test_untiled_guard_rejects_then_tiled_sweeps(self):
        from repro.kernels.fused_sweep import (fused_sweep_tokens,
                                               fused_vmem_bytes)
        from repro.kernels.fused_sweep.ops import VMEM_BUDGET_BYTES
        from repro.kernels.fused_sweep.ref import fused_sweep_ref
        I, T, doc_rows, n_blk = 2000, 1024, 256, 32
        *args, dto = self._big_stream(I=I, T=T, doc_rows=doc_rows,
                                      n_blk=n_blk)
        kw = dict(alpha=50.0 / T, beta=0.01, beta_bar=0.01 * 8)
        # whole-shard estimate exceeds the budget → the compiled path
        # refuses (raised host-side, before any pallas_call)
        assert fused_vmem_bytes(I, 8, T, n_blk) > VMEM_BUDGET_BYTES
        with pytest.raises(ValueError, match="VMEM budget"):
            fused_sweep_tokens(*args, n_blk=n_blk, interpret=False, **kw)
        # the tiled estimate fits with an order of magnitude to spare
        assert fused_vmem_bytes(I, 8, T, n_blk, doc_rows) \
            < VMEM_BUDGET_BYTES // 8
        # ... and the tiled sweep runs the exact chain
        got = fused_sweep_tokens(*args, doc_tile_of=dto, doc_rows=doc_rows,
                                 n_blk=n_blk, **kw)
        ref = fused_sweep_ref(*args, **kw)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestNomadDocTiling:
    def test_paged_equals_untiled_both_kinds(self):
        """W=1 in-process: paged fused execution ≡ whole-shard execution ≡
        scan, on dense and ragged grouped layouts."""
        from repro.core.nomad import NomadLDA
        T = 16
        corpus = _corpus(20, 50, 9)
        mesh = jax.make_mesh((1,), ("worker",))
        results = {}
        for kind in ("dense", "ragged"):
            lay = build_layout(
                corpus, n_workers=1, T=T, n_blocks=4, layout=kind,
                doc_tile=5, **(dict(doc_blk=16) if kind == "dense" else {}))
            for page, inner in ((None, "scan"), (None, "fused"),
                                (5, "fused")):
                lda = NomadLDA(mesh=mesh, ring_axes=("worker",),
                               layout=lay, alpha=50.0 / T, beta=0.01,
                               sync_mode="stoken", inner_mode=inner,
                               ring_mode="pipelined", doc_tile=page)
                arrays = lda.init_arrays(seed=0)
                for it in range(2):
                    arrays = lda.sweep(arrays, seed=it)
                results[kind, page, inner] = (
                    lay.extract_canonical(np.asarray(arrays["z"])),
                    *lda.global_counts(arrays))
        ref = results["dense", None, "scan"]
        for key, got in results.items():
            for a, b in zip(got, ref):
                np.testing.assert_array_equal(a, b, err_msg=str(key))

    def test_doc_tile_mismatch_rejected(self):
        from repro.core.nomad import NomadLDA
        corpus = _corpus(12, 32, 1)
        mesh = jax.make_mesh((1,), ("worker",))
        lay = build_layout(corpus, n_workers=1, T=8)
        with pytest.raises(ValueError, match="doc_tile"):
            NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                     alpha=1.0, beta=0.01, doc_tile=4)
