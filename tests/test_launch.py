"""Launch-layer unit tests: sharding rules, HLO collective parser,
input specs (no multi-device requirement — pure logic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_config, shape_applicable
from repro.launch.dryrun import collective_bytes
from repro.launch.input_specs import input_specs
from repro.launch.sharding_rules import sanitize_spec
from repro.models import transformer
from repro.train.train_step import init_train_state
from repro.launch import sharding_rules as rules


def _fake_mesh():
    """AbstractMesh stand-in: we only need axis sizes for spec logic."""
    dev = np.array(jax.devices()[:1])
    # use a 1-device concrete mesh for NamedSharding construction and a
    # shape dict for divisibility logic via a tiny shim
    class Shim:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    return Shim()


class TestSanitize:
    def test_drops_nondivisible_axes(self):
        mesh = _fake_mesh()
        spec = sanitize_spec(P("model", None), (151655, 896), mesh)
        assert spec == P(None, None)
        spec = sanitize_spec(P("model", None), (163840, 7168), mesh)
        assert spec == P("model", None)

    def test_tuple_axes(self):
        mesh = _fake_mesh()
        spec = sanitize_spec(P(("data", "model"), None), (512, 4), mesh)
        assert spec == P(("data", "model"), None)
        spec = sanitize_spec(P(("data", "model"), None), (100, 4), mesh)
        assert spec == P(None, None)

    def test_pads_short_specs(self):
        mesh = _fake_mesh()
        spec = sanitize_spec(P("model"), (32, 4, 4), mesh)
        assert spec == P("model", None, None)


class TestCollectiveParser:
    HLO = """
  %ag = f32[2048,2048]{0,1} all-gather(%copy), channel_id=1
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={}
  %rs = bf16[64,32]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = f32[16,8]{1,0} all-to-all(%z)
  %cp = s32[128]{0} collective-permute(%w)
  %ags = (f32[256],f32[256]) all-gather-start(%v)
  %agd = f32[256]{0} all-gather-done(%ags)
  %fusion = f32[8]{0} fusion(%a), calls=%c, metadata={op_name="all-reduce"}
"""

    def test_counts_and_bytes(self):
        out = collective_bytes(self.HLO)
        assert out["all-gather"] == 2048 * 2048 * 4 + 256 * 4  # + start/2
        assert out["all-reduce"] == 1024 * 4      # metadata line not counted
        assert out["reduce-scatter"] == 64 * 32 * 2
        assert out["all-to-all"] == 16 * 8 * 4
        assert out["collective-permute"] == 128 * 4
        assert out["op_counts"]["all-gather"] == 2

    def test_empty(self):
        out = collective_bytes("%x = f32[4]{0} add(%a, %b)")
        assert out["total"] == 0


class TestInputSpecs:
    @pytest.mark.parametrize("arch", sorted(ARCHS))
    @pytest.mark.parametrize("shape", sorted(INPUT_SHAPES))
    def test_specs_exist_for_applicable(self, arch, shape):
        cfg = get_config(arch)
        ok, note = shape_applicable(cfg, shape)
        if not ok:
            assert cfg.is_encoder_only
            return
        sds = input_specs(cfg, shape)
        spec = INPUT_SHAPES[shape]
        if spec["kind"] == "decode":
            assert sds["tokens"].shape == (spec["global_batch"], 1)
            assert sds["pos"].shape == (spec["global_batch"],)
        elif cfg.modality == "audio_frames":
            assert sds["frames"].shape[0] == spec["global_batch"]
        else:
            assert sds["tokens"].shape[0] == spec["global_batch"]

    def test_vlm_prefill_splits_patches(self):
        cfg = get_config("internvl2-1b")
        sds = input_specs(cfg, "prefill_32k")
        total = sds["tokens"].shape[1] + sds["patches"].shape[1]
        assert total == INPUT_SHAPES["prefill_32k"]["seq_len"]


class TestParamSpecCoverage:
    """Every arch's param tree gets a spec; sharded axes always divide."""

    @pytest.mark.parametrize("arch", sorted(ARCHS))
    def test_specs_cover_tree(self, arch):
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.key(0)))
        specs = rules.param_specs(shapes, _fake_mesh(), fsdp=False)
        n_shapes = len(jax.tree_util.tree_leaves(shapes))
        n_specs = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_shapes == n_specs

    def test_moe_experts_sharded(self):
        cfg = get_config("deepseek-moe-16b")
        shapes = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.key(0)))
        specs = rules.param_specs(shapes, _fake_mesh(), fsdp=False)
        # find a routed expert weight: stacked (L, E, d, f) → E over model
        seg = specs["segments"][-1]["mlp"]
        assert seg["w_gate"] == P(None, "model", None, None)
        assert seg["router"] == P(None, None, "model")
