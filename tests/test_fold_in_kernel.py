"""Pallas fold-in kernel tests (DESIGN.md §10a).

The tentpole equality, factored in two:

* the draw precompute + pure-jnp oracle (`fold_in_kernel_ref`) is
  bit-identical to `core/heldout.py:fold_in_batch` — the counter-mode
  chains agree when hoisted out of the sweep loop;
* the Pallas kernel (`fold_in_pallas`, via the `fold_in_fused` wrapper)
  is bit-identical to that oracle — the kernel replays the chain
  faithfully across doc counts, length buckets, sweep counts, empty
  docs and garbage padding.

Wrapper policy (interpret default, VMEM budget, validation) rides the
same class.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.heldout import doc_fold_key, fold_in, fold_in_batch
from repro.kernels.fold_in import (fold_in_draws, fold_in_fused,
                                   fold_in_kernel_ref, fold_in_vmem_bytes)
from repro.kernels.fused_sweep.ops import VMEM_BUDGET_BYTES

J, T = 31, 8
ALPHA = 0.375


@pytest.fixture(scope="module")
def phi():
    rng = np.random.default_rng(11)
    return jnp.asarray(rng.random((J, T), np.float32))


def _batch(seed, lengths, L):
    rng = np.random.default_rng(seed)
    D = len(lengths)
    w = rng.integers(0, J, (D, L)).astype(np.int32)
    v = np.arange(L)[None, :] < np.asarray(lengths)[:, None]
    return jnp.asarray(w), jnp.asarray(v)


def _keys(key, D):
    return jax.vmap(doc_fold_key, in_axes=(None, 0))(
        key, jnp.arange(D, dtype=jnp.int32))


class TestFoldInKernelParity:
    @pytest.mark.parametrize("lengths,L,sweeps", [
        ([0, 1, 5, 12], 16, 3),
        ([4], 4, 1),
        ([7, 7, 7, 7, 7, 7, 7, 7], 8, 2),
        ([0, 0], 8, 4),                      # all-empty batch
        ([30, 2], 32, 5),
    ])
    def test_fused_bitexact_vs_scan(self, phi, lengths, L, sweeps):
        w, v = _batch(0, lengths, L)
        dk = _keys(jax.random.key(7), len(lengths))
        ref = np.asarray(fold_in_batch(w, v, phi, ALPHA, dk, sweeps))
        z0, u = fold_in_draws(dk, L, T, sweeps)
        oracle = np.asarray(fold_in_kernel_ref(
            w, v, z0, u, jnp.float32(ALPHA), phi))
        fused = np.asarray(fold_in_fused(w, v, phi, ALPHA, dk, sweeps))
        np.testing.assert_array_equal(oracle, ref)
        np.testing.assert_array_equal(fused, ref)

    def test_fused_matches_serial_fold_in(self, phi):
        words = np.asarray([3, 3, 9, 14, 2], np.int32)
        key = jax.random.key(123)
        serial = np.asarray(fold_in(words, np.zeros(5, np.int32), 1, phi,
                                    ALPHA, key, sweeps=4))
        w = jnp.asarray(np.pad(words, (0, 3))[None])
        v = jnp.asarray((np.arange(8) < 5)[None])
        fused = np.asarray(fold_in_fused(
            w, v, phi, ALPHA, doc_fold_key(key, 0)[None], 4))
        np.testing.assert_array_equal(fused[0], serial[0])

    def test_padding_garbage_inert(self, phi):
        """Garbage word ids in padded slots and a wider L cannot perturb
        any row — same contract as fold_in_batch."""
        lengths = [3, 6]
        w, v = _batch(1, lengths, 8)
        dk = _keys(jax.random.key(3), 2)
        base = np.asarray(fold_in_fused(w, v, phi, ALPHA, dk, 3))
        w_g = np.asarray(w).copy()
        w_g[~np.asarray(v)] = J - 1
        garbage = np.asarray(fold_in_fused(
            jnp.asarray(w_g), v, phi, ALPHA, dk, 3))
        np.testing.assert_array_equal(base, garbage)
        w32, v32 = _batch(1, lengths, 32)
        w32 = np.asarray(w32).copy()
        w32[:, :8] = np.asarray(w)           # same real tokens
        wider = np.asarray(fold_in_fused(
            jnp.asarray(w32), v32, phi, ALPHA, dk, 3))
        np.testing.assert_array_equal(base, wider)

    def test_draws_match_reference_chains(self, phi):
        """z0/u are the exact arrays fold_in_batch derives internally:
        a doc keyed identically in two different batch positions draws
        identically (row RNG is batch-independent)."""
        dk = _keys(jax.random.key(5), 4)
        z0, u = fold_in_draws(dk, 8, T, 2)
        assert z0.shape == (4, 8) and z0.dtype == jnp.int32
        assert u.shape == (4, 2, 8) and u.dtype == jnp.float32
        z0b, ub = fold_in_draws(dk[2:3], 8, T, 2)
        np.testing.assert_array_equal(np.asarray(z0[2]), np.asarray(z0b[0]))
        np.testing.assert_array_equal(np.asarray(u[2]), np.asarray(ub[0]))
        assert (np.asarray(z0) >= 0).all() and (np.asarray(z0) < T).all()


class TestFoldInWrapper:
    def test_shape_validation(self, phi):
        w, v = _batch(0, [2, 2], 4)
        dk = _keys(jax.random.key(0), 2)
        with pytest.raises(ValueError, match="matching"):
            fold_in_fused(w, v[:1], phi, ALPHA, dk, 2)
        with pytest.raises(ValueError, match="keys"):
            fold_in_fused(w, v, phi, ALPHA, dk[:1], 2)
        with pytest.raises(ValueError, match="sweeps"):
            fold_in_fused(w, v, phi, ALPHA, dk, 0)

    def test_vmem_budget_guard_compiled_only(self, phi):
        w, v = _batch(0, [2, 2], 4)
        dk = _keys(jax.random.key(0), 2)
        # estimate is monotone and the guard trips only on the compiled
        # path; interpret mode must not consult it
        assert fold_in_vmem_bytes(4, T, 2) < VMEM_BUDGET_BYTES
        big_L = VMEM_BUDGET_BYTES  # sweeps·L alone blows the budget
        assert fold_in_vmem_bytes(big_L, T, 2) > VMEM_BUDGET_BYTES
        wide = jnp.zeros((1, big_L), jnp.int32)
        with pytest.raises(ValueError, match="VMEM budget"):
            fold_in_fused(wide, wide.astype(bool), phi, ALPHA, dk[:1],
                          2, interpret=False)

    def test_jittable_inside_theta_kernel(self, phi):
        """The wrapper traces under jit with alpha as a tracer (the
        engine's _theta_kernel passes buf.alpha as a traced arg)."""
        w, v = _batch(2, [3, 1], 4)
        dk = _keys(jax.random.key(1), 2)

        @jax.jit
        def run(w, v, phi, alpha, dk):
            return fold_in_fused(w, v, phi, alpha, dk, 2)

        got = np.asarray(run(w, v, phi, jnp.float32(ALPHA), dk))
        ref = np.asarray(fold_in_batch(w, v, phi, ALPHA, dk, 2))
        np.testing.assert_array_equal(got, ref)
