"""Robustness: float drift bounds, degenerate corpora, edge shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cgs, ftree, likelihood
from repro.data.corpus import Corpus
from repro.data.sharding import build_layout
from repro.data import synthetic


class TestFTreeDrift:
    """DESIGN §3: repeated delta updates drift in f32; rebuilds bound it."""

    def test_drift_grows_then_rebuild_resets(self):
        T = 1024
        rng = np.random.default_rng(0)
        p = rng.random(T).astype(np.float32) + 0.5
        F = ftree.build(jnp.asarray(p))
        ts = rng.integers(0, T, 20_000).astype(np.int32)
        ds = (rng.random(20_000).astype(np.float32) - 0.5) * 0.1

        def many(F, ts, ds):
            def body(F, td):
                return ftree.update(F, td[0], td[1]), None
            return jax.lax.scan(body, F, (jnp.asarray(ts),
                                          jnp.asarray(ds)))[0]
        F = jax.jit(many)(F, ts, ds)
        p2 = p.copy()
        np.add.at(p2, ts, ds)
        # internal consistency after 20k updates: root vs true sum
        drift = abs(float(ftree.total(F)) - p2.sum())
        assert drift < 0.5, drift   # bounded but nonzero in general
        # rebuild restores exactness
        F_rebuilt = ftree.build(jnp.asarray(ftree.leaves(F)))
        resid = abs(float(ftree.total(F_rebuilt))
                    - float(ftree.leaves(F).sum()))
        assert resid < 1e-2

    @given(n_upd=st.integers(1, 500), seed=st.integers(0, 20))
    @settings(max_examples=15, deadline=None)
    def test_internal_nodes_stay_consistent(self, n_upd, seed):
        T = 64
        rng = np.random.default_rng(seed)
        p = rng.random(T).astype(np.float32) + 0.1
        F = ftree.build(jnp.asarray(p))
        for _ in range(n_upd // 50 + 1):
            ts = jnp.asarray(rng.integers(0, T, 50).astype(np.int32))
            ds = jnp.asarray(rng.random(50).astype(np.float32) * 0.2)
            F = ftree.update_batch(F, ts, ds)
        Fn = np.asarray(F)
        for i in range(1, T):
            np.testing.assert_allclose(Fn[i], Fn[2 * i] + Fn[2 * i + 1],
                                       rtol=1e-3, atol=1e-3)


class TestDegenerateCorpora:
    def test_single_word_vocab(self):
        doc_ids = np.repeat(np.arange(4, dtype=np.int32), 5)
        word_ids = np.zeros(20, np.int32)
        corpus = Corpus(doc_ids=doc_ids, word_ids=word_ids,
                        num_docs=4, num_words=1)
        T = 4
        state = cgs.init_state(corpus, T, jax.random.key(0))
        order = jnp.asarray(corpus.word_order())
        boundary = jnp.asarray(corpus.word_boundary())
        state = cgs.sweep_fplda_word(
            state, jnp.asarray(doc_ids), jnp.asarray(word_ids),
            order, boundary, 0.5, 0.01)
        assert cgs.check_invariants(state, corpus)["n_t_mismatch"] == 0

    def test_one_token_documents(self):
        doc_ids = np.arange(10, dtype=np.int32)
        word_ids = (np.arange(10) % 3).astype(np.int32)
        corpus = Corpus(doc_ids=doc_ids, word_ids=word_ids,
                        num_docs=10, num_words=3)
        state = cgs.init_state(corpus, 4, jax.random.key(1))
        order = jnp.asarray(corpus.doc_order())
        state = cgs.sweep_reference(
            state, jnp.asarray(doc_ids), jnp.asarray(word_ids), order,
            0.5, 0.01)
        v = cgs.check_invariants(state, corpus)
        assert all(x == 0 for x in v.values())

    def test_layout_with_empty_workers(self):
        """More workers than documents: some workers own nothing."""
        doc_ids = np.zeros(6, np.int32)
        word_ids = np.arange(6, dtype=np.int32)
        corpus = Corpus(doc_ids=doc_ids, word_ids=word_ids,
                        num_docs=1, num_words=6)
        lay = build_layout(corpus, n_workers=4, T=4)
        assert int(lay.tok_valid.sum()) == 6
        assert lay.cell_sizes.sum() == 6

    def test_ll_on_empty_topic(self):
        """Topics with zero mass must not produce NaN LL."""
        corpus, _, _ = synthetic.make_corpus(
            num_docs=10, vocab_size=16, num_topics=2, mean_doc_len=5.0,
            seed=2)
        T = 8  # more topics than data uses
        state = cgs.init_state(corpus, T, jax.random.key(0))
        z0 = jnp.zeros_like(state.z)  # all mass on topic 0
        n_td, n_wt, n_t = cgs.counts_from_assignments(
            jnp.asarray(corpus.doc_ids), jnp.asarray(corpus.word_ids),
            z0, corpus.num_docs, corpus.num_words, T)
        s = cgs.LDAState(z=z0, n_td=n_td, n_wt=n_wt, n_t=n_t, key=state.key)
        assert np.isfinite(likelihood.log_likelihood(s, 0.1, 0.01))


class TestSweepOrderPermutationInvariance:
    @given(seed=st.integers(0, 30))
    @settings(max_examples=8, deadline=None)
    def test_invariants_hold_for_random_orders(self, seed):
        """CGS stays exact for ANY visitation order, not just doc/word."""
        corpus, _, _ = synthetic.make_corpus(
            num_docs=15, vocab_size=32, num_topics=4, mean_doc_len=8.0,
            seed=seed)
        state = cgs.init_state(corpus, 4, jax.random.key(seed))
        rng = np.random.default_rng(seed)
        order = jnp.asarray(rng.permutation(corpus.num_tokens)
                            .astype(np.int32))
        state = cgs.sweep_reference(
            state, jnp.asarray(corpus.doc_ids),
            jnp.asarray(corpus.word_ids), order, 0.5, 0.01)
        v = cgs.check_invariants(state, corpus)
        assert all(x == 0 for x in v.values()), v
