"""Ring-buffer KV cache (§Perf long-context decode optimization).

Decoding with a window-sized ring buffer must produce the same logits as
decoding with the full-length cache, for sliding-window models — including
after the buffer wraps."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer
from repro.serve.serve_step import decode_step, init_cache
from repro.train.train_step import init_train_state


@pytest.fixture(scope="module")
def sw_model():
    cfg = get_config("granite-3-2b").smoke()
    cfg = cfg.with_long_context(window=8)      # tiny window to force wraps
    state = init_train_state(cfg, jax.random.key(0))
    return cfg, state.params


def _decode_seq(cfg, params, tokens, cache):
    """Greedy-decode through `tokens` one at a time, collecting logits."""
    B, S = tokens.shape
    outs = []
    for t in range(S):
        _, logits, cache = decode_step(
            params, cfg, tokens[:, t:t + 1],
            jnp.full((B,), t, jnp.int32), cache)
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1), cache


class TestRingCache:
    def test_matches_full_cache_after_wrap(self, sw_model):
        cfg, params = sw_model
        B, S = 2, 24                           # 3× the window: wraps twice
        tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                    cfg.vocab_size)
        full = init_cache(cfg, B, S + 4)
        ring = init_cache(cfg, B, S + 4, ring=True)
        lf, _ = _decode_seq(cfg, params, tokens, full)
        lr, _ = _decode_seq(cfg, params, tokens, ring)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lr),
                                   rtol=2e-3, atol=2e-3)

    def test_ring_cache_is_window_sized(self, sw_model):
        cfg, params = sw_model
        ring = init_cache(cfg, 2, 1000, ring=True)
        k = ring["segments"][0]["k"]      # stacked: (count, B, S_cache, …)
        assert k.shape[2] == cfg.sliding_window
        assert "slot_pos" in ring["segments"][0]

    def test_full_cache_unaffected_without_flag(self, sw_model):
        cfg, params = sw_model
        full = init_cache(cfg, 2, 1000)
        assert full["segments"][0]["k"].shape[2] == 1000
        assert "slot_pos" not in full["segments"][0]

    def test_no_ring_for_global_attention(self):
        cfg = get_config("qwen3-8b").smoke()    # global attention
        ring = init_cache(cfg, 2, 64, ring=True)
        assert "slot_pos" not in ring["segments"][0]
