"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles.

Kernels run in interpret=True mode (kernel body executed in Python on CPU —
semantics identical to the TPU lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ftree
from repro.kernels.ftree_sample import ftree_sample
from repro.kernels.ftree_sample.ref import ftree_sample_ref
from repro.kernels.ftree_update import ftree_update_batch
from repro.kernels.ftree_update.ref import ftree_update_ref
from repro.kernels.lda_scores import lda_scores_draw
from repro.kernels.lda_scores.ref import lda_scores_draw_ref


class TestFTreeSampleKernel:
    @pytest.mark.parametrize("T", [2, 16, 128, 1024, 4096])
    @pytest.mark.parametrize("n", [1, 100, 1024, 2500])
    def test_matches_oracle(self, T, n):
        rng = np.random.default_rng(T * 31 + n)
        p = jnp.asarray(rng.random(T).astype(np.float32) + 0.01)
        F = ftree.build(p)
        u = jnp.asarray(rng.random(n).astype(np.float32))
        z_k = ftree_sample(F, u)
        z_r = ftree_sample_ref(F, u)
        np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_r))

    def test_skewed_distribution(self):
        T = 256
        p = np.full(T, 1e-6, np.float32)
        p[7] = 100.0
        F = ftree.build(jnp.asarray(p))
        u = jax.random.uniform(jax.random.key(0), (512,))
        z = np.asarray(ftree_sample(F, u))
        assert (z == 7).mean() > 0.99

    def test_u01_edge_on_padded_tree_matches_oracle(self):
        """u01 → 1 with pad_pow2 zero padding and a large total: the kernel
        must carry the same zero-mass-right-subtree guard as ftree.sample
        and land on a positive leaf."""
        size = 300
        rng = np.random.default_rng(5)
        p = (rng.random(size).astype(np.float32) + 0.01) * 1e8
        F = ftree.build(ftree.pad_pow2(jnp.asarray(p)))
        u = jnp.asarray([1.0 - 1e-7,
                         np.nextafter(np.float32(1.0), np.float32(0.0)),
                         1.0], dtype=jnp.float32)
        z_k = np.asarray(ftree_sample(F, u))
        z_r = np.asarray(ftree_sample_ref(F, u))
        np.testing.assert_array_equal(z_k, z_r)
        assert (z_k < size).all()

    def test_batch_exactly_one_tile(self):
        """N == N_BLK: the padding path must be a no-op, not an off-by-one."""
        from repro.kernels.ftree_sample.ftree_sample import N_BLK
        T = 64
        rng = np.random.default_rng(11)
        F = ftree.build(jnp.asarray(rng.random(T).astype(np.float32) + 0.01))
        u = jnp.asarray(rng.random(N_BLK).astype(np.float32))
        z_k = ftree_sample(F, u)
        assert z_k.shape == (N_BLK,)
        np.testing.assert_array_equal(np.asarray(z_k),
                                      np.asarray(ftree_sample_ref(F, u)))

    def test_zero_probability_leaves_never_drawn(self):
        """Zero-mass leaves are unreachable for u01 < 1 (paper §3.1 note)."""
        T = 128
        rng = np.random.default_rng(13)
        p = np.zeros(T, np.float32)
        alive = rng.choice(T, size=T // 4, replace=False)
        p[alive] = rng.random(T // 4).astype(np.float32) + 0.1
        F = ftree.build(jnp.asarray(p))
        u = jnp.asarray(rng.random(2048).astype(np.float32))
        z_k = np.asarray(ftree_sample(F, u))
        assert np.isin(z_k, alive).all()
        np.testing.assert_array_equal(z_k,
                                      np.asarray(ftree_sample_ref(F, u)))


class TestFTreeUpdateKernel:
    @pytest.mark.parametrize("T", [2, 64, 1024])
    @pytest.mark.parametrize("k", [1, 7, 256])
    def test_matches_oracle(self, T, k):
        rng = np.random.default_rng(T + k)
        p = jnp.asarray(rng.random(T).astype(np.float32) + 0.5)
        F = ftree.build(p)
        ts = jnp.asarray(rng.integers(0, T, k).astype(np.int32))
        ds = jnp.asarray((rng.random(k) - 0.3).astype(np.float32))
        F_k = ftree_update_batch(F, ts, ds)
        F_r = ftree_update_ref(F, ts, ds)
        np.testing.assert_allclose(np.asarray(F_k), np.asarray(F_r),
                                   rtol=1e-5, atol=1e-5)

    def test_duplicates_accumulate(self):
        T = 32
        F = ftree.build(jnp.ones(T))
        ts = jnp.zeros(16, jnp.int32)
        ds = jnp.ones(16, jnp.float32)
        F2 = ftree_update_batch(F, ts, ds)
        assert float(ftree.leaves(F2)[0]) == 17.0
        assert float(ftree.total(F2)) == T + 16.0

    def test_duplicate_paths_match_oracle(self):
        """Many updates to the same leaf and to siblings sharing ancestors:
        level-by-level scatter must accumulate exactly like Alg. 2 walks."""
        T = 64
        rng = np.random.default_rng(21)
        F = ftree.build(jnp.asarray(rng.random(T).astype(np.float32) + 0.5))
        # half the batch hits leaf 3, rest hits its sibling 2 and cousin 5
        ts = jnp.asarray(np.array([3] * 32 + [2] * 16 + [5] * 16, np.int32))
        ds = jnp.asarray(rng.standard_normal(64).astype(np.float32) * 0.1)
        F_k = ftree_update_batch(F, ts, ds)
        F_r = ftree_update_ref(F, ts, ds)
        np.testing.assert_allclose(np.asarray(F_k), np.asarray(F_r),
                                   rtol=1e-5, atol=1e-5)

    def test_zero_delta_is_identity(self):
        T = 32
        F = ftree.build(jnp.asarray(
            np.random.default_rng(0).random(T).astype(np.float32)))
        ts = jnp.asarray(np.arange(8, dtype=np.int32))
        F2 = ftree_update_batch(F, ts, jnp.zeros(8, jnp.float32))
        np.testing.assert_array_equal(np.asarray(F2), np.asarray(F))

    def test_update_then_sample_consistent(self):
        """Kernel-composed pipeline: update then sample = rebuild then sample."""
        T = 512
        rng = np.random.default_rng(9)
        p = rng.random(T).astype(np.float32) + 0.1
        ts = jnp.asarray(rng.integers(0, T, 64).astype(np.int32))
        ds = jnp.asarray(rng.random(64).astype(np.float32))
        F = ftree_update_batch(ftree.build(jnp.asarray(p)), ts, ds)
        p2 = p.copy()
        np.add.at(p2, np.asarray(ts), np.asarray(ds))
        F_direct = ftree.build(jnp.asarray(p2))
        u = jax.random.uniform(jax.random.key(1), (2048,))
        np.testing.assert_array_equal(
            np.asarray(ftree_sample(F, u)),
            np.asarray(ftree_sample(F_direct, u)))


class TestLdaScoresKernel:
    @pytest.mark.parametrize("T", [128, 1024])
    @pytest.mark.parametrize("n", [1, 64, 256, 777])
    def test_matches_oracle(self, T, n):
        rng = np.random.default_rng(T + 7 * n)
        ntd = jnp.asarray(rng.integers(0, 8, (n, T)).astype(np.int32))
        nwt = jnp.asarray(rng.integers(0, 20, (n, T)).astype(np.int32))
        nt = jnp.asarray((rng.integers(20, 1000, T)).astype(np.int32))
        u = jnp.asarray(rng.random(n).astype(np.float32))
        kw = dict(alpha=0.05, beta=0.01, beta_bar=0.01 * 5000)
        z_k, norm_k = lda_scores_draw(ntd, nwt, nt, u, **kw)
        z_r, norm_r = lda_scores_draw_ref(ntd, nwt, nt, u, **kw)
        np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_r))
        np.testing.assert_allclose(np.asarray(norm_k), np.asarray(norm_r),
                                   rtol=1e-5)

    def test_batch_exactly_one_tile(self):
        """N == N_BLK exercises the unpadded grid edge."""
        from repro.kernels.lda_scores.lda_scores import N_BLK
        T = 128
        rng = np.random.default_rng(29)
        ntd = jnp.asarray(rng.integers(0, 8, (N_BLK, T)).astype(np.int32))
        nwt = jnp.asarray(rng.integers(0, 20, (N_BLK, T)).astype(np.int32))
        nt = jnp.asarray(rng.integers(20, 1000, T).astype(np.int32))
        u = jnp.asarray(rng.random(N_BLK).astype(np.float32))
        kw = dict(alpha=0.05, beta=0.01, beta_bar=0.01 * 5000)
        z_k, norm_k = lda_scores_draw(ntd, nwt, nt, u, **kw)
        z_r, norm_r = lda_scores_draw_ref(ntd, nwt, nt, u, **kw)
        assert z_k.shape == (N_BLK,)
        np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_r))
        np.testing.assert_allclose(np.asarray(norm_k), np.asarray(norm_r),
                                   rtol=1e-5)

    def test_draw_distribution(self):
        """Kernel draws follow the CGS conditional (χ²-style tolerance)."""
        T = 16
        rng = np.random.default_rng(3)
        ntd = jnp.asarray(np.tile(rng.integers(0, 8, T), (20000, 1))
                          .astype(np.int32))
        nwt = jnp.asarray(np.tile(rng.integers(0, 9, T), (20000, 1))
                          .astype(np.int32))
        nt = jnp.asarray(rng.integers(50, 90, T).astype(np.int32))
        u = jax.random.uniform(jax.random.key(5), (20000,))
        kw = dict(alpha=0.4, beta=0.01, beta_bar=0.01 * 300)
        z, _ = lda_scores_draw(ntd, nwt, nt, u, **kw)
        p = ((np.asarray(ntd[0]) + 0.4) * (np.asarray(nwt[0]) + 0.01)
             / (np.asarray(nt) + 3.0))
        p = p / p.sum()
        hist = np.bincount(np.asarray(z), minlength=T) / 20000
        np.testing.assert_allclose(hist, p, atol=0.015)
