"""Model-component correctness: SSD vs naive recurrence, sliding-window
attention, RoPE properties, softcap, encoder bidirectionality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import softcap

# Model-zoo coverage is minutes-long; excluded from the fast signal via
# `pytest -m "not slow"` (tier-1 still runs everything).
pytestmark = pytest.mark.slow


class TestSSDOracle:
    """Chunked SSD must equal the naive per-step recurrence."""

    def _naive(self, xh, B, C, dt, log_a, D):
        Bb, S, H, P = xh.shape
        N = B.shape[-1]
        h = np.zeros((Bb, H, P, N), np.float64)
        ys = np.zeros((Bb, S, H, P), np.float64)
        for t in range(S):
            a = np.exp(log_a[:, t])[:, :, None, None]
            inp = (dt[:, t][:, :, None, None]
                   * xh[:, t][:, :, :, None]
                   * B[:, t][:, None, None, :])
            h = a * h + inp
            ys[:, t] = (h * C[:, t][:, None, None, :]).sum(-1)
        ys += D[None, None, :, None] * xh
        return ys

    @pytest.mark.parametrize("S", [4, 16, 64])
    def test_matches_naive(self, S):
        rng = np.random.default_rng(S)
        Bb, H, P, N = 2, 3, 4, 5
        xh = rng.normal(size=(Bb, S, H, P)).astype(np.float32)
        Bm = rng.normal(size=(Bb, S, N)).astype(np.float32)
        Cm = rng.normal(size=(Bb, S, N)).astype(np.float32)
        dt = rng.uniform(0.01, 0.5, size=(Bb, S, H)).astype(np.float32)
        log_a = (-dt * rng.uniform(0.1, 2.0, size=(1, 1, H))
                 ).astype(np.float32)
        D = rng.normal(size=(H,)).astype(np.float32)

        # force small chunks so the cross-chunk path is exercised
        old = ssm_mod.CHUNK
        ssm_mod.CHUNK = 8
        try:
            y, h_fin = ssm_mod._ssd_chunked(
                jnp.asarray(xh), jnp.asarray(Bm), jnp.asarray(Cm),
                jnp.asarray(dt), jnp.asarray(log_a), jnp.asarray(D),
                H, P, N, jnp.zeros((Bb, H, P, N)))
        finally:
            ssm_mod.CHUNK = old
        want = self._naive(xh, Bm, Cm, dt, log_a, D).reshape(Bb, S, H * P)
        np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-4)

    def test_initial_state_carried(self):
        rng = np.random.default_rng(0)
        Bb, S, H, P, N = 1, 8, 2, 3, 4
        args = [rng.normal(size=s).astype(np.float32) for s in
                [(Bb, S, H, P), (Bb, S, N), (Bb, S, N)]]
        dt = rng.uniform(0.1, 0.3, (Bb, S, H)).astype(np.float32)
        la = (-dt * 0.5).astype(np.float32)
        D = np.zeros(H, np.float32)
        h0 = rng.normal(size=(Bb, H, P, N)).astype(np.float32)
        # run 2S in one go vs two halves with carried state
        big = [np.concatenate([a, a], axis=1) for a in args]
        dt2 = np.concatenate([dt, dt], 1)
        la2 = np.concatenate([la, la], 1)
        y_full, _ = ssm_mod._ssd_chunked(
            *map(jnp.asarray, big), jnp.asarray(dt2), jnp.asarray(la2),
            jnp.asarray(D), H, P, N, jnp.asarray(h0))
        y1, h_mid = ssm_mod._ssd_chunked(
            *map(jnp.asarray, args), jnp.asarray(dt), jnp.asarray(la),
            jnp.asarray(D), H, P, N, jnp.asarray(h0))
        y2, _ = ssm_mod._ssd_chunked(
            *map(jnp.asarray, args), jnp.asarray(dt), jnp.asarray(la),
            jnp.asarray(D), H, P, N, h_mid)
        got = np.concatenate([np.asarray(y1), np.asarray(y2)], axis=1)
        np.testing.assert_allclose(got, np.asarray(y_full),
                                   rtol=2e-4, atol=2e-4)


class TestAttention:
    def _qkv(self, B=1, S=16, H=2, D=8, seed=0):
        k = jax.random.split(jax.random.key(seed), 3)
        return (jax.random.normal(k[0], (B, S, H, D)),
                jax.random.normal(k[1], (B, S, H, D)),
                jax.random.normal(k[2], (B, S, H, D)))

    def test_sliding_window_masks_past(self):
        """With window=4, outputs must equal attention over last 4 keys."""
        q, k, v = self._qkv(S=12)
        off = jnp.zeros((1,), jnp.int32)
        out_w = attn_mod._sdpa(q, k, v, causal=True, window=4, q_offset=off,
                               logit_cap=0.0)
        # manual: for query t, keys in (t-4, t]
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(q.shape[-1])
        qpos = jnp.arange(12)[:, None]
        kpos = jnp.arange(12)[None, :]
        mask = (kpos <= qpos) & (kpos > qpos - 4)
        s = jnp.where(mask[None, None], s, -1e30)
        want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
        np.testing.assert_allclose(np.asarray(out_w), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_window_zero_is_global(self):
        q, k, v = self._qkv()
        off = jnp.zeros((1,), jnp.int32)
        a = attn_mod._sdpa(q, k, v, causal=True, window=0, q_offset=off,
                           logit_cap=0.0)
        b = attn_mod._sdpa(q, k, v, causal=True, window=None, q_offset=off,
                           logit_cap=0.0)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_encoder_attends_to_future(self):
        """Bidirectional: changing a future token changes earlier outputs."""
        q, k, v = self._qkv(S=8, seed=3)
        off = jnp.zeros((1,), jnp.int32)
        out1 = attn_mod._sdpa(q, k, v, causal=False, window=0, q_offset=off,
                              logit_cap=0.0)
        k2 = k.at[:, -1].add(10.0)
        out2 = attn_mod._sdpa(q, k2, v, causal=False, window=0, q_offset=off,
                              logit_cap=0.0)
        assert float(jnp.abs(out1[:, 0] - out2[:, 0]).max()) > 1e-4

    def test_causal_ignores_future(self):
        q, k, v = self._qkv(S=8, seed=4)
        off = jnp.zeros((1,), jnp.int32)
        out1 = attn_mod._sdpa(q, k, v, causal=True, window=0, q_offset=off,
                              logit_cap=0.0)
        k2 = k.at[:, -1].add(10.0)
        v2 = v.at[:, -1].add(10.0)
        out2 = attn_mod._sdpa(q, k2, v2, causal=True, window=0, q_offset=off,
                              logit_cap=0.0)
        np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                                   np.asarray(out2[:, :-1]), rtol=1e-5)

    def test_chunked_equals_direct(self):
        """Sq > Q_CHUNK path must equal the direct path."""
        old = attn_mod.Q_CHUNK
        try:
            q, k, v = self._qkv(S=32, seed=5)
            off = jnp.zeros((1,), jnp.int32)
            attn_mod.Q_CHUNK = 64   # direct
            a = attn_mod._sdpa(q, k, v, causal=True, window=0, q_offset=off,
                               logit_cap=0.0)
            attn_mod.Q_CHUNK = 8    # scanned chunks
            b = attn_mod._sdpa(q, k, v, causal=True, window=0, q_offset=off,
                               logit_cap=0.0)
        finally:
            attn_mod.Q_CHUNK = old
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)

    def test_gqa_group_broadcast(self):
        """Hq=4, Hkv=2: query heads 0,1 read kv head 0; 2,3 read kv head 1."""
        B, S, D = 1, 6, 4
        q = jax.random.normal(jax.random.key(0), (B, S, 4, D))
        k = jax.random.normal(jax.random.key(1), (B, S, 2, D))
        v = jax.random.normal(jax.random.key(2), (B, S, 2, D))
        off = jnp.zeros((1,), jnp.int32)
        out = attn_mod._sdpa(q, k, v, causal=True, window=0, q_offset=off,
                             logit_cap=0.0)
        # head 0 with kv0 computed manually
        s = jnp.einsum("bqd,bkd->bqk", q[:, :, 0], k[:, :, 0]) / np.sqrt(D)
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
        want = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, -1), v[:, :, 0])
        np.testing.assert_allclose(np.asarray(out[:, :, 0]),
                                   np.asarray(want), rtol=1e-4, atol=1e-5)


class TestRope:
    def test_norm_preserved(self):
        x = jax.random.normal(jax.random.key(0), (2, 8, 4, 16))
        pos = jnp.arange(8)[None, :].repeat(2, 0)
        y = attn_mod.rope(x, pos, 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)

    def test_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        D = 16
        q = jax.random.normal(jax.random.key(1), (1, 1, 1, D))
        k = jax.random.normal(jax.random.key(2), (1, 1, 1, D))

        def dot_at(i, j):
            qi = attn_mod.rope(q, jnp.asarray([[i]]), 10000.0)
            kj = attn_mod.rope(k, jnp.asarray([[j]]), 10000.0)
            return float(jnp.sum(qi * kj))
        assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)
        assert dot_at(5, 5) == pytest.approx(dot_at(0, 0), rel=1e-4)


class TestSoftcap:
    def test_bounded(self):
        x = jnp.linspace(-1000, 1000, 101)
        y = softcap(x, 30.0)
        assert float(jnp.abs(y).max()) <= 30.0

    def test_identity_when_off(self):
        x = jnp.linspace(-5, 5, 11)
        np.testing.assert_array_equal(np.asarray(softcap(x, 0.0)),
                                      np.asarray(x))

    def test_near_identity_for_small(self):
        x = jnp.asarray([0.1, -0.2])
        np.testing.assert_allclose(np.asarray(softcap(x, 50.0)),
                                   np.asarray(x), rtol=1e-4)
