"""Boundary-bug sweep across the sampler baselines (DESIGN.md §5a).

The LSearch idiom ``t = Σ(cumsum(p) ≤ u)`` walks off the end of its support
when ``u`` reaches ``cumsum(p)[-1]``.  That CAN happen whenever ``u`` is
scaled by a total computed as a *different* float reduction than the cumsum
(``p.sum()`` vs ``cumsum(p)[-1]`` disagree on mixed-magnitude f32 vectors —
XLA's reductions and scans associate differently), and the old dense
``clip(t, 0, T-1)`` then silently selected topic ``T-1`` regardless of its
mass.  These tests pin the firing mechanism deterministically (they FAIL on
the pre-fix code), and property-check the guarded draws and the r-bucket
side tables around the same boundaries.
"""
from __future__ import annotations

from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import cgs
from repro.core.alias_lda import sweep_alias_lda
from repro.core.heldout import fold_in
from repro.core.samplers import (LSearchState, lsearch_draw, lsearch_guarded,
                                 lsearch_init)
from repro.core.sparse_lda import sweep_sparse_lda
from repro.data import synthetic
from repro.kernels.fused_sweep import rbucket

# The largest f32 uniform jax.random.uniform can return: 1 - 2^-24.
U_MAX = np.float32(np.nextafter(np.float32(1.0), np.float32(0.0)))

# A mixed-magnitude count row (found by random search) whose f32
# ``sum()`` exceeds its blocked ``cumsum()[-1]`` — the reduction-mismatch
# that makes the LSearch overrun reachable.  Trailing zero => the overrun
# lands on a zero-mass topic, which is what the guard must prevent.
ROW = np.array([73, 91, 289735, 8790, 11, 0, 0, 274, 461, 245, 2001000,
                815, 88026, 3, 240, 0, 0, 1475, 0, 153, 8531, 34647, 1180,
                800, 47, 170569, 9, 2231, 0, 5613, 5, 24, 2, 10729, 28371,
                13, 948, 1, 166020, 45013, 105, 126, 190, 126246, 1, 691,
                34649, 3168, 1389, 0, 439094, 1, 118, 10195, 119, 463,
                1908, 0, 0, 646325, 4204, 6, 12890, 0], dtype=np.int64)


def _forced_uniform(value):
    """A jax.random.uniform stand-in returning ``value`` everywhere."""
    def forced(key, shape=(), dtype=jnp.float32, **kw):
        return jnp.full(shape, jnp.asarray(value, dtype))
    return forced


# ---------------------------------------------------------------------------
# lsearch_guarded / lsearch_draw
# ---------------------------------------------------------------------------
def test_lsearch_guarded_boundary_drift():
    """A drifted normalizer (Θ(1) updates track sums approximately) pushes
    u past cumsum[-1]; the pre-fix draw returned T — out of range."""
    p = jnp.asarray(ROW, jnp.float32)
    c = jnp.cumsum(p)
    state = LSearchState(p=p, c_T=jnp.float32(float(c[-1]) * (1 + 1e-6)))
    t = lsearch_draw(state, jnp.float32(U_MAX))
    assert 0 <= int(t) < p.shape[0]
    assert float(p[t]) > 0.0


def test_lsearch_sum_cumsum_mismatch_is_real():
    """The firing mechanism itself: on the word-bucket vector the pinned
    trigger produces (ROW scaled by (n_td+α)/denom), ``sum()`` exceeds
    ``cumsum()[-1]`` — so a near-1 uniform scaled by the sum overruns."""
    p = jnp.asarray(ROW, jnp.float32) * jnp.float32(0.5) / jnp.float32(7.08)
    assert float(jnp.sum(p)) > float(jnp.cumsum(p)[-1])
    # and lsearch_init caches the sum-reduction as the normalizer
    st_ = lsearch_init(p)
    assert float(st_.c_T) == float(jnp.sum(p))


@settings(max_examples=10, deadline=None)
@given(u01=st.sampled_from([0.0, float(U_MAX), 0.5]),
       seed=st.integers(min_value=0, max_value=10_000))
def test_lsearch_guarded_in_support(u01, seed):
    """For any cumsum and any u01 (both boundaries forced), the guarded
    draw lands on a positive-mass index."""
    rng = np.random.default_rng(seed)
    p = rng.choice([0.0, 1e-3, 1.0, 1e4], size=32).astype(np.float32)
    if p.sum() == 0:
        p[rng.integers(32)] = 1.0
    c = jnp.cumsum(jnp.asarray(p))
    t = int(lsearch_guarded(c, jnp.float32(u01) * c[-1]))
    assert 0 <= t < 32
    assert p[t] > 0.0


# ---------------------------------------------------------------------------
# SparseLDA (the live bug: three .sum() masses, three cumsum walks)
# ---------------------------------------------------------------------------
def _pinned_sparse_state():
    T, J = 64, 8
    n_wt = np.zeros((J, T), np.int32)
    n_wt[0] = ROW
    n_wt[0, 0] += 1              # token 0's own assignment
    n_td = np.zeros((1, T), np.int32)
    n_td[0, 0] = 1
    n_t = np.full(T, 7, np.int32)
    n_t[0] += 1
    return cgs.LDAState(
        z=jnp.zeros((1,), jnp.int32),
        n_td=jnp.asarray(n_td), n_wt=jnp.asarray(n_wt),
        n_t=jnp.asarray(n_t), key=jax.random.PRNGKey(0))


def test_sparse_lda_word_bucket_zero_mass_guarded():
    """Deterministic trigger: u01 = 1-2^-22 lands in the word bucket by the
    .sum() dispatch but at the bucket's cumsum[-1] (which sits one ulp-gap
    below q_mass on this ROW) — the pre-fix clip then selected topic T-1,
    whose word-bucket mass is exactly zero."""
    alpha, beta = 0.5, 0.01
    u01 = np.float32(0.9999997615814209)           # 1 - 2^-22
    state = _pinned_sparse_state()
    order = jnp.zeros((1,), jnp.int32)
    with mock.patch.object(jax.random, "uniform", _forced_uniform(u01)):
        new, buckets = sweep_sparse_lda(
            state, jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
            order, alpha, beta, return_bucket_stats=True)

    # mirror the step's f32 arithmetic on the post-decrement counts to pin
    # the boundary actually exercised: in_q holds yet u_val ≥ cumsum(q)[-1]
    f32 = jnp.float32
    denom = jnp.full((64,), 7, f32) + beta * 8
    s_mass = ((alpha * beta) / denom).sum()
    q_vec = (jnp.asarray(ROW, f32) * (jnp.zeros((64,), f32) + alpha)
             / denom)
    q_mass = q_vec.sum()
    u_val = u01 * (s_mass + f32(0.0) + q_mass)
    assert bool(u_val < q_mass), "dispatch precondition (in_q) lost"
    assert bool(u_val >= jnp.cumsum(q_vec)[-1]), \
        "overrun precondition lost"
    assert int(buckets[0]) == 2
    t_new = int(new.z[0])
    assert float(q_vec[t_new]) > 0.0, \
        f"guarded word-bucket draw selected zero-mass topic {t_new}"


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1000),
       u01=st.sampled_from([0.0, float(U_MAX)]))
def test_sparse_lda_boundary_invariants(seed, u01):
    """Forced boundary uniforms on a toy corpus (incl. single-token docs):
    counts stay consistent and every z stays in range."""
    corpus, _, _ = synthetic.make_corpus(
        num_docs=12, vocab_size=24, num_topics=4, mean_doc_len=3.0,
        seed=seed)
    T = 8
    state = cgs.init_state(corpus, T, jax.random.PRNGKey(seed))
    order = jnp.asarray(corpus.doc_order())
    with mock.patch.object(jax.random, "uniform", _forced_uniform(u01)):
        new = sweep_sparse_lda(state, jnp.asarray(corpus.doc_ids),
                               jnp.asarray(corpus.word_ids), order,
                               0.5, 0.01)
    bad = cgs.check_invariants(new, corpus)
    assert all(v == 0 for v in bad.values()), bad


def test_sparse_lda_single_topic_doc():
    """A document whose every token holds one topic: the doc bucket's
    r-vector has a single nonzero — boundary draws must stay on it."""
    T = 16
    n_docs, n_words = 1, 4
    doc_ids = jnp.zeros((5,), jnp.int32)
    word_ids = jnp.asarray([0, 1, 2, 3, 0], jnp.int32)
    z = jnp.full((5,), 3, jnp.int32)
    n_td, n_wt, n_t = cgs.counts_from_assignments(
        doc_ids, word_ids, z, n_docs, n_words, T)
    state = cgs.LDAState(z=z, n_td=n_td, n_wt=n_wt, n_t=n_t,
                         key=jax.random.PRNGKey(1))
    with mock.patch.object(jax.random, "uniform", _forced_uniform(U_MAX)):
        new, buckets = sweep_sparse_lda(
            state, doc_ids, word_ids, jnp.arange(5, dtype=jnp.int32),
            0.5, 0.01, return_bucket_stats=True)
    rebuilt = cgs.counts_from_assignments(doc_ids, word_ids, new.z,
                                          n_docs, n_words, T)
    for ref, got in zip(rebuilt, (new.n_td, new.n_wt, new.n_t)):
        assert int(jnp.abs(ref - got).sum()) == 0
    assert bool(jnp.all((new.z >= 0) & (new.z < T)))


def test_sparse_lda_word_bucket_dominates_zipf():
    """Table-2 argument: on a Zipf corpus the word bucket absorbs nearly
    all draws (β scales the other two buckets)."""
    corpus, _, _ = synthetic.make_corpus(
        num_docs=100, vocab_size=128, num_topics=8, mean_doc_len=30.0,
        zipf_a=1.3, seed=7)
    T = 16
    state = cgs.init_state(corpus, T, jax.random.PRNGKey(0))
    order = jnp.asarray(corpus.doc_order())
    d, w = jnp.asarray(corpus.doc_ids), jnp.asarray(corpus.word_ids)
    for _ in range(2):                       # brief burn-in
        state = sweep_sparse_lda(state, d, w, order, 0.5, 0.01)
    state, buckets = sweep_sparse_lda(state, d, w, order, 0.5, 0.01,
                                      return_bucket_stats=True)
    hit = np.bincount(np.asarray(buckets), minlength=3) / buckets.shape[0]
    assert hit[2] > 0.5, f"word-bucket hit rate {hit[2]:.3f}"
    assert hit[2] > hit[1] and hit[2] > hit[0]


# ---------------------------------------------------------------------------
# AliasLDA — guarded stale proposals + MH acceptance invariant
# ---------------------------------------------------------------------------
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1000),
       u01=st.sampled_from([0.0, float(U_MAX)]))
def test_alias_lda_mh_invariant(seed, u01):
    """Every MH step must see a finite ratio and an acceptance probability
    in (0, 1] — a zero-density proposal (what an unguarded boundary draw
    can produce) breaks this."""
    corpus, _, _ = synthetic.make_corpus(
        num_docs=16, vocab_size=32, num_topics=4, mean_doc_len=8.0,
        seed=seed)
    T = 8
    state = cgs.init_state(corpus, T, jax.random.PRNGKey(seed))
    order = jnp.asarray(corpus.doc_order())
    with mock.patch.object(jax.random, "uniform", _forced_uniform(u01)):
        new, mh_ok = sweep_alias_lda(
            state, jnp.asarray(corpus.doc_ids),
            jnp.asarray(corpus.word_ids), order, 0.5, 0.01,
            return_mh_stats=True)
    assert bool(jnp.all(mh_ok)), \
        f"{int((~mh_ok).sum())} tokens with broken MH acceptance"
    assert bool(jnp.all((new.z >= 0) & (new.z < T)))
    bad = cgs.check_invariants(new, corpus)
    assert all(v == 0 for v in bad.values()), bad


# ---------------------------------------------------------------------------
# Held-out fold-in — guarded draw + named key derivation
# ---------------------------------------------------------------------------
def test_fold_in_all_zero_phi_row():
    """A φ row with zero mass everywhere (word absent from training): the
    pre-fix clip parked every such token on topic T-1; the guarded draw
    keeps the table consistent and non-negative."""
    T, n_docs = 8, 3
    phi = np.full((4, T), 0.25, np.float32)
    phi[2] = 0.0                                     # unseen word
    word_ids = jnp.asarray([0, 2, 2, 1], jnp.int32)
    doc_ids = jnp.asarray([0, 0, 1, 2], jnp.int32)
    n_td = fold_in(word_ids, doc_ids, n_docs, jnp.asarray(phi), 0.5,
                   jax.random.PRNGKey(0), sweeps=3)
    assert int(n_td.sum()) == 4
    assert bool(jnp.all(n_td >= 0))
    # doc 1 holds only the unseen word: its conditional is all-zero every
    # sweep, so the guarded draw collapses to index 0 — the pre-fix clip
    # parked it on T-1 instead
    assert int(n_td[1, 0]) == 1 and int(n_td[1].sum()) == 1


def test_fold_in_boundary_uniform_in_range():
    """u01 at both boundaries: all fold-in assignments stay in [0, T)."""
    T, n_docs = 8, 4
    rng = np.random.default_rng(0)
    phi = rng.dirichlet(np.ones(T), size=16).astype(np.float32)
    word_ids = jnp.asarray(rng.integers(0, 16, 20), jnp.int32)
    doc_ids = jnp.asarray(np.sort(rng.integers(0, n_docs, 20)), jnp.int32)
    for u01 in (0.0, float(U_MAX)):
        with mock.patch.object(jax.random, "uniform", _forced_uniform(u01)):
            n_td = fold_in(word_ids, doc_ids, n_docs, jnp.asarray(phi),
                           0.5, jax.random.PRNGKey(1), sweeps=2)
        assert int(n_td.sum()) == 20
        assert bool(jnp.all(n_td >= 0))


def test_fold_in_key_roles_distinct():
    """The init draw and the per-sweep draws must come from distinct key
    roles: 0 sweeps (init only) vs 1 sweep must differ, and the result is
    a pure function of the key."""
    T, n_docs = 8, 4
    rng = np.random.default_rng(3)
    phi = rng.dirichlet(np.ones(T), size=16).astype(np.float32)
    word_ids = jnp.asarray(rng.integers(0, 16, 30), jnp.int32)
    doc_ids = jnp.asarray(np.sort(rng.integers(0, n_docs, 30)), jnp.int32)
    a = fold_in(word_ids, doc_ids, n_docs, jnp.asarray(phi), 0.5,
                jax.random.PRNGKey(5), sweeps=2)
    b = fold_in(word_ids, doc_ids, n_docs, jnp.asarray(phi), 0.5,
                jax.random.PRNGKey(5), sweeps=2)
    c = fold_in(word_ids, doc_ids, n_docs, jnp.asarray(phi), 0.5,
                jax.random.PRNGKey(6), sweeps=2)
    assert bool(jnp.array_equal(a, b))
    assert not bool(jnp.array_equal(a, c))


# ---------------------------------------------------------------------------
# r-bucket side tables around the same boundaries
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_rbucket_incremental_matches_compaction(seed):
    """Random increment/decrement walks preserve the side-table invariant
    (topics, counts) == compact_row(dense row)."""
    T, cap = 16, 16
    rng = np.random.default_rng(seed)
    row = rng.integers(0, 3, T).astype(np.int32)
    topics, counts = rbucket.compact_row(jnp.asarray(row), cap)
    for _ in range(20):
        t = int(rng.integers(T))
        if rng.random() < 0.5 and row[t] > 0:
            row[t] -= 1
            topics, counts = rbucket.decrement(topics, counts,
                                               jnp.int32(t), True)
        else:
            row[t] += 1
            topics, counts = rbucket.increment(topics, counts,
                                               jnp.int32(t), True)
        ref_t, ref_c = rbucket.compact_row(jnp.asarray(row), cap)
        assert bool(jnp.array_equal(topics, ref_t))
        assert bool(jnp.array_equal(counts, ref_c))


def test_rbucket_pick_boundary_stays_on_support():
    """rbucket.pick at u = c[-1] (the padded plateau) returns the last
    active topic, never a zero-count pad slot."""
    topics = jnp.asarray([1, 5, 9, 0, 0, 0], jnp.int32)
    counts = jnp.asarray([2, 1, 3, 0, 0, 0], jnp.int32)
    q = jnp.ones((16,), jnp.float32)
    c = rbucket.r_cumsum(topics, counts, q)
    for u in (0.0, float(c[-1]) * float(U_MAX), float(c[-1])):
        t = int(rbucket.pick(topics, counts, c, jnp.float32(u)))
        assert t in (1, 5, 9)
    assert int(rbucket.pick(topics, counts, c, c[-1])) == 9
