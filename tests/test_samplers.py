"""Unified sampler tests — the four Table-1 samplers must agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import samplers

KEY = jax.random.key(42)


def _rand_p(seed, T):
    return jnp.asarray(np.random.default_rng(seed).random(T).astype(np.float32)
                       + 0.01)


class TestExactSamplersAgree:
    """LSearch / BSearch / F+tree are exact inverse-CDF samplers: for the
    same u they must return the same index (up to float boundary slack)."""

    @pytest.mark.parametrize("T", [2, 16, 256, 1024])
    def test_same_u_same_index(self, T):
        p = _rand_p(T, T)
        ls = samplers.lsearch_init(p)
        bs = samplers.bsearch_init(p)
        ft = samplers.ftree_init(p)
        u_grid = jnp.asarray(np.linspace(0, 1 - 1e-6, 101, dtype=np.float32))
        z_ls = jax.vmap(lambda u: samplers.lsearch_draw(ls, u))(u_grid)
        z_bs = jax.vmap(lambda u: samplers.bsearch_draw(bs, u))(u_grid)
        z_ft = jax.vmap(lambda u: samplers.ftree_draw(ft, u))(u_grid)
        np.testing.assert_array_equal(np.asarray(z_ls), np.asarray(z_bs))
        # F+tree accumulates sums in tree order: boundary ulps may differ.
        assert (np.asarray(z_ft) == np.asarray(z_ls)).mean() > 0.97

    def test_updates_preserve_agreement(self):
        T = 64
        p = _rand_p(0, T)
        ls = samplers.lsearch_init(p)
        bs = samplers.bsearch_init(p)
        ft = samplers.ftree_init(p)
        rng = np.random.default_rng(7)
        for _ in range(20):
            t = int(rng.integers(T))
            delta = float(rng.random() * 2 - 0.5)
            delta = max(delta, -float(ls.p[t]) * 0.9)  # keep p nonnegative
            ls = samplers.lsearch_update(ls, t, delta)
            bs = samplers.bsearch_update(bs, t, delta)
            ft = samplers.ftree_update(ft, t, delta)
        u_grid = jnp.asarray(np.linspace(0, 1 - 1e-6, 53, dtype=np.float32))
        z_ls = jax.vmap(lambda u: samplers.lsearch_draw(ls, u))(u_grid)
        z_bs = jax.vmap(lambda u: samplers.bsearch_draw(bs, u))(u_grid)
        z_ft = jax.vmap(lambda u: samplers.ftree_draw(ft, u))(u_grid)
        assert (np.asarray(z_ls) == np.asarray(z_bs)).mean() > 0.95
        assert (np.asarray(z_ft) == np.asarray(z_ls)).mean() > 0.95


class TestAlias:
    @pytest.mark.parametrize("T", [2, 7, 16, 100, 512])
    def test_alias_table_is_valid(self, T):
        """Reconstructed pmf from (prob, alias) must equal p/Σp exactly."""
        p = _rand_p(T + 1, T)
        st_ = samplers.alias_init(p)
        prob = np.asarray(st_.prob, dtype=np.float64)
        alias = np.asarray(st_.alias)
        pmf = np.zeros(T)
        pmf += prob / T
        np.add.at(pmf, alias, (1.0 - prob) / T)
        want = np.asarray(p, dtype=np.float64)
        want = want / want.sum()
        np.testing.assert_allclose(pmf, want, atol=1e-5)

    def test_alias_histogram(self):
        T = 16
        p = _rand_p(3, T)
        st_ = samplers.alias_init(p)
        u = jax.random.uniform(KEY, (200_000,))
        z = jax.vmap(lambda uu: samplers.alias_draw(st_, uu))(u)
        hist = np.bincount(np.asarray(z), minlength=T) / u.shape[0]
        want = np.asarray(p) / float(p.sum())
        np.testing.assert_allclose(hist, want, atol=0.01)

    def test_alias_degenerate_point_mass(self):
        p = jnp.asarray([0.0, 0.0, 5.0, 0.0], dtype=jnp.float32)
        st_ = samplers.alias_init(p)
        u = jax.random.uniform(KEY, (1000,))
        z = jax.vmap(lambda uu: samplers.alias_draw(st_, uu))(u)
        assert (np.asarray(z) == 2).all()

    def test_alias_inside_jit(self):
        p = _rand_p(9, 32)
        st_ = jax.jit(samplers.alias_init)(p)
        assert st_.prob.shape == (32,)


class TestProperty:
    @given(T_log=st.integers(1, 7), seed=st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_all_draws_in_range_and_positive_mass(self, T_log, seed):
        T = 1 << T_log
        rng = np.random.default_rng(seed)
        p_np = rng.random(T).astype(np.float32)
        p_np[rng.random(T) < 0.5] = 0.0
        p_np[rng.integers(T)] += 0.5  # ensure nonzero mass
        p = jnp.asarray(p_np)
        u = jnp.asarray(rng.random(64).astype(np.float32))
        for name, (init, draw, _) in samplers.SAMPLERS.items():
            state = init(p)
            z = np.asarray(jax.vmap(lambda uu: draw(state, uu))(u))
            assert ((z >= 0) & (z < T)).all(), name
            if name != "alias":  # exact samplers never hit zero-mass leaves
                assert (p_np[z] > 0).all(), (name, z, p_np)
