"""F+tree unit + property tests (paper §3.1, Algorithms 1-2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ftree

jax.config.update("jax_enable_x64", False)


def _rand_p(rng, T):
    return jnp.asarray(rng.random(T).astype(np.float32) + 0.01)


class TestBuild:
    @pytest.mark.parametrize("T", [1, 2, 4, 16, 128, 1024])
    def test_internal_nodes_are_child_sums(self, T):
        rng = np.random.default_rng(0)
        p = _rand_p(rng, T)
        F = ftree.build(p)
        assert F.shape == (2 * T,)
        F = np.asarray(F)
        for i in range(1, T):
            np.testing.assert_allclose(F[i], F[2 * i] + F[2 * i + 1],
                                       rtol=1e-6)
        np.testing.assert_allclose(F[T:], np.asarray(p))
        np.testing.assert_allclose(F[1], np.asarray(p).sum(), rtol=1e-6)

    def test_non_pow2_raises(self):
        with pytest.raises(ValueError):
            ftree.build(jnp.ones(3))

    def test_pad_pow2(self):
        p = jnp.ones(5)
        pp = ftree.pad_pow2(p)
        assert pp.shape == (8,)
        assert float(pp.sum()) == 5.0

    def test_batched_build(self):
        rng = np.random.default_rng(1)
        p = jnp.asarray(rng.random((3, 8)).astype(np.float32))
        F = ftree.build(p)
        assert F.shape == (3, 16)
        np.testing.assert_allclose(np.asarray(ftree.total(F)),
                                   np.asarray(p.sum(-1)), rtol=1e-6)


class TestSample:
    @pytest.mark.parametrize("T", [2, 8, 64, 1024])
    def test_matches_inverse_cdf(self, T):
        """F.sample(u) must equal min{t: cumsum(p)_t > u} for a grid of u."""
        rng = np.random.default_rng(2)
        p = _rand_p(rng, T)
        F = ftree.build(p)
        c = np.cumsum(np.asarray(p))
        u01 = jnp.asarray(np.linspace(0.0, 1.0 - 1e-6, 257, dtype=np.float32))
        got = np.asarray(ftree.sample_batch(F, u01))
        want = np.searchsorted(c, np.asarray(u01) * c[-1], side="right")
        # float accumulation order differs near boundaries: allow ulp slack
        # by checking the chosen leaf's cumulative interval contains u.
        u = np.asarray(u01) * c[-1]
        lo = np.concatenate([[0.0], c])[got]
        hi = np.concatenate([[0.0], c])[got + 1]
        ok = (u >= lo - 1e-4) & (u <= hi + 1e-4)
        assert ok.all(), (got[~ok], want[~ok])

    def test_zero_mass_leaves_never_sampled(self):
        p = jnp.asarray([0.0, 1.0, 0.0, 2.0, 0.0, 0.0, 3.0, 0.0],
                        dtype=jnp.float32)
        F = ftree.build(p)
        u = jax.random.uniform(jax.random.key(0), (4096,))
        got = np.asarray(ftree.sample_batch(F, u))
        assert set(np.unique(got)).issubset({1, 3, 6})

    @given(size=st.integers(1, 600), scale_log=st.integers(0, 9),
           seed=st.integers(0, 1000))
    @settings(max_examples=60, deadline=None)
    def test_u01_edge_never_falls_onto_padding(self, size, scale_log, seed):
        """u01 → 1 must land on a positive leaf, even when ``u01 * F[1]``
        rounds up to ``F[1]`` in f32 (large totals) and the tree carries
        ``pad_pow2`` zero padding past the true ``size``."""
        rng = np.random.default_rng(seed)
        scale = 10.0 ** scale_log
        p = (rng.random(size).astype(np.float32) + 0.01) * scale
        F = ftree.build(ftree.pad_pow2(jnp.asarray(p)))
        edge = jnp.asarray([1.0 - 1e-7, np.float32(1.0 - 1e-7),
                            np.nextafter(np.float32(1.0), np.float32(0.0)),
                            1.0], dtype=jnp.float32)
        got_b = np.asarray(ftree.sample_batch(F, edge))
        got_s = np.asarray(
            [ftree.sample(F, u) for u in edge])
        for got in (got_b, got_s):
            assert (got < size).all(), (size, scale, got)
            assert (np.asarray(ftree.leaves(F))[got] > 0).all()

    def test_u01_edge_large_total_unpadded(self):
        """The same overflow hazard exists without padding: u ≥ F[1] must
        clamp to the last leaf, not walk off the heap."""
        T = 64
        p = jnp.full((T,), np.float32(1e8))
        F = ftree.build(p)
        got = np.asarray(ftree.sample_batch(F, jnp.asarray([1.0], jnp.float32)))
        assert (got == T - 1).all()

    def test_histogram_matches_distribution(self):
        rng = np.random.default_rng(3)
        T = 32
        p = _rand_p(rng, T)
        F = ftree.build(p)
        n = 200_000
        u = jax.random.uniform(jax.random.key(1), (n,))
        got = np.asarray(ftree.sample_batch(F, u))
        hist = np.bincount(got, minlength=T) / n
        want = np.asarray(p) / float(np.asarray(p).sum())
        np.testing.assert_allclose(hist, want, atol=0.01)


class TestUpdate:
    @given(T_log=st.integers(1, 8), t_frac=st.floats(0, 0.999),
           delta=st.floats(-0.5, 5.0), seed=st.integers(0, 100))
    @settings(max_examples=60, deadline=None)
    def test_update_equals_rebuild(self, T_log, t_frac, delta, seed):
        T = 1 << T_log
        rng = np.random.default_rng(seed)
        p = rng.random(T).astype(np.float32) + 1.0
        t = int(t_frac * T)
        F1 = ftree.update(ftree.build(jnp.asarray(p)), t, delta)
        p2 = p.copy()
        p2[t] += delta
        F2 = ftree.build(jnp.asarray(p2))
        np.testing.assert_allclose(np.asarray(F1), np.asarray(F2),
                                   rtol=1e-4, atol=1e-5)

    def test_update_batch_duplicates_accumulate(self):
        T = 16
        p = jnp.ones(T)
        ts = jnp.asarray([3, 3, 3, 7], dtype=jnp.int32)
        ds = jnp.asarray([1.0, 1.0, 1.0, 2.0], dtype=jnp.float32)
        F = ftree.update_batch(ftree.build(p), ts, ds)
        leaves = np.asarray(ftree.leaves(F))
        assert leaves[3] == 4.0 and leaves[7] == 3.0
        np.testing.assert_allclose(float(ftree.total(F)), T + 5.0, rtol=1e-6)

    def test_set_leaf(self):
        T = 8
        p = jnp.arange(1.0, T + 1)
        F = ftree.set_leaf(ftree.build(p), 2, 10.0)
        leaves = np.asarray(ftree.leaves(F))
        assert leaves[2] == 10.0
        np.testing.assert_allclose(float(ftree.total(F)),
                                   float(p.sum()) + 7.0, rtol=1e-6)

    def test_update_inside_jit_and_scan(self):
        T = 64
        F0 = ftree.build(jnp.ones(T))

        def body(F, t):
            return ftree.update(F, t, 1.0), None

        ts = jnp.arange(T, dtype=jnp.int32)
        F, _ = jax.jit(lambda F: jax.lax.scan(body, F, ts))(F0)
        np.testing.assert_allclose(np.asarray(ftree.leaves(F)),
                                   np.full(T, 2.0), rtol=1e-6)
