"""Nomad distributed LDA tests (paper §4).

Single-device ring (W=1, degenerate but exercises the full code path)
runs in-process; multi-device rings run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count so the main test process
keeps its single real device (per the dry-run isolation rule).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.nomad import NomadLDA
from repro.data import synthetic
from repro.data.sharding import build_layout, lpt_assign

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_module(module, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", module, *map(str, args)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def _run_check(n_dev, sync_mode, pods=1, inner_mode="scan", n_blocks=None,
               ring_mode="barrier", layout="dense"):
    return _run_module(
        "repro.launch.lda_dist_check", n_dev, sync_mode, pods, inner_mode,
        n_dev if n_blocks is None else n_blocks, ring_mode, layout)


class TestLayout:
    def test_lpt_balances_zipf(self):
        rng = np.random.default_rng(0)
        weights = (1e6 / np.arange(1, 2001) ** 1.1).astype(np.int64)
        assign = lpt_assign(weights, 8, balance=True)
        loads = np.bincount(assign, weights=weights, minlength=8)
        # LPT reaches the packing lower bound max(mean, heaviest item)
        lower = max(loads.mean(), weights.max())
        assert loads.max() <= lower * 1.01
        naive = lpt_assign(weights, 8, balance=False)
        loads_naive = np.bincount(naive, weights=weights, minlength=8)
        assert loads_naive.max() / loads_naive.mean() > 2.0  # skew is real

    def test_layout_covers_all_tokens(self):
        corpus, _, _ = synthetic.make_corpus(
            num_docs=50, vocab_size=128, num_topics=8, mean_doc_len=20.0,
            seed=1)
        lay = build_layout(corpus, n_workers=4, T=8)
        assert int(lay.tok_valid.sum()) == corpus.num_tokens
        # every token's global word id maps back through block/local index
        w, b, l = np.nonzero(lay.tok_valid)
        gw = lay.word_of_block[b, lay.tok_wrd[w, b, l]]
        np.testing.assert_array_equal(gw, lay.tok_gwrd[w, b, l])
        # word->block assignment is respected
        assert (lay.word_assign[gw] == b).all()

    def test_multiblock_layout_covers_all_tokens(self):
        """B = 3W: the queue geometry must still place every token exactly
        once, with the word→block map respected."""
        corpus, _, _ = synthetic.make_corpus(
            num_docs=50, vocab_size=128, num_topics=8, mean_doc_len=20.0,
            seed=1)
        lay = build_layout(corpus, n_workers=4, T=8, n_blocks=12)
        assert (lay.W, lay.B, lay.k) == (4, 12, 3)
        assert int(lay.tok_valid.sum()) == corpus.num_tokens
        w, b, l = np.nonzero(lay.tok_valid)
        gw = lay.word_of_block[b, lay.tok_wrd[w, b, l]]
        np.testing.assert_array_equal(gw, lay.tok_gwrd[w, b, l])
        assert (lay.word_assign[gw] == b).all()

    def test_more_blocks_smooth_round_imbalance(self):
        """The scaling knob must be free: a power-law vocabulary packed into
        B = 8W blocks round-balances exactly as well as B = W, because word
        chunks are LPT-packed at ring granularity first and only then split
        into the k per-queue blocks (hierarchical LPT)."""
        from repro.data.corpus import Corpus
        rng = np.random.default_rng(7)
        doc_ids = np.repeat(np.arange(200), 12)
        word_ids = np.minimum(rng.zipf(1.3, size=doc_ids.shape[0]), 500) - 1
        corpus = Corpus(doc_ids=doc_ids.astype(np.int32),
                        word_ids=word_ids.astype(np.int32),
                        num_docs=200, num_words=500)
        lay1 = build_layout(corpus, n_workers=4, T=8, n_blocks=4)
        lay8 = build_layout(corpus, n_workers=4, T=8, n_blocks=32)
        assert lay8.round_imbalance <= lay1.round_imbalance * 1.05, (
            lay1.round_imbalance, lay8.round_imbalance)

    def test_invalid_n_blocks_rejected(self):
        corpus, _, _ = synthetic.make_corpus(
            num_docs=20, vocab_size=64, num_topics=8, mean_doc_len=10.0,
            seed=3)
        for bad in (3, 6, 0):
            with pytest.raises(ValueError, match="multiple"):
                build_layout(corpus, n_workers=4, T=8, n_blocks=bad)

    def test_half_queue_split_points(self):
        from repro.data.sharding import half_queue_split
        assert half_queue_split(0) == 0
        assert half_queue_split(1) == 0          # degenerate: no overlap
        for k in range(2, 10):
            k0 = half_queue_split(k)
            assert 0 < k0 < k and k0 == k // 2

    def test_half_loads_balanced_on_zipf(self):
        """The pipelined split must produce load-matched half-queues even
        under power-law word skew: within each chunk the blocks are ordered
        (``_order_bins_for_halves``) so the halves differ by at most one
        block's load — the best any block-granular split can do."""
        from repro.data.corpus import Corpus
        rng = np.random.default_rng(11)
        doc_ids = np.repeat(np.arange(200), 12)
        word_ids = np.minimum(rng.zipf(1.3, size=doc_ids.shape[0]), 500) - 1
        corpus = Corpus(doc_ids=doc_ids.astype(np.int32),
                        word_ids=word_ids.astype(np.int32),
                        num_docs=200, num_words=500)
        lay = build_layout(corpus, n_workers=4, T=8, n_blocks=16)  # k = 4
        halves = lay.half_loads()                # (W_rounds, W, 2)
        W, k = lay.W, lay.k
        # the two halves together are exactly the round loads
        for r in range(W):
            for w in range(W):
                c = (w + r) % W
                assert halves[r, w].sum() == \
                    lay.cell_sizes[w, c * k:(c + 1) * k].sum()
        # at the granularity the split is enforced (global block loads),
        # the halves differ by at most the heaviest block of the chunk
        gaps = lay.half_balance_gaps()
        assert (gaps[:, 0] <= gaps[:, 1]).all(), gaps

    def test_boundaries_mark_distinct_words_per_cell(self):
        corpus, _, _ = synthetic.make_corpus(
            num_docs=30, vocab_size=64, num_topics=8, mean_doc_len=15.0,
            seed=2)
        lay = build_layout(corpus, n_workers=2, T=8)
        for w in range(lay.W):
            for b in range(lay.B):
                m = lay.tok_valid[w, b]
                words = lay.tok_gwrd[w, b][m]
                bounds = lay.tok_bound[w, b][m]
                assert bounds.sum() == len(np.unique(words))


class TestSingleDeviceRing:
    """W=1: the nomad machinery must reduce to serial F+LDA semantics,
    for any queue length k = B (the whole ring is one worker)."""

    @pytest.mark.parametrize("n_blocks,inner_mode,ring_mode,layout", [
        (1, "scan", "barrier", "dense"), (4, "scan", "barrier", "dense"),
        (4, "fused", "barrier", "dense"),
        (4, "vectorized", "barrier", "dense"),
        (1, "scan", "pipelined", "dense"), (4, "scan", "pipelined", "dense"),
        (4, "fused", "pipelined", "dense"),
        (1, "fused", "barrier", "ragged"), (4, "fused", "barrier", "ragged"),
        (4, "fused", "pipelined", "ragged"),
        (4, "scan", "pipelined", "ragged"),
        (4, "vectorized", "barrier", "ragged"),
    ])
    def test_invariants_and_ll(self, n_blocks, inner_mode, ring_mode,
                               layout):
        T = 8
        corpus, _, _ = synthetic.make_corpus(
            num_docs=60, vocab_size=128, num_topics=T, mean_doc_len=25.0,
            seed=4)
        mesh = jax.make_mesh((1,), ("worker",))
        lay = build_layout(corpus, n_workers=1, T=T, n_blocks=n_blocks,
                           layout=layout)
        lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                       alpha=50.0 / T, beta=0.01, inner_mode=inner_mode,
                       ring_mode=ring_mode)
        arrays = lda.init_arrays(seed=0)
        ll0 = lda.log_likelihood(arrays)
        for it in range(3):
            arrays = lda.sweep(arrays, seed=it)
        ll1 = lda.log_likelihood(arrays)
        assert ll1 > ll0

        n_td, n_wt, n_t = lda.global_counts(arrays)
        assert int(n_t.sum()) == corpus.num_tokens
        np.testing.assert_array_equal(n_td.sum(0), n_t)
        np.testing.assert_array_equal(n_wt.sum(0), n_t)

    def test_block_count_does_not_change_totals(self):
        """Same corpus under B=1 vs B=4 queues: different visit order (so a
        different chain), but identical exactness invariants and token mass
        per word — the block split must be invisible in the totals."""
        T = 8
        corpus, _, _ = synthetic.make_corpus(
            num_docs=40, vocab_size=96, num_topics=T, mean_doc_len=15.0,
            seed=6)
        mesh = jax.make_mesh((1,), ("worker",))
        per_word = {}
        for B in (1, 4):
            lay = build_layout(corpus, n_workers=1, T=T, n_blocks=B)
            lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                           alpha=50.0 / T, beta=0.01)
            arrays = lda.init_arrays(seed=0)
            arrays = lda.sweep(arrays, seed=0)
            _, n_wt, n_t = lda.global_counts(arrays)
            assert int(n_t.sum()) == corpus.num_tokens
            per_word[B] = n_wt.sum(1)
        np.testing.assert_array_equal(per_word[1], per_word[4])

    @pytest.mark.parametrize("inner_mode", ["scan", "fused", "vectorized"])
    def test_pipelined_is_bit_identical_to_barrier(self, inner_mode):
        """The tentpole invariant, in-process: the pipelined schedule only
        moves when the first half-queue's hop is issued — the per-token
        chain (z, all count tables) must be bit-equal to the barrier ring."""
        T = 8
        corpus, _, _ = synthetic.make_corpus(
            num_docs=40, vocab_size=96, num_topics=T, mean_doc_len=15.0,
            seed=12)
        mesh = jax.make_mesh((1,), ("worker",))
        lay = build_layout(corpus, n_workers=1, T=T, n_blocks=4)
        res = {}
        for ring_mode in ("barrier", "pipelined"):
            lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                           alpha=50.0 / T, beta=0.01, inner_mode=inner_mode,
                           ring_mode=ring_mode)
            arrays = lda.init_arrays(seed=0)
            for it in range(2):
                arrays = lda.sweep(arrays, seed=it)
            res[ring_mode] = arrays
        for name in ("z", "n_td", "n_wt", "n_t"):
            np.testing.assert_array_equal(
                np.asarray(res["barrier"][name]),
                np.asarray(res["pipelined"][name]))

    @pytest.mark.parametrize("inner_mode", ["scan", "fused", "vectorized"])
    def test_ragged_is_bit_identical_to_dense(self, inner_mode):
        """The ragged tentpole invariant, in-process: the tile-stream
        geometry changes only where tokens sit, never the chain — the
        canonical per-token z and every count table must be bit-equal to
        the dense run, in both ring modes."""
        T = 8
        corpus, _, _ = synthetic.make_corpus(
            num_docs=40, vocab_size=96, num_topics=T, mean_doc_len=15.0,
            seed=12)
        mesh = jax.make_mesh((1,), ("worker",))
        for ring_mode in ("barrier", "pipelined"):
            res = {}
            for kind in ("dense", "ragged"):
                lay = build_layout(corpus, n_workers=1, T=T, n_blocks=4,
                                   layout=kind)
                lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                               alpha=50.0 / T, beta=0.01,
                               inner_mode=inner_mode, ring_mode=ring_mode)
                arrays = lda.init_arrays(seed=0)
                for it in range(2):
                    arrays = lda.sweep(arrays, seed=it)
                res[kind] = (lay.extract_canonical(np.asarray(arrays["z"])),
                             *lda.global_counts(arrays))
            for a, b in zip(res["dense"], res["ragged"]):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ragged_needs_tile_geometry(self):
        """nomad_sweep_fn must reject a ragged request without the
        layout's static tile geometry."""
        from repro.core.nomad import nomad_sweep_fn
        mesh = jax.make_mesh((1,), ("worker",))
        with pytest.raises(ValueError, match="tile geometry"):
            nomad_sweep_fn(mesh, ("worker",), B=4, T=8, alpha=1.0,
                           beta=0.01, beta_bar=0.64, layout_kind="ragged")

    def test_mismatched_layout_rejected(self):
        corpus, _, _ = synthetic.make_corpus(
            num_docs=20, vocab_size=64, num_topics=8, mean_doc_len=10.0,
            seed=8)
        mesh = jax.make_mesh((1,), ("worker",))
        lay = build_layout(corpus, n_workers=2, T=8)
        with pytest.raises(ValueError, match="ring has"):
            NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                     alpha=1.0, beta=0.01)

    def test_invalid_ring_mode_rejected(self):
        corpus, _, _ = synthetic.make_corpus(
            num_docs=20, vocab_size=64, num_topics=8, mean_doc_len=10.0,
            seed=8)
        mesh = jax.make_mesh((1,), ("worker",))
        lay = build_layout(corpus, n_workers=1, T=8)
        with pytest.raises(ValueError, match="overlapped"):
            NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                     alpha=1.0, beta=0.01, ring_mode="overlapped")


@pytest.mark.slow
class TestMultiDevice:
    @pytest.mark.parametrize("sync_mode", ["stoken", "stale", "allreduce"])
    def test_8dev_ring(self, sync_mode):
        rep = _run_check(8, sync_mode)
        assert rep["n_td_mismatch"] == 0, rep
        assert rep["n_wt_mismatch"] == 0, rep
        assert rep["n_t_mismatch"] == 0, rep
        assert rep["word_map_mismatch"] == 0
        assert rep["tokens_preserved"] and rep["z_in_range"]
        assert rep["ll_improved"], rep["ll"]

    def test_multipod_ring(self):
        """2 pods × 4 workers: the cross-pod boundary hop must be exact."""
        rep = _run_check(8, "stoken", pods=2)
        assert rep["n_td_mismatch"] == 0, rep
        assert rep["n_wt_mismatch"] == 0, rep
        assert rep["n_t_mismatch"] == 0, rep
        assert rep["ll_improved"], rep["ll"]

    def test_load_balance_beats_naive(self):
        rep = _run_check(4, "stale")
        assert rep["round_imbalance"] < 3.0, rep

    def test_vectorized_inner_mode(self):
        """Beyond-paper batched cell pass: exact tables, LL still improves."""
        rep = _run_check(4, "stoken", inner_mode="vectorized")
        assert rep["n_td_mismatch"] == 0, rep
        assert rep["n_wt_mismatch"] == 0, rep
        assert rep["n_t_mismatch"] == 0, rep
        assert rep["ll_improved"], rep["ll"]

    @pytest.mark.parametrize("inner_mode,ring_mode,layout", [
        ("scan", "barrier", "dense"), ("fused", "barrier", "dense"),
        ("scan", "pipelined", "dense"), ("fused", "pipelined", "dense"),
        ("fused", "barrier", "ragged"), ("fused", "pipelined", "ragged"),
    ])
    def test_block_queue_ring(self, inner_mode, ring_mode, layout):
        """B = 4W: each worker circulates a 4-block queue; counts must stay
        exact and the chain must still mix — in both ring schedules and
        both token layouts."""
        rep = _run_check(4, "stoken", inner_mode=inner_mode, n_blocks=16,
                         ring_mode=ring_mode, layout=layout)
        assert rep["blocks_per_worker"] == 4
        assert rep["layout"] == layout
        assert rep["n_td_mismatch"] == 0, rep
        assert rep["n_wt_mismatch"] == 0, rep
        assert rep["n_t_mismatch"] == 0, rep
        assert rep["ll_improved"], rep["ll"]
        if layout == "ragged":
            # the tile streams must actually be leaner than the dense grid
            dense = _run_check(4, "stoken", inner_mode=inner_mode,
                               n_blocks=16, ring_mode=ring_mode)
            assert rep["pad_fraction"] < dense["pad_fraction"], (
                rep["pad_fraction"], dense["pad_fraction"])

    @pytest.mark.parametrize("ring_mode", ["barrier", "pipelined"])
    def test_multipod_ragged_ring(self, ring_mode):
        """2 pods × 2 workers on the ragged streams: the wrap-around queue
        hop must cross the pod axis exactly with the tile geometry too."""
        rep = _run_check(4, "stoken", pods=2, n_blocks=8,
                         ring_mode=ring_mode, layout="ragged")
        assert rep["n_td_mismatch"] == 0, rep
        assert rep["n_wt_mismatch"] == 0, rep
        assert rep["n_t_mismatch"] == 0, rep
        assert rep["ll_improved"], rep["ll"]

    @pytest.mark.parametrize("ring_mode", ["barrier", "pipelined"])
    def test_multipod_block_queue(self, ring_mode):
        """2 pods × 2 workers with B = 2W: the wrap-around queue hop must
        cross the pod axis exactly (in pipelined mode, twice per round)."""
        rep = _run_check(4, "stoken", pods=2, n_blocks=8,
                         ring_mode=ring_mode)
        assert rep["n_td_mismatch"] == 0, rep
        assert rep["n_wt_mismatch"] == 0, rep
        assert rep["n_t_mismatch"] == 0, rep
        assert rep["ll_improved"], rep["ll"]

    def test_non_multiple_n_blocks_rejected_end_to_end(self):
        """B % W != 0 must die in the launch path too, not just in
        build_layout unit tests."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.lda_dist_check",
             "4", "stoken", "1", "scan", "6"],
            capture_output=True, text=True, env=env, timeout=900)
        assert out.returncode != 0
        assert "multiple" in out.stderr

    def test_exactness_matrix(self):
        """The full sync × inner × B × ring × layout × doc_tile × r_mode
        matrix on the 8-device mesh: global counts bit-equal to a rebuild
        from z in every combination, the pipelined ring bit-equal to the
        barrier ring in every cell, the ragged layout bit-equal to the
        dense one in every cell, every doc-tiled (slab-paged) run
        bit-equal to the untiled run over the same grouped layout, and
        every sparse-r run bit-equal to its dense-r twin."""
        # 420 combos (the r_mode axis grew the matrix 252 -> 420) need
        # more than the default 900 s budget on a loaded CPU host
        rep = _run_module("repro.launch.lda_matrix_check", 8, 2,
                          timeout=2700)
        assert len(rep["combos"]) == 420
        assert {c["ring_mode"] for c in rep["combos"]} == \
            {"barrier", "pipelined"}
        assert {c["layout"] for c in rep["combos"]} == {"dense", "ragged"}
        assert {c["r_mode"] for c in rep["combos"]} == {"dense", "sparse"}
        assert len({c["doc_tile"] for c in rep["combos"]}) == 3  # None + 2
        cross_ring = [c for c in rep["combos"]
                      if "vs_barrier_z_mismatch" in c]
        cross_layout = [c for c in rep["combos"]
                        if "vs_dense_z_mismatch" in c]
        cross_paging = [c for c in rep["combos"]
                        if "vs_untiled_z_mismatch" in c]
        cross_rmode = [c for c in rep["combos"]
                       if "vs_rdense_z_mismatch" in c]
        assert len(cross_ring) == 126 and len(cross_layout) == 126
        assert len(cross_paging) == 144
        # every exact inner mode (scan, fused) gets a sparse twin
        assert len(cross_rmode) == 168
        assert all(c["r_mode"] == "sparse" for c in cross_rmode)
        bad = [c for c in rep["combos"]
               if c["n_td_mismatch"] or c["n_wt_mismatch"]
               or c["n_t_mismatch"] or not c["tokens_preserved"]
               or any(c.get(f"{p}_{f}_mismatch", 0)
                      for p in ("vs_barrier", "vs_dense", "vs_untiled",
                                "vs_rdense")
                      for f in ("z", "n_wt", "n_t"))]
        assert rep["all_exact"], bad


class TestDocTileSmoke:
    """Fast (non-slow) doc-tiling + sparse-r regression signal: the
    matrix check's smoke subset — fused/pipelined/stoken at B = 2W on
    both layouts, doc_tile ∈ {None, 3}, paged vs untiled twins, plus a
    sparse-r twin per untiled layout — so a doc-tiling or r-bucket chain
    break fails tier-1's fast stage, not just the slow matrix."""

    def test_matrix_smoke_subset(self):
        rep = _run_module("repro.launch.lda_matrix_check", 4, 1, "smoke")
        assert rep["subset"] == "smoke"
        assert len(rep["combos"]) == 6
        assert {c["layout"] for c in rep["combos"]} == {"dense", "ragged"}
        tiled = [c for c in rep["combos"] if c["doc_tile"]]
        assert tiled and all("vs_untiled_z_mismatch" in c for c in tiled)
        sparse = [c for c in rep["combos"] if c["r_mode"] == "sparse"]
        assert len(sparse) == 2
        assert all("vs_rdense_z_mismatch" in c and not c["doc_tile"]
                   for c in sparse)
        # the smoke subset reports the slab-vs-whole-shard VMEM numbers
        # (ci.sh prints them for silicon tuning)
        assert all(s["ntd_slab_bytes"] < s["ntd_whole_bytes"]
                   for s in rep["slab_vmem"])
        assert rep["all_exact"], rep["combos"]


@pytest.mark.slow
class TestRingShift:
    """Direct unit coverage of ``_ring_shift_down`` (previously only hit
    through whole sweeps)."""

    def test_flat_ring(self):
        rep = _run_module("repro.launch.ring_shift_check", 8, 1)
        assert rep["one_shift_mismatch"] == 0, rep
        assert rep["one_shift_vec_mismatch"] == 0, rep
        assert rep["identity_mismatch"] == 0, rep
        assert rep["identity_vec_mismatch"] == 0, rep

    def test_two_axis_ring_crosses_pod_boundary(self):
        """('pod','worker') mesh: one shift moves flat position i+1 → i,
        the wrap-around element crosses the pod axis, and W shifts restore
        the identity."""
        rep = _run_module("repro.launch.ring_shift_check", 8, 2)
        assert rep["ring_axes"] == ["pod", "worker"]
        assert rep["one_shift_mismatch"] == 0, rep
        assert rep["one_shift_vec_mismatch"] == 0, rep
        assert rep["identity_mismatch"] == 0, rep
        assert rep["identity_vec_mismatch"] == 0, rep
        assert rep["cross_pod_ok"], rep


@pytest.mark.slow
class TestStokenStaleness:
    """The s-token working copy is stale but boundedly so (paper Alg. 4):
    instrumented sweeps must match the fold schedule exactly and never
    exceed the documented (W−1)·k-cell staleness bound — and the pipelined
    ring must produce the bit-identical lag trace."""

    @pytest.mark.parametrize("n_dev,inner_mode,n_blocks", [
        (8, "scan", 16), (4, "fused", 8),
    ])
    def test_lag_bounded_and_ring_mode_invariant(self, n_dev, inner_mode,
                                                 n_blocks):
        rep = _run_module("repro.launch.stoken_lag_check",
                          n_dev, inner_mode, n_blocks)
        assert rep["fold_schedule_exact"], rep
        assert rep["lag_within_bound"], rep
        assert rep["lag_nonzero"], rep          # the check isn't vacuous
        assert rep["documented_bound_ok"], rep
        assert rep["fold_window_rounds_max"] <= rep["n_devices"] - 1, rep
        assert rep["ring_modes_identical"], rep
        assert rep["layout_modes_identical"], rep
