"""Nomad distributed LDA tests (paper §4).

Single-device ring (W=1, degenerate but exercises the full code path)
runs in-process; multi-device rings run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count so the main test process
keeps its single real device (per the dry-run isolation rule).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.nomad import NomadLDA
from repro.data import synthetic
from repro.data.sharding import build_layout, lpt_assign

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_check(n_dev, sync_mode, pods=1, inner_mode="scan"):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.lda_dist_check",
         str(n_dev), sync_mode, str(pods), inner_mode],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


class TestLayout:
    def test_lpt_balances_zipf(self):
        rng = np.random.default_rng(0)
        weights = (1e6 / np.arange(1, 2001) ** 1.1).astype(np.int64)
        assign = lpt_assign(weights, 8, balance=True)
        loads = np.bincount(assign, weights=weights, minlength=8)
        # LPT reaches the packing lower bound max(mean, heaviest item)
        lower = max(loads.mean(), weights.max())
        assert loads.max() <= lower * 1.01
        naive = lpt_assign(weights, 8, balance=False)
        loads_naive = np.bincount(naive, weights=weights, minlength=8)
        assert loads_naive.max() / loads_naive.mean() > 2.0  # skew is real

    def test_layout_covers_all_tokens(self):
        corpus, _, _ = synthetic.make_corpus(
            num_docs=50, vocab_size=128, num_topics=8, mean_doc_len=20.0,
            seed=1)
        lay = build_layout(corpus, n_workers=4, T=8)
        assert int(lay.tok_valid.sum()) == corpus.num_tokens
        # every token's global word id maps back through block/local index
        w, b, l = np.nonzero(lay.tok_valid)
        gw = lay.word_of_block[b, lay.tok_wrd[w, b, l]]
        np.testing.assert_array_equal(gw, lay.tok_gwrd[w, b, l])
        # word->block assignment is respected
        assert (lay.word_assign[gw] == b).all()

    def test_boundaries_mark_distinct_words_per_cell(self):
        corpus, _, _ = synthetic.make_corpus(
            num_docs=30, vocab_size=64, num_topics=8, mean_doc_len=15.0,
            seed=2)
        lay = build_layout(corpus, n_workers=2, T=8)
        for w in range(lay.W):
            for b in range(lay.B):
                m = lay.tok_valid[w, b]
                words = lay.tok_gwrd[w, b][m]
                bounds = lay.tok_bound[w, b][m]
                assert bounds.sum() == len(np.unique(words))


class TestSingleDeviceRing:
    """W=1: the nomad machinery must reduce to serial F+LDA semantics."""

    def test_invariants_and_ll(self):
        T = 8
        corpus, _, _ = synthetic.make_corpus(
            num_docs=60, vocab_size=128, num_topics=T, mean_doc_len=25.0,
            seed=4)
        mesh = jax.make_mesh((1,), ("worker",))
        lay = build_layout(corpus, n_workers=1, T=T)
        lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                       alpha=50.0 / T, beta=0.01)
        arrays = lda.init_arrays(seed=0)
        ll0 = lda.log_likelihood(arrays)
        for it in range(3):
            arrays = lda.sweep(arrays, seed=it)
        ll1 = lda.log_likelihood(arrays)
        assert ll1 > ll0

        n_td, n_wt, n_t = lda.global_counts(arrays)
        assert int(n_t.sum()) == corpus.num_tokens
        np.testing.assert_array_equal(n_td.sum(0), n_t)
        np.testing.assert_array_equal(n_wt.sum(0), n_t)


@pytest.mark.slow
class TestMultiDevice:
    @pytest.mark.parametrize("sync_mode", ["stoken", "stale", "allreduce"])
    def test_8dev_ring(self, sync_mode):
        rep = _run_check(8, sync_mode)
        assert rep["n_td_mismatch"] == 0, rep
        assert rep["n_wt_mismatch"] == 0, rep
        assert rep["n_t_mismatch"] == 0, rep
        assert rep["word_map_mismatch"] == 0
        assert rep["tokens_preserved"] and rep["z_in_range"]
        assert rep["ll_improved"], rep["ll"]

    def test_multipod_ring(self):
        """2 pods × 4 workers: the cross-pod boundary hop must be exact."""
        rep = _run_check(8, "stoken", pods=2)
        assert rep["n_td_mismatch"] == 0, rep
        assert rep["n_wt_mismatch"] == 0, rep
        assert rep["n_t_mismatch"] == 0, rep
        assert rep["ll_improved"], rep["ll"]

    def test_load_balance_beats_naive(self):
        rep = _run_check(4, "stale")
        assert rep["round_imbalance"] < 3.0, rep

    def test_vectorized_inner_mode(self):
        """Beyond-paper batched cell pass: exact tables, LL still improves."""
        rep = _run_check(4, "stoken", inner_mode="vectorized")
        assert rep["n_td_mismatch"] == 0, rep
        assert rep["n_wt_mismatch"] == 0, rep
        assert rep["n_t_mismatch"] == 0, rep
        assert rep["ll_improved"], rep["ll"]
