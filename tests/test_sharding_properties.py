"""Property-based tests for the hierarchical-LPT nomad layout.

The block count ``B`` is supposed to be a *free* scaling knob (DESIGN.md
§3/§4): for any corpus shape and any multiple ``B = m·W``,

* the per-round queue loads — and hence ``round_imbalance`` — are exactly
  those of the ``B = W`` packing (words are LPT-packed into ``W`` ring
  chunks first, then each chunk is split into ``k`` blocks);
* the rotation schedule visits every ``(worker, block)`` pair exactly once
  per sweep, and the layout places every corpus token exactly once;
* the pipelined half-queues partition each queue and are load-matched to
  within one block's load (``_order_bins_for_halves``);
* any ``B`` that is not a positive multiple of ``W`` is rejected.

Runs under real ``hypothesis`` when installed — CI servers export
``REPRO_CI_INSTALL_HYPOTHESIS=1`` so ``tools/ci.sh`` installs it and these
run un-shimmed; hermetic/offline containers (the default) fall back to the
deterministic shim from ``tests/conftest.py``, which caps the example
count (REPRO_SHIM_MAX_EXAMPLES, default 10).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import synthetic
from repro.data.sharding import build_layout, half_queue_split


def _corpus(num_docs, vocab, seed):
    corpus, _, _ = synthetic.make_corpus(
        num_docs=num_docs, vocab_size=vocab, num_topics=8,
        mean_doc_len=12.0, seed=seed)
    return corpus


class TestHierarchicalLPT:
    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(2, 5), mult=st.integers(2, 4),
           num_docs=st.integers(12, 60), vocab=st.integers(32, 128),
           seed=st.integers(0, 10))
    def test_round_imbalance_is_free_in_B(self, W, mult, num_docs, vocab,
                                          seed):
        corpus = _corpus(num_docs, vocab, seed)
        lay_w = build_layout(corpus, n_workers=W, T=8, n_blocks=W)
        lay_b = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W)
        # chunk membership is identical, so the per-round loads — integer
        # token counts — agree exactly, and so does the float statistic
        assert lay_b.round_imbalance == lay_w.round_imbalance

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(1, 5), mult=st.integers(1, 4),
           num_docs=st.integers(12, 60), vocab=st.integers(32, 128),
           seed=st.integers(0, 10))
    def test_schedule_visits_each_pair_once_and_covers_tokens(
            self, W, mult, num_docs, vocab, seed):
        corpus = _corpus(num_docs, vocab, seed)
        lay = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W)
        k = lay.k
        visited = set()
        for r in range(W):
            for w in range(W):
                c = (w + r) % W
                for b in range(c * k, (c + 1) * k):
                    assert (w, b) not in visited
                    visited.add((w, b))
        assert len(visited) == W * lay.B
        # every token placed exactly once, word→block map respected
        assert int(lay.tok_valid.sum()) == corpus.num_tokens
        w_i, b_i, l_i = np.nonzero(lay.tok_valid)
        gw = lay.word_of_block[b_i, lay.tok_wrd[w_i, b_i, l_i]]
        np.testing.assert_array_equal(gw, lay.tok_gwrd[w_i, b_i, l_i])
        assert (lay.word_assign[gw] == b_i).all()

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(2, 5), mult=st.integers(2, 4),
           num_docs=st.integers(12, 60), vocab=st.integers(32, 128),
           seed=st.integers(0, 10))
    def test_half_queues_partition_and_balance(self, W, mult, num_docs,
                                               vocab, seed):
        corpus = _corpus(num_docs, vocab, seed)
        lay = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W)
        k = lay.k
        k0 = half_queue_split(k)
        assert 0 < k0 < k
        halves = lay.half_loads()
        # the halves partition every round's queue load exactly
        for r in range(W):
            for w in range(W):
                c = (w + r) % W
                assert halves[r, w].sum() == \
                    lay.cell_sizes[w, c * k:(c + 1) * k].sum()
        # greedy half ordering: per chunk, |half0 − half1| ≤ max block load
        gaps = lay.half_balance_gaps()
        assert (gaps[:, 0] <= gaps[:, 1]).all(), gaps

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(2, 6), B=st.integers(0, 40),
           seed=st.integers(0, 5))
    def test_non_multiple_B_rejected(self, W, B, seed):
        from hypothesis import assume
        assume(B % W != 0 or B < W)
        corpus = _corpus(20, 64, seed)
        with pytest.raises(ValueError, match="multiple"):
            build_layout(corpus, n_workers=W, T=8, n_blocks=B)
