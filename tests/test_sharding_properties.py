"""Property-based tests for the hierarchical-LPT nomad layout.

The block count ``B`` is supposed to be a *free* scaling knob (DESIGN.md
§3/§4): for any corpus shape and any multiple ``B = m·W``,

* the per-round queue loads — and hence ``round_imbalance`` — are exactly
  those of the ``B = W`` packing (words are LPT-packed into ``W`` ring
  chunks first, then each chunk is split into ``k`` blocks);
* the rotation schedule visits every ``(worker, block)`` pair exactly once
  per sweep, and the layout places every corpus token exactly once;
* the pipelined half-queues partition each queue and are load-matched to
  within one block's load (``_order_bins_for_halves``);
* any ``B`` that is not a positive multiple of ``W`` is rejected;
* the ragged tile-stream layout carries the identical canonical token
  sequence as the dense grid, pads at most one tile per cell (so its
  pad_fraction is bounded by the tile size independent of ``B``), and
  realizes the pipelined half split as one static tile index.

Runs under real ``hypothesis`` when installed — CI servers export
``REPRO_CI_INSTALL_HYPOTHESIS=1`` so ``tools/ci.sh`` installs it and these
run un-shimmed; hermetic/offline containers (the default) fall back to the
deterministic shim from ``tests/conftest.py``, which caps the example
count (REPRO_SHIM_MAX_EXAMPLES, default 10).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import synthetic
from repro.data.sharding import build_layout, half_queue_split


def _corpus(num_docs, vocab, seed):
    corpus, _, _ = synthetic.make_corpus(
        num_docs=num_docs, vocab_size=vocab, num_topics=8,
        mean_doc_len=12.0, seed=seed)
    return corpus


class TestHierarchicalLPT:
    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(2, 5), mult=st.integers(2, 4),
           num_docs=st.integers(12, 60), vocab=st.integers(32, 128),
           seed=st.integers(0, 10))
    def test_round_imbalance_is_free_in_B(self, W, mult, num_docs, vocab,
                                          seed):
        corpus = _corpus(num_docs, vocab, seed)
        lay_w = build_layout(corpus, n_workers=W, T=8, n_blocks=W)
        lay_b = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W)
        # chunk membership is identical, so the per-round loads — integer
        # token counts — agree exactly, and so does the float statistic
        assert lay_b.round_imbalance == lay_w.round_imbalance

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(1, 5), mult=st.integers(1, 4),
           num_docs=st.integers(12, 60), vocab=st.integers(32, 128),
           seed=st.integers(0, 10))
    def test_schedule_visits_each_pair_once_and_covers_tokens(
            self, W, mult, num_docs, vocab, seed):
        corpus = _corpus(num_docs, vocab, seed)
        lay = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W)
        k = lay.k
        visited = set()
        for r in range(W):
            for w in range(W):
                c = (w + r) % W
                for b in range(c * k, (c + 1) * k):
                    assert (w, b) not in visited
                    visited.add((w, b))
        assert len(visited) == W * lay.B
        # every token placed exactly once, word→block map respected
        assert int(lay.tok_valid.sum()) == corpus.num_tokens
        w_i, b_i, l_i = np.nonzero(lay.tok_valid)
        gw = lay.word_of_block[b_i, lay.tok_wrd[w_i, b_i, l_i]]
        np.testing.assert_array_equal(gw, lay.tok_gwrd[w_i, b_i, l_i])
        assert (lay.word_assign[gw] == b_i).all()

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(2, 5), mult=st.integers(2, 4),
           num_docs=st.integers(12, 60), vocab=st.integers(32, 128),
           seed=st.integers(0, 10))
    def test_half_queues_partition_and_balance(self, W, mult, num_docs,
                                               vocab, seed):
        corpus = _corpus(num_docs, vocab, seed)
        lay = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W)
        k = lay.k
        k0 = half_queue_split(k)
        assert 0 < k0 < k
        halves = lay.half_loads()
        # the halves partition every round's queue load exactly
        for r in range(W):
            for w in range(W):
                c = (w + r) % W
                assert halves[r, w].sum() == \
                    lay.cell_sizes[w, c * k:(c + 1) * k].sum()
        # greedy half ordering: per chunk, |half0 − half1| ≤ max block load
        gaps = lay.half_balance_gaps()
        assert (gaps[:, 0] <= gaps[:, 1]).all(), gaps

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(2, 6), B=st.integers(0, 40),
           seed=st.integers(0, 5))
    def test_non_multiple_B_rejected(self, W, B, seed):
        from hypothesis import assume
        assume(B % W != 0 or B < W)
        corpus = _corpus(20, 64, seed)
        with pytest.raises(ValueError, match="multiple"):
            build_layout(corpus, n_workers=W, T=8, n_blocks=B)


class TestRaggedLayout:
    """The ragged tile streams must carry exactly the dense grid's tokens
    (same cells, same in-cell order), with padding bounded by the tile
    size per cell — the property that keeps pad_fraction independent of
    ``B`` — and with the pipelined half split expressible as one static
    tile index."""

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(1, 5), mult=st.integers(1, 4),
           num_docs=st.integers(12, 60), vocab=st.integers(32, 128),
           seed=st.integers(0, 10))
    def test_token_multiset_and_order_match_dense(self, W, mult, num_docs,
                                                  vocab, seed):
        corpus = _corpus(num_docs, vocab, seed)
        dense = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W)
        rag = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W,
                           layout="ragged")
        # identical canonical sequence => per-(worker, cell) multiset AND
        # per-cell order both preserved
        for lay in (dense, rag):
            assert int(lay.tok_valid.sum()) == corpus.num_tokens
        dw, db, dd, dj = dense.token_coords()
        rw, rb, rd, rj = rag.token_coords()
        np.testing.assert_array_equal(dw, rw)
        np.testing.assert_array_equal(db, rb)
        np.testing.assert_array_equal(dd, rd)
        np.testing.assert_array_equal(dj, rj)
        np.testing.assert_array_equal(
            dense.extract_canonical(dense.tok_gwrd),
            rag.extract_canonical(rag.tok_gwrd))
        np.testing.assert_array_equal(
            dense.extract_canonical(dense.tok_bound),
            rag.extract_canonical(rag.tok_bound))
        assert rag.word_map_mismatches() == 0
        # canonical placement round-trips
        vals = np.arange(corpus.num_tokens, dtype=np.int32)
        np.testing.assert_array_equal(
            rag.extract_canonical(rag.place_canonical(vals)), vals)

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(1, 5), mult=st.integers(1, 4),
           num_docs=st.integers(12, 60), vocab=st.integers(32, 128),
           seed=st.integers(0, 10), tile=st.sampled_from([4, 8, 32]))
    def test_pad_bounded_by_tile_size(self, W, mult, num_docs, vocab,
                                      seed, tile):
        corpus = _corpus(num_docs, vocab, seed)
        lay = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W,
                           layout="ragged", tile=tile)
        k, k0 = lay.k, half_queue_split(lay.k)
        sizes = lay.cell_sizes.reshape(W, W, k)
        half0, half1 = sizes[:, :, :k0].sum(2), sizes[:, :, k0:].sum(2)
        r0, r1 = lay.tile_split, lay.n_tiles - lay.tile_split
        # each half is padded to its own max: every cell wastes < tile
        # (empty cells exactly one tile), so the stream capacity exceeds
        # the heaviest half by at most one tile per cell — independent of
        # how fine B slices the vocabulary.
        assert r0 * tile <= half0.max() + k0 * tile
        assert r1 * tile <= half1.max() + (k - k0) * tile
        cap = W * W * lay.stream_len
        assert lay.pad_fraction == 1.0 - lay.cell_sizes.sum() / cap
        assert cap <= (half0.max() + half1.max() + k * tile) * W * W

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(2, 5), mult=st.integers(2, 4),
           num_docs=st.integers(12, 60), vocab=st.integers(32, 128),
           seed=st.integers(0, 10))
    def test_half_split_is_a_tile_split(self, W, mult, num_docs, vocab,
                                        seed):
        """Tiles [0, tile_split) hold exactly the cells [0, k0) of every
        stream, and the valid-token loads of the two tile ranges equal the
        dense layout's half_loads() — the pipelined ring can split at one
        static tile index with no load-match regression."""
        corpus = _corpus(num_docs, vocab, seed)
        lay = build_layout(corpus, n_workers=W, T=8, n_blocks=mult * W,
                           layout="ragged")
        k, k0 = lay.k, half_queue_split(lay.k)
        r0 = lay.tile_split
        assert 0 < k0 < k and 0 < r0 < lay.n_tiles
        halves = lay.half_loads()             # (W_rounds, W, 2) from sizes
        valid = lay.tok_valid.reshape(W, W, lay.n_tiles, lay.tile)
        for w in range(W):
            for c in range(W):
                cot = lay.cell_of_tile[w, c]
                assert cot[:r0].max() < k0 <= cot[r0:].min()
                r = (c - w) % W               # round when w sweeps chunk c
                assert valid[w, c, :r0].sum() == halves[r, w, 0]
                assert valid[w, c, r0:].sum() == halves[r, w, 1]

    def test_bad_tile_and_layout_rejected(self):
        corpus = _corpus(20, 64, 0)
        with pytest.raises(ValueError, match="layout"):
            build_layout(corpus, n_workers=2, T=8, layout="csr")
        with pytest.raises(ValueError, match="tile"):
            build_layout(corpus, n_workers=2, T=8, layout="ragged", tile=0)


class TestChunkedBuild:
    """The out-of-core chunked build (``build_layout_from_store``) must be
    *byte-identical* to the monolithic ``build_layout`` on the same corpus
    — this is what lets the whole distributed exactness matrix transfer to
    store-fed layouts for free (ISSUE 7 / DESIGN.md §9)."""

    @settings(max_examples=20, deadline=None)
    @given(W=st.integers(1, 4), mult=st.integers(1, 3),
           num_docs=st.integers(12, 60), vocab=st.integers(32, 128),
           seed=st.integers(0, 10),
           kind=st.sampled_from(["dense", "ragged"]),
           doc_tile=st.sampled_from([None, 4]),
           tokens_per_shard=st.sampled_from([64, 257, 1 << 20]))
    def test_chunked_build_byte_identical(self, W, mult, num_docs, vocab,
                                          seed, kind, doc_tile,
                                          tokens_per_shard):
        # tempfile, not the tmp_path fixture: function-scoped fixtures
        # don't mix with @given under real hypothesis
        import tempfile

        from repro.data.corpus_store import (CorpusStore,
                                             build_layout_from_store)
        corpus = _corpus(num_docs, vocab, seed)
        with tempfile.TemporaryDirectory() as td:
            store = CorpusStore.from_corpus(
                corpus, td + "/store", tokens_per_shard=tokens_per_shard)
            kw = dict(n_workers=W, T=8, n_blocks=mult * W, layout=kind,
                      doc_tile=doc_tile)
            self._compare(corpus, store, kw)

    @staticmethod
    def _compare(corpus, store, kw):
        from repro.data.corpus_store import build_layout_from_store
        mono = build_layout(corpus, **kw)
        chunk = build_layout_from_store(store, **kw)
        for f in ("tok_doc", "tok_wrd", "tok_valid", "tok_bound",
                  "tok_gwrd", "tok_slot", "canon_idx", "cell_sizes",
                  "doc_of_worker", "word_of_block", "doc_assign",
                  "word_assign", "cell_of_tile", "doc_tile_of"):
            a, b = getattr(mono, f), getattr(chunk, f)
            if a is None:
                assert b is None, f
                continue
            assert a.dtype == b.dtype, f
            np.testing.assert_array_equal(a, b, err_msg=f)
        for f in ("L", "I_max", "J_max", "tile", "n_tiles", "tile_split",
                  "stream_len", "doc_tile", "doc_blk", "r_cap", "kind"):
            assert getattr(mono, f) == getattr(chunk, f), f

    @settings(max_examples=10, deadline=None)
    @given(num_docs=st.integers(12, 40), vocab=st.integers(32, 96),
           seed=st.integers(0, 5))
    def test_store_roundtrip_and_stats(self, num_docs, vocab, seed):
        import tempfile

        from repro.data.corpus_store import CorpusStore
        corpus = _corpus(num_docs, vocab, seed)
        with tempfile.TemporaryDirectory() as td:
            store = CorpusStore.from_corpus(corpus, td + "/s",
                                            tokens_per_shard=100)
            back = store.to_corpus()
            np.testing.assert_array_equal(back.doc_ids, corpus.doc_ids)
            np.testing.assert_array_equal(back.word_ids, corpus.word_ids)
            # stats come from the per-shard side tables, not a token scan
            np.testing.assert_array_equal(store.doc_lengths(),
                                          corpus.doc_lengths())
            np.testing.assert_array_equal(store.word_freqs(),
                                          corpus.word_freqs())
