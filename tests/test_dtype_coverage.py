"""bf16 mixed-precision coverage: every arch's forward + train step must be
finite in bf16 (the §Perf dtype variant must be safe framework-wide)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer
from repro.train.train_step import init_train_state, make_train_step

# Model-zoo coverage is minutes-long; excluded from the fast signal via
# `pytest -m "not slow"` (tier-1 still runs everything).
pytestmark = pytest.mark.slow

B, S = 2, 16


def _batch(cfg, key):
    kt, kp, kf, kl = jax.random.split(key, 4)
    if cfg.modality == "audio_frames":
        return {"frames": jax.random.normal(kf, (B, S, cfg.frontend_dim),
                                            jnp.bfloat16),
                "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size)}
    if cfg.modality == "image_patches":
        return {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
                "patches": jax.random.normal(
                    kp, (B, cfg.frontend_tokens, cfg.frontend_dim),
                    jnp.bfloat16)}
    return {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_bf16_train_step_finite(name):
    cfg = get_config(name).smoke()
    state = init_train_state(cfg, jax.random.key(0), jnp.bfloat16)
    # params really are bf16
    dts = {leaf.dtype for leaf in jax.tree_util.tree_leaves(state.params)}
    assert any(d == jnp.bfloat16 for d in dts)
    step = jax.jit(make_train_step(cfg, lr=1e-3, remat=False))
    state2, metrics = step(state, _batch(cfg, jax.random.key(1)))
    assert bool(jnp.isfinite(metrics["loss"])), (name, metrics)
    # optimizer state stays f32 (mixed precision, not pure-bf16 training)
    m_leaf = jax.tree_util.tree_leaves(state2.opt.m)[0]
    assert m_leaf.dtype == jnp.float32


@pytest.mark.parametrize("name", ["qwen3-8b", "mamba2-1.3b", "zamba2-2.7b",
                                  "deepseek-moe-16b"])
def test_bf16_layer_remat_train(name):
    cfg = get_config(name).smoke()
    state = init_train_state(cfg, jax.random.key(0), jnp.bfloat16)
    step = jax.jit(make_train_step(cfg, lr=1e-3, layer_remat=True))
    _, metrics = step(state, _batch(cfg, jax.random.key(2)))
    assert bool(jnp.isfinite(metrics["loss"])), name
