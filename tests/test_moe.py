"""MoE block tests: routing, dispatch/combine, capacity, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import moe as moe_mod
from repro.models.moe import dispatch_indices

# Model-zoo coverage is minutes-long; excluded from the fast signal via
# `pytest -m "not slow"` (tier-1 still runs everything).
pytestmark = pytest.mark.slow


class TestDispatchIndices:
    @given(n=st.integers(1, 64), k=st.integers(1, 4), E=st.integers(2, 16),
           seed=st.integers(0, 99))
    @settings(max_examples=40, deadline=None)
    def test_ranks_unique_per_expert(self, n, k, E, seed):
        rng = np.random.default_rng(seed)
        experts = jnp.asarray(rng.integers(0, E, (n, k)).astype(np.int32))
        cap = max(1, (n * k) // E)
        dest, rank, keep = jax.jit(
            lambda e: dispatch_indices(e, E, cap))(experts)
        dest, rank, keep = map(np.asarray, (dest, rank, keep))
        # kept (dest, rank) pairs are unique bucket slots
        kept = list(zip(dest[keep], rank[keep]))
        assert len(kept) == len(set(kept))
        assert (rank[keep] < cap).all()
        # dropped = exactly the overflow beyond capacity per expert
        flat = np.asarray(experts).reshape(-1)
        for e in range(E):
            n_e = (flat == e).sum()
            assert (dest == e).sum() == min(n_e, cap)

    def test_order_stability(self):
        experts = jnp.asarray([[0], [1], [0], [0]], dtype=jnp.int32)
        dest, rank, keep = dispatch_indices(experts, 2, cap := 2)
        np.testing.assert_array_equal(np.asarray(rank), [0, 0, 1, 1])
        np.testing.assert_array_equal(np.asarray(keep), [1, 1, 1, 0])


@pytest.mark.slow
class TestExpertParallel:
    def test_ep_matches_single_program(self):
        """shard_map EP path (a2a dispatch) ≡ single-program path."""
        import json
        import os
        import subprocess
        import sys
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(repo, "src")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.ep_check", "4"],
            capture_output=True, text=True, env=env, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        rep = json.loads(out.stdout.strip().splitlines()[-1])
        assert rep["agree"], rep


class TestMoEForward:
    def _cfg(self):
        return get_config("deepseek-moe-16b").smoke()

    def test_output_shape_and_aux(self):
        cfg = self._cfg()
        p = moe_mod.moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
        y, aux = jax.jit(lambda p, x: moe_mod.moe_forward(p, cfg, x))(p, x)
        assert y.shape == x.shape
        assert jnp.isfinite(y).all() and jnp.isfinite(aux)
        # balanced router ⇒ aux ≈ 1 (Switch normalization); wildly off = bug
        assert 0.5 < float(aux) < 4.0

    def test_capacity_1_0_drops_overflow_but_stays_finite(self):
        cfg = self._cfg()
        p = moe_mod.moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
        y, _ = moe_mod.moe_forward(p, cfg, x, capacity_factor=0.5)
        assert jnp.isfinite(y).all()

    def test_grad_flows(self):
        cfg = self._cfg()
        p = moe_mod.moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))

        def loss(p):
            y, aux = moe_mod.moe_forward(p, cfg, x)
            return jnp.sum(y ** 2) + 0.01 * aux

        g = jax.grad(loss)(p)
        gnorm = {k: float(jnp.abs(v).max()) for k, v in
                 [("router", g["router"]), ("w_gate", g["w_gate"]),
                  ("w_down", g["w_down"])]}
        assert all(v > 0 for v in gnorm.values()), gnorm

    def test_identical_tokens_get_identical_outputs(self):
        cfg = self._cfg()
        p = moe_mod.moe_init(jax.random.key(0), cfg)
        x0 = jax.random.normal(jax.random.key(2), (1, 1, cfg.d_model))
        x = jnp.tile(x0, (1, 8, 1))
        y, _ = moe_mod.moe_forward(p, cfg, x, capacity_factor=8.0)
        # all tokens identical → all outputs identical (no capacity drops)
        np.testing.assert_allclose(np.asarray(y - y[:, :1]), 0.0, atol=1e-5)
