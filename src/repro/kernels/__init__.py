"""Pallas TPU kernels for the paper's compute hot spot: the per-token sampler.

Each kernel package ships three modules:
    <name>.py — pl.pallas_call + BlockSpec VMEM tiling (TPU target)
    ops.py    — jit'd public wrapper (padding, dtype plumbing, interpret flag)
    ref.py    — pure-jnp oracle used by the allclose test sweeps
"""
