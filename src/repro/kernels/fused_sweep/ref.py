"""Pure-jnp oracle for the fused F+LDA sweep kernel.

A ``lax.scan`` over the token stream with exactly the kernel's masked
semantics (and exactly ``cgs.sweep_fplda_word``'s float-op order), used to
pin the Pallas kernel down bit-for-bit in tests and benchmarks.

The r-bucket draw runs over the capacity-``r_cap`` compacted topic vector
(:mod:`repro.kernels.fused_sweep.rbucket`): ``r_mode="dense"`` recomputes
the compaction from the dense ``n_td`` row per token, ``r_mode="sparse"``
maintains it as per-doc ``(topics, counts)`` side tables threaded through
the scan — bit-identical chains by construction (see the rbucket module
docstring for the exactness argument).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ftree
from repro.kernels.fused_sweep import rbucket

F32 = jnp.float32


def fused_sweep_ref(tok_doc, tok_wrd, tok_valid, tok_bound, z, u,
                    n_td, n_wt, n_t, *, alpha, beta, beta_bar, F0=None,
                    r_mode="dense", r_cap=None, topics=None, counts=None):
    """Reference sweep; same signature/returns as ``fused_sweep_pallas``.

    ``F0`` is the incoming F+tree (zeros by default — the single-call
    convention); the cell-batch oracle threads it across cells to mirror
    the kernel's carried tree.

    ``r_cap`` is the compacted r-vector capacity (default ``T`` — note it
    is chain-affecting, see :mod:`rbucket`).  ``r_mode="sparse"`` threads
    per-doc ``(topics, counts)`` side tables (built from ``n_td`` when not
    given) and returns them appended: a 7-tuple instead of the dense
    5-tuple.
    """
    T = n_t.shape[-1]
    cap = T if r_cap is None else int(r_cap)
    sparse = r_mode == "sparse"
    if r_mode not in ("dense", "sparse"):
        raise ValueError(f"r_mode must be 'dense' or 'sparse', got {r_mode}")
    if sparse and topics is None:
        topics, counts = rbucket.build_side_table(n_td, cap)

    def q_of(nwt_row, nt):
        return (nwt_row.astype(F32) + beta) / (nt.astype(F32) + beta_bar)

    def step(carry, inp):
        if sparse:
            z, n_td, n_wt, n_t, F, tpc_tab, cnt_tab = carry
        else:
            z, n_td, n_wt, n_t, F = carry
        k, u01 = inp
        d, w = tok_doc[k], tok_wrd[k]
        valid, boundary = tok_valid[k] != 0, tok_bound[k] != 0
        t_old = z[k]
        one = valid.astype(jnp.int32)

        F = lax.cond(boundary, lambda: ftree.build(q_of(n_wt[w], n_t)),
                     lambda: F)

        n_td = n_td.at[d, t_old].add(-one)
        n_wt = n_wt.at[w, t_old].add(-one)
        n_t = n_t.at[t_old].add(-one)
        new_leaf = ((n_wt[w, t_old].astype(F32) + beta)
                    / (n_t[t_old].astype(F32) + beta_bar))
        F = ftree.set_leaf(F, t_old,
                           jnp.where(valid, new_leaf, F[T + t_old]))

        q = ftree.leaves(F)
        if sparse:
            tpc, cnt = rbucket.decrement(tpc_tab[d], cnt_tab[d],
                                         t_old, valid)
        else:
            tpc, cnt = rbucket.compact_row(n_td[d], cap)
        c = rbucket.r_cumsum(tpc, cnt, q)
        r_mass = c[-1]
        q_total = ftree.total(F)
        norm = alpha * q_total + r_mass
        u_val = u01 * norm
        in_r = u_val < r_mass
        t_r = rbucket.pick(tpc, cnt, c, u_val)
        t_q = ftree.sample(F, jnp.clip((u_val - r_mass)
                                       / jnp.maximum(alpha * q_total, 1e-30),
                                       0.0, 1.0 - 1e-7))
        t_new = jnp.where(valid, jnp.where(in_r, t_r, t_q), t_old)

        n_td = n_td.at[d, t_new].add(one)
        n_wt = n_wt.at[w, t_new].add(one)
        n_t = n_t.at[t_new].add(one)
        new_leaf2 = ((n_wt[w, t_new].astype(F32) + beta)
                     / (n_t[t_new].astype(F32) + beta_bar))
        F = ftree.set_leaf(F, t_new,
                           jnp.where(valid, new_leaf2, F[T + t_new]))
        z = z.at[k].set(t_new)
        if sparse:
            tpc, cnt = rbucket.increment(tpc, cnt, t_new, valid)
            tpc_tab = tpc_tab.at[d].set(tpc)
            cnt_tab = cnt_tab.at[d].set(cnt)
            return (z, n_td, n_wt, n_t, F, tpc_tab, cnt_tab), None
        return (z, n_td, n_wt, n_t, F), None

    n = tok_doc.shape[0]
    if F0 is None:
        F0 = jnp.zeros((2 * T,), F32)
    carry0 = (z, n_td, n_wt, n_t, F0)
    if sparse:
        carry0 += (topics, counts)
    carry, _ = lax.scan(step, carry0,
                        (jnp.arange(n, dtype=jnp.int32), u))
    return carry if sparse else carry[:5]


def fused_sweep_cells_ref(tok_doc, tok_wrd, tok_valid, tok_bound, z, u,
                          n_td, n_wt, n_t, *, alpha, beta, beta_bar,
                          cell_start=0, num_cells=None,
                          r_mode="dense", r_cap=None,
                          topics=None, counts=None):
    """Oracle for the cell-batch kernel: the k cells swept one after another
    with ``n_td``/``n_t``/``F`` carried through — same signature/returns as
    ``fused_sweep_cells_pallas`` (tok_* (k, L); n_wt (k, J, T)).

    ``cell_start``/``num_cells`` mirror ``ops.fused_sweep_cells``'s
    sub-queue restriction: only cells ``[cell_start, cell_start+num_cells)``
    are swept and returned.  ``r_mode="sparse"`` threads the doc-side
    tables across cells and appends them to the return."""
    k_total = tok_doc.shape[0]
    sparse = r_mode == "sparse"
    if num_cells is None:
        num_cells = k_total - cell_start
    T = n_t.shape[-1]
    cap = T if r_cap is None else int(r_cap)
    if sparse and topics is None:
        topics, counts = rbucket.build_side_table(n_td, cap)
    z_rows, nwt_rows = [], []
    F = jnp.zeros((2 * T,), F32)
    for c in range(cell_start, cell_start + num_cells):
        out = fused_sweep_ref(
            tok_doc[c], tok_wrd[c], tok_valid[c], tok_bound[c], z[c], u[c],
            n_td, n_wt[c], n_t, alpha=alpha, beta=beta, beta_bar=beta_bar,
            F0=F, r_mode=r_mode, r_cap=cap, topics=topics, counts=counts)
        if sparse:
            z_c, n_td, nwt_c, n_t, F, topics, counts = out
        else:
            z_c, n_td, nwt_c, n_t, F = out
        z_rows.append(z_c)
        nwt_rows.append(nwt_c)
    if not z_rows:
        out = (z[:0], n_td, n_wt[:0], n_t, F)
        return out + ((topics, counts) if sparse else ())
    out = (jnp.stack(z_rows), n_td, jnp.stack(nwt_rows), n_t, F)
    return out + ((topics, counts) if sparse else ())


def fused_sweep_ragged_ref(tok_doc, tok_wrd, tok_valid, tok_bound, z, u,
                           cell_of_tile, n_td, n_wt, n_t, *,
                           alpha, beta, beta_bar, n_blk,
                           tile_start=0, num_tiles=None,
                           cell_start=0, num_cells=None,
                           r_mode="dense", r_cap=None,
                           topics=None, counts=None):
    """Oracle for the ragged-stream kernel — same signature/returns as
    ``ops.fused_sweep_ragged`` (tok_* (S,); cell_of_tile (S//n_blk,);
    n_wt (k, J, T)).

    The paged per-cell blocks are emulated by flattening the queue to one
    ``(k·J, T)`` table and addressing rows at ``cell·J + tok_wrd`` — the
    same rows, touched by the same float ops in the same order, so the
    kernel is pinned bit-for-bit."""
    k_total, J, T = n_wt.shape
    sparse = r_mode == "sparse"
    cap = T if r_cap is None else int(r_cap)
    if sparse and topics is None:
        topics, counts = rbucket.build_side_table(n_td, cap)
    r_total = cell_of_tile.shape[0]
    nt_ = r_total - tile_start if num_tiles is None else int(num_tiles)
    nc = k_total - cell_start if num_cells is None else int(num_cells)
    lo, hi = tile_start * n_blk, (tile_start + nt_) * n_blk
    sub = lambda a: a[lo:hi]
    cot = cell_of_tile[tile_start:tile_start + nt_] - cell_start
    nwt_sub = n_wt[cell_start:cell_start + nc]
    if nt_ == 0 or nc == 0:
        out = (z[:0], n_td, nwt_sub[:0], n_t, jnp.zeros((2 * T,), F32))
        return out + ((topics, counts) if sparse else ())
    cell_tok = jnp.repeat(cot, n_blk, total_repeat_length=nt_ * n_blk)
    wrd_flat = cell_tok * J + sub(tok_wrd)
    out = fused_sweep_ref(
        sub(tok_doc), wrd_flat, sub(tok_valid), sub(tok_bound),
        sub(z), sub(u), n_td, nwt_sub.reshape(nc * J, T), n_t,
        alpha=alpha, beta=beta, beta_bar=beta_bar,
        r_mode=r_mode, r_cap=cap, topics=topics, counts=counts)
    if sparse:
        z_s, n_td, nwt_flat, n_t, F, topics, counts = out
        return (z_s, n_td, nwt_flat.reshape(nc, J, T), n_t, F,
                topics, counts)
    z_s, n_td, nwt_flat, n_t, F = out
    return z_s, n_td, nwt_flat.reshape(nc, J, T), n_t, F
