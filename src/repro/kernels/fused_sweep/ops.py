"""Public wrapper: padding, dtype plumbing and VMEM budgeting for the
fused F+LDA sweep kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_sweep.fused_sweep import (N_BLK,
                                                   fused_sweep_cells_pallas,
                                                   fused_sweep_pallas,
                                                   fused_sweep_ragged_pallas)

# Soft ceiling for the compiled path: the count tables + tree + one token
# tile must fit on-chip (~16 MiB/core, leave headroom for double buffers).
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def default_interpret() -> bool:
    """Pallas interpret-mode default: compiled on TPU, interpreted elsewhere.

    The kernels target the TPU memory hierarchy; on CPU/GPU backends the
    interpreter is the only correct way to run them.
    """
    return jax.default_backend() != "tpu"


def fused_sweep_tokens(tok_doc: jax.Array, tok_wrd: jax.Array,
                       tok_valid: jax.Array, tok_bound: jax.Array,
                       z: jax.Array, u: jax.Array,
                       n_td: jax.Array, n_wt: jax.Array, n_t: jax.Array, *,
                       alpha: float, beta: float, beta_bar: float,
                       n_blk: int = N_BLK, interpret: bool = True):
    """Fused word-by-word F+LDA sweep over an arbitrary-length token stream.

    Pads the stream to a multiple of ``n_blk`` with masked no-op tokens,
    runs the single-``pallas_call`` kernel, and unpads.  Returns
    ``(z', n_td', n_wt', n_t', F)`` where ``F`` is the final F+tree.
    """
    I, T = n_td.shape
    J = n_wt.shape[0]
    if not _is_pow2(T):
        raise ValueError(f"fused sweep needs a power-of-two T, got {T}")
    n = tok_doc.shape[0]
    if n == 0:
        return (z, n_td, n_wt, n_t,
                jnp.zeros((2 * T,), jnp.float32))
    if not interpret:
        # Whole-array in_specs AND out_specs each get their own VMEM buffer:
        # two copies of every count table, one tree output, plus the six
        # tiled input streams and the z output tile.
        vmem = 2 * 4 * (I * T + J * T + T) + 4 * 2 * T + 7 * 4 * n_blk
        if vmem > VMEM_BUDGET_BYTES:
            raise ValueError(
                f"fused sweep state ({vmem / 2**20:.1f} MiB) exceeds the "
                f"VMEM budget; shard n_td/n_wt (nomad cells) or use "
                f"backend='scan'")

    n_pad = -n % n_blk
    pad_i = lambda a: jnp.pad(a.astype(jnp.int32), (0, n_pad))
    tok_doc, tok_wrd, z = pad_i(tok_doc), pad_i(tok_wrd), pad_i(z)
    tok_valid = jnp.pad(tok_valid.astype(jnp.int32), (0, n_pad))
    tok_bound = jnp.pad(tok_bound.astype(jnp.int32), (0, n_pad))
    u = jnp.pad(u.astype(jnp.float32), (0, n_pad))

    z_out, n_td, n_wt, n_t, F = fused_sweep_pallas(
        tok_doc, tok_wrd, tok_valid, tok_bound, z, u,
        n_td.astype(jnp.int32), n_wt.astype(jnp.int32),
        n_t.astype(jnp.int32),
        alpha=float(alpha), beta=float(beta), beta_bar=float(beta_bar),
        n_blk=n_blk, interpret=interpret)
    return z_out[:n], n_td, n_wt, n_t, F


def fused_sweep_cells(tok_doc: jax.Array, tok_wrd: jax.Array,
                      tok_valid: jax.Array, tok_bound: jax.Array,
                      z: jax.Array, u: jax.Array,
                      n_td: jax.Array, n_wt: jax.Array, n_t: jax.Array, *,
                      alpha: float, beta: float, beta_bar: float,
                      cell_start: int = 0, num_cells: int | None = None,
                      n_blk: int = N_BLK, interpret: bool = True):
    """Fused F+LDA sweep over a batch of ``k`` padded cells in ONE kernel.

    This is the nomad hot path: ``tok_* / z / u`` are ``(k, L)`` — one row
    per cell of a worker's per-round block queue — and ``n_wt`` is
    ``(k, J, T)``, the queue's word-topic blocks.  The kernel's grid is
    ``(k, tiles)``: cells run in sequence, the word-topic block is paged per
    cell, and ``n_td``/``n_t``/the F+tree carry across cells, so the result
    is chain-identical to sweeping the cells one after another.

    ``cell_start``/``num_cells`` (static) restrict the call to the
    sub-queue ``[cell_start, cell_start + num_cells)``: the kernel grid
    shrinks to ``(num_cells, tiles)`` and the returned ``z'``/``n_wt'``
    cover only that range (leading dim ``num_cells``).  The pipelined ring
    (``core/nomad.py``, ``ring_mode="pipelined"``) uses this to sweep a
    half-queue per call; because every cell's first valid token is a word
    boundary (which rebuilds the F+tree from the incoming block), splitting
    a queue across calls is chain-identical to one whole-queue call.

    Pads ``L`` to a multiple of ``n_blk`` with masked no-op tokens and
    unpads.  Returns ``(z', n_td', n_wt', n_t', F)``.
    """
    I, T = n_td.shape
    k_total, J = n_wt.shape[0], n_wt.shape[1]
    if not _is_pow2(T):
        raise ValueError(f"fused sweep needs a power-of-two T, got {T}")
    if tok_doc.shape[0] != k_total:
        raise ValueError(f"queue length mismatch: tokens have "
                         f"{tok_doc.shape[0]} cells, n_wt has {k_total} "
                         f"blocks")
    cell_start = int(cell_start)
    k = k_total - cell_start if num_cells is None else int(num_cells)
    if cell_start < 0 or k < 0 or cell_start + k > k_total:
        raise ValueError(
            f"cell range [{cell_start}, {cell_start + k}) outside the "
            f"{k_total}-cell queue")
    if (cell_start, k) != (0, k_total):
        sub = lambda a: a[cell_start:cell_start + k]
        tok_doc, tok_wrd = sub(tok_doc), sub(tok_wrd)
        tok_valid, tok_bound = sub(tok_valid), sub(tok_bound)
        z, u, n_wt = sub(z), sub(u), sub(n_wt)
    L = tok_doc.shape[1]
    if k == 0 or L == 0:
        return z, n_td, n_wt, n_t, jnp.zeros((2 * T,), jnp.float32)
    if not interpret:
        # Whole-array n_td in+out, ONE (J,T) word-topic block in+out (the
        # queue is paged per cell), tree output, token tiles.
        vmem = 2 * 4 * (I * T + J * T + T) + 4 * 2 * T + 7 * 4 * n_blk
        if vmem > VMEM_BUDGET_BYTES:
            raise ValueError(
                f"fused cell-batch state ({vmem / 2**20:.1f} MiB) exceeds "
                f"the VMEM budget; shard docs/vocab into smaller nomad "
                f"cells or use inner_mode='scan'")

    n_pad = -L % n_blk
    pad_i = lambda a: jnp.pad(a.astype(jnp.int32), ((0, 0), (0, n_pad)))
    tok_doc, tok_wrd, z_p = pad_i(tok_doc), pad_i(tok_wrd), pad_i(z)
    tok_valid = jnp.pad(tok_valid.astype(jnp.int32), ((0, 0), (0, n_pad)))
    tok_bound = jnp.pad(tok_bound.astype(jnp.int32), ((0, 0), (0, n_pad)))
    u = jnp.pad(u.astype(jnp.float32), ((0, 0), (0, n_pad)))

    z_out, n_td, n_wt, n_t, F = fused_sweep_cells_pallas(
        tok_doc, tok_wrd, tok_valid, tok_bound, z_p, u,
        n_td.astype(jnp.int32), n_wt.astype(jnp.int32),
        n_t.astype(jnp.int32),
        alpha=float(alpha), beta=float(beta), beta_bar=float(beta_bar),
        n_blk=n_blk, interpret=interpret)
    return z_out[:, :L], n_td, n_wt, n_t, F


def fused_sweep_ragged(tok_doc: jax.Array, tok_wrd: jax.Array,
                       tok_valid: jax.Array, tok_bound: jax.Array,
                       z: jax.Array, u: jax.Array, cell_of_tile: jax.Array,
                       n_td: jax.Array, n_wt: jax.Array, n_t: jax.Array, *,
                       alpha: float, beta: float, beta_bar: float,
                       n_blk: int,
                       tile_start: int = 0, num_tiles: int | None = None,
                       cell_start: int = 0, num_cells: int | None = None,
                       interpret: bool = True):
    """Fused F+LDA sweep over a ragged cell stream (the nomad hot path).

    ``tok_* / z / u`` are flat ``(S,)`` streams — a worker's whole
    per-round queue with each cell padded only to the next ``n_blk``
    multiple (``NomadLayout`` ``kind="ragged"``); ``cell_of_tile`` is the
    non-decreasing ``(S // n_blk,)`` tile→cell map and ``n_wt`` is
    ``(k, J, T)``, the queue's word-topic blocks.  Grid is flat
    ``(num_tiles,)``; the map is scalar-prefetched so each tile pages the
    right block (see :func:`fused_sweep_ragged_pallas`).

    ``tile_start``/``num_tiles`` and ``cell_start``/``num_cells`` (static)
    restrict the call to a tile range and its matching cell range — the
    pipelined ring's half-queues at ``NomadLayout.tile_split``.  The tile
    range must cover every cell of ``[cell_start, cell_start+num_cells)``
    at least once (the layout builder gives every cell ≥ 1 tile) so each
    sliced ``n_wt`` block is paged through the kernel; returned
    ``z'``/``n_wt'`` cover only the requested ranges.  Returns
    ``(z', n_td', n_wt', n_t', F)``.
    """
    I, T = n_td.shape
    k_total, J = n_wt.shape[0], n_wt.shape[1]
    if not _is_pow2(T):
        raise ValueError(f"fused sweep needs a power-of-two T, got {T}")
    S = tok_doc.shape[0]
    if S % n_blk != 0 or cell_of_tile.shape[0] != S // n_blk:
        raise ValueError(
            f"ragged stream length {S} does not tile into "
            f"{cell_of_tile.shape[0]} tiles of {n_blk}")
    tile_start, cell_start = int(tile_start), int(cell_start)
    r_total = cell_of_tile.shape[0]
    nt_ = r_total - tile_start if num_tiles is None else int(num_tiles)
    nc = k_total - cell_start if num_cells is None else int(num_cells)
    if tile_start < 0 or nt_ < 0 or tile_start + nt_ > r_total:
        raise ValueError(
            f"tile range [{tile_start}, {tile_start + nt_}) outside the "
            f"{r_total}-tile stream")
    if cell_start < 0 or nc < 0 or cell_start + nc > k_total:
        raise ValueError(
            f"cell range [{cell_start}, {cell_start + nc}) outside the "
            f"{k_total}-cell queue")
    if (tile_start, nt_) != (0, r_total):
        lo, hi = tile_start * n_blk, (tile_start + nt_) * n_blk
        sub = lambda a: a[lo:hi]
        tok_doc, tok_wrd = sub(tok_doc), sub(tok_wrd)
        tok_valid, tok_bound = sub(tok_valid), sub(tok_bound)
        z, u = sub(z), sub(u)
    cot = cell_of_tile[tile_start:tile_start + nt_] - cell_start
    if (cell_start, nc) != (0, k_total):
        n_wt = n_wt[cell_start:cell_start + nc]
    if nt_ == 0 or nc == 0:
        return z, n_td, n_wt, n_t, jnp.zeros((2 * T,), jnp.float32)
    if not interpret:
        # Whole-array n_td in+out, ONE (J,T) word-topic block in+out (the
        # stream is paged per tile), tree output, token tiles.
        vmem = 2 * 4 * (I * T + J * T + T) + 4 * 2 * T + 7 * 4 * n_blk
        if vmem > VMEM_BUDGET_BYTES:
            raise ValueError(
                f"fused ragged-stream state ({vmem / 2**20:.1f} MiB) "
                f"exceeds the VMEM budget; shard docs/vocab into smaller "
                f"nomad cells or use inner_mode='scan'")

    z_out, n_td, n_wt, n_t, F = fused_sweep_ragged_pallas(
        cot.astype(jnp.int32),
        tok_doc.astype(jnp.int32), tok_wrd.astype(jnp.int32),
        tok_valid.astype(jnp.int32), tok_bound.astype(jnp.int32),
        z.astype(jnp.int32), u.astype(jnp.float32),
        n_td.astype(jnp.int32), n_wt.astype(jnp.int32),
        n_t.astype(jnp.int32),
        alpha=float(alpha), beta=float(beta), beta_bar=float(beta_bar),
        n_blk=n_blk, interpret=interpret)
    return z_out, n_td, n_wt, n_t, F
