"""Public wrapper: padding, dtype plumbing and VMEM budgeting for the
fused F+LDA sweep kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_sweep import rbucket
from repro.kernels.fused_sweep.fused_sweep import (
    N_BLK, fused_sweep_cells_docs_pallas, fused_sweep_cells_pallas,
    fused_sweep_docs_pallas, fused_sweep_pallas,
    fused_sweep_ragged_docs_pallas, fused_sweep_ragged_pallas)

# Soft ceiling for the compiled path: the count tables + tree + one token
# tile must fit on-chip (~16 MiB/core, leave headroom for double buffers).
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def fused_vmem_bytes(I: int, J: int, T: int, n_blk: int = N_BLK,
                     doc_rows: int = 0, r_cap: int = 0) -> int:
    """VMEM-resident bytes of one fused sweep call (DESIGN.md §7).

    Whole-shard mode (``doc_rows=0``) keeps the ``(I, T)`` doc-topic table
    in VMEM twice (input + output buffers); doc-tiled mode keeps a single
    ``(doc_rows, T)`` scratch slab and leaves the table in HBM.  Either
    way one ``(J, T)`` word-topic block rides in+out, plus ``n_t``, the
    F+tree output and the seven token-tile streams.  ``r_cap > 0``
    (sparse r-mode) adds the two ``(I, r_cap)`` i32 side tables, each
    riding in+out whole-VMEM (doc-tiled twins included — the tables are
    never slabbed).
    """
    ntd = 4 * doc_rows * T if doc_rows > 0 else 2 * 4 * I * T
    rb = 4 * 4 * I * r_cap if r_cap > 0 else 0
    return ntd + rb + 2 * 4 * (J * T + T) + 4 * 2 * T + 7 * 4 * n_blk


def _resolve_rmode(r_mode: str, r_cap, T: int):
    """Validate ``r_mode``/``r_cap`` → (sparse, cap)."""
    if r_mode not in ("dense", "sparse"):
        raise ValueError(f"r_mode must be 'dense' or 'sparse', got {r_mode!r}")
    cap = T if r_cap is None else int(r_cap)
    if not 1 <= cap <= T:
        raise ValueError(f"r_cap must be in [1, T={T}], got {cap}")
    return r_mode == "sparse", cap


def _side_tables(sparse, topics, counts, n_td, cap):
    """Auto-build (or cast) the sparse-mode side tables; (None, None) in
    dense mode."""
    if not sparse:
        if topics is not None or counts is not None:
            raise ValueError("topics/counts side tables passed with "
                             "r_mode='dense'")
        return None, None
    if topics is None:
        return rbucket.build_side_table(n_td.astype(jnp.int32), cap)
    return topics.astype(jnp.int32), counts.astype(jnp.int32)


def _check_doc_args(doc_tile_of, doc_rows: int, shape) -> None:
    if (doc_tile_of is None) != (doc_rows <= 0):
        raise ValueError(
            "doc tiling needs both doc_tile_of and doc_rows > 0 "
            f"(got doc_rows={doc_rows}, "
            f"doc_tile_of={'set' if doc_tile_of is not None else None})")
    if doc_tile_of is not None and tuple(doc_tile_of.shape) != tuple(shape):
        raise ValueError(
            f"doc_tile_of shape {tuple(doc_tile_of.shape)} does not match "
            f"the {tuple(shape)} token-tile grid")


def _pad_doc_slabs(n_td, doc_rows: int):
    """Pad the doc-topic table to a whole number of ``doc_rows`` slabs so
    slab DMAs never run off the end; the pad rows are untouched (no token
    addresses them) and are stripped on return."""
    I = n_td.shape[0]
    pad = -I % doc_rows
    if pad:
        n_td = jnp.pad(n_td, ((0, pad), (0, 0)))
    return n_td, I


def default_interpret() -> bool:
    """Pallas interpret-mode default: compiled on TPU, interpreted elsewhere.

    The kernels target the TPU memory hierarchy; on CPU/GPU backends the
    interpreter is the only correct way to run them.
    """
    return jax.default_backend() != "tpu"


def fused_sweep_tokens(tok_doc: jax.Array, tok_wrd: jax.Array,
                       tok_valid: jax.Array, tok_bound: jax.Array,
                       z: jax.Array, u: jax.Array,
                       n_td: jax.Array, n_wt: jax.Array, n_t: jax.Array, *,
                       alpha: float, beta: float, beta_bar: float,
                       doc_tile_of: jax.Array | None = None,
                       doc_rows: int = 0,
                       r_mode: str = "dense", r_cap: int | None = None,
                       topics: jax.Array | None = None,
                       counts: jax.Array | None = None,
                       n_blk: int = N_BLK, interpret: bool = True):
    """Fused word-by-word F+LDA sweep over an arbitrary-length token stream.

    Pads the stream to a multiple of ``n_blk`` with masked no-op tokens,
    runs the single-``pallas_call`` kernel, and unpads.  Returns
    ``(z', n_td', n_wt', n_t', F)`` where ``F`` is the final F+tree.

    ``doc_tile_of``/``doc_rows`` switch to the doc-tiled kernel: the
    stream must already be a whole number of ``n_blk`` tiles, each tile
    addressing doc rows of slab ``doc_tile_of[tile]`` only (the
    ``build_layout(doc_tile=...)`` grouped order); ``n_td`` stays in HBM
    and only one ``(doc_rows, T)`` slab is VMEM-resident.

    ``r_mode="sparse"`` maintains the per-doc ``(topics, counts)`` side
    tables ((I, r_cap) i32, built from ``n_td`` when not passed) instead
    of recomputing the compacted r-vector per token; the tables are
    returned appended — a 7-tuple.  ``r_cap`` defaults to ``T`` and is
    chain-affecting (see :mod:`repro.kernels.fused_sweep.rbucket`).
    """
    I, T = n_td.shape
    J = n_wt.shape[0]
    if not _is_pow2(T):
        raise ValueError(f"fused sweep needs a power-of-two T, got {T}")
    sparse, cap = _resolve_rmode(r_mode, r_cap, T)
    topics, counts = _side_tables(sparse, topics, counts, n_td, cap)
    n = tok_doc.shape[0]
    if n == 0:
        out = (z, n_td, n_wt, n_t, jnp.zeros((2 * T,), jnp.float32))
        return out + ((topics, counts) if sparse else ())
    docs = doc_tile_of is not None
    if docs and n % n_blk != 0:
        raise ValueError(
            f"doc-tiled stream length {n} is not a whole number of "
            f"{n_blk}-token tiles (the slab map is per tile)")
    _check_doc_args(doc_tile_of, doc_rows, (n // n_blk,) if docs else None)
    if not interpret:
        # Whole-array in_specs AND out_specs each get their own VMEM buffer:
        # two copies of every count table, one tree output, plus the six
        # tiled input streams and the z output tile (doc-tiled: one slab
        # scratch instead of the two n_td copies).
        vmem = fused_vmem_bytes(I, J, T, n_blk,
                                doc_rows if docs else 0,
                                cap if sparse else 0)
        if vmem > VMEM_BUDGET_BYTES:
            raise ValueError(
                f"fused sweep state ({vmem / 2**20:.1f} MiB) exceeds the "
                f"VMEM budget; shard n_td/n_wt (nomad cells), tile the "
                f"doc axis (build_layout doc_tile) or use backend='scan'")

    n_pad = -n % n_blk
    pad_i = lambda a: jnp.pad(a.astype(jnp.int32), (0, n_pad))
    tok_doc, tok_wrd, z_p = pad_i(tok_doc), pad_i(tok_wrd), pad_i(z)
    tok_valid = jnp.pad(tok_valid.astype(jnp.int32), (0, n_pad))
    tok_bound = jnp.pad(tok_bound.astype(jnp.int32), (0, n_pad))
    u = jnp.pad(u.astype(jnp.float32), (0, n_pad))

    kw = dict(alpha=float(alpha), beta=float(beta),
              beta_bar=float(beta_bar), n_blk=n_blk, interpret=interpret)
    kw["r_cap"] = cap
    if sparse:
        kw.update(topics=topics, counts=counts)
    if docs:
        n_td_p, I = _pad_doc_slabs(n_td.astype(jnp.int32), doc_rows)
        out = fused_sweep_docs_pallas(
            doc_tile_of.astype(jnp.int32),
            tok_doc, tok_wrd, tok_valid, tok_bound, z_p, u,
            n_td_p, n_wt.astype(jnp.int32), n_t.astype(jnp.int32),
            doc_rows=int(doc_rows), **kw)
        z_out, n_td, n_wt, n_t, F = out[:5]
        return (z_out[:n], n_td[:I], n_wt, n_t, F) + tuple(out[5:])
    out = fused_sweep_pallas(
        tok_doc, tok_wrd, tok_valid, tok_bound, z_p, u,
        n_td.astype(jnp.int32), n_wt.astype(jnp.int32),
        n_t.astype(jnp.int32), **kw)
    z_out = out[0]
    return (z_out[:n],) + tuple(out[1:])


def fused_sweep_cells(tok_doc: jax.Array, tok_wrd: jax.Array,
                      tok_valid: jax.Array, tok_bound: jax.Array,
                      z: jax.Array, u: jax.Array,
                      n_td: jax.Array, n_wt: jax.Array, n_t: jax.Array, *,
                      alpha: float, beta: float, beta_bar: float,
                      cell_start: int = 0, num_cells: int | None = None,
                      doc_tile_of: jax.Array | None = None,
                      doc_rows: int = 0,
                      r_mode: str = "dense", r_cap: int | None = None,
                      topics: jax.Array | None = None,
                      counts: jax.Array | None = None,
                      n_blk: int = N_BLK, interpret: bool = True):
    """Fused F+LDA sweep over a batch of ``k`` padded cells in ONE kernel.

    This is the nomad hot path: ``tok_* / z / u`` are ``(k, L)`` — one row
    per cell of a worker's per-round block queue — and ``n_wt`` is
    ``(k, J, T)``, the queue's word-topic blocks.  The kernel's grid is
    ``(k, tiles)``: cells run in sequence, the word-topic block is paged per
    cell, and ``n_td``/``n_t``/the F+tree carry across cells, so the result
    is chain-identical to sweeping the cells one after another.

    ``cell_start``/``num_cells`` (static) restrict the call to the
    sub-queue ``[cell_start, cell_start + num_cells)``: the kernel grid
    shrinks to ``(num_cells, tiles)`` and the returned ``z'``/``n_wt'``
    cover only that range (leading dim ``num_cells``).  The pipelined ring
    (``core/nomad.py``, ``ring_mode="pipelined"``) uses this to sweep a
    half-queue per call; because every cell's first valid token is a word
    boundary (which rebuilds the F+tree from the incoming block), splitting
    a queue across calls is chain-identical to one whole-queue call.

    Pads ``L`` to a multiple of ``n_blk`` with masked no-op tokens and
    unpads.  ``doc_tile_of`` ((k, L // n_blk), with ``L`` already tiled)
    + ``doc_rows`` switch to the doc-tiled kernel (see
    :func:`fused_sweep_tokens`); the map is sliced along the cell range
    with the queue.  Returns ``(z', n_td', n_wt', n_t', F)``, plus the
    ``(topics, counts)`` side tables appended under ``r_mode="sparse"``
    (see :func:`fused_sweep_tokens`; the tables span the whole doc shard
    and are never sliced with the cell range).
    """
    I, T = n_td.shape
    k_total, J = n_wt.shape[0], n_wt.shape[1]
    if not _is_pow2(T):
        raise ValueError(f"fused sweep needs a power-of-two T, got {T}")
    sparse, cap = _resolve_rmode(r_mode, r_cap, T)
    topics, counts = _side_tables(sparse, topics, counts, n_td, cap)
    if tok_doc.shape[0] != k_total:
        raise ValueError(f"queue length mismatch: tokens have "
                         f"{tok_doc.shape[0]} cells, n_wt has {k_total} "
                         f"blocks")
    docs = doc_tile_of is not None
    if docs and tok_doc.shape[1] % n_blk != 0:
        raise ValueError(
            f"doc-tiled cell rows of {tok_doc.shape[1]} tokens are not a "
            f"whole number of {n_blk}-token tiles (the slab map is per "
            f"tile)")
    _check_doc_args(doc_tile_of, doc_rows,
                    (k_total, tok_doc.shape[1] // n_blk) if docs else None)
    cell_start = int(cell_start)
    k = k_total - cell_start if num_cells is None else int(num_cells)
    if cell_start < 0 or k < 0 or cell_start + k > k_total:
        raise ValueError(
            f"cell range [{cell_start}, {cell_start + k}) outside the "
            f"{k_total}-cell queue")
    if (cell_start, k) != (0, k_total):
        sub = lambda a: a[cell_start:cell_start + k]
        tok_doc, tok_wrd = sub(tok_doc), sub(tok_wrd)
        tok_valid, tok_bound = sub(tok_valid), sub(tok_bound)
        z, u, n_wt = sub(z), sub(u), sub(n_wt)
        if docs:
            doc_tile_of = sub(doc_tile_of)
    L = tok_doc.shape[1]
    if k == 0 or L == 0:
        out = (z, n_td, n_wt, n_t, jnp.zeros((2 * T,), jnp.float32))
        return out + ((topics, counts) if sparse else ())
    if not interpret:
        # Whole-array n_td in+out (or one slab scratch when doc-tiled),
        # ONE (J,T) word-topic block in+out (the queue is paged per
        # cell), tree output, token tiles.
        vmem = fused_vmem_bytes(I, J, T, n_blk, doc_rows if docs else 0,
                                cap if sparse else 0)
        if vmem > VMEM_BUDGET_BYTES:
            raise ValueError(
                f"fused cell-batch state ({vmem / 2**20:.1f} MiB) exceeds "
                f"the VMEM budget; shard docs/vocab into smaller nomad "
                f"cells, tile the doc axis (build_layout doc_tile) or use "
                f"inner_mode='scan'")

    n_pad = -L % n_blk
    pad_i = lambda a: jnp.pad(a.astype(jnp.int32), ((0, 0), (0, n_pad)))
    tok_doc, tok_wrd, z_p = pad_i(tok_doc), pad_i(tok_wrd), pad_i(z)
    tok_valid = jnp.pad(tok_valid.astype(jnp.int32), ((0, 0), (0, n_pad)))
    tok_bound = jnp.pad(tok_bound.astype(jnp.int32), ((0, 0), (0, n_pad)))
    u = jnp.pad(u.astype(jnp.float32), ((0, 0), (0, n_pad)))

    kw = dict(alpha=float(alpha), beta=float(beta),
              beta_bar=float(beta_bar), n_blk=n_blk, interpret=interpret)
    kw["r_cap"] = cap
    if sparse:
        kw.update(topics=topics, counts=counts)
    if docs:
        n_td_p, I = _pad_doc_slabs(n_td.astype(jnp.int32), doc_rows)
        out = fused_sweep_cells_docs_pallas(
            doc_tile_of.astype(jnp.int32),
            tok_doc, tok_wrd, tok_valid, tok_bound, z_p, u,
            n_td_p, n_wt.astype(jnp.int32), n_t.astype(jnp.int32),
            doc_rows=int(doc_rows), **kw)
        z_out, n_td, n_wt, n_t, F = out[:5]
        return (z_out[:, :L], n_td[:I], n_wt, n_t, F) + tuple(out[5:])
    out = fused_sweep_cells_pallas(
        tok_doc, tok_wrd, tok_valid, tok_bound, z_p, u,
        n_td.astype(jnp.int32), n_wt.astype(jnp.int32),
        n_t.astype(jnp.int32), **kw)
    return (out[0][:, :L],) + tuple(out[1:])


def fused_sweep_ragged(tok_doc: jax.Array, tok_wrd: jax.Array,
                       tok_valid: jax.Array, tok_bound: jax.Array,
                       z: jax.Array, u: jax.Array, cell_of_tile: jax.Array,
                       n_td: jax.Array, n_wt: jax.Array, n_t: jax.Array, *,
                       alpha: float, beta: float, beta_bar: float,
                       n_blk: int,
                       tile_start: int = 0, num_tiles: int | None = None,
                       cell_start: int = 0, num_cells: int | None = None,
                       doc_tile_of: jax.Array | None = None,
                       doc_rows: int = 0,
                       r_mode: str = "dense", r_cap: int | None = None,
                       topics: jax.Array | None = None,
                       counts: jax.Array | None = None,
                       interpret: bool = True):
    """Fused F+LDA sweep over a ragged cell stream (the nomad hot path).

    ``tok_* / z / u`` are flat ``(S,)`` streams — a worker's whole
    per-round queue with each cell padded only to the next ``n_blk``
    multiple (``NomadLayout`` ``kind="ragged"``); ``cell_of_tile`` is the
    non-decreasing ``(S // n_blk,)`` tile→cell map and ``n_wt`` is
    ``(k, J, T)``, the queue's word-topic blocks.  Grid is flat
    ``(num_tiles,)``; the map is scalar-prefetched so each tile pages the
    right block (see :func:`fused_sweep_ragged_pallas`).

    ``tile_start``/``num_tiles`` and ``cell_start``/``num_cells`` (static)
    restrict the call to a tile range and its matching cell range — the
    pipelined ring's half-queues at ``NomadLayout.tile_split``.  The tile
    range must cover every cell of ``[cell_start, cell_start+num_cells)``
    at least once (the layout builder gives every cell ≥ 1 tile) so each
    sliced ``n_wt`` block is paged through the kernel; returned
    ``z'``/``n_wt'`` cover only the requested ranges.  ``doc_tile_of``
    ((S // n_blk,), sliced with the tile range) + ``doc_rows`` switch to
    the doc-tiled kernel (see :func:`fused_sweep_tokens`).  Returns
    ``(z', n_td', n_wt', n_t', F)``, plus the ``(topics, counts)`` side
    tables appended under ``r_mode="sparse"`` (whole doc shard, never
    sliced with the tile/cell ranges).
    """
    I, T = n_td.shape
    k_total, J = n_wt.shape[0], n_wt.shape[1]
    if not _is_pow2(T):
        raise ValueError(f"fused sweep needs a power-of-two T, got {T}")
    sparse, cap = _resolve_rmode(r_mode, r_cap, T)
    topics, counts = _side_tables(sparse, topics, counts, n_td, cap)
    S = tok_doc.shape[0]
    if S % n_blk != 0 or cell_of_tile.shape[0] != S // n_blk:
        raise ValueError(
            f"ragged stream length {S} does not tile into "
            f"{cell_of_tile.shape[0]} tiles of {n_blk}")
    docs = doc_tile_of is not None
    _check_doc_args(doc_tile_of, doc_rows, (S // n_blk,) if docs else None)
    tile_start, cell_start = int(tile_start), int(cell_start)
    r_total = cell_of_tile.shape[0]
    nt_ = r_total - tile_start if num_tiles is None else int(num_tiles)
    nc = k_total - cell_start if num_cells is None else int(num_cells)
    if tile_start < 0 or nt_ < 0 or tile_start + nt_ > r_total:
        raise ValueError(
            f"tile range [{tile_start}, {tile_start + nt_}) outside the "
            f"{r_total}-tile stream")
    if cell_start < 0 or nc < 0 or cell_start + nc > k_total:
        raise ValueError(
            f"cell range [{cell_start}, {cell_start + nc}) outside the "
            f"{k_total}-cell queue")
    if (tile_start, nt_) != (0, r_total):
        lo, hi = tile_start * n_blk, (tile_start + nt_) * n_blk
        sub = lambda a: a[lo:hi]
        tok_doc, tok_wrd = sub(tok_doc), sub(tok_wrd)
        tok_valid, tok_bound = sub(tok_valid), sub(tok_bound)
        z, u = sub(z), sub(u)
    cot = cell_of_tile[tile_start:tile_start + nt_] - cell_start
    if docs:
        doc_tile_of = doc_tile_of[tile_start:tile_start + nt_]
    if (cell_start, nc) != (0, k_total):
        n_wt = n_wt[cell_start:cell_start + nc]
    if nt_ == 0 or nc == 0:
        out = (z, n_td, n_wt, n_t, jnp.zeros((2 * T,), jnp.float32))
        return out + ((topics, counts) if sparse else ())
    if not interpret:
        # Whole-array n_td in+out (or one slab scratch when doc-tiled),
        # ONE (J,T) word-topic block in+out (the stream is paged per
        # tile), tree output, token tiles.
        vmem = fused_vmem_bytes(I, J, T, n_blk, doc_rows if docs else 0,
                                cap if sparse else 0)
        if vmem > VMEM_BUDGET_BYTES:
            raise ValueError(
                f"fused ragged-stream state ({vmem / 2**20:.1f} MiB) "
                f"exceeds the VMEM budget; shard docs/vocab into smaller "
                f"nomad cells, tile the doc axis (build_layout doc_tile) "
                f"or use inner_mode='scan'")

    kw = dict(alpha=float(alpha), beta=float(beta),
              beta_bar=float(beta_bar), n_blk=n_blk, interpret=interpret)
    kw["r_cap"] = cap
    if sparse:
        kw.update(topics=topics, counts=counts)
    args = (tok_doc.astype(jnp.int32), tok_wrd.astype(jnp.int32),
            tok_valid.astype(jnp.int32), tok_bound.astype(jnp.int32),
            z.astype(jnp.int32), u.astype(jnp.float32))
    if docs:
        n_td_p, I = _pad_doc_slabs(n_td.astype(jnp.int32), doc_rows)
        out = fused_sweep_ragged_docs_pallas(
            cot.astype(jnp.int32), doc_tile_of.astype(jnp.int32), *args,
            n_td_p, n_wt.astype(jnp.int32), n_t.astype(jnp.int32),
            doc_rows=int(doc_rows), **kw)
        z_out, n_td, n_wt, n_t, F = out[:5]
        return (z_out, n_td[:I], n_wt, n_t, F) + tuple(out[5:])
    return tuple(fused_sweep_ragged_pallas(
        cot.astype(jnp.int32), *args,
        n_td.astype(jnp.int32), n_wt.astype(jnp.int32),
        n_t.astype(jnp.int32), **kw))
