"""Doc-sparse r-bucket: compacted (topics, counts) side tables (paper §3).

The F+LDA conditional p = α·q + r has r_t = n_td·q_t supported on the
document's |T_d| nonzero topics with |T_d| ≪ T (the paper's complexity
argument for Alg. 3).  This module defines the **canonical r-draw** shared
by every fused-sweep implementation (the Pallas kernels and the scan
oracle): the r-term cumsum runs over a fixed-capacity compacted vector —
the document's active topics in ascending order, zero-padded to a static
capacity ``cap`` — instead of a dense ``(T,)`` vector.

Two ways to obtain the compacted vector, selected by ``r_mode``:

* ``"dense"``  — recompute it from the dense ``n_td`` row at every token
  (:func:`compact_row`): Θ(T) per token, no extra state.
* ``"sparse"`` — maintain it incrementally as a per-doc ``(topics, counts)``
  side table (:func:`decrement` / :func:`increment`): Θ(cap) per token, so
  the r-draw cost stops scaling with T.

Exactness argument: both modes operate on the *same* compacted vector —
the side table's invariant is ``(topics, counts) == compact_row(n_td[d])``
at every step, preserved by the integer-only increment/decrement — so the
float ops of the draw (``cumsum`` over ``counts·q[topics]``) are performed
on bit-identical inputs and the two chains are bit-equal by construction.
Note the compacted cumsum is **not** bit-equal to a dense ``(T,)`` cumsum
(XLA's scan is blocked/tree-associated, so dropping zeros reorders the
partial sums); that is why *both* modes draw from the compacted vector.
For the same reason the capacity is chain-affecting: runs compared
bit-for-bit must share ``cap`` (the default ``cap = T`` everywhere keeps
cross-mode comparisons trivially paired).

Zero padding is exact: pad slots are ``(topic 0, count 0)`` and contribute
``0·q[0] = 0.0`` to the cumsum, and ``x + 0.0 == x`` for every finite f32,
so the padded suffix never perturbs a partial sum.

Capacity bound: ``cap = min(T, max_d len(d))`` suffices — a document of
``n`` tokens holds at most ``n`` distinct topics, and at increment time the
document holds ``n − 1`` assigned tokens, so either the incoming topic is
already present or the table has a free slot (``NomadLayout.r_cap``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

F32 = jnp.float32

__all__ = ["compact_row", "build_side_table", "decrement", "increment",
           "r_cumsum", "pick"]


def compact_row(row, cap: int):
    """Compact a dense ``(T,)`` count row into capacity-``cap`` parallel
    ``(topics, counts)`` int32 vectors: active topics ascending, padded
    with ``(0, 0)`` slots.  Active topics beyond ``cap`` are dropped
    (never happens under the layout's capacity bound)."""
    T = row.shape[-1]
    row = row.astype(jnp.int32)
    active = row > 0
    rank = jnp.cumsum(active.astype(jnp.int32)) - 1
    pos = jnp.where(active, rank, cap)                   # inactive → dropped
    topics = jnp.zeros((cap,), jnp.int32).at[pos].set(
        jnp.arange(T, dtype=jnp.int32), mode="drop")
    counts = jnp.zeros((cap,), jnp.int32).at[pos].set(row, mode="drop")
    return topics, counts


def build_side_table(n_td, cap: int):
    """Per-doc side tables for a whole ``(I, T)`` doc-topic table:
    returns ``(topics, counts)``, each ``(I, cap)`` int32."""
    return jax.vmap(functools.partial(compact_row, cap=cap))(n_td)


def decrement(topics, counts, t, valid):
    """Remove one occurrence of topic ``t`` from the table (no-op when
    ``valid`` is False).  ``t`` must be present with count ≥ 1 for a valid
    token (it is the token's current assignment); a count reaching zero
    shifts the tail left so active entries stay packed and ascending."""
    cap = topics.shape[0]
    j = jnp.arange(cap, dtype=jnp.int32)
    pos = jnp.sum(((topics < t) & (counts > 0)).astype(jnp.int32))
    newc = counts[pos] - 1
    remove = newc == 0
    t_next = jnp.concatenate([topics[1:], jnp.zeros((1,), jnp.int32)])
    c_next = jnp.concatenate([counts[1:], jnp.zeros((1,), jnp.int32)])
    topics2 = jnp.where(remove & (j >= pos), t_next, topics)
    counts2 = jnp.where(remove,
                        jnp.where(j >= pos, c_next, counts),
                        jnp.where(j == pos, newc, counts))
    return (jnp.where(valid, topics2, topics),
            jnp.where(valid, counts2, counts))


def increment(topics, counts, t, valid):
    """Add one occurrence of topic ``t`` (no-op when ``valid`` is False):
    bump the count if present, else shift the tail right and insert
    ``(t, 1)`` at its ascending position (a free slot exists under the
    capacity bound — see module docstring)."""
    cap = topics.shape[0]
    j = jnp.arange(cap, dtype=jnp.int32)
    pos = jnp.sum(((topics < t) & (counts > 0)).astype(jnp.int32))
    present = (counts[pos] > 0) & (topics[pos] == t)
    t_prev = jnp.concatenate([jnp.zeros((1,), jnp.int32), topics[:-1]])
    c_prev = jnp.concatenate([jnp.zeros((1,), jnp.int32), counts[:-1]])
    ins_t = jnp.where(j > pos, t_prev, jnp.where(j == pos, t, topics))
    ins_c = jnp.where(j > pos, c_prev, jnp.where(j == pos, 1, counts))
    topics2 = jnp.where(present, topics, ins_t)
    counts2 = jnp.where(present,
                        jnp.where(j == pos, counts + 1, counts), ins_c)
    return (jnp.where(valid, topics2, topics),
            jnp.where(valid, counts2, counts))


def r_cumsum(topics, counts, q):
    """Cumulative r-bucket masses over the compacted vector:
    ``cumsum(counts · q[topics])`` (pad slots contribute exactly 0.0)."""
    return jnp.cumsum(counts.astype(F32) * q[topics])


def pick(topics, counts, c, u_val):
    """Zero-mass-aware LSearch on the compacted cumsum: the drawn slot is
    ``#{c ≤ u_val}``, guarded to the last active slot so a boundary-rounded
    ``u_val`` can never land on a zero-count pad (when ``u_val < c[-1]``
    the guard is a no-op: pad entries all equal ``c[-1]``)."""
    m = jnp.sum((counts > 0).astype(jnp.int32))
    j_r = jnp.minimum(jnp.sum((c <= u_val).astype(jnp.int32)),
                      jnp.maximum(m - 1, 0))
    return topics[j_r]
