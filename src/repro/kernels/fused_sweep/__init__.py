from repro.kernels.fused_sweep.ops import (default_interpret,  # noqa: F401
                                           fused_sweep_cells,
                                           fused_sweep_ragged,
                                           fused_sweep_tokens,
                                           fused_vmem_bytes)
