from repro.kernels.fused_sweep.ops import fused_sweep_tokens  # noqa: F401
