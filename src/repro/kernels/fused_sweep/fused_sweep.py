"""Fused F+LDA token-sweep kernel (paper Alg. 3, whole inner loop on-chip).

The ``lax.scan`` sweeps in :mod:`repro.core.cgs` and :mod:`repro.core.nomad`
honour the exact Gibbs chain but pay for it in memory traffic: every token
re-reads and re-writes its count rows and the F+tree through HBM, and each
scan step is its own XLA while-loop iteration.  This kernel fuses the whole
word-by-word sweep (decrement → F.update → q/r two-level draw → increment →
F.update) into **one** ``pallas_call``:

* the F+tree (``2T`` f32) and the global topic counts ``n_t`` (``T`` i32)
  stay VMEM-resident for the entire sweep — they are carried through the
  per-token ``fori_loop`` as register/VMEM values and only written back to
  the output buffers once per token tile;
* the doc-topic table ``n_td`` and the word-topic block ``n_wt`` live in
  VMEM buffers for the whole call; per token the kernel touches exactly one
  row of each via dynamic-slice load/store (``pl.ds``) — no (N, T) HBM
  intermediates are ever materialized;
* tokens are tiled over a sequential grid (``N_BLK`` per program).  The
  count/tree outputs use constant index maps, so the state persists across
  grid steps — the standard Pallas accumulator pattern — and the chain is
  exact across tile boundaries.

Two entry points share the tile body:

* :func:`fused_sweep_pallas` — one token stream against one word-topic
  block (the serial ``cgs`` hot path).  Grid ``(n_tiles,)``.
* :func:`fused_sweep_cells_pallas` — a *batch of k cells* (one nomad
  worker's whole per-round block queue) in a single call.  Grid
  ``(k, n_tiles)`` with the cell index leftmost, so the k cells run in
  sequence on the sequential TPU grid; ``n_td``/``n_t``/``F`` use constant
  index maps and carry across cell boundaries, while the per-cell
  word-topic block ``n_wt[c]`` is paged in/out by the BlockSpec index map —
  only one ``(J, T)`` block is VMEM-resident at a time.  Cross-cell chain
  exactness needs no special handling: a cell's first valid token is always
  a word boundary (``NomadLayout.tok_bound``), which rebuilds the tree from
  the incoming block's q vector.  The same property makes the grid freely
  *splittable*: a call over a sub-queue of ``m ≤ k`` cells (grid
  ``(m, tiles)``, see ``ops.fused_sweep_cells``'s ``cell_start`` /
  ``num_cells``) chains bit-identically with the calls for the remaining
  cells — the pipelined nomad ring sweeps half-queues this way.

* :func:`fused_sweep_ragged_pallas` — the same k-cell queue as a **ragged
  tile stream** (``NomadLayout`` ``kind="ragged"``): the dense ``(k, L)``
  grid pads every cell to the heaviest one, so the grid's token capacity
  blows up with ``B``; the ragged stream pads each cell only to its next
  tile multiple and the grid flattens to ``(n_tiles,)``.  The per-tile
  cell id rides in as a **scalar-prefetch** operand
  (``pltpu.PrefetchScalarGridSpec``): the ``n_wt`` BlockSpec index map
  reads ``cell_of_tile[t]`` to page the right ``(J, T)`` block, and the
  kernel body compares ``cell_of_tile[t]`` against ``t−1``'s to detect
  cell starts (the map is non-decreasing, so each block is paged in/out
  exactly once).  Everything else — carried ``n_td``/``n_t``/``F``,
  boundary rebuilds, masked no-op padding, splittability by tile range —
  is identical to the cell-batch grid, and the chain is bit-equal to it
  token for token.

Every entry point also has a **doc-tiled** twin (``*_docs_pallas``) that
lifts the whole-shard VMEM residency of the doc-topic table: ``n_td``
stays in ``ANY`` memory (HBM on TPU) and the kernel pages one
``(doc_rows, T)`` slab through a VMEM scratch buffer, driven by a
scalar-prefetched per-tile ``doc_tile_of`` map (``NomadLayout`` built
with ``doc_tile``, whose grouped token order guarantees each grid step
touches exactly one slab).  Slabs *recur* across cells, so BlockSpec
window paging cannot carry them (an input window re-fetch reads the
stale initial table; a revisited output window is undefined on TPU) —
instead the kernel bulk-copies the table input→output once at the first
step and then DMAs slabs in/out of the output buffer explicitly
(``pltpu.make_async_copy``): every page-in reads the accumulated counts
because every write-back went through the same buffer.  The token chain
itself is untouched — tiled and untiled execution over the same layout
are bit-identical.

Masking follows the nomad cell-sweep convention: ``valid=False`` tokens are
no-ops (count deltas of 0, leaf rewritten to itself, ``z`` kept), which is
what makes arbitrary padding of the token stream safe.  ``boundary=True``
rebuilds the tree from the incoming word's q vector; the tree starts zeroed,
so the first valid token of the stream must be a boundary (guaranteed by
``Corpus.word_boundary`` and by ``NomadLayout.tok_bound``).

Chain exactness: every float op (q rebuild, path update, cumsum, draw) is
performed by the same :mod:`repro.core.ftree` value ops and in the same
order as ``cgs.sweep_fplda_word``, so given identical uniforms the kernel
reproduces that sweep's ``z``/counts bit-for-bit (the clip/max guards are
no-ops on consistent count tables).  ``interpret=True`` is the CPU-safe
default; the compiled path targets the layout above.

The r-bucket draw is **doc-sparse** (paper §3's |T_d| ≪ T argument,
DESIGN.md §7): the r-term cumsum runs over the capacity-``r_cap``
compacted vector of the document's nonzero topics
(:mod:`repro.kernels.fused_sweep.rbucket`).  Every kernel takes a static
``r_cap`` and a ``sparse`` switch: dense mode recomputes the compaction
from the VMEM ``n_td`` row per token (Θ(T)); sparse mode maintains it as
per-doc ``(topics, counts)`` side tables — two extra ``(I, r_cap)`` i32
operands riding in/out exactly like ``n_td`` (whole-VMEM with constant
index maps, *including* in the doc-tiled twins: the tables are a factor
``T/r_cap`` smaller than the table the slab paging evicts) — making the
per-token r-draw Θ(r_cap), independent of T.  Both modes draw from the
same compacted vector, so their chains are bit-identical (the rbucket
module docstring carries the exactness argument; ``r_cap`` itself is
chain-affecting, so compared runs must share it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ftree
from repro.kernels.fused_sweep import rbucket

N_BLK = 256  # tokens per grid program

F32 = jnp.float32


def _sweep_tile(T: int, n_blk: int, r_cap: int, alpha: float, beta: float,
                beta_bar: float, tok_doc, tok_wrd, tok_valid, tok_bound,
                z_tile, u_tile, nt0, F0,
                ntd_load, ntd_store, nwt_load, nwt_store,
                rb_load=None, rb_store=None):
    """Exact Alg. 3 chain over one token tile.

    Row access to the doc-topic / word-topic tables is abstracted behind
    ``*_load(idx) -> (T,)`` / ``*_store(idx, row)`` so the single-block and
    cell-batch kernels share the float-op order exactly.  The r-bucket
    draw runs over the capacity-``r_cap`` compacted topic vector: with
    ``rb_load``/``rb_store`` unset (dense mode) it is recomputed from the
    decremented doc row per token; set, it is loaded from / stored to the
    per-doc side table (``rb_load(d) -> (topics, counts)``,
    ``rb_store(d, topics, counts)``) and maintained incrementally.
    """

    def q_of(nwt_row, nt):
        return (nwt_row.astype(F32) + beta) / (nt.astype(F32) + beta_bar)

    def body(k, carry):
        z_tile, nt, F = carry
        d, w = tok_doc[k], tok_wrd[k]
        valid, boundary = tok_valid[k] != 0, tok_bound[k] != 0
        u01 = u_tile[k]
        t_old = z_tile[k]
        one = valid.astype(jnp.int32)

        ntd_row = ntd_load(d)                         # (T,) doc-topic row
        nwt_row = nwt_load(w)                         # (T,) word-topic row

        # Word boundary: rebuild the tree for the incoming word's q vector
        # (cond, not where: the Θ(T) build must not run on interior tokens).
        F = jax.lax.cond(boundary,
                         lambda: ftree.build(q_of(nwt_row, nt)),
                         lambda: F)

        # --- decrement (Alg. 3 inner loop, masked) ------------------------
        ntd_row = ntd_row.at[t_old].add(-one)
        nwt_row = nwt_row.at[t_old].add(-one)
        nt = nt.at[t_old].add(-one)
        new_leaf = ((nwt_row[t_old].astype(F32) + beta)
                    / (nt[t_old].astype(F32) + beta_bar))
        F = ftree.set_leaf(F, t_old,
                           jnp.where(valid, new_leaf, F[T + t_old]))

        # --- two-level draw p = α·q + r (eq. (6), doc-sparse r-bucket) -----
        q = ftree.leaves(F)
        if rb_load is None:
            topics_d, counts_d = rbucket.compact_row(ntd_row, r_cap)
        else:
            topics_d, counts_d = rb_load(d)
            topics_d, counts_d = rbucket.decrement(topics_d, counts_d,
                                                   t_old, valid)
        c = rbucket.r_cumsum(topics_d, counts_d, q)
        r_mass = c[-1]
        q_total = ftree.total(F)
        norm = alpha * q_total + r_mass
        u_val = u01 * norm
        in_r = u_val < r_mass
        t_r = rbucket.pick(topics_d, counts_d, c, u_val)
        t_q = ftree.sample(F, jnp.clip((u_val - r_mass)
                                       / jnp.maximum(alpha * q_total, 1e-30),
                                       0.0, 1.0 - 1e-7))
        t_new = jnp.where(valid, jnp.where(in_r, t_r, t_q), t_old)

        # --- increment -----------------------------------------------------
        ntd_row = ntd_row.at[t_new].add(one)
        nwt_row = nwt_row.at[t_new].add(one)
        nt = nt.at[t_new].add(one)
        new_leaf2 = ((nwt_row[t_new].astype(F32) + beta)
                     / (nt[t_new].astype(F32) + beta_bar))
        F = ftree.set_leaf(F, t_new,
                           jnp.where(valid, new_leaf2, F[T + t_new]))

        if rb_store is not None:
            topics_d, counts_d = rbucket.increment(topics_d, counts_d,
                                                   t_new, valid)
            rb_store(d, topics_d, counts_d)
        ntd_store(d, ntd_row)
        nwt_store(w, nwt_row)
        z_tile = z_tile.at[k].set(t_new)
        return z_tile, nt, F

    return jax.lax.fori_loop(0, n_blk, body, (z_tile, nt0, F0))


def _rb_accessors(tpc_ref, cnt_ref):
    """Row load/store on the whole-VMEM per-doc side tables (sparse mode)."""
    def load(d):
        return (tpc_ref[pl.ds(d, 1), :][0], cnt_ref[pl.ds(d, 1), :][0])

    def store(d, topics, counts):
        tpc_ref[pl.ds(d, 1), :] = topics[None]
        cnt_ref[pl.ds(d, 1), :] = counts[None]

    return load, store


def _rb_kw(sparse, tpc_ref, cnt_ref):
    if not sparse:
        return {}
    rb_load, rb_store = _rb_accessors(tpc_ref, cnt_ref)
    return dict(rb_load=rb_load, rb_store=rb_store)


def _kernel(T: int, n_blk: int, r_cap: int, sparse: bool, alpha: float,
            beta: float, beta_bar: float, *refs):
    (tok_doc_ref, tok_wrd_ref, tok_valid_ref, tok_bound_ref,
     z_in_ref, u_ref, ntd_in_ref, nwt_in_ref, nt_in_ref) = refs[:9]
    if sparse:
        tpc_in_ref, cnt_in_ref = refs[9:11]
        z_ref, ntd_ref, nwt_ref, nt_ref, f_ref, tpc_ref, cnt_ref = refs[11:]
    else:
        tpc_ref = cnt_ref = None
        z_ref, ntd_ref, nwt_ref, nt_ref, f_ref = refs[9:]
    first = pl.program_id(0) == 0

    @pl.when(first)
    def _init():
        ntd_ref[...] = ntd_in_ref[...]
        nwt_ref[...] = nwt_in_ref[...]
        nt_ref[...] = nt_in_ref[...]
        f_ref[...] = jnp.zeros((2 * T,), F32)
        if sparse:
            tpc_ref[...] = tpc_in_ref[...]
            cnt_ref[...] = cnt_in_ref[...]

    z_tile, nt, F = _sweep_tile(
        T, n_blk, r_cap, alpha, beta, beta_bar,
        tok_doc_ref[...], tok_wrd_ref[...], tok_valid_ref[...],
        tok_bound_ref[...], z_in_ref[...], u_ref[...],
        nt_ref[...], f_ref[...],
        ntd_load=lambda d: ntd_ref[pl.ds(d, 1), :][0],
        ntd_store=lambda d, row: ntd_ref.__setitem__(
            (pl.ds(d, 1), slice(None)), row[None]),
        nwt_load=lambda w: nwt_ref[pl.ds(w, 1), :][0],
        nwt_store=lambda w, row: nwt_ref.__setitem__(
            (pl.ds(w, 1), slice(None)), row[None]),
        **_rb_kw(sparse, tpc_ref, cnt_ref))

    z_ref[...] = z_tile
    nt_ref[...] = nt
    f_ref[...] = F


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "beta_bar",
                                             "n_blk", "r_cap", "interpret"))
def fused_sweep_pallas(tok_doc: jax.Array, tok_wrd: jax.Array,
                       tok_valid: jax.Array, tok_bound: jax.Array,
                       z: jax.Array, u: jax.Array,
                       n_td: jax.Array, n_wt: jax.Array, n_t: jax.Array,
                       topics: jax.Array | None = None,
                       counts: jax.Array | None = None, *,
                       alpha: float, beta: float, beta_bar: float,
                       r_cap: int = 0,
                       n_blk: int = N_BLK, interpret: bool = True):
    """One fused F+LDA sweep over a padded token stream.

    Shapes: tok_* / z / u are (N,) with N % n_blk == 0; n_td (I, T) i32;
    n_wt (J, T) i32; n_t (T,) i32; T a power of two.  Returns
    (z', n_td', n_wt', n_t', F) with F the final F+tree (2T,) f32.

    ``r_cap`` (static; 0 → T) is the compacted r-vector capacity.  Passing
    ``topics``/``counts`` side tables ((I, r_cap) i32 each) selects sparse
    r-mode: they are maintained in VMEM and returned appended — a 7-tuple.
    """
    n = tok_doc.shape[0]
    I, T = n_td.shape
    J = n_wt.shape[0]
    cap = int(r_cap) if r_cap else T
    sparse = topics is not None
    grid = (n // n_blk,)

    tile = lambda: pl.BlockSpec((n_blk,), lambda b: (b,))
    whole = lambda *shape: pl.BlockSpec(shape, lambda b: (0,) * len(shape))

    rb_specs = [whole(I, cap), whole(I, cap)] if sparse else []
    rb_shape = ([jax.ShapeDtypeStruct((I, cap), jnp.int32)] * 2
                if sparse else [])
    rb_args = (topics, counts) if sparse else ()

    return pl.pallas_call(
        functools.partial(_kernel, T, n_blk, cap, sparse,
                          float(alpha), float(beta), float(beta_bar)),
        grid=grid,
        in_specs=[
            tile(), tile(), tile(), tile(), tile(), tile(),   # token stream
            whole(I, T), whole(J, T), whole(T),               # count tables
            *rb_specs,                                        # side tables
        ],
        out_specs=[
            tile(),                                           # z'
            whole(I, T), whole(J, T), whole(T),               # tables
            whole(2 * T),                                     # final F+tree
            *rb_specs,                                        # side tables
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((I, T), jnp.int32),
            jax.ShapeDtypeStruct((J, T), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((2 * T,), F32),
            *rb_shape,
        ],
        interpret=interpret,
    )(tok_doc, tok_wrd, tok_valid, tok_bound, z, u, n_td, n_wt, n_t,
      *rb_args)


def _cells_kernel(T: int, n_blk: int, r_cap: int, sparse: bool,
                  alpha: float, beta: float, beta_bar: float, *refs):
    (tok_doc_ref, tok_wrd_ref, tok_valid_ref, tok_bound_ref,
     z_in_ref, u_ref, ntd_in_ref, nwt_in_ref, nt_in_ref) = refs[:9]
    if sparse:
        tpc_in_ref, cnt_in_ref = refs[9:11]
        z_ref, ntd_ref, nwt_ref, nt_ref, f_ref, tpc_ref, cnt_ref = refs[11:]
    else:
        tpc_ref = cnt_ref = None
        z_ref, ntd_ref, nwt_ref, nt_ref, f_ref = refs[9:]
    first = (pl.program_id(0) == 0) & (pl.program_id(1) == 0)
    cell_start = pl.program_id(1) == 0

    @pl.when(first)
    def _init():
        ntd_ref[...] = ntd_in_ref[...]
        nt_ref[...] = nt_in_ref[...]
        f_ref[...] = jnp.zeros((2 * T,), F32)
        if sparse:
            tpc_ref[...] = tpc_in_ref[...]
            cnt_ref[...] = cnt_in_ref[...]

    # New cell ⇒ new word-topic block paged into the output accumulator.
    @pl.when(cell_start)
    def _load_block():
        nwt_ref[...] = nwt_in_ref[...]

    z_tile, nt, F = _sweep_tile(
        T, n_blk, r_cap, alpha, beta, beta_bar,
        tok_doc_ref[0], tok_wrd_ref[0], tok_valid_ref[0],
        tok_bound_ref[0], z_in_ref[0], u_ref[0],
        nt_ref[...], f_ref[...],
        ntd_load=lambda d: ntd_ref[pl.ds(d, 1), :][0],
        ntd_store=lambda d, row: ntd_ref.__setitem__(
            (pl.ds(d, 1), slice(None)), row[None]),
        nwt_load=lambda w: nwt_ref[0, pl.ds(w, 1), :][0],
        nwt_store=lambda w, row: nwt_ref.__setitem__(
            (0, pl.ds(w, 1), slice(None)), row[None]),
        **_rb_kw(sparse, tpc_ref, cnt_ref))

    z_ref[...] = z_tile[None]
    nt_ref[...] = nt
    f_ref[...] = F


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "beta_bar",
                                             "n_blk", "r_cap", "interpret"))
def fused_sweep_cells_pallas(tok_doc: jax.Array, tok_wrd: jax.Array,
                             tok_valid: jax.Array, tok_bound: jax.Array,
                             z: jax.Array, u: jax.Array,
                             n_td: jax.Array, n_wt: jax.Array,
                             n_t: jax.Array,
                             topics: jax.Array | None = None,
                             counts: jax.Array | None = None, *,
                             alpha: float, beta: float, beta_bar: float,
                             r_cap: int = 0,
                             n_blk: int = N_BLK, interpret: bool = True):
    """One fused F+LDA sweep over a batch of k cells (a nomad block queue).

    Shapes: tok_* / z / u are (k, L) with L % n_blk == 0; n_td (I, T) i32
    shared across cells; n_wt (k, J, T) i32, one word-topic block per cell
    (``tok_wrd`` is block-local); n_t (T,) i32.  Cells are swept in order
    c = 0..k-1 with the exact chain carried through ``n_td``/``n_t``/``F``;
    returns (z', n_td', n_wt', n_t', F), plus the ``(topics, counts)``
    side tables appended when they are passed (sparse r-mode).
    """
    k, L = tok_doc.shape
    I, T = n_td.shape
    J = n_wt.shape[1]
    cap = int(r_cap) if r_cap else T
    sparse = topics is not None
    grid = (k, L // n_blk)

    tile = lambda: pl.BlockSpec((1, n_blk), lambda c, t: (c, t))
    blk = lambda: pl.BlockSpec((1, J, T), lambda c, t: (c, 0, 0))
    whole = lambda *shape: pl.BlockSpec(shape,
                                        lambda c, t: (0,) * len(shape))

    rb_specs = [whole(I, cap), whole(I, cap)] if sparse else []
    rb_shape = ([jax.ShapeDtypeStruct((I, cap), jnp.int32)] * 2
                if sparse else [])
    rb_args = (topics, counts) if sparse else ()

    return pl.pallas_call(
        functools.partial(_cells_kernel, T, n_blk, cap, sparse,
                          float(alpha), float(beta), float(beta_bar)),
        grid=grid,
        in_specs=[
            tile(), tile(), tile(), tile(), tile(), tile(),   # token stream
            whole(I, T), blk(), whole(T),                     # count tables
            *rb_specs,                                        # side tables
        ],
        out_specs=[
            tile(),                                           # z'
            whole(I, T), blk(), whole(T),                     # tables
            whole(2 * T),                                     # final F+tree
            *rb_specs,                                        # side tables
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, L), jnp.int32),
            jax.ShapeDtypeStruct((I, T), jnp.int32),
            jax.ShapeDtypeStruct((k, J, T), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((2 * T,), F32),
            *rb_shape,
        ],
        interpret=interpret,
    )(tok_doc, tok_wrd, tok_valid, tok_bound, z, u, n_td, n_wt, n_t,
      *rb_args)


def _ragged_kernel(T: int, n_blk: int, r_cap: int, sparse: bool,
                   alpha: float, beta: float, beta_bar: float, *refs):
    cot_ref = refs[0]                                  # scalar prefetch
    (tok_doc_ref, tok_wrd_ref, tok_valid_ref, tok_bound_ref,
     z_in_ref, u_ref, ntd_in_ref, nwt_in_ref, nt_in_ref) = refs[1:10]
    if sparse:
        tpc_in_ref, cnt_in_ref = refs[10:12]
        z_ref, ntd_ref, nwt_ref, nt_ref, f_ref, tpc_ref, cnt_ref = refs[12:]
    else:
        tpc_ref = cnt_ref = None
        z_ref, ntd_ref, nwt_ref, nt_ref, f_ref = refs[10:]
    t = pl.program_id(0)
    first = t == 0
    # Cell start: the tile→cell map steps (it is non-decreasing, one
    # contiguous tile run per cell) — page the cell's block into the
    # output accumulator, exactly like the cell-batch grid's first tile.
    cell_start = first | (cot_ref[t] != cot_ref[jnp.maximum(t - 1, 0)])

    @pl.when(first)
    def _init():
        ntd_ref[...] = ntd_in_ref[...]
        nt_ref[...] = nt_in_ref[...]
        f_ref[...] = jnp.zeros((2 * T,), F32)
        if sparse:
            tpc_ref[...] = tpc_in_ref[...]
            cnt_ref[...] = cnt_in_ref[...]

    @pl.when(cell_start)
    def _load_block():
        nwt_ref[...] = nwt_in_ref[...]

    z_tile, nt, F = _sweep_tile(
        T, n_blk, r_cap, alpha, beta, beta_bar,
        tok_doc_ref[...], tok_wrd_ref[...], tok_valid_ref[...],
        tok_bound_ref[...], z_in_ref[...], u_ref[...],
        nt_ref[...], f_ref[...],
        ntd_load=lambda d: ntd_ref[pl.ds(d, 1), :][0],
        ntd_store=lambda d, row: ntd_ref.__setitem__(
            (pl.ds(d, 1), slice(None)), row[None]),
        nwt_load=lambda w: nwt_ref[0, pl.ds(w, 1), :][0],
        nwt_store=lambda w, row: nwt_ref.__setitem__(
            (0, pl.ds(w, 1), slice(None)), row[None]),
        **_rb_kw(sparse, tpc_ref, cnt_ref))

    z_ref[...] = z_tile
    nt_ref[...] = nt
    f_ref[...] = F


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "beta_bar",
                                             "n_blk", "r_cap", "interpret"))
def fused_sweep_ragged_pallas(cell_of_tile: jax.Array,
                              tok_doc: jax.Array, tok_wrd: jax.Array,
                              tok_valid: jax.Array, tok_bound: jax.Array,
                              z: jax.Array, u: jax.Array,
                              n_td: jax.Array, n_wt: jax.Array,
                              n_t: jax.Array,
                              topics: jax.Array | None = None,
                              counts: jax.Array | None = None, *,
                              alpha: float, beta: float, beta_bar: float,
                              r_cap: int = 0,
                              n_blk: int, interpret: bool = True):
    """One fused F+LDA sweep over a ragged cell stream (a nomad queue).

    Shapes: tok_* / z / u are (S,) with ``S = n_tiles·n_blk``;
    cell_of_tile (n_tiles,) i32, non-decreasing, values in [0, k);
    n_td (I, T) i32; n_wt (k, J, T) i32, one word-topic block per cell
    (``tok_wrd`` is block-local); n_t (T,) i32.  Tiles run in sequence
    with ``n_td``/``n_t``/``F`` carried; tile ``t`` addresses word-topic
    block ``cell_of_tile[t]``, paged by scalar-prefetched index map.
    Returns (z', n_td', n_wt', n_t', F), plus the ``(topics, counts)``
    side tables appended when they are passed (sparse r-mode).
    """
    n = tok_doc.shape[0]
    I, T = n_td.shape
    k, J = n_wt.shape[0], n_wt.shape[1]
    cap = int(r_cap) if r_cap else T
    sparse = topics is not None
    n_tiles = n // n_blk

    rb_in = ([pl.BlockSpec((I, cap), lambda t, cot: (0, 0))] * 2
             if sparse else [])
    rb_out = ([pl.BlockSpec((I, cap), lambda t, cot: (0, 0))] * 2
              if sparse else [])
    rb_shape = ([jax.ShapeDtypeStruct((I, cap), jnp.int32)] * 2
                if sparse else [])
    rb_args = (topics, counts) if sparse else ()

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            *(pl.BlockSpec((n_blk,), lambda t, cot: (t,))
              for _ in range(6)),                          # token stream
            pl.BlockSpec((I, T), lambda t, cot: (0, 0)),
            pl.BlockSpec((1, J, T), lambda t, cot: (cot[t], 0, 0)),
            pl.BlockSpec((T,), lambda t, cot: (0,)),
            *rb_in,                                        # side tables
        ],
        out_specs=[
            pl.BlockSpec((n_blk,), lambda t, cot: (t,)),   # z'
            pl.BlockSpec((I, T), lambda t, cot: (0, 0)),
            pl.BlockSpec((1, J, T), lambda t, cot: (cot[t], 0, 0)),
            pl.BlockSpec((T,), lambda t, cot: (0,)),
            pl.BlockSpec((2 * T,), lambda t, cot: (0,)),   # final F+tree
            *rb_out,                                       # side tables
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_kernel, T, n_blk, cap, sparse,
                          float(alpha), float(beta), float(beta_bar)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((I, T), jnp.int32),
            jax.ShapeDtypeStruct((k, J, T), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((2 * T,), F32),
            *rb_shape,
        ],
        interpret=interpret,
    )(cell_of_tile, tok_doc, tok_wrd, tok_valid, tok_bound, z, u,
      n_td, n_wt, n_t, *rb_args)


# ---------------------------------------------------------------------------
# Doc-tiled variants: n_td stays in ANY/HBM, one (doc_rows, T) slab is
# paged through a VMEM scratch by explicit DMA (module docstring).
# ---------------------------------------------------------------------------
def _slab_copy(src, dst, sem):
    cp = pltpu.make_async_copy(src, dst, sem)
    cp.start()
    cp.wait()


def _doc_slab_page(doc_rows, g, g_prev, first,
                   ntd_in_ref, ntd_out_ref, slab, sem):
    """Slab prologue of one grid step: at the first step, bulk-copy the
    whole table input→output and pull the first slab; at a slab switch,
    write the previous slab back and pull the new one.  All reads go
    through the output buffer, so recurring slabs see every prior
    write-back."""
    @pl.when(first)
    def _init():
        _slab_copy(ntd_in_ref, ntd_out_ref, sem)
        _slab_copy(ntd_out_ref.at[pl.ds(g * doc_rows, doc_rows)], slab, sem)

    @pl.when(jnp.logical_not(first) & (g != g_prev))
    def _switch():
        _slab_copy(slab, ntd_out_ref.at[pl.ds(g_prev * doc_rows, doc_rows)],
                   sem)
        _slab_copy(ntd_out_ref.at[pl.ds(g * doc_rows, doc_rows)], slab, sem)


def _slab_accessors(slab, g, doc_rows):
    """Row load/store on the resident slab; ``tok_doc`` carries worker-local
    doc indices, the slab holds rows [g·doc_rows, (g+1)·doc_rows)."""
    row0 = g * doc_rows
    load = lambda d: slab[pl.ds(d - row0, 1), :][0]
    store = lambda d, row: slab.__setitem__(
        (pl.ds(d - row0, 1), slice(None)), row[None])
    return load, store


def _docs_kernel(T: int, n_blk: int, doc_rows: int, r_cap: int,
                 sparse: bool, alpha: float, beta: float, beta_bar: float,
                 *refs):
    dto_ref = refs[0]                                  # scalar prefetch
    (tok_doc_ref, tok_wrd_ref, tok_valid_ref, tok_bound_ref,
     z_in_ref, u_ref, ntd_in_ref, nwt_in_ref, nt_in_ref) = refs[1:10]
    if sparse:
        tpc_in_ref, cnt_in_ref = refs[10:12]
        (z_ref, ntd_out_ref, nwt_ref, nt_ref, f_ref,
         tpc_ref, cnt_ref) = refs[12:19]
        slab, sem = refs[19:]
    else:
        tpc_ref = cnt_ref = None
        z_ref, ntd_out_ref, nwt_ref, nt_ref, f_ref = refs[10:15]
        slab, sem = refs[15:]
    t = pl.program_id(0)
    first = t == 0
    g = dto_ref[t]
    g_prev = dto_ref[jnp.maximum(t - 1, 0)]

    @pl.when(first)
    def _init():
        nwt_ref[...] = nwt_in_ref[...]
        nt_ref[...] = nt_in_ref[...]
        f_ref[...] = jnp.zeros((2 * T,), F32)
        if sparse:
            tpc_ref[...] = tpc_in_ref[...]
            cnt_ref[...] = cnt_in_ref[...]

    _doc_slab_page(doc_rows, g, g_prev, first, ntd_in_ref, ntd_out_ref,
                   slab, sem)
    ntd_load, ntd_store = _slab_accessors(slab, g, doc_rows)

    z_tile, nt, F = _sweep_tile(
        T, n_blk, r_cap, alpha, beta, beta_bar,
        tok_doc_ref[...], tok_wrd_ref[...], tok_valid_ref[...],
        tok_bound_ref[...], z_in_ref[...], u_ref[...],
        nt_ref[...], f_ref[...],
        ntd_load=ntd_load, ntd_store=ntd_store,
        nwt_load=lambda w: nwt_ref[pl.ds(w, 1), :][0],
        nwt_store=lambda w, row: nwt_ref.__setitem__(
            (pl.ds(w, 1), slice(None)), row[None]),
        **_rb_kw(sparse, tpc_ref, cnt_ref))

    z_ref[...] = z_tile
    nt_ref[...] = nt
    f_ref[...] = F

    @pl.when(t == pl.num_programs(0) - 1)
    def _flush():
        _slab_copy(slab, ntd_out_ref.at[pl.ds(g * doc_rows, doc_rows)], sem)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "beta_bar",
                                             "doc_rows", "n_blk", "r_cap",
                                             "interpret"))
def fused_sweep_docs_pallas(doc_tile_of: jax.Array,
                            tok_doc: jax.Array, tok_wrd: jax.Array,
                            tok_valid: jax.Array, tok_bound: jax.Array,
                            z: jax.Array, u: jax.Array,
                            n_td: jax.Array, n_wt: jax.Array,
                            n_t: jax.Array,
                            topics: jax.Array | None = None,
                            counts: jax.Array | None = None, *,
                            alpha: float, beta: float, beta_bar: float,
                            doc_rows: int, r_cap: int = 0,
                            n_blk: int = N_BLK,
                            interpret: bool = True):
    """Doc-tiled twin of :func:`fused_sweep_pallas`.

    ``doc_tile_of`` is the (n // n_blk,) per-tile slab map; ``n_td`` rows
    must be a whole number of ``doc_rows`` slabs (``ops`` pads) and every
    tile's tokens must address rows of its own slab only (guaranteed by
    ``build_layout(doc_tile=...)``'s grouped order).  The sparse-mode side
    tables stay whole-VMEM (they are a factor T/r_cap smaller than the
    table the slab paging evicts) and are not padded to slab multiples.
    """
    n = tok_doc.shape[0]
    I, T = n_td.shape
    J = n_wt.shape[0]
    cap = int(r_cap) if r_cap else T
    sparse = topics is not None
    I_tab = topics.shape[0] if sparse else 0
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    rb_specs = ([pl.BlockSpec((I_tab, cap), lambda t, dto: (0, 0))] * 2
                if sparse else [])
    rb_shape = ([jax.ShapeDtypeStruct((I_tab, cap), jnp.int32)] * 2
                if sparse else [])
    rb_args = (topics, counts) if sparse else ()

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // n_blk,),
        in_specs=[
            *(pl.BlockSpec((n_blk,), lambda t, dto: (t,))
              for _ in range(6)),                          # token stream
            any_spec,                                      # n_td (HBM)
            pl.BlockSpec((J, T), lambda t, dto: (0, 0)),
            pl.BlockSpec((T,), lambda t, dto: (0,)),
            *rb_specs,                                     # side tables
        ],
        out_specs=[
            pl.BlockSpec((n_blk,), lambda t, dto: (t,)),   # z'
            any_spec,                                      # n_td' (HBM)
            pl.BlockSpec((J, T), lambda t, dto: (0, 0)),
            pl.BlockSpec((T,), lambda t, dto: (0,)),
            pl.BlockSpec((2 * T,), lambda t, dto: (0,)),   # final F+tree
            *rb_specs,                                     # side tables
        ],
        scratch_shapes=[pltpu.VMEM((doc_rows, T), jnp.int32),
                        pltpu.SemaphoreType.DMA],
    )
    return pl.pallas_call(
        functools.partial(_docs_kernel, T, n_blk, int(doc_rows), cap,
                          sparse,
                          float(alpha), float(beta), float(beta_bar)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((I, T), jnp.int32),
            jax.ShapeDtypeStruct((J, T), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((2 * T,), F32),
            *rb_shape,
        ],
        interpret=interpret,
    )(doc_tile_of, tok_doc, tok_wrd, tok_valid, tok_bound, z, u,
      n_td, n_wt, n_t, *rb_args)


def _cells_docs_kernel(T: int, n_blk: int, doc_rows: int, r_cap: int,
                       sparse: bool, alpha: float, beta: float,
                       beta_bar: float, *refs):
    dto_ref = refs[0]                                  # scalar prefetch
    (tok_doc_ref, tok_wrd_ref, tok_valid_ref, tok_bound_ref,
     z_in_ref, u_ref, ntd_in_ref, nwt_in_ref, nt_in_ref) = refs[1:10]
    if sparse:
        tpc_in_ref, cnt_in_ref = refs[10:12]
        (z_ref, ntd_out_ref, nwt_ref, nt_ref, f_ref,
         tpc_ref, cnt_ref) = refs[12:19]
        slab, sem = refs[19:]
    else:
        tpc_ref = cnt_ref = None
        z_ref, ntd_out_ref, nwt_ref, nt_ref, f_ref = refs[10:15]
        slab, sem = refs[15:]
    c, t = pl.program_id(0), pl.program_id(1)
    n_c, n_t_g = pl.num_programs(0), pl.num_programs(1)
    first = (c == 0) & (t == 0)
    cell_start = t == 0
    g = dto_ref[c, t]
    # previous grid step in raster order (the last tile of the previous
    # cell when t == 0); unused garbage at the very first step
    pc = jnp.where(t == 0, jnp.maximum(c - 1, 0), c)
    pt = jnp.where(t == 0, n_t_g - 1, t - 1)
    g_prev = dto_ref[pc, pt]

    @pl.when(first)
    def _init():
        nt_ref[...] = nt_in_ref[...]
        f_ref[...] = jnp.zeros((2 * T,), F32)
        if sparse:
            tpc_ref[...] = tpc_in_ref[...]
            cnt_ref[...] = cnt_in_ref[...]

    @pl.when(cell_start)
    def _load_block():
        nwt_ref[...] = nwt_in_ref[...]

    _doc_slab_page(doc_rows, g, g_prev, first, ntd_in_ref, ntd_out_ref,
                   slab, sem)
    ntd_load, ntd_store = _slab_accessors(slab, g, doc_rows)

    z_tile, nt, F = _sweep_tile(
        T, n_blk, r_cap, alpha, beta, beta_bar,
        tok_doc_ref[0], tok_wrd_ref[0], tok_valid_ref[0],
        tok_bound_ref[0], z_in_ref[0], u_ref[0],
        nt_ref[...], f_ref[...],
        ntd_load=ntd_load, ntd_store=ntd_store,
        nwt_load=lambda w: nwt_ref[0, pl.ds(w, 1), :][0],
        nwt_store=lambda w, row: nwt_ref.__setitem__(
            (0, pl.ds(w, 1), slice(None)), row[None]),
        **_rb_kw(sparse, tpc_ref, cnt_ref))

    z_ref[...] = z_tile[None]
    nt_ref[...] = nt
    f_ref[...] = F

    @pl.when((c == n_c - 1) & (t == n_t_g - 1))
    def _flush():
        _slab_copy(slab, ntd_out_ref.at[pl.ds(g * doc_rows, doc_rows)], sem)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "beta_bar",
                                             "doc_rows", "n_blk", "r_cap",
                                             "interpret"))
def fused_sweep_cells_docs_pallas(doc_tile_of: jax.Array,
                                  tok_doc: jax.Array, tok_wrd: jax.Array,
                                  tok_valid: jax.Array, tok_bound: jax.Array,
                                  z: jax.Array, u: jax.Array,
                                  n_td: jax.Array, n_wt: jax.Array,
                                  n_t: jax.Array,
                                  topics: jax.Array | None = None,
                                  counts: jax.Array | None = None, *,
                                  alpha: float, beta: float, beta_bar: float,
                                  doc_rows: int, r_cap: int = 0,
                                  n_blk: int = N_BLK,
                                  interpret: bool = True):
    """Doc-tiled twin of :func:`fused_sweep_cells_pallas`; ``doc_tile_of``
    is the (k, L // n_blk) per-(cell, tile) slab map."""
    k, L = tok_doc.shape
    I, T = n_td.shape
    J = n_wt.shape[1]
    cap = int(r_cap) if r_cap else T
    sparse = topics is not None
    I_tab = topics.shape[0] if sparse else 0
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    rb_specs = ([pl.BlockSpec((I_tab, cap), lambda c, t, dto: (0, 0))] * 2
                if sparse else [])
    rb_shape = ([jax.ShapeDtypeStruct((I_tab, cap), jnp.int32)] * 2
                if sparse else [])
    rb_args = (topics, counts) if sparse else ()

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k, L // n_blk),
        in_specs=[
            *(pl.BlockSpec((1, n_blk), lambda c, t, dto: (c, t))
              for _ in range(6)),                          # token stream
            any_spec,                                      # n_td (HBM)
            pl.BlockSpec((1, J, T), lambda c, t, dto: (c, 0, 0)),
            pl.BlockSpec((T,), lambda c, t, dto: (0,)),
            *rb_specs,                                     # side tables
        ],
        out_specs=[
            pl.BlockSpec((1, n_blk), lambda c, t, dto: (c, t)),
            any_spec,                                      # n_td' (HBM)
            pl.BlockSpec((1, J, T), lambda c, t, dto: (c, 0, 0)),
            pl.BlockSpec((T,), lambda c, t, dto: (0,)),
            pl.BlockSpec((2 * T,), lambda c, t, dto: (0,)),
            *rb_specs,                                     # side tables
        ],
        scratch_shapes=[pltpu.VMEM((doc_rows, T), jnp.int32),
                        pltpu.SemaphoreType.DMA],
    )
    return pl.pallas_call(
        functools.partial(_cells_docs_kernel, T, n_blk, int(doc_rows),
                          cap, sparse,
                          float(alpha), float(beta), float(beta_bar)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((k, L), jnp.int32),
            jax.ShapeDtypeStruct((I, T), jnp.int32),
            jax.ShapeDtypeStruct((k, J, T), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((2 * T,), F32),
            *rb_shape,
        ],
        interpret=interpret,
    )(doc_tile_of, tok_doc, tok_wrd, tok_valid, tok_bound, z, u,
      n_td, n_wt, n_t, *rb_args)


def _ragged_docs_kernel(T: int, n_blk: int, doc_rows: int, r_cap: int,
                        sparse: bool, alpha: float, beta: float,
                        beta_bar: float, *refs):
    cot_ref, dto_ref = refs[:2]                        # scalar prefetch
    (tok_doc_ref, tok_wrd_ref, tok_valid_ref, tok_bound_ref,
     z_in_ref, u_ref, ntd_in_ref, nwt_in_ref, nt_in_ref) = refs[2:11]
    if sparse:
        tpc_in_ref, cnt_in_ref = refs[11:13]
        (z_ref, ntd_out_ref, nwt_ref, nt_ref, f_ref,
         tpc_ref, cnt_ref) = refs[13:20]
        slab, sem = refs[20:]
    else:
        tpc_ref = cnt_ref = None
        z_ref, ntd_out_ref, nwt_ref, nt_ref, f_ref = refs[11:16]
        slab, sem = refs[16:]
    t = pl.program_id(0)
    first = t == 0
    cell_start = first | (cot_ref[t] != cot_ref[jnp.maximum(t - 1, 0)])
    g = dto_ref[t]
    g_prev = dto_ref[jnp.maximum(t - 1, 0)]

    @pl.when(first)
    def _init():
        nt_ref[...] = nt_in_ref[...]
        f_ref[...] = jnp.zeros((2 * T,), F32)
        if sparse:
            tpc_ref[...] = tpc_in_ref[...]
            cnt_ref[...] = cnt_in_ref[...]

    @pl.when(cell_start)
    def _load_block():
        nwt_ref[...] = nwt_in_ref[...]

    _doc_slab_page(doc_rows, g, g_prev, first, ntd_in_ref, ntd_out_ref,
                   slab, sem)
    ntd_load, ntd_store = _slab_accessors(slab, g, doc_rows)

    z_tile, nt, F = _sweep_tile(
        T, n_blk, r_cap, alpha, beta, beta_bar,
        tok_doc_ref[...], tok_wrd_ref[...], tok_valid_ref[...],
        tok_bound_ref[...], z_in_ref[...], u_ref[...],
        nt_ref[...], f_ref[...],
        ntd_load=ntd_load, ntd_store=ntd_store,
        nwt_load=lambda w: nwt_ref[0, pl.ds(w, 1), :][0],
        nwt_store=lambda w, row: nwt_ref.__setitem__(
            (0, pl.ds(w, 1), slice(None)), row[None]),
        **_rb_kw(sparse, tpc_ref, cnt_ref))

    z_ref[...] = z_tile
    nt_ref[...] = nt
    f_ref[...] = F

    @pl.when(t == pl.num_programs(0) - 1)
    def _flush():
        _slab_copy(slab, ntd_out_ref.at[pl.ds(g * doc_rows, doc_rows)], sem)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "beta_bar",
                                             "doc_rows", "n_blk", "r_cap",
                                             "interpret"))
def fused_sweep_ragged_docs_pallas(cell_of_tile: jax.Array,
                                   doc_tile_of: jax.Array,
                                   tok_doc: jax.Array, tok_wrd: jax.Array,
                                   tok_valid: jax.Array,
                                   tok_bound: jax.Array,
                                   z: jax.Array, u: jax.Array,
                                   n_td: jax.Array, n_wt: jax.Array,
                                   n_t: jax.Array,
                                   topics: jax.Array | None = None,
                                   counts: jax.Array | None = None, *,
                                   alpha: float, beta: float,
                                   beta_bar: float, doc_rows: int,
                                   r_cap: int = 0,
                                   n_blk: int, interpret: bool = True):
    """Doc-tiled twin of :func:`fused_sweep_ragged_pallas`: two
    scalar-prefetch maps drive the paging — ``cell_of_tile`` pages the
    word-topic block (BlockSpec window, visited once per cell) and
    ``doc_tile_of`` pages the doc-topic slab (explicit DMA, slabs
    recur)."""
    n = tok_doc.shape[0]
    I, T = n_td.shape
    k, J = n_wt.shape[0], n_wt.shape[1]
    cap = int(r_cap) if r_cap else T
    sparse = topics is not None
    I_tab = topics.shape[0] if sparse else 0
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    rb_specs = ([pl.BlockSpec((I_tab, cap),
                              lambda t, cot, dto: (0, 0))] * 2
                if sparse else [])
    rb_shape = ([jax.ShapeDtypeStruct((I_tab, cap), jnp.int32)] * 2
                if sparse else [])
    rb_args = (topics, counts) if sparse else ()

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n // n_blk,),
        in_specs=[
            *(pl.BlockSpec((n_blk,), lambda t, cot, dto: (t,))
              for _ in range(6)),                          # token stream
            any_spec,                                      # n_td (HBM)
            pl.BlockSpec((1, J, T), lambda t, cot, dto: (cot[t], 0, 0)),
            pl.BlockSpec((T,), lambda t, cot, dto: (0,)),
            *rb_specs,                                     # side tables
        ],
        out_specs=[
            pl.BlockSpec((n_blk,), lambda t, cot, dto: (t,)),
            any_spec,                                      # n_td' (HBM)
            pl.BlockSpec((1, J, T), lambda t, cot, dto: (cot[t], 0, 0)),
            pl.BlockSpec((T,), lambda t, cot, dto: (0,)),
            pl.BlockSpec((2 * T,), lambda t, cot, dto: (0,)),
            *rb_specs,                                     # side tables
        ],
        scratch_shapes=[pltpu.VMEM((doc_rows, T), jnp.int32),
                        pltpu.SemaphoreType.DMA],
    )
    return pl.pallas_call(
        functools.partial(_ragged_docs_kernel, T, n_blk, int(doc_rows),
                          cap, sparse,
                          float(alpha), float(beta), float(beta_bar)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((I, T), jnp.int32),
            jax.ShapeDtypeStruct((k, J, T), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((2 * T,), F32),
            *rb_shape,
        ],
        interpret=interpret,
    )(cell_of_tile, doc_tile_of, tok_doc, tok_wrd, tok_valid, tok_bound,
      z, u, n_td, n_wt, n_t, *rb_args)
