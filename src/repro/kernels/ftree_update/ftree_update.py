"""Batched F+tree maintenance kernel (paper Alg. 2, TPU-adapted).

Applies K single-parameter updates p_{t_k} += δ_k to the tree in one pass.
Instead of K serial bottom-up walks (Alg. 2), the kernel processes the tree
**level by level**: at level ℓ every update touches exactly one node
(leaf index >> ℓ), so each level is one vectorized scatter-add of the K
deltas — duplicate paths accumulate naturally.  Depth stays O(log T); work
per level is lane-parallel over the update batch.

The whole tree and the update batch live in VMEM (tree ≤ 128 KiB at
T=16384; batch tiles at 1024).  Single grid program with an inner loop over
batch tiles keeps the scatter target resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(depth: int, f_ref, t_ref, d_ref, out_ref):
    out_ref[...] = f_ref[...]
    T = f_ref.shape[0] // 2
    leaf = t_ref[...] + T                    # (K,) heap leaf indices
    delta = d_ref[...]                       # (K,)
    for lvl in range(depth + 1):             # leaf .. root, unrolled
        node = leaf >> lvl
        cur = out_ref[...]
        out_ref[...] = cur.at[node].add(delta)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ftree_update_pallas(F: jax.Array, ts: jax.Array, deltas: jax.Array,
                        *, interpret: bool = True) -> jax.Array:
    two_t = F.shape[0]
    T = two_t // 2
    depth = T.bit_length() - 1
    k = ts.shape[0]
    return pl.pallas_call(
        functools.partial(_kernel, depth),
        in_specs=[
            pl.BlockSpec((two_t,), lambda: (0,)),
            pl.BlockSpec((k,), lambda: (0,)),
            pl.BlockSpec((k,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((two_t,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((two_t,), F.dtype),
        interpret=interpret,
    )(F, ts, deltas)
