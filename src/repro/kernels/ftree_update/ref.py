"""Pure-jnp oracle for the ftree_update kernel."""
import jax

from repro.core import ftree


def ftree_update_ref(F: jax.Array, ts: jax.Array,
                     deltas: jax.Array) -> jax.Array:
    return ftree.update_batch(F, ts, deltas)
