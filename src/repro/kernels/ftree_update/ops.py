"""Public wrapper for the batched F+tree update kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ftree_update.ftree_update import ftree_update_pallas


def ftree_update_batch(F: jax.Array, ts: jax.Array, deltas: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """F+tree after p[ts[k]] += deltas[k] for all k (duplicates accumulate)."""
    return ftree_update_pallas(
        F.astype(jnp.float32), ts.astype(jnp.int32),
        deltas.astype(jnp.float32), interpret=interpret)
