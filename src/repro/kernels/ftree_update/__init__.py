from repro.kernels.ftree_update.ops import ftree_update_batch  # noqa: F401
