"""Pallas fold-in kernel: the φ-frozen per-document sweep, VMEM-resident.

The serving hot path (DESIGN.md §10) answers a θ query by Gibbs fold-in
against a frozen φ snapshot — ``core/heldout.py:fold_in_batch`` runs it
as a vmapped ``lax.scan``.  This kernel is its Pallas twin: the padded
``(D, L)`` batch rides the grid's doc axis (one program per document),
the per-doc ``(T,)`` topic counts live in registers/VMEM for the whole
multi-sweep chain, and φ stays in ANY/HBM with the current token's row
gathered by explicit DMA (``pltpu.make_async_copy``) into a ``(1, T)``
VMEM scratch — the §7 doc-slab machinery specialized to one row.

**Bit-exactness contract:** all randomness is precomputed outside the
kernel (``ops.fold_in_draws``) by the identical counter-mode
``doc_fold_key`` chains ``fold_in_batch`` derives internally — the
kernel consumes ``z0`` (initial assignments) and ``u`` (per-sweep
LSearch uniforms) as plain arrays and replays the exact per-token op
order of the reference: decrement, ``(n_td+α)·φ[w]``, ``jnp.cumsum``,
guarded LSearch, masked re-assign, increment.  Padded positions are
inert by construction (their draws are consumed and discarded, their
count updates are ±0), so a kernel row is bit-identical to the serial
``fold_in`` on that document alone.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.samplers import lsearch_guarded

F32 = jnp.float32


def _row_copy(phi_ref, w, row, sem):
    """DMA φ row ``w`` (ANY/HBM) into the ``(1, T)`` VMEM scratch."""
    cp = pltpu.make_async_copy(phi_ref.at[pl.ds(w, 1), :], row, sem)
    cp.start()
    cp.wait()


def _kernel(T: int, L: int, sweeps: int, *refs):
    (w_ref, v_ref, z0_ref, u_ref, alpha_ref, phi_ref,
     ntd_ref, phi_row, sem) = refs
    words = w_ref[0]                       # (L,) i32
    vmask = v_ref[0]                       # (L,) i32 0/1
    z0 = z0_ref[0]                         # (L,) i32
    u = u_ref[0]                           # (sweeps·L,) f32, sweep-major
    alpha = alpha_ref[0, 0]                # f32 scalar

    # Initial counts: n_td[z0[p]] += v[p].  Scalar scatter adds in a
    # fori_loop — integer adds are order-independent, so this matches the
    # reference's vector `.at[z].add(v)` bit-for-bit.
    def init_count(p, ntd):
        return ntd.at[z0[p]].add(vmask[p])

    n_td = jax.lax.fori_loop(0, L, init_count,
                             jnp.zeros((T,), jnp.int32))

    # sweeps·L flattened token chain — identical sequence to the
    # reference's scan-over-sweeps of scan-over-positions.
    def tok_step(i, carry):
        z, n_td = carry
        p = i % L
        w, vi, t_old = words[p], vmask[p], z[p]
        n_td = n_td.at[t_old].add(-vi)
        _row_copy(phi_ref, w, phi_row, sem)
        prob = (n_td.astype(F32) + alpha) * phi_row[0]
        cdf = jnp.cumsum(prob)
        t_new = lsearch_guarded(cdf, u[i] * cdf[-1])
        t_new = jnp.where(vi > 0, t_new, t_old)
        n_td = n_td.at[t_new].add(vi)
        z = z.at[p].set(t_new)
        return z, n_td

    _, n_td = jax.lax.fori_loop(0, sweeps * L, tok_step, (z0, n_td))
    ntd_ref[...] = n_td[None]


@functools.partial(jax.jit, static_argnames=("sweeps", "interpret"))
def fold_in_pallas(word_ids: jax.Array, valid: jax.Array, z0: jax.Array,
                   u: jax.Array, alpha: jax.Array, phi: jax.Array, *,
                   sweeps: int, interpret: bool = True) -> jax.Array:
    """One fused multi-sweep fold-in over a padded doc batch.

    Shapes: ``word_ids``/``valid``/``z0`` are ``(D, L)`` i32;
    ``u`` is ``(D, sweeps·L)`` f32 (sweep-major per row — the flattened
    ``ops.fold_in_draws`` output); ``alpha`` a ``(1, 1)`` f32; ``phi``
    ``(J, T)`` f32, HBM-resident.  Returns ``(D, T)`` i32 fold-in counts,
    row-for-row bit-identical to ``fold_in_batch``.
    """
    D, L = word_ids.shape
    T = phi.shape[1]
    doc = lambda: pl.BlockSpec((1, L), lambda d: (d, 0))
    return pl.pallas_call(
        functools.partial(_kernel, T, L, int(sweeps)),
        grid=(D,),
        in_specs=[
            doc(), doc(), doc(),                            # words/valid/z0
            pl.BlockSpec((1, sweeps * L), lambda d: (d, 0)),  # uniforms
            pl.BlockSpec((1, 1), lambda d: (0, 0)),           # alpha
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # φ (HBM)
        ],
        out_specs=pl.BlockSpec((1, T), lambda d: (d, 0)),
        out_shape=jax.ShapeDtypeStruct((D, T), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, T), F32),
                        pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(word_ids, valid, z0, u, alpha, phi)
