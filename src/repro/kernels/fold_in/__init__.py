"""Pallas fold-in kernel for the serving hot path (DESIGN.md §10a).

Same package shape as ``kernels/fused_sweep``:
    fold_in.py — pl.pallas_call kernel (doc-axis grid, φ rows by DMA)
    ops.py     — public wrapper (draw precompute, interpret/VMEM guard)
    ref.py     — pure-jnp oracle on the same precomputed draws
"""
from repro.kernels.fold_in.ops import (fold_in_draws,  # noqa: F401
                                       fold_in_fused, fold_in_vmem_bytes)
from repro.kernels.fold_in.ref import fold_in_kernel_ref  # noqa: F401
