"""Pure-jnp oracle for the Pallas fold-in kernel.

Consumes the same precomputed ``(z0, u)`` draw arrays as the kernel
(``ops.fold_in_draws``) and replays the identical per-token chain as a
vmapped ``lax.scan`` — the bridge that factors the tentpole equality
into two independently testable halves:

* ``fold_in_kernel_ref == fold_in_pallas`` — the kernel replays the
  chain faithfully (tests sweep shapes/padding);
* ``fold_in_kernel_ref == core/heldout.py:fold_in_batch`` — the draw
  precompute is bit-identical to the reference's internal derivation
  (same counter-mode ``fold_in`` chains, reorganized).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.samplers import lsearch_guarded


def fold_in_kernel_ref(word_ids, valid, z0, u, alpha, phi):
    """Reference fold-in on precomputed draws.

    ``word_ids``/``valid``/``z0``: (D, L); ``u``: (D, sweeps, L) f32;
    returns (D, T) i32 counts — same contract as ``fold_in_pallas``
    (which takes ``u`` flattened to ``(D, sweeps·L)``).
    """
    T = phi.shape[1]
    L = word_ids.shape[1]
    pos = jnp.arange(L, dtype=jnp.int32)

    def one_doc(words, mask, z_init, u_doc):
        v = mask.astype(jnp.int32)
        n_td = jnp.zeros((T,), jnp.int32).at[z_init].add(v)

        def sweep(carry, u_row):
            z, n_td = carry

            def step(c, inp):
                z, n_td = c
                i, u01, vi = inp
                w, t_old = words[i], z[i]
                n_td = n_td.at[t_old].add(-vi)
                p = (n_td.astype(jnp.float32) + alpha) * phi[w]
                cdf = jnp.cumsum(p)
                t_new = lsearch_guarded(cdf, u01 * cdf[-1])
                t_new = jnp.where(vi > 0, t_new, t_old)
                n_td = n_td.at[t_new].add(vi)
                z = z.at[i].set(t_new)
                return (z, n_td), None

            (z, n_td), _ = lax.scan(step, (z, n_td), (pos, u_row, v))
            return (z, n_td), None

        (_, n_td), _ = lax.scan(sweep, (z_init, n_td), u_doc)
        return n_td

    return jax.vmap(one_doc)(word_ids, valid.astype(jnp.int32), z0, u)
