"""Public wrapper for the Pallas fold-in kernel: draw precompute, shape
validation, interpret default and VMEM budgeting.

:func:`fold_in_fused` is a drop-in for ``core/heldout.py:fold_in_batch``
(same signature + ``interpret``), bit-identical per document.  The RNG
split is the one piece of the reference that cannot run inside a Pallas
body — ``jax.random`` key ops don't lower to Mosaic — so
:func:`fold_in_draws` precomputes every draw *outside* the kernel by the
identical counter-mode ``doc_fold_key`` chains the reference derives
internally (same ``fold_in``/``randint``/``uniform`` callsites, so the
bits agree), and the kernel replays the chain on plain arrays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.heldout import _ROLE_INIT, _ROLE_SWEEP
from repro.kernels.fold_in.fold_in import fold_in_pallas
from repro.kernels.fused_sweep.ops import (VMEM_BUDGET_BYTES,
                                           default_interpret)


def fold_in_vmem_bytes(L: int, T: int, sweeps: int) -> int:
    """VMEM-resident bytes of one fold-in kernel program (DESIGN.md §10a).

    Per grid step: three i32 ``(1, L)`` token streams (words, mask, z0),
    the f32 ``(1, sweeps·L)`` uniform block, the i32 ``(1, T)`` count
    output, the f32 ``(1, T)`` φ-row scratch, and the loop-carried
    ``z``/``n_td`` values (≈ one more L + T).  φ itself stays in HBM —
    only one row is ever resident.
    """
    return 4 * (3 * L + sweeps * L + 2 * T) + 4 * (L + T)


def fold_in_draws(doc_keys, L: int, T: int, sweeps: int):
    """Precompute the kernel's draws: ``(z0, u)`` of shapes ``(D, L)``
    i32 and ``(D, sweeps, L)`` f32.

    Bit-identical to the draws ``fold_in_batch`` derives internally:
    position ``p``'s init assignment comes from
    ``fold_in(fold_in(dk, _ROLE_INIT), p)`` and sweep ``k``'s uniform
    from ``fold_in(fold_in(fold_in(dk, _ROLE_SWEEP), k), p)`` — pure
    functions of the key bits, so hoisting them out of the sweep loop
    changes nothing.
    """
    pos = jnp.arange(L, dtype=jnp.int32)

    def per_doc(dk):
        ik = jax.random.fold_in(dk, _ROLE_INIT)
        tk = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(ik, pos)
        z0 = jax.vmap(
            lambda kk: jax.random.randint(kk, (), 0, T,
                                          dtype=jnp.int32))(tk)
        sk = jax.random.fold_in(dk, _ROLE_SWEEP)

        def sweep_u(k):
            ks = jax.random.fold_in(sk, k)
            uk = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(ks, pos)
            return jax.vmap(jax.random.uniform)(uk)

        u = jax.vmap(sweep_u)(jnp.arange(sweeps, dtype=jnp.int32))
        return z0, u

    return jax.vmap(per_doc)(doc_keys)


def fold_in_fused(word_ids, valid, phi, alpha, doc_keys,
                  sweeps: int = 20, *, interpret: bool | None = None):
    """Pallas twin of ``fold_in_batch``: (D, L) padded batch → (D, T)
    i32 fold-in counts, bit-identical per document.

    ``interpret=None`` → :func:`default_interpret` (compiled on TPU,
    interpreted elsewhere); the compiled path is guarded by the §7 VMEM
    budget — oversized ``(L, sweeps)`` must fall back to
    ``inner_mode="scan"`` rather than fail in Mosaic.  Fully jittable
    (validation is shape-only; ``alpha`` may be traced).
    """
    if word_ids.ndim != 2 or word_ids.shape != valid.shape:
        raise ValueError(
            f"word_ids/valid must be matching (D, L) arrays; got "
            f"{word_ids.shape} and {valid.shape}")
    if doc_keys.shape[0] != word_ids.shape[0]:
        raise ValueError(
            f"doc_keys carries {doc_keys.shape[0]} keys for "
            f"{word_ids.shape[0]} rows")
    if sweeps < 1:
        raise ValueError(
            f"fold_in_fused needs sweeps >= 1, got {sweeps} (sweeps=0 is "
            f"the init counts — use fold_in_batch)")
    D, L = word_ids.shape
    T = phi.shape[1]
    if interpret is None:
        interpret = default_interpret()
    if not interpret:
        vmem = fold_in_vmem_bytes(L, T, int(sweeps))
        if vmem > VMEM_BUDGET_BYTES:
            raise ValueError(
                f"fold-in kernel state ({vmem / 2**20:.1f} MiB) exceeds "
                f"the VMEM budget; lower the length bucket L={L} / "
                f"sweeps={sweeps} or use inner_mode='scan'")
    z0, u = fold_in_draws(doc_keys, L, T, int(sweeps))
    return fold_in_pallas(
        word_ids.astype(jnp.int32), valid.astype(jnp.int32), z0,
        u.reshape(D, int(sweeps) * L),
        jnp.asarray(alpha, jnp.float32).reshape(1, 1),
        phi.astype(jnp.float32), sweeps=int(sweeps),
        interpret=bool(interpret))
