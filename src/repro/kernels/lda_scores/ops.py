"""Public wrapper for the fused CGS conditional + draw kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lda_scores.lda_scores import N_BLK, lda_scores_pallas


def lda_scores_draw(n_td_rows: jax.Array, n_wt_rows: jax.Array,
                    n_t: jax.Array, u01: jax.Array, *,
                    alpha: float, beta: float, beta_bar: float,
                    interpret: bool = True):
    """(z, norm) for a batch of tokens; batch padded to the tile size."""
    n = n_td_rows.shape[0]
    n_pad = -n % N_BLK
    if n_pad:
        n_td_rows = jnp.pad(n_td_rows, ((0, n_pad), (0, 0)))
        n_wt_rows = jnp.pad(n_wt_rows, ((0, n_pad), (0, 0)))
        u01 = jnp.pad(u01, (0, n_pad))
    z, norm = lda_scores_pallas(
        n_td_rows, n_wt_rows, n_t, u01.astype(jnp.float32),
        alpha=float(alpha), beta=float(beta), beta_bar=float(beta_bar),
        interpret=interpret)
    return z[:n], norm[:n]
