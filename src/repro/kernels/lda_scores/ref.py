"""Pure-jnp oracle for the fused CGS conditional + draw kernel."""
import jax
import jax.numpy as jnp


def lda_scores_draw_ref(n_td_rows, n_wt_rows, n_t, u01, *,
                        alpha, beta, beta_bar):
    p = ((n_td_rows.astype(jnp.float32) + alpha)
         * (n_wt_rows.astype(jnp.float32) + beta)
         / (n_t.astype(jnp.float32)[None, :] + beta_bar))
    c = jnp.cumsum(p, axis=-1)
    norm = c[:, -1]
    u = u01 * norm
    z = jnp.sum(c <= u[:, None], axis=-1).astype(jnp.int32)
    return z, norm
