from repro.kernels.lda_scores.ops import lda_scores_draw  # noqa: F401
