"""Fused CGS conditional + inverse-CDF draw kernel.

For a tile of tokens, computes the paper's conditional (2)

    p_t = (n_td + α)(n_tw + β)/(n_t + β̄)

from gathered count rows, cumulative-sums along T, and draws the new topic —
all in one VMEM-resident pass.  This is the dense-vectorized TPU alternative
(DESIGN.md §3) the F+tree path is compared against in the roofline analysis:
arithmetic intensity is low (3 reads of T + O(T) flops per token), so the
kernel's job is purely to avoid materializing (N, T) intermediates in HBM.

Tiling: tokens tile the grid at ``N_BLK`` rows; each program holds
(N_BLK, T) count rows + the shared (T,) global counts in VMEM.
T is expected MXU/VPU-aligned (multiple of 128; T=1024 in the paper's runs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_BLK = 256


def _kernel(alpha: float, beta: float, beta_bar: float,
            ntd_ref, nwt_ref, nt_ref, u_ref, z_ref, norm_ref):
    ntd = ntd_ref[...].astype(jnp.float32)        # (N_BLK, T)
    nwt = nwt_ref[...].astype(jnp.float32)        # (N_BLK, T)
    nt = nt_ref[...].astype(jnp.float32)          # (T,)
    p = (ntd + alpha) * (nwt + beta) / (nt[None, :] + beta_bar)
    c = jnp.cumsum(p, axis=-1)                    # (N_BLK, T)
    norm = c[:, -1]
    u = u_ref[...] * norm
    z_ref[...] = jnp.sum(c <= u[:, None], axis=-1).astype(jnp.int32)
    norm_ref[...] = norm


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "beta_bar",
                                             "interpret"))
def lda_scores_pallas(n_td_rows: jax.Array, n_wt_rows: jax.Array,
                      n_t: jax.Array, u01: jax.Array, *,
                      alpha: float, beta: float, beta_bar: float,
                      interpret: bool = True):
    n, T = n_td_rows.shape
    grid = (n // N_BLK,)
    return pl.pallas_call(
        functools.partial(_kernel, alpha, beta, beta_bar),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N_BLK, T), lambda b: (b, 0)),
            pl.BlockSpec((N_BLK, T), lambda b: (b, 0)),
            pl.BlockSpec((T,), lambda b: (0,)),
            pl.BlockSpec((N_BLK,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((N_BLK,), lambda b: (b,)),
            pl.BlockSpec((N_BLK,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(n_td_rows, n_wt_rows, n_t, u01)
