from repro.kernels.ftree_sample.ops import ftree_sample  # noqa: F401
