"""Batched F+tree sampling kernel (paper Alg. 1, TPU-adapted).

Layout (DESIGN.md §3): a scalar O(log T) walk wastes the 8×128 VPU, so the
walk is *batched across tokens*: each grid program loads the whole tree
(2T f32 — ≤128 KiB for T=16384, comfortably VMEM-resident) plus one tile of
``N_BLK`` uniforms, and performs the log₂T traversal as unrolled steps of
vectorized gather + select over the full tile.  Depth stays O(log T); every
step is lane-parallel over tokens.

The tree is replicated to every program via a constant index_map; uniforms
and outputs tile the batch axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_BLK = 1024  # tokens per grid program (8×128 lanes)


def _kernel(depth: int, f_ref, u_ref, z_ref):
    F = f_ref[...]                       # (2T,) in VMEM
    u = u_ref[...] * F[1]                # scale uniforms by the root
    i = jnp.ones(u.shape, jnp.int32)     # all walks start at the root
    for _ in range(depth):               # unrolled log₂T vector steps
        left = F[2 * i]                  # vectorized VMEM gather
        # zero-mass right subtrees are never entered — same edge guard as
        # ftree.sample_batch (u01→1 can round u up to F[1] in f32, which
        # would otherwise walk onto a zero-probability padded leaf)
        go_right = (u >= left) & (F[2 * i + 1] > 0)
        i = 2 * i + go_right.astype(jnp.int32)
        u = jnp.where(go_right, u - left, u)
    T = F.shape[0] // 2
    z_ref[...] = i - T


@functools.partial(jax.jit, static_argnames=("interpret",))
def ftree_sample_pallas(F: jax.Array, u01: jax.Array,
                        *, interpret: bool = True) -> jax.Array:
    """z[k] = F.sample(u01[k]); F: (2T,) f32, u01: (N,) f32, N % N_BLK == 0."""
    two_t = F.shape[0]
    T = two_t // 2
    depth = T.bit_length() - 1
    n = u01.shape[0]
    grid = (n // N_BLK,)
    return pl.pallas_call(
        functools.partial(_kernel, depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((two_t,), lambda b: (0,)),      # tree: replicated
            pl.BlockSpec((N_BLK,), lambda b: (b,)),      # uniforms: tiled
        ],
        out_specs=pl.BlockSpec((N_BLK,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(F, u01)
