"""Public wrapper: padding + dtype plumbing for the ftree_sample kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ftree_sample.ftree_sample import N_BLK, ftree_sample_pallas


def ftree_sample(F: jax.Array, u01: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    """Batched F+tree draws; any N (internally padded to the tile size)."""
    n = u01.shape[0]
    n_pad = -n % N_BLK
    u = jnp.pad(u01.astype(jnp.float32), (0, n_pad))
    z = ftree_sample_pallas(F.astype(jnp.float32), u, interpret=interpret)
    return z[:n]
