"""Pure-jnp oracle for the ftree_sample kernel."""
import jax

from repro.core import ftree


def ftree_sample_ref(F: jax.Array, u01: jax.Array) -> jax.Array:
    return ftree.sample_batch(F, u01)
