"""While-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**
(verified: an 8-step scanned matmul reports 1/8 of the unrolled flops), so
scanned layer stacks / attention chunks / nomad rounds are systematically
under-counted.  This module re-derives flops, HBM bytes, and collective
bytes from the partitioned HLO text, scaling every while body by its trip
count (recursively — scans nest).

Model:
    flops       2 · |output| · |contracting dims| per dot (batch dims land
                in |output| automatically); fusion computations recursed.
    bytes       Σ (operands + result) over *memory-touching* top-level ops
                (fusion, dot, custom-call, copy, slice/dynamic-*,
                 collectives, sort, scatter, gather…) — fusion boundaries
                are materialization points, so this approximates HBM
                traffic at the right granularity.
    collective  result bytes per collective op kind.
    trip count  the integer constant in the while condition computation
                (lax.scan lowers to 0..N step-1 counters).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "Cost"]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "pred": 1, "s8": 1,
                "u8": 1, "f8e4m3": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"^\s*(ROOT\s+)?(%[\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[^\]]*\])"
    r"(?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*(?:\(.*\))?\s*->.*\{")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=(%[\w.\-]+)")
_WHILE_RE = re.compile(r"condition=(%[\w.\-]+),?\s*body=(%[\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"(%[\w.\-]+)")

MEM_OPS = {"fusion", "dot", "custom-call", "copy", "slice", "dynamic-slice",
           "dynamic-update-slice", "scatter", "gather", "sort", "transpose",
           "reshape", "broadcast", "reduce", "concatenate", "pad", "select",
           "convert", "iota", "rng", "rng-bit-generator", "all-gather",
           "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
           "all-gather-start", "all-reduce-start", "collective-permute-start"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in filter(None, dims.split(",")):
            n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in filter(None, m.group(2).split(",")):
        n *= int(d)
    return n


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in filter(None, m.group(2).split(","))]


@dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    rest: str       # everything after the opening '('


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # %name -> type_str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0) + v
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n,
                    self.collective_bytes * n,
                    {k: v * n for k, v in self.collective_by_kind.items()})


def _parse(text: str) -> tuple[dict, str]:
    comps: dict[str, _Computation] = {}
    entry = None
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip())
        if hdr and line.strip().endswith("{"):
            cur = _Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            # parameters declared in the header: %p: f32[...]
            for pname, ptype in re.findall(
                    r"(%?[\w.\-]+)\s*:\s*((?:\([^)]*\)|[a-z0-9]+\[[^\]]*\]))",
                    line):
                key = pname if pname.startswith("%") else "%" + pname
                cur.symbols[key] = ptype
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _LINE_RE.match(line)
        if m:
            _, name, type_str, kind, rest = m.groups()
            cur.symbols[name] = type_str
            cur.ops.append(_Op(name, type_str, kind, rest))
    return comps, entry


def _trip_count(cond: _Computation) -> int:
    """Largest integer constant in the while condition computation."""
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.match(r"([0-9]+)\)?", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out_elems = _shape_elems(op.type_str)
    cm = _CONTRACT_RE.search(op.rest)
    contract = 1
    if cm:
        operands = _OPERAND_RE.findall(op.rest.split(")")[0])
        lhs_type = comp.symbols.get(operands[0], "") if operands else ""
        dims = _shape_dims(lhs_type)
        for idx in filter(None, cm.group(1).split(",")):
            i = int(idx)
            if i < len(dims):
                contract *= dims[i]
    return 2.0 * out_elems * contract


def _operand_bytes(op: _Op, comp: _Computation) -> float:
    total = _shape_bytes(op.type_str)
    operands = _OPERAND_RE.findall(op.rest.split("),")[0])
    for o in operands:
        t = comp.symbols.get(o)
        if t:
            total += _shape_bytes(t)
    return total


def _comp_cost(comp: _Computation, comps: dict, memo: dict,
               count_bytes: bool = True) -> Cost:
    """count_bytes=False inside fusion computations: fused intermediates
    live in registers/VMEM — only the fusion op's own operands+result are
    HBM traffic (counted by the caller).  While/conditional bodies are real
    programs and keep byte counting."""
    key = (comp.name, count_bytes)
    if key in memo:
        return memo[key]
    memo[key] = Cost()                # break cycles defensively
    total = Cost()
    for op in comp.ops:
        if op.kind == "while":
            wm = _WHILE_RE.search(op.rest)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                trips = _trip_count(comps[cond_name]) \
                    if cond_name in comps else 1
                body = _comp_cost(comps[body_name], comps, memo,
                                  count_bytes) if body_name in comps \
                    else Cost()
                total += body.scaled(trips)
            continue
        if op.kind == "conditional":
            for c in _CALL_RE.findall(op.rest):
                if c in comps:
                    total += _comp_cost(comps[c], comps, memo, count_bytes)
            continue
        if op.kind == "dot":
            total += Cost(flops=_dot_flops(op, comp),
                          bytes=_operand_bytes(op, comp)
                          if count_bytes else 0.0)
            continue
        base_kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
        if base_kind in COLLECTIVES:
            nbytes = _shape_bytes(op.type_str)
            if op.kind.endswith("-start") and op.type_str.startswith("("):
                nbytes //= 2
            total += Cost(bytes=nbytes if count_bytes else 0.0,
                          collective_bytes=nbytes,
                          collective_by_kind={base_kind: nbytes})
            continue
        # fusions / calls: recurse for flops+collectives only; the op's own
        # operands+result are the HBM traffic.
        for c in _CALL_RE.findall(op.rest):
            if c in comps:
                total += _comp_cost(comps[c], comps, memo,
                                    count_bytes=False)
        if count_bytes and op.kind in MEM_OPS:
            if op.kind in ("dynamic-update-slice", "scatter"):
                # in-place (aliased) update: traffic = touched bytes, not
                # the whole buffer (XLA updates donated buffers in place)
                ops_ = _OPERAND_RE.findall(op.rest.split("),")[0])
                upd = comp.symbols.get(ops_[1]) if len(ops_) > 1 else None
                touched = 2 * _shape_bytes(upd) if upd else \
                    _shape_bytes(op.type_str)
                total += Cost(bytes=touched)
            elif op.kind in ("dynamic-slice", "gather"):
                # reads only the gathered/sliced elements, not the table
                total += Cost(bytes=2 * _shape_bytes(op.type_str))
            else:
                total += Cost(bytes=_operand_bytes(op, comp))
    memo[key] = total
    return total


def analyze_hlo(text: str) -> Cost:
    comps, entry = _parse(text)
    if entry is None:
        return Cost()
    return _comp_cost(comps[entry], comps, {}, count_bytes=True)
