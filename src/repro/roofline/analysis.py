"""Roofline analysis (deliverable g).

Derives the three roofline terms from a compiled dry-run artifact:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197e12 bf16)
    memory     = HLO_bytes_per_device / HBM_bw               (819e9 B/s)
    collective = collective_bytes_per_device / link_bw       (50e9 B/s)

``compiled.cost_analysis()`` is per-partition-program = per-device
(verified in launch/dryrun.py); collective bytes come from parsing the
partitioned HLO (cost_analysis does not expose them).

MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference) with N = *active* params;
the ratio MODEL_FLOPS / (chips · HLO_FLOPs) measures how much of the
compiled compute is useful (catches remat/redundancy waste — remat'd
training legitimately sits below 1).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import HW

__all__ = ["model_flops", "roofline_terms", "load_reports", "build_table"]

REPORTS = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))), "reports", "dryrun")


def model_flops(arch: str, shape: str) -> float:
    if arch.startswith("lda"):
        return 0.0
    cfg = get_config(arch)
    spec = INPUT_SHAPES[shape]
    n_active = cfg.active_param_count()
    if spec["kind"] == "train":
        return 6.0 * n_active * spec["global_batch"] * spec["seq_len"]
    if spec["kind"] == "prefill":
        return 2.0 * n_active * spec["global_batch"] * spec["seq_len"]
    return 2.0 * n_active * spec["global_batch"]


def roofline_terms(flops_dev: float, bytes_dev: float,
                   coll_bytes_dev: float) -> dict:
    return {
        "compute": flops_dev / HW.PEAK_FLOPS,
        "memory": bytes_dev / HW.HBM_BW,
        "collective": coll_bytes_dev / HW.ICI_BW,
    }


def load_reports(reports_dir: str | None = None) -> list[dict]:
    out = []
    for path in sorted(glob.glob(
            os.path.join(reports_dir or REPORTS, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def build_table(reports: list[dict], mesh_filter: str | None = None):
    """Markdown roofline table rows from dry-run reports."""
    rows = []
    for rep in reports:
        if mesh_filter and rep.get("mesh") != mesh_filter:
            continue
        if "skipped" in rep:
            rows.append((rep["arch"], rep["shape"], rep["mesh"], "SKIP",
                         rep["skipped"]))
            continue
        if "error" in rep:
            rows.append((rep["arch"], rep["shape"], rep["mesh"], "ERROR",
                         rep["error"][:80]))
            continue
        t = rep["roofline_seconds"]
        mf = model_flops(rep["arch"], rep["shape"])
        useful = mf / (rep["hlo_flops_per_device"] * rep["chips"]) \
            if rep["hlo_flops_per_device"] else 0.0
        rows.append((
            rep["arch"], rep["shape"], rep["mesh"], rep["bottleneck"],
            f"compute={t['compute']:.2e} memory={t['memory']:.2e} "
            f"collective={t['collective']:.2e} useful={useful:.2f}"))
    return rows
