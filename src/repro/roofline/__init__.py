from repro.roofline.analysis import (  # noqa: F401
    model_flops, roofline_terms, load_reports, build_table)
