"""Training step: loss, grads, AdamW update, remat policy."""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamWState, adamw_init, adamw_update

__all__ = ["TrainState", "init_train_state", "loss_fn", "make_train_step"]


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState


def init_train_state(cfg: ModelConfig, key, dtype=jnp.float32) -> TrainState:
    """Params in ``dtype`` (bf16 for mixed precision); AdamW m/v stay f32."""
    params = transformer.init_params(cfg, key, dtype)
    return TrainState(params=params, opt=adamw_init(params))


def _cross_entropy(logits, targets, mask):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def _chunked_ce_from_hidden(x, head, targets, mask, cap, chunk=512):
    """CE computed per sequence chunk — the (B,S,V) logits tensor never
    materializes (§Perf memory-term optimization for huge vocabularies)."""
    from repro.models.layers import softcap as _softcap
    B, S, _ = x.shape
    n = S // chunk if S % chunk == 0 else 1
    chunk = S // n
    xc = x.reshape(B, n, chunk, -1).swapaxes(0, 1)
    tc = targets.reshape(B, n, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, inp):
        xs, ts, ms = inp
        logits = _softcap(xs @ head, cap).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        return carry + ((logz - gold) * ms).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc, mc))
    return total / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg: ModelConfig, batch, *, ep_ctx=None,
            chunked_ce: bool = False, act_sharding=None,
            layer_remat: bool = False):
    """Next-token CE (text/vlm) or frame classification CE (audio)."""
    if chunked_ce and cfg.modality == "text":
        hidden, _, aux = transformer.forward(params, cfg, batch,
                                             ep_ctx=ep_ctx,
                                             return_hidden=True,
                                             act_sharding=act_sharding,
                                             layer_remat=layer_remat)
        targets = batch["tokens"][:, 1:]
        mask = jnp.ones(targets.shape, jnp.float32)
        head = params.get("lm_head")
        head = head if head is not None else params["embed"].T
        ce = _chunked_ce_from_hidden(hidden[:, :-1], head, targets, mask,
                                     cfg.final_logit_softcap)
        loss = ce + cfg.router_aux_coef * aux
        return loss, {"ce": ce, "aux": aux}
    logits, _, aux = transformer.forward(params, cfg, batch, ep_ctx=ep_ctx,
                                         act_sharding=act_sharding,
                                         layer_remat=layer_remat)
    if cfg.modality == "audio_frames":
        mask = jnp.ones(batch["labels"].shape, jnp.float32)
        ce = _cross_entropy(logits, batch["labels"], mask)
    elif cfg.modality == "image_patches":
        # loss on text positions only (patches occupy the prefix)
        n_p = batch["patches"].shape[1]
        text_logits = logits[:, n_p:-1]
        targets = batch["tokens"][:, 1:]
        mask = jnp.ones(targets.shape, jnp.float32)
        ce = _cross_entropy(text_logits, targets, mask)
    else:
        targets = batch["tokens"][:, 1:]
        mask = jnp.ones(targets.shape, jnp.float32)
        ce = _cross_entropy(logits[:, :-1], targets, mask)
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, *, lr=3e-4, remat: bool = True,
                    ep_ctx=None, chunked_ce: bool = False,
                    act_sharding=None, layer_remat: bool = False):
    """Build the jittable train_step(state, batch) -> (state, metrics)."""
    if layer_remat:
        remat = False            # per-layer remat supersedes whole-loss remat

    def step(state: TrainState, batch):
        kw = dict(ep_ctx=ep_ctx, chunked_ce=chunked_ce,
                  act_sharding=act_sharding, layer_remat=layer_remat)
        if remat:
            f = jax.checkpoint(
                functools.partial(loss_fn, cfg=cfg, **kw),
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                static_argnums=())
            grad_fn = jax.value_and_grad(lambda p: f(p, batch=batch),
                                         has_aux=True)
        else:
            grad_fn = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch, **kw), has_aux=True)
        (loss, metrics), grads = grad_fn(state.params)
        params, opt, gnorm = adamw_update(state.params, grads, state.opt,
                                          lr=lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(params=params, opt=opt), metrics

    return step
