"""Checkpointing: msgpack-free, numpy ``.npz`` of the flattened pytree.

Two stores live here:

* :func:`save` / :func:`restore` — path-keyed flat dict → npz; restore
  rebuilds with the same treedef.  Works for params, optimizer state,
  and LDA count tables alike (the original transformer-side store).

* :func:`save_chain` / :func:`load_chain` — the format-versioned LDA
  chain store (DESIGN.md §9).  A chain checkpoint is ``state`` (a flat
  ``str → ndarray`` dict: z in canonical order, compact count tables,
  r-bucket side tables, …) plus ``meta`` (a JSON-able dict carrying the
  format version, the RNG counter for the next sweep, and every
  chain-affecting knob so a mismatched resume fails loudly instead of
  silently forking the chain).  Writes are atomic (tmp + ``os.replace``)
  so a preemption mid-write never corrupts the previous checkpoint.

* :func:`save_phi` / :func:`load_phi` — the format-versioned φ snapshot
  store (DESIGN.md §10).  A φ snapshot is the frozen posterior-mean
  word-topic table the serving engine folds against — derived state, not
  the chain — published by ``NomadLDA.export_phi_snapshot`` and consumed
  by ``repro.serve.lda_engine``.  Same atomic-write discipline, its own
  ``PHI_FORMAT_VERSION`` gate (a serving fleet and a trainer upgrade on
  different schedules), and an integrity digest checked on load.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile

import jax
import numpy as np

__all__ = ["save", "restore", "save_chain", "load_chain", "save_phi",
           "load_phi", "CHAIN_FORMAT_VERSION", "PHI_FORMAT_VERSION"]

CHAIN_FORMAT_VERSION = 1
PHI_FORMAT_VERSION = 1


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz has no bf16: lossless f32
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, like):
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


# ---------------------------------------------------------------------------
# Format-versioned LDA chain store (DESIGN.md §9).
# ---------------------------------------------------------------------------
_META_KEY = "__chain_meta__"
_PHI_META_KEY = "__phi_meta__"


def _atomic_savez(path: str, payload: dict, meta: dict,
                  meta_key: str) -> str:
    """Write ``payload`` + JSON ``meta`` as one npz, atomically: the write
    goes to a temp file in the destination directory and is
    ``os.replace``d into place, so readers only ever see a complete
    file.  Returns the final path (``.npz`` appended if missing)."""
    if meta_key in payload:
        raise ValueError(f"state may not use the reserved key {meta_key!r}")
    if not path.endswith(".npz"):
        path = path + ".npz"
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    payload = dict(payload)
    payload[meta_key] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), np.uint8)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def save_chain(path: str, state: dict[str, np.ndarray], meta: dict) -> None:
    """Atomically write a chain checkpoint (``state`` arrays + ``meta``).

    ``meta`` must be JSON-able; ``format_version`` is stamped here.
    """
    meta = dict(meta)
    meta["format_version"] = CHAIN_FORMAT_VERSION
    _atomic_savez(path, {k: np.asarray(v) for k, v in state.items()},
                  meta, _META_KEY)


def load_chain(path: str) -> tuple[dict[str, np.ndarray], dict]:
    """Read a chain checkpoint; raises on unknown format versions."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path) as data:
        if _META_KEY not in data:
            raise ValueError(
                f"{path} is not a chain checkpoint (no {_META_KEY})")
        meta = json.loads(bytes(data[_META_KEY].tobytes()).decode())
        ver = meta.get("format_version")
        if ver != CHAIN_FORMAT_VERSION:
            raise ValueError(
                f"chain checkpoint format v{ver} unsupported (this build "
                f"reads v{CHAIN_FORMAT_VERSION})")
        state = {k: data[k] for k in data.files if k != _META_KEY}
    return state, meta


# ---------------------------------------------------------------------------
# Format-versioned φ snapshot store (DESIGN.md §10).
# ---------------------------------------------------------------------------
def phi_digest(phi: np.ndarray) -> str:
    """Content digest of a φ table — the torn-read/corruption detector the
    serving engine threads through every answer."""
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(phi, np.float32)).tobytes()
    ).hexdigest()


def save_phi(path: str, phi: np.ndarray, meta: dict) -> None:
    """Atomically write a φ snapshot (``(J, T)`` f32 table + ``meta``).

    ``format_version`` and the integrity ``digest`` are stamped here;
    ``meta`` must be JSON-able.
    """
    phi = np.asarray(phi, np.float32)
    if phi.ndim != 2:
        raise ValueError(f"phi must be a (J, T) table; got shape {phi.shape}")
    meta = dict(meta)
    meta["format_version"] = PHI_FORMAT_VERSION
    meta["J"], meta["T"] = int(phi.shape[0]), int(phi.shape[1])
    meta["digest"] = phi_digest(phi)
    _atomic_savez(path, {"phi": phi}, meta, _PHI_META_KEY)


def load_phi(path: str) -> tuple[np.ndarray, dict]:
    """Read a φ snapshot; refuses unknown format versions and corrupt
    (digest-mismatched) tables — a serving fleet must never fold against
    a φ it cannot prove it understands."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path) as data:
        if _PHI_META_KEY not in data:
            raise ValueError(f"{path} is not a φ snapshot (no "
                             f"{_PHI_META_KEY})")
        meta = json.loads(bytes(data[_PHI_META_KEY].tobytes()).decode())
        ver = meta.get("format_version")
        if ver != PHI_FORMAT_VERSION:
            raise ValueError(
                f"φ snapshot format v{ver} unsupported (this build reads "
                f"v{PHI_FORMAT_VERSION})")
        phi = np.asarray(data["phi"], np.float32)
    if phi.shape != (meta.get("J"), meta.get("T")):
        raise ValueError(
            f"φ snapshot shape {phi.shape} does not match its meta "
            f"({meta.get('J')}, {meta.get('T')})")
    got = phi_digest(phi)
    if meta.get("digest") not in (None, got):
        raise ValueError("φ snapshot digest mismatch — corrupt or "
                         "hand-edited table")
    return phi, meta
