"""Checkpointing: msgpack-free, numpy ``.npz`` of the flattened pytree.

Path-keyed flat dict → npz; restore rebuilds with the same treedef.  Works
for params, optimizer state, and LDA count tables alike.
"""
from __future__ import annotations

import os

import jax
import numpy as np

__all__ = ["save", "restore"]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz has no bf16: lossless f32
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, like):
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
