"""Checkpointing: msgpack-free, numpy ``.npz`` of the flattened pytree.

Two stores live here:

* :func:`save` / :func:`restore` — path-keyed flat dict → npz; restore
  rebuilds with the same treedef.  Works for params, optimizer state,
  and LDA count tables alike (the original transformer-side store).

* :func:`save_chain` / :func:`load_chain` — the format-versioned LDA
  chain store (DESIGN.md §9).  A chain checkpoint is ``state`` (a flat
  ``str → ndarray`` dict: z in canonical order, compact count tables,
  r-bucket side tables, …) plus ``meta`` (a JSON-able dict carrying the
  format version, the RNG counter for the next sweep, and every
  chain-affecting knob so a mismatched resume fails loudly instead of
  silently forking the chain).  Writes are atomic (tmp + ``os.replace``)
  so a preemption mid-write never corrupts the previous checkpoint.

* :func:`save_phi` / :func:`load_phi` — the format-versioned φ snapshot
  store (DESIGN.md §10).  A φ snapshot is the frozen posterior-mean
  word-topic table the serving engine folds against — derived state, not
  the chain — published by ``NomadLDA.export_phi_snapshot`` and consumed
  by ``repro.serve.lda_engine``.  Same atomic-write discipline, its own
  ``PHI_FORMAT_VERSION`` gate (a serving fleet and a trainer upgrade on
  different schedules), and an integrity digest checked on load.

Failure model (DESIGN.md §11): every write is atomic (tmp + ``os.replace``)
**and durable** (the tmp file and its directory are fsynced, so a host
crash after the rename cannot lose the entry); every payload array gets a
per-key sha256 in meta, verified on load.  Damage — truncation, flipped
bytes, missing meta — surfaces as :class:`repro.fault.SnapshotCorruptError`
and an unknown format version as :class:`repro.fault.FormatVersionError`
(both ``ValueError`` subclasses), so recovery code can tell *skip this
slot* from *this build cannot read the store*.  :class:`CheckpointRotation`
turns those typed errors into self-healing: it keeps the last ``keep``
slots plus a last-good pointer, and ``load_latest_valid`` walks slots
newest-first past any damaged ones — the fallback ``NomadLDA.run``
resumes from bit-exactly.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile

import jax
import numpy as np

from repro.fault import fire as _fault_fire
from repro.fault.errors import (FormatVersionError, SnapshotCorruptError,
                                SnapshotDigestError)

__all__ = ["save", "restore", "save_chain", "load_chain", "save_phi",
           "load_phi", "CheckpointRotation", "CHAIN_FORMAT_VERSION",
           "PHI_FORMAT_VERSION", "SnapshotCorruptError",
           "FormatVersionError"]

CHAIN_FORMAT_VERSION = 1
PHI_FORMAT_VERSION = 1


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz has no bf16: lossless f32
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, like):
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


# ---------------------------------------------------------------------------
# Format-versioned LDA chain store (DESIGN.md §9).
# ---------------------------------------------------------------------------
_META_KEY = "__chain_meta__"
_PHI_META_KEY = "__phi_meta__"


def _fsync_dir(d: str) -> None:
    """fsync a directory so a completed ``os.replace`` survives a host
    crash (the rename itself lives in the directory's metadata)."""
    fd = os.open(d, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _array_digest(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def _atomic_savez(path: str, payload: dict, meta: dict,
                  meta_key: str, *, fault_site: str | None = None) -> str:
    """Write ``payload`` + JSON ``meta`` as one npz, atomically AND
    durably: the write goes to a temp file in the destination directory,
    is fsynced, ``os.replace``d into place, and the directory is fsynced
    — so readers only ever see a complete file and a host crash at any
    point keeps either the old entry or the new one, never neither.
    Per-payload sha256 digests are stamped into meta
    (``payload_sha256``), verified by the loaders.  Returns the final
    path (``.npz`` appended if missing).  ``fault_site`` names the
    injection site fired *after* the durable write — the hook the fault
    layer uses to model bit rot / partial writes surfacing later."""
    if meta_key in payload:
        raise ValueError(f"state may not use the reserved key {meta_key!r}")
    if not path.endswith(".npz"):
        path = path + ".npz"
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    meta = dict(meta)
    meta["payload_sha256"] = {k: _array_digest(np.asarray(v))
                              for k, v in payload.items()}
    payload = dict(payload)
    payload[meta_key] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), np.uint8)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(d)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    if fault_site is not None:
        _fault_fire(fault_site, path=path)
    return path


def _verify_payload_digests(path: str, state: dict, meta: dict) -> None:
    """Check every loaded array against the per-key sha256 stamped at
    write time (absent in pre-§11 checkpoints: nothing to verify)."""
    want = meta.get("payload_sha256") or {}
    for k, arr in state.items():
        exp = want.get(k)
        if exp is not None and _array_digest(arr) != exp:
            raise SnapshotDigestError(
                f"{path}: payload {k!r} sha256 digest mismatch — corrupt "
                f"or truncated entry")


def save_chain(path: str, state: dict[str, np.ndarray], meta: dict) -> str:
    """Atomically + durably write a chain checkpoint (``state`` arrays +
    ``meta``) → the final path.  ``meta`` must be JSON-able;
    ``format_version`` and per-payload digests are stamped here.
    """
    meta = dict(meta)
    meta["format_version"] = CHAIN_FORMAT_VERSION
    return _atomic_savez(path, {k: np.asarray(v) for k, v in state.items()},
                         meta, _META_KEY, fault_site="chain.write")


def load_chain(path: str) -> tuple[dict[str, np.ndarray], dict]:
    """Read a chain checkpoint.  Typed failure surface (DESIGN.md §11):
    damage of any shape — truncated archive, flipped payload byte,
    missing ``__chain_meta__``, per-payload digest mismatch — raises
    :class:`SnapshotCorruptError`; an unknown ``format_version`` raises
    :class:`FormatVersionError`; a missing file stays
    ``FileNotFoundError``.  Rotation fallback skips the first kind of
    slot and hard-stops on the second."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    try:
        with np.load(path) as data:
            if _META_KEY not in data:
                raise SnapshotCorruptError(
                    f"{path} is not a chain checkpoint (no {_META_KEY})")
            meta = json.loads(bytes(data[_META_KEY].tobytes()).decode())
            ver = meta.get("format_version")
            if ver != CHAIN_FORMAT_VERSION:
                raise FormatVersionError(
                    f"chain checkpoint format v{ver} unsupported (this "
                    f"build reads v{CHAIN_FORMAT_VERSION})")
            # force every member read inside the guard: a truncated zip
            # member fails here, not at first use
            state = {k: np.asarray(data[k]) for k in data.files
                     if k != _META_KEY}
    except (SnapshotCorruptError, FormatVersionError):
        raise
    except Exception as e:      # BadZipFile, zlib/OSError, bad JSON, ...
        raise SnapshotCorruptError(
            f"unreadable chain checkpoint {path}: {e!r}") from e
    _verify_payload_digests(path, state, meta)
    return state, meta


# ---------------------------------------------------------------------------
# Self-healing checkpoint rotation (DESIGN.md §11).
# ---------------------------------------------------------------------------
class CheckpointRotation:
    """A directory of rotating chain-checkpoint slots with a last-good
    pointer — the multi-day-run store.

    Layout: ``root/slot-{step:08d}.npz`` (``step`` = the chain's
    ``next_seed`` at the checkpoint, i.e. sweeps completed) plus
    ``root/LAST_GOOD`` (a JSON pointer ``{"step": ...}``, atomically
    replaced and fsynced after every successful slot write).  The newest
    ``keep`` slots are retained; older ones are pruned, except a slot
    the pointer still names.

    Recovery contract: the pointer is **advisory provenance** — the
    fault model explicitly includes damage that lands *after* a durable
    write (bit rot, a torn mirror copy), so :meth:`load_latest_valid`
    never trusts it.  It walks the slots newest-first, returning the
    first one ``load_chain`` fully validates (meta present, format
    version known, every payload digest matching), and reports what it
    skipped.  Only when every slot is damaged does it raise
    :class:`SnapshotCorruptError`; a :class:`FormatVersionError` always
    propagates (no amount of slot-walking fixes a version skew — every
    slot was written by the same build).
    """

    POINTER = "LAST_GOOD"

    def __init__(self, root: str, *, keep: int = 3):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.root = root
        self.keep = int(keep)

    def slot_path(self, step: int) -> str:
        return os.path.join(self.root, f"slot-{int(step):08d}.npz")

    def slots(self) -> list[tuple[int, str]]:
        """All present slots as ``(step, path)``, ascending by step."""
        if not os.path.isdir(self.root):
            return []
        out = []
        for name in os.listdir(self.root):
            if name.startswith("slot-") and name.endswith(".npz"):
                try:
                    out.append((int(name[5:-4]),
                                os.path.join(self.root, name)))
                except ValueError:
                    continue
        return sorted(out)

    def last_good(self) -> int | None:
        """The advisory pointer's step (``None`` if absent/unreadable)."""
        try:
            with open(os.path.join(self.root, self.POINTER)) as f:
                return int(json.load(f)["step"])
        except (OSError, ValueError, KeyError, json.JSONDecodeError):
            return None

    def _promote(self, step: int) -> None:
        """Atomically + durably point ``LAST_GOOD`` at ``step``."""
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".ptr.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"step": int(step),
                           "slot": os.path.basename(self.slot_path(step))},
                          f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, os.path.join(self.root, self.POINTER))
            _fsync_dir(self.root)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def _prune(self) -> None:
        slots = self.slots()
        if len(slots) <= self.keep:
            return
        pinned = self.last_good()
        for step, path in slots[:-self.keep]:
            if step == pinned:
                continue
            try:
                os.unlink(path)
            except OSError:
                pass

    def save(self, state: dict[str, np.ndarray], meta: dict, *,
             step: int) -> str:
        """Write slot ``step`` (atomic + durable), promote the pointer,
        prune old slots → the slot path.  Fault injection at the
        ``"chain.write"`` site (inside :func:`save_chain`) lands on the
        slot *after* the durable write — exactly the
        damage-after-success window rotation exists to survive."""
        os.makedirs(self.root, exist_ok=True)
        path = save_chain(self.slot_path(step), state, meta)
        self._promote(step)
        self._prune()
        return path

    def load_latest_valid(self) -> tuple[dict[str, np.ndarray], dict, int]:
        """→ ``(state, meta, step)`` of the newest slot that validates,
        skipping corrupt/truncated ones (each skip is the self-healing
        fallback).  Raises ``FileNotFoundError`` when there are no slots
        at all, :class:`SnapshotCorruptError` when every slot is damaged
        and :class:`FormatVersionError` on the first version skew."""
        slots = self.slots()
        if not slots:
            raise FileNotFoundError(
                f"no checkpoint slots in {self.root!r}")
        skipped = []
        for step, path in reversed(slots):
            try:
                state, meta = load_chain(path)
                return state, meta, step
            except FormatVersionError:
                raise
            except (SnapshotCorruptError, FileNotFoundError) as e:
                skipped.append(f"slot {step}: {e}")
        raise SnapshotCorruptError(
            f"every checkpoint slot in {self.root!r} is damaged: "
            + "; ".join(skipped))


# ---------------------------------------------------------------------------
# Format-versioned φ snapshot store (DESIGN.md §10).
# ---------------------------------------------------------------------------
def phi_digest(phi: np.ndarray) -> str:
    """Content digest of a φ table — the torn-read/corruption detector the
    serving engine threads through every answer."""
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(phi, np.float32)).tobytes()
    ).hexdigest()


def save_phi(path: str, phi: np.ndarray, meta: dict) -> str:
    """Atomically + durably write a φ snapshot (``(J, T)`` f32 table +
    ``meta``) → the final path.  ``format_version`` and the integrity
    ``digest`` are stamped here; ``meta`` must be JSON-able.
    """
    phi = np.asarray(phi, np.float32)
    if phi.ndim != 2:
        raise ValueError(f"phi must be a (J, T) table; got shape {phi.shape}")
    meta = dict(meta)
    meta["format_version"] = PHI_FORMAT_VERSION
    meta["J"], meta["T"] = int(phi.shape[0]), int(phi.shape[1])
    meta["digest"] = phi_digest(phi)
    return _atomic_savez(path, {"phi": phi}, meta, _PHI_META_KEY,
                         fault_site="phi.write")


def load_phi(path: str) -> tuple[np.ndarray, dict]:
    """Read a φ snapshot; refuses unknown format versions
    (:class:`FormatVersionError`) and damaged tables — truncated archive,
    digest mismatch, meta/shape skew — as :class:`SnapshotCorruptError`.
    A serving fleet must never fold against a φ it cannot prove it
    understands, and retry logic needs to tell transient damage (a
    publisher mid-write: retry) from version skew (never retry)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    try:
        with np.load(path) as data:
            if _PHI_META_KEY not in data:
                raise SnapshotCorruptError(f"{path} is not a φ snapshot "
                                           f"(no {_PHI_META_KEY})")
            meta = json.loads(bytes(data[_PHI_META_KEY].tobytes()).decode())
            ver = meta.get("format_version")
            if ver != PHI_FORMAT_VERSION:
                raise FormatVersionError(
                    f"φ snapshot format v{ver} unsupported (this build "
                    f"reads v{PHI_FORMAT_VERSION})")
            phi = np.asarray(data["phi"], np.float32)
    except (SnapshotCorruptError, FormatVersionError):
        raise
    except Exception as e:      # BadZipFile, zlib/OSError, bad JSON, ...
        raise SnapshotCorruptError(
            f"unreadable φ snapshot {path}: {e!r}") from e
    # Past this point the archive parsed end to end — writers rename
    # atomically, so content-vs-meta contradictions are permanent damage
    # (SnapshotDigestError), not a publisher mid-write worth retrying.
    if phi.shape != (meta.get("J"), meta.get("T")):
        raise SnapshotDigestError(
            f"φ snapshot shape {phi.shape} does not match its meta "
            f"({meta.get('J')}, {meta.get('T')})")
    got = phi_digest(phi)
    if meta.get("digest") not in (None, got):
        raise SnapshotDigestError("φ snapshot digest mismatch — corrupt "
                                  "or hand-edited table")
    return phi, meta
