"""AdamW with decoupled weight decay + cosine schedule (built from scratch).

Optimizer state is a pytree mirroring params (m, v in f32) — under pjit the
state inherits the parameter sharding, which is what makes FSDP-style
sharded optimizer states fall out for free.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule"]


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """One AdamW step with global-norm clipping. lr: scalar or callable."""
    step = state.step + 1
    if callable(lr):
        lr = lr(step)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm


def cosine_schedule(peak_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * peak_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr
