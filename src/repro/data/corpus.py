"""Bag-of-words corpus representation.

The corpus is the hyper-edge list of the paper's access graph (Fig. 2): one
entry per word *occurrence*, i.e. flat parallel arrays

    doc_ids  (N,) int32   document index i of each occurrence
    word_ids (N,) int32   vocabulary index j of each occurrence

plus derived orderings.  The LDA state (topic assignment ``z`` and the three
count tables) lives next to it in :mod:`repro.core.cgs`.

Orders:
    ``doc_order``  — occurrences sorted by (doc, position): doc-by-doc sweeps.
    ``word_order`` — occurrences sorted by (word, doc): word-by-word sweeps
                     (Alg. 3); ``word_boundary`` flags the first occurrence of
                     each vocabulary item in this order.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Corpus"]


@dataclass(frozen=True)
class Corpus:
    doc_ids: np.ndarray          # (N,) int32
    word_ids: np.ndarray         # (N,) int32
    num_docs: int                # I
    num_words: int               # J (vocabulary size)

    def __post_init__(self):
        # Explicit ValueErrors, not asserts: validation must survive
        # ``python -O``, and these arrays now also arrive from on-disk
        # corpus-store shards (repro.data.corpus_store), not just code.
        d, w = self.doc_ids, self.word_ids
        if d.ndim != 1 or d.shape != w.shape:
            raise ValueError(
                f"doc_ids/word_ids must be 1-D parallel arrays; got shapes "
                f"{d.shape} and {w.shape}")
        if d.dtype != np.int32 or w.dtype != np.int32:
            raise ValueError(
                f"doc_ids/word_ids must be int32; got {d.dtype} and "
                f"{w.dtype}")
        if self.num_docs < 0 or self.num_words < 0:
            raise ValueError(
                f"num_docs/num_words must be >= 0; got {self.num_docs}, "
                f"{self.num_words}")
        if d.size:
            if int(d.min()) < 0 or int(d.max()) >= self.num_docs:
                raise ValueError(
                    f"doc_ids out of range [0, {self.num_docs}): "
                    f"[{d.min()}, {d.max()}]")
            if int(w.min()) < 0 or int(w.max()) >= self.num_words:
                raise ValueError(
                    f"word_ids out of range [0, {self.num_words}): "
                    f"[{w.min()}, {w.max()}]")

    @property
    def num_tokens(self) -> int:
        return int(self.doc_ids.shape[0])

    # ---- sweep orders -----------------------------------------------------
    def doc_order(self) -> np.ndarray:
        """Occurrence permutation for document-by-document sweeps."""
        return np.argsort(self.doc_ids, kind="stable").astype(np.int32)

    def word_order(self) -> np.ndarray:
        """Occurrence permutation for word-by-word sweeps (paper Alg. 3)."""
        return np.argsort(self.word_ids, kind="stable").astype(np.int32)

    def word_boundary(self, order: np.ndarray | None = None) -> np.ndarray:
        """Bool flags: token k (in word order) starts a new vocabulary item."""
        order = self.word_order() if order is None else order
        w = self.word_ids[order]
        return np.concatenate([[True], w[1:] != w[:-1]])

    # ---- stats ------------------------------------------------------------
    def doc_lengths(self) -> np.ndarray:
        return np.bincount(self.doc_ids, minlength=self.num_docs)

    def word_freqs(self) -> np.ndarray:
        return np.bincount(self.word_ids, minlength=self.num_words)

    @staticmethod
    def from_dense(counts: np.ndarray) -> "Corpus":
        """Build from a dense doc×word count matrix (tests / tiny corpora)."""
        I, J = counts.shape
        docs, words = np.nonzero(counts)
        reps = counts[docs, words]
        doc_ids = np.repeat(docs, reps).astype(np.int32)
        word_ids = np.repeat(words, reps).astype(np.int32)
        return Corpus(doc_ids=doc_ids, word_ids=word_ids,
                      num_docs=I, num_words=J)

    def subset(self, doc_mask: np.ndarray) -> "Corpus":
        """Restrict to documents where ``doc_mask`` is True (ids preserved)."""
        keep = doc_mask[self.doc_ids]
        return Corpus(doc_ids=self.doc_ids[keep], word_ids=self.word_ids[keep],
                      num_docs=self.num_docs, num_words=self.num_words)
