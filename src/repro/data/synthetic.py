"""Synthetic corpora drawn from the LDA generative process (paper §2).

Used for all experiments (no network access): topics φ_k ~ Dirichlet(β) over
a Zipf-weighted vocabulary, per-document θ_i ~ Dirichlet(α), document lengths
log-normal — mimicking the UCI bag-of-words statistics (Enron/NyTimes scale
is reachable by turning the knobs).
"""
from __future__ import annotations

import numpy as np

from repro.data.corpus import Corpus

__all__ = ["make_corpus", "SyntheticCorpusSpec"]


def make_corpus(
    *,
    num_docs: int,
    vocab_size: int,
    num_topics: int,
    mean_doc_len: float = 80.0,
    alpha: float = 0.1,
    beta: float = 0.01,
    zipf_a: float = 1.1,
    seed: int = 0,
) -> tuple[Corpus, np.ndarray, np.ndarray]:
    """Sample (corpus, true_theta, true_phi) from the LDA generative process.

    Vocabulary gets a Zipf tilt on top of Dirichlet(β) topics so word
    frequencies are realistically skewed (important: the nomad word-block
    load balancing is only interesting under skew).
    """
    rng = np.random.default_rng(seed)
    # Topic-word distributions with Zipf prior tilt.
    zipf = 1.0 / np.arange(1, vocab_size + 1) ** zipf_a
    rng.shuffle(zipf)
    phi = rng.dirichlet(np.full(vocab_size, beta) + beta * vocab_size *
                        zipf / zipf.sum(), size=num_topics)
    theta = rng.dirichlet(np.full(num_topics, alpha), size=num_docs)

    lengths = np.maximum(
        1, rng.lognormal(np.log(mean_doc_len), 0.6, size=num_docs).astype(int))
    N = int(lengths.sum())
    doc_ids = np.repeat(np.arange(num_docs, dtype=np.int32), lengths)
    # Topic per token, then word per token — vectorized inverse-CDF draws.
    z = _sample_rows(rng, theta, doc_ids)
    word_ids = _sample_rows(rng, phi, z).astype(np.int32)
    return (Corpus(doc_ids=doc_ids, word_ids=word_ids,
                   num_docs=num_docs, num_words=vocab_size),
            theta, phi)


def _sample_rows(rng: np.random.Generator, table: np.ndarray,
                 rows: np.ndarray) -> np.ndarray:
    """Draw one categorical sample from ``table[rows[k]]`` for each k."""
    cdf = np.cumsum(table, axis=1)
    cdf /= cdf[:, -1:]
    u = rng.random(rows.shape[0])
    # searchsorted per row via the "offset trick": each row's cdf is in [0,1];
    # add the row index so rows occupy disjoint unit intervals.
    flat = (cdf[rows] + np.arange(rows.shape[0])[:, None]).ravel()
    targets = u + np.arange(rows.shape[0])
    idx = np.searchsorted(flat, targets, side="right")
    # flat position = k * T + idx_within_row
    return (idx - np.arange(rows.shape[0]) * table.shape[1]).astype(np.int32)


class SyntheticCorpusSpec:
    """Named corpus presets scaled down from the paper's Table 3."""

    PRESETS = {
        # name: (num_docs, vocab, topics, mean_len)  — scaled-down analogues
        "enron-xs": (400, 512, 16, 60.0),
        "enron-sm": (2_000, 2_048, 64, 80.0),
        "nytimes-sm": (6_000, 4_096, 64, 120.0),
        "pubmed-sm": (20_000, 8_192, 128, 90.0),
    }

    @classmethod
    def make(cls, name: str, seed: int = 0):
        d, v, t, ml = cls.PRESETS[name]
        return make_corpus(num_docs=d, vocab_size=v, num_topics=t,
                           mean_doc_len=ml, seed=seed)
