"""Out-of-core chunked corpus store + streaming/incremental layout builds.

The paper's target regime is millions of documents and billions of tokens;
holding the flat occurrence arrays (``data/corpus.py``) host-side is the
scaling ceiling once the per-worker shards are HBM-bound (DESIGN.md §7).
This module replaces the monolithic ingestion path with three pieces:

**The store** (:class:`CorpusStore`): an append-only directory of token
shards — each an ``.npz`` with the shard's ``doc_ids``/``word_ids`` slice
plus per-shard doc/word occurrence stats — under a format-versioned
``meta.json``.  Shards are contiguous slices of the corpus occurrence
stream, so concatenating them in order reproduces the corpus exactly;
documents may span shards.  Marginal stats (``doc_lengths``,
``word_freqs``) aggregate from the per-shard stat arrays without touching
the token arrays (``np.load`` reads npz members lazily).

**Streaming build** (:func:`build_layout_from_store`): builds the
:class:`~repro.data.sharding.NomadLayout` from shard streams without ever
materializing the full ``doc_ids``/``word_ids``.  All global geometry is
derived from streamed *count* accumulators (doc lengths, word freqs, the
``(W, B)`` cell sizes and per-(cell, doc-group) segment counts), and the
token arrays are then filled one worker at a time: canonical order is
worker-major, and a stable per-worker sort of shard-streamed tokens equals
the global lexsort restricted to that worker — so the monolithic
:func:`~repro.data.sharding.build_layout` and this builder feed the same
``_LayoutAssembler`` and produce **byte-identical** layouts by
construction (property-tested in ``tests/test_sharding_properties.py``).
Peak memory is one worker's token slice plus the output arrays.

**Incremental add/retire** (:func:`update_layout`): documents join or
leave a *live* layout with only the touched (worker, block, doc-group)
segments re-padded.  Invariants (DESIGN.md §9):

- requires a ``doc_tile``-grouped layout: new docs start at a fresh
  doc-group boundary, so their tokens sort strictly after every existing
  token of the same cell and the canonical order of untouched tokens is
  preserved verbatim;
- surviving tokens keep their within-cell ``slot`` — and the stride ``L``
  is frozen — so live chains keep their counter-mode RNG uids
  (``uid = global_block·L + slot``, ``core/nomad.py``);
- new tokens get slots above the cell's historical high-water mark
  (retired slots are never reused while the cell still has survivors'
  slots above them; a cell whose demand would exceed ``L`` raises — that
  layout needs a full rebuild);
- retired docs leave ``-1`` holes in ``doc_of_worker``/``doc_assign``;
  consumers mask on ``>= 0`` (count tables keep zero rows).

The returned ``old_to_new`` canonical index map (``-1`` for retired
tokens) is what carries a live chain across the update
(:func:`remap_canonical` / :func:`carry_assignments`).
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.data import sharding
from repro.data.corpus import Corpus
from repro.data.sharding import NomadLayout

__all__ = ["CorpusStore", "build_layout_from_store", "update_layout",
           "remap_canonical", "carry_assignments", "STORE_FORMAT_VERSION"]

STORE_FORMAT_VERSION = 1
_META = "meta.json"
_RETIRED_WFREQ = "retired_wfreq.npy"


def _as_token_array(a, name: str) -> np.ndarray:
    """Validate + canonicalize one shard token/metadata array to int32."""
    a = np.asarray(a)
    if a.ndim != 1:
        raise ValueError(f"{name} must be 1-D, got shape {a.shape}")
    if not np.issubdtype(a.dtype, np.integer):
        raise ValueError(f"{name} must be an integer array, got {a.dtype}")
    if a.size and (int(a.min()) < np.iinfo(np.int32).min
                   or int(a.max()) > np.iinfo(np.int32).max):
        raise ValueError(f"{name} values overflow int32")
    return a.astype(np.int32)


class CorpusStore:
    """Append-only on-disk corpus shard store (module docstring).

    Layout on disk::

        <path>/meta.json            format version, sizes, shard index,
                                    retired doc ids
        <path>/shard-00000.npz      doc_ids, word_ids (the token slice)
                                    + stat_doc_ids/stat_doc_len,
                                      stat_word_ids/stat_word_freq
        <path>/retired_wfreq.npy    word-frequency mass of retired docs
                                    (subtracted from the stat aggregate)
    """

    def __init__(self, path: str, meta: dict):
        self.path = path
        self._meta = meta

    # -- construction -------------------------------------------------------
    @classmethod
    def create(cls, path: str, *, num_words: int,
               num_docs: int = 0) -> "CorpusStore":
        if num_words < 1:
            raise ValueError(f"num_words must be >= 1, got {num_words}")
        os.makedirs(path, exist_ok=True)
        if os.path.exists(os.path.join(path, _META)):
            raise FileExistsError(f"store already exists at {path}")
        store = cls(path, {
            "format_version": STORE_FORMAT_VERSION,
            "num_docs": int(num_docs), "num_words": int(num_words),
            "shards": [], "retired": []})
        store._write_meta()
        return store

    @classmethod
    def open(cls, path: str) -> "CorpusStore":
        with open(os.path.join(path, _META)) as f:
            meta = json.load(f)
        v = meta.get("format_version")
        if v != STORE_FORMAT_VERSION:
            raise ValueError(
                f"corpus store at {path} has format_version={v}; this "
                f"build reads version {STORE_FORMAT_VERSION}")
        return cls(path, meta)

    @classmethod
    def from_corpus(cls, corpus: Corpus, path: str, *,
                    tokens_per_shard: int = 1 << 20) -> "CorpusStore":
        """Chunk a materialized corpus into contiguous token-slice shards
        (round-trips exactly: shard order preserves occurrence order)."""
        if tokens_per_shard < 1:
            raise ValueError(
                f"tokens_per_shard must be >= 1, got {tokens_per_shard}")
        store = cls.create(path, num_words=corpus.num_words,
                           num_docs=corpus.num_docs)
        for lo in range(0, corpus.num_tokens, tokens_per_shard):
            hi = min(lo + tokens_per_shard, corpus.num_tokens)
            store.append(corpus.doc_ids[lo:hi], corpus.word_ids[lo:hi])
        return store

    def _write_meta(self) -> None:
        # atomic: a kill mid-write must not corrupt the store index
        tmp = os.path.join(self.path, _META + ".tmp")
        with open(tmp, "w") as f:
            json.dump(self._meta, f, indent=1)
        os.replace(tmp, os.path.join(self.path, _META))

    # -- properties ----------------------------------------------------------
    @property
    def num_docs(self) -> int:
        return self._meta["num_docs"]

    @property
    def num_words(self) -> int:
        return self._meta["num_words"]

    @property
    def num_shards(self) -> int:
        return len(self._meta["shards"])

    @property
    def retired(self) -> np.ndarray:
        return np.asarray(self._meta["retired"], np.int64)

    @property
    def num_tokens(self) -> int:
        """Live (non-retired) token count."""
        total = sum(s["n_tokens"] for s in self._meta["shards"])
        return total - int(self._retired_doc_lengths().sum())

    # -- ingestion -----------------------------------------------------------
    def append(self, doc_ids, word_ids, *, num_docs: int | None = None):
        """Append one shard of occurrences.  Doc ids may be new (the doc-id
        space grows) or existing (documents may span shards); ``num_docs``
        forces a larger doc-id space (for trailing empty documents)."""
        d = _as_token_array(doc_ids, "doc_ids")
        w = _as_token_array(word_ids, "word_ids")
        if d.shape != w.shape:
            raise ValueError(
                f"doc_ids/word_ids length mismatch: {d.shape} vs {w.shape}")
        if d.size and int(d.min()) < 0:
            raise ValueError(f"doc_ids must be >= 0, got min {d.min()}")
        if w.size and (int(w.min()) < 0 or int(w.max()) >= self.num_words):
            raise ValueError(
                f"word_ids out of range [0, {self.num_words}): "
                f"[{w.min()}, {w.max()}]")
        if d.size and self.retired.size and np.isin(d, self.retired).any():
            raise ValueError("cannot append occurrences of retired docs")
        sd, sl = np.unique(d, return_counts=True)
        sw, sf = np.unique(w, return_counts=True)
        name = f"shard-{self.num_shards:05d}.npz"
        np.savez(os.path.join(self.path, name),
                 doc_ids=d, word_ids=w,
                 stat_doc_ids=sd.astype(np.int32),
                 stat_doc_len=sl.astype(np.int64),
                 stat_word_ids=sw.astype(np.int32),
                 stat_word_freq=sf.astype(np.int64))
        self._meta["shards"].append({"file": name, "n_tokens": int(d.size)})
        nd = self.num_docs if num_docs is None else int(num_docs)
        if d.size:
            nd = max(nd, int(d.max()) + 1)
        self._meta["num_docs"] = nd
        self._write_meta()
        return self

    def retire(self, doc_ids) -> "CorpusStore":
        """Tombstone documents: their occurrences vanish from every stream
        and stat.  One pass over the shards containing them records the
        word-frequency mass to subtract from the stat aggregate."""
        ids = np.unique(np.asarray(doc_ids, np.int64))
        if ids.size == 0:
            return self
        if int(ids.min()) < 0 or int(ids.max()) >= self.num_docs:
            raise ValueError(
                f"retire ids out of range [0, {self.num_docs})")
        if np.isin(ids, self.retired).any():
            raise ValueError("some doc ids are already retired")
        delta = np.zeros(self.num_words, np.int64)
        for s in self._meta["shards"]:
            with np.load(os.path.join(self.path, s["file"])) as z:
                if not np.isin(z["stat_doc_ids"], ids).any():
                    continue
                m = np.isin(z["doc_ids"], ids)
                np.add.at(delta, z["word_ids"][m], 1)
        old = self._retired_wfreq()
        np.save(os.path.join(self.path, _RETIRED_WFREQ), old + delta)
        self._meta["retired"] = sorted(
            set(self._meta["retired"]) | set(map(int, ids)))
        self._write_meta()
        return self

    # -- streams & stats ------------------------------------------------------
    def iter_tokens(self, include_retired: bool = False):
        """Yield ``(doc_ids, word_ids)`` per shard, in corpus order."""
        retired = self.retired
        for s in self._meta["shards"]:
            with np.load(os.path.join(self.path, s["file"])) as z:
                d, w = z["doc_ids"], z["word_ids"]
            if not include_retired and retired.size:
                keep = ~np.isin(d, retired)
                d, w = d[keep], w[keep]
            yield d, w

    def _retired_wfreq(self) -> np.ndarray:
        p = os.path.join(self.path, _RETIRED_WFREQ)
        if os.path.exists(p):
            a = np.load(p)
            if a.shape != (self.num_words,) or a.dtype != np.int64:
                raise ValueError(
                    f"corrupt {_RETIRED_WFREQ}: expected "
                    f"({self.num_words},) int64, got {a.shape} {a.dtype}")
            return a
        return np.zeros(self.num_words, np.int64)

    def _retired_doc_lengths(self) -> np.ndarray:
        """(num_docs,) lengths of retired docs only (0 elsewhere)."""
        out = np.zeros(self.num_docs, np.int64)
        if not self._meta["retired"]:
            return out
        retired = self.retired
        for s in self._meta["shards"]:
            with np.load(os.path.join(self.path, s["file"])) as z:
                ids, ln = z["stat_doc_ids"], z["stat_doc_len"]
            m = np.isin(ids, retired)
            np.add.at(out, ids[m].astype(np.int64), ln[m])
        return out

    def doc_lengths(self) -> np.ndarray:
        """(num_docs,) live token count per doc — stats only, no token IO."""
        out = np.zeros(self.num_docs, np.int64)
        for s in self._meta["shards"]:
            with np.load(os.path.join(self.path, s["file"])) as z:
                np.add.at(out, z["stat_doc_ids"].astype(np.int64),
                          z["stat_doc_len"])
        out -= self._retired_doc_lengths()
        return out

    def word_freqs(self) -> np.ndarray:
        """(num_words,) live corpus frequency per word — stats only."""
        out = np.zeros(self.num_words, np.int64)
        for s in self._meta["shards"]:
            with np.load(os.path.join(self.path, s["file"])) as z:
                np.add.at(out, z["stat_word_ids"].astype(np.int64),
                          z["stat_word_freq"])
        return out - self._retired_wfreq()

    def to_corpus(self) -> Corpus:
        """Materialize the live occurrence stream (tests / small stores)."""
        parts = list(self.iter_tokens())
        d = (np.concatenate([p[0] for p in parts]) if parts
             else np.zeros(0, np.int32))
        w = (np.concatenate([p[1] for p in parts]) if parts
             else np.zeros(0, np.int32))
        return Corpus(doc_ids=d, word_ids=w, num_docs=self.num_docs,
                      num_words=self.num_words)


def build_layout_from_store(store: CorpusStore, *, n_workers: int, T: int,
                            n_blocks: int | None = None,
                            balance: bool = True, seed: int = 0,
                            layout: str = "dense",
                            tile: int | None = None,
                            doc_tile: int | None = None,
                            doc_blk: int | None = None) -> NomadLayout:
    """Build the nomad layout from shard streams — byte-identical to
    ``build_layout(store.to_corpus(), ...)`` without ever holding the full
    token arrays (module docstring).  Same signature as
    :func:`repro.data.sharding.build_layout`."""
    B = n_workers if n_blocks is None else n_blocks
    W = n_workers
    sharding._validate_build_args(W, B, layout, doc_tile, doc_blk)
    doc_lengths = store.doc_lengths()
    freqs = store.word_freqs()

    def freq_w(doc_assign):
        fw = np.zeros((W, store.num_words), np.int64)
        for d, wds in store.iter_tokens():
            np.add.at(fw, (doc_assign[d], wds), 1)
        return fw

    doc_assign, word_assign = sharding._plan_partition(
        doc_lengths, freqs, W=W, B=B, balance=balance, freq_w=freq_w)
    (doc_of_worker, doc_local, word_of_block, word_local,
     I_max, J_max) = sharding._local_maps(doc_assign, word_assign, W, B)

    dt = int(doc_tile) if doc_tile is not None else 0
    n_doc_tiles = max(-(-I_max // dt), 1) if dt else 1

    # count pass: everything the global geometry needs, streamed
    cell_sizes = np.zeros((W, B), np.int64)
    seg_counts = np.zeros((W * B, n_doc_tiles), np.int64) if dt else None
    n_tokens = 0
    for d, wds in store.iter_tokens():
        tw, tb = doc_assign[d], word_assign[wds]
        np.add.at(cell_sizes, (tw, tb), 1)
        if dt:
            g = (doc_local[d] // dt).astype(np.int64)
            np.add.at(seg_counts, (tw.astype(np.int64) * B + tb, g), 1)
        n_tokens += d.size
    gran, tile = sharding._resolve_gran(layout, dt, doc_blk, tile,
                                        cell_sizes)
    geom = sharding._build_geometry(
        cell_sizes, seg_counts, layout=layout, W=W, B=B, dt=dt, gran=gran,
        n_doc_tiles=n_doc_tiles, tile=tile)
    asm = sharding._LayoutAssembler(geom, n_tokens)

    # fill pass, one worker at a time: gather worker w's tokens in shard
    # order (= corpus order, so sort ties match the monolithic lexsort),
    # stable-sort by (block[, group], word), place.
    for w in range(W):
        pd, pw = [], []
        for d, wds in store.iter_tokens():
            m = doc_assign[d] == w
            if m.any():
                pd.append(d[m])
                pw.append(wds[m])
        dw = np.concatenate(pd) if pd else np.zeros(0, np.int32)
        jw = np.concatenate(pw) if pw else np.zeros(0, np.int32)
        tbw = word_assign[jw]
        if dt:
            sgw = (doc_local[dw] // dt).astype(np.int64)
            order = np.lexsort((jw, sgw, tbw)).astype(np.int64)
        else:
            sgw = None
            order = np.lexsort((jw, tbw)).astype(np.int64)
        asm.add_worker(w, tbw[order], doc_local[dw[order]],
                       word_local[jw[order]], jw[order],
                       sgw[order] if dt else None)

    r_cap = max(1, min(T, int(doc_lengths.max(initial=1))))
    return asm.finish(
        T=T, num_words=store.num_words, doc_of_worker=doc_of_worker,
        word_of_block=word_of_block, I_max=I_max, J_max=J_max,
        doc_assign=doc_assign, word_assign=word_assign,
        cell_sizes=cell_sizes, r_cap=r_cap)


def update_layout(lay: NomadLayout, *, add_doc_ids=None, add_word_ids=None,
                  retire=None, num_new_docs: int | None = None):
    """Incremental doc add/retire with localized layout rebuild.

    Returns ``(new_layout, old_to_new)`` where ``old_to_new`` maps each
    old canonical token index to its new canonical index (``-1`` for
    tokens of retired docs).  See the module docstring for the
    order/slot/uid invariants; the canonical order of surviving tokens is
    preserved verbatim, only touched (worker, block, doc-group) segments
    re-pad, and the RNG stride ``L`` is frozen.

    ``add_doc_ids``/``add_word_ids`` are the new documents' occurrences
    with *fresh* global doc ids (``>= lay.doc_assign.shape[0]``);
    ``retire`` is an iterable of existing doc ids to drop.
    """
    dt = lay.doc_tile
    if dt <= 0:
        raise ValueError(
            "incremental update needs a doc_tile-grouped layout: ungrouped "
            "layouts derive RNG uids from token position, so any insertion "
            "would re-key every live token's chain (rebuild instead, or "
            "build with doc_tile=)")
    W, B, T = lay.W, lay.B, lay.T
    num_docs_old = lay.doc_assign.shape[0]

    retired = np.unique(np.asarray(list(retire) if retire is not None
                                   else [], np.int64))
    if retired.size:
        if int(retired.min()) < 0 or int(retired.max()) >= num_docs_old:
            raise ValueError(
                f"retire ids out of range [0, {num_docs_old})")
        if (lay.doc_assign[retired] < 0).any():
            raise ValueError("some retire ids are already retired")

    # old tokens in canonical order
    ow, ob, odl, owl = lay.token_coords()
    ogd = lay.doc_of_worker[ow, odl]
    ogw = lay.extract_canonical(lay.tok_gwrd)
    oslot = lay.extract_canonical(lay.tok_slot).astype(np.int64)
    og = (odl // dt).astype(np.int64)
    n_old = ow.shape[0]
    keep = (~np.isin(ogd, retired) if retired.size
            else np.ones(n_old, bool))

    # new documents
    if add_doc_ids is None:
        ad = np.zeros(0, np.int64)
        aw = np.zeros(0, np.int64)
    else:
        ad = _as_token_array(add_doc_ids, "add_doc_ids").astype(np.int64)
        aw = _as_token_array(add_word_ids, "add_word_ids").astype(np.int64)
        if ad.shape != aw.shape:
            raise ValueError("add_doc_ids/add_word_ids length mismatch")
        if ad.size and int(ad.min()) < num_docs_old:
            raise ValueError(
                f"added documents must use fresh doc ids >= "
                f"{num_docs_old} (existing documents are immutable)")
        if aw.size and (int(aw.min()) < 0
                        or int(aw.max()) >= lay.num_words):
            raise ValueError(
                f"add_word_ids out of range [0, {lay.num_words})")
    num_new = (int(num_new_docs) if num_new_docs is not None
               else (int(ad.max()) + 1 - num_docs_old if ad.size else 0))
    if ad.size and int(ad.max()) >= num_docs_old + num_new:
        raise ValueError("num_new_docs smaller than the added id range")
    new_len = np.bincount(ad - num_docs_old, minlength=num_new) \
        if num_new else np.zeros(0, np.int64)

    # assign new docs to workers: LPT against the live loads
    import heapq
    live_loads = np.bincount(ow[keep], minlength=W)
    heap = [(int(live_loads[w]), w) for w in range(W)]
    heapq.heapify(heap)
    assign_new = np.zeros(num_new, np.int32)
    for i in np.argsort(-new_len, kind="stable"):
        load, w = heapq.heappop(heap)
        assign_new[i] = w
        heapq.heappush(heap, (load + int(new_len[i]), w))

    # local ids: each worker's new docs start at the next doc-group
    # boundary past its historical high-water mark (never reuse local
    # slots — retired rows stay holes), so new groups are strictly fresh.
    used = np.zeros(W, np.int64)
    for w in range(W):
        occ = np.nonzero(lay.doc_of_worker[w] >= 0)[0]
        used[w] = int(occ[-1]) + 1 if occ.size else 0
    ctr = -(-used // dt) * dt
    new_dloc = np.zeros(num_new, np.int64)
    for i in range(num_new):           # doc-id order → deterministic ids
        w = assign_new[i]
        new_dloc[i] = ctr[w]
        ctr[w] += 1
    recv = np.unique(assign_new) if num_new else np.zeros(0, np.int64)
    I_max_new = max(lay.I_max, int(ctr[recv].max()) if recv.size else 0)
    n_doc_tiles_new = max(-(-I_max_new // dt), 1)

    # doc bookkeeping
    doc_assign_new = np.concatenate(
        [lay.doc_assign, assign_new]).astype(np.int32)
    doc_assign_new[retired] = -1
    doc_of_worker_new = np.full((W, I_max_new), -1, np.int32)
    doc_of_worker_new[:, :lay.I_max] = lay.doc_of_worker
    if retired.size:
        doc_of_worker_new[np.isin(doc_of_worker_new, retired)] = -1
    new_gids = np.arange(num_docs_old, num_docs_old + num_new)
    doc_of_worker_new[assign_new, new_dloc] = new_gids

    # word-local map back from word_of_block
    word_local = np.zeros(lay.num_words, np.int32)
    for b in range(B):
        ids = lay.word_of_block[b]
        m = ids >= 0
        word_local[ids[m]] = np.nonzero(m)[0]

    # new tokens, sorted by (worker, block, group, word, arrival)
    tw_n = assign_new[ad - num_docs_old] if ad.size else np.zeros(0, np.int64)
    dl_n = new_dloc[ad - num_docs_old] if ad.size else np.zeros(0, np.int64)
    tb_n = lay.word_assign[aw] if ad.size else np.zeros(0, np.int64)
    g_n = dl_n // dt
    order_n = np.lexsort((aw, g_n, tb_n, tw_n)).astype(np.int64)
    tw_n, dl_n, tb_n, g_n, aw_s = (tw_n[order_n], dl_n[order_n],
                                   tb_n[order_n], g_n[order_n],
                                   aw[order_n])

    # slots: survivors keep theirs; new tokens continue above the cell's
    # historical high-water mark (uid stride L is frozen)
    hwm = np.zeros(W * B, np.int64)        # high-water mark = max slot + 1
    cellkey_old = ow * B + ob
    np.maximum.at(hwm, cellkey_old, oslot + 1)
    cellkey_n = tw_n.astype(np.int64) * B + tb_n
    slot_n = hwm[cellkey_n] + sharding._running_count(cellkey_n)
    # RNG-uid safety (uniforms are drawn from a per-worker key,
    # core/nomad.py): uid = global_block·L + slot, so a slot >= L would
    # alias into the next block's uid range.  Slots are arbitrary int32s
    # whose only job is the uid, so tokens that would overflow a cell's
    # normal [0, L) range instead take slots mapping into the per-worker
    # uid region past B·L — free by construction at build time (every
    # build-time uid is < B·L) and kept free across repeated updates by
    # continuing past the worker's live uid maximum.
    over = slot_n >= lay.L
    if over.any():
        uid_keep = ob[keep] * np.int64(lay.L) + oslot[keep]
        live_uid_max = np.full(W, np.int64(B) * lay.L - 1)
        np.maximum.at(live_uid_max, ow[keep], uid_keep)
        uid_over = (live_uid_max + 1)[tw_n[over]] \
            + sharding._running_count(tw_n[over])
        slot_n[over] = uid_over - tb_n[over].astype(np.int64) * lay.L
    if slot_n.size and int(slot_n.max(initial=0)) > np.iinfo(np.int32).max:
        raise ValueError(
            "overflow slots no longer fit int32 — the uid space is "
            "exhausted; rebuild the layout (build_layout_from_store)")

    # merge: old survivors (their canonical order intact) + new tokens.
    # New docs occupy strictly fresh doc-groups, so a stable sort on
    # (worker, block, group) alone restores the full canonical
    # (w, b, g, word) order — no (w, b, g) key ever mixes old and new.
    mw = np.concatenate([ow[keep], tw_n])
    mb = np.concatenate([ob[keep], tb_n])
    mg = np.concatenate([og[keep], g_n])
    mdl = np.concatenate([odl[keep].astype(np.int64), dl_n])
    mwl = np.concatenate([owl[keep].astype(np.int64),
                          word_local[aw_s].astype(np.int64)])
    mgw = np.concatenate([ogw.astype(np.int64)[keep], aw_s])
    mslot = np.concatenate([oslot[keep], slot_n])
    src = np.concatenate([np.nonzero(keep)[0],
                          np.full(tw_n.shape[0], -1, np.int64)])
    perm = np.lexsort((mg, mb, mw)).astype(np.int64)
    mw, mb, mg, mdl, mwl, mgw, mslot, src = (
        a[perm] for a in (mw, mb, mg, mdl, mwl, mgw, mslot, src))
    n_new_total = mw.shape[0]
    old_to_new = np.full(n_old, -1, np.int64)
    kept_pos = np.nonzero(src >= 0)[0]
    old_to_new[src[kept_pos]] = kept_pos

    # re-derive geometry from the merged counts (untouched cells get the
    # identical segment layout; touched segments re-pad) with L frozen
    cell_sizes_new = np.zeros((W, B), np.int64)
    np.add.at(cell_sizes_new, (mw, mb), 1)
    seg_counts_new = np.zeros((W * B, n_doc_tiles_new), np.int64)
    np.add.at(seg_counts_new, (mw * B + mb, mg), 1)
    geom = sharding._build_geometry(
        cell_sizes_new, seg_counts_new, layout=lay.kind, W=W, B=B, dt=dt,
        gran=lay.doc_blk, n_doc_tiles=n_doc_tiles_new, tile=lay.tile)
    geom.L = lay.L                       # freeze the RNG stride

    asm = sharding._LayoutAssembler(geom, n_new_total)
    w_bounds = np.searchsorted(mw, np.arange(W + 1))
    for w in range(W):
        lo, hi = int(w_bounds[w]), int(w_bounds[w + 1])
        asm.add_worker(w, mb[lo:hi], mdl[lo:hi], mwl[lo:hi], mgw[lo:hi],
                       mg[lo:hi], slot=mslot[lo:hi])

    r_cap = max(lay.r_cap,
                min(T, int(new_len.max())) if num_new else 1)
    new_lay = asm.finish(
        T=T, num_words=lay.num_words, doc_of_worker=doc_of_worker_new,
        word_of_block=lay.word_of_block, I_max=I_max_new, J_max=lay.J_max,
        doc_assign=doc_assign_new, word_assign=lay.word_assign,
        cell_sizes=cell_sizes_new, r_cap=r_cap)
    return new_lay, old_to_new


def remap_canonical(old_vals: np.ndarray, old_to_new: np.ndarray,
                    n_new: int, *, fill=0) -> np.ndarray:
    """Carry per-token canonical-order values across an
    :func:`update_layout` (retired entries dropped, new tokens ``fill``)."""
    out = np.full(n_new, fill, dtype=np.asarray(old_vals).dtype)
    m = old_to_new >= 0
    out[old_to_new[m]] = np.asarray(old_vals)[m]
    return out


def carry_assignments(z_canon_old: np.ndarray, old_to_new: np.ndarray,
                      new_lay: NomadLayout, *, seed: int = 0) -> np.ndarray:
    """Carry a live chain's canonical ``z`` across an update: surviving
    tokens keep their topics, new tokens draw fresh ones from ``seed``."""
    n_new = new_lay.canon_idx.shape[0]
    z = remap_canonical(z_canon_old, old_to_new, n_new, fill=-1)
    fresh = z < 0
    if fresh.any():
        rng = np.random.default_rng(seed)
        z[fresh] = rng.integers(0, new_lay.T, int(fresh.sum()))
    return z.astype(np.int32)
