from repro.data.corpus import Corpus  # noqa: F401
from repro.data.corpus_store import (  # noqa: F401
    CorpusStore,
    build_layout_from_store,
    carry_assignments,
    remap_canonical,
    update_layout,
)
