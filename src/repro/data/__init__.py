from repro.data.corpus import Corpus  # noqa: F401
