"""Data partition and subtask split for Nomad LDA (paper §4.1, Fig. 2b).

The corpus grid: documents are partitioned into ``W`` worker shards (block
rows of Fig. 2b) and the vocabulary into ``B`` word blocks (the nomadic
tokens).  Cell ``(w, b)`` holds every occurrence of a block-``b`` word inside
a worker-``w`` document, sorted by word id — the "unit subtask" t_j of the
paper, batched per block.

Load balance (DESIGN.md §3): the paper relies on asynchrony to absorb the
power-law skew of word frequencies; on a lock-step TPU mesh we instead
balance statically — greedy LPT bin-packing of documents by length and of
words by corpus frequency — and measure the residual imbalance.

Two token geometries (``layout=``, DESIGN.md §4/§7), both plain numpy
arrays ready to become sharded ``jax.Array``s:

``"dense"`` — the padded cell grid: every cell padded to the globally
heaviest cell length ``L``:

    tok_doc   (W, B, L) int32   local doc index (within worker shard)
    tok_wrd   (W, B, L) int32   local word index (within block)
    tok_gwrd  (W, B, L) int32   global word id (diagnostics)
    tok_valid (W, B, L) bool    padding mask
    tok_bound (W, B, L) bool    first occurrence of a word within the cell

``"ragged"`` — the CSR-style tile stream: per (worker, ring chunk) the
chunk's ``k`` cells are concatenated into ONE stream of ``tile``-token
tiles, each cell padded only up to its next tile multiple (and each
pipelined half-queue padded to its own global tile max, so the half split
is a *static tile split*).  Same five ``tok_*`` arrays with shape
``(W, W, S)`` — axis 1 is the ring *chunk* id, ``S = n_tiles·tile`` — plus

    cell_of_tile (W, W, n_tiles) int32  queue-local cell (0..k-1) per tile
    tok_slot     (W, W, S)       int32  slot of the token within its cell

Both layouts order valid tokens identically (by worker, block, word id) —
the *canonical* token order, recorded in ``canon_idx`` — so the per-token
Gibbs chain is bit-identical across layouts (the nomad sweep derives its
uniforms and initial ``z`` from canonical coordinates, ``core/nomad.py``).

``doc_tile`` (DESIGN.md §7) additionally partitions each worker's local
document rows into groups of ``doc_tile`` consecutive rows and refines the
canonical order to (worker, block, **doc group**, word id): every aligned
token tile then touches exactly one ``(doc_tile, T)`` slab of the
doc-topic table, which is what lets the fused kernels page the slab
through VMEM instead of holding the whole ``(I_max, T)`` shard resident.
The grouped order is itself a canonical order — dense, ragged, tiled and
untiled execution over the *same* layout all run the bit-identical chain —
but it differs from the ``doc_tile=None`` order, so ``doc_tile`` is a
layout-build-time choice, not a runtime switch.  ``doc_tile_of`` maps each
token tile (dense: ``doc_blk`` tokens, ragged: ``tile`` tokens) to its doc
group; ``tok_slot`` (emitted for dense layouts too when grouping) keeps
the per-token RNG ids position-independent exactly like the ragged stream.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.data.corpus import Corpus

__all__ = ["NomadLayout", "counts_from_layout", "lpt_assign",
           "build_layout", "half_queue_split", "default_ragged_tile"]


def _pow2_ceil(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _segments_from_counts(seg_counts: np.ndarray, gran: int):
    """Doc-group segment geometry from the ``(W·B, G)`` per-(cell, group)
    token-count accumulator, with each segment padded to a multiple of
    ``gran``.

    Segments are the non-empty (cell, group) pairs in cell-major, group-
    ascending order — exactly the runs a (cell, group)-sorted token stream
    produces, but derived purely from counts so the chunked store builder
    (:mod:`repro.data.corpus_store`) can accumulate them shard by shard
    without the token arrays.  Returns ``(seg_cell, seg_g, seg_start,
    seg_pad, cell_pad, seg_start_arr)``: per-segment cell id / group id /
    start-within-cell / padded length, the per-cell padded length
    (``(W·B,)``), and a ``(W·B, G)`` start-within-cell lookup used to
    place tokens one worker at a time.
    """
    WB, G = seg_counts.shape
    seg_cell, seg_g = np.nonzero(seg_counts)           # row-major = sorted
    seg_sizes = seg_counts[seg_cell, seg_g]
    seg_pad = -(-seg_sizes // gran) * gran
    cell_change = np.ones(seg_cell.shape[0], bool)
    cell_change[1:] = seg_cell[1:] != seg_cell[:-1]
    run = np.cumsum(seg_pad) - seg_pad                 # global segment start
    base = np.maximum.accumulate(np.where(cell_change, run, 0))
    seg_start = run - base                             # start within cell
    cell_pad = np.zeros(WB, np.int64)
    np.add.at(cell_pad, seg_cell, seg_pad)
    seg_start_arr = np.zeros((WB, G), np.int64)
    seg_start_arr[seg_cell, seg_g] = seg_start
    return seg_cell, seg_g, seg_start, seg_pad, cell_pad, seg_start_arr


def _dense_doc_blk() -> int:
    """Default dense doc-tiling grid step: the fused kernel's native token
    tile, so doc-group padding aligns with the grid the kernel runs."""
    from repro.kernels.fused_sweep.fused_sweep import N_BLK
    return N_BLK


def _ffill_nonneg(a: np.ndarray) -> np.ndarray:
    """Forward-fill negative entries along the last axis (remaining
    leading negatives become 0) — pads ``doc_tile_of`` tiles that carry
    no tokens with the previous real group so paging never flips slabs
    for padding-only tiles."""
    neg = a < 0
    idx = np.where(neg, 0, np.arange(a.shape[-1]))
    np.maximum.accumulate(idx, axis=-1, out=idx)
    out = np.take_along_axis(a, idx, axis=-1)
    return np.where(out < 0, 0, out)


def default_ragged_tile(cell_sizes: np.ndarray) -> int:
    """Default ragged token-tile size: ~a quarter of the mean occupied
    cell load, rounded to a power of two and clamped to [8, 256].

    Per-cell padding in the ragged stream is < one tile, so a tile well
    under the typical cell keeps pad_fraction small at any ``B`` — and
    because the mean cell shrinks with ``B``, the chosen tile shrinks
    too, keeping the per-round *slot* count (the work the kernel actually
    sweeps) roughly flat in ``B`` instead of favouring small ``B``.  The
    256 ceiling matches the fused kernel's native ``N_BLK`` so
    large-scale layouts land on the TPU-friendly tile, and the floor of
    8 keeps the tile count (one grid step each) from exploding on tiny
    corpora.
    """
    occupied = cell_sizes[cell_sizes > 0]
    mean = float(occupied.mean()) if occupied.size else 1.0
    return int(min(max(_pow2_ceil(max(int(mean) // 4, 1)), 8), 256))


def half_queue_split(k: int) -> int:
    """Split point ``k0`` of a ``k``-cell queue for the pipelined ring.

    ``ring_mode="pipelined"`` (``core/nomad.py``) sweeps cells ``[0, k0)``,
    forwards their blocks immediately, then sweeps ``[k0, k)`` while that
    hop is in flight.  ``k0 = k // 2`` keeps the two half-queues
    load-matched: within a ring chunk the ``k`` blocks are themselves
    LPT-packed (:func:`build_layout`'s hierarchical split), so any
    contiguous ``k // 2`` of them carry ≈ half the chunk's tokens and the
    second half's sweep time can actually hide the first half's hop.
    ``k < 2`` returns 0 — a single-cell queue has nothing to overlap and
    the pipelined schedule degenerates to the barrier one.
    """
    return k // 2 if k >= 2 else 0


def _order_bins_for_halves(bins: np.ndarray, weights: np.ndarray,
                           kq: int, k0: int,
                           worker_loads: np.ndarray | None = None
                           ) -> np.ndarray:
    """Renumber a chunk's ``kq`` LPT bins so the pipelined half-queues
    ``[0, k0)`` and ``[k0, kq)`` are load-matched.

    LPT gives near-equal bins but arbitrary ids; under power-law skew one
    bin can hold most of a chunk's tokens, and if its id landed in the
    wrong half the pipelined ring would have nothing to overlap.  Greedy
    capacity-constrained partition (heaviest bin to the lighter half with
    room) keeps ``|half0 − half1| ≤ max bin load`` — the best any
    block-granular split can do.

    ``worker_loads`` (``(W, kq)`` per-worker bin loads) refines the choice:
    among the partitions that respect the greedy global-gap bound, pick the
    one minimizing ``max_w half0 + max_w half1`` — the quantity the ragged
    layout's stream capacity pays, since each half is padded to its
    heaviest (worker, chunk) occurrence (DESIGN.md §4).  Global halves can
    be perfectly matched while one worker's halves are badly skewed, so
    the global objective alone leaves real padding on the table.  The
    search enumerates subsets when that is cheap and keeps the greedy
    answer otherwise; the bound invariant is unchanged either way.
    Returns the remapped bin assignment.
    """
    loads = np.bincount(bins, weights=weights, minlength=kq)
    h0, h1 = [], []
    l0 = l1 = 0.0
    for b in np.argsort(-loads, kind="stable"):
        if len(h0) >= k0:
            h1.append(b); l1 += loads[b]
        elif len(h1) >= kq - k0:
            h0.append(b); l0 += loads[b]
        elif l0 <= l1:
            h0.append(b); l0 += loads[b]
        else:
            h1.append(b); l1 += loads[b]

    from math import comb
    if worker_loads is not None and 0 < k0 < kq and comb(kq, k0) <= 20000:
        from itertools import combinations
        gap_bound = max(abs(l0 - l1), float(loads.max()))
        best = (float(worker_loads[:, h0].sum(1).max()
                      + worker_loads[:, h1].sum(1).max()),
                abs(l0 - l1))
        for sub in combinations(range(kq), k0):
            s = np.array(sub)
            gap = abs(2.0 * loads[s].sum() - loads.sum())
            if gap > gap_bound:
                continue
            r = np.setdiff1d(np.arange(kq), s, assume_unique=True)
            key = (float(worker_loads[:, s].sum(1).max()
                         + worker_loads[:, r].sum(1).max()), gap)
            if key < best:
                best, h0, h1 = key, list(s), list(r)

    perm = np.empty(kq, np.int64)
    perm[np.array(h0 + h1, np.int64)] = np.arange(kq)   # old bin → new id
    return perm[bins].astype(bins.dtype)


def lpt_assign(weights: np.ndarray, n_bins: int,
               balance: bool = True) -> np.ndarray:
    """Assign items to bins. ``balance=True``: greedy LPT (largest first to
    lightest bin); else contiguous equal-count chunks (the naive split)."""
    n = weights.shape[0]
    if not balance:
        return (np.arange(n) * n_bins // max(n, 1)).astype(np.int32)
    import heapq
    order = np.argsort(-weights, kind="stable")
    out = np.zeros(n, dtype=np.int32)
    # LPT via a min-heap keyed on bin load: pop lightest, assign, push back.
    heap = [(0, b) for b in range(n_bins)]
    heapq.heapify(heap)
    for i in order:
        load, b = heapq.heappop(heap)
        out[i] = b
        heapq.heappush(heap, (load + int(weights[i]), b))
    return out


@dataclass
class NomadLayout:
    """Padded cell grid + count-table geometry for a nomad run.

    ``B`` must be a multiple of ``W``: each worker owns a queue of
    ``k = B // W`` blocks that travels the ring as one payload.  At ring
    round ``r`` (of ``W`` per sweep) worker ``w`` holds chunk
    ``c = (w + r) % W``, i.e. global blocks ``c*k .. c*k + k - 1``, and
    sweeps all ``k`` of those cells before the queue hops (DESIGN.md §4).
    ``B = W`` (``k = 1``) is the paper's minimal setup; ``B ≫ W`` is the
    paper's actual choice — finer blocks shrink the per-block vocabulary
    (the fused kernel's VMEM page) and, thanks to the hierarchical LPT in
    :func:`build_layout`, cost nothing in round balance.

    ``kind`` selects the token geometry (module docstring): ``"dense"``
    token arrays are ``(W, B, L)`` cell rows; ``"ragged"`` token arrays are
    ``(W, W, S)`` per-chunk tile streams with ``S = n_tiles·tile``,
    ``tile_split`` tiles covering the pipelined first half-queue, and the
    ``cell_of_tile``/``tok_slot`` side arrays.  ``L`` is always the true
    heaviest cell size — the dense pad length AND the canonical slot
    stride both layouts derive per-token RNG ids from.
    """
    W: int                       # workers (ring length)
    B: int                       # word blocks (multiple of W)
    L: int                       # heaviest cell (dense pad len / RNG stride)
    T: int                       # topics
    num_words: int               # true vocabulary size J (for β̄)
    tok_doc: np.ndarray          # (W,B,L)|(W,W,S) int32 local doc index
    tok_wrd: np.ndarray          # (W,B,L)|(W,W,S) int32 local word in block
    tok_gwrd: np.ndarray         # (W,B,L)|(W,W,S) int32 global word id
    tok_valid: np.ndarray        # (W,B,L)|(W,W,S) bool
    tok_bound: np.ndarray        # (W,B,L)|(W,W,S) bool
    doc_of_worker: np.ndarray    # (W, I_max) int32 global doc id (-1 pad)
    word_of_block: np.ndarray    # (B, J_max) int32 global word id (-1 pad)
    I_max: int                   # padded docs per worker
    J_max: int                   # padded words per block
    doc_assign: np.ndarray       # (I,) worker of each document
    word_assign: np.ndarray      # (J,) block of each word
    cell_sizes: np.ndarray       # (W,B) true token counts (imbalance stats)
    canon_idx: np.ndarray        # (N,) int64 flat tok_* position of each
                                 #   token in canonical (w, block, word) order
    kind: str = "dense"          # token geometry: "dense" | "ragged"
    tile: int = 0                # ragged: tokens per tile
    n_tiles: int = 0             # ragged: tiles per (worker, chunk) stream
    tile_split: int = 0          # ragged: first-half tiles (pipelined split)
    cell_of_tile: np.ndarray | None = None   # ragged (W,W,n_tiles) int32
    tok_slot: np.ndarray | None = None       # ragged (W,W,S) int32;
                                 #   dense too when doc_tile grouping is on
    r_cap: int = 0               # sparse r-bucket capacity: the per-shard
                                 #   T_d_max bound min(T, max doc length)
                                 #   (0 = unknown, callers fall back to T)
    doc_tile: int = 0            # doc rows per slab (0 = ungrouped)
    n_doc_tiles: int = 1         # slabs per worker shard (ceil(I_max/doc_tile))
    doc_blk: int = 0             # dense: tokens per doc-tile-aligned grid step
    doc_tile_of: np.ndarray | None = None
                                 #   dense (W,B,Lrow//doc_blk) int32 /
                                 #   ragged (W,W,n_tiles) int32: tile → slab

    @property
    def k(self) -> int:
        """Blocks per worker queue (``B // W``)."""
        return self.B // self.W

    @property
    def stream_len(self) -> int:
        """Ragged: tokens per (worker, chunk) stream (``n_tiles·tile``)."""
        return self.n_tiles * self.tile

    @property
    def pad_fraction(self) -> float:
        """Padding overhead of this layout's actual token capacity: the
        dense grid's ``W·B·Lrow`` slots (``Lrow ≥ L`` once doc-tile
        grouping pads group segments), or the ragged streams' ``W·W·S``."""
        return 1.0 - self.cell_sizes.sum() / self.tok_doc.size

    @property
    def total_tiles(self) -> int:
        """Token tiles one full sweep runs through the fused kernel: the
        ragged streams' tile count, or the dense grid's row length padded
        to the kernel's grid step (``doc_blk`` when doc-tile grouping
        fixes it, the kernel's native ``N_BLK`` otherwise — the dense
        kernel tiles at call time)."""
        if self.kind == "ragged":
            return self.W * self.W * self.n_tiles
        if self.doc_blk > 0:
            return self.W * self.B * (self.tok_doc.shape[-1] // self.doc_blk)
        from repro.kernels.fused_sweep.fused_sweep import N_BLK
        return self.W * self.B * -(-self.L // N_BLK)

    @property
    def ntd_row_bytes(self) -> int:
        """Bytes of one int32 doc-topic row — the unit the ``doc_tile``
        VMEM budget scales with."""
        return 4 * self.T

    @property
    def ntd_whole_bytes(self) -> int:
        """Doc-topic bytes of whole-shard residency: the ``(I_max, T)``
        table in VMEM twice (input + output buffers, DESIGN.md §7)."""
        return 2 * self.I_max * self.ntd_row_bytes

    @property
    def ntd_slab_bytes(self) -> int:
        """Doc-topic bytes the fused kernels keep VMEM-resident per
        worker: one ``(doc_tile, T)`` slab when grouping is on, else
        :attr:`ntd_whole_bytes`."""
        if self.doc_tile > 0:
            return self.doc_tile * self.ntd_row_bytes
        return self.ntd_whole_bytes

    # -- canonical token order ------------------------------------------------
    def extract_canonical(self, a: np.ndarray) -> np.ndarray:
        """Values of a token-geometry array at the valid tokens, in
        canonical (worker, block, word, occurrence) order — identical
        across layouts, the basis of every cross-layout comparison."""
        return np.asarray(a).reshape(-1)[self.canon_idx]

    def place_canonical(self, vals: np.ndarray, fill=0) -> np.ndarray:
        """Scatter canonical-order per-token values into this layout's
        token geometry (padding slots get ``fill``)."""
        out = np.full(self.tok_doc.shape, fill, np.asarray(vals).dtype)
        out.reshape(-1)[self.canon_idx] = vals
        return out

    def token_coords(self):
        """Canonical-order (worker, block, local_doc, local_word) of every
        token, derived purely from the layout arrays."""
        flat = lambda a: self.extract_canonical(a)
        if self.kind == "ragged":
            S = self.stream_len
            w = self.canon_idx // (self.W * S)
            c = (self.canon_idx // S) % self.W
            cell = np.repeat(self.cell_of_tile, self.tile,
                             axis=2).reshape(-1)[self.canon_idx]
            b = c * self.k + cell
        else:
            Lrow = self.tok_doc.shape[-1]      # ≥ L under doc-tile grouping
            w = self.canon_idx // (self.B * Lrow)
            b = (self.canon_idx // Lrow) % self.B
        return w, b, flat(self.tok_doc), flat(self.tok_wrd)

    def token_globals(self):
        """Canonical-order (global doc id, global word id) per token."""
        w, b, d, j = self.token_coords()
        return self.doc_of_worker[w, d], self.word_of_block[b, j]

    def word_map_mismatches(self) -> int:
        """Tokens whose stored global word id disagrees with the
        block/local maps — the layout self-consistency diagnostic."""
        _, gwrd = self.token_globals()
        return int((gwrd != self.extract_canonical(self.tok_gwrd)).sum())

    @property
    def round_imbalance(self) -> float:
        """max/mean token count over the per-worker queue loads in a ring
        round, worst round — the 'last reducer' exposure of the static
        schedule.  A round's load on worker ``w`` is the sum over its
        ``k``-cell queue, so larger ``B`` (smaller blocks, more of them)
        averages the power-law word skew down within each round."""
        W, k = self.W, self.k
        worst = 0.0
        for r in range(W):
            chunk = (np.arange(W) + r) % W
            active = np.array([
                self.cell_sizes[w, chunk[w] * k:(chunk[w] + 1) * k].sum()
                for w in range(W)])
            if active.mean() > 0:
                worst = max(worst, active.max() / active.mean())
        return float(worst)

    def half_balance_gaps(self) -> np.ndarray:
        """(W, 2) per ring chunk: the global-load gap between the two
        pipelined half-queues, and the chunk's heaviest block load — the
        bound :func:`_order_bins_for_halves` guarantees (``gap ≤ max``).
        The single statement of the half-balance invariant the tests
        assert."""
        k = self.k
        k0 = half_queue_split(k)
        block_loads = self.cell_sizes.sum(axis=0)           # (B,)
        out = np.zeros((self.W, 2), np.int64)
        for c in range(self.W):
            q = block_loads[c * k:(c + 1) * k]
            out[c] = (abs(int(q[:k0].sum()) - int(q[k0:].sum())),
                      int(q.max()))
        return out

    def half_loads(self) -> np.ndarray:
        """(W_rounds, W, 2) token loads of the two pipelined half-queues.

        Entry ``[r, w]`` is ``(first-half, second-half)`` token counts of
        the queue worker ``w`` sweeps in ring round ``r`` when split at
        :func:`half_queue_split`.  With ``k < 2`` the first column is all
        zero (degenerate split)."""
        W, k = self.W, self.k
        k0 = half_queue_split(k)
        out = np.zeros((W, W, 2), np.int64)
        for r in range(W):
            for w in range(W):
                c = (w + r) % W
                q = self.cell_sizes[w, c * k:(c + 1) * k]
                out[r, w] = (q[:k0].sum(), q[k0:].sum())
        return out


def counts_from_layout(lay: NomadLayout, z: np.ndarray, T: int):
    """Rebuild compact global ``(n_td, n_wt, n_t)`` from the assignment
    array ``z`` in the layout's token geometry (dense grid or ragged
    streams) — the single oracle every distributed exactness check
    compares ``NomadLDA.global_counts`` against.

    (Distinct from :func:`repro.core.cgs.counts_from_assignments`, which
    rebuilds from the flat serial corpus arrays.)"""
    zz = lay.extract_canonical(z)
    gdoc, gwrd = lay.token_globals()
    I = lay.doc_assign.shape[0]        # full doc-id space: retired docs
    n_td = np.zeros((I, T), np.int64)  # keep zero rows (corpus_store)
    n_wt = np.zeros((lay.num_words, T), np.int64)
    np.add.at(n_td, (gdoc, zz), 1)
    np.add.at(n_wt, (gwrd, zz), 1)
    return n_td, n_wt, np.bincount(zz, minlength=T).astype(np.int64)


def _validate_build_args(W: int, B: int, layout: str,
                         doc_tile: int | None, doc_blk: int | None) -> None:
    """Shared argument validation for the monolithic and chunked builders."""
    if layout not in ("dense", "ragged"):
        raise ValueError(f"unknown layout {layout!r} (dense|ragged)")
    if doc_tile is not None and int(doc_tile) < 1:
        raise ValueError(f"doc_tile must be >= 1, got {doc_tile}")
    if doc_blk is not None and doc_tile is None:
        raise ValueError("doc_blk only applies with doc_tile grouping")
    if doc_blk is not None and layout == "ragged":
        raise ValueError(
            "ragged doc grouping is tiled at the stream's own `tile` "
            "granularity; doc_blk only applies to layout='dense'")
    if B % W != 0 or B < W:
        raise ValueError(
            f"n_blocks must be a positive multiple of n_workers so each "
            f"worker's block queue has equal length; got n_blocks={B}, "
            f"n_workers={W}")


def _plan_partition(doc_lengths: np.ndarray, freqs: np.ndarray, *,
                    W: int, B: int, balance: bool, freq_w):
    """Assign docs → workers and words → blocks from the marginal stats.

    Hierarchical word packing: LPT into W ring chunks first (so per-round
    queue loads are exactly as balanced as the B = W packing — flat LPT
    into B small bins lets single heavy words dominate a bin and would
    *worsen* round balance), then LPT each chunk into k = B/W blocks.
    Block b of chunk c gets global id c*k + b, matching the queue layout.

    ``freq_w`` is a callable ``doc_assign -> (W, J)`` per-worker word
    frequency table, invoked only when the pipelined half ordering needs
    it — the chunked store builder streams it shard by shard instead of
    indexing the full token arrays.
    """
    doc_assign = lpt_assign(doc_lengths, W, balance)
    chunk_assign = lpt_assign(freqs, W, balance)
    if B == W:
        return doc_assign, chunk_assign
    kq = B // W
    k0 = half_queue_split(kq)
    # per-worker word frequencies: the half ordering balances not just
    # the chunk's global halves but each worker's (identically for
    # both layouts — the ragged streams pad each half to its heaviest
    # per-worker occurrence)
    fw = freq_w(doc_assign) if (balance and k0 > 0) else None
    word_assign = np.zeros_like(chunk_assign)
    for c in range(W):
        ids = np.nonzero(chunk_assign == c)[0]
        bins = lpt_assign(freqs[ids], kq, balance)
        if balance and k0 > 0:
            # order blocks within the chunk so the pipelined ring's
            # half-queues [0, k0) / [k0, kq) are load-matched
            wl = np.stack([np.bincount(bins, weights=fw[w, ids],
                                       minlength=kq) for w in range(W)])
            bins = _order_bins_for_halves(bins, freqs[ids], kq, k0, wl)
        word_assign[ids] = c * kq + bins
    return doc_assign, word_assign


def _local_maps(doc_assign: np.ndarray, word_assign: np.ndarray,
                W: int, B: int):
    """Local doc / word index maps from the assignment vectors."""
    I_counts = np.bincount(doc_assign, minlength=W)
    J_counts = np.bincount(word_assign, minlength=B)
    I_max, J_max = int(I_counts.max()), int(J_counts.max())
    doc_of_worker = np.full((W, I_max), -1, np.int32)
    doc_local = np.zeros(doc_assign.shape[0], np.int32)
    for w in range(W):
        ids = np.nonzero(doc_assign == w)[0]
        doc_of_worker[w, :len(ids)] = ids
        doc_local[ids] = np.arange(len(ids))
    word_of_block = np.full((B, J_max), -1, np.int32)
    word_local = np.zeros(word_assign.shape[0], np.int32)
    for b in range(B):
        ids = np.nonzero(word_assign == b)[0]
        word_of_block[b, :len(ids)] = ids
        word_local[ids] = np.arange(len(ids))
    return (doc_of_worker, doc_local, word_of_block, word_local,
            I_max, J_max)


@dataclass
class _Geom:
    """Token-geometry constants derived purely from count accumulators
    (``cell_sizes`` and, under doc grouping, the per-(cell, group) segment
    counts) — everything :class:`_LayoutAssembler` needs to place one
    worker's tokens without seeing any other worker's."""
    layout: str
    W: int
    B: int
    L: int                       # heaviest cell (RNG stride)
    dt: int                      # doc_tile (0 = ungrouped)
    gran: int                    # segment grid step (doc_blk / tile)
    n_doc_tiles: int
    shape: tuple
    seg_start_arr: np.ndarray | None   # (W·B, G) segment start within cell
    L_row: int = 0               # dense row length (≥ L under grouping)
    tile: int = 0                # ragged tokens per tile
    R0: int = 0                  # ragged first-half tiles
    R: int = 0                   # ragged tiles per stream
    S: int = 0                   # ragged stream length (R·tile)
    off: np.ndarray | None = None          # ragged (W, W, k) cell → tile
    cell_of_tile: np.ndarray | None = None
    dto: np.ndarray | None = None          # doc_tile_of map


def _build_geometry(cell_sizes: np.ndarray, seg_counts: np.ndarray | None,
                    *, layout: str, W: int, B: int, dt: int, gran: int,
                    n_doc_tiles: int, tile: int) -> _Geom:
    """Global token geometry from the count accumulators alone."""
    L = max(int(cell_sizes.max()), 1)
    if layout == "dense":
        if dt:
            seg_cell, seg_g, seg_start, seg_pad, cp, seg_start_arr = \
                _segments_from_counts(seg_counts, gran)
            L_row = max(int(cp.max()), gran)
            dto = np.full((W, B, L_row // gran), -1, np.int32)
            for s in range(seg_cell.shape[0]):
                w_, b_ = divmod(int(seg_cell[s]), B)
                t0 = int(seg_start[s]) // gran
                dto[w_, b_, t0:t0 + int(seg_pad[s]) // gran] = seg_g[s]
            return _Geom(layout, W, B, L, dt, gran, n_doc_tiles,
                         (W, B, L_row), seg_start_arr, L_row=L_row,
                         dto=_ffill_nonneg(dto))
        return _Geom(layout, W, B, L, 0, 0, 1, (W, B, L), None, L_row=L)
    k = B // W
    k0 = half_queue_split(k)
    # Tiles per cell (empty cells keep one tile so every block is paged
    # through the kernel exactly once per round), grouped (W, chunk, k).
    if dt:
        seg_cell, seg_g, seg_start, seg_pad, cp, seg_start_arr = \
            _segments_from_counts(seg_counts, gran)
        tiles_cell = np.maximum(1, cp // tile).reshape(W, W, k)
    else:
        seg_start_arr = None
        tiles_cell = np.maximum(1, -(-cell_sizes // tile)).reshape(W, W, k)
    half0 = tiles_cell[:, :, :k0].sum(axis=2)          # (W, W) tiles
    half1 = tiles_cell[:, :, k0:].sum(axis=2)
    # Each pipelined half-queue is padded to its own global tile max so
    # the half split is one static tile index for every (w, chunk).
    R0 = int(half0.max()) if k0 > 0 else 0
    R1 = int(half1.max())
    R = R0 + R1
    S = R * tile
    # tile offset of cell j within its (w, chunk) stream
    start = np.cumsum(tiles_cell, axis=2) - tiles_cell
    off = np.where(np.arange(k)[None, None, :] < k0,
                   start, R0 + start - half0[:, :, None])
    cell_of_tile = np.zeros((W, W, R), np.int32)
    if k0 > 0:                     # half-padding tiles: last cell of the
        cell_of_tile[:, :, :R0] = k0 - 1      # half (keeps the tile→cell
    cell_of_tile[:, :, R0:] = k - 1           # map non-decreasing)
    for w in range(W):
        for c in range(W):
            for j in range(k):
                o, n = int(off[w, c, j]), int(tiles_cell[w, c, j])
                cell_of_tile[w, c, o:o + n] = j
    geom = _Geom("ragged", W, B, L, dt, gran, n_doc_tiles, (W, W, S),
                 seg_start_arr, tile=tile, R0=R0, R=R, S=S, off=off,
                 cell_of_tile=cell_of_tile)
    if dt:
        dto = np.full((W, W, R), -1, np.int32)
        for s in range(seg_cell.shape[0]):
            w_, b_ = divmod(int(seg_cell[s]), B)
            c_, j_ = divmod(b_, k)
            t0 = int(off[w_, c_, j_]) + int(seg_start[s]) // tile
            dto[w_, c_, t0:t0 + int(seg_pad[s]) // tile] = seg_g[s]
        geom.dto = _ffill_nonneg(dto)
    return geom


class _LayoutAssembler:
    """Fills the token-geometry arrays one worker at a time.

    Canonical order is worker-major, so feeding workers in ascending
    order with each worker's tokens already sorted by (block[, doc
    group], word id) — ties in original corpus order — reproduces the
    global lexsorted order exactly.  Both :func:`build_layout` (which
    sorts the whole corpus at once) and the chunked store builder (which
    sorts one worker's shard-streamed tokens at a time) feed this same
    assembler, which is what makes their outputs byte-identical by
    construction.

    ``slot`` may be supplied per worker to *preserve* historical slot
    indices (the incremental add/retire path, where surviving tokens must
    keep their RNG uids); by default it is the within-cell running count,
    the initial-build rule.
    """

    def __init__(self, geom: _Geom, n_tokens: int):
        g = self.geom = geom
        self.tok_doc = np.zeros(g.shape, np.int32)
        self.tok_wrd = np.zeros(g.shape, np.int32)
        self.tok_gwrd = np.zeros(g.shape, np.int32)
        self.tok_valid = np.zeros(g.shape, bool)
        self.tok_bound = np.zeros(g.shape, bool)
        need_slot = g.layout == "ragged" or g.dt > 0
        self.tok_slot = np.zeros(g.shape, np.int32) if need_slot else None
        self.canon_idx = np.zeros(n_tokens, np.int64)
        self._n0 = 0
        self._last_w = -1

    def add_worker(self, w: int, sb: np.ndarray, dloc: np.ndarray,
                   wloc: np.ndarray, gwrd: np.ndarray,
                   sg: np.ndarray | None = None,
                   slot: np.ndarray | None = None) -> None:
        """Place worker ``w``'s tokens (sorted by (block[, group], word))."""
        if w <= self._last_w:
            raise ValueError("workers must be added in ascending order")
        self._last_w = w
        g = self.geom
        n = sb.shape[0]
        flat_cell = w * np.int64(g.B) + sb.astype(np.int64)
        if slot is None:
            # slot index of each token within its cell (canonical order is
            # the lexsorted order itself: worker, block, word, occurrence)
            slot = _running_count(flat_cell)
        # word boundary within cell: first slot, or word differs from
        # previous (the first token of a cell always bounds — its
        # predecessor in the global order is another worker's cell)
        prev_same_cell = np.zeros(n, bool)
        prev_same_cell[1:] = flat_cell[1:] == flat_cell[:-1]
        prev_same_word = np.zeros(n, bool)
        prev_same_word[1:] = gwrd[1:] == gwrd[:-1]
        bound = ~(prev_same_cell & prev_same_word)
        if g.dt:
            seg_key = flat_cell * np.int64(g.n_doc_tiles) + sg
            pos_c = (g.seg_start_arr[flat_cell, sg]
                     + _running_count(seg_key))
        if g.layout == "dense":
            pos = pos_c if g.dt else slot
            canon = flat_cell * g.L_row + pos
        else:
            k = g.B // g.W
            sc, sj = sb // k, sb % k
            pos = g.off[w, sc, sj] * g.tile + (pos_c if g.dt else slot)
            canon = (np.int64(w) * g.W + sc.astype(np.int64)) * g.S + pos
        for arr, vals in ((self.tok_doc, dloc), (self.tok_wrd, wloc),
                          (self.tok_gwrd, gwrd), (self.tok_valid, True),
                          (self.tok_bound, bound), (self.tok_slot, slot)):
            if arr is not None:
                arr.reshape(-1)[canon] = vals
        self.canon_idx[self._n0:self._n0 + n] = canon
        self._n0 += n

    def finish(self, *, T: int, num_words: int, doc_of_worker, word_of_block,
               I_max: int, J_max: int, doc_assign, word_assign, cell_sizes,
               r_cap: int) -> NomadLayout:
        if self._n0 != self.canon_idx.shape[0]:
            raise ValueError(
                f"assembled {self._n0} tokens but the layout was sized for "
                f"{self.canon_idx.shape[0]}")
        g = self.geom
        extra = {}
        if g.layout == "dense":
            if g.dt:
                extra = dict(doc_tile=g.dt, n_doc_tiles=g.n_doc_tiles,
                             doc_blk=g.gran, doc_tile_of=g.dto,
                             tok_slot=self.tok_slot)
        else:
            extra = dict(kind="ragged", tile=g.tile, n_tiles=g.R,
                         tile_split=g.R0, cell_of_tile=g.cell_of_tile,
                         tok_slot=self.tok_slot)
            if g.dt:
                extra.update(doc_tile=g.dt, n_doc_tiles=g.n_doc_tiles,
                             doc_blk=g.gran, doc_tile_of=g.dto)
        return NomadLayout(
            W=g.W, B=g.B, L=g.L, T=T, num_words=num_words,
            tok_doc=self.tok_doc, tok_wrd=self.tok_wrd,
            tok_gwrd=self.tok_gwrd, tok_valid=self.tok_valid,
            tok_bound=self.tok_bound,
            doc_of_worker=doc_of_worker, word_of_block=word_of_block,
            I_max=I_max, J_max=J_max,
            doc_assign=doc_assign, word_assign=word_assign,
            cell_sizes=cell_sizes, canon_idx=self.canon_idx,
            r_cap=r_cap, **extra)


def _resolve_gran(layout: str, dt: int, doc_blk: int | None,
                  tile: int | None, cell_sizes: np.ndarray) -> tuple:
    """Resolve the (segment grid step, ragged tile) pair for a build."""
    if layout == "ragged":
        tile = (default_ragged_tile(cell_sizes) if tile is None
                else int(tile))
        if tile < 1:
            raise ValueError(f"ragged tile must be >= 1, got {tile}")
        return tile, tile
    if dt:
        gran = int(doc_blk) if doc_blk is not None else _dense_doc_blk()
        if gran < 1:
            raise ValueError(f"doc_blk must be >= 1, got {gran}")
        return gran, 0
    return 0, 0


def build_layout(corpus: Corpus, *, n_workers: int, T: int,
                 n_blocks: int | None = None,
                 balance: bool = True, seed: int = 0,
                 layout: str = "dense",
                 tile: int | None = None,
                 doc_tile: int | None = None,
                 doc_blk: int | None = None) -> NomadLayout:
    """Partition ``corpus`` into the nomad cell grid.

    ``layout="dense"`` pads every cell to the heaviest cell's length;
    ``layout="ragged"`` builds per-(worker, chunk) tile streams with
    per-cell padding only up to the next ``tile`` multiple (default
    :func:`default_ragged_tile`).  Word/doc assignment, cell membership
    and the canonical token order are identical in both layouts.

    ``doc_tile`` groups each worker's local doc rows into slabs of that
    many consecutive rows and refines the canonical order to (worker,
    block, doc group, word): within every cell the doc-group segments are
    laid out back to back, each padded to the layout's grid step
    (``doc_blk`` tokens for dense — default the fused kernel's ``N_BLK`` —
    and ``tile`` for ragged), so every aligned token tile touches exactly
    one ``(doc_tile, T)`` doc-topic slab, recorded in ``doc_tile_of``.
    ``doc_tile=None`` (default) keeps the ungrouped order bit-for-bit.

    :func:`repro.data.corpus_store.build_layout_from_store` builds the
    identical layout from an out-of-core shard store; both feed the same
    :class:`_LayoutAssembler` so the outputs are byte-for-byte equal.
    """
    B = n_workers if n_blocks is None else n_blocks
    W = n_workers
    _validate_build_args(W, B, layout, doc_tile, doc_blk)

    def freq_w(doc_assign):
        fw = np.zeros((W, corpus.num_words), np.int64)
        np.add.at(fw, (doc_assign[corpus.doc_ids], corpus.word_ids), 1)
        return fw

    doc_assign, word_assign = _plan_partition(
        corpus.doc_lengths(), corpus.word_freqs(), W=W, B=B,
        balance=balance, freq_w=freq_w)
    (doc_of_worker, doc_local, word_of_block, word_local,
     I_max, J_max) = _local_maps(doc_assign, word_assign, W, B)

    # Cell grid: sort tokens by (worker, block[, doc group], word id).
    tw = doc_assign[corpus.doc_ids]
    tb = word_assign[corpus.word_ids]
    if doc_tile is not None:
        dt = int(doc_tile)
        n_doc_tiles = max(-(-I_max // dt), 1)
        g_tok = (doc_local[corpus.doc_ids] // dt).astype(np.int64)
        order = np.lexsort((corpus.word_ids, g_tok, tb, tw)).astype(np.int64)
        sg = g_tok[order]
    else:
        dt, n_doc_tiles, sg = 0, 1, None
        order = np.lexsort((corpus.word_ids, tb, tw)).astype(np.int64)
    sw, sb = tw[order], tb[order]
    sdoc, swrd = corpus.doc_ids[order], corpus.word_ids[order]

    cell_sizes = np.zeros((W, B), np.int64)
    np.add.at(cell_sizes, (sw, sb), 1)
    seg_counts = None
    if dt:
        seg_counts = np.zeros((W * B, n_doc_tiles), np.int64)
        np.add.at(seg_counts, (sw.astype(np.int64) * B + sb, sg), 1)
    gran, tile = _resolve_gran(layout, dt, doc_blk, tile, cell_sizes)

    geom = _build_geometry(cell_sizes, seg_counts, layout=layout, W=W, B=B,
                           dt=dt, gran=gran, n_doc_tiles=n_doc_tiles,
                           tile=tile)
    asm = _LayoutAssembler(geom, sw.shape[0])
    w_bounds = np.searchsorted(sw, np.arange(W + 1))
    for w in range(W):
        lo, hi = int(w_bounds[w]), int(w_bounds[w + 1])
        asm.add_worker(w, sb[lo:hi], doc_local[sdoc[lo:hi]],
                       word_local[swrd[lo:hi]], swrd[lo:hi],
                       sg[lo:hi] if dt else None)

    # Sparse r-bucket capacity (rbucket module docstring): a document of n
    # tokens holds ≤ min(T, n) distinct topics, and at increment time one
    # token is unassigned, so min(T, max doc length) slots always suffice.
    r_cap = max(1, min(T, int(corpus.doc_lengths().max(initial=1))))
    return asm.finish(
        T=T, num_words=corpus.num_words, doc_of_worker=doc_of_worker,
        word_of_block=word_of_block, I_max=I_max, J_max=J_max,
        doc_assign=doc_assign, word_assign=word_assign,
        cell_sizes=cell_sizes, r_cap=r_cap)


def _running_count(groups: np.ndarray) -> np.ndarray:
    """For a sorted group array, the 0-based occurrence index within group."""
    n = groups.shape[0]
    if n == 0:
        return np.zeros(0, np.int64)
    starts = np.ones(n, bool)
    starts[1:] = groups[1:] != groups[:-1]
    idx = np.arange(n)
    start_idx = np.maximum.accumulate(np.where(starts, idx, 0))
    return idx - start_idx
