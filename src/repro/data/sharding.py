"""Data partition and subtask split for Nomad LDA (paper §4.1, Fig. 2b).

The corpus grid: documents are partitioned into ``W`` worker shards (block
rows of Fig. 2b) and the vocabulary into ``B`` word blocks (the nomadic
tokens).  Cell ``(w, b)`` holds every occurrence of a block-``b`` word inside
a worker-``w`` document, sorted by word id — the "unit subtask" t_j of the
paper, batched per block.

Load balance (DESIGN.md §3): the paper relies on asynchrony to absorb the
power-law skew of word frequencies; on a lock-step TPU mesh we instead
balance statically — greedy LPT bin-packing of documents by length and of
words by corpus frequency — and measure the residual imbalance.

All outputs are dense, padded numpy arrays ready to become sharded
``jax.Array``s:

    tok_doc   (W, B, L) int32   local doc index (within worker shard)
    tok_wrd   (W, B, L) int32   local word index (within block)
    tok_gwrd  (W, B, L) int32   global word id (diagnostics)
    tok_valid (W, B, L) bool    padding mask
    tok_bound (W, B, L) bool    first occurrence of a word within the cell
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.data.corpus import Corpus

__all__ = ["NomadLayout", "counts_from_layout", "lpt_assign",
           "build_layout", "half_queue_split"]


def half_queue_split(k: int) -> int:
    """Split point ``k0`` of a ``k``-cell queue for the pipelined ring.

    ``ring_mode="pipelined"`` (``core/nomad.py``) sweeps cells ``[0, k0)``,
    forwards their blocks immediately, then sweeps ``[k0, k)`` while that
    hop is in flight.  ``k0 = k // 2`` keeps the two half-queues
    load-matched: within a ring chunk the ``k`` blocks are themselves
    LPT-packed (:func:`build_layout`'s hierarchical split), so any
    contiguous ``k // 2`` of them carry ≈ half the chunk's tokens and the
    second half's sweep time can actually hide the first half's hop.
    ``k < 2`` returns 0 — a single-cell queue has nothing to overlap and
    the pipelined schedule degenerates to the barrier one.
    """
    return k // 2 if k >= 2 else 0


def _order_bins_for_halves(bins: np.ndarray, weights: np.ndarray,
                           kq: int, k0: int) -> np.ndarray:
    """Renumber a chunk's ``kq`` LPT bins so the pipelined half-queues
    ``[0, k0)`` and ``[k0, kq)`` are load-matched.

    LPT gives near-equal bins but arbitrary ids; under power-law skew one
    bin can hold most of a chunk's tokens, and if its id landed in the
    wrong half the pipelined ring would have nothing to overlap.  Greedy
    capacity-constrained partition (heaviest bin to the lighter half with
    room) keeps ``|half0 − half1| ≤ max bin load`` — the best any
    block-granular split can do.  Returns the remapped bin assignment.
    """
    loads = np.bincount(bins, weights=weights, minlength=kq)
    h0, h1 = [], []
    l0 = l1 = 0.0
    for b in np.argsort(-loads, kind="stable"):
        if len(h0) >= k0:
            h1.append(b); l1 += loads[b]
        elif len(h1) >= kq - k0:
            h0.append(b); l0 += loads[b]
        elif l0 <= l1:
            h0.append(b); l0 += loads[b]
        else:
            h1.append(b); l1 += loads[b]
    perm = np.empty(kq, np.int64)
    perm[np.array(h0 + h1, np.int64)] = np.arange(kq)   # old bin → new id
    return perm[bins].astype(bins.dtype)


def lpt_assign(weights: np.ndarray, n_bins: int,
               balance: bool = True) -> np.ndarray:
    """Assign items to bins. ``balance=True``: greedy LPT (largest first to
    lightest bin); else contiguous equal-count chunks (the naive split)."""
    n = weights.shape[0]
    if not balance:
        return (np.arange(n) * n_bins // max(n, 1)).astype(np.int32)
    import heapq
    order = np.argsort(-weights, kind="stable")
    out = np.zeros(n, dtype=np.int32)
    # LPT via a min-heap keyed on bin load: pop lightest, assign, push back.
    heap = [(0, b) for b in range(n_bins)]
    heapq.heapify(heap)
    for i in order:
        load, b = heapq.heappop(heap)
        out[i] = b
        heapq.heappush(heap, (load + int(weights[i]), b))
    return out


@dataclass
class NomadLayout:
    """Padded cell grid + count-table geometry for a nomad run.

    ``B`` must be a multiple of ``W``: each worker owns a queue of
    ``k = B // W`` blocks that travels the ring as one payload.  At ring
    round ``r`` (of ``W`` per sweep) worker ``w`` holds chunk
    ``c = (w + r) % W``, i.e. global blocks ``c*k .. c*k + k - 1``, and
    sweeps all ``k`` of those cells before the queue hops (DESIGN.md §4).
    ``B = W`` (``k = 1``) is the paper's minimal setup; ``B ≫ W`` is the
    paper's actual choice — finer blocks shrink the per-block vocabulary
    (the fused kernel's VMEM page) and, thanks to the hierarchical LPT in
    :func:`build_layout`, cost nothing in round balance.
    """
    W: int                       # workers (ring length)
    B: int                       # word blocks (multiple of W)
    L: int                       # padded cell length
    T: int                       # topics
    num_words: int               # true vocabulary size J (for β̄)
    tok_doc: np.ndarray          # (W,B,L) int32 local doc index
    tok_wrd: np.ndarray          # (W,B,L) int32 local word index in block
    tok_gwrd: np.ndarray         # (W,B,L) int32 global word id
    tok_valid: np.ndarray        # (W,B,L) bool
    tok_bound: np.ndarray        # (W,B,L) bool
    doc_of_worker: np.ndarray    # (W, I_max) int32 global doc id (-1 pad)
    word_of_block: np.ndarray    # (B, J_max) int32 global word id (-1 pad)
    I_max: int                   # padded docs per worker
    J_max: int                   # padded words per block
    doc_assign: np.ndarray       # (I,) worker of each document
    word_assign: np.ndarray      # (J,) block of each word
    cell_sizes: np.ndarray       # (W,B) true token counts (imbalance stats)

    @property
    def k(self) -> int:
        """Blocks per worker queue (``B // W``)."""
        return self.B // self.W

    @property
    def pad_fraction(self) -> float:
        return 1.0 - self.cell_sizes.sum() / (self.W * self.B * self.L)

    @property
    def round_imbalance(self) -> float:
        """max/mean token count over the per-worker queue loads in a ring
        round, worst round — the 'last reducer' exposure of the static
        schedule.  A round's load on worker ``w`` is the sum over its
        ``k``-cell queue, so larger ``B`` (smaller blocks, more of them)
        averages the power-law word skew down within each round."""
        W, k = self.W, self.k
        worst = 0.0
        for r in range(W):
            chunk = (np.arange(W) + r) % W
            active = np.array([
                self.cell_sizes[w, chunk[w] * k:(chunk[w] + 1) * k].sum()
                for w in range(W)])
            if active.mean() > 0:
                worst = max(worst, active.max() / active.mean())
        return float(worst)

    def half_balance_gaps(self) -> np.ndarray:
        """(W, 2) per ring chunk: the global-load gap between the two
        pipelined half-queues, and the chunk's heaviest block load — the
        bound :func:`_order_bins_for_halves` guarantees (``gap ≤ max``).
        The single statement of the half-balance invariant the tests
        assert."""
        k = self.k
        k0 = half_queue_split(k)
        block_loads = self.cell_sizes.sum(axis=0)           # (B,)
        out = np.zeros((self.W, 2), np.int64)
        for c in range(self.W):
            q = block_loads[c * k:(c + 1) * k]
            out[c] = (abs(int(q[:k0].sum()) - int(q[k0:].sum())),
                      int(q.max()))
        return out

    def half_loads(self) -> np.ndarray:
        """(W_rounds, W, 2) token loads of the two pipelined half-queues.

        Entry ``[r, w]`` is ``(first-half, second-half)`` token counts of
        the queue worker ``w`` sweeps in ring round ``r`` when split at
        :func:`half_queue_split`.  With ``k < 2`` the first column is all
        zero (degenerate split)."""
        W, k = self.W, self.k
        k0 = half_queue_split(k)
        out = np.zeros((W, W, 2), np.int64)
        for r in range(W):
            for w in range(W):
                c = (w + r) % W
                q = self.cell_sizes[w, c * k:(c + 1) * k]
                out[r, w] = (q[:k0].sum(), q[k0:].sum())
        return out


def counts_from_layout(lay: NomadLayout, z: np.ndarray, T: int):
    """Rebuild compact global ``(n_td, n_wt, n_t)`` from the padded
    assignment grid ``z`` (W,B,L) — the single oracle every distributed
    exactness check compares ``NomadLDA.global_counts`` against.

    (Distinct from :func:`repro.core.cgs.counts_from_assignments`, which
    rebuilds from the flat serial corpus arrays.)"""
    w_idx, b_idx, l_idx = np.nonzero(lay.tok_valid)
    zz = z[w_idx, b_idx, l_idx]
    gdoc = lay.doc_of_worker[w_idx, lay.tok_doc[w_idx, b_idx, l_idx]]
    gwrd = lay.word_of_block[b_idx, lay.tok_wrd[w_idx, b_idx, l_idx]]
    I = int((lay.doc_of_worker >= 0).sum())
    n_td = np.zeros((I, T), np.int64)
    n_wt = np.zeros((lay.num_words, T), np.int64)
    np.add.at(n_td, (gdoc, zz), 1)
    np.add.at(n_wt, (gwrd, zz), 1)
    return n_td, n_wt, np.bincount(zz, minlength=T).astype(np.int64)


def build_layout(corpus: Corpus, *, n_workers: int, T: int,
                 n_blocks: int | None = None,
                 balance: bool = True, seed: int = 0) -> NomadLayout:
    B = n_workers if n_blocks is None else n_blocks
    W = n_workers
    if B % W != 0 or B < W:
        raise ValueError(
            f"n_blocks must be a positive multiple of n_workers so each "
            f"worker's block queue has equal length; got n_blocks={B}, "
            f"n_workers={W}")
    doc_assign = lpt_assign(corpus.doc_lengths(), W, balance)
    # Hierarchical word packing: LPT into W ring chunks first (so per-round
    # queue loads are exactly as balanced as the B = W packing — flat LPT
    # into B small bins lets single heavy words dominate a bin and would
    # *worsen* round balance), then LPT each chunk into k = B/W blocks.
    # Block b of chunk c gets global id c*k + b, matching the queue layout.
    freqs = corpus.word_freqs()
    chunk_assign = lpt_assign(freqs, W, balance)
    if B == W:
        word_assign = chunk_assign
    else:
        kq = B // W
        k0 = half_queue_split(kq)
        word_assign = np.zeros_like(chunk_assign)
        for c in range(W):
            ids = np.nonzero(chunk_assign == c)[0]
            bins = lpt_assign(freqs[ids], kq, balance)
            if balance and k0 > 0:
                # order blocks within the chunk so the pipelined ring's
                # half-queues [0, k0) / [k0, kq) are load-matched
                bins = _order_bins_for_halves(bins, freqs[ids], kq, k0)
            word_assign[ids] = c * kq + bins

    # Local doc / word index maps.
    I_counts = np.bincount(doc_assign, minlength=W)
    J_counts = np.bincount(word_assign, minlength=B)
    I_max, J_max = int(I_counts.max()), int(J_counts.max())
    doc_of_worker = np.full((W, I_max), -1, np.int32)
    doc_local = np.zeros(corpus.num_docs, np.int32)
    for w in range(W):
        ids = np.nonzero(doc_assign == w)[0]
        doc_of_worker[w, :len(ids)] = ids
        doc_local[ids] = np.arange(len(ids))
    word_of_block = np.full((B, J_max), -1, np.int32)
    word_local = np.zeros(corpus.num_words, np.int32)
    for b in range(B):
        ids = np.nonzero(word_assign == b)[0]
        word_of_block[b, :len(ids)] = ids
        word_local[ids] = np.arange(len(ids))

    # Cell grid: sort tokens by (worker, block, word id).
    tw = doc_assign[corpus.doc_ids]
    tb = word_assign[corpus.word_ids]
    order = np.lexsort((corpus.word_ids, tb, tw)).astype(np.int64)
    sw, sb = tw[order], tb[order]
    sdoc, swrd = corpus.doc_ids[order], corpus.word_ids[order]

    cell_sizes = np.zeros((W, B), np.int64)
    np.add.at(cell_sizes, (sw, sb), 1)
    L = max(int(cell_sizes.max()), 1)

    tok_doc = np.zeros((W, B, L), np.int32)
    tok_wrd = np.zeros((W, B, L), np.int32)
    tok_gwrd = np.zeros((W, B, L), np.int32)
    tok_valid = np.zeros((W, B, L), bool)
    tok_bound = np.zeros((W, B, L), bool)

    # slot index of each token within its cell
    flat_cell = sw.astype(np.int64) * B + sb
    # stable running count per cell
    slot = _running_count(flat_cell)
    tok_doc[sw, sb, slot] = doc_local[sdoc]
    tok_wrd[sw, sb, slot] = word_local[swrd]
    tok_gwrd[sw, sb, slot] = swrd
    tok_valid[sw, sb, slot] = True
    # word boundary within cell: first slot, or word differs from previous
    prev_same_cell = np.zeros_like(flat_cell, bool)
    prev_same_cell[1:] = flat_cell[1:] == flat_cell[:-1]
    prev_same_word = np.zeros_like(flat_cell, bool)
    prev_same_word[1:] = swrd[1:] == swrd[:-1]
    bound = ~(prev_same_cell & prev_same_word)
    tok_bound[sw, sb, slot] = bound
    # padding slots: mark as boundary=False, doc/wrd 0 (masked in the sweep)

    return NomadLayout(
        W=W, B=B, L=L, T=T, num_words=corpus.num_words,
        tok_doc=tok_doc, tok_wrd=tok_wrd, tok_gwrd=tok_gwrd,
        tok_valid=tok_valid, tok_bound=tok_bound,
        doc_of_worker=doc_of_worker, word_of_block=word_of_block,
        I_max=I_max, J_max=J_max,
        doc_assign=doc_assign, word_assign=word_assign,
        cell_sizes=cell_sizes)


def _running_count(groups: np.ndarray) -> np.ndarray:
    """For a sorted group array, the 0-based occurrence index within group."""
    n = groups.shape[0]
    if n == 0:
        return np.zeros(0, np.int64)
    starts = np.ones(n, bool)
    starts[1:] = groups[1:] != groups[:-1]
    idx = np.arange(n)
    start_idx = np.maximum.accumulate(np.where(starts, idx, 0))
    return idx - start_idx
