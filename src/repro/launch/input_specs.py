"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation).

``input_specs(cfg, shape_name)`` returns the abstract batch for the step
kind of that shape (train / prefill / decode); ``make_step_fn`` returns the
matching step callable so the dry-run lowers exactly what production runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES
from repro.models.config import ModelConfig

__all__ = ["input_specs", "abstract_batch"]

I32 = jnp.int32
F32 = jnp.float32


def abstract_batch(cfg: ModelConfig, *, batch: int, seq: int,
                   kind: str) -> dict:
    """Abstract (ShapeDtypeStruct) inputs for one step of ``kind``."""
    sds = jax.ShapeDtypeStruct
    if kind == "decode":
        return {"tokens": sds((batch, 1), I32), "pos": sds((batch,), I32)}
    if cfg.modality == "audio_frames":
        out = {"frames": sds((batch, seq, cfg.frontend_dim), F32)}
        if kind == "train":
            out["labels"] = sds((batch, seq), I32)
        return out
    if cfg.modality == "image_patches":
        text = seq - cfg.frontend_tokens
        return {"tokens": sds((batch, text), I32),
                "patches": sds((batch, cfg.frontend_tokens,
                                cfg.frontend_dim), F32)}
    return {"tokens": sds((batch, seq), I32)}


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    spec = INPUT_SHAPES[shape_name]
    return abstract_batch(cfg, batch=spec["global_batch"],
                          seq=spec["seq_len"], kind=spec["kind"])
