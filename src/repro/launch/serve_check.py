"""Publish-while-serving torn-read + parity check (run as a subprocess).

The end-to-end serving story on fake CPU devices (DESIGN.md §10): a
background :class:`NomadLDA` ring trains and publishes a φ snapshot every
``--publish-every`` sweeps into a live :class:`LdaEngine` while the main
thread fires ≥``--queries`` batched θ queries at it.  After the ring
joins, every answer is audited:

* **torn reads** — each answer's ``(generation, digest)`` must match
  exactly one published snapshot.  Because a reader pins the buffer with
  a single reference read, this count must be zero no matter how the
  publishes interleave.
* **fold-in parity** — each answer's per-document counts are recomputed
  with the *serial* ``core/heldout.py:fold_in`` against the φ of the
  generation the answer claims, under the same base key.  Batched padded
  serving must be bit-exact, across every generation, for the whole run.
* **fused×scan exactness** — every unique ``(composition, key,
  generation)`` the live engine answered is replayed offline through a
  second engine built with the *other* ``inner_mode``; the Pallas
  fold-in kernel and the scan path must agree bit-for-bit.

Queries rotate through a fixed document pool (including an empty, a
single-token, and a long outlier document that forces length-bucket
splits) and a small key cycle, so serial references are cached by
``(composition, key, generation)`` and the audit stays cheap.

Sets ``XLA_FLAGS`` *before* importing jax and prints a JSON report as
the last stdout line, like the other ``launch/*_check`` harnesses; exits
nonzero unless every check passes.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading


def _parse(argv):
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--n-devices", type=int, default=4)
    p.add_argument("--sweeps", type=int, default=9,
                   help="total trainer sweeps")
    p.add_argument("--publish-every", type=int, default=3)
    p.add_argument("--queries", type=int, default=100,
                   help="minimum reader queries (keeps going while the "
                        "trainer is still publishing)")
    p.add_argument("--batch", type=int, default=8,
                   help="documents per query")
    p.add_argument("--fold-sweeps", type=int, default=3)
    p.add_argument("--key-cycle", type=int, default=5)
    p.add_argument("--pool", type=int, default=12,
                   help="fixed document-pool size")
    p.add_argument("--inner-mode", choices=("scan", "fused"),
                   default="scan",
                   help="fold-in path for the live engine; the audit "
                        "replays answers through the other mode")
    return p.parse_args(argv)


def _build_trainer(args):
    import jax

    from repro.core.nomad import NomadLDA
    from repro.data import synthetic
    from repro.data.sharding import build_layout

    T = 8
    corpus, _, _ = synthetic.make_corpus(
        num_docs=80, vocab_size=128, num_topics=T, mean_doc_len=25.0,
        seed=3)
    n_dev = args.n_devices
    mesh = jax.make_mesh((n_dev,), ("worker",))
    lay = build_layout(corpus, n_workers=n_dev, T=T, n_blocks=n_dev)
    lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                   alpha=50.0 / T, beta=0.01, sync_mode="stoken",
                   inner_mode="scan")
    return lda, corpus


def _doc_pool(corpus, n_pool: int):
    """Fixed query documents over the trained vocabulary; slots 0 and 1
    are the degenerate cases (empty, single-token) and slot 2 is a long
    outlier that lands in its own length bucket."""
    import numpy as np
    rng = np.random.default_rng(7)
    words = np.unique(np.asarray(corpus.word_ids))
    # 200 tokens → a pow-2 bucket >4x any median the short docs can
    # produce, so the engine's outlier rule always splits it off
    lens = [0, 1, 200] + [int(rng.integers(2, 24)) for _ in range(n_pool - 3)]
    return [rng.choice(words, size=n, replace=True).astype(np.int32)
            for n in lens]


def run_check(args) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.heldout import (_fold_in_core, _positions_in_doc)
    from repro.serve.lda_engine import LdaEngine, TopicQuery

    lda, corpus = _build_trainer(args)
    engine = LdaEngine(sweeps=args.fold_sweeps, tile=4,
                       max_batch=max(args.batch, 8),
                       inner_mode=args.inner_mode)

    published = {}            # generation -> {"digest", "phi", "alpha"}
    pub_lock = threading.Lock()

    def record_publish(snap):
        gen = engine.publish(snap)
        with pub_lock:
            published[gen] = {"digest": snap.digest,
                              "phi": np.asarray(snap.phi),
                              "alpha": snap.alpha,
                              "sweep": snap.meta.get("sweep"),
                              "snap": snap}
        return gen

    # generation 1: the init-state counts, published before serving opens
    record_publish(lda.export_phi_snapshot(lda.init_arrays(seed=0),
                                           sweep=0))

    trainer_exc = []

    def trainer():
        try:
            lda.run(args.sweeps, init_seed=0,
                    publish_every=args.publish_every,
                    on_publish=record_publish)
        except BaseException as e:           # surfaced in the report
            trainer_exc.append(repr(e))

    pool = _doc_pool(corpus, args.pool)
    P, b = len(pool), args.batch
    answers = []
    th = threading.Thread(target=trainer, daemon=True)
    th.start()
    i = 0
    while i < args.queries or th.is_alive():
        comp, kidx = i % P, i % args.key_cycle
        docs = tuple(pool[(comp + j) % P] for j in range(b))
        res = engine.query(TopicQuery(
            docs=docs, key=jax.random.key(1000 + kidx)))
        answers.append({"comp": comp, "kidx": kidx,
                        "generation": res.generation, "digest": res.digest,
                        "n_td": res.n_td, "theta": res.theta})
        i += 1
    th.join()

    # ---- audit ----------------------------------------------------------
    gens_seen = sorted({a["generation"] for a in answers})
    torn = sum(1 for a in answers
               if published.get(a["generation"], {}).get("digest")
               != a["digest"])

    ref_fn = jax.jit(_fold_in_core, static_argnames=("num_docs", "sweeps"))
    ref_cache = {}

    def serial_ref(comp, kidx, gen):
        ck = (comp, kidx, gen)
        if ck not in ref_cache:
            docs = [pool[(comp + j) % P] for j in range(b)]
            w = np.concatenate(docs).astype(np.int32)
            d = np.concatenate([np.full(x.size, j, np.int32)
                                for j, x in enumerate(docs)])
            pub = published[gen]
            n_td = ref_fn(jnp.asarray(w), jnp.asarray(d),
                          jnp.asarray(_positions_in_doc(d)),
                          jnp.asarray(pub["phi"]), pub["alpha"],
                          jax.random.key(1000 + kidx),
                          num_docs=b, sweeps=args.fold_sweeps)
            ref_cache[ck] = np.asarray(n_td)
        return ref_cache[ck]

    mismatch = 0
    theta_bad = 0
    for a in answers:
        if a["generation"] not in published:
            mismatch += 1
            continue
        ref = serial_ref(a["comp"], a["kidx"], a["generation"])
        if not np.array_equal(ref, a["n_td"]):
            mismatch += 1
        if not np.allclose(a["theta"].sum(1), 1.0, atol=1e-5):
            theta_bad += 1

    # ---- fused×scan exactness ------------------------------------------
    # Replay every unique (composition, key, generation) through an
    # offline engine on the OTHER inner mode; the Pallas kernel and the
    # scan path must be bit-identical through the whole serving stack
    # (bucketing, padding, publish generations included).
    other = "fused" if args.inner_mode == "scan" else "scan"
    cross_eng = LdaEngine(sweeps=args.fold_sweeps, tile=4,
                          max_batch=max(args.batch, 8), inner_mode=other)
    triples = sorted({(a["comp"], a["kidx"], a["generation"])
                      for a in answers if a["generation"] in published})
    by_triple = {(a["comp"], a["kidx"], a["generation"]): a
                 for a in answers}
    cross_mismatch = 0
    for gen in sorted(published):
        if not any(t[2] == gen for t in triples):
            continue
        cross_eng.publish(published[gen]["snap"])
        for comp, kidx, g in triples:
            if g != gen:
                continue
            docs = tuple(pool[(comp + j) % P] for j in range(b))
            res = cross_eng.query(TopicQuery(
                docs=docs, key=jax.random.key(1000 + kidx)))
            if not np.array_equal(res.n_td,
                                  by_triple[(comp, kidx, gen)]["n_td"]):
                cross_mismatch += 1

    ok = (torn == 0 and mismatch == 0 and theta_bad == 0
          and cross_mismatch == 0
          and not trainer_exc and len(published) >= 3
          and len(answers) >= args.queries
          and len(gens_seen) >= 2)          # actually interleaved
    return {"publishes": len(published), "queries": len(answers),
            "generations_seen": gens_seen, "torn_reads": torn,
            "fold_in_mismatch": mismatch, "theta_rows_bad": theta_bad,
            "serial_refs_computed": len(ref_cache),
            "inner_mode": args.inner_mode,
            "cross_mode_replays": len(triples),
            "cross_mode_mismatch": cross_mismatch,
            "trainer_error": trainer_exc[0] if trainer_exc else None,
            "all_ok": ok}


def main(argv=None) -> None:
    args = _parse(sys.argv[1:] if argv is None else argv)
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.n_devices} "
        + os.environ.get("XLA_FLAGS", ""))
    report = run_check(args)
    print(json.dumps(report))
    if not report["all_ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
