"""s-token staleness check (run as a subprocess).

Usage:  python -m repro.launch.stoken_lag_check [n_devices] [inner_mode]
                                                [n_blocks]

``sync_mode="stoken"`` lets every worker sample against a **stale** working
copy of the global topic counts — the paper's Alg. 4, whose correctness
argument is that the staleness is *bounded*: a worker's copy is refreshed
every ``W`` rounds, and the information it carries about any other worker
is at most ``W−1`` ring rounds (= ``(W−1)·k`` cell sweeps) old at the
moment the token is received (DESIGN.md §4).

This check instruments one sweep with ``nomad_sweep_fn(collect_lag=True)``
— which records, per round and worker, ``n_t_local`` after the round's
synchronization and the cumulative own-delta ``delta_mine``, adding **no**
collectives — and verifies, in numpy, for BOTH ring modes × BOTH token
layouts (dense cell grid / ragged tile streams):

* **fold schedule, exactly.**  The s token visits workers in ring order
  (holder of round ``ρ`` is ``(−ρ) mod W``), so worker ``w``'s copy at the
  end of round ``r`` must equal
  ``n_t0 + delta_mine[r, w] + Σ_{w'≠w} delta_mine[ρ'', w']`` with
  ``ρ'' = r_h − ((w'−w) mod W)`` and ``r_h`` the worker's last hold round
  (terms with ``ρ'' < 0`` drop — the token hadn't reached ``w'`` yet).
  Asserted bit-exactly; this pins the fold point of both ring schedules.
* **staleness bound.**  The L1 gap between the copy and the exact counts
  (``n_t0 + Σ_w delta_mine[r, w]``) is at most twice the number of tokens
  in the cell sweeps the copy has not seen — computed exactly from the
  deterministic schedule and ``layout.cell_sizes``.  Per source worker the
  unseen window is ≤ ``W−1`` rounds (``(W−1)·k`` cells) at fold rounds,
  and ≤ ``2(W−1)`` rounds between folds (up to ``W−1`` rounds of token
  age at receipt + up to ``W−1`` rounds holding the copy).
* **ring-mode equivalence.**  The pipelined ring's lag trace is
  bit-identical to the barrier ring's — pipelining moves only when the
  first half-queue's hop is issued, not what any worker's copy contains.
* **layout equivalence.**  The ragged layout's lag trace is bit-identical
  to the dense one's — the tile-stream geometry changes how tokens are
  stored, not which deltas any round produces or when s folds.

Prints one JSON report with per-check booleans and summary magnitudes.
"""
import json
import os
import sys


def main() -> None:
    n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    inner_mode = sys.argv[2] if len(sys.argv) > 2 else "scan"
    n_blocks = int(sys.argv[3]) if len(sys.argv) > 3 else 2 * n_dev

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.nomad import NomadLDA, nomad_sweep_fn
    from repro.data import synthetic
    from repro.data.sharding import build_layout

    assert len(jax.devices()) == n_dev, jax.devices()

    T = 16
    W = n_dev
    alpha, beta = 50.0 / T, 0.01
    corpus, _, _ = synthetic.make_corpus(
        num_docs=120, vocab_size=256, num_topics=T, mean_doc_len=30.0, seed=3)
    mesh = jax.make_mesh((n_dev,), ("worker",))

    diags = {}
    for kind in ("dense", "ragged"):
        layout = build_layout(corpus, n_workers=n_dev, T=T,
                              n_blocks=n_blocks, layout=kind)
        k = layout.k
        lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=layout,
                       alpha=alpha, beta=beta, sync_mode="stoken",
                       inner_mode=inner_mode)
        arrays = lda.init_arrays(seed=0)
        n_t0 = np.asarray(arrays["n_t"]).astype(np.int64)
        for ring_mode in ("barrier", "pipelined"):
            sweep = nomad_sweep_fn(
                mesh, ("worker",), B=layout.B, T=T, alpha=alpha, beta=beta,
                beta_bar=lda.beta_bar, sync_mode="stoken",
                inner_mode=inner_mode, ring_mode=ring_mode, collect_lag=True,
                layout_kind=kind, tile=layout.tile, n_tiles=layout.n_tiles,
                tile_split=layout.tile_split, rng_stride=layout.L)
            args = (arrays["tok_doc"], arrays["tok_wrd"],
                    arrays["tok_valid"], arrays["tok_bound"], arrays["z"],
                    arrays["n_td"], arrays["n_wt"], arrays["n_t"],
                    jnp.int32(0))
            if kind == "ragged":
                args += (arrays["cell_of_tile"], arrays["tok_slot"])
            *_, diag = sweep(*args)
            diags[kind, ring_mode] = np.asarray(diag).astype(np.int64)

    ring_modes_identical = bool(
        (diags["dense", "barrier"] == diags["dense", "pipelined"]).all())
    layouts_identical = all(
        bool((diags["dense", rm] == diags["ragged", rm]).all())
        for rm in ("barrier", "pipelined"))

    diag = diags["dense", "barrier"]      # (W_rounds, W, 2, T)
    local = diag[:, :, 0]                 # n_t_local after round sync
    delta = diag[:, :, 1]                 # cumulative delta_mine
    exact = n_t0[None] + delta.sum(axis=1)            # (W_rounds, T)

    def round_tokens(w, rho):
        c = (w + rho) % W
        return int(layout.cell_sizes[w, c * k:(c + 1) * k].sum())

    fold_schedule_exact = True
    lag_within_bound = True
    lag_max = 0
    bound_max = 0
    lag_nonzero = False
    fold_window_max = 0                   # unseen rounds/source at folds
    window_max = 0                        # unseen rounds/source, any round
    for r in range(W):
        for w in range(W):
            r_h0 = (-w) % W               # worker w's first hold round
            held = r >= r_h0
            r_h = r_h0 + ((r - r_h0) // W) * W if held else None
            expected = n_t0 + delta[r, w]
            missing_tokens = 0
            for w2 in range(W):
                if w2 == w:
                    continue
                d = (w2 - w) % W
                rho = (r_h - d) if held else -1
                if rho >= 0:
                    expected = expected + delta[rho, w2]
                unseen_lo = max(rho + 1, 0)
                window = r - unseen_lo + 1
                window_max = max(window_max, window)
                if held and r == r_h:
                    fold_window_max = max(fold_window_max, window)
                missing_tokens += sum(
                    round_tokens(w2, rho2) for rho2 in range(unseen_lo, r + 1))
            if (local[r, w] != expected).any():
                fold_schedule_exact = False
            lag = int(np.abs(local[r, w] - exact[r]).sum())
            bound = 2 * missing_tokens    # one token move: ±1 at two coords
            lag_max = max(lag_max, lag)
            bound_max = max(bound_max, bound)
            lag_nonzero = lag_nonzero or lag > 0
            if lag > bound:
                lag_within_bound = False

    report = {
        "n_devices": n_dev,
        "inner_mode": inner_mode,
        "n_blocks": layout.B,
        "k": k,
        "ring_modes_identical": ring_modes_identical,
        "layout_modes_identical": layouts_identical,
        "fold_schedule_exact": fold_schedule_exact,
        "lag_within_bound": lag_within_bound,
        "lag_nonzero": lag_nonzero,
        "lag_max_l1": lag_max,
        "bound_max_l1": bound_max,
        # unseen-window sizes, in rounds per source worker (k cells each):
        "fold_window_rounds_max": fold_window_max,
        "fold_window_rounds_bound": W - 1,        # the documented bound
        "window_rounds_max": window_max,
        "window_rounds_bound": 2 * (W - 1),
        "documented_bound_ok": fold_window_max <= W - 1
                               and window_max <= 2 * (W - 1),
    }
    print(json.dumps(report))


if __name__ == "__main__":
    main()
