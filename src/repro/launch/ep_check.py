"""Expert-parallel MoE correctness check (run as a subprocess).

Usage: python -m repro.launch.ep_check [n_devices]
Builds a smoke MoE config, runs the same tokens through the single-program
path and the shard_map EP path (experts sharded over 'model', tokens
chunked, two all-to-alls), and reports the max output difference — with
generous capacity both paths drop nothing and must agree.
"""
import json
import os
import sys


def main() -> None:
    n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.launch.ep import make_ep_ctx
    from repro.models import moe as moe_mod

    cfg = get_config("deepseek-moe-16b").smoke()   # 4 experts, top-2, shared
    assert cfg.num_experts % n_dev == 0 or n_dev % cfg.num_experts == 0
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))

    key = jax.random.key(0)
    p = moe_mod.moe_init(key, cfg)
    B, S = 2, 4 * n_dev
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))

    y_single, aux_single = jax.jit(
        lambda p, x: moe_mod.moe_forward(p, cfg, x, capacity_factor=8.0)
    )(p, x)

    ep_ctx = make_ep_ctx(mesh, cfg, capacity_factor=8.0)
    assert ep_ctx is not None, "EP not engaged"
    with mesh:
        x_sh = jax.device_put(x, NamedSharding(mesh, P("data", "model",
                                                       None)))
        y_ep, aux_ep = jax.jit(lambda p, x: ep_ctx(p, x))(p, x_sh)

    diff = float(jnp.abs(y_single - y_ep).max())
    rel = diff / float(jnp.abs(y_single).max())
    print(json.dumps({
        "n_devices": n_dev,
        "max_abs_diff": diff,
        "max_rel_diff": rel,
        "aux_single": float(aux_single),
        "aux_ep": float(aux_ep),
        "agree": rel < 1e-4,
    }))


if __name__ == "__main__":
    main()
