"""Parameter / activation / cache sharding rules for the production mesh.

Layout (DESIGN.md §4):
    batch                over ('pod','data')   (or ('data',) single-pod)
    TP (heads, d_ff, vocab, experts) over 'model'
    FSDP: contracting dims of big weight matrices additionally over 'data'
          (required for kimi-k2: 1T params / 512 chips).

Rules are name-based on the param pytree paths produced by
``transformer.init_params`` — stacked segment params carry a leading layer
axis that is never sharded.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["batch_axes", "param_specs", "cache_specs", "batch_specs",
           "train_state_specs", "sds_with_sharding"]


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _param_spec(path: str, ndim: int, fsdp: bool,
                attn_model_shard: bool = True) -> P:
    """PartitionSpec for one parameter; the layer-stack axis (leading axis of
    segment params) is handled by padding specs with None on the left.

    attn_model_shard=False: heads don't divide the model axis (e.g.
    internvl2's 14 q / 2 kv heads on a 16-way axis) — sharding the flat
    qkv output dim makes GSPMD reshard (B,S,H,D) activations with per-layer
    all-reduces (§Perf pair-2 finding: 1.4 TB/device).  Replicate attention
    weights instead; MLP TP carries the model axis."""
    d_axis = "data" if fsdp else None

    def pad(spec_tail: tuple) -> P:
        return P(*([None] * (ndim - len(spec_tail)) + list(spec_tail)))

    name = path.split("/")[-1]
    if name in ("embed",):
        return P("model", d_axis)
    if name == "lm_head":
        return pad((d_axis, "model"))
    if name == "frontend_proj":
        return pad((None, None))
    # attention
    if name in ("wq", "wk", "wv"):
        return pad((d_axis, "model" if attn_model_shard else None))
    if name == "wo":
        return pad(("model" if attn_model_shard else None, d_axis))
    # mlp (dense + shared experts)
    if name in ("w_gate", "w_up") and "mlp" in path and ndim <= 3 \
            and "shared" not in path:
        # routed expert weights are (L, E, d, f) — handled below by ndim
        return pad((d_axis, "model"))
    if "shared" in path and name in ("w_gate", "w_up"):
        return pad((d_axis, "model"))
    if "shared" in path and name == "w_down":
        return pad(("model", d_axis))
    if name == "w_down" and ndim <= 3:
        return pad(("model", d_axis))
    # MoE routed experts: (L, E, d, f) / (L, E, f, d) → experts over model,
    # contracting dim over 'data' when FSDP is on.
    if name in ("w_gate", "w_up", "w_down") and ndim >= 4:
        return P(*([None] * (ndim - 3)), "model", d_axis, None)
    if name == "router":
        return pad((None, "model"))
    # ssm
    if name == "in_proj":
        return pad((d_axis, "model"))
    if name == "out_proj":
        return pad(("model", d_axis))
    if name in ("conv_w", "conv_b"):
        return pad(("model",)) if name == "conv_b" else pad((None, "model"))
    # norms, scalars, A_log, dt_bias, D, q_norm, k_norm …
    return P(*([None] * ndim))


def param_specs(params_shape, mesh: Mesh, *, fsdp: bool = False,
                attn_model_shard: bool = True):
    """Pytree of PartitionSpec matching a params (shape) pytree."""
    def one(path, leaf):
        return _param_spec(_path_str(path), len(leaf.shape), fsdp,
                           attn_model_shard)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def _cache_spec(path: str, shape: tuple, baxes, bsize: int) -> P:
    name = path.split("/")[-1]
    ndim = len(shape)
    lead = ndim - {"k": 4, "v": 4, "len": 1, "conv": 3, "ssm": 4,
                   "slot_pos": 2}[name]
    pre = [None] * lead
    B = shape[lead]
    batch_shardable = B % bsize == 0
    if name in ("k", "v"):       # (…,B,S,Hkv,Dh)
        if batch_shardable:
            return P(*pre, baxes, None, "model", None)
        # tiny-batch long-context decode: shard the sequence axis instead
        return P(*pre, None, baxes, "model", None)
    if name == "len":            # (…,B)
        return P(*pre, baxes) if batch_shardable else P(*pre, None)
    if name == "slot_pos":       # (…,B,S_cache) ring-buffer positions
        return P(*pre, baxes if batch_shardable else None, None)
    if name == "conv":           # (…,B,W-1,C)
        return P(*pre, baxes if batch_shardable else None, None, "model")
    if name == "ssm":            # (…,B,H,P,N)
        if batch_shardable:
            return P(*pre, baxes, "model", None, None)
        return P(*pre, None, "model", baxes, None)
    raise ValueError(name)


def cache_specs(cache_shape, mesh: Mesh):
    baxes = batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in baxes]))
    def one(path, leaf):
        return _cache_spec(_path_str(path), leaf.shape, baxes, bsize)
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_specs(batch_shape, mesh: Mesh):
    baxes = batch_axes(mesh)
    def one(path, leaf):
        return P(baxes, *([None] * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map_with_path(one, batch_shape)


def train_state_specs(state_shape, mesh: Mesh, *, fsdp: bool = False,
                      attn_model_shard: bool = True):
    """TrainState = (params, AdamWState(step, m, v)): m/v mirror params."""
    p_specs = param_specs(state_shape.params, mesh, fsdp=fsdp,
                          attn_model_shard=attn_model_shard)
    return type(state_shape)(
        params=p_specs,
        opt=type(state_shape.opt)(step=P(), m=p_specs,
                                  v=jax.tree_util.tree_map(lambda s: s,
                                                           p_specs)))


def sanitize_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop spec axes that do not divide the dimension (e.g. odd vocabs)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(entry if dim % size == 0 else None)
    return P(*out)


def sds_with_sharding(shape_tree, spec_tree, mesh: Mesh):
    """ShapeDtypeStructs carrying NamedShardings (for .lower())."""
    def one(sds, spec):
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=NamedSharding(mesh,
                                   sanitize_spec(spec, sds.shape, mesh)))
    return jax.tree_util.tree_map(one, shape_tree, spec_tree,
                                  is_leaf=lambda x: isinstance(
                                      x, jax.ShapeDtypeStruct))
