"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

Proves the distribution config is coherent without real hardware:
``.lower().compile()`` must succeed on the 16×16 single-pod mesh and the
2×16×16 multi-pod mesh for every assigned architecture and input shape;
``memory_analysis()`` proves it fits, ``cost_analysis()`` feeds §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
    python -m repro.launch.dryrun --arch lda --shape train_4k   # the paper
Writes one JSON report per combo into reports/dryrun/.
"""
# The VERY FIRST lines: fake a 512-device host platform BEFORE any jax
# import (jax locks the device count on first init).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                     # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCHS, INPUT_SHAPES, get_config,  # noqa: E402
                           shape_applicable)
from repro.launch import sharding_rules as rules            # noqa: E402
from repro.launch.ep import make_ep_ctx                    # noqa: E402
from repro.launch.input_specs import input_specs            # noqa: E402
from repro.launch.mesh import (HW, make_lda_mesh,           # noqa: E402
                               make_production_mesh)
from repro.models import transformer                        # noqa: E402
from repro.serve import serve_step as serve_mod             # noqa: E402
from repro.train.train_step import (init_train_state,       # noqa: E402
                                    make_train_step)

REPORTS = os.path.join(os.path.dirname(__file__), "../../../reports/dryrun")


# ---------------------------------------------------------------------------
# Collective-bytes extraction (for §Roofline; cost_analysis lacks it).
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "pred": 1, "s8": 1,
                "u8": 1, "f8e4m3": 1, "f8e5m2": 1}

# post-partitioning HLO line:  %name = f32[..]{layout} all-gather(...)
_COLL_LINE = re.compile(
    r"^\s*[%\w.\-]+\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[^\]]*\]))(?:\{[^}]*\})?"
    r"\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(", re.M)


def collective_bytes(compiled_hlo: str) -> dict:
    """Per-device result bytes of every collective in the partitioned HLO.

    Counts plain and ``-start`` (async) forms; ``-done`` is skipped (same
    transfer).  Tuple-shaped ``-start`` results hold in+out buffers → halved.
    """
    out = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = dict(out)
    for m in _COLL_LINE.finditer(compiled_hlo):
        shapes, kind, started = m.group(1), m.group(2), m.group(3)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes):
            if dt not in _DTYPE_BYTES:
                continue
            size = 1
            for d in filter(None, dims.split(",")):
                size *= int(d)
            nbytes += size * _DTYPE_BYTES[dt]
        if started and shapes.startswith("("):
            nbytes //= 2
        out[kind] += nbytes
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["op_counts"] = counts
    return out


# ---------------------------------------------------------------------------
# Lowering helpers.
# ---------------------------------------------------------------------------
def lower_arch(arch: str, shape_name: str, mesh, *, fsdp=None,
               dtype=None, chunked_ce: bool = False,
               act_shard: bool = False, ring_kv: bool = False,
               layer_remat: bool = False, attn_replicate: bool = False,
               attn_seq_shard: bool = False, moe_cap: float = 1.25):
    cfg = get_config(arch)
    spec = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        cfg = cfg.with_long_context()
    ok, note = shape_applicable(get_config(arch), shape_name)
    if not ok:
        return None, note
    if fsdp is None:
        fsdp = cfg.param_count() * 2 > 8e9 * mesh.devices.size / 64
        fsdp = fsdp or cfg.param_count() > 50e9
    dtype = dtype or jnp.float32

    batch_sds = input_specs(cfg, shape_name)
    batch_specs_tree = rules.batch_specs(batch_sds, mesh)
    batch_sds = rules.sds_with_sharding(batch_sds, batch_specs_tree, mesh)

    act_sharding = None
    if act_shard:
        act_sharding = NamedSharding(
            mesh, P(rules.batch_axes(mesh), None, None))
    attn_ms = not attn_replicate
    attn_seq_sharding = None
    if attn_seq_shard:
        attn_seq_sharding = NamedSharding(
            mesh, P(rules.batch_axes(mesh), "model", None))

    if spec["kind"] == "train":
        state_shape = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.key(0), dtype))
        state_specs = rules.train_state_specs(state_shape, mesh, fsdp=fsdp,
                                              attn_model_shard=attn_ms)
        state_sds = rules.sds_with_sharding(state_shape, state_specs, mesh)
        ep_ctx = make_ep_ctx(mesh, cfg, capacity_factor=moe_cap)
        step = make_train_step(cfg, ep_ctx=ep_ctx, chunked_ce=chunked_ce,
                               act_sharding=act_sharding,
                               layer_remat=layer_remat)
        with mesh:
            lowered = jax.jit(step).lower(state_sds, batch_sds)
        return lowered, note

    # serving shapes
    params_shape = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.key(0), dtype))
    p_specs = rules.param_specs(params_shape, mesh, fsdp=fsdp,
                                attn_model_shard=attn_ms)
    params_sds = rules.sds_with_sharding(params_shape, p_specs, mesh)
    B, S = spec["global_batch"], spec["seq_len"]
    cache_shape = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, S, dtype, ring=ring_kv))
    c_specs = rules.cache_specs(cache_shape, mesh)
    cache_sds = rules.sds_with_sharding(cache_shape, c_specs, mesh)

    if spec["kind"] == "prefill":
        ep_ctx = make_ep_ctx(mesh, cfg)

        def step(params, batch, cache):
            logits, new_cache, _ = transformer.forward(
                params, cfg, batch, cache=cache, ep_ctx=ep_ctx,
                act_sharding=act_sharding,
                attn_seq_sharding=attn_seq_sharding)
            return logits, new_cache
        with mesh:
            lowered = jax.jit(step).lower(params_sds, batch_sds, cache_sds)
        return lowered, note

    # decode
    def step(params, tokens, pos, cache):
        return serve_mod.decode_step(params, cfg, tokens, pos, cache)
    with mesh:
        lowered = jax.jit(step).lower(
            params_sds, batch_sds["tokens"], batch_sds["pos"], cache_sds)
    return lowered, note


def lower_lda(shape_name: str, mesh, *, topics=1024, sync_mode="stoken",
              inner_mode="scan"):
    """Lower the paper's own workload: one Nomad F+LDA sweep on the mesh.

    The LDA 'input shape' maps the corpus scale: tokens ≈ batch×seq of the
    named shape, vocabulary 10k·ring, T=1024 (the paper's setting)."""
    import numpy as np
    from repro.core.nomad import nomad_sweep_fn
    spec = INPUT_SHAPES[shape_name]
    W = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    ring_axes = tuple(mesh.axis_names)
    n_tokens = spec["global_batch"] * spec["seq_len"]
    L = max(64, n_tokens // (W * W))
    I_max, J_max, T, B = 1024, 64, topics, W
    beta = 0.01
    sweep = nomad_sweep_fn(mesh, ring_axes, B=B, T=T, alpha=50.0 / T,
                           beta=beta, beta_bar=beta * J_max * B,
                           sync_mode=sync_mode, inner_mode=inner_mode)
    sds = jax.ShapeDtypeStruct
    ring = P(ring_axes)
    sh = lambda spec_: NamedSharding(mesh, spec_)
    tok = sds((W, B, L), jnp.int32, sharding=sh(P(ring_axes, None, None)))
    tokb = sds((W, B, L), jnp.bool_, sharding=sh(P(ring_axes, None, None)))
    args = (
        tok, tok, tokb, tokb, tok,                      # tok arrays + z
        sds((W, I_max, T), jnp.int32, sharding=sh(P(ring_axes, None, None))),
        sds((B, J_max, T), jnp.int32, sharding=sh(P(ring_axes, None, None))),
        sds((T,), jnp.int32, sharding=sh(P())),
        sds((), jnp.int32, sharding=sh(P())),
    )
    with mesh:
        lowered = sweep.lower(*args)
    return lowered, (f"nomad sweep: W={W} ring, L={L} cell, T={T}, "
                     f"sync={sync_mode}, inner={inner_mode}")


# ---------------------------------------------------------------------------
# Report.
# ---------------------------------------------------------------------------
def analyse(lowered, arch, shape_name, mesh_name, n_chips, note=""):
    """Numbers are PER-DEVICE: the compiled artifact is the partition
    program (verified against a hand-sharded matmul).

    flops/bytes/collectives come from the while-aware HLO analyzer
    (repro.roofline.hlo_cost) because XLA's cost_analysis counts scan
    bodies ONCE (verified: an 8-step scanned matmul reports 1/8 of the
    unrolled flops); xla_cost_analysis is kept for reference."""
    from repro.roofline.hlo_cost import analyze_hlo
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo_text = compiled.as_text()
    acc = analyze_hlo(hlo_text)
    coll = {k: acc.collective_by_kind.get(k, 0)
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute")}
    coll["total"] = acc.collective_bytes

    flops = acc.flops
    bytes_acc = acc.bytes
    report = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": n_chips, "note": note,
        "compile_seconds": round(compile_s, 1),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll,
        "xla_cost_analysis": {          # raw XLA numbers (scan bodies ×1)
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline_seconds": {
            "compute": flops / HW.PEAK_FLOPS,
            "memory": bytes_acc / HW.HBM_BW,
            "collective": coll["total"] / HW.ICI_BW,
        },
    }
    terms = report["roofline_seconds"]
    report["bottleneck"] = max(terms, key=terms.get)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id | all | lda")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--dtype", default="f32", choices=["f32", "bf16"],
                    help="param/activation dtype (§Perf mixed precision)")
    ap.add_argument("--chunked-ce", action="store_true",
                    help="§Perf: never materialize (B,S,V) logits")
    ap.add_argument("--act-shard", action="store_true",
                    help="§Perf: pin layer activations to batch sharding")
    ap.add_argument("--ring-kv", action="store_true",
                    help="§Perf: window-sized ring KV cache (SW archs)")
    ap.add_argument("--layer-remat", action="store_true",
                    help="§Perf: per-layer remat (scan saves layer inputs "
                         "only)")
    ap.add_argument("--attn-replicate", action="store_true",
                    help="§Perf: replicate attention weights (heads "
                         "indivisible by the model axis)")
    ap.add_argument("--attn-seq-shard", action="store_true",
                    help="§Perf: context parallelism — shard S over "
                         "'model' for attention (prefill)")
    ap.add_argument("--moe-cap", type=float, default=1.25,
                    help="§Perf: MoE expert capacity factor")
    ap.add_argument("--lda-topics", type=int, default=1024,
                    help="T for the LDA dry-run (paper scaling axis)")
    ap.add_argument("--tag", default="",
                    help="suffix for report filenames (perf variants)")
    args = ap.parse_args()

    os.makedirs(args.out or REPORTS, exist_ok=True)
    out_dir = args.out or REPORTS

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]

    if args.arch == "lda":
        mesh = make_lda_mesh(multi_pod=args.multi_pod)
        mesh_name = "lda-512" if args.multi_pod else "lda-256"
        for shape_name in shapes:
            tag = f"lda__{shape_name}__{mesh_name}"
            if args.tag:
                tag += "__" + args.tag
            try:
                lowered, note = lower_lda(
                    shape_name, mesh, topics=args.lda_topics,
                    sync_mode=os.environ.get("LDA_SYNC", "stoken"),
                    inner_mode=os.environ.get("LDA_INNER", "scan"))
                rep = analyse(lowered, "lda-fnomad", shape_name, mesh_name,
                              mesh.devices.size, note)
                rep["variant"] = args.tag or "baseline"
            except Exception as e:  # noqa: BLE001
                rep = {"arch": "lda-fnomad", "shape": shape_name,
                       "mesh": mesh_name, "error": str(e),
                       "trace": traceback.format_exc()[-2000:]}
            _write(out_dir, tag, rep)
        return

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    dtype = jnp.bfloat16 if args.dtype == "bf16" else jnp.float32
    for arch in archs:
        for shape_name in shapes:
            tag = f"{arch}__{shape_name}__{mesh_name}"
            if args.tag:
                tag += "__" + args.tag
            try:
                lowered, note = lower_arch(arch, shape_name, mesh,
                                           dtype=dtype,
                                           chunked_ce=args.chunked_ce,
                                           act_shard=args.act_shard,
                                           ring_kv=args.ring_kv,
                                           layer_remat=args.layer_remat,
                                           attn_replicate=args.attn_replicate,
                                           attn_seq_shard=args.attn_seq_shard,
                                           moe_cap=args.moe_cap)
                if lowered is None:
                    rep = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "skipped": note}
                else:
                    rep = analyse(lowered, arch, shape_name, mesh_name,
                                  mesh.devices.size, note)
                    rep["variant"] = args.tag or "baseline"
            except Exception as e:  # noqa: BLE001
                rep = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "error": str(e),
                       "trace": traceback.format_exc()[-2000:]}
            _write(out_dir, tag, rep)


def _write(out_dir, tag, rep):
    path = os.path.join(out_dir, tag + ".json")
    with open(path, "w") as f:
        json.dump(rep, f, indent=1)
    status = ("ERROR " + rep["error"][:120]) if "error" in rep else \
        ("SKIP " + rep.get("skipped", "")) if "skipped" in rep else \
        (f"ok compile={rep['compile_seconds']}s "
         f"bottleneck={rep['bottleneck']}")
    print(f"[dryrun] {tag}: {status}", flush=True)


if __name__ == "__main__":
    main()
