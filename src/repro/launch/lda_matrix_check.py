"""Distributed sweep exactness matrix (run as a subprocess).

Usage:  python -m repro.launch.lda_matrix_check [n_devices] [n_sweeps] \
            [subset]

One faked-multi-device process sweeps every combination of
``sync_mode`` ∈ {stoken, stale, allreduce} × ``inner_mode`` ∈ {scan, fused,
vectorized} × ``B`` × ``ring_mode`` ∈ {barrier, pipelined} × ``layout`` ∈
{dense, ragged} × ``doc_tile`` ∈ {None, I_max//3, 8} and, after each run,
rebuilds the count tables from the final assignments ``z``.  Five
invariants under test (DESIGN.md §4/§7):

* at every sweep boundary ``global_counts`` must be **bit-equal** to the
  rebuild, for any queue length — staleness modes only reorder when ``n_t``
  information travels, never what the counts are;
* the pipelined ring must be **bit-identical** to the barrier ring — same
  ``z``, same ``n_wt``, same ``n_t`` — in every cell, because pipelining
  only moves when the first half-queue's hop is issued, never the cell
  order or the s-token fold point;
* the ragged tile-stream layout must be **bit-identical** to the dense
  cell grid in every cell: both geometries carry the same tokens in the
  same order with the same per-token-uid uniforms, and padding slots are
  exact no-ops;
* for ``doc_tile`` layouts, the **paged** run (fused kernels keep one
  ``(doc_tile, T)`` doc-topic slab VMEM-resident) must be bit-identical
  to the **untiled** run (whole shard resident) over the same layout —
  doc tiling changes memory residency only, never the chain;
* the **sparse r-bucket** run (``r_mode="sparse"``: the r-draw walks
  per-doc compacted side tables instead of recompacting the dense
  ``n_td`` row per token, DESIGN.md §7a) must be bit-identical to the
  same-config dense run for every exact inner mode — both modes draw
  from the same compacted vector, so maintenance strategy is
  chain-invisible (``vectorized`` has no per-token chain and rejects
  sparse mode by construction).

``doc_tile`` values are layout-build-time choices (they fix the token
order), so the untiled reference runs on the *same grouped layout* with
``NomadLDA(doc_tile=None)``; the barrier-ring reference suffices for both
ring modes (pipelined paged ≡ barrier paged by the ring invariant).
``B`` runs {W, 2W, 4W} for ungrouped layouts and {W, 4W} for the doc-tile
axis to bound runtime.

``subset = "smoke"`` (argv[3]) runs a ~30 s slice — both layouts,
doc_tile ∈ {None, 3}, fused/pipelined/stoken at B = 2W with the untiled
twin and (ungrouped only) the sparse-r twin — and reports each layout's ``ntd_slab_bytes`` vs whole-shard bytes
(``repro.kernels.fused_sweep.fused_vmem_bytes``) so CI prints the slab
VMEM number; the full matrix stays behind the tier-1 ``slow`` marker.

Prints one JSON report: ``{"combos": [...], "all_exact": bool}``.
"""
import json
import os
import sys


def main() -> None:
    n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    n_sweeps = int(sys.argv[2]) if len(sys.argv) > 2 else 2
    subset = sys.argv[3] if len(sys.argv) > 3 else "full"
    if subset not in ("full", "smoke"):
        raise SystemExit(f"unknown subset {subset!r} (full|smoke)")

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.core.nomad import NomadLDA
    from repro.data import synthetic
    from repro.data.sharding import build_layout, counts_from_layout
    from repro.kernels.fused_sweep import fused_vmem_bytes

    assert len(jax.devices()) == n_dev, jax.devices()

    T = 8
    alpha, beta = 50.0 / T, 0.01
    smoke = subset == "smoke"
    corpus, _, _ = synthetic.make_corpus(
        num_docs=32 if smoke else 64, vocab_size=96, num_topics=T,
        mean_doc_len=12.0, seed=5)
    mesh = jax.make_mesh((n_dev,), ("worker",))

    def run(layout, sync_mode, inner_mode, ring_mode, doc_page,
            r_mode="dense"):
        lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=layout,
                       alpha=alpha, beta=beta, sync_mode=sync_mode,
                       inner_mode=inner_mode, ring_mode=ring_mode,
                       doc_tile=doc_page, r_mode=r_mode)
        arrays = lda.init_arrays(seed=0)
        for it in range(n_sweeps):
            arrays = lda.sweep(arrays, seed=it)
        n_td, n_wt, n_t = lda.global_counts(arrays)
        td_ref, wt_ref, t_ref = counts_from_layout(
            layout, np.asarray(arrays["z"]), T)
        # canonical per-token assignments: the layout-free view every
        # cross-run comparison (ring / layout / paging) uses
        z_c = layout.extract_canonical(np.asarray(arrays["z"]))
        entry = {
            "B": layout.B, "k": layout.k, "layout": layout.kind,
            "doc_tile": layout.doc_tile or None,
            "paged": doc_page is not None,
            "sync_mode": sync_mode,
            "inner_mode": inner_mode,
            "ring_mode": ring_mode,
            "r_mode": r_mode,
            "pad_fraction": layout.pad_fraction,
            "n_td_mismatch": int(np.abs(n_td - td_ref).sum()),
            "n_wt_mismatch": int(np.abs(n_wt - wt_ref).sum()),
            "n_t_mismatch": int(np.abs(n_t - t_ref).sum()),
            "tokens_preserved":
                int(n_t.sum()) == int(corpus.num_tokens),
        }
        return entry, (z_c, n_wt, np.asarray(n_t))

    def layouts_for(b_mult, dt):
        # small dense grid step so doc-group padding stays bounded on the
        # toy corpus (the N_BLK default is tuned for real streams)
        kw = dict(doc_tile=dt) if dt else {}
        dense = build_layout(corpus, n_workers=n_dev, T=T,
                             n_blocks=b_mult * n_dev,
                             **(dict(kw, doc_blk=16) if dt else {}))
        ragged = build_layout(corpus, n_workers=n_dev, T=T,
                              n_blocks=b_mult * n_dev, layout="ragged",
                              **kw)
        return {"dense": dense, "ragged": ragged}

    combos = []
    if smoke:
        cases = [(2, dt) for dt in (None, 3)]
        sync_modes, inner_modes = ("stoken",), ("fused",)
        ring_modes = ("pipelined",)
    else:
        cases = [(m, None) for m in (1, 2, 4)]
        i_max = layouts_for(1, None)["dense"].I_max
        for dt in (max(i_max // 3, 1), 8):
            cases += [(m, dt) for m in (1, 4)]
        sync_modes = ("stoken", "stale", "allreduce")
        inner_modes = ("scan", "fused", "vectorized")
        ring_modes = ("barrier", "pipelined")

    slab_report = []
    for b_mult, dt in cases:
        layouts = layouts_for(b_mult, dt)
        if dt:
            for kind, lay in layouts.items():
                slab_report.append({
                    "B": lay.B, "layout": kind, "doc_tile": dt,
                    "ntd_slab_bytes": lay.ntd_slab_bytes,
                    "ntd_whole_bytes": lay.ntd_whole_bytes,
                    "fused_vmem_bytes": fused_vmem_bytes(
                        lay.I_max, lay.J_max, lay.T,
                        lay.doc_blk if kind == "dense" else lay.tile,
                        doc_rows=dt),
                })
        for sync_mode in sync_modes:
            for inner_mode in inner_modes:
                per_run = {}
                for kind in ("dense", "ragged"):
                    layout = layouts[kind]
                    if dt:
                        # untiled twin: same grouped layout, whole-shard
                        # residency — the reference every paged run (and,
                        # transitively via vs_barrier, every ring mode)
                        # must reproduce bit-for-bit
                        _, per_run[kind, "untiled"] = run(
                            layout, sync_mode, inner_mode, "barrier", None)
                    for ring_mode in ring_modes:
                        entry, res = run(layout, sync_mode, inner_mode,
                                         ring_mode, dt if dt else None)
                        per_run[kind, ring_mode] = res
                        combos.append(entry)
                        # barrier vs pipelined (same layout): the
                        # per-token chain itself must be unchanged.
                        if ring_mode == "pipelined" and \
                                ("barrier" in ring_modes):
                            _diff(entry, "vs_barrier",
                                  per_run[kind, "barrier"],
                                  per_run[kind, "pipelined"])
                        # ragged vs dense (same ring): same canonical
                        # chain through the other token geometry.
                        if kind == "ragged":
                            _diff(entry, "vs_dense",
                                  per_run["dense", ring_mode],
                                  per_run["ragged", ring_mode])
                        # paged vs untiled (same layout): doc tiling
                        # must be memory-residency-only.
                        if dt:
                            _diff(entry, "vs_untiled",
                                  per_run[kind, "untiled"],
                                  per_run[kind, ring_mode])
                        # sparse vs dense r-bucket (same everything):
                        # side-table maintenance must be chain-invisible.
                        # (Smoke keeps one ungrouped sparse twin per
                        # layout to bound runtime.)
                        if inner_mode != "vectorized" and \
                                not (smoke and dt):
                            sentry, sres = run(
                                layout, sync_mode, inner_mode, ring_mode,
                                dt if dt else None, r_mode="sparse")
                            combos.append(sentry)
                            _diff(sentry, "vs_rdense",
                                  per_run[kind, ring_mode], sres)

    all_exact = all(
        c["n_td_mismatch"] == 0 and c["n_wt_mismatch"] == 0
        and c["n_t_mismatch"] == 0 and c["tokens_preserved"]
        and all(c.get(f"{p}_{f}_mismatch", 0) == 0
                for p in ("vs_barrier", "vs_dense", "vs_untiled",
                          "vs_rdense")
                for f in ("z", "n_wt", "n_t"))
        for c in combos)
    print(json.dumps({"n_devices": n_dev, "n_sweeps": n_sweeps,
                      "subset": subset, "combos": combos,
                      "slab_vmem": slab_report, "all_exact": all_exact}))


def _diff(entry: dict, prefix: str, a, b) -> None:
    """Record mismatch counts between two runs' (canonical z, global n_wt,
    n_t) triples under ``{prefix}_{field}_mismatch`` keys."""
    import numpy as np
    za, wta, ta = a
    zb, wtb, tb = b
    entry[f"{prefix}_z_mismatch"] = int((za != zb).sum())
    entry[f"{prefix}_n_wt_mismatch"] = int(np.abs(wta - wtb).sum())
    entry[f"{prefix}_n_t_mismatch"] = int(
        np.abs(ta.astype(np.int64) - tb.astype(np.int64)).sum())


if __name__ == "__main__":
    main()
