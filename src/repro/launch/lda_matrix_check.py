"""Distributed sweep exactness matrix (run as a subprocess).

Usage:  python -m repro.launch.lda_matrix_check [n_devices] [n_sweeps]

One faked-multi-device process sweeps every combination of
``sync_mode`` ∈ {stoken, stale, allreduce} × ``inner_mode`` ∈ {scan, fused,
vectorized} × ``B`` ∈ {W, 2W, 4W} × ``ring_mode`` ∈ {barrier, pipelined}
and, after each run, rebuilds the count tables from the final assignments
``z``.  Two invariants under test (DESIGN.md §4):

* at every sweep boundary ``global_counts`` must be **bit-equal** to the
  rebuild, for any queue length — staleness modes only reorder when ``n_t``
  information travels, never what the counts are;
* the pipelined ring must be **bit-identical** to the barrier ring — same
  ``z``, same ``n_wt``, same ``n_t`` — in every (sync, inner, B) cell,
  because pipelining only moves when the first half-queue's hop is issued,
  never the cell order or the s-token fold point.

Prints one JSON report: ``{"combos": [...], "all_exact": bool}``.
"""
import json
import os
import sys


def main() -> None:
    n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    n_sweeps = int(sys.argv[2]) if len(sys.argv) > 2 else 2

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.core.nomad import NomadLDA
    from repro.data import synthetic
    from repro.data.sharding import build_layout, counts_from_layout

    assert len(jax.devices()) == n_dev, jax.devices()

    T = 8
    alpha, beta = 50.0 / T, 0.01
    corpus, _, _ = synthetic.make_corpus(
        num_docs=64, vocab_size=96, num_topics=T, mean_doc_len=12.0, seed=5)
    mesh = jax.make_mesh((n_dev,), ("worker",))

    combos = []
    for b_mult in (1, 2, 4):
        layout = build_layout(corpus, n_workers=n_dev, T=T,
                              n_blocks=b_mult * n_dev)
        for sync_mode in ("stoken", "stale", "allreduce"):
            for inner_mode in ("scan", "fused", "vectorized"):
                per_ring = {}
                for ring_mode in ("barrier", "pipelined"):
                    lda = NomadLDA(mesh=mesh, ring_axes=("worker",),
                                   layout=layout, alpha=alpha, beta=beta,
                                   sync_mode=sync_mode,
                                   inner_mode=inner_mode,
                                   ring_mode=ring_mode)
                    arrays = lda.init_arrays(seed=0)
                    for it in range(n_sweeps):
                        arrays = lda.sweep(arrays, seed=it)
                    n_td, n_wt, n_t = lda.global_counts(arrays)
                    td_ref, wt_ref, t_ref = counts_from_layout(
                        layout, np.asarray(arrays["z"]), T)
                    per_ring[ring_mode] = (
                        np.asarray(arrays["z"]), np.asarray(arrays["n_wt"]),
                        np.asarray(arrays["n_t"]))
                    combos.append({
                        "B": layout.B, "k": layout.k,
                        "sync_mode": sync_mode, "inner_mode": inner_mode,
                        "ring_mode": ring_mode,
                        "n_td_mismatch": int(np.abs(n_td - td_ref).sum()),
                        "n_wt_mismatch": int(np.abs(n_wt - wt_ref).sum()),
                        "n_t_mismatch": int(np.abs(n_t - t_ref).sum()),
                        "tokens_preserved":
                            int(n_t.sum()) == int(corpus.num_tokens),
                    })
                # barrier vs pipelined: the per-token chain itself must be
                # unchanged, so z (and with it every table) is bit-equal.
                zb, wtb, tb = per_ring["barrier"]
                zp, wtp, tp = per_ring["pipelined"]
                combos[-1]["vs_barrier_z_mismatch"] = int((zb != zp).sum())
                combos[-1]["vs_barrier_n_wt_mismatch"] = (
                    int(np.abs(wtb - wtp).sum()))
                combos[-1]["vs_barrier_n_t_mismatch"] = (
                    int(np.abs(tb.astype(np.int64)
                               - tp.astype(np.int64)).sum()))

    all_exact = all(
        c["n_td_mismatch"] == 0 and c["n_wt_mismatch"] == 0
        and c["n_t_mismatch"] == 0 and c["tokens_preserved"]
        and c.get("vs_barrier_z_mismatch", 0) == 0
        and c.get("vs_barrier_n_wt_mismatch", 0) == 0
        and c.get("vs_barrier_n_t_mismatch", 0) == 0
        for c in combos)
    print(json.dumps({"n_devices": n_dev, "n_sweeps": n_sweeps,
                      "combos": combos, "all_exact": all_exact}))


if __name__ == "__main__":
    main()
