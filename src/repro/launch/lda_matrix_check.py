"""Distributed sweep exactness matrix (run as a subprocess).

Usage:  python -m repro.launch.lda_matrix_check [n_devices] [n_sweeps]

One faked-multi-device process sweeps every combination of
``sync_mode`` ∈ {stoken, stale, allreduce} × ``inner_mode`` ∈ {scan, fused,
vectorized} × ``B`` ∈ {W, 2W, 4W} × ``ring_mode`` ∈ {barrier, pipelined}
× ``layout`` ∈ {dense, ragged} and, after each run, rebuilds the count
tables from the final assignments ``z``.  Three invariants under test
(DESIGN.md §4):

* at every sweep boundary ``global_counts`` must be **bit-equal** to the
  rebuild, for any queue length — staleness modes only reorder when ``n_t``
  information travels, never what the counts are;
* the pipelined ring must be **bit-identical** to the barrier ring — same
  ``z``, same ``n_wt``, same ``n_t`` — in every (sync, inner, B, layout)
  cell, because pipelining only moves when the first half-queue's hop is
  issued, never the cell order or the s-token fold point;
* the ragged tile-stream layout must be **bit-identical** to the dense
  cell grid — same canonical per-token ``z``, same global tables — in
  every (sync, inner, B, ring) cell: both geometries carry the same
  tokens in the same order with the same per-token-uid uniforms, and
  padding slots are exact no-ops.

Prints one JSON report: ``{"combos": [...], "all_exact": bool}``.
"""
import json
import os
import sys


def main() -> None:
    n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    n_sweeps = int(sys.argv[2]) if len(sys.argv) > 2 else 2

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.core.nomad import NomadLDA
    from repro.data import synthetic
    from repro.data.sharding import build_layout, counts_from_layout

    assert len(jax.devices()) == n_dev, jax.devices()

    T = 8
    alpha, beta = 50.0 / T, 0.01
    corpus, _, _ = synthetic.make_corpus(
        num_docs=64, vocab_size=96, num_topics=T, mean_doc_len=12.0, seed=5)
    mesh = jax.make_mesh((n_dev,), ("worker",))

    combos = []
    for b_mult in (1, 2, 4):
        layouts = {kind: build_layout(corpus, n_workers=n_dev, T=T,
                                      n_blocks=b_mult * n_dev, layout=kind)
                   for kind in ("dense", "ragged")}
        for sync_mode in ("stoken", "stale", "allreduce"):
            for inner_mode in ("scan", "fused", "vectorized"):
                per_run = {}
                for kind in ("dense", "ragged"):
                    layout = layouts[kind]
                    for ring_mode in ("barrier", "pipelined"):
                        lda = NomadLDA(mesh=mesh, ring_axes=("worker",),
                                       layout=layout, alpha=alpha, beta=beta,
                                       sync_mode=sync_mode,
                                       inner_mode=inner_mode,
                                       ring_mode=ring_mode)
                        arrays = lda.init_arrays(seed=0)
                        for it in range(n_sweeps):
                            arrays = lda.sweep(arrays, seed=it)
                        n_td, n_wt, n_t = lda.global_counts(arrays)
                        td_ref, wt_ref, t_ref = counts_from_layout(
                            layout, np.asarray(arrays["z"]), T)
                        # canonical per-token assignments: the layout-free
                        # view both the ring and the layout comparisons use
                        z_c = layout.extract_canonical(
                            np.asarray(arrays["z"]))
                        per_run[kind, ring_mode] = (z_c, n_wt,
                                                    np.asarray(n_t))
                        combos.append({
                            "B": layout.B, "k": layout.k, "layout": kind,
                            "sync_mode": sync_mode,
                            "inner_mode": inner_mode,
                            "ring_mode": ring_mode,
                            "pad_fraction": layout.pad_fraction,
                            "n_td_mismatch": int(np.abs(n_td - td_ref).sum()),
                            "n_wt_mismatch": int(np.abs(n_wt - wt_ref).sum()),
                            "n_t_mismatch": int(np.abs(n_t - t_ref).sum()),
                            "tokens_preserved":
                                int(n_t.sum()) == int(corpus.num_tokens),
                        })
                        # barrier vs pipelined (same layout): the per-token
                        # chain itself must be unchanged.
                        if ring_mode == "pipelined":
                            _diff(combos[-1], "vs_barrier",
                                  per_run[kind, "barrier"],
                                  per_run[kind, "pipelined"])
                        # ragged vs dense (same ring): same canonical chain
                        # through the other token geometry.
                        if kind == "ragged":
                            _diff(combos[-1], "vs_dense",
                                  per_run["dense", ring_mode],
                                  per_run["ragged", ring_mode])

    all_exact = all(
        c["n_td_mismatch"] == 0 and c["n_wt_mismatch"] == 0
        and c["n_t_mismatch"] == 0 and c["tokens_preserved"]
        and all(c.get(f"{p}_{f}_mismatch", 0) == 0
                for p in ("vs_barrier", "vs_dense")
                for f in ("z", "n_wt", "n_t"))
        for c in combos)
    print(json.dumps({"n_devices": n_dev, "n_sweeps": n_sweeps,
                      "combos": combos, "all_exact": all_exact}))


def _diff(entry: dict, prefix: str, a, b) -> None:
    """Record mismatch counts between two runs' (canonical z, global n_wt,
    n_t) triples under ``{prefix}_{field}_mismatch`` keys."""
    import numpy as np
    za, wta, ta = a
    zb, wtb, tb = b
    entry[f"{prefix}_z_mismatch"] = int((za != zb).sum())
    entry[f"{prefix}_n_wt_mismatch"] = int(np.abs(wta - wtb).sum())
    entry[f"{prefix}_n_t_mismatch"] = int(
        np.abs(ta.astype(np.int64) - tb.astype(np.int64)).sum())


if __name__ == "__main__":
    main()
