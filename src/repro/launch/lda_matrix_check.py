"""Distributed sweep exactness matrix (run as a subprocess).

Usage:  python -m repro.launch.lda_matrix_check [n_devices] [n_sweeps]

One faked-multi-device process sweeps every combination of
``sync_mode`` ∈ {stoken, stale, allreduce} × ``inner_mode`` ∈ {scan, fused,
vectorized} × ``B`` ∈ {W, 2W, 4W} and, after each run, rebuilds the count
tables from the final assignments ``z``.  The nomad invariant under test
(DESIGN.md §4): at every sweep boundary ``global_counts`` must be
**bit-equal** to the rebuild, for any queue length — staleness modes only
reorder when ``n_t`` information travels, never what the counts are.

Prints one JSON report: ``{"combos": [...], "all_exact": bool}``.
"""
import json
import os
import sys


def main() -> None:
    n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    n_sweeps = int(sys.argv[2]) if len(sys.argv) > 2 else 2

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.core.nomad import NomadLDA
    from repro.data import synthetic
    from repro.data.sharding import build_layout, counts_from_layout

    assert len(jax.devices()) == n_dev, jax.devices()

    T = 8
    alpha, beta = 50.0 / T, 0.01
    corpus, _, _ = synthetic.make_corpus(
        num_docs=64, vocab_size=96, num_topics=T, mean_doc_len=12.0, seed=5)
    mesh = jax.make_mesh((n_dev,), ("worker",))

    combos = []
    for b_mult in (1, 2, 4):
        layout = build_layout(corpus, n_workers=n_dev, T=T,
                              n_blocks=b_mult * n_dev)
        for sync_mode in ("stoken", "stale", "allreduce"):
            for inner_mode in ("scan", "fused", "vectorized"):
                lda = NomadLDA(mesh=mesh, ring_axes=("worker",),
                               layout=layout, alpha=alpha, beta=beta,
                               sync_mode=sync_mode, inner_mode=inner_mode)
                arrays = lda.init_arrays(seed=0)
                for it in range(n_sweeps):
                    arrays = lda.sweep(arrays, seed=it)
                n_td, n_wt, n_t = lda.global_counts(arrays)
                td_ref, wt_ref, t_ref = counts_from_layout(
                    layout, np.asarray(arrays["z"]), T)
                combos.append({
                    "B": layout.B, "k": layout.k,
                    "sync_mode": sync_mode, "inner_mode": inner_mode,
                    "n_td_mismatch": int(np.abs(n_td - td_ref).sum()),
                    "n_wt_mismatch": int(np.abs(n_wt - wt_ref).sum()),
                    "n_t_mismatch": int(np.abs(n_t - t_ref).sum()),
                    "tokens_preserved":
                        int(n_t.sum()) == int(corpus.num_tokens),
                })

    all_exact = all(
        c["n_td_mismatch"] == 0 and c["n_wt_mismatch"] == 0
        and c["n_t_mismatch"] == 0 and c["tokens_preserved"]
        for c in combos)
    print(json.dumps({"n_devices": n_dev, "n_sweeps": n_sweeps,
                      "combos": combos, "all_exact": all_exact}))


if __name__ == "__main__":
    main()
