"""Production launcher.

LDA (the paper):
    python -m repro.launch.train lda --devices 8 --sweeps 40 [--multi-pod]
Neural archs (substrate):
    python -m repro.launch.train lm --arch qwen3-8b --steps 100 --smoke

The LDA path fakes the device count (training actually executes); the LM
path runs the reduced config on the host devices.  Production-mesh lowering
is exercised by ``repro.launch.dryrun`` (this container has one real core).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("mode", choices=["lda", "lm"])
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--sweeps", type=int, default=40)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--topics", type=int, default=64)
    ap.add_argument("--docs", type=int, default=1000)
    ap.add_argument("--sync", default="stoken",
                    choices=["stoken", "stale", "allreduce"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt.npz")
    args = ap.parse_args()

    if args.mode == "lda":
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
        _run_lda(args)
    else:
        _run_lm(args)


def _run_lda(args):
    import time

    import jax

    from repro.core.nomad import NomadLDA
    from repro.data import synthetic
    from repro.data.sharding import build_layout
    from repro.train import checkpoint

    T = args.topics
    corpus, _, _ = synthetic.make_corpus(
        num_docs=args.docs, vocab_size=4096, num_topics=T,
        mean_doc_len=80.0, seed=0)
    n_dev = len(jax.devices())
    if args.multi_pod and n_dev % 2 == 0:
        mesh = jax.make_mesh((2, n_dev // 2), ("pod", "worker"))
        ring = ("pod", "worker")
    else:
        mesh = jax.make_mesh((n_dev,), ("worker",))
        ring = ("worker",)
    layout = build_layout(corpus, n_workers=n_dev, T=T)
    lda = NomadLDA(mesh=mesh, ring_axes=ring, layout=layout,
                   alpha=50.0 / T, beta=0.01, sync_mode=args.sync)
    arrays = lda.init_arrays(seed=0)
    print(f"[lda] {corpus.num_tokens:,} tokens, {n_dev} workers "
          f"({'x'.join(map(str, mesh.devices.shape))} mesh), "
          f"sync={args.sync}")
    t0 = time.time()
    for it in range(args.sweeps):
        arrays = lda.sweep(arrays, seed=it)
        if (it + 1) % 10 == 0 or it == args.sweeps - 1:
            jax.block_until_ready(arrays["n_t"])
            ll = lda.log_likelihood(arrays)
            print(f"[lda] sweep {it + 1:4d} ll {ll:,.0f} "
                  f"({corpus.num_tokens * (it + 1) / (time.time() - t0):,.0f}"
                  f" tok/s)")
    checkpoint.save(args.ckpt, {k: arrays[k]
                                for k in ("z", "n_td", "n_wt", "n_t")})
    print(f"[lda] checkpoint: {args.ckpt}")


def _run_lm(args):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    state = init_train_state(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"[lm] {cfg.name}: {n / 1e6:.1f}M params")
    step = jax.jit(make_train_step(cfg, lr=3e-4, remat=False))
    key = jax.random.key(1)
    B, S = 4, 128
    for it in range(args.steps):
        key, k1 = jax.random.split(key)
        if cfg.modality == "audio_frames":
            batch = {"frames": jax.random.normal(k1, (B, S, cfg.frontend_dim)),
                     "labels": jax.random.randint(k1, (B, S), 0,
                                                  cfg.vocab_size)}
        elif cfg.modality == "image_patches":
            batch = {"tokens": jax.random.randint(k1, (B, S), 0,
                                                  cfg.vocab_size),
                     "patches": jax.random.normal(
                         k1, (B, cfg.frontend_tokens, cfg.frontend_dim))}
        else:
            start = jax.random.randint(k1, (B, 1), 0, cfg.vocab_size)
            batch = {"tokens": (start + jnp.arange(S)[None, :] * 7)
                     % cfg.vocab_size}
        state, metrics = step(state, batch)
        if (it + 1) % 20 == 0:
            print(f"[lm] step {it + 1:4d} loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
