"""Chaos harness: the failure model of DESIGN.md §11, replayed from a
seeded :class:`repro.fault.FaultPlan`.

Trainer story (extends ``resume_check``): run the ring with a rotating
checkpoint directory while the fault injector corrupts the newest slot
and then kills the process (``os._exit(137)``, the real preemption);
the resume must fall back to the previous *valid* rotation slot and the
finished chain's digest must be bit-identical to an uninterrupted run.
Subprocess phases::

    --phase straight   run ``--sweeps`` uninterrupted, print chain digest
    --phase train      checkpoint every sweep into ``--ckpt`` (a rotation
                       directory), corrupt the slot written at sweep
                       ``--kill-at`` (``--corrupt-newest``), then die hard
    --phase resume     resume from the newest valid slot, run to
                       ``--sweeps``, print chain digest + fallback story
    --phase matrix     the same comparison in-process across damage kinds
                       {none, corrupt, truncate}, soft kills
    --phase recovery   timed: wall-clock of the uninterrupted run vs the
                       full kill + corrupt-newest-slot + fallback-resume
                       path, back-to-back in one process (the
                       ``sweep_bench`` ``recovery`` row)

Serving story (``--phase serve``): a publisher thread feeds an
:class:`LdaEngine` a scripted mix of good, corrupt, stale-generation and
format-skewed snapshots while reader threads flood it with queries
behind admission control.  The audit asserts every answer folded against
an *accepted* ``(generation, digest)`` pair, every bad publish was
refused with the right typed error, overload shed rather than queueing
unboundedly (``max_pending_seen`` ≤ the bound, shed > 0, degraded > 0)
and accepted-query p99 stayed within ``REPRO_CHAOS_P99_RATIO`` × median.
A fetch-retry sub-check replays transient fetch failures through
:func:`fetch_snapshot`'s backoff loop.

Sets ``XLA_FLAGS`` *before* importing jax and prints a JSON report as
the last stdout line, like the other ``launch/*_check`` harnesses; exits
nonzero unless every check passes.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import threading


def _parse(argv):
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--phase", default="matrix",
                   choices=["straight", "train", "resume", "matrix",
                            "recovery", "serve"])
    p.add_argument("--n-devices", type=int, default=4)
    p.add_argument("--sync-mode", default="stoken")
    p.add_argument("--inner-mode", default="scan")
    p.add_argument("--n-blocks", type=int, default=0, help="0 → n_devices")
    p.add_argument("--ring-mode", default="barrier")
    p.add_argument("--layout", default="dense", choices=["dense", "ragged"])
    p.add_argument("--doc-tile", type=int, default=0)
    p.add_argument("--r-mode", default="dense", choices=["dense", "sparse"])
    p.add_argument("--sweeps", type=int, default=5)
    p.add_argument("--kill-at", type=int, default=3,
                   help="train phase: die after this many sweeps")
    p.add_argument("--ckpt", default="",
                   help="rotation directory (train/resume phases)")
    p.add_argument("--keep", type=int, default=3,
                   help="rotation slots kept")
    p.add_argument("--corrupt-newest", action="store_true",
                   help="train phase: corrupt the newest slot before dying")
    p.add_argument("--fault-seed", type=int, default=0)
    p.add_argument("--fast", action="store_true",
                   help="serve/matrix: smaller schedule")
    # serve-phase knobs
    p.add_argument("--flood-threads", type=int, default=8)
    p.add_argument("--flood-queries", type=int, default=20,
                   help="queries per flood thread")
    p.add_argument("--max-pending", type=int, default=2)
    p.add_argument("--degrade-pending", type=int, default=1)
    return p.parse_args(argv)


def _trainer_plan(args):
    """The seeded trainer fault schedule: corrupt the slot written at
    sweep ``kill_at`` (chain.write fires once per checkpoint, so with
    checkpoint_every=1 write index == sweep index), then a hard kill."""
    from repro.fault import FaultPlan, FaultSpec
    specs = [FaultSpec("kill", "trainer.sweep", at=args.kill_at - 1,
                       hard=True)]
    if args.corrupt_newest:
        specs.insert(0, FaultSpec("corrupt", "chain.write",
                                  at=args.kill_at - 1, nbytes=4))
    return FaultPlan(specs, seed=args.fault_seed)


# ---------------------------------------------------------------------------
# Trainer phases (kill + corruption → rotation fallback → bit-exact).
# ---------------------------------------------------------------------------
def _run_straight(args) -> dict:
    from repro.launch.resume_check import _build, chain_digest
    lda = _build(args, layout_kind=args.layout, ring_mode=args.ring_mode,
                 r_mode=args.r_mode)
    arrays, done = lda.run(args.sweeps, init_seed=0)
    return {"phase": "straight", "sweeps": done,
            "digest": chain_digest(lda, arrays)}


def _run_train(args) -> dict:
    from repro.launch.resume_check import _build
    lda = _build(args, layout_kind=args.layout, ring_mode=args.ring_mode,
                 r_mode=args.r_mode, ckpt_every=1, ckpt_path=args.ckpt)
    lda.checkpoint_keep = args.keep
    # hard kill: this call never returns past sweep kill_at-1
    lda.run(args.sweeps, init_seed=0, fault_plan=_trainer_plan(args))
    return {"phase": "train", "error": "plan did not kill the run"}


def _run_resume(args) -> dict:
    from repro.launch.resume_check import _build, chain_digest
    from repro.train.checkpoint import CheckpointRotation
    rot = CheckpointRotation(args.ckpt, keep=args.keep)
    slots = [s for s, _ in rot.slots()]
    _, _, chosen = rot.load_latest_valid()
    lda = _build(args, layout_kind=args.layout, ring_mode=args.ring_mode,
                 r_mode=args.r_mode, resume_from=args.ckpt)
    lda.checkpoint_keep = args.keep
    arrays, done = lda.run(args.sweeps)
    return {"phase": "resume", "sweeps": done,
            "digest": chain_digest(lda, arrays),
            "slots": slots, "last_good": rot.last_good(),
            "resumed_from_step": chosen,
            "fell_back": chosen < max(slots)}


def _run_matrix(args) -> dict:
    """In-process kill+damage → fallback-resume → bit-exact, across
    damage kinds.  Soft kills (InjectedKill) stand in for the subprocess
    phases' SIGKILL; the checkpoint state on disk is identical."""
    from repro.fault import FaultPlan, FaultSpec, InjectedKill
    from repro.launch.resume_check import _build, chain_digest
    from repro.train.checkpoint import CheckpointRotation

    lda_ref = _build(args, layout_kind=args.layout,
                     ring_mode=args.ring_mode, r_mode=args.r_mode)
    arrays, _ = lda_ref.run(args.sweeps, init_seed=0)
    ref = chain_digest(lda_ref, arrays)

    damages = ("none", "corrupt") if args.fast else ("none", "corrupt",
                                                     "truncate")
    combos, ok = [], True
    for damage in damages:
        tmpd = tempfile.mkdtemp(prefix=f"chaos-{damage}-")
        specs = [FaultSpec("kill", "trainer.sweep", at=args.kill_at - 1)]
        if damage == "corrupt":
            specs.insert(0, FaultSpec("corrupt", "chain.write",
                                      at=args.kill_at - 1, nbytes=4))
        elif damage == "truncate":
            specs.insert(0, FaultSpec("truncate", "chain.write",
                                      at=args.kill_at - 1, frac=0.5))
        plan = FaultPlan(specs, seed=args.fault_seed)

        lda = _build(args, layout_kind=args.layout,
                     ring_mode=args.ring_mode, r_mode=args.r_mode,
                     ckpt_every=1, ckpt_path=tmpd)
        lda.checkpoint_keep = args.keep
        killed = False
        try:
            lda.run(args.sweeps, init_seed=0, fault_plan=plan)
        except InjectedKill:
            killed = True

        rot = CheckpointRotation(tmpd, keep=args.keep)
        slots = [s for s, _ in rot.slots()]
        _, _, chosen = rot.load_latest_valid()
        lda2 = _build(args, layout_kind=args.layout,
                      ring_mode=args.ring_mode, r_mode=args.r_mode,
                      resume_from=tmpd)
        arrays2, _ = lda2.run(args.sweeps)
        got = chain_digest(lda2, arrays2)

        fell_back = chosen < max(slots)
        combo_ok = (killed and got == ref
                    and fell_back == (damage != "none"))
        ok &= combo_ok
        combos.append({"damage": damage, "killed": killed,
                       "slots": slots, "resumed_from_step": chosen,
                       "fell_back": fell_back, "exact": got == ref,
                       "ok": combo_ok,
                       "fault_log": [list(e) for e in plan.log]})
    return {"phase": "matrix", "straight_digest": ref, "combos": combos,
            "all_ok": ok}


def _run_recovery(args) -> dict:
    """Timed recovery story for the bench harness (``sweep_bench``'s
    ``recovery`` row): wall-clock of an uninterrupted ``--sweeps`` run vs
    the whole kill path — train with a rotating checkpoint directory,
    corrupt the newest slot, die at ``--kill-at``, rebuild, fall back to
    the previous valid slot and finish.  An untimed straight leg runs
    first to eat the initial XLA compile, then both timed legs run
    back-to-back in this process, so their ratio cancels host speed
    (the interleaved-measurement story of ``lda_canary_check``)."""
    import shutil
    import time

    from repro.fault import FaultPlan, FaultSpec, InjectedKill
    from repro.launch.resume_check import _build, chain_digest
    from repro.train.checkpoint import CheckpointRotation

    kw = dict(layout_kind=args.layout, ring_mode=args.ring_mode,
              r_mode=args.r_mode)

    def straight():
        lda = _build(args, **kw)
        arrays, _ = lda.run(args.sweeps, init_seed=0)
        return chain_digest(lda, arrays)

    ref = straight()                      # warmup leg: first compile
    t0 = time.perf_counter()
    ref2 = straight()
    straight_sec = time.perf_counter() - t0

    tmpd = tempfile.mkdtemp(prefix="chaos-recovery-")
    plan = FaultPlan(
        [FaultSpec("corrupt", "chain.write", at=args.kill_at - 1,
                   nbytes=4),
         FaultSpec("kill", "trainer.sweep", at=args.kill_at - 1)],
        seed=args.fault_seed)
    killed = False
    t0 = time.perf_counter()
    lda = _build(args, ckpt_every=1, ckpt_path=tmpd, **kw)
    lda.checkpoint_keep = args.keep
    try:
        lda.run(args.sweeps, init_seed=0, fault_plan=plan)
    except InjectedKill:
        killed = True
    rot = CheckpointRotation(tmpd, keep=args.keep)
    slots = [s for s, _ in rot.slots()]
    _, _, chosen = rot.load_latest_valid()
    lda2 = _build(args, resume_from=tmpd, **kw)
    arrays2, _ = lda2.run(args.sweeps)
    got = chain_digest(lda2, arrays2)
    recovery_sec = time.perf_counter() - t0
    shutil.rmtree(tmpd, ignore_errors=True)

    fell_back = chosen < max(slots)
    exact = got == ref and ref2 == ref
    return {"phase": "recovery", "sweeps": args.sweeps,
            "kill_at": args.kill_at, "straight_sec": straight_sec,
            "recovery_sec": recovery_sec,
            "overhead_ratio": recovery_sec / max(straight_sec, 1e-9),
            "slots": slots, "resumed_from_step": chosen,
            "fell_back": fell_back, "killed": killed, "exact": exact,
            "all_ok": killed and exact and fell_back}


# ---------------------------------------------------------------------------
# Serving phase (bad publishes + query flood behind admission control).
# ---------------------------------------------------------------------------
def _run_serve(args) -> dict:
    import time

    import jax
    import numpy as np

    from repro import fault
    from repro.fault import FaultPlan, FaultSpec
    from repro.launch.serve_check import _build_trainer, _doc_pool
    from repro.serve.lda_engine import (EngineOverloadedError,
                                        FormatVersionError, LdaEngine,
                                        PhiSnapshot, SnapshotCorruptError,
                                        StaleGenerationError, TopicQuery,
                                        fetch_snapshot)

    lda, corpus = _build_trainer(args)

    # pre-train the publish schedule: one good snapshot per sweep
    n_good = 3 if args.fast else 5
    arrays = lda.init_arrays(seed=0)
    snaps = [lda.export_phi_snapshot(arrays, sweep=0)]
    for s in range(n_good):
        arrays = lda.sweep(arrays, seed=s)
        jax.block_until_ready(arrays["n_t"])
        snaps.append(lda.export_phi_snapshot(arrays, sweep=s + 1))

    engine = LdaEngine(snapshot=snaps[0], sweeps=8, tile=4, max_batch=8,
                       max_pending=args.max_pending,
                       degrade_pending=args.degrade_pending,
                       degraded_sweeps=2)
    accepted = {1: snaps[0].digest}     # generation -> digest
    pub_lock = threading.Lock()
    rejected = {"corrupt": 0, "stale": 0, "format": 0, "unexpected": 0}
    rng = np.random.default_rng(args.fault_seed)

    def tampered(snap):
        """Flip one φ value but keep the original meta digest — the
        mid-flight corruption publish must refuse."""
        phi = np.array(snap.phi)
        j, t = rng.integers(phi.shape[0]), rng.integers(phi.shape[1])
        phi[j, t] += 0.125
        return PhiSnapshot(phi=phi, meta=dict(snap.meta))

    def skewed(snap):
        meta = dict(snap.meta)
        meta["format_version"] = meta["format_version"] + 1
        return PhiSnapshot(phi=snap.phi, meta=meta)

    pub_errors = []

    def publisher():
        try:
            for i, snap in enumerate(snaps[1:], start=1):
                # a scripted bad publish before every good one
                bad_kind = ("corrupt", "stale", "format")[i % 3]
                try:
                    if bad_kind == "corrupt":
                        engine.publish(tampered(snap))
                    elif bad_kind == "stale":
                        engine.publish(snaps[i - 1])   # sweep regresses
                    else:
                        engine.publish(skewed(snap))
                    rejected["unexpected"] += 1        # publish succeeded!?
                except SnapshotCorruptError:
                    rejected["corrupt"] += 1
                except StaleGenerationError:
                    rejected["stale"] += 1
                except FormatVersionError:
                    rejected["format"] += 1
                gen = engine.publish(snap)
                with pub_lock:
                    accepted[gen] = snap.digest
                time.sleep(0.02)
        except BaseException as e:
            pub_errors.append(repr(e))

    pool = _doc_pool(corpus, 8)
    docs = tuple(pool[2:5])
    # warm both jit variants (full and degraded sweep counts) so the
    # flood measures serving latency, not compilation
    engine.query(TopicQuery(docs=docs))
    engine.query(TopicQuery(docs=docs, sweeps=engine.degraded_sweeps))

    answers, sheds, reader_errors = [], [0] * args.flood_threads, []
    ans_lock = threading.Lock()

    def reader(tid):
        try:
            for i in range(args.flood_queries):
                try:
                    res = engine.query(TopicQuery(
                        docs=docs, key=jax.random.key(tid * 1000 + i)))
                except EngineOverloadedError:
                    sheds[tid] += 1
                    continue
                with ans_lock:
                    answers.append({"generation": res.generation,
                                    "digest": res.digest,
                                    "latency_s": res.latency_s,
                                    "degraded": res.degraded,
                                    "sweeps_used": res.sweeps_used})
        except BaseException as e:
            reader_errors.append(repr(e))

    pub = threading.Thread(target=publisher, daemon=True)
    readers = [threading.Thread(target=reader, args=(t,), daemon=True)
               for t in range(args.flood_threads)]
    pub.start()
    for th in readers:
        th.start()
    pub.join()
    for th in readers:
        th.join()

    # ---- audit ----------------------------------------------------------
    invalid_gen = sum(1 for a in answers
                      if accepted.get(a["generation"]) != a["digest"])
    stats = engine.stats()
    lat = sorted(a["latency_s"] for a in answers)
    p50 = lat[len(lat) // 2] if lat else 0.0
    p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))] if lat else 0.0
    p99_ratio_cap = float(os.environ.get("REPRO_CHAOS_P99_RATIO", "80"))
    p99_ok = p99 <= p99_ratio_cap * max(p50, 1e-9)

    # fetch retry: the first two fetch attempts fail by plan, the third
    # succeeds — bounded backoff turns transient damage into a result
    fetch_dir = tempfile.mkdtemp(prefix="chaos-fetch-")
    fetch_path = os.path.join(fetch_dir, "phi.npz")
    snaps[-1].save(fetch_path)
    plan = FaultPlan([FaultSpec("fail", "serve.fetch", at=0, count=2)],
                     seed=args.fault_seed)
    with fault.install(plan):
        fetched = fetch_snapshot(fetch_path, retries=3, backoff_s=1e-4)
    fetch_ok = (fetched.digest == snaps[-1].digest
                and len(plan.log) == 2)

    total_shed = sum(sheds)
    ok = (invalid_gen == 0
          and not pub_errors and not reader_errors
          and rejected["corrupt"] > 0 and rejected["stale"] > 0
          and rejected["format"] > 0 and rejected["unexpected"] == 0
          and stats["rejected_publishes"] >= sum(
              rejected[k] for k in ("corrupt", "stale", "format"))
          and total_shed > 0 and stats["shed"] == total_shed
          and stats["degraded"] > 0
          and stats["max_pending_seen"] <= args.max_pending
          and stats["pending"] == 0
          and len(accepted) == n_good + 1
          and fetch_ok and p99_ok)
    return {"phase": "serve", "publishes_accepted": len(accepted),
            "publishes_rejected": rejected, "queries": len(answers),
            "shed": total_shed, "stats": stats,
            "generations_seen": sorted({a["generation"] for a in answers}),
            "invalid_generation_answers": invalid_gen,
            "degraded_answers": sum(a["degraded"] for a in answers),
            "latency_p50_s": p50, "latency_p99_s": p99, "p99_ok": p99_ok,
            "fetch_retry_ok": fetch_ok,
            "publisher_error": pub_errors[0] if pub_errors else None,
            "reader_error": reader_errors[0] if reader_errors else None,
            "all_ok": ok}


def main(argv=None) -> None:
    args = _parse(sys.argv[1:] if argv is None else argv)
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.n_devices} "
        + os.environ.get("XLA_FLAGS", ""))

    if args.phase in ("train", "resume") and not args.ckpt:
        raise SystemExit("--ckpt is required for train/resume phases")

    if args.phase == "straight":
        report = _run_straight(args)
    elif args.phase == "train":
        report = _run_train(args)       # normally never returns (kill)
    elif args.phase == "resume":
        report = _run_resume(args)
    elif args.phase == "recovery":
        report = _run_recovery(args)
    elif args.phase == "serve":
        report = _run_serve(args)
    else:
        report = _run_matrix(args)
    print(json.dumps(report))
    if not report.get("all_ok", True):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
