"""Multi-device Nomad LDA correctness check (run as a subprocess).

Usage:  python -m repro.launch.lda_dist_check \
            [--n-devices N] [--sync-mode M] [--pods P] [--inner-mode M] \
            [--n-blocks B] [--ring-mode M] [--layout L] [--doc-tile D] \
            [--r-mode M] [--resume-from CKPT]

The old positional form ``[n_devices] [sync_mode] [pods] [inner_mode]
[n_blocks] [ring_mode] [layout] [doc_tile] [r_mode]`` still works for
one release (a deprecation note goes to stderr); flags win over
positionals when both are given.  ``--resume-from`` starts the chain
from a ``launch/resume_check.py``-style checkpoint instead of a fresh
init.

Sets XLA_FLAGS *before* importing jax (the only supported way to fake a
multi-device CPU platform), runs sweeps of Nomad F+LDA on a synthetic
corpus, and prints a JSON report: count-table invariants (must be exact)
and the log-likelihood trajectory (must increase).  ``layout`` selects
the token geometry (``dense`` | ``ragged``, DESIGN.md §4); the report's
throughput line carries the layout's ``pad_fraction`` and ``total_tiles``
so the padding cost of each geometry is visible next to its tokens/sec.
``doc_tile`` (0 = off) builds a doc-grouped layout and pages
``(doc_tile, T)`` doc-topic slabs through the fused kernels (DESIGN.md
§7); the report then carries ``ntd_slab_bytes`` vs the whole-shard bytes.
``r_mode`` (``dense`` | ``sparse``) selects the r-bucket draw; ``sparse``
walks the per-doc compacted side tables at the layout's ``r_cap``
capacity (DESIGN.md §7a) and the report carries both knobs.
"""
import argparse
import json
import os
import sys

# (name, type, default) — positional order of the deprecated legacy form.
_ARGS = [("n_devices", int, 8), ("sync_mode", str, "stoken"),
         ("pods", int, 1), ("inner_mode", str, "scan"),
         ("n_blocks", int, 0), ("ring_mode", str, "barrier"),
         ("layout", str, "dense"), ("doc_tile", int, 0),
         ("r_mode", str, "dense")]


def parse_args(argv):
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    for name, typ, default in _ARGS:
        p.add_argument("--" + name.replace("_", "-"), type=typ, default=None)
    p.add_argument("--resume-from", default="",
                   help="chain checkpoint to start from (fresh init if "
                        "unset)")
    p.add_argument("--checkpoint-path", default="",
                   help="write a chain checkpoint here after the last "
                        "sweep (consumable by --resume-from)")
    p.add_argument("legacy", nargs="*",
                   help="deprecated positional form: "
                        + " ".join(f"[{n}]" for n, _, _ in _ARGS))
    args = p.parse_args(argv)
    if args.legacy:
        print("lda_dist_check: positional arguments are deprecated; use "
              "the --flag form (see --help)", file=sys.stderr)
        if len(args.legacy) > len(_ARGS):
            p.error(f"at most {len(_ARGS)} positional arguments")
    for i, (name, typ, default) in enumerate(_ARGS):
        if getattr(args, name) is None:
            setattr(args, name,
                    typ(args.legacy[i]) if i < len(args.legacy) else default)
    args.n_blocks = args.n_blocks or args.n_devices
    return args


def main() -> None:
    args = parse_args(sys.argv[1:])
    n_dev, sync_mode, pods = args.n_devices, args.sync_mode, args.pods
    inner_mode, n_blocks = args.inner_mode, args.n_blocks
    ring_mode, layout_kind = args.ring_mode, args.layout
    doc_tile, r_mode = args.doc_tile, args.r_mode

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))

    import time

    import jax
    import numpy as np

    from repro.core.nomad import NomadLDA
    from repro.data import synthetic
    from repro.data.sharding import build_layout

    assert len(jax.devices()) == n_dev, jax.devices()

    T = 16
    alpha, beta = 50.0 / T, 0.01
    corpus, _, _ = synthetic.make_corpus(
        num_docs=120, vocab_size=256, num_topics=T, mean_doc_len=30.0, seed=3)

    if pods > 1:
        mesh = jax.make_mesh((pods, n_dev // pods), ("pod", "worker"))
        ring_axes = ("pod", "worker")
    else:
        mesh = jax.make_mesh((n_dev,), ("worker",))
        ring_axes = ("worker",)

    doc_kw = {}
    if doc_tile > 0:
        doc_kw = dict(doc_tile=doc_tile)
        if layout_kind == "dense":
            doc_kw["doc_blk"] = 16      # toy-corpus grid step (cf. N_BLK)
    layout = build_layout(corpus, n_workers=n_dev, T=T,
                          n_blocks=n_blocks, layout=layout_kind, **doc_kw)
    r_cap = layout.r_cap if r_mode == "sparse" else 0
    lda = NomadLDA(mesh=mesh, ring_axes=ring_axes, layout=layout,
                   alpha=alpha, beta=beta, sync_mode=sync_mode,
                   inner_mode=inner_mode, ring_mode=ring_mode,
                   doc_tile=doc_tile if doc_tile > 0 else None,
                   r_mode=r_mode, r_cap=r_cap)
    if args.resume_from:
        arrays, seed0 = lda.load_checkpoint(args.resume_from)
    else:
        arrays, seed0 = lda.init_arrays(seed=0), 0

    # Host reference clock: a fixed jitted workload timed in the same
    # process, interleaved with the timed sweeps.  On a shared CI host a
    # whole subprocess can run 2-3x slower than its neighbour, so raw
    # cross-subprocess (and cross-snapshot) tokens/sec comparisons are
    # noise; ``tokens_per_sec · ref_sweep_sec`` cancels the host's speed
    # and is what ``benchmarks.sweep_bench.check_regression`` compares
    # when both snapshots carry it.
    import jax.numpy as jnp
    from jax import lax

    @jax.jit
    def _ref_step(x):
        return lax.fori_loop(0, 16, lambda _, a: a @ a / 257.0, x)

    ref_x = jnp.full((256, 256), 1.001, jnp.float32)
    jax.block_until_ready(_ref_step(ref_x))      # compile

    n_sweeps = 7                          # 6 timed sweeps
    lls = [lda.log_likelihood(arrays)]
    arrays = lda.sweep(arrays, seed=seed0)    # compile + first sweep
    lls.append(lda.log_likelihood(arrays))
    sweep_times, ref_times = [], []
    for it in range(1, n_sweeps):
        t0 = time.perf_counter()
        jax.block_until_ready(_ref_step(ref_x))
        ref_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()              # time the sweep alone — the
        arrays = lda.sweep(arrays, seed=seed0 + it)  # LL eval is diagnostics,
        jax.block_until_ready(arrays["n_t"])  # not the throughput under test
        sweep_times.append(time.perf_counter() - t0)
        lls.append(lda.log_likelihood(arrays))
    # Median per-sweep wall: a single stalled sweep must not swing the row.
    tokens_per_sec = corpus.num_tokens / max(float(np.median(sweep_times)),
                                             1e-9)
    ref_sweep_sec = float(np.median(ref_times))

    if args.checkpoint_path:
        lda.save_checkpoint(args.checkpoint_path, arrays,
                            next_seed=seed0 + n_sweeps)

    # --- invariants ---------------------------------------------------------
    from repro.data.sharding import counts_from_layout
    n_td, n_wt, n_t = lda.global_counts(arrays)
    z = np.asarray(arrays["z"])
    lay = layout
    n_td_ref, n_wt_ref, n_t_ref = counts_from_layout(lay, z, T)

    zz = lay.extract_canonical(z)
    report = {
        "n_devices": n_dev,
        "sync_mode": sync_mode,
        "inner_mode": inner_mode,
        "ring_mode": ring_mode,
        "layout": lay.kind,
        "pods": pods,
        "n_blocks": layout.B,
        "blocks_per_worker": layout.k,
        "tokens_per_sec": tokens_per_sec,
        "ref_sweep_sec": ref_sweep_sec,
        "n_tokens": int(corpus.num_tokens),
        "ll": lls,
        "ll_improved": bool(lls[-1] > lls[0]),
        "n_td_mismatch": int(np.abs(n_td - n_td_ref).sum()),
        "n_wt_mismatch": int(np.abs(n_wt - n_wt_ref).sum()),
        "n_t_mismatch": int(np.abs(n_t - n_t_ref).sum()),
        # layout maps self-consistent with the original corpus
        "word_map_mismatch": lay.word_map_mismatches(),
        "z_in_range": bool(((zz >= 0) & (zz < T)).all()),
        "tokens_preserved": int(n_t.sum()) == int(corpus.num_tokens),
        "round_imbalance": layout.round_imbalance,
        "pad_fraction": layout.pad_fraction,
        "total_tiles": layout.total_tiles,
        "ragged_tile": layout.tile,
        "doc_tile": layout.doc_tile,
        "r_mode": r_mode,
        "r_cap": r_cap,
        "resumed_from": args.resume_from,
        "next_seed": seed0 + n_sweeps,
        "ntd_row_bytes": layout.ntd_row_bytes,
        "ntd_slab_bytes": layout.ntd_slab_bytes,
        "ntd_whole_bytes": layout.ntd_whole_bytes,
    }
    print(json.dumps(report))


if __name__ == "__main__":
    main()
