"""Production mesh construction (spec: MULTI-POD DRY-RUN step 1).

Target hardware: TPU v5e pods — 197 bf16 TFLOP/s, 16 GiB HBM @ 819 GB/s per
chip, ~50 GB/s/link ICI.  Single pod = 16×16 = 256 chips; two pods = 512.

Functions, not module-level constants: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_lda_mesh", "HW"]


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""
    PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
    HBM_BW = 819e9               # bytes/s per chip
    ICI_BW = 50e9                # bytes/s per link
    HBM_BYTES = 16 * 2**30       # per chip


def _mesh(shape, axes):
    import numpy as np
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(jax.devices())} "
            "(dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count before importing jax)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_lda_mesh(*, multi_pod: bool = False):
    """Flat worker ring for Nomad LDA (DESIGN.md §4): the ring spans the
    whole mesh; the pod axis is kept so the cross-pod boundary hop of the
    ring is explicit in the collective schedule."""
    if multi_pod:
        return _mesh((2, 256), ("pod", "worker"))
    return _mesh((256,), ("worker",))
