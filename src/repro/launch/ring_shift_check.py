"""Direct unit check of the flat-ring payload hop (run as a subprocess).

Usage:  python -m repro.launch.ring_shift_check [n_devices] [pods]

``core/nomad.py::_ring_shift_down`` is the one collective the nomad ring is
built on; until now it was only covered indirectly through whole sweeps.
This check exercises it directly on a faked multi-device mesh — flat
(``('worker',)``) or two-axis (``('pod', 'worker')``) — and verifies the
ring semantics payload-by-payload:

* **one shift** moves the value at flat position ``i+1 (mod W)`` to
  position ``i`` (blocks travel toward lower worker index);  on the
  two-axis mesh the wrap-around element of each pod must cross the pod
  axis (worker ``n_inner−1`` of pod ``p`` receives from worker 0 of pod
  ``p+1``), which is exactly the boundary-fix branch of the helper;
* **W shifts** restore the identity — one full loop of the ring;
* a **pytree payload** (array + vector pair, like ``(n_wt_q, s_tok)``)
  moves as one unit.

Prints one JSON report with per-check mismatch counts.
"""
import json
import os
import sys


def main() -> None:
    n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    pods = int(sys.argv[2]) if len(sys.argv) > 2 else 1

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.nomad import _flat_index, _ring_shift_down

    assert len(jax.devices()) == n_dev, jax.devices()

    if pods > 1:
        mesh = jax.make_mesh((pods, n_dev // pods), ("pod", "worker"))
        ring_axes = ("pod", "worker")
    else:
        mesh = jax.make_mesh((n_dev,), ("worker",))
        ring_axes = ("worker",)
    sizes = tuple(int(mesh.shape[ax]) for ax in ring_axes)
    W = n_dev
    D = 4                                   # payload vector length

    def worker_fn(_x):
        # Payload identifies its home position: (pos, pos·10 + lane).
        pos = _flat_index(ring_axes, sizes)
        scalar = jnp.full((1,), pos, jnp.int32)
        vec = (pos * 10 + jnp.arange(D, dtype=jnp.int32))[None]

        one_s, one_v = _ring_shift_down((scalar, vec), ring_axes, sizes)

        full_s, full_v = scalar, vec
        for _ in range(W):
            full_s, full_v = _ring_shift_down((full_s, full_v),
                                              ring_axes, sizes)
        return one_s, one_v, full_s, full_v

    spec = P(tuple(ring_axes))
    spec_v = P(tuple(ring_axes), None)
    fn = shard_map(
        worker_fn, mesh=mesh,
        in_specs=(spec,),
        out_specs=(spec, spec_v, spec, spec_v),
        check_vma=False)
    one_s, one_v, full_s, full_v = jax.jit(fn)(
        jnp.zeros((n_dev,), jnp.int32))

    one_s, one_v = np.asarray(one_s), np.asarray(one_v)
    full_s, full_v = np.asarray(full_s), np.asarray(full_v)
    pos = np.arange(W)
    want_s = (pos + 1) % W                      # i receives from i+1
    want_v = want_s[:, None] * 10 + np.arange(D)[None, :]

    # Wrap-around elements that must have crossed the pod axis: the last
    # worker of each pod receives from worker 0 of the *next* pod.
    if pods > 1:
        n_inner = sizes[-1]
        boundary = pos[pos % n_inner == n_inner - 1]
        cross_pod_ok = bool(
            (one_s[boundary] == (boundary + 1) % W).all()
            and (boundary // n_inner != ((boundary + 1) % W) // n_inner
                 ).all())
    else:
        cross_pod_ok = True                     # no pod axis to cross

    report = {
        "n_devices": n_dev,
        "pods": pods,
        "ring_axes": list(ring_axes),
        "one_shift_mismatch": int((one_s != want_s).sum()),
        "one_shift_vec_mismatch": int((one_v != want_v).sum()),
        "identity_mismatch": int((full_s != pos).sum()),
        "identity_vec_mismatch": int(
            (full_v != pos[:, None] * 10 + np.arange(D)[None, :]).sum()),
        "cross_pod_ok": cross_pod_ok,
    }
    print(json.dumps(report))


if __name__ == "__main__":
    main()
