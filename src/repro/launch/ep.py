"""Expert-parallel execution context for MoE layers.

Installs :func:`repro.models.moe.moe_forward_ep` under ``shard_map``:
experts sharded over 'model', tokens chunked over 'model' along the
sequence axis, two all-to-alls per layer (dispatch + return) — the
owner-computes pattern of the paper's nomadic word tokens (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.models import moe as moe_mod
from repro.launch.sharding_rules import batch_axes

__all__ = ["make_ep_ctx"]


def make_ep_ctx(mesh: Mesh, cfg, *, capacity_factor: float = 1.25):
    """Returns ep_ctx(moe_params, x) -> (y, aux) or None if EP not viable."""
    if "model" not in mesh.axis_names:
        return None
    M = int(mesh.shape["model"])
    if M == 1 or not cfg.num_experts or cfg.num_experts % M != 0:
        return None
    baxes = batch_axes(mesh)

    def ep_ctx(moe_params, x):
        S = x.shape[1]
        if S % M != 0:
            # decode shapes: fall back to the single-program path (GSPMD)
            return moe_mod.moe_forward(moe_params, cfg, x,
                                       capacity_factor=capacity_factor)

        in_specs = (
            {
                "router": P(None, None),                 # replicated
                "w_gate": P("model", None, None),        # experts sharded
                "w_up": P("model", None, None),
                "w_down": P("model", None, None),
                **({"shared": {"w_gate": P(None, None),
                               "w_up": P(None, None),
                               "w_down": P(None, None)}}
                   if cfg.num_shared_experts else {}),
            },
            P(baxes, "model", None),                     # x: tokens chunked
        )
        out_specs = (P(baxes, "model", None), P(baxes))

        def body(p_local, x_local):
            y, aux = moe_mod.moe_forward_ep(
                p_local, cfg, x_local, model_axis="model", model_size=M,
                capacity_factor=capacity_factor)
            aux_vec = jnp.broadcast_to(aux, (x_local.shape[0],))
            return y, aux_vec

        f = shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=False)
        y, aux_vec = f(moe_params, x)
        return y, aux_vec.mean()

    return ep_ctx
