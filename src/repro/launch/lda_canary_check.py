"""Padding-blowup canary measurement (run as a subprocess).

Usage:  python -m repro.launch.lda_canary_check [n_devices] [reps]

Times the ragged nomad-fused sweep at B = W and B = 4W **interleaved in
one process** — sweep A, sweep B, sweep A, ... — and reports the
tokens/sec of each from the median per-sweep wall plus their ratio.

The interleaving is the point: `BENCH_sweep.json`'s per-config rows come
from separate subprocesses, and on a shared CI host the machine can be
2-3x slower for one whole subprocess than the next, so a cross-row
ratio gate at the 10% level is pure noise.  Alternating single sweeps
puts both configurations through the same contention epochs, so their
*ratio* — the quantity the canary gates, see
``benchmarks.sweep_bench._check_canary`` — is stable even when the
absolute numbers are not.  The dense layout's blowup this guards
against is ~2x at B=4W and ~6x at B=16W (DESIGN.md §4): far outside
the gate's noise floor.

Both runs use ``ring_mode="barrier"`` so the comparison isolates the
*layout* cost: at B = W the queue has one cell and the pipelined
schedule degenerates to barrier anyway, so a pipelined B = 4W run would
fold the second schedule's structural overhead (an extra kernel launch
and ppermute per round — an interpret-mode artifact already tracked by
the barrier-vs-pipelined bench rows) into the padding signal.

Prints one JSON report:
``{"tokens_per_sec_w", "tokens_per_sec_4w", "ratio_4w_over_w", ...}``.
"""
import json
import os
import sys


def main() -> None:
    n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    reps = int(sys.argv[2]) if len(sys.argv) > 2 else 8

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))

    import time

    import jax
    import numpy as np

    from repro.core.nomad import NomadLDA
    from repro.data import synthetic
    from repro.data.sharding import build_layout

    assert len(jax.devices()) == n_dev, jax.devices()

    T = 16
    alpha, beta = 50.0 / T, 0.01
    corpus, _, _ = synthetic.make_corpus(
        num_docs=120, vocab_size=256, num_topics=T, mean_doc_len=30.0, seed=3)
    mesh = jax.make_mesh((n_dev,), ("worker",))

    runs = {}
    for B in (n_dev, 4 * n_dev):
        layout = build_layout(corpus, n_workers=n_dev, T=T, n_blocks=B,
                              layout="ragged")
        lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=layout,
                       alpha=alpha, beta=beta, sync_mode="stoken",
                       inner_mode="fused", ring_mode="barrier")
        arrays = lda.sweep(lda.init_arrays(seed=0), seed=0)   # compile
        jax.block_until_ready(arrays["n_t"])
        runs[B] = (lda, arrays, [])

    for it in range(1, reps + 1):
        for B, (lda, arrays, times) in runs.items():
            t0 = time.perf_counter()
            arrays = lda.sweep(arrays, seed=it)
            jax.block_until_ready(arrays["n_t"])
            times.append(time.perf_counter() - t0)
            runs[B] = (lda, arrays, times)

    tps = {B: corpus.num_tokens / max(float(np.median(times)), 1e-9)
           for B, (_, _, times) in runs.items()}
    print(json.dumps({
        "n_devices": n_dev,
        "reps": reps,
        "n_tokens": int(corpus.num_tokens),
        "tokens_per_sec_w": tps[n_dev],
        "tokens_per_sec_4w": tps[4 * n_dev],
        "ratio_4w_over_w": tps[4 * n_dev] / tps[n_dev],
    }))


if __name__ == "__main__":
    main()
