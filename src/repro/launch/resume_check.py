"""Bit-exact checkpoint/resume check for the Nomad LDA chain (run as a
subprocess).

Three subprocess phases tell the preemption story end to end::

    --phase straight   run ``--sweeps`` uninterrupted, print chain digest
    --phase train      run to ``--checkpoint-at``, write ``--ckpt``, then
                       die (``--kill`` exits abruptly, mid-process, the
                       way a preempted job does)
    --phase resume     resume from ``--ckpt``, run to ``--sweeps``, print
                       chain digest

The driver (``tools/ci.sh --resume-smoke``) asserts the straight and
train→kill→resume digests are identical: the chain is bit-for-bit
independent of the interruption.  ``--phase matrix`` runs the whole
comparison in-process across {dense, ragged} × {barrier, pipelined} ×
{dense, sparse} r_mode combos — the acceptance matrix of ISSUE 7.

Sets ``XLA_FLAGS`` *before* importing jax (the only supported way to
fake a multi-device CPU platform) and prints a JSON report as the last
stdout line, like the other ``launch/*_check`` harnesses.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys


def _parse(argv):
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--phase", default="matrix",
                   choices=["straight", "train", "resume", "matrix"])
    p.add_argument("--n-devices", type=int, default=4)
    p.add_argument("--sync-mode", default="stoken")
    p.add_argument("--inner-mode", default="scan")
    p.add_argument("--n-blocks", type=int, default=0,
                   help="0 → n_devices")
    p.add_argument("--ring-mode", default="barrier")
    p.add_argument("--layout", default="dense", choices=["dense", "ragged"])
    p.add_argument("--doc-tile", type=int, default=0)
    p.add_argument("--r-mode", default="dense", choices=["dense", "sparse"])
    p.add_argument("--sweeps", type=int, default=6)
    p.add_argument("--checkpoint-at", type=int, default=3)
    p.add_argument("--ckpt", default="")
    p.add_argument("--kill", action="store_true",
                   help="train phase: die abruptly after the checkpoint "
                        "write instead of exiting cleanly")
    return p.parse_args(argv)


def _build(args, *, layout_kind, ring_mode, r_mode, ckpt_every=None,
           ckpt_path=None, resume_from=None):
    import jax

    from repro.core.nomad import NomadLDA
    from repro.data import synthetic
    from repro.data.sharding import build_layout

    T = 8
    corpus, _, _ = synthetic.make_corpus(
        num_docs=80, vocab_size=128, num_topics=T, mean_doc_len=25.0, seed=3)
    n_dev = args.n_devices
    B = args.n_blocks or n_dev
    mesh = jax.make_mesh((n_dev,), ("worker",))
    doc_kw = {}
    if args.doc_tile > 0:
        doc_kw = dict(doc_tile=args.doc_tile)
        if layout_kind == "dense":
            doc_kw["doc_blk"] = 16
    lay = build_layout(corpus, n_workers=n_dev, T=T, n_blocks=B,
                       layout=layout_kind, **doc_kw)
    r_cap = lay.r_cap if r_mode == "sparse" else 0
    lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                   alpha=50.0 / T, beta=0.01, sync_mode=args.sync_mode,
                   inner_mode=args.inner_mode, ring_mode=ring_mode,
                   doc_tile=args.doc_tile or None, r_mode=r_mode,
                   r_cap=r_cap, checkpoint_every=ckpt_every,
                   checkpoint_path=ckpt_path, resume_from=resume_from)
    return lda


def chain_digest(lda, arrays) -> str:
    """sha256 over every chain-carrying field, in canonical order."""
    import numpy as np
    lay = lda.layout
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(
        lay.extract_canonical(np.asarray(arrays["z"]))).tobytes())
    for part in lda.global_counts(arrays):
        h.update(np.ascontiguousarray(part).tobytes())
    if lda.r_mode == "sparse":
        h.update(np.ascontiguousarray(np.asarray(
            arrays["rb_topics"])).tobytes())
        h.update(np.ascontiguousarray(np.asarray(
            arrays["rb_counts"])).tobytes())
    return h.hexdigest()


def _run_matrix(args) -> dict:
    import numpy as np

    combos, exact = [], True
    for layout_kind in ("dense", "ragged"):
        for ring_mode in ("barrier", "pipelined"):
            for r_mode in ("dense", "sparse"):
                lda = _build(args, layout_kind=layout_kind,
                             ring_mode=ring_mode, r_mode=r_mode)
                arrays = lda.init_arrays(seed=0)
                for s in range(args.sweeps):
                    arrays = lda.sweep(arrays, seed=s)
                ref = chain_digest(lda, arrays)

                arrays2 = lda.init_arrays(seed=0)
                for s in range(args.checkpoint_at):
                    arrays2 = lda.sweep(arrays2, seed=s)
                state, meta = lda.export_chain_state(
                    arrays2, next_seed=args.checkpoint_at)
                # round-trip through bytes, as a real resume would
                state = {k: np.asarray(v).copy() for k, v in state.items()}
                meta = json.loads(json.dumps(meta))
                arrays3, start = lda.restore_chain_state(state, meta)
                for s in range(start, args.sweeps):
                    arrays3 = lda.sweep(arrays3, seed=s)
                got = chain_digest(lda, arrays3)
                ok = got == ref
                exact &= ok
                combos.append({"layout": layout_kind, "ring_mode": ring_mode,
                               "r_mode": r_mode, "exact": ok})
    return {"phase": "matrix", "combos": combos, "all_exact": exact}


def main(argv=None) -> None:
    args = _parse(sys.argv[1:] if argv is None else argv)
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.n_devices} "
        + os.environ.get("XLA_FLAGS", ""))

    if args.phase == "matrix":
        print(json.dumps(_run_matrix(args)))
        return

    if args.phase in ("train", "resume") and not args.ckpt:
        raise SystemExit("--ckpt is required for train/resume phases")

    if args.phase == "straight":
        lda = _build(args, layout_kind=args.layout, ring_mode=args.ring_mode,
                     r_mode=args.r_mode)
        arrays, done = lda.run(args.sweeps, init_seed=0)
        print(json.dumps({"phase": "straight", "sweeps": done,
                          "digest": chain_digest(lda, arrays)}))
    elif args.phase == "train":
        lda = _build(args, layout_kind=args.layout, ring_mode=args.ring_mode,
                     r_mode=args.r_mode, ckpt_every=args.checkpoint_at,
                     ckpt_path=args.ckpt)
        lda.run(args.checkpoint_at, init_seed=0)
        print(json.dumps({"phase": "train", "sweeps": args.checkpoint_at,
                          "ckpt": args.ckpt}))
        if args.kill:                      # preemption: no clean teardown
            sys.stdout.flush()
            os._exit(137)
    else:                                  # resume
        lda = _build(args, layout_kind=args.layout, ring_mode=args.ring_mode,
                     r_mode=args.r_mode, resume_from=args.ckpt)
        arrays, done = lda.run(args.sweeps)
        print(json.dumps({"phase": "resume", "sweeps": done,
                          "digest": chain_digest(lda, arrays)}))


if __name__ == "__main__":
    main()
