"""AliasLDA baseline (Li, Ahmed, Ravi, Smola — paper §3.3).

Decomposition (doc-by-doc):  p_t = α·(n_wt+β)/(n_t+β̄) + n_td·(n_wt+β)/(n_t+β̄).

The first (dense word-proposal) term is drawn from a **stale** alias table
built per word and reused for up to T draws; the second (|T_d|-sparse) term
is drawn fresh.  Because the proposal is stale, the draw is corrected by
#MH Metropolis–Hastings steps — the sampler is *not* exact (paper Table 2,
"Fresh samples: No"), which is why the paper observes slightly slower
per-iteration convergence in Fig. 4.

Implementation: the per-word alias tables are rebuilt at word-block
boundaries of a word-major order within the doc sweep is not possible (doc
order!), so tables for all J words are built once per sweep from a snapshot
of (n_wt, n_t) — exactly the "amortize the Θ(T) build over T draws"
argument, with staleness = one sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.cgs import LDAState
from repro.core.samplers import lsearch_guarded

__all__ = ["sweep_alias_lda"]


def sweep_alias_lda(state: LDAState, doc_ids, word_ids, order,
                    alpha: float, beta: float, num_mh: int = 2,
                    return_mh_stats: bool = False):
    """One AliasLDA sweep with ``num_mh`` MH steps per token.

    The stale proposal for word w is  q̃_t ∝ (ñ_wt+β)/(ñ_t+β̄)  with counts
    snapshotted at sweep start; sampling from q̃ is done by inverse-CDF on a
    precomputed per-word cumulative table (the jnp-equivalent of the alias
    table draw — Θ(1)/Θ(log T) per draw from a stale structure; the true
    alias construction is exercised in samplers.py / kernels tests).

    Both inverse-CDF draws are boundary-guarded (:func:`lsearch_guarded`):
    a stale table whose scaled ``u`` rounds up to the table total must not
    walk past the last positive-mass topic — a zero-density proposal would
    poison the MH ratio of every later step that compares against it.

    ``return_mh_stats=True`` additionally returns a per-token bool array:
    True iff every MH step of that token had a finite ratio and an
    acceptance probability in (0, 1] — the invariant the guarded proposal
    restores (a zero-density proposal yields ratio 0 or inf).
    """
    beta_bar = beta * state.n_wt.shape[0]
    key, k1, k2, k3 = jax.random.split(state.key, 4)
    N = order.shape[0]
    f32 = jnp.float32

    # --- stale per-word proposal tables (snapshot at sweep start) ----------
    stale_q = ((state.n_wt.astype(f32) + beta)
               / (state.n_t.astype(f32) + beta_bar))          # (J,T)
    stale_cdf = jnp.cumsum(stale_q, axis=1)                   # (J,T)
    stale_mass = stale_cdf[:, -1]                             # (J,)

    u_r = jax.random.uniform(k1, (N,))            # bucket + r-draw
    u_mh = jax.random.uniform(k2, (N, num_mh))    # MH accept
    u_prop = jax.random.uniform(k3, (N, num_mh))  # proposal draws

    def step(carry, inp):
        z, n_td, n_wt, n_t = carry
        k, u01, u_acc, u_pp = inp
        d, w, t_old = doc_ids[k], word_ids[k], z[k]
        n_td = n_td.at[d, t_old].add(-1)
        n_wt = n_wt.at[w, t_old].add(-1)
        n_t = n_t.at[t_old].add(-1)

        denom = n_t.astype(f32) + beta_bar
        q_vec = (n_wt[w].astype(f32) + beta) / denom       # fresh, for MH ratio
        r_vec = n_td[d].astype(f32) * q_vec                # fresh sparse term
        r_cdf = jnp.cumsum(r_vec)
        r_mass = r_cdf[-1]
        prop_mass = alpha * stale_mass[w] + r_mass

        def p_true(t):
            return (n_td[d, t].astype(f32) + alpha) * q_vec[t]

        def propose(uu):
            """Draw from the mixture proposal: stale α·q̃ + fresh r."""
            uval = uu * prop_mass
            in_r = uval < r_mass
            t_r = lsearch_guarded(r_cdf, uval)
            u_q = jnp.clip((uval - r_mass) / (alpha * stale_mass[w]),
                           0.0, 1.0 - 1e-7) * stale_mass[w]
            t_q = lsearch_guarded(stale_cdf[w], u_q)
            return jnp.where(in_r, t_r, t_q)

        def prop_density(t):
            return alpha * stale_q[w, t] + r_vec[t]

        # --- MH chain over num_mh proposals --------------------------------
        def mh_body(i, carry):
            t_cur, ok = carry
            t_prop = propose(u_pp[i])
            ratio = (p_true(t_prop) * prop_density(t_cur)) / \
                    jnp.maximum(p_true(t_cur) * prop_density(t_prop), 1e-30)
            acc = jnp.minimum(ratio, 1.0)
            ok = ok & jnp.isfinite(ratio) & (acc > 0.0) & (acc <= 1.0)
            accept = u_acc[i] < acc
            return jnp.where(accept, t_prop, t_cur), ok

        t0 = propose(u01)
        t_new, mh_ok = lax.fori_loop(0, num_mh, mh_body,
                                     (t0, jnp.bool_(True)))

        n_td = n_td.at[d, t_new].add(1)
        n_wt = n_wt.at[w, t_new].add(1)
        n_t = n_t.at[t_new].add(1)
        z = z.at[k].set(t_new)
        return (z, n_td, n_wt, n_t), mh_ok

    (z, n_td, n_wt, n_t), mh_ok = lax.scan(
        step, (state.z, state.n_td, state.n_wt, state.n_t),
        (order, u_r, u_mh, u_prop))
    new = LDAState(z=z, n_td=n_td, n_wt=n_wt, n_t=n_t, key=key)
    if return_mh_stats:
        return new, mh_ok
    return new
