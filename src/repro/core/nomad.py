"""Nomad-distributed F+LDA on a JAX device mesh (paper §4).

The paper's nomadic framework, mapped to SPMD TPU semantics (DESIGN.md §3):

* **Word tokens** τ_j: the word-topic count blocks ``n_wt[b]`` are the
  nomadic payloads.  ``W`` workers form a flat ring over the whole mesh and
  each owns a **queue of k = B/W blocks** (paper §4: circulate more blocks
  than workers).  The queue hops one ring position per round via
  ``lax.ppermute``: in round ``r`` (of ``W`` per sweep) worker ``w`` holds
  chunk ``c = (w + r) % W`` — global blocks ``c·k .. c·k+k−1`` — and sweeps
  all ``k`` of those cells (all occurrences of the queue's words in its
  document shard) before passing the queue on.  Chunks are disjoint, so the
  word counts stay **always exact and conflict-free** — the paper's key
  invariant — for any ``B`` that is a multiple of ``W``.  Raising ``B``
  shrinks each block's vocabulary slice (the fused kernel's VMEM page) at
  no round-balance cost: the hierarchical LPT in ``data/sharding.py``
  keeps ``NomadLayout.round_imbalance`` equal to the ``B = W`` packing
  (DESIGN.md §4).

  Two rotation schedules (``ring_mode``): ``"barrier"`` sweeps the whole
  queue then hops it in one ``ppermute``; ``"pipelined"`` forwards the
  first ``half_queue_split(k)`` blocks as soon as their cells finish, so
  that hop can overlap the second half's sweep — the paper's
  communication-hides-behind-sampling property on a lock-step mesh.  Cell
  order and s-token fold point are unchanged, so both schedules run the
  **bit-identical** per-token chain (asserted across the whole
  sync × inner × B matrix by ``launch/lda_matrix_check.py``).

* **The s token** τ_s: the only globally shared state is ``s = n_t`` (size
  T).  Three synchronization modes:

    - ``"stoken"``   (paper-faithful): one authoritative ``s`` vector rides
      the same ring; each worker keeps a working copy ``s_l`` and folds its
      accumulated delta in when the token passes (Alg. 4: s += s_l − s̄).
      Staleness ≤ W−1 ring rounds (k cells each), exactly the paper's bound.
    - ``"stale"``    (AD-LDA-like): no intra-sweep sync; deltas psum at
      sweep end.  Staleness = 1 sweep.
    - ``"allreduce"``(beyond-paper): psum the cumulative deltas every round.
      Staleness ≤ 1 round; costs one (T,) all-reduce per round — cheap on
      ICI, impossible on the paper's commodity cluster.

  Every mode finishes the sweep with an **exact** ``n_t`` (additivity of
  s — the paper's observation), so count invariants hold at sweep
  boundaries regardless of mode.

* **Documents** never move (paper: "keep the ownership of d_i").
  ``n_td`` is sharded by worker; ``z`` is sharded with its token cells.

The per-round compute is the word-by-word F+LDA cell sweep (Alg. 3) over the
padded cell, with the same F+tree q-term maintenance as the serial version.

Two token geometries feed that sweep (``NomadLayout.kind``, DESIGN.md §4):
the **dense** ``(W, B, L)`` cell grid, and the **ragged** ``(W, W, S)``
per-chunk tile streams whose padding stays bounded by the tile size for any
``B``.  Initial assignments and per-token uniforms are derived from
canonical token coordinates (not array positions), so the two layouts run
**bit-identical** chains — the layout is purely a storage/throughput choice.

A third axis, ``doc_tile`` (DESIGN.md §7), lifts the doc-topic VMEM
ceiling: a layout built with ``doc_tile`` orders each cell's tokens by doc
group so the fused kernels can page one ``(doc_tile, T)`` slab of ``n_td``
through VMEM (``NomadLDA(doc_tile=...)``) — again with paged, unpaged,
dense and ragged execution all bit-identical over the same layout.
"""
from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.data.sharding import NomadLayout

__all__ = ["NomadLDA", "nomad_sweep_fn"]

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Ring topology helpers (flat ring over possibly-multiple mesh axes).
# ---------------------------------------------------------------------------
def _flat_index(axes: Sequence[str], sizes: Sequence[int]):
    idx = jnp.zeros((), jnp.int32)
    for ax, sz in zip(axes, sizes):
        idx = idx * sz + lax.axis_index(ax)
    return idx


def _ring_shift_down(x, axes: Sequence[str], sizes: Sequence[int]):
    """Move value from flat-ring position i+1 to position i (blocks travel
    toward lower worker index, so worker w picks up block w+r+1 next round).

    For a single axis this is one ppermute; with a leading 'pod' axis the
    wrap-around element additionally hops across pods (DESIGN.md §4).
    """
    inner = axes[-1]
    n_inner = sizes[-1]
    perm = [(i, (i - 1) % n_inner) for i in range(n_inner)]
    x_w = lax.ppermute(x, inner, perm)
    if len(axes) == 1:
        return x_w
    # multi-axis: the element that wrapped within the pod actually belongs
    # to the previous pod's boundary worker — fix it with a pod-axis hop.
    outer = axes[0]
    n_outer = sizes[0]
    perm_o = [(p, (p - 1) % n_outer) for p in range(n_outer)]
    x_pw = lax.ppermute(x_w, outer, perm_o)
    at_boundary = lax.axis_index(inner) == n_inner - 1
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(at_boundary, b, a), x_w, x_pw)


# ---------------------------------------------------------------------------
# Layout-independent per-token uniforms.
# ---------------------------------------------------------------------------
def _token_uniforms(key, uids):
    """Counter-mode uniforms: one draw per token id, independent of the
    array geometry the ids arrive in.

    ``uid = global_block·L + slot`` names a token by its canonical cell
    coordinates, so the dense grid and the ragged stream draw the *same*
    uniform for the same token — the property that makes the two layouts'
    Gibbs chains bit-identical (and padding slots' draws harmless: they
    are computed but discarded by the valid mask)."""
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, uids.ravel())
    return jax.vmap(jax.random.uniform)(keys).reshape(uids.shape)


# ---------------------------------------------------------------------------
# Per-cell word-by-word F+LDA sweep (Alg. 3 with masking + local indices).
# ---------------------------------------------------------------------------
def _cell_sweep(tok_doc, tok_wrd, tok_valid, tok_bound, z_cell,
                n_td, n_wt, n_t, u, alpha, beta, beta_bar,
                r_mode: str = "dense", r_cap: int = 0,
                topics=None, counts=None):
    """Exact CGS over one padded cell (Alg. 3 with masking + local indices).

    tok_* / z_cell / u: (L,); n_td: (I,T) int32 (local docs); n_wt: (J,T)
    int32 (current block, local words); n_t: (T,) int32 (worker's working
    copy — possibly stale).  Returns updated (z_cell, n_td, n_wt, n_t)
    — with the per-doc ``(topics, counts)`` r-bucket side tables appended
    when ``r_mode="sparse"`` (see :mod:`repro.kernels.fused_sweep.rbucket`).

    The masked per-token chain itself lives in
    :func:`repro.kernels.fused_sweep.ref.fused_sweep_ref` — the single
    jnp reference all implementations (this scan mode, the fused Pallas
    kernel, its tests) share, so the float-op order is defined once.
    """
    from repro.kernels.fused_sweep.ref import fused_sweep_ref
    out = fused_sweep_ref(
        tok_doc, tok_wrd, tok_valid, tok_bound, z_cell, u,
        n_td, n_wt, n_t, alpha=alpha, beta=beta, beta_bar=beta_bar,
        r_mode=r_mode, r_cap=r_cap or None, topics=topics, counts=counts)
    if r_mode == "sparse":
        return out[0], out[1], out[2], out[3], out[5], out[6]
    return out[0], out[1], out[2], out[3]


def _vectorized_pass(doc_idx, wrd_idx, mask, z, n_td, n_wt, n_t, u,
                     alpha, beta, beta_bar):
    """One batched delayed-count pass over a flat token segment: every
    ``mask``-selected token is sampled against the counts as of entry
    (minus its own contribution — the standard delayed/minibatch CGS),
    then the deltas are applied exactly (batched scatter-add, duplicates
    accumulate).  Unmasked tokens are exact no-ops.

    The single definition both vectorized inner modes share: the dense
    grid passes one cell with ``mask = tok_valid``, the ragged stream
    passes the whole segment with ``mask`` selecting one cell — keeping
    the float-op order identical is what makes the two layouts'
    vectorized chains bit-equal.
    """
    T = n_t.shape[-1]
    one = mask.astype(jnp.int32)
    z_oh = jax.nn.one_hot(z, T, dtype=jnp.int32) * one[:, None]

    ntd_rows = n_td[doc_idx] - z_oh                    # (L,T) self-excluded
    nwt_rows = n_wt[wrd_idx] - z_oh
    nt_rows = n_t[None, :] - z_oh

    p = ((ntd_rows.astype(F32) + alpha)
         * (nwt_rows.astype(F32) + beta)
         / (nt_rows.astype(F32) + beta_bar))
    c = jnp.cumsum(p, axis=-1)
    draw = jnp.sum(c <= (u * c[:, -1])[:, None], axis=-1).astype(jnp.int32)
    z_new = jnp.where(mask, jnp.clip(draw, 0, T - 1), z)

    n_td = n_td.at[doc_idx, z].add(-one).at[doc_idx, z_new].add(one)
    n_wt = n_wt.at[wrd_idx, z].add(-one).at[wrd_idx, z_new].add(one)
    n_t = n_t.at[z].add(-one).at[z_new].add(one)
    return z_new, n_td, n_wt, n_t


def _cell_sweep_vectorized(tok_doc, tok_wrd, tok_valid, tok_bound, z_cell,
                           n_td, n_wt, n_t, u, alpha, beta, beta_bar):
    """Beyond-paper TPU mode (DESIGN §3 last row): the whole cell is sampled
    in one batched pass against counts frozen at cell start (minus each
    token's own contribution — the standard delayed/minibatch CGS, AD-LDA
    style *within* a cell), then the count deltas are applied exactly
    (:func:`_vectorized_pass`).

    Trades the paper's per-token exact chain for full 8×128-lane VPU
    utilization — the dense conditional here is exactly what the
    ``lda_scores`` Pallas kernel computes per tile.  Staleness ≤ one cell;
    cross-cell/nomad semantics unchanged.
    """
    return _vectorized_pass(tok_doc, tok_wrd, tok_valid, z_cell,
                            n_td, n_wt, n_t, u, alpha, beta, beta_bar)


def _queue_sweep_fused(tok_doc, tok_wrd, tok_valid, tok_bound, z_q,
                       n_td, n_wt_q, n_t, u, alpha, beta, beta_bar,
                       cell_start: int = 0, num_cells: int | None = None,
                       dto=None, doc_rows: int = 0, doc_blk: int = 0,
                       r_mode: str = "dense", r_cap: int = 0,
                       topics=None, counts=None,
                       interpret: bool = True):
    """Exact per-token chain like :func:`_cell_sweep`, but the worker's whole
    per-round block queue runs as ONE fused ``pallas_call``
    (:func:`repro.kernels.fused_sweep.fused_sweep_cells`): grid over the k
    cells, F+tree / ``n_t`` / ``n_td`` carried across grid steps, one
    word-topic block VMEM-resident at a time (DESIGN.md §7).  Bit-exact
    same chain as ``inner_mode="scan"`` over the same queue.

    tok_* / z_q / u: (k, L); n_td: (I,T); n_wt_q: (k,J,T); n_t: (T,).
    ``cell_start``/``num_cells`` restrict the call to a sub-queue (the
    pipelined ring's half-queues); returned ``z_q``/``n_wt_q`` then cover
    only that range.  ``dto``/``doc_rows``/``doc_blk`` (a doc-tiled
    layout being *paged*, DESIGN.md §7) swap in the doc-tiled kernel:
    only one ``(doc_rows, T)`` doc-topic slab is VMEM-resident, with the
    chain untouched.
    """
    from repro.kernels.fused_sweep import fused_sweep_cells
    kw = dict(doc_tile_of=dto, doc_rows=doc_rows,
              n_blk=doc_blk) if dto is not None else {}
    out = fused_sweep_cells(
        tok_doc, tok_wrd, tok_valid, tok_bound, z_q, u, n_td, n_wt_q, n_t,
        alpha=alpha, beta=beta, beta_bar=beta_bar,
        cell_start=cell_start, num_cells=num_cells, interpret=interpret,
        r_mode=r_mode, r_cap=r_cap or None, topics=topics, counts=counts,
        **kw)
    if r_mode == "sparse":
        return out[0], out[1], out[2], out[3], out[5], out[6]
    return out[0], out[1], out[2], out[3]


def _queue_sweep_cells(cell_fn, tok_doc, tok_wrd, tok_valid, tok_bound, z_q,
                       n_td, n_wt_q, n_t, u, alpha, beta, beta_bar,
                       cell_start: int = 0, num_cells: int | None = None,
                       dto=None, doc_rows: int = 0, doc_blk: int = 0,
                       r_mode: str = "dense",
                       topics=None, counts=None):
    """Sweep a worker's k-cell queue with a per-cell function (``scan`` /
    ``vectorized`` inner modes): an inner ``lax.scan`` over the stacked
    cells, the exact chain carried through ``n_td``/``n_t``; each cell's
    ``z`` row and word-topic block ride as scan xs/ys.  Same shapes and
    sub-queue convention as :func:`_queue_sweep_fused`; the doc-tiling
    arguments are accepted and ignored — XLA manages residency here, and
    a doc-grouped layout's order is already baked into the token arrays,
    so the chain matches the paged fused kernel bit-for-bit.  With
    ``r_mode="sparse"`` the per-doc r-bucket side tables ride the scan
    carry next to ``n_td`` and are appended to the return."""
    del dto, doc_rows, doc_blk
    sparse = r_mode == "sparse"
    if num_cells is None:
        num_cells = tok_doc.shape[0] - cell_start
    sub = lambda a: a[cell_start:cell_start + num_cells]

    def cell_body(carry, xs):
        tok_d, tok_w, tok_v, tok_b, z_c, nwt_c, u_c = xs
        if sparse:
            n_td, n_t, tpc, cnt = carry
            z_c, n_td, nwt_c, n_t, tpc, cnt = cell_fn(
                tok_d, tok_w, tok_v, tok_b, z_c, n_td, nwt_c, n_t, u_c,
                alpha, beta, beta_bar, topics=tpc, counts=cnt)
            return (n_td, n_t, tpc, cnt), (z_c, nwt_c)
        n_td, n_t = carry
        z_c, n_td, nwt_c, n_t = cell_fn(
            tok_d, tok_w, tok_v, tok_b, z_c, n_td, nwt_c, n_t, u_c,
            alpha, beta, beta_bar)
        return (n_td, n_t), (z_c, nwt_c)

    carry0 = (n_td, n_t, topics, counts) if sparse else (n_td, n_t)
    carry, (z_q, n_wt_q) = lax.scan(
        cell_body, carry0,
        (sub(tok_doc), sub(tok_wrd), sub(tok_valid), sub(tok_bound),
         sub(z_q), sub(n_wt_q), sub(u)))
    if sparse:
        n_td, n_t, topics, counts = carry
        return z_q, n_td, n_wt_q, n_t, topics, counts
    n_td, n_t = carry
    return z_q, n_td, n_wt_q, n_t


# ---------------------------------------------------------------------------
# Ragged-stream queue sweeps (NomadLayout kind="ragged", DESIGN.md §4/§7):
# tok_* / z / u are flat (S,) per-chunk streams, cot the (S//tile,)
# tile→cell map; same sub-range convention as the dense queue sweeps but
# expressed as (tile_start, num_tiles) + (cell_start, num_cells).
# ---------------------------------------------------------------------------
def _queue_sweep_ragged_fused(tok_doc, tok_wrd, tok_valid, tok_bound, z_s,
                              n_td, n_wt_q, n_t, u, cot,
                              alpha, beta, beta_bar, *, tile,
                              tile_start=0, num_tiles=None,
                              cell_start=0, num_cells=None,
                              dto=None, doc_rows: int = 0,
                              r_mode: str = "dense", r_cap: int = 0,
                              topics=None, counts=None,
                              interpret: bool = True):
    """The ragged nomad hot path: the worker's whole per-round stream as
    ONE flat-grid ``pallas_call`` with scalar-prefetch block paging
    (:func:`repro.kernels.fused_sweep.fused_sweep_ragged`).  Bit-exact
    same chain as the dense queue sweeps over the same tokens.
    ``dto``/``doc_rows`` page the doc-topic slab (DESIGN.md §7)."""
    from repro.kernels.fused_sweep import fused_sweep_ragged
    out = fused_sweep_ragged(
        tok_doc, tok_wrd, tok_valid, tok_bound, z_s, u, cot,
        n_td, n_wt_q, n_t, alpha=alpha, beta=beta, beta_bar=beta_bar,
        n_blk=tile, tile_start=tile_start, num_tiles=num_tiles,
        cell_start=cell_start, num_cells=num_cells,
        doc_tile_of=dto, doc_rows=doc_rows,
        r_mode=r_mode, r_cap=r_cap or None, topics=topics, counts=counts,
        interpret=interpret)
    if r_mode == "sparse":
        return out[0], out[1], out[2], out[3], out[5], out[6]
    return out[0], out[1], out[2], out[3]


def _queue_sweep_ragged_scan(tok_doc, tok_wrd, tok_valid, tok_bound, z_s,
                             n_td, n_wt_q, n_t, u, cot,
                             alpha, beta, beta_bar, *, tile,
                             tile_start=0, num_tiles=None,
                             cell_start=0, num_cells=None,
                             dto=None, doc_rows: int = 0,
                             r_mode: str = "dense", r_cap: int = 0,
                             topics=None, counts=None):
    """Exact per-token chain over the ragged stream: one ``lax.scan``
    (the shared oracle) with the queue's blocks flattened to a
    ``(k·J, T)`` table — the same float ops in the same order as the
    dense ``"scan"`` mode over the same tokens.  Doc-tiling arguments
    accepted and ignored (see :func:`_queue_sweep_cells`)."""
    del dto, doc_rows
    from repro.kernels.fused_sweep.ref import fused_sweep_ragged_ref
    out = fused_sweep_ragged_ref(
        tok_doc, tok_wrd, tok_valid, tok_bound, z_s, u, cot,
        n_td, n_wt_q, n_t, alpha=alpha, beta=beta, beta_bar=beta_bar,
        n_blk=tile, tile_start=tile_start, num_tiles=num_tiles,
        cell_start=cell_start, num_cells=num_cells,
        r_mode=r_mode, r_cap=r_cap or None, topics=topics, counts=counts)
    if r_mode == "sparse":
        return out[0], out[1], out[2], out[3], out[5], out[6]
    return out[0], out[1], out[2], out[3]


def _queue_sweep_ragged_vectorized(tok_doc, tok_wrd, tok_valid, tok_bound,
                                   z_s, n_td, n_wt_q, n_t, u, cot,
                                   alpha, beta, beta_bar, *, tile,
                                   tile_start=0, num_tiles=None,
                                   cell_start=0, num_cells=None,
                                   dto=None, doc_rows: int = 0):
    """Beyond-paper batched mode on the ragged stream: one masked pass per
    cell over the stream segment (:func:`_vectorized_pass`), counts frozen
    at cell start — the same per-cell freeze points (and bit-identical
    draws) as :func:`_cell_sweep_vectorized` on the dense grid.
    Doc-tiling arguments accepted and ignored (see
    :func:`_queue_sweep_cells`)."""
    del dto, doc_rows
    k_total, J, T = n_wt_q.shape
    r_total = cot.shape[0]
    nt_ = r_total - tile_start if num_tiles is None else int(num_tiles)
    nc = k_total - cell_start if num_cells is None else int(num_cells)
    lo, hi = tile_start * tile, (tile_start + nt_) * tile
    sub = lambda a: a[lo:hi]
    cell_tok = jnp.repeat(cot[tile_start:tile_start + nt_] - cell_start,
                          tile, total_repeat_length=nt_ * tile)
    doc_seg, valid_seg = sub(tok_doc), sub(tok_valid)
    wrd_flat = cell_tok * J + sub(tok_wrd)
    u_seg = sub(u)
    nwt_flat = n_wt_q[cell_start:cell_start + nc].reshape(nc * J, T)

    def cell_body(carry, j):
        z_s, n_td, nwt_flat, n_t = carry
        mask = valid_seg & (cell_tok == j)
        return _vectorized_pass(doc_seg, wrd_flat, mask, z_s,
                                n_td, nwt_flat, n_t, u_seg,
                                alpha, beta, beta_bar), None

    (z_seg, n_td, nwt_flat, n_t), _ = lax.scan(
        cell_body, (sub(z_s), n_td, nwt_flat, n_t),
        jnp.arange(nc, dtype=jnp.int32))
    return z_seg, n_td, nwt_flat.reshape(nc, J, T), n_t


# ---------------------------------------------------------------------------
# The distributed sweep.
# ---------------------------------------------------------------------------
def nomad_sweep_fn(mesh: Mesh, ring_axes: Sequence[str], *,
                   B: int, T: int, alpha: float, beta: float,
                   beta_bar: float, sync_mode: str = "stoken",
                   inner_mode: str = "scan", ring_mode: str = "barrier",
                   interpret: bool | None = None,
                   collect_lag: bool = False,
                   layout_kind: str = "dense", tile: int = 0,
                   n_tiles: int = 0, tile_split: int = 0,
                   rng_stride: int = 0,
                   doc_rows: int = 0, doc_blk: int = 0,
                   page_docs: bool = False,
                   r_mode: str = "dense", r_cap: int = 0):
    """Build the jittable distributed sweep for ``mesh``.

    Ring spans the product of ``ring_axes`` (e.g. ('worker',) or
    ('pod', 'worker')).  Returns ``sweep(tok_*, z, n_td, n_wt, n_t, seed)``
    operating on global arrays sharded as documented in NomadLayout.

    ``B`` may be any multiple of the ring size ``W``: each worker's shard of
    the ``(B, J_max, T)`` word-topic array is its ``k = B/W``-block queue,
    and the sweep runs ``W`` ring rounds of ``k`` cells each (``B`` cell
    sweeps per worker per sweep — every (worker, block) pair exactly once).

    inner_mode: "scan" = exact per-token chain (paper Alg. 3), inner scan
    over the queue; "fused" = the same chain with the whole queue as ONE
    fused Pallas kernel per round (see :func:`_queue_sweep_fused`);
    "vectorized" = beyond-paper batched cell pass (see
    :func:`_cell_sweep_vectorized`).  ``interpret=None`` auto-selects the
    compiled Pallas path on TPU and the interpreter elsewhere.

    ring_mode: "barrier" = sweep all k cells, then hop the whole queue —
    one ``ppermute`` on the critical path per round.  "pipelined" = sweep
    the first half-queue (``half_queue_split(k)`` cells), issue its hop
    immediately, sweep the second half while that collective is in flight,
    then hop the rest together with the s token (DESIGN.md §4).  The cell
    order and the s-token fold point are identical in both modes, so the
    per-token chain is **bit-identical** — only the moment the first
    half's ``ppermute`` is *issued* moves.  With ``k < 2`` the pipelined
    schedule degenerates to the barrier one.

    collect_lag: diagnostic mode — the sweep additionally returns a
    ``(W_rounds, W, 2, T)`` int32 array holding, per round and worker,
    ``n_t_local`` after the round's s synchronization and the cumulative
    ``delta_mine``.  Adds no collectives (the exact ``n_t`` is
    reconstructed offline by summing deltas); used by
    ``launch/stoken_lag_check.py`` to verify the staleness bound.

    layout_kind: the token geometry the sweep operates on (DESIGN.md §4).
    ``"dense"``: tok_* are the padded ``(W, B, L)`` cell grid.
    ``"ragged"``: tok_* are the ``(W, W, S)`` per-chunk tile streams and
    the returned sweep takes two extra trailing arguments,
    ``cell_of_tile`` ``(W, W, n_tiles)`` and ``tok_slot`` ``(W, W, S)``;
    ``tile``/``n_tiles``/``tile_split`` are the layout's static tile
    geometry and ``rng_stride`` its ``L``.  Both layouts draw uniforms
    per canonical token id (:func:`_token_uniforms`), so for the same
    corpus, seed and modes their per-token chains are **bit-identical**
    (asserted across the whole matrix by ``launch/lda_matrix_check.py``).

    r_mode / r_cap: the r-bucket draw mode (DESIGN.md §7a,
    :mod:`repro.kernels.fused_sweep.rbucket`).  ``"dense"`` recomputes the
    capacity-``r_cap`` compacted topic vector from the ``n_td`` row per
    token; ``"sparse"`` maintains it as per-doc ``(topics, counts)`` side
    tables — the sweep then takes two extra trailing ``(W, I_max, r_cap)``
    table arguments (sharded like ``n_td``; build them with
    ``rbucket.build_side_table``) and returns them updated after the base
    four outputs.  Both modes draw from the same compacted vector, so for
    equal ``r_cap`` the chains are bit-identical; ``r_cap`` itself is
    chain-affecting (``0`` → ``T``, which preserves the dense default).
    ``"sparse"`` requires an exact per-token inner mode
    (``inner_mode != "vectorized"``).

    doc_rows / doc_blk / page_docs: a ``doc_tile``-grouped layout
    (DESIGN.md §7) sets ``doc_rows`` to its slab height — the sweep then
    takes a trailing ``doc_tile_of`` argument (and, for dense layouts, a
    ``tok_slot`` array so RNG ids stay position-independent across the
    group-padded rows).  ``page_docs=True`` makes the fused inner modes
    page one ``(doc_rows, T)`` doc-topic slab through VMEM instead of
    holding the whole ``(I_max, T)`` shard; all other modes (and
    ``page_docs=False``) run whole-shard on the identical grouped order,
    so paged, unpaged, dense and ragged chains are all bit-identical
    over the same layout.  ``doc_blk`` is the dense grid step the layout
    was built for (``NomadLayout.doc_blk``; ragged pages at its own
    ``tile``).
    """
    from repro.data.sharding import half_queue_split

    sizes = tuple(int(mesh.shape[ax]) for ax in ring_axes)
    W = int(np.prod(sizes))
    if B % W != 0 or B < W:
        raise ValueError(
            f"B must be a positive multiple of the ring size; got B={B}, "
            f"W={W}")
    k = B // W
    if sync_mode not in ("stoken", "stale", "allreduce"):
        raise ValueError(sync_mode)
    if inner_mode not in ("scan", "fused", "vectorized"):
        raise ValueError(inner_mode)
    if ring_mode not in ("barrier", "pipelined"):
        raise ValueError(ring_mode)
    if layout_kind not in ("dense", "ragged"):
        raise ValueError(layout_kind)
    ragged = layout_kind == "ragged"
    if ragged and (tile < 1 or n_tiles < 1 or rng_stride < 1):
        raise ValueError(
            f"ragged sweep needs the layout's tile geometry; got "
            f"tile={tile}, n_tiles={n_tiles}, rng_stride={rng_stride}")
    grouped = doc_rows > 0
    if page_docs and not grouped:
        raise ValueError(
            "page_docs needs a doc_tile-grouped layout (doc_rows > 0)")
    if grouped and rng_stride < 1:
        raise ValueError(
            "doc-grouped sweeps need rng_stride (the layout's true L)")
    if grouped and not ragged and doc_blk < 1:
        raise ValueError(
            "doc-grouped dense sweeps need doc_blk (the layout's grid "
            "step)")
    if r_mode not in ("dense", "sparse"):
        raise ValueError(f"r_mode must be 'dense' or 'sparse', got {r_mode}")
    sparse = r_mode == "sparse"
    if sparse and inner_mode == "vectorized":
        raise ValueError(
            "r_mode='sparse' needs an exact per-token chain; the batched "
            "'vectorized' inner mode has no per-token side-table order")
    cap = int(r_cap) if r_cap else T
    if not 1 <= cap <= T:
        raise ValueError(f"r_cap must be in [1, T]; got {r_cap} (T={T})")
    rbk = dict(r_mode=r_mode, r_cap=cap)
    if interpret is None:
        from repro.kernels.fused_sweep import default_interpret
        interpret = default_interpret()
    if ragged:
        if inner_mode == "fused":
            queue_fn = functools.partial(_queue_sweep_ragged_fused,
                                         tile=tile, interpret=interpret,
                                         **rbk)
        elif inner_mode == "scan":
            queue_fn = functools.partial(_queue_sweep_ragged_scan,
                                         tile=tile, **rbk)
        else:
            queue_fn = functools.partial(_queue_sweep_ragged_vectorized,
                                         tile=tile)
    elif inner_mode == "fused":
        queue_fn = functools.partial(_queue_sweep_fused, interpret=interpret,
                                     **rbk)
    else:
        cell_fn = {"scan": functools.partial(_cell_sweep, **rbk),
                   "vectorized": _cell_sweep_vectorized}[inner_mode]
        queue_fn = functools.partial(_queue_sweep_cells, cell_fn,
                                     r_mode=r_mode)
    k0 = half_queue_split(k) if ring_mode == "pipelined" else 0
    # the static tile index of the ragged half split (0 degenerates to the
    # barrier schedule, exactly like k0 = 0 on the dense grid)
    r0 = tile_split if (ragged and k0 > 0) else 0

    spec_tok = P(tuple(ring_axes), None, None)
    spec_td = P(tuple(ring_axes), None, None)
    spec_wt = P(tuple(ring_axes), None, None)
    spec_rep = P()

    def worker_fn(tok_doc, tok_wrd, tok_valid, tok_bound,
                  z, n_td, n_wt_q, n_t, seed, *aux):
        # local shapes: tok_* (1,B,L) dense / (1,W,S) ragged; n_td (1,I,T);
        # n_wt_q (k,J,T) — the worker's block queue; n_t (T,) replicated;
        # seed () replicated.  Trailing aux arrays, in order: ragged adds
        # cell_of_tile (1,W,n_tiles); ragged-or-grouped adds tok_slot
        # (1,W,S)|(1,B,L); grouped adds doc_tile_of (1,W,n_tiles)|
        # (1,B,L//doc_blk); sparse r-mode adds the rb_topics/rb_counts
        # side tables (1,I,r_cap), sharded like n_td.
        a = list(aux)
        cell_of_tile = a.pop(0) if ragged else None
        tok_slot = a.pop(0) if (ragged or grouped) else None
        doc_tile_of = a.pop(0) if grouped else None
        rb_t, rb_c = (a.pop(0), a.pop(0)) if sparse else (None, None)
        w_flat = _flat_index(ring_axes, sizes)
        key = jax.random.fold_in(jax.random.key(seed), w_flat)
        # RNG stride: the true heaviest cell.  Ungrouped dense rows ARE
        # that long; group padding makes rows longer, so the stride must
        # come from the layout there.
        L = rng_stride if (ragged or grouped) else tok_doc.shape[-1]
        S = tok_doc.shape[-1]

        n_t_start = n_t
        s_tok = n_t                       # authoritative s payload (holder 0)
        delta_folded = jnp.zeros_like(n_t)

        def round_body(carry, r):
            if sparse:
                (z, n_td, n_wt_q, n_t_local, delta_mine, s_tok,
                 delta_folded, rb_t, rb_c) = carry
                rb_kw = dict(topics=rb_t[0], counts=rb_c[0])
            else:
                (z, n_td, n_wt_q, n_t_local, delta_mine, s_tok,
                 delta_folded) = carry
                rb_t = rb_c = None
                rb_kw = {}
            c = (w_flat + r) % W          # chunk id this queue corresponds to
            b0 = c * k                    # its first global block index
            key_r = jax.random.fold_in(key, r)
            n_t_before = n_t_local
            doc_kw = {}
            if ragged:
                chunk = lambda a: lax.dynamic_slice_in_dim(a[0], c, 1,
                                                           axis=0)[0]
                tq = (chunk(tok_doc), chunk(tok_wrd), chunk(tok_valid),
                      chunk(tok_bound))
                z_q_in = chunk(z)
                cot = chunk(cell_of_tile)                      # (n_tiles,)
                cell_tok = jnp.repeat(cot, tile, total_repeat_length=S)
                uid = (b0 + cell_tok) * L + chunk(tok_slot)
                u = _token_uniforms(key_r, uid)
                sweep_args = tq + (z_q_in, n_td[0], n_wt_q, n_t_local, u,
                                   cot, alpha, beta, beta_bar)
                if page_docs:
                    doc_kw = dict(dto=chunk(doc_tile_of), doc_rows=doc_rows)
                if r0 > 0:
                    halves = dict(
                        first=dict(tile_start=0, num_tiles=r0,
                                   cell_start=0, num_cells=k0),
                        second=dict(tile_start=r0, num_tiles=n_tiles - r0,
                                    cell_start=k0, num_cells=k - k0))
            else:
                queue = lambda a: lax.dynamic_slice_in_dim(a[0], b0, k,
                                                           axis=0)
                tq = (queue(tok_doc), queue(tok_wrd), queue(tok_valid),
                      queue(tok_bound))
                z_q_in = queue(z)
                if grouped:
                    # group padding breaks the position == slot identity
                    # of the ungrouped dense row, so slots ride along
                    uid = ((b0 + jnp.arange(k, dtype=jnp.int32))[:, None]
                           * L + queue(tok_slot))
                else:
                    uid = ((b0 + jnp.arange(k, dtype=jnp.int32))[:, None]
                           * L + jnp.arange(L, dtype=jnp.int32)[None, :])
                u = _token_uniforms(key_r, uid)
                sweep_args = tq + (z_q_in, n_td[0], n_wt_q, n_t_local, u,
                                   alpha, beta, beta_bar)
                if page_docs:
                    doc_kw = dict(dto=queue(doc_tile_of),
                                  doc_rows=doc_rows, doc_blk=doc_blk)
                if k0 > 0:
                    halves = dict(
                        first=dict(cell_start=0, num_cells=k0),
                        second=dict(cell_start=k0, num_cells=k - k0))
            pipelined = (r0 if ragged else k0) > 0
            if pipelined:
                # Pipelined: sweep the first half-queue, hop its blocks
                # right away — nothing consumes the shifted value until the
                # next round, so the collective can run concurrently with
                # the second half's sweep (one extra ppermute per round,
                # but off the critical path).
                out0 = queue_fn(*sweep_args, **doc_kw, **rb_kw,
                                **halves["first"])
                z_h0, n_td0, nwt_h0, n_t_local = out0[:4]
                if sparse:
                    rb_kw = dict(topics=out0[4], counts=out0[5])
                nwt_h0 = _ring_shift_down(nwt_h0, ring_axes, sizes)
                args2 = (sweep_args[:5] + (n_td0, n_wt_q, n_t_local)
                         + sweep_args[8:])
                out1 = queue_fn(*args2, **doc_kw, **rb_kw,
                                **halves["second"])
                z_h1, n_td0, nwt_h1, n_t_local = out1[:4]
                if sparse:
                    rb_t, rb_c = out1[4][None], out1[5][None]
                z_q = jnp.concatenate([z_h0, z_h1], axis=0)
            else:
                out = queue_fn(*sweep_args, **doc_kw, **rb_kw)
                z_q, n_td0, nwt_swept, n_t_local = out[:4]
                if sparse:
                    rb_t, rb_c = out[4][None], out[5][None]
            n_td = n_td0[None]
            if ragged:
                z = lax.dynamic_update_slice_in_dim(
                    z[0], z_q[None], c, axis=0)[None]
            else:
                z = lax.dynamic_update_slice_in_dim(
                    z[0], z_q, b0, axis=0)[None]
            delta_mine = delta_mine + (n_t_local - n_t_before)

            # --- s synchronization ---------------------------------------
            # Identical fold point in both ring modes (after the whole
            # k-cell round) — this is what keeps the chains bit-identical.
            if sync_mode == "allreduce":
                n_t_local = n_t_start + lax.psum(delta_mine, tuple(ring_axes))
            elif sync_mode == "stoken":
                has_token = ((w_flat + r) % W) == 0
                fold = delta_mine - delta_folded
                s_new = s_tok + fold
                s_tok = jnp.where(has_token, s_new, s_tok)
                n_t_local = jnp.where(has_token, s_new, n_t_local)
                delta_folded = jnp.where(has_token, delta_mine, delta_folded)
            # "stale": nothing until sweep end.

            # --- rotate the remaining nomadic payloads --------------------
            if pipelined:
                nwt_h1, s_tok = _ring_shift_down((nwt_h1, s_tok),
                                                 ring_axes, sizes)
                n_wt_q = jnp.concatenate([nwt_h0, nwt_h1], axis=0)
            else:
                n_wt_q, s_tok = _ring_shift_down((nwt_swept, s_tok),
                                                 ring_axes, sizes)
            ys = (jnp.stack([n_t_local, delta_mine])[None]
                  if collect_lag else None)
            carry = (z, n_td, n_wt_q, n_t_local, delta_mine, s_tok,
                     delta_folded)
            if sparse:
                carry += (rb_t, rb_c)
            return carry, ys

        carry0 = (z, n_td, n_wt_q, n_t, jnp.zeros_like(n_t), s_tok,
                  delta_folded)
        if sparse:
            carry0 += (rb_t, rb_c)
        carry, lag = lax.scan(
            round_body, carry0, jnp.arange(W, dtype=jnp.int32))
        z, n_td, n_wt_q, _, delta_mine = carry[:5]

        # W shifts = one full loop: every queue is back home, in block order.
        # exact sweep-end resync (additivity of s)
        n_t_out = n_t_start + lax.psum(delta_mine, tuple(ring_axes))
        out = (z, n_td, n_wt_q, n_t_out)
        if sparse:
            out += (carry[7], carry[8])
        if collect_lag:
            out += (lag,)
        return out

    out_specs = (spec_tok, spec_td, spec_wt, spec_rep)
    if sparse:
        out_specs += (spec_td, spec_td)                # rb_topics, rb_counts
    if collect_lag:
        out_specs += (P(None, tuple(ring_axes), None, None),)
    in_specs = (spec_tok, spec_tok, spec_tok, spec_tok,
                spec_tok, spec_td, spec_wt, spec_rep, spec_rep)
    if ragged:
        # trailing cell_of_tile + tok_slot, sharded with the token streams
        in_specs += (spec_tok, spec_tok)
        if grouped:
            in_specs += (spec_tok,)                    # doc_tile_of
    elif grouped:
        in_specs += (spec_tok, spec_tok)               # tok_slot, dto
    if sparse:
        in_specs += (spec_td, spec_td)                 # rb_topics, rb_counts
    fn = shard_map(
        worker_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_vma=False)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Driver.
# ---------------------------------------------------------------------------
@dataclass
class NomadLDA:
    """End-to-end distributed LDA trainer (the paper's F+Nomad LDA).

    ``layout.B`` may be any multiple of the ring size: each worker then
    carries a ``k = B/W``-block queue around the ring (paper §4's
    blocks ≫ workers setup).  ``interpret=None`` (the default) compiles the
    ``inner_mode="fused"`` Pallas path on TPU and interprets it elsewhere.
    ``ring_mode="pipelined"`` overlaps each round's first half-queue hop
    with the second half's sweep — bit-identical chain to ``"barrier"``
    (see :func:`nomad_sweep_fn`).  The token geometry follows the layout:
    ``build_layout(layout="ragged")`` swaps the padded cell grid for the
    ragged tile streams (bit-identical chain again), which keeps
    pad_fraction — and throughput — independent of ``B``.

    ``doc_tile`` lifts the doc-topic VMEM ceiling (DESIGN.md §7): on a
    layout built with the same ``doc_tile``, the fused kernels page one
    ``(doc_tile, T)`` slab of each worker's ``n_td`` shard through VMEM
    instead of holding the whole ``(I_max, T)`` table.  ``doc_tile=None``
    (default) runs whole-shard — today's behavior — even on a grouped
    layout, and is bit-identical to the paged run over the same layout
    (the grouping lives in the token order, the paging only in memory
    residency).

    ``r_mode="sparse"`` maintains the per-doc r-bucket side tables
    (DESIGN.md §7a) as two extra ``(W, I_max, r_cap)`` sweep arrays,
    initialised from ``n_td`` by :meth:`init_arrays` and threaded through
    :meth:`sweep`.  ``r_cap=0`` (default) keeps the full ``T`` capacity —
    bit-identical to the dense default; set ``r_cap=layout.r_cap`` (the
    per-shard max-doc-length bound) to make the r-draw cost independent
    of ``T`` (chain-affecting: compared runs must share ``r_cap``).
    """
    mesh: Mesh
    ring_axes: tuple
    layout: NomadLayout
    alpha: float
    beta: float
    sync_mode: str = "stoken"
    inner_mode: str = "scan"
    ring_mode: str = "barrier"
    interpret: bool | None = None  # Pallas mode for inner_mode="fused"
    doc_tile: int | None = None    # page (doc_tile, T) n_td slabs if set
    r_mode: str = "dense"          # r-bucket draw: "dense" | "sparse"
    r_cap: int = 0                 # compaction capacity (0 → T; the layout's
                                   #   T_d_max bound is ``layout.r_cap``)
    checkpoint_every: int | None = None  # sweeps between chain checkpoints
    checkpoint_path: str | None = None   # ``.npz`` = single file; else a
                                         #   CheckpointRotation directory
    resume_from: str | None = None       # chain checkpoint ``run`` loads
                                         #   (same file-vs-directory rule)
    checkpoint_keep: int = 3             # rotation slots kept (dirs only)

    def __post_init__(self):
        lay = self.layout
        if self.checkpoint_every is not None:
            if self.checkpoint_every < 1:
                raise ValueError(
                    f"checkpoint_every must be >= 1, got "
                    f"{self.checkpoint_every}")
            if not self.checkpoint_path:
                raise ValueError(
                    "checkpoint_every needs checkpoint_path to write to")
        W = int(np.prod([self.mesh.shape[ax] for ax in self.ring_axes]))
        if lay.W != W:
            raise ValueError(
                f"layout built for {lay.W} workers but the ring has {W}")
        if lay.B % lay.W != 0:
            raise ValueError(
                f"layout B={lay.B} is not a multiple of W={lay.W}")
        if self.doc_tile is not None and self.doc_tile != lay.doc_tile:
            raise ValueError(
                f"doc_tile={self.doc_tile} but the layout was built with "
                f"doc_tile={lay.doc_tile or None}; the slab height is a "
                f"layout-build-time choice (it fixes the token order)")
        self.beta_bar = self.beta * lay.num_words
        self._sweep = nomad_sweep_fn(
            self.mesh, self.ring_axes, B=lay.B, T=lay.T,
            alpha=self.alpha, beta=self.beta, beta_bar=self.beta_bar,
            sync_mode=self.sync_mode, inner_mode=self.inner_mode,
            ring_mode=self.ring_mode, interpret=self.interpret,
            layout_kind=lay.kind, tile=lay.tile, n_tiles=lay.n_tiles,
            tile_split=lay.tile_split, rng_stride=lay.L,
            doc_rows=lay.doc_tile, doc_blk=lay.doc_blk,
            page_docs=self.doc_tile is not None,
            r_mode=self.r_mode, r_cap=self.r_cap)
        ring = tuple(self.ring_axes)
        self._sh_tok = NamedSharding(self.mesh, P(ring, None, None))
        self._sh_rep = NamedSharding(self.mesh, P())

    # -- state construction --------------------------------------------------
    def init_arrays(self, seed: int = 0):
        lay = self.layout
        rng = np.random.default_rng(seed)
        # Initial assignments are drawn in canonical token order — the same
        # per-token values whichever geometry (dense/ragged) carries them,
        # so sweeps over the two layouts start from the identical chain.
        z_canon = rng.integers(0, lay.T,
                               lay.canon_idx.shape[0]).astype(np.int32)
        n_td = np.zeros((lay.W, lay.I_max, lay.T), np.int32)
        n_wt = np.zeros((lay.B, lay.J_max, lay.T), np.int32)
        w_idx, b_idx, d_idx, j_idx = lay.token_coords()
        np.add.at(n_td, (w_idx, d_idx, z_canon), 1)
        np.add.at(n_wt, (b_idx, j_idx, z_canon), 1)
        n_t = np.bincount(z_canon, minlength=lay.T)

        put = lambda a, sh: jax.device_put(a, sh)
        arrays = dict(
            tok_doc=put(lay.tok_doc, self._sh_tok),
            tok_wrd=put(lay.tok_wrd, self._sh_tok),
            tok_valid=put(lay.tok_valid, self._sh_tok),
            tok_bound=put(lay.tok_bound, self._sh_tok),
            z=put(lay.place_canonical(z_canon), self._sh_tok),
            n_td=put(n_td, self._sh_tok),
            n_wt=put(n_wt, self._sh_tok),
            n_t=put(n_t.astype(np.int32), self._sh_rep),
        )
        if lay.kind == "ragged":
            arrays.update(
                cell_of_tile=put(lay.cell_of_tile, self._sh_tok),
                tok_slot=put(lay.tok_slot, self._sh_tok))
        elif lay.doc_tile > 0:
            arrays.update(tok_slot=put(lay.tok_slot, self._sh_tok))
        if lay.doc_tile > 0:
            arrays.update(doc_tile_of=put(lay.doc_tile_of, self._sh_tok))
        if self.r_mode == "sparse":
            from repro.kernels.fused_sweep import rbucket
            cap = self.r_cap or lay.T
            tpc, cnt = rbucket.build_side_table(
                jnp.asarray(n_td.reshape(lay.W * lay.I_max, lay.T)), cap)
            arrays.update(
                rb_topics=put(np.asarray(
                    tpc.reshape(lay.W, lay.I_max, cap)), self._sh_tok),
                rb_counts=put(np.asarray(
                    cnt.reshape(lay.W, lay.I_max, cap)), self._sh_tok))
        return arrays

    def sweep(self, arrays: dict, seed: int) -> dict:
        lay = self.layout
        args = (arrays["tok_doc"], arrays["tok_wrd"], arrays["tok_valid"],
                arrays["tok_bound"], arrays["z"], arrays["n_td"],
                arrays["n_wt"], arrays["n_t"], jnp.int32(seed))
        if lay.kind == "ragged":
            args += (arrays["cell_of_tile"], arrays["tok_slot"])
        elif lay.doc_tile > 0:
            args += (arrays["tok_slot"],)
        if lay.doc_tile > 0:
            args += (arrays["doc_tile_of"],)
        if self.r_mode == "sparse":
            args += (arrays["rb_topics"], arrays["rb_counts"])
        res = self._sweep(*args)
        out = dict(arrays)
        out.update(z=res[0], n_td=res[1], n_wt=res[2], n_t=res[3])
        if self.r_mode == "sparse":
            out.update(rb_topics=res[4], rb_counts=res[5])
        return out

    # -- evaluation -----------------------------------------------------------
    def log_likelihood(self, arrays: dict) -> float:
        """Joint LL from the padded sharded tables (pad rows contribute 0)."""
        from jax.scipy.special import gammaln
        lay = self.layout
        T, J = lay.T, lay.num_words
        alpha, beta = self.alpha, self.beta
        n_td = arrays["n_td"].astype(F32)            # (W,I_max,T) padded
        n_wt = arrays["n_wt"].astype(F32)            # (B,J_max,T) padded
        n_t = arrays["n_t"].astype(F32)
        n_i = n_td.sum(axis=2)                       # (W,I_max)
        is_doc = jnp.asarray(self.layout.doc_of_worker >= 0)
        I = int(is_doc.sum())
        doc_part = (I * (gammaln(T * alpha) - T * gammaln(alpha))
                    - jnp.where(is_doc, gammaln(T * alpha + n_i), 0.0).sum()
                    + gammaln(alpha + n_td).sum()
                    - (~is_doc).sum() * T * gammaln(jnp.float32(alpha)))
        topic_part = (T * (gammaln(J * beta) - J * gammaln(beta))
                      - gammaln(J * beta + n_t).sum()
                      + gammaln(beta + n_wt).sum()
                      - (lay.B * lay.J_max - J) * T * gammaln(jnp.float32(beta)))
        return float(doc_part + topic_part)

    def global_counts(self, arrays: dict):
        """Gather compact global (n_td, n_wt, n_t) for validation."""
        lay = self.layout
        n_td_p = np.asarray(arrays["n_td"])
        n_wt_p = np.asarray(arrays["n_wt"])
        I = lay.doc_assign.shape[0]    # full doc-id space (retired docs
        J = lay.num_words              # keep zero rows, corpus_store)
        n_td = np.zeros((I, lay.T), np.int64)
        for w in range(lay.W):
            ids = lay.doc_of_worker[w]
            m = ids >= 0
            n_td[ids[m]] = n_td_p[w, m]
        n_wt = np.zeros((J, lay.T), np.int64)
        for b in range(lay.B):
            ids = lay.word_of_block[b]
            m = ids >= 0
            n_wt[ids[m]] = n_wt_p[b, m]
        return n_td, n_wt, np.asarray(arrays["n_t"], np.int64)

    # -- φ snapshot export (DESIGN.md §10) ------------------------------------
    def export_phi_snapshot(self, arrays: dict, *, sweep: int | None = None):
        """Freeze the current word-topic counts into a serving snapshot
        (``repro.serve.lda_engine.PhiSnapshot``): the posterior-mean φ̂
        plus α/β and provenance meta.  Derived state only — publishing
        never perturbs the chain, so a background ring can call this
        every ``publish_every`` sweeps while readers keep serving."""
        from repro.serve.lda_engine import snapshot_from_counts
        _, n_wt, n_t = self.global_counts(arrays)
        extra = {"source": "nomad", "T": self.layout.T,
                 "num_words": self.layout.num_words}
        if sweep is not None:
            extra["sweep"] = int(sweep)
        return snapshot_from_counts(n_wt, n_t, alpha=self.alpha,
                                    beta=self.beta, extra_meta=extra)

    # -- chain checkpoint/resume (DESIGN.md §9) -------------------------------
    def _chain_meta(self, *, next_seed: int) -> dict:
        """Every chain-affecting knob; a resume with any of these different
        would silently fork the chain, so :meth:`restore_chain_state`
        refuses mismatches."""
        lay = self.layout
        return {
            "next_seed": int(next_seed),    # the RNG counter: sweep seeds
            "ring_round": 0,                # checkpoints sit at sweep
            "half_pos": 0,                  # boundaries — queues are home
            "T": lay.T, "alpha": float(self.alpha), "beta": float(self.beta),
            "sync_mode": self.sync_mode, "r_mode": self.r_mode,
            "r_cap": int(self.r_cap), "rng_stride": int(lay.L),
            "n_tokens": int(lay.canon_idx.shape[0]),
            "W": lay.W, "B": lay.B, "layout_kind": lay.kind,
            "doc_tile": int(lay.doc_tile), "num_docs": lay.doc_assign.shape[0],
            "num_words": lay.num_words,
        }

    def export_chain_state(self, arrays: dict, *, next_seed: int):
        """Snapshot the chain at a sweep boundary → ``(state, meta)``.

        ``z`` is stored in canonical token order and the count tables
        compact (global doc/word ids), so the snapshot is independent of
        the padded token geometry.  The sparse r-bucket side tables are
        stored verbatim: they are maintained incrementally and a fresh
        rebuild from ``n_td`` may list a doc's topics in a different
        order — same distribution, different bits.  The F+tree is derived
        state (rebuilt inside each sweep at every block boundary from the
        current counts), so only a digest of its basis is kept, as a
        restore-time integrity check.
        """
        import hashlib
        lay = self.layout
        z_canon = lay.extract_canonical(np.asarray(arrays["z"]))
        n_td, n_wt, n_t = self.global_counts(arrays)
        state = {
            "z_canon": z_canon.astype(np.int32),
            "n_td": n_td.astype(np.int32),
            "n_wt": n_wt.astype(np.int32),
            "n_t": n_t.astype(np.int32),
        }
        if self.r_mode == "sparse":
            state["rb_topics"] = np.asarray(arrays["rb_topics"])
            state["rb_counts"] = np.asarray(arrays["rb_counts"])
        meta = self._chain_meta(next_seed=next_seed)
        meta["ftree_digest"] = hashlib.sha256(
            np.ascontiguousarray(state["n_wt"]).tobytes()).hexdigest()
        return state, meta

    def restore_chain_state(self, state: dict, meta: dict):
        """Rebuild the sharded sweep arrays from a chain snapshot →
        ``(arrays, next_seed)``.  Bit-exact inverse of
        :meth:`export_chain_state` for this trainer's layout."""
        import hashlib
        lay = self.layout
        want = self._chain_meta(next_seed=0)
        for k in ("T", "alpha", "beta", "sync_mode", "r_mode", "r_cap",
                  "rng_stride", "n_tokens", "W", "B", "doc_tile",
                  "num_docs", "num_words"):
            if meta.get(k) != want[k]:
                raise ValueError(
                    f"chain checkpoint mismatch on {k!r}: checkpoint has "
                    f"{meta.get(k)!r}, this trainer has {want[k]!r} — "
                    f"resuming would fork the chain")
        if meta.get("ring_round") or meta.get("half_pos"):
            raise ValueError(
                "chain checkpoint not at a sweep boundary "
                f"(ring_round={meta.get('ring_round')}, "
                f"half_pos={meta.get('half_pos')})")
        got = hashlib.sha256(np.ascontiguousarray(
            state["n_wt"].astype(np.int32)).tobytes()).hexdigest()
        if meta.get("ftree_digest") not in (None, got):
            raise ValueError("chain checkpoint n_wt digest mismatch — "
                             "corrupt or hand-edited snapshot")

        z_canon = state["z_canon"].astype(np.int32)
        n_td_c = state["n_td"]
        n_wt_c = state["n_wt"]
        n_td = np.zeros((lay.W, lay.I_max, lay.T), np.int32)
        for w in range(lay.W):
            ids = lay.doc_of_worker[w]
            m = ids >= 0
            n_td[w, m] = n_td_c[ids[m]]
        n_wt = np.zeros((lay.B, lay.J_max, lay.T), np.int32)
        for b in range(lay.B):
            ids = lay.word_of_block[b]
            m = ids >= 0
            n_wt[b, m] = n_wt_c[ids[m]]

        put = lambda a, sh: jax.device_put(a, sh)
        arrays = dict(
            tok_doc=put(lay.tok_doc, self._sh_tok),
            tok_wrd=put(lay.tok_wrd, self._sh_tok),
            tok_valid=put(lay.tok_valid, self._sh_tok),
            tok_bound=put(lay.tok_bound, self._sh_tok),
            z=put(lay.place_canonical(z_canon), self._sh_tok),
            n_td=put(n_td, self._sh_tok),
            n_wt=put(n_wt, self._sh_tok),
            n_t=put(state["n_t"].astype(np.int32), self._sh_rep),
        )
        if lay.kind == "ragged":
            arrays.update(
                cell_of_tile=put(lay.cell_of_tile, self._sh_tok),
                tok_slot=put(lay.tok_slot, self._sh_tok))
        elif lay.doc_tile > 0:
            arrays.update(tok_slot=put(lay.tok_slot, self._sh_tok))
        if lay.doc_tile > 0:
            arrays.update(doc_tile_of=put(lay.doc_tile_of, self._sh_tok))
        if self.r_mode == "sparse":
            cap = self.r_cap or lay.T
            for k in ("rb_topics", "rb_counts"):
                if state[k].shape != (lay.W, lay.I_max, cap):
                    raise ValueError(
                        f"checkpoint {k} shape {state[k].shape} != "
                        f"{(lay.W, lay.I_max, cap)}")
            arrays.update(
                rb_topics=put(state["rb_topics"].astype(np.int32),
                              self._sh_tok),
                rb_counts=put(state["rb_counts"].astype(np.int32),
                              self._sh_tok))
        return arrays, int(meta["next_seed"])

    def save_checkpoint(self, path: str, arrays: dict, *,
                        next_seed: int) -> str:
        """Checkpoint the chain to ``path`` → the written file.  A path
        ending ``.npz`` is the legacy single-file store; anything else is
        a :class:`repro.train.checkpoint.CheckpointRotation` directory
        (slot step = ``next_seed``, keeping ``checkpoint_keep`` slots)."""
        from repro.train import checkpoint
        state, meta = self.export_chain_state(arrays, next_seed=next_seed)
        if path.endswith(".npz"):
            return checkpoint.save_chain(path, state, meta)
        rot = checkpoint.CheckpointRotation(path, keep=self.checkpoint_keep)
        return rot.save(state, meta, step=next_seed)

    def load_checkpoint(self, path: str):
        """Inverse of :meth:`save_checkpoint`: a ``.npz`` path loads that
        file; a directory loads the newest *valid* rotation slot —
        damaged slots are skipped (DESIGN.md §11 self-healing fallback),
        and the resumed chain is bit-exact from the slot's sweep."""
        from repro.train import checkpoint
        if path.endswith(".npz"):
            state, meta = checkpoint.load_chain(path)
        else:
            rot = checkpoint.CheckpointRotation(
                path, keep=self.checkpoint_keep)
            state, meta, _ = rot.load_latest_valid()
        return self.restore_chain_state(state, meta)

    def run(self, n_sweeps: int, *, init_seed: int = 0, on_sweep=None,
            publish_every: int | None = None,
            on_publish=None, fault_plan=None) -> tuple[dict, int]:
        """Drive the chain to ``n_sweeps`` total sweeps, checkpointing
        every ``checkpoint_every`` sweeps (resuming from ``resume_from``
        if set) → ``(arrays, sweeps_done)``.  Sweep ``s`` always runs with
        ``seed=s`` whether reached directly or across a resume, so an
        interrupted run is bit-identical to a straight-through one.

        ``publish_every``/``on_publish`` is the serving hook (DESIGN.md
        §10): every ``publish_every`` sweeps the counts are frozen into a
        φ snapshot (:meth:`export_phi_snapshot`) and handed to
        ``on_publish`` — typically ``LdaEngine.publish`` — so readers get
        fresh topics while the ring keeps training.  Publishing reads the
        chain but never writes it: a run with and without the hook is
        bit-identical.

        ``fault_plan`` (a :class:`repro.fault.FaultPlan`) is installed
        for the duration of the loop (DESIGN.md §11).  Sites fired per
        sweep ``s``: ``"trainer.publish"`` (index ``s``, before a
        scheduled publish — ``drop`` skips it, ``delay`` stalls it),
        ``"chain.write"`` (inside the checkpoint write, so ``corrupt`` /
        ``truncate`` land on the slot just written) and
        ``"trainer.sweep"`` (index ``s``, *after* the checkpoint — the
        kill-after-checkpoint preemption the chaos harness replays)."""
        from repro import fault
        if publish_every is not None:
            if publish_every < 1:
                raise ValueError(
                    f"publish_every must be >= 1, got {publish_every}")
            if on_publish is None:
                raise ValueError("publish_every needs an on_publish "
                                 "callback to hand snapshots to")
        with fault.install(fault_plan) if fault_plan is not None \
                else contextlib.nullcontext():
            if self.resume_from:
                arrays, start = self.load_checkpoint(self.resume_from)
            else:
                arrays = self.init_arrays(seed=init_seed)
                start = 0
            for s in range(start, n_sweeps):
                arrays = self.sweep(arrays, seed=s)
                if on_sweep is not None:
                    on_sweep(s, arrays)
                if publish_every and (s + 1) % publish_every == 0:
                    jax.block_until_ready(arrays["n_t"])
                    if "drop" not in fault.fire("trainer.publish", index=s):
                        on_publish(
                            self.export_phi_snapshot(arrays, sweep=s + 1))
                if (self.checkpoint_every
                        and (s + 1) % self.checkpoint_every == 0):
                    jax.block_until_ready(arrays["n_t"])
                    self.save_checkpoint(self.checkpoint_path, arrays,
                                         next_seed=s + 1)
                fault.fire("trainer.sweep", index=s)
        return arrays, n_sweeps
