"""Nomad-distributed F+LDA on a JAX device mesh (paper §4).

The paper's nomadic framework, mapped to SPMD TPU semantics (DESIGN.md §3):

* **Word tokens** τ_j: the word-topic count blocks ``n_wt[b]`` are the
  nomadic payloads.  ``W`` workers form a flat ring over the whole mesh and
  each owns a **queue of k = B/W blocks** (paper §4: circulate more blocks
  than workers).  The queue hops one ring position per round via
  ``lax.ppermute``: in round ``r`` (of ``W`` per sweep) worker ``w`` holds
  chunk ``c = (w + r) % W`` — global blocks ``c·k .. c·k+k−1`` — and sweeps
  all ``k`` of those cells (all occurrences of the queue's words in its
  document shard) before passing the queue on.  Chunks are disjoint, so the
  word counts stay **always exact and conflict-free** — the paper's key
  invariant — for any ``B`` that is a multiple of ``W``.  Raising ``B``
  shrinks each block's vocabulary slice (the fused kernel's VMEM page) at
  no round-balance cost: the hierarchical LPT in ``data/sharding.py``
  keeps ``NomadLayout.round_imbalance`` equal to the ``B = W`` packing
  (DESIGN.md §4).

  Two rotation schedules (``ring_mode``): ``"barrier"`` sweeps the whole
  queue then hops it in one ``ppermute``; ``"pipelined"`` forwards the
  first ``half_queue_split(k)`` blocks as soon as their cells finish, so
  that hop can overlap the second half's sweep — the paper's
  communication-hides-behind-sampling property on a lock-step mesh.  Cell
  order and s-token fold point are unchanged, so both schedules run the
  **bit-identical** per-token chain (asserted across the whole
  sync × inner × B matrix by ``launch/lda_matrix_check.py``).

* **The s token** τ_s: the only globally shared state is ``s = n_t`` (size
  T).  Three synchronization modes:

    - ``"stoken"``   (paper-faithful): one authoritative ``s`` vector rides
      the same ring; each worker keeps a working copy ``s_l`` and folds its
      accumulated delta in when the token passes (Alg. 4: s += s_l − s̄).
      Staleness ≤ W−1 ring rounds (k cells each), exactly the paper's bound.
    - ``"stale"``    (AD-LDA-like): no intra-sweep sync; deltas psum at
      sweep end.  Staleness = 1 sweep.
    - ``"allreduce"``(beyond-paper): psum the cumulative deltas every round.
      Staleness ≤ 1 round; costs one (T,) all-reduce per round — cheap on
      ICI, impossible on the paper's commodity cluster.

  Every mode finishes the sweep with an **exact** ``n_t`` (additivity of
  s — the paper's observation), so count invariants hold at sweep
  boundaries regardless of mode.

* **Documents** never move (paper: "keep the ownership of d_i").
  ``n_td`` is sharded by worker; ``z`` is sharded with its token cells.

The per-round compute is the word-by-word F+LDA cell sweep (Alg. 3) over the
padded cell, with the same F+tree q-term maintenance as the serial version.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.data.sharding import NomadLayout

__all__ = ["NomadLDA", "nomad_sweep_fn"]

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Ring topology helpers (flat ring over possibly-multiple mesh axes).
# ---------------------------------------------------------------------------
def _flat_index(axes: Sequence[str], sizes: Sequence[int]):
    idx = jnp.zeros((), jnp.int32)
    for ax, sz in zip(axes, sizes):
        idx = idx * sz + lax.axis_index(ax)
    return idx


def _ring_shift_down(x, axes: Sequence[str], sizes: Sequence[int]):
    """Move value from flat-ring position i+1 to position i (blocks travel
    toward lower worker index, so worker w picks up block w+r+1 next round).

    For a single axis this is one ppermute; with a leading 'pod' axis the
    wrap-around element additionally hops across pods (DESIGN.md §4).
    """
    inner = axes[-1]
    n_inner = sizes[-1]
    perm = [(i, (i - 1) % n_inner) for i in range(n_inner)]
    x_w = lax.ppermute(x, inner, perm)
    if len(axes) == 1:
        return x_w
    # multi-axis: the element that wrapped within the pod actually belongs
    # to the previous pod's boundary worker — fix it with a pod-axis hop.
    outer = axes[0]
    n_outer = sizes[0]
    perm_o = [(p, (p - 1) % n_outer) for p in range(n_outer)]
    x_pw = lax.ppermute(x_w, outer, perm_o)
    at_boundary = lax.axis_index(inner) == n_inner - 1
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(at_boundary, b, a), x_w, x_pw)


# ---------------------------------------------------------------------------
# Per-cell word-by-word F+LDA sweep (Alg. 3 with masking + local indices).
# ---------------------------------------------------------------------------
def _cell_sweep(tok_doc, tok_wrd, tok_valid, tok_bound, z_cell,
                n_td, n_wt, n_t, u, alpha, beta, beta_bar):
    """Exact CGS over one padded cell (Alg. 3 with masking + local indices).

    tok_* / z_cell / u: (L,); n_td: (I,T) int32 (local docs); n_wt: (J,T)
    int32 (current block, local words); n_t: (T,) int32 (worker's working
    copy — possibly stale).  Returns updated (z_cell, n_td, n_wt, n_t).

    The masked per-token chain itself lives in
    :func:`repro.kernels.fused_sweep.ref.fused_sweep_ref` — the single
    jnp reference all implementations (this scan mode, the fused Pallas
    kernel, its tests) share, so the float-op order is defined once.
    """
    from repro.kernels.fused_sweep.ref import fused_sweep_ref
    z_cell, n_td, n_wt, n_t, _ = fused_sweep_ref(
        tok_doc, tok_wrd, tok_valid, tok_bound, z_cell, u,
        n_td, n_wt, n_t, alpha=alpha, beta=beta, beta_bar=beta_bar)
    return z_cell, n_td, n_wt, n_t


def _cell_sweep_vectorized(tok_doc, tok_wrd, tok_valid, tok_bound, z_cell,
                           n_td, n_wt, n_t, u, alpha, beta, beta_bar):
    """Beyond-paper TPU mode (DESIGN §3 last row): the whole cell is sampled
    in one batched pass against counts frozen at cell start (minus each
    token's own contribution — the standard delayed/minibatch CGS, AD-LDA
    style *within* a cell), then the count deltas are applied exactly.

    Trades the paper's per-token exact chain for full 8×128-lane VPU
    utilization — the dense conditional here is exactly what the
    ``lda_scores`` Pallas kernel computes per tile.  Staleness ≤ one cell;
    cross-cell/nomad semantics unchanged.
    """
    L = tok_doc.shape[0]
    T = n_t.shape[-1]
    one = tok_valid.astype(jnp.int32)
    z_oh = jax.nn.one_hot(z_cell, T, dtype=jnp.int32) * one[:, None]

    ntd_rows = n_td[tok_doc] - z_oh                    # (L,T) self-excluded
    nwt_rows = n_wt[tok_wrd] - z_oh
    nt_rows = n_t[None, :] - z_oh

    p = ((ntd_rows.astype(F32) + alpha)
         * (nwt_rows.astype(F32) + beta)
         / (nt_rows.astype(F32) + beta_bar))
    c = jnp.cumsum(p, axis=-1)
    draw = jnp.sum(c <= (u * c[:, -1])[:, None], axis=-1).astype(jnp.int32)
    z_new = jnp.where(tok_valid, jnp.clip(draw, 0, T - 1), z_cell)

    # exact delta application (batched scatter-add, duplicates accumulate)
    n_td = n_td.at[tok_doc, z_cell].add(-one).at[tok_doc, z_new].add(one)
    n_wt = n_wt.at[tok_wrd, z_cell].add(-one).at[tok_wrd, z_new].add(one)
    n_t = n_t.at[z_cell].add(-one).at[z_new].add(one)
    return z_new, n_td, n_wt, n_t


def _queue_sweep_fused(tok_doc, tok_wrd, tok_valid, tok_bound, z_q,
                       n_td, n_wt_q, n_t, u, alpha, beta, beta_bar,
                       cell_start: int = 0, num_cells: int | None = None,
                       interpret: bool = True):
    """Exact per-token chain like :func:`_cell_sweep`, but the worker's whole
    per-round block queue runs as ONE fused ``pallas_call``
    (:func:`repro.kernels.fused_sweep.fused_sweep_cells`): grid over the k
    cells, F+tree / ``n_t`` / ``n_td`` carried across grid steps, one
    word-topic block VMEM-resident at a time (DESIGN.md §7).  Bit-exact
    same chain as ``inner_mode="scan"`` over the same queue.

    tok_* / z_q / u: (k, L); n_td: (I,T); n_wt_q: (k,J,T); n_t: (T,).
    ``cell_start``/``num_cells`` restrict the call to a sub-queue (the
    pipelined ring's half-queues); returned ``z_q``/``n_wt_q`` then cover
    only that range.
    """
    from repro.kernels.fused_sweep import fused_sweep_cells
    z_q, n_td, n_wt_q, n_t, _ = fused_sweep_cells(
        tok_doc, tok_wrd, tok_valid, tok_bound, z_q, u, n_td, n_wt_q, n_t,
        alpha=alpha, beta=beta, beta_bar=beta_bar,
        cell_start=cell_start, num_cells=num_cells, interpret=interpret)
    return z_q, n_td, n_wt_q, n_t


def _queue_sweep_cells(cell_fn, tok_doc, tok_wrd, tok_valid, tok_bound, z_q,
                       n_td, n_wt_q, n_t, u, alpha, beta, beta_bar,
                       cell_start: int = 0, num_cells: int | None = None):
    """Sweep a worker's k-cell queue with a per-cell function (``scan`` /
    ``vectorized`` inner modes): an inner ``lax.scan`` over the stacked
    cells, the exact chain carried through ``n_td``/``n_t``; each cell's
    ``z`` row and word-topic block ride as scan xs/ys.  Same shapes and
    sub-queue convention as :func:`_queue_sweep_fused`."""
    if num_cells is None:
        num_cells = tok_doc.shape[0] - cell_start
    sub = lambda a: a[cell_start:cell_start + num_cells]

    def cell_body(carry, xs):
        n_td, n_t = carry
        tok_d, tok_w, tok_v, tok_b, z_c, nwt_c, u_c = xs
        z_c, n_td, nwt_c, n_t = cell_fn(
            tok_d, tok_w, tok_v, tok_b, z_c, n_td, nwt_c, n_t, u_c,
            alpha, beta, beta_bar)
        return (n_td, n_t), (z_c, nwt_c)

    (n_td, n_t), (z_q, n_wt_q) = lax.scan(
        cell_body, (n_td, n_t),
        (sub(tok_doc), sub(tok_wrd), sub(tok_valid), sub(tok_bound),
         sub(z_q), sub(n_wt_q), sub(u)))
    return z_q, n_td, n_wt_q, n_t


# ---------------------------------------------------------------------------
# The distributed sweep.
# ---------------------------------------------------------------------------
def nomad_sweep_fn(mesh: Mesh, ring_axes: Sequence[str], *,
                   B: int, T: int, alpha: float, beta: float,
                   beta_bar: float, sync_mode: str = "stoken",
                   inner_mode: str = "scan", ring_mode: str = "barrier",
                   interpret: bool | None = None,
                   collect_lag: bool = False):
    """Build the jittable distributed sweep for ``mesh``.

    Ring spans the product of ``ring_axes`` (e.g. ('worker',) or
    ('pod', 'worker')).  Returns ``sweep(tok_*, z, n_td, n_wt, n_t, seed)``
    operating on global arrays sharded as documented in NomadLayout.

    ``B`` may be any multiple of the ring size ``W``: each worker's shard of
    the ``(B, J_max, T)`` word-topic array is its ``k = B/W``-block queue,
    and the sweep runs ``W`` ring rounds of ``k`` cells each (``B`` cell
    sweeps per worker per sweep — every (worker, block) pair exactly once).

    inner_mode: "scan" = exact per-token chain (paper Alg. 3), inner scan
    over the queue; "fused" = the same chain with the whole queue as ONE
    fused Pallas kernel per round (see :func:`_queue_sweep_fused`);
    "vectorized" = beyond-paper batched cell pass (see
    :func:`_cell_sweep_vectorized`).  ``interpret=None`` auto-selects the
    compiled Pallas path on TPU and the interpreter elsewhere.

    ring_mode: "barrier" = sweep all k cells, then hop the whole queue —
    one ``ppermute`` on the critical path per round.  "pipelined" = sweep
    the first half-queue (``half_queue_split(k)`` cells), issue its hop
    immediately, sweep the second half while that collective is in flight,
    then hop the rest together with the s token (DESIGN.md §4).  The cell
    order and the s-token fold point are identical in both modes, so the
    per-token chain is **bit-identical** — only the moment the first
    half's ``ppermute`` is *issued* moves.  With ``k < 2`` the pipelined
    schedule degenerates to the barrier one.

    collect_lag: diagnostic mode — the sweep additionally returns a
    ``(W_rounds, W, 2, T)`` int32 array holding, per round and worker,
    ``n_t_local`` after the round's s synchronization and the cumulative
    ``delta_mine``.  Adds no collectives (the exact ``n_t`` is
    reconstructed offline by summing deltas); used by
    ``launch/stoken_lag_check.py`` to verify the staleness bound.
    """
    from repro.data.sharding import half_queue_split

    sizes = tuple(int(mesh.shape[ax]) for ax in ring_axes)
    W = int(np.prod(sizes))
    if B % W != 0 or B < W:
        raise ValueError(
            f"B must be a positive multiple of the ring size; got B={B}, "
            f"W={W}")
    k = B // W
    if sync_mode not in ("stoken", "stale", "allreduce"):
        raise ValueError(sync_mode)
    if inner_mode not in ("scan", "fused", "vectorized"):
        raise ValueError(inner_mode)
    if ring_mode not in ("barrier", "pipelined"):
        raise ValueError(ring_mode)
    if interpret is None:
        from repro.kernels.fused_sweep import default_interpret
        interpret = default_interpret()
    if inner_mode == "fused":
        queue_fn = functools.partial(_queue_sweep_fused, interpret=interpret)
    else:
        cell_fn = {"scan": _cell_sweep,
                   "vectorized": _cell_sweep_vectorized}[inner_mode]
        queue_fn = functools.partial(_queue_sweep_cells, cell_fn)
    k0 = half_queue_split(k) if ring_mode == "pipelined" else 0

    spec_tok = P(tuple(ring_axes), None, None)
    spec_td = P(tuple(ring_axes), None, None)
    spec_wt = P(tuple(ring_axes), None, None)
    spec_rep = P()

    def worker_fn(tok_doc, tok_wrd, tok_valid, tok_bound,
                  z, n_td, n_wt_q, n_t, seed):
        # local shapes: tok_* (1,B,L); n_td (1,I,T); n_wt_q (k,J,T) — the
        # worker's block queue; n_t (T,) replicated; seed () replicated.
        w_flat = _flat_index(ring_axes, sizes)
        key = jax.random.fold_in(jax.random.key(seed), w_flat)
        L = tok_doc.shape[-1]

        n_t_start = n_t
        s_tok = n_t                       # authoritative s payload (holder 0)
        delta_folded = jnp.zeros_like(n_t)

        def round_body(carry, r):
            z, n_td, n_wt_q, n_t_local, delta_mine, s_tok, delta_folded = carry
            c = (w_flat + r) % W          # chunk id this queue corresponds to
            b0 = c * k                    # its first global block index
            queue = lambda a: lax.dynamic_slice_in_dim(a[0], b0, k, axis=0)
            tq = (queue(tok_doc), queue(tok_wrd), queue(tok_valid),
                  queue(tok_bound))
            z_q_in = queue(z)
            u = jax.random.uniform(jax.random.fold_in(key, r), (k, L))
            n_t_before = n_t_local
            if k0 > 0:
                # Pipelined: sweep the first half-queue, hop its blocks
                # right away — nothing consumes the shifted value until the
                # next round, so the collective can run concurrently with
                # the second half's sweep (one extra ppermute per round,
                # but off the critical path).
                z_h0, n_td0, nwt_h0, n_t_local = queue_fn(
                    *tq, z_q_in, n_td[0], n_wt_q, n_t_local, u,
                    alpha, beta, beta_bar, cell_start=0, num_cells=k0)
                nwt_h0 = _ring_shift_down(nwt_h0, ring_axes, sizes)
                z_h1, n_td0, nwt_h1, n_t_local = queue_fn(
                    *tq, z_q_in, n_td0, n_wt_q, n_t_local, u,
                    alpha, beta, beta_bar, cell_start=k0, num_cells=k - k0)
                z_q = jnp.concatenate([z_h0, z_h1], axis=0)
            else:
                z_q, n_td0, nwt_swept, n_t_local = queue_fn(
                    *tq, z_q_in, n_td[0], n_wt_q, n_t_local, u,
                    alpha, beta, beta_bar)
            n_td = n_td0[None]
            z = lax.dynamic_update_slice_in_dim(z[0], z_q, b0, axis=0)[None]
            delta_mine = delta_mine + (n_t_local - n_t_before)

            # --- s synchronization ---------------------------------------
            # Identical fold point in both ring modes (after the whole
            # k-cell round) — this is what keeps the chains bit-identical.
            if sync_mode == "allreduce":
                n_t_local = n_t_start + lax.psum(delta_mine, tuple(ring_axes))
            elif sync_mode == "stoken":
                has_token = ((w_flat + r) % W) == 0
                fold = delta_mine - delta_folded
                s_new = s_tok + fold
                s_tok = jnp.where(has_token, s_new, s_tok)
                n_t_local = jnp.where(has_token, s_new, n_t_local)
                delta_folded = jnp.where(has_token, delta_mine, delta_folded)
            # "stale": nothing until sweep end.

            # --- rotate the remaining nomadic payloads --------------------
            if k0 > 0:
                nwt_h1, s_tok = _ring_shift_down((nwt_h1, s_tok),
                                                 ring_axes, sizes)
                n_wt_q = jnp.concatenate([nwt_h0, nwt_h1], axis=0)
            else:
                n_wt_q, s_tok = _ring_shift_down((nwt_swept, s_tok),
                                                 ring_axes, sizes)
            ys = (jnp.stack([n_t_local, delta_mine])[None]
                  if collect_lag else None)
            return (z, n_td, n_wt_q, n_t_local, delta_mine, s_tok,
                    delta_folded), ys

        carry0 = (z, n_td, n_wt_q, n_t, jnp.zeros_like(n_t), s_tok,
                  delta_folded)
        (z, n_td, n_wt_q, _, delta_mine, _, _), lag = lax.scan(
            round_body, carry0, jnp.arange(W, dtype=jnp.int32))

        # W shifts = one full loop: every queue is back home, in block order.
        # exact sweep-end resync (additivity of s)
        n_t_out = n_t_start + lax.psum(delta_mine, tuple(ring_axes))
        if collect_lag:
            return z, n_td, n_wt_q, n_t_out, lag
        return z, n_td, n_wt_q, n_t_out

    out_specs = (spec_tok, spec_td, spec_wt, spec_rep)
    if collect_lag:
        out_specs += (P(None, tuple(ring_axes), None, None),)
    fn = shard_map(
        worker_fn, mesh=mesh,
        in_specs=(spec_tok, spec_tok, spec_tok, spec_tok,
                  spec_tok, spec_td, spec_wt, spec_rep, spec_rep),
        out_specs=out_specs,
        check_vma=False)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Driver.
# ---------------------------------------------------------------------------
@dataclass
class NomadLDA:
    """End-to-end distributed LDA trainer (the paper's F+Nomad LDA).

    ``layout.B`` may be any multiple of the ring size: each worker then
    carries a ``k = B/W``-block queue around the ring (paper §4's
    blocks ≫ workers setup).  ``interpret=None`` (the default) compiles the
    ``inner_mode="fused"`` Pallas path on TPU and interprets it elsewhere.
    ``ring_mode="pipelined"`` overlaps each round's first half-queue hop
    with the second half's sweep — bit-identical chain to ``"barrier"``
    (see :func:`nomad_sweep_fn`).
    """
    mesh: Mesh
    ring_axes: tuple
    layout: NomadLayout
    alpha: float
    beta: float
    sync_mode: str = "stoken"
    inner_mode: str = "scan"
    ring_mode: str = "barrier"
    interpret: bool | None = None  # Pallas mode for inner_mode="fused"

    def __post_init__(self):
        lay = self.layout
        W = int(np.prod([self.mesh.shape[ax] for ax in self.ring_axes]))
        if lay.W != W:
            raise ValueError(
                f"layout built for {lay.W} workers but the ring has {W}")
        if lay.B % lay.W != 0:
            raise ValueError(
                f"layout B={lay.B} is not a multiple of W={lay.W}")
        self.beta_bar = self.beta * lay.num_words
        self._sweep = nomad_sweep_fn(
            self.mesh, self.ring_axes, B=lay.B, T=lay.T,
            alpha=self.alpha, beta=self.beta, beta_bar=self.beta_bar,
            sync_mode=self.sync_mode, inner_mode=self.inner_mode,
            ring_mode=self.ring_mode, interpret=self.interpret)
        ring = tuple(self.ring_axes)
        self._sh_tok = NamedSharding(self.mesh, P(ring, None, None))
        self._sh_rep = NamedSharding(self.mesh, P())

    # -- state construction --------------------------------------------------
    def init_arrays(self, seed: int = 0):
        lay = self.layout
        rng = np.random.default_rng(seed)
        z = np.where(lay.tok_valid,
                     rng.integers(0, lay.T, lay.tok_valid.shape),
                     0).astype(np.int32)
        n_td = np.zeros((lay.W, lay.I_max, lay.T), np.int32)
        n_wt = np.zeros((lay.B, lay.J_max, lay.T), np.int32)
        n_t = np.zeros((lay.T,), np.int64)
        w_idx, b_idx, l_idx = np.nonzero(lay.tok_valid)
        zz = z[w_idx, b_idx, l_idx]
        np.add.at(n_td, (w_idx, lay.tok_doc[w_idx, b_idx, l_idx], zz), 1)
        np.add.at(n_wt, (b_idx, lay.tok_wrd[w_idx, b_idx, l_idx], zz), 1)
        np.add.at(n_t, zz, 1)

        put = lambda a, sh: jax.device_put(a, sh)
        arrays = dict(
            tok_doc=put(lay.tok_doc, self._sh_tok),
            tok_wrd=put(lay.tok_wrd, self._sh_tok),
            tok_valid=put(lay.tok_valid, self._sh_tok),
            tok_bound=put(lay.tok_bound, self._sh_tok),
            z=put(z, self._sh_tok),
            n_td=put(n_td, self._sh_tok),
            n_wt=put(n_wt, self._sh_tok),
            n_t=put(n_t.astype(np.int32), self._sh_rep),
        )
        return arrays

    def sweep(self, arrays: dict, seed: int) -> dict:
        z, n_td, n_wt, n_t = self._sweep(
            arrays["tok_doc"], arrays["tok_wrd"], arrays["tok_valid"],
            arrays["tok_bound"], arrays["z"], arrays["n_td"],
            arrays["n_wt"], arrays["n_t"], jnp.int32(seed))
        out = dict(arrays)
        out.update(z=z, n_td=n_td, n_wt=n_wt, n_t=n_t)
        return out

    # -- evaluation -----------------------------------------------------------
    def log_likelihood(self, arrays: dict) -> float:
        """Joint LL from the padded sharded tables (pad rows contribute 0)."""
        from jax.scipy.special import gammaln
        lay = self.layout
        T, J = lay.T, lay.num_words
        alpha, beta = self.alpha, self.beta
        n_td = arrays["n_td"].astype(F32)            # (W,I_max,T) padded
        n_wt = arrays["n_wt"].astype(F32)            # (B,J_max,T) padded
        n_t = arrays["n_t"].astype(F32)
        n_i = n_td.sum(axis=2)                       # (W,I_max)
        is_doc = jnp.asarray(self.layout.doc_of_worker >= 0)
        I = int(is_doc.sum())
        doc_part = (I * (gammaln(T * alpha) - T * gammaln(alpha))
                    - jnp.where(is_doc, gammaln(T * alpha + n_i), 0.0).sum()
                    + gammaln(alpha + n_td).sum()
                    - (~is_doc).sum() * T * gammaln(jnp.float32(alpha)))
        topic_part = (T * (gammaln(J * beta) - J * gammaln(beta))
                      - gammaln(J * beta + n_t).sum()
                      + gammaln(beta + n_wt).sum()
                      - (lay.B * lay.J_max - J) * T * gammaln(jnp.float32(beta)))
        return float(doc_part + topic_part)

    def global_counts(self, arrays: dict):
        """Gather compact global (n_td, n_wt, n_t) for validation."""
        lay = self.layout
        n_td_p = np.asarray(arrays["n_td"])
        n_wt_p = np.asarray(arrays["n_wt"])
        I = int((lay.doc_of_worker >= 0).sum())
        J = lay.num_words
        n_td = np.zeros((I, lay.T), np.int64)
        for w in range(lay.W):
            ids = lay.doc_of_worker[w]
            m = ids >= 0
            n_td[ids[m]] = n_td_p[w, m]
        n_wt = np.zeros((J, lay.T), np.int64)
        for b in range(lay.B):
            ids = lay.word_of_block[b]
            m = ids >= 0
            n_wt[ids[m]] = n_wt_p[b, m]
        return n_td, n_wt, np.asarray(arrays["n_t"], np.int64)
