"""F+tree: the paper's O(log T) multinomial sampling structure (paper §3.1).

The F+tree is a complete binary tree over the ``T`` unnormalized multinomial
parameters ``p`` (leaves), where every internal node stores the sum of its two
children and the root stores the normalizer ``Σ_t p_t``.  It is represented
heap-style in a flat array ``F`` of length ``2T``:

    F[0]        unused (kept 0)
    F[1]        root = Σ p
    F[i]        internal node, children at 2i and 2i+1
    F[T + t]    leaf t, stores p_t          (t = 0..T-1)

Operations (all pure, jit/vmap/scan friendly):

    build(p)          Θ(T)        construct from parameters
    total(F)          Θ(1)        normalizer  (= F[1])
    sample(F, u01)    Θ(log T)    inverse-CDF draw, top-down traversal (Alg. 1)
    update(F, t, δ)   Θ(log T)    p_t += δ, bottom-up path add      (Alg. 2)
    leaves(F)         Θ(1)        view of p
    set_leaf(F,t,v)   Θ(log T)    p_t = v  (update with δ = v - p_t)

``T`` must be a power of two (paper's simplifying assumption); :func:`pad_pow2`
zero-pads arbitrary ``p``.  Zero-probability leaves are never returned by
``sample`` provided no negative leaves exist: the traversal refuses to enter
a zero-mass right subtree, so even ``u01`` so close to 1 that ``u01 * F[1]``
rounds up to ``F[1]`` in f32 (easy at large totals) lands on the last
positive leaf instead of falling off the right edge onto padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "build",
    "depth",
    "leaves",
    "pad_pow2",
    "sample",
    "sample_batch",
    "set_leaf",
    "total",
    "update",
    "update_batch",
]


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def depth(T: int) -> int:
    """Tree depth = number of traversal steps = log2(T)."""
    if not _is_pow2(T):
        raise ValueError(f"F+tree size must be a power of two, got {T}")
    return T.bit_length() - 1


def pad_pow2(p: jax.Array) -> jax.Array:
    """Zero-pad the last axis of ``p`` up to the next power of two."""
    T = p.shape[-1]
    Tp = 1 << max(0, (T - 1).bit_length())
    if Tp == T:
        return p
    pad = [(0, 0)] * (p.ndim - 1) + [(0, Tp - T)]
    return jnp.pad(p, pad)


def build(p: jax.Array) -> jax.Array:
    """Construct an F+tree from unnormalized parameters ``p`` (paper eq. (3)).

    Works on the last axis; leading axes are batch.  Θ(T) work, built level by
    level with pairwise sums (vectorized — the paper's reverse-index loop).
    """
    T = p.shape[-1]
    if not _is_pow2(T):
        raise ValueError(f"F+tree size must be a power of two, got {T} "
                         "(use pad_pow2)")
    levels = [p]
    cur = p
    while cur.shape[-1] > 1:
        cur = cur.reshape(*cur.shape[:-1], cur.shape[-1] // 2, 2).sum(-1)
        levels.append(cur)
    zero = jnp.zeros_like(p[..., :1])
    return jnp.concatenate([zero] + levels[::-1], axis=-1)


def total(F: jax.Array) -> jax.Array:
    """Normalizer Σ_t p_t — stored at the root."""
    return F[..., 1]


def leaves(F: jax.Array) -> jax.Array:
    """The parameter vector ``p`` (leaf values)."""
    T = F.shape[-1] // 2
    return F[..., T:]


def sample(F: jax.Array, u01: jax.Array) -> jax.Array:
    """Draw ``z = min{t : Σ_{s≤t} p_s > u}`` with ``u = u01 * F[1]`` (Alg. 1).

    ``F`` is a single tree (1-D); use :func:`sample_batch`/vmap for batches.
    Θ(log T): one gather + select per level.

    Edge guard: descending right additionally requires the right subtree to
    hold positive mass.  Without it, ``u = u01 * F[1]`` can round up to
    ``F[1]`` exactly (f32, large totals) and the walk marches off the right
    edge onto a zero-probability padded leaf.
    """
    T = F.shape[-1] // 2
    d = depth(T)
    u = u01 * F[1]

    def step(_, carry):
        i, u = carry
        left = F[2 * i]
        go_right = (u >= left) & (F[2 * i + 1] > 0)
        i = 2 * i + go_right.astype(i.dtype)
        u = jnp.where(go_right, u - left, u)
        return i, u

    i0 = jnp.asarray(1, dtype=jnp.int32)
    i, _ = lax.fori_loop(0, d, step, (i0, u))
    return i - T


@functools.partial(jax.jit, static_argnames=())
def sample_batch(F: jax.Array, u01: jax.Array) -> jax.Array:
    """Vectorized draws from one tree: ``u01`` is any-shape uniforms in [0,1).

    Same zero-mass-right-subtree guard as :func:`sample`.
    """
    T = F.shape[-1] // 2
    d = depth(T)
    u = u01 * F[1]
    i = jnp.ones_like(u, dtype=jnp.int32)

    def step(_, carry):
        i, u = carry
        left = F[2 * i]
        go_right = (u >= left) & (F[2 * i + 1] > 0)
        i = 2 * i + go_right.astype(i.dtype)
        u = jnp.where(go_right, u - left, u)
        return i, u

    i, _ = lax.fori_loop(0, d, step, (i, u))
    return i - T


def _path_indices(T: int, t: jax.Array) -> jax.Array:
    """Heap indices of leaf t and all its ancestors (incl. root), shape (d+1,)."""
    d = depth(T)
    node = t + T
    shifts = jnp.arange(d + 1, dtype=jnp.int32)
    return (node[..., None] >> shifts).astype(jnp.int32)


def update(F: jax.Array, t: jax.Array, delta: jax.Array) -> jax.Array:
    """``p_t += delta``: add ``delta`` to leaf t and every ancestor (Alg. 2)."""
    T = F.shape[-1] // 2
    idx = _path_indices(T, jnp.asarray(t))
    return F.at[idx].add(jnp.broadcast_to(delta, idx.shape).astype(F.dtype))


def update_batch(F: jax.Array, ts: jax.Array, deltas: jax.Array) -> jax.Array:
    """Batched updates ``p_{ts[k]} += deltas[k]``; duplicate paths accumulate."""
    T = F.shape[-1] // 2
    idx = _path_indices(T, ts)                      # (..., d+1)
    vals = jnp.broadcast_to(deltas[..., None], idx.shape).astype(F.dtype)
    return F.at[idx.reshape(-1)].add(vals.reshape(-1))


def set_leaf(F: jax.Array, t: jax.Array, value: jax.Array) -> jax.Array:
    """``p_t = value`` — the Alg. 3 form ``F.update(t, v - F[leaf(t)])``."""
    T = F.shape[-1] // 2
    cur = F[..., T + t]
    return update(F, t, value - cur)
