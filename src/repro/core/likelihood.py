"""Model-quality evaluation: training log-likelihood (paper §5, "we use the
same training likelihood routine ... see eq. (2) in [16]").

The collapsed joint likelihood of a CGS state (Griffiths & Steyvers):

    log p(w, z | α, β) =
        Σ_i [ logΓ(Tα) − logΓ(Tα + n_i)  + Σ_t ( logΓ(α + n_td) − logΓ(α) ) ]
      + Σ_t [ logΓ(Jβ) − logΓ(Jβ + n_t) + Σ_w ( logΓ(β + n_wt) − logΓ(β) ) ]

computed densely from the count tables (Θ((I+J)·T)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

__all__ = ["log_likelihood", "per_token_ll"]


@jax.jit
def _ll(n_td, n_wt, n_t, alpha, beta):
    I, T = n_td.shape
    J = n_wt.shape[0]
    n_td = n_td.astype(jnp.float32)
    n_wt = n_wt.astype(jnp.float32)
    n_t = n_t.astype(jnp.float32)
    n_i = n_td.sum(axis=1)

    doc_part = (I * (gammaln(T * alpha) - T * gammaln(alpha))
                - gammaln(T * alpha + n_i).sum()
                + gammaln(alpha + n_td).sum())
    topic_part = (T * (gammaln(J * beta) - J * gammaln(beta))
                  - gammaln(J * beta + n_t).sum()
                  + gammaln(beta + n_wt).sum())
    return doc_part + topic_part


def log_likelihood(state, alpha: float, beta: float) -> float:
    """Joint log p(w, z) of an :class:`repro.core.cgs.LDAState`."""
    return float(_ll(state.n_td, state.n_wt, state.n_t,
                     jnp.float32(alpha), jnp.float32(beta)))


def per_token_ll(state, alpha: float, beta: float) -> float:
    n_tokens = int(state.n_t.sum())
    return log_likelihood(state, alpha, beta) / max(n_tokens, 1)
