"""SparseLDA baseline (Yao, Mimno, McCallum — paper §3.3).

Three-term decomposition of the CGS conditional, doc-by-doc order:

    p_t = αβ/(n_t+β̄)  +  β·n_td/(n_t+β̄)  +  n_wt·(n_td+α)/(n_t+β̄)
          └─ smoothing ─┘  └─ doc-sparse ──┘  └──── word-sparse ─────┘

LSearch is used for all three buckets (as in Mallet / Yahoo!LDA): draw
u ~ U[0, s+r+q_mass); if u lands in the word bucket walk the |T_w| nonzeros,
else the |T_d| nonzeros, else the dense smoothing term.

Exact sampler — same conditional as the reference sweep; implemented as a
scan with dense vector arithmetic (see DESIGN.md §3 on the VPU trade), with
the bucket logic preserved so the benchmark can count bucket hit rates (the
paper's argument for why LSearch suffices rests on the word bucket absorbing
most of the mass).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.cgs import LDAState
from repro.core.samplers import lsearch_guarded

__all__ = ["sweep_sparse_lda"]


def sweep_sparse_lda(state: LDAState, doc_ids, word_ids, order,
                     alpha: float, beta: float,
                     return_bucket_stats: bool = False):
    """One exact doc-by-doc SparseLDA sweep. Optionally returns per-token
    bucket choice (0=smoothing, 1=doc, 2=word) for Table-2 style analysis."""
    beta_bar = beta * state.n_wt.shape[0]
    key, sweep_key = jax.random.split(state.key)
    u = jax.random.uniform(sweep_key, (order.shape[0],))
    f32 = jnp.float32

    def step(carry, inp):
        z, n_td, n_wt, n_t = carry
        k, u01 = inp
        d, w, t_old = doc_ids[k], word_ids[k], z[k]
        n_td = n_td.at[d, t_old].add(-1)
        n_wt = n_wt.at[w, t_old].add(-1)
        n_t = n_t.at[t_old].add(-1)

        denom = n_t.astype(f32) + beta_bar
        s_vec = (alpha * beta) / denom                     # dense smoothing
        r_vec = beta * n_td[d].astype(f32) / denom         # |T_d|-sparse
        q_vec = (n_wt[w].astype(f32)
                 * (n_td[d].astype(f32) + alpha) / denom)  # |T_w|-sparse
        s_mass, r_mass, q_mass = s_vec.sum(), r_vec.sum(), q_vec.sum()
        u_val = u01 * (s_mass + r_mass + q_mass)

        # Bucket dispatch (SparseLDA order: word bucket checked first).
        in_q = u_val < q_mass
        in_r = (~in_q) & (u_val < q_mass + r_mass)
        # Guarded LSearch within each bucket: the bucket masses are .sum()
        # reductions but the walk is over cumsum(vec) — different float
        # reductions that disagree on mixed-magnitude vectors — so a draw
        # the dispatch assigns to a bucket can overrun that bucket's cumsum
        # (the old dense clip to T-1 then selected topic T-1 regardless of
        # its mass).  lsearch_guarded pins such draws to the bucket's last
        # positive-mass topic instead, keeping the draw in-support and the
        # bucket stats consistent with the dispatch.
        t_from = lambda vec, uu: lsearch_guarded(jnp.cumsum(vec), uu)
        t_new = jnp.where(
            in_q, t_from(q_vec, u_val),
            jnp.where(in_r, t_from(r_vec, u_val - q_mass),
                      t_from(s_vec, u_val - q_mass - r_mass)))
        bucket = jnp.where(in_q, 2, jnp.where(in_r, 1, 0)).astype(jnp.int32)

        n_td = n_td.at[d, t_new].add(1)
        n_wt = n_wt.at[w, t_new].add(1)
        n_t = n_t.at[t_new].add(1)
        z = z.at[k].set(t_new)
        return (z, n_td, n_wt, n_t), bucket

    (z, n_td, n_wt, n_t), buckets = lax.scan(
        step, (state.z, state.n_td, state.n_wt, state.n_t), (order, u))
    new = LDAState(z=z, n_td=n_td, n_wt=n_wt, n_t=n_t, key=key)
    if return_bucket_stats:
        return new, buckets
    return new
