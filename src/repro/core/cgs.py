"""Collapsed Gibbs Sampling for LDA (paper §2.1, §3.2).

State layout (the paper's count tables, eq. (1)):

    z     (N,)   int32  current topic assignment per occurrence
    n_td  (I,T)  int32  doc-topic counts        (paper n_{t,d,*}; node d_i)
    n_wt  (J,T)  int32  word-topic counts       (paper n_{t,*,w}; node w_j)
    n_t   (T,)   int32  global topic counts     (paper n_{t,*,*}; node s)

Sweeps (all exact CGS — they sample from the same conditional (2)):

    sweep_reference   dense vectorized conditional, any token order — the
                      oracle every other implementation is tested against.
    sweep_fplda_word  Algorithm 3: word-by-word order, p = α·q + r with
                      q_t=(n_wt+β)/(n_t+β̄) kept in an F+tree (O(log T)
                      maintenance) and r_t=n_td·q_t drawn by BSearch.
    sweep_fplda_doc   the doc-by-doc twin (decomposition (4)).

All sweeps run as a single ``lax.scan`` over occurrences inside jit: the
Gibbs chain is honoured exactly (each step sees all previous updates).

TPU adaptation note (DESIGN.md §3): the r-term and boundary rebuilds are
computed as dense length-T vector ops (VPU-friendly); the O(log T) F+tree
path operations are kept for the q-term exactly as in Alg. 3, and the
abstract op-count accounting (what Table 1/2 claim) is reported by
``benchmarks/sampler_bench.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import ftree
from repro.data.corpus import Corpus

__all__ = [
    "LDAState", "init_state", "counts_from_assignments", "check_invariants",
    "sweep_reference", "sweep_fplda_word", "sweep_fplda_doc",
    "conditional_probs", "state_to_checkpoint", "state_from_checkpoint",
]


class LDAState(NamedTuple):
    z: jax.Array       # (N,)  int32
    n_td: jax.Array    # (I,T) int32
    n_wt: jax.Array    # (J,T) int32
    n_t: jax.Array     # (T,)  int32
    key: jax.Array     # PRNG key for the chain


def counts_from_assignments(doc_ids, word_ids, z, I, J, T):
    """Rebuild the three count tables from z (Θ(N) segment sums)."""
    z = z.astype(jnp.int32)
    n_td = jnp.zeros((I, T), jnp.int32).at[doc_ids, z].add(1)
    n_wt = jnp.zeros((J, T), jnp.int32).at[word_ids, z].add(1)
    n_t = jnp.zeros((T,), jnp.int32).at[z].add(1)
    return n_td, n_wt, n_t


def init_state(corpus: Corpus, T: int, key: jax.Array) -> LDAState:
    """Random uniform topic init — the standard CGS start."""
    key, sub = jax.random.split(key)
    z = jax.random.randint(sub, (corpus.num_tokens,), 0, T, dtype=jnp.int32)
    doc_ids = jnp.asarray(corpus.doc_ids)
    word_ids = jnp.asarray(corpus.word_ids)
    n_td, n_wt, n_t = counts_from_assignments(
        doc_ids, word_ids, z, corpus.num_docs, corpus.num_words, T)
    return LDAState(z=z, n_td=n_td, n_wt=n_wt, n_t=n_t, key=key)


def state_to_checkpoint(state: LDAState) -> dict[str, np.ndarray]:
    """Flatten a serial chain state for :func:`repro.train.checkpoint.
    save_chain`.  The typed PRNG key is stored via ``key_data`` so the
    split/fold sequence resumes bit-exactly."""
    return {
        "z": np.asarray(state.z),
        "n_td": np.asarray(state.n_td),
        "n_wt": np.asarray(state.n_wt),
        "n_t": np.asarray(state.n_t),
        "key_data": np.asarray(jax.random.key_data(state.key)),
    }


def state_from_checkpoint(d: dict[str, np.ndarray]) -> LDAState:
    """Inverse of :func:`state_to_checkpoint`."""
    return LDAState(
        z=jnp.asarray(d["z"], jnp.int32),
        n_td=jnp.asarray(d["n_td"], jnp.int32),
        n_wt=jnp.asarray(d["n_wt"], jnp.int32),
        n_t=jnp.asarray(d["n_t"], jnp.int32),
        key=jax.random.wrap_key_data(jnp.asarray(d["key_data"])))


def check_invariants(state: LDAState, corpus: Corpus) -> dict:
    """Count-table consistency (DESIGN.md §8). Returns violation counts."""
    I, T = state.n_td.shape
    J = state.n_wt.shape[0]
    n_td, n_wt, n_t = counts_from_assignments(
        jnp.asarray(corpus.doc_ids), jnp.asarray(corpus.word_ids),
        state.z, I, J, T)
    return {
        "n_td_mismatch": int(jnp.abs(n_td - state.n_td).sum()),
        "n_wt_mismatch": int(jnp.abs(n_wt - state.n_wt).sum()),
        "n_t_mismatch": int(jnp.abs(n_t - state.n_t).sum()),
        "negatives": int((state.n_td < 0).sum() + (state.n_wt < 0).sum()
                         + (state.n_t < 0).sum()),
        "z_range": int(((state.z < 0) | (state.z >= T)).sum()),
    }


def conditional_probs(n_td_row, n_wt_row, n_t, alpha, beta, beta_bar):
    """Unnormalized CGS conditional p_t (paper eq. (2)/(4))."""
    return ((n_td_row.astype(jnp.float32) + alpha)
            * (n_wt_row.astype(jnp.float32) + beta)
            / (n_t.astype(jnp.float32) + beta_bar))


def _inverse_cdf_draw(p: jax.Array, u01: jax.Array) -> jax.Array:
    """z = min{t : cumsum(p)_t > u01 * Σp} — the LSearch/BSearch reference."""
    c = jnp.cumsum(p)
    u = u01 * c[-1]
    return jnp.sum(c <= u).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Reference sweep — dense conditional, exact chain, any order.
# ---------------------------------------------------------------------------
def sweep_reference(state: LDAState, doc_ids, word_ids, order,
                    alpha: float, beta: float) -> LDAState:
    """One full Gibbs sweep over `order`; the pure-jnp oracle (Θ(N·T))."""
    T = state.n_t.shape[0]
    beta_bar = beta * state.n_wt.shape[0]
    key, sweep_key = jax.random.split(state.key)
    u = jax.random.uniform(sweep_key, (order.shape[0],))

    def step(carry, inp):
        z, n_td, n_wt, n_t = carry
        k, u01 = inp
        d, w, t_old = doc_ids[k], word_ids[k], z[k]
        n_td = n_td.at[d, t_old].add(-1)
        n_wt = n_wt.at[w, t_old].add(-1)
        n_t = n_t.at[t_old].add(-1)
        p = conditional_probs(n_td[d], n_wt[w], n_t, alpha, beta, beta_bar)
        t_new = _inverse_cdf_draw(p, u01)
        n_td = n_td.at[d, t_new].add(1)
        n_wt = n_wt.at[w, t_new].add(1)
        n_t = n_t.at[t_new].add(1)
        z = z.at[k].set(t_new)
        return (z, n_td, n_wt, n_t), None

    (z, n_td, n_wt, n_t), _ = lax.scan(
        step, (state.z, state.n_td, state.n_wt, state.n_t), (order, u))
    return LDAState(z=z, n_td=n_td, n_wt=n_wt, n_t=n_t, key=key)


# ---------------------------------------------------------------------------
# F+LDA word-by-word — Algorithm 3.
# ---------------------------------------------------------------------------
def sweep_fplda_word(state: LDAState, doc_ids, word_ids, order, boundary,
                     alpha: float, beta: float, *, backend: str = "scan",
                     interpret: bool | None = None,
                     r_mode: str = "dense",
                     r_cap: int | None = None) -> LDAState:
    """Paper Algorithm 3.  Tokens arrive sorted by word; ``boundary[k]`` marks
    the first occurrence of a new vocabulary item.

    Decomposition (5): p_t = α·q_t + r_t,  q_t=(n_wt+β)/(n_t+β̄),  r_t=n_td·q_t.
    The F+tree carries q; per-token maintenance is two O(log T) ``set_leaf``
    calls (the Alg. 3 F.update lines).  At a word boundary the tree is rebuilt
    for the incoming word — the dense-vectorized form of the paper's
    ``F.update(t, ±n_tw/(n_t+β̄)) ∀t∈T_w`` enter/exit updates (equal result;
    DESIGN.md §3 explains the VPU trade).

    ``backend`` selects the implementation of the hot loop:
        "scan"   — one ``lax.scan`` over occurrences
                   (:func:`repro.kernels.fused_sweep.ref.fused_sweep_ref`).
        "fused"  — the single-``pallas_call`` kernel in
                   :mod:`repro.kernels.fused_sweep`, which keeps the F+tree
                   and count tables VMEM-resident (DESIGN.md §7).  Same
                   chain bit-for-bit; ``interpret=None`` (default) compiles
                   on TPU and runs the CPU-safe interpreter elsewhere.
                   ``alpha``/``beta`` are baked into the
                   kernel as static values, so they must be concrete
                   Python floats (not traced), and each distinct value
                   compiles its own kernel.

    ``r_mode`` selects the r-bucket draw (:mod:`..kernels.fused_sweep.rbucket`):
    ``"dense"`` recomputes the compacted topic vector from the ``n_td`` row
    per token, ``"sparse"`` maintains per-doc side tables — bit-identical
    chains, so this sweep rebuilds the tables from ``n_td`` each call and
    drops them afterwards (state stays the 5-field :class:`LDAState`).
    ``r_cap`` is the compaction capacity (default ``T``; chain-affecting —
    compared runs must share it).
    """
    T = state.n_t.shape[0]
    Tp = 1 << (T - 1).bit_length()
    if Tp != T:
        raise ValueError("T must be a power of two for the F+tree sweep")
    beta_bar = beta * state.n_wt.shape[0]
    key, sweep_key = jax.random.split(state.key)
    u = jax.random.uniform(sweep_key, (order.shape[0],))

    if backend == "fused":
        from repro.kernels.fused_sweep import (default_interpret,
                                               fused_sweep_tokens)
        if interpret is None:
            interpret = default_interpret()
        sweep = functools.partial(fused_sweep_tokens, interpret=interpret,
                                  r_mode=r_mode, r_cap=r_cap)
    elif backend == "scan":
        # The masked per-token chain (Alg. 3 inner loop: boundary rebuild,
        # decrement, F.update, q/r two-level draw, increment, F.update) is
        # defined once, in repro.kernels.fused_sweep.ref — the oracle both
        # backends and the nomad cell sweep share, so the float-op order
        # has a single source of truth.
        from repro.kernels.fused_sweep.ref import fused_sweep_ref
        sweep = functools.partial(fused_sweep_ref, r_mode=r_mode, r_cap=r_cap)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    valid = jnp.ones(order.shape[0], jnp.int32)
    # Token 0 starts its word's run by definition; forcing the flag keeps
    # the zero-initialized tree safe for boundary vectors that don't mark
    # position 0 (equivalent to the former unconditional F0 prebuild).
    boundary = jnp.asarray(boundary).at[0].set(True)
    # Sparse mode returns the side tables appended (a 7-tuple); they are
    # derivable from n_td, so this per-sweep API drops them.
    out = sweep(
        doc_ids[order], word_ids[order], valid, boundary,
        state.z[order], u, state.n_td, state.n_wt, state.n_t,
        alpha=alpha, beta=beta, beta_bar=beta_bar)
    z_new, n_td, n_wt, n_t = out[0], out[1], out[2], out[3]
    z = state.z.at[order].set(z_new)
    return LDAState(z=z, n_td=n_td, n_wt=n_wt, n_t=n_t, key=key)


# ---------------------------------------------------------------------------
# F+LDA doc-by-doc — decomposition (4).
# ---------------------------------------------------------------------------
def sweep_fplda_doc(state: LDAState, doc_ids, word_ids, order, boundary,
                    alpha: float, beta: float) -> LDAState:
    """Doc-by-doc F+LDA: p_t = β·q_t + r_t with q_t=(n_td+α)/(n_t+β̄) in the
    F+tree and r_t = n_wt·q_t drawn by BSearch.  ``boundary`` marks the first
    token of each document."""
    T = state.n_t.shape[0]
    beta_bar = beta * state.n_wt.shape[0]
    key, sweep_key = jax.random.split(state.key)
    u = jax.random.uniform(sweep_key, (order.shape[0],))
    f32 = jnp.float32

    def q_dense(n_td_row, n_t):
        return (n_td_row.astype(f32) + alpha) / (n_t.astype(f32) + beta_bar)

    F0 = ftree.build(q_dense(state.n_td[doc_ids[order[0]]], state.n_t))

    def step(carry, inp):
        z, n_td, n_wt, n_t, F = carry
        k, u01, is_boundary = inp
        d, w, t_old = doc_ids[k], word_ids[k], z[k]

        F = lax.cond(is_boundary,
                     lambda: ftree.build(q_dense(n_td[d], n_t)),
                     lambda: F)

        n_td = n_td.at[d, t_old].add(-1)
        n_wt = n_wt.at[w, t_old].add(-1)
        n_t = n_t.at[t_old].add(-1)
        F = ftree.set_leaf(F, t_old,
                           (n_td[d, t_old].astype(f32) + alpha)
                           / (n_t[t_old].astype(f32) + beta_bar))

        q = ftree.leaves(F)
        r = n_wt[w].astype(f32) * q
        c = jnp.cumsum(r)
        r_mass = c[-1]
        norm = beta * ftree.total(F) + r_mass
        u_scaled = u01 * norm
        in_r = u_scaled < r_mass
        t_r = jnp.sum(c <= u_scaled).astype(jnp.int32)
        t_q = ftree.sample(F, jnp.clip((u_scaled - r_mass)
                                       / (beta * ftree.total(F)),
                                       0.0, 1.0 - 1e-7))
        t_new = jnp.where(in_r, t_r, t_q)

        n_td = n_td.at[d, t_new].add(1)
        n_wt = n_wt.at[w, t_new].add(1)
        n_t = n_t.at[t_new].add(1)
        F = ftree.set_leaf(F, t_new,
                           (n_td[d, t_new].astype(f32) + alpha)
                           / (n_t[t_new].astype(f32) + beta_bar))
        z = z.at[k].set(t_new)
        return (z, n_td, n_wt, n_t, F), None

    carry0 = (state.z, state.n_td, state.n_wt, state.n_t, F0)
    (z, n_td, n_wt, n_t, _), _ = lax.scan(
        step, carry0, (order, u, boundary))
    return LDAState(z=z, n_td=n_td, n_wt=n_wt, n_t=n_t, key=key)
