"""Core: the paper's contribution — F+tree sampling and Nomad-distributed CGS."""
from repro.core import ftree  # noqa: F401
from repro.core.cgs import (  # noqa: F401
    LDAState, counts_from_assignments, init_state,
    sweep_fplda_doc, sweep_fplda_word, sweep_reference,
)
from repro.core.likelihood import log_likelihood, per_token_ll  # noqa: F401
