"""Held-out evaluation and fold-in inference: the φ-frozen Gibbs primitives.

The paper evaluates training log-likelihood (§5, following Yahoo!LDA); the
standard complementary check in the LDA literature is document completion:
hold out a set of documents, estimate each held-out document's θ from the
first half of its tokens (Gibbs with the trained φ frozen), then score the
second half:

    perplexity = exp( − Σ log p(w | θ̂, φ̂) / N_second_half )

φ̂ is the posterior mean from the trained counts:
    φ̂_tw = (n_wt + β) / (n_t + Jβ)
θ̂ from the fold-in counts:  θ̂_dt = (n_td + α) / (n_d + Tα).

The same φ-frozen fold-in is the *serving* algorithm (DESIGN.md §10): an
incoming document's θ is exactly a fold-in against a published φ snapshot.
Two implementations share one chain:

* :func:`fold_in` — the serial reference: a flat ``(word_ids, doc_ids)``
  token list, one ``lax.scan`` over all tokens.
* :func:`fold_in_batch` — the serving hot path: a padded ``(D, L)`` doc
  batch swept by one vmapped multi-sweep kernel
  (``repro.serve.lda_engine`` batches requests into it).

**RNG contract (what makes them bit-identical per document):** every draw
is counter-mode per (document stream, position-in-document[, sweep]) —
``doc_fold_key(key, d)`` names document ``d``'s stream, and within it
position ``p``'s init assignment and sweep-``k`` uniform are derived by
``fold_in`` chains, never by array-shaped draws.  A document's chain
therefore depends only on its own stream key and its own tokens — not on
the batch it rides in, the padding around it, or the other documents in a
flat serial call — so a batched padded row reproduces the serial path
bit-for-bit (``tests/test_serving.py`` pins this, hypothesis-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.samplers import lsearch_guarded
from repro.data.corpus import Corpus

__all__ = ["document_completion_perplexity", "fold_in", "fold_in_batch",
           "doc_fold_key", "theta_from_counts"]

# Role indices of the two per-document RNG sub-streams.
_ROLE_INIT = 0    # initial z assignments
_ROLE_SWEEP = 1   # per-sweep LSearch uniforms


def _phi_hat(n_wt, n_t, beta):
    J = n_wt.shape[0]
    return ((n_wt.astype(jnp.float32) + beta)
            / (n_t.astype(jnp.float32)[None, :] + J * beta))  # (J,T)


def doc_fold_key(key, d):
    """Document ``d``'s fold-in RNG stream under ``key``.

    :func:`fold_in` derives it internally as ``fold_in(key, doc_id)``; a
    :func:`fold_in_batch` row keyed with ``doc_fold_key(key, d)`` runs the
    bit-identical chain to serial document ``d`` under ``key`` — the
    contract the serving engine uses to stay provably exact.
    """
    return jax.random.fold_in(key, d)


def theta_from_counts(n_td, alpha):
    """Posterior-mean θ rows from fold-in counts: (n+α)/(Σn+Tα).

    Shared by the perplexity path and the serving engine so their float
    ops agree bit-for-bit on equal counts.  All-zero rows (empty
    documents) come out uniform 1/T.
    """
    T = n_td.shape[-1]
    n_d = n_td.sum(-1, keepdims=True)
    return ((n_td.astype(jnp.float32) + alpha)
            / (n_d.astype(jnp.float32) + T * alpha))


def _positions_in_doc(doc_ids: np.ndarray) -> np.ndarray:
    """Occurrence rank of each token within its document (host-side).

    Stable in input order for any interleaving: token i's position is the
    number of earlier tokens with the same doc id.
    """
    n = doc_ids.shape[0]
    order = np.argsort(doc_ids, kind="stable")
    sorted_ids = doc_ids[order]
    idx = np.arange(n, dtype=np.int32)
    is_start = np.ones(n, bool)
    is_start[1:] = sorted_ids[1:] != sorted_ids[:-1]
    start = np.maximum.accumulate(np.where(is_start, idx, 0))
    pos = np.empty(n, np.int32)
    pos[order] = idx - start
    return pos


def _validate_fold_in(word_ids, doc_ids, num_docs, num_words):
    """Explicit ValueErrors (mirroring ``data/corpus.py``): fold-in inputs
    arrive from serving requests and held-out splits, not just code."""
    d, w = np.asarray(doc_ids), np.asarray(word_ids)
    if d.ndim != 1 or d.shape != w.shape:
        raise ValueError(
            f"word_ids/doc_ids must be 1-D parallel arrays; got shapes "
            f"{w.shape} and {d.shape}")
    if num_docs < 1:
        raise ValueError(
            f"fold_in needs num_docs >= 1, got {num_docs} (an empty "
            f"fold-in corpus has no θ to estimate)")
    if d.size == 0:
        raise ValueError(
            "fold_in got an empty token list; a document with no tokens "
            "is served by fold_in_batch as an all-False mask row (its θ "
            "is the uniform α prior), not by the serial path")
    if int(d.min()) < 0 or int(d.max()) >= num_docs:
        raise ValueError(
            f"doc_ids out of range [0, {num_docs}): "
            f"[{d.min()}, {d.max()}]")
    if int(w.min()) < 0 or int(w.max()) >= num_words:
        raise ValueError(
            f"word_ids out of range [0, {num_words}) (φ has {num_words} "
            f"rows): [{w.min()}, {w.max()}]")


def _fold_in_core(word_ids, doc_ids, pos, phi, alpha, key, *,
                  num_docs: int, sweeps: int):
    """Jittable serial fold-in body (validation and position ranking live
    in :func:`fold_in`; harnesses jit this directly for repeated
    fixed-shape reference runs)."""
    T = phi.shape[1]
    dk = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, doc_ids)
    ik = jax.vmap(jax.random.fold_in, in_axes=(0, None))(dk, _ROLE_INIT)
    ik = jax.vmap(jax.random.fold_in)(ik, pos)
    z = jax.vmap(
        lambda kk: jax.random.randint(kk, (), 0, T, dtype=jnp.int32))(ik)
    n_td = jnp.zeros((num_docs, T), jnp.int32).at[doc_ids, z].add(1)
    sk = jax.vmap(jax.random.fold_in, in_axes=(0, None))(dk, _ROLE_SWEEP)
    N = word_ids.shape[0]

    def sweep(carry, k):
        z, n_td = carry
        uk = jax.vmap(jax.random.fold_in, in_axes=(0, None))(sk, k)
        uk = jax.vmap(jax.random.fold_in)(uk, pos)
        u = jax.vmap(jax.random.uniform)(uk)

        def step(c, inp):
            z, n_td = c
            i, u01 = inp
            d, w, t_old = doc_ids[i], word_ids[i], z[i]
            n_td = n_td.at[d, t_old].add(-1)
            p = (n_td[d].astype(jnp.float32) + alpha) * phi[w]
            cdf = jnp.cumsum(p)
            # Guarded LSearch: u01·cdf[-1] shares the cumsum reduction, so
            # overrun needs u01·M to round up to M — impossible for
            # u01 ≤ 1−2⁻²⁴ f32 — but the guard also covers all-zero φ
            # rows, where a clip would silently select topic T−1 with
            # zero mass.
            t_new = lsearch_guarded(cdf, u01 * cdf[-1])
            n_td = n_td.at[d, t_new].add(1)
            z = z.at[i].set(t_new)
            return (z, n_td), None

        (z, n_td), _ = lax.scan(step, (z, n_td),
                                (jnp.arange(N, dtype=jnp.int32), u))
        return (z, n_td), None

    (z, n_td), _ = lax.scan(sweep, (z, n_td),
                            jnp.arange(sweeps, dtype=jnp.int32))
    return n_td


def fold_in(word_ids, doc_ids, num_docs, phi, alpha, key, sweeps: int = 20):
    """Gibbs fold-in with φ frozen: sample z for held-out tokens, return
    per-doc topic counts.  word_ids/doc_ids: (N,) flat token list (any
    interleaving; within-document order is the chain order).

    Raises ``ValueError`` on an empty token list, ``num_docs < 1``, or
    out-of-range ids — serving requests and held-out splits must fail
    loudly, not fold garbage (mirrors ``data/corpus.py`` validation).

    RNG: each document runs its own counter-mode stream (see the module
    docstring), so per-document results are independent of the other
    documents in the call and bit-reproducible by :func:`fold_in_batch`.
    """
    _validate_fold_in(word_ids, doc_ids, num_docs, phi.shape[0])
    pos = jnp.asarray(_positions_in_doc(np.asarray(doc_ids)))
    return _fold_in_core(jnp.asarray(word_ids), jnp.asarray(doc_ids), pos,
                         phi, alpha, key, num_docs=int(num_docs),
                         sweeps=int(sweeps))


def fold_in_batch(word_ids, valid, phi, alpha, doc_keys, sweeps: int = 20):
    """Padded-batch fold-in — the serving hot path.

    word_ids: (D, L) int32 padded word ids; valid: (D, L) bool mask;
    doc_keys: (D,) per-document stream keys (``doc_fold_key``).  Returns
    (D, T) int32 fold-in counts.

    Row ``d`` is **bit-identical** to the serial path on that document
    alone: ``fold_in(words, zeros, 1, phi, alpha, key)`` with
    ``doc_keys[d] == doc_fold_key(key, 0)``.  Padded positions are inert
    by construction — they draw from their own counter-mode slots (the
    draws are discarded), add 0 to every count, and re-assign ``z`` to
    itself — so growing L or changing the garbage in padded word slots
    cannot perturb a row.  An all-False row (empty document) returns a
    zero count row (θ becomes the uniform α prior).  Fully jittable:
    validation here is shape-only.
    """
    if word_ids.ndim != 2 or word_ids.shape != valid.shape:
        raise ValueError(
            f"word_ids/valid must be matching (D, L) arrays; got "
            f"{word_ids.shape} and {valid.shape}")
    if doc_keys.shape[0] != word_ids.shape[0]:
        raise ValueError(
            f"doc_keys carries {doc_keys.shape[0]} keys for "
            f"{word_ids.shape[0]} rows")
    T = phi.shape[1]
    L = word_ids.shape[1]
    pos = jnp.arange(L, dtype=jnp.int32)

    def one_doc(words, mask, dk):
        ik = jax.random.fold_in(dk, _ROLE_INIT)
        tk = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(ik, pos)
        z = jax.vmap(
            lambda kk: jax.random.randint(kk, (), 0, T,
                                          dtype=jnp.int32))(tk)
        v = mask.astype(jnp.int32)
        n_td = jnp.zeros((T,), jnp.int32).at[z].add(v)
        sk = jax.random.fold_in(dk, _ROLE_SWEEP)

        def sweep(carry, k):
            z, n_td = carry
            ks = jax.random.fold_in(sk, k)
            uk = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(ks, pos)
            u = jax.vmap(jax.random.uniform)(uk)

            def step(c, inp):
                z, n_td = c
                i, u01, vi = inp
                w, t_old = words[i], z[i]
                n_td = n_td.at[t_old].add(-vi)
                p = (n_td.astype(jnp.float32) + alpha) * phi[w]
                cdf = jnp.cumsum(p)
                t_new = lsearch_guarded(cdf, u01 * cdf[-1])
                t_new = jnp.where(vi > 0, t_new, t_old)
                n_td = n_td.at[t_new].add(vi)
                z = z.at[i].set(t_new)
                return (z, n_td), None

            (z, n_td), _ = lax.scan(step, (z, n_td), (pos, u, v))
            return (z, n_td), None

        (z, n_td), _ = lax.scan(sweep, (z, n_td),
                                jnp.arange(sweeps, dtype=jnp.int32))
        return n_td

    return jax.vmap(one_doc)(word_ids, valid, doc_keys)


def document_completion_perplexity(
        heldout: Corpus, n_wt, n_t, *, alpha: float, beta: float,
        key=None, fold_sweeps: int = 20) -> float:
    """Split each held-out doc's tokens in half (alternating positions),
    fold in on the first half, score the second half.

    A corpus of single-token documents puts every token in the
    estimation half: the score half is empty, the log-likelihood sum is
    0 over 0 tokens, and the perplexity is exactly 1.0 — *not* a raise
    through :func:`fold_in`'s empty-token ValueError, which only an
    entirely token-free corpus can trigger (``tests/test_serving.py``
    pins this edge)."""
    key = jax.random.key(0) if key is None else key
    phi = _phi_hat(jnp.asarray(n_wt), jnp.asarray(n_t), beta)   # (J,T)

    order = heldout.doc_order()
    # alternate within each document: even position → estimation half
    pos_in_doc = _positions_in_doc(heldout.doc_ids[order])
    first = (pos_in_doc % 2 == 0)
    est_idx, score_idx = order[first], order[~first]

    n_td = fold_in(jnp.asarray(heldout.word_ids[est_idx]),
                   jnp.asarray(heldout.doc_ids[est_idx]),
                   heldout.num_docs, phi, alpha, key, fold_sweeps)
    theta = theta_from_counts(n_td, alpha)                      # (I,T)

    w = jnp.asarray(heldout.word_ids[score_idx])
    d = jnp.asarray(heldout.doc_ids[score_idx])
    p_tok = jnp.einsum("nt,nt->n", theta[d], phi[w])
    ll = jnp.log(jnp.maximum(p_tok, 1e-30)).sum()
    return float(jnp.exp(-ll / max(len(score_idx), 1)))
