"""Held-out evaluation: document-completion perplexity.

The paper evaluates training log-likelihood (§5, following Yahoo!LDA); the
standard complementary check in the LDA literature is document completion:
hold out a set of documents, estimate each held-out document's θ from the
first half of its tokens (Gibbs with the trained φ frozen), then score the
second half:

    perplexity = exp( − Σ log p(w | θ̂, φ̂) / N_second_half )

φ̂ is the posterior mean from the trained counts:
    φ̂_tw = (n_wt + β) / (n_t + Jβ)
θ̂ from the fold-in counts:  θ̂_dt = (n_td + α) / (n_d + Tα).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.samplers import lsearch_guarded
from repro.data.corpus import Corpus

__all__ = ["document_completion_perplexity", "fold_in"]


def _phi_hat(n_wt, n_t, beta):
    J = n_wt.shape[0]
    return ((n_wt.astype(jnp.float32) + beta)
            / (n_t.astype(jnp.float32)[None, :] + J * beta))  # (J,T)


def fold_in(word_ids, doc_ids, num_docs, phi, alpha, key, sweeps: int = 20):
    """Gibbs fold-in with φ frozen: sample z for held-out tokens, return
    per-doc topic counts.  word_ids/doc_ids: (N,) held-out first halves."""
    N = word_ids.shape[0]
    T = phi.shape[1]
    # Named key derivation: one child per role.  (The former
    # ``key, sub = split(key)`` reused the first child both as the per-sweep
    # fold-in base and as the live ``key`` name — an accidental aliasing
    # that made it easy to consume the same stream twice.)
    init_key, sweep_key = jax.random.split(key)
    z = jax.random.randint(init_key, (N,), 0, T, dtype=jnp.int32)
    n_td = jnp.zeros((num_docs, T), jnp.int32).at[doc_ids, z].add(1)

    def sweep(carry, k):
        z, n_td = carry
        u = jax.random.uniform(jax.random.fold_in(sweep_key, k), (N,))

        def step(c, inp):
            z, n_td = c
            i, u01 = inp
            d, w, t_old = doc_ids[i], word_ids[i], z[i]
            n_td = n_td.at[d, t_old].add(-1)
            p = (n_td[d].astype(jnp.float32) + alpha) * phi[w]
            cdf = jnp.cumsum(p)
            # Guarded LSearch: u01·cdf[-1] shares the cumsum reduction, so
            # overrun needs u01·M to round up to M — impossible for
            # u01 ≤ 1−2⁻²⁴ f32 (the old clip was dead code on that path),
            # but the guard also covers all-zero φ rows, where the clip
            # silently selected topic T−1 with zero mass.
            t_new = lsearch_guarded(cdf, u01 * cdf[-1])
            n_td = n_td.at[d, t_new].add(1)
            z = z.at[i].set(t_new)
            return (z, n_td), None

        (z, n_td), _ = lax.scan(step, (z, n_td),
                                (jnp.arange(N, dtype=jnp.int32), u))
        return (z, n_td), None

    (z, n_td), _ = lax.scan(sweep, (z, n_td),
                            jnp.arange(sweeps, dtype=jnp.int32))
    return n_td


def document_completion_perplexity(
        heldout: Corpus, n_wt, n_t, *, alpha: float, beta: float,
        key=None, fold_sweeps: int = 20) -> float:
    """Split each held-out doc's tokens in half (alternating positions),
    fold in on the first half, score the second half."""
    key = jax.random.key(0) if key is None else key
    phi = _phi_hat(jnp.asarray(n_wt), jnp.asarray(n_t), beta)   # (J,T)
    T = phi.shape[1]

    order = heldout.doc_order()
    doc_sorted = heldout.doc_ids[order]
    # alternate within each document: even position → estimation half
    pos_in_doc = np.zeros_like(order)
    counts: dict[int, int] = {}
    for idx, d in enumerate(doc_sorted):
        c = counts.get(d, 0)
        pos_in_doc[idx] = c
        counts[d] = c + 1
    first = (pos_in_doc % 2 == 0)
    est_idx, score_idx = order[first], order[~first]

    n_td = fold_in(jnp.asarray(heldout.word_ids[est_idx]),
                   jnp.asarray(heldout.doc_ids[est_idx]),
                   heldout.num_docs, phi, alpha, key, fold_sweeps)
    n_d = n_td.sum(1, keepdims=True)
    theta = ((n_td.astype(jnp.float32) + alpha)
             / (n_d.astype(jnp.float32) + T * alpha))           # (I,T)

    w = jnp.asarray(heldout.word_ids[score_idx])
    d = jnp.asarray(heldout.doc_ids[score_idx])
    p_tok = jnp.einsum("nt,nt->n", theta[d], phi[w])
    ll = jnp.log(jnp.maximum(p_tok, 1e-30)).sum()
    return float(jnp.exp(-ll / max(len(score_idx), 1)))
