"""Multinomial samplers compared in paper Table 1.

Four ways to draw ``z`` with ``Pr(z=t) ∝ p_t`` from unnormalized ``p``:

    =============  ==========  ============  ================
    sampler        init        generation    parameter update
    =============  ==========  ============  ================
    LSearch        Θ(T)        Θ(T)          Θ(1)
    BSearch        Θ(T)        Θ(log T)      Θ(T)   (rebuild)
    Alias          Θ(T)        Θ(1)          Θ(T)   (rebuild)
    F+tree         Θ(T)        Θ(log T)      Θ(log T)
    =============  ==========  ============  ================

All samplers share the same functional API so the LDA inner loops and the
Table-1 benchmark can swap them: ``init(p) -> state``, ``draw(state, u01) ->
t``, ``update(state, t, delta) -> state``.  States are pytrees; every function
is jit/vmap friendly.  ``u01`` is a uniform in [0, 1).

The Alias table is built with Vose's algorithm (ref. [18] in the paper)
expressed as a bounded ``lax.while_loop`` over explicit small/large stacks, so
it runs inside jit.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ftree

__all__ = [
    "AliasState", "BSearchState", "FTreeState", "LSearchState",
    "alias_draw", "alias_init", "alias_update",
    "bsearch_draw", "bsearch_init", "bsearch_update",
    "ftree_draw", "ftree_init", "ftree_update",
    "lsearch_draw", "lsearch_guarded", "lsearch_init", "lsearch_update",
    "SAMPLERS",
]


def lsearch_guarded(c: jax.Array, u_val: jax.Array) -> jax.Array:
    """Zero-mass-aware LSearch over a cumulative vector: ``min{t : c_t > u}``,
    guarded to the last positive-mass index.

    The naive ``Σ(c ≤ u)`` walks off the end of the support whenever ``u``
    reaches ``c[-1]`` — which a boundary draw CAN produce when the caller
    scales ``u01`` by a separately computed total (``p.sum()`` and
    ``cumsum(p)[-1]`` are different float reductions and disagree on mixed-
    magnitude vectors), selecting an out-of-range or zero-mass index.  The
    guard ``Σ(c < c[-1])`` is exactly the index of the last entry with
    positive mass (every earlier entry's cumsum is strictly below the
    total), so boundary draws collapse onto the topmost valid topic and
    interior draws are untouched (interior indices satisfy both bounds).
    """
    last = jnp.sum((c < c[-1]).astype(jnp.int32))
    return jnp.minimum(jnp.sum((c <= u_val).astype(jnp.int32)),
                       last).astype(jnp.int32)


# --------------------------------------------------------------------------
# LSearch — linear search on p; only the normalizer is cached.
# --------------------------------------------------------------------------
class LSearchState(NamedTuple):
    p: jax.Array       # (T,) unnormalized parameters
    c_T: jax.Array     # () normalizer Σ p


def lsearch_init(p: jax.Array) -> LSearchState:
    return LSearchState(p=p, c_T=p.sum())


def lsearch_draw(state: LSearchState, u01: jax.Array) -> jax.Array:
    # z = min{t : c_t > u}; vectorized linear search (Θ(T) work).  The
    # cached normalizer c_T is a different float reduction than cumsum(p)
    # (and drifts under Θ(1) updates), so u01·c_T can reach past the last
    # cumsum entry — the guard keeps boundary draws in-support.
    return lsearch_guarded(jnp.cumsum(state.p), u01 * state.c_T)


def lsearch_update(state: LSearchState, t: jax.Array,
                   delta: jax.Array) -> LSearchState:
    # Θ(1) bookkeeping: only the normalizer needs adjusting (plus the raw p_t).
    return LSearchState(p=state.p.at[t].add(delta), c_T=state.c_T + delta)


# --------------------------------------------------------------------------
# BSearch — binary search on the cached cumulative sums.
# --------------------------------------------------------------------------
class BSearchState(NamedTuple):
    c: jax.Array       # (T,) cumsum(p)


def bsearch_init(p: jax.Array) -> BSearchState:
    return BSearchState(c=jnp.cumsum(p))


def bsearch_draw(state: BSearchState, u01: jax.Array) -> jax.Array:
    u = u01 * state.c[-1]
    return jnp.searchsorted(state.c, u, side="right").astype(jnp.int32)


def bsearch_update(state: BSearchState, t: jax.Array,
                   delta: jax.Array) -> BSearchState:
    # Θ(T): every cumsum entry at or after t shifts — full rebuild semantics.
    T = state.c.shape[-1]
    bump = jnp.where(jnp.arange(T) >= t, delta, 0.0).astype(state.c.dtype)
    return BSearchState(c=state.c + bump)


# --------------------------------------------------------------------------
# Alias method — Walker/Vose table; Θ(1) generation, Θ(T) (re)build.
# --------------------------------------------------------------------------
class AliasState(NamedTuple):
    prob: jax.Array    # (T,) acceptance probability per bucket
    alias: jax.Array   # (T,) alias index per bucket
    c_T: jax.Array     # () normalizer Σ p


def alias_init(p: jax.Array) -> AliasState:
    """Vose's linear-time construction as a bounded while_loop.

    Buckets with scaled mass < 1 go on the small stack, ≥ 1 on the large
    stack; each pairing finalizes one small bucket.  At most T pairings.
    """
    T = p.shape[-1]
    c_T = p.sum()
    scaled = jnp.where(c_T > 0, p * (T / c_T), jnp.ones_like(p))

    idx = jnp.arange(T, dtype=jnp.int32)
    is_small = scaled < 1.0
    # Stable partition into stacks (order irrelevant for correctness).
    order_small = jnp.argsort(~is_small, stable=True).astype(jnp.int32)
    n_small = is_small.sum().astype(jnp.int32)
    order_large = jnp.argsort(is_small, stable=True).astype(jnp.int32)
    n_large = (T - n_small).astype(jnp.int32)

    prob0 = jnp.ones((T,), dtype=scaled.dtype)
    alias0 = idx

    def cond(carry):
        _, _, _, n_s, _, n_l, _ = carry
        return (n_s > 0) & (n_l > 0)

    def body(carry):
        scaled, prob, alias, n_s, small, n_l, large = carry
        s = small[n_s - 1]
        l = large[n_l - 1]
        n_s = n_s - 1
        prob = prob.at[s].set(scaled[s])
        alias = alias.at[s].set(l)
        new_l = scaled[l] - (1.0 - scaled[s])
        scaled = scaled.at[l].set(new_l)
        # Re-file the large bucket depending on its remaining mass.
        goes_small = new_l < 1.0
        small = lax.cond(goes_small,
                         lambda: small.at[n_s].set(l),
                         lambda: small)
        n_s = n_s + goes_small.astype(n_s.dtype)
        # If it stays large it remains at position n_l-1 of `large`.
        n_l = n_l - goes_small.astype(n_l.dtype)
        return scaled, prob, alias, n_s, small, n_l, large

    carry = (scaled, prob0, alias0, n_small, order_small, n_large, order_large)
    scaled, prob, alias, n_s, small, n_l, large = lax.while_loop(
        cond, body, carry)
    # Leftovers (numerical residue) get probability 1, alias to self.
    return AliasState(prob=prob, alias=alias, c_T=c_T)


def alias_draw(state: AliasState, u01: jax.Array) -> jax.Array:
    T = state.prob.shape[-1]
    u = u01 * T
    j = jnp.clip(jnp.floor(u).astype(jnp.int32), 0, T - 1)
    frac = u - j
    return jnp.where(frac < state.prob[j], j, state.alias[j]).astype(jnp.int32)


def alias_update(state: AliasState, t: jax.Array, delta: jax.Array,
                 p: jax.Array | None = None) -> AliasState:
    """Θ(T): the alias table cannot absorb a single-parameter change — full
    rebuild from the (caller-maintained) parameter vector."""
    if p is None:
        raise ValueError("alias_update needs the full parameter vector p "
                         "(the table is rebuilt — paper Table 1, Θ(T)).")
    return alias_init(p.at[t].add(delta) if t is not None else p)


# --------------------------------------------------------------------------
# F+tree — paper §3.1.
# --------------------------------------------------------------------------
class FTreeState(NamedTuple):
    F: jax.Array       # (2T,) heap array


def ftree_init(p: jax.Array) -> FTreeState:
    return FTreeState(F=ftree.build(p))


def ftree_draw(state: FTreeState, u01: jax.Array) -> jax.Array:
    return ftree.sample(state.F, u01)


def ftree_update(state: FTreeState, t: jax.Array,
                 delta: jax.Array) -> FTreeState:
    return FTreeState(F=ftree.update(state.F, t, delta))


SAMPLERS = {
    "lsearch": (lsearch_init, lsearch_draw, lsearch_update),
    "bsearch": (bsearch_init, bsearch_draw, bsearch_update),
    "alias": (alias_init, alias_draw, None),   # update needs full p
    "ftree": (ftree_init, ftree_draw, ftree_update),
}
