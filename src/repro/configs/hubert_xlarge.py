"""HuBERT X-Large [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (cluster targets);
encoder-only (bidirectional), same backbone as wav2vec 2.0.  The
mel/conv feature extractor is a stub per spec — the model consumes
precomputed 512-d frame embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,                 # encoder-only
    activation="gelu",
    modality="audio_frames",
    frontend_dim=512,             # conv feature extractor output (stubbed)
    source="arXiv:2106.07447 (HuBERT)",
)
