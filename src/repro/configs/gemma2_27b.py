"""Gemma 2 27B [arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000;
alternating local(4096)/global attention, attn softcap 50, final softcap 30.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    arch_type="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    rope_theta=10_000.0,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,
    local_global_alternating=True,
    activation="geglu",
    tie_embeddings=True,
    source="arXiv:2408.00118 (Gemma 2)",
)
