"""Mamba2 1.3B [arXiv:2405.21060] — SSD (state-space duality).

48L d_model=2048, attention-free, ssm_state=128, vocab=50280.
Mamba2 blocks have no separate MLP (d_ff=0).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,                  # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba2 / SSD)",
)
