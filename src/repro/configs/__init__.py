"""Assigned-architecture registry (+ the paper's own LDA config)."""
from __future__ import annotations

from repro.models.config import ModelConfig

from repro.configs.kimi_k2_1t_a32b import CONFIG as kimi_k2_1t_a32b
from repro.configs.gemma2_27b import CONFIG as gemma2_27b
from repro.configs.hubert_xlarge import CONFIG as hubert_xlarge
from repro.configs.zamba2_2_7b import CONFIG as zamba2_2_7b
from repro.configs.internvl2_1b import CONFIG as internvl2_1b
from repro.configs.mamba2_1_3b import CONFIG as mamba2_1_3b
from repro.configs.phi4_mini_3_8b import CONFIG as phi4_mini_3_8b
from repro.configs.deepseek_moe_16b import CONFIG as deepseek_moe_16b
from repro.configs.granite_3_2b import CONFIG as granite_3_2b
from repro.configs.qwen3_8b import CONFIG as qwen3_8b

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        kimi_k2_1t_a32b, gemma2_27b, hubert_xlarge, zamba2_2_7b,
        internvl2_1b, mamba2_1_3b, phi4_mini_3_8b, deepseek_moe_16b,
        granite_3_2b, qwen3_8b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[:-6]].smoke()
    return ARCHS[name]


# ---------------------------------------------------------------------------
# Input shapes (assigned).
# ---------------------------------------------------------------------------
INPUT_SHAPES = {
    "train_4k":    dict(seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524_288, global_batch=1,   kind="decode"),
}


def shape_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """DESIGN.md §5 policy.  Returns (runnable, note)."""
    spec = INPUT_SHAPES[shape_name]
    if spec["kind"] == "decode" and cfg.is_encoder_only:
        return False, "encoder-only: no decode step (DESIGN §5)"
    if shape_name == "long_500k":
        eff = cfg if cfg.sub_quadratic else cfg.with_long_context()
        if not eff.sub_quadratic:
            return False, "full attention at 500k (no sub-quadratic variant)"
        note = "" if cfg.sub_quadratic else \
            "runs the sliding-window variant (DESIGN §5)"
        return True, note
    return True, ""
