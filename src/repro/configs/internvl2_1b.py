"""InternVL2-1B [arXiv:2404.16821].

LM backbone (Qwen2-0.5B lineage): 24L d_model=896 14H (GQA kv=2)
d_ff=4864 vocab=151655.  The InternViT vision encoder + MLP projector is a
stub per spec — the model consumes precomputed 1024-d patch embeddings
(256 patches) prepended to the text sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    rope_theta=1_000_000.0,
    activation="swiglu",
    modality="image_patches",
    frontend_tokens=256,          # ViT patches per image (stubbed)
    frontend_dim=1024,
    source="arXiv:2404.16821 (InternVL2)",
)
