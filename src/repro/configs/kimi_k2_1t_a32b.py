"""Kimi K2 — trillion-param MoE [arXiv:2501.kimi2 / paper-table].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
MoE 384 experts top-8 + 1 shared expert, first layer dense.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18432,                 # dense first layer (DeepSeek-V3 lineage)
    vocab_size=163840,
    rope_theta=50_000.0,
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_k_dense=1,
    activation="swiglu",
    source="arXiv:2501.kimi2 (Kimi K2 paper table)",
)
