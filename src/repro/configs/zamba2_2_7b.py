"""Zamba2 2.7B [arXiv:2411.15242].

54 Mamba2 layers d_model=2560 with a shared attention block (32H kv=32)
applied every 6 layers; d_ff=10240; ssm_state=64; vocab=32000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,                 # shared block cadence
    activation="swiglu",
    source="arXiv:2411.15242 (Zamba2)",
)
