"""DeepSeekMoE 16B [arXiv:2401.06066].

28L d_model=2048 16H (kv=16) vocab=102400; fine-grained MoE: 64 routed
experts top-6 + 2 shared experts, expert d_ff=1408; first layer dense
(d_ff=10944).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,                   # dense first layer
    vocab_size=102400,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_k_dense=1,
    activation="swiglu",
    source="arXiv:2401.06066 (DeepSeekMoE)",
)
