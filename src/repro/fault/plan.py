"""Deterministic fault injection: a seeded, declarative fault schedule
(DESIGN.md §11).

At the paper's scale — multi-machine, multi-day runs — worker failure
and partial writes are the common case, not the exception (Glint,
PAPERS.md).  This module makes every such failure *reproducible in CI*
without real crashes: a :class:`FaultPlan` is a list of
:class:`FaultSpec` events, each naming a **site** (a string the runtime
fires at well-defined points, e.g. ``"trainer.sweep"`` after sweep ``s``
or ``"chain.write"`` after a checkpoint file lands), an index window
(``at``/``count``) and a fault ``kind``:

====================  ====================================================
kind                  effect when the site fires inside the window
====================  ====================================================
``"kill"``            preemption: ``hard=True`` → ``os._exit(137)`` (the
                      real SIGKILL story, for subprocess harnesses);
                      ``hard=False`` → raise :class:`InjectedKill`
``"stall"``           worker stall: sleep ``delay_s`` seconds
``"corrupt"``         flip ``nbytes`` bytes of the file at ``path``
                      (offsets drawn from the plan's seeded RNG)
``"truncate"``        truncate the file at ``path`` to ``frac`` of its
                      size (a torn / partial write surfacing later)
``"fail"``            raise :class:`SnapshotCorruptError` (a transient
                      fetch/read failure, for retry logic)
``"drop"``            returned to the caller, which skips the action
                      (e.g. a dropped publish)
``"delay"``           sleep ``delay_s``, then let the action proceed
                      (a delayed publish)
====================  ====================================================

Everything is deterministic: byte offsets and values come from
``np.random.default_rng([seed, crc32(site), index])``, so the same plan
replays the same damage bit-for-bit.  Sites the plan does not mention
cost one dict lookup (and zero when no plan is installed at all).

Sites fired by the runtime today (callers pass ``index`` where a
meaningful global ordinal exists, else the plan's per-site counter):

* ``"trainer.sweep"``   — ``NomadLDA.run``, after sweep ``s`` (and after
  its checkpoint write, so kill-after-checkpoint is expressible);
  ``index`` = global sweep number.
* ``"trainer.publish"`` — before a scheduled φ publish; ``index`` =
  global sweep number.  ``drop``/``delay`` apply.
* ``"chain.write"``     — after a chain-checkpoint file is durably
  written; counter-indexed, ``path`` = the file.
* ``"phi.write"``       — same, for φ snapshots.
* ``"serve.fetch"``     — each attempt inside
  ``repro.serve.lda_engine.fetch_snapshot``; counter-indexed across
  calls (so ``at=0, count=2`` fails the first two attempts overall).

Install a plan for a scope with :func:`install` (re-entrant context
manager); runtime hooks call :func:`fire`, which is a no-op without an
installed plan.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import zlib

import numpy as np

from repro.fault.errors import InjectedKill, SnapshotCorruptError

__all__ = ["FaultSpec", "FaultPlan", "install", "active", "fire"]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: ``kind`` at ``site`` for event indices in
    ``[at, at + count)``.  See the module docstring for kind semantics."""
    kind: str
    site: str
    at: int
    count: int = 1
    hard: bool = False       # kill: os._exit(137) instead of InjectedKill
    nbytes: int = 1          # corrupt: bytes to flip
    frac: float = 0.5        # truncate: fraction of the file kept
    delay_s: float = 0.0     # stall / delay: seconds slept

    _KINDS = ("kill", "stall", "corrupt", "truncate", "fail", "drop",
              "delay")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {self._KINDS}")
        if self.at < 0 or self.count < 1:
            raise ValueError(f"need at >= 0, count >= 1; got at={self.at}, "
                             f"count={self.count}")
        if not 0.0 <= self.frac < 1.0:
            raise ValueError(f"truncate frac must be in [0, 1), got "
                             f"{self.frac}")

    def matches(self, site: str, index: int) -> bool:
        return self.site == site and self.at <= index < self.at + self.count


class FaultPlan:
    """A seeded schedule of :class:`FaultSpec` events.

    Thread-safe: per-site counters and the event log are lock-guarded,
    so a serving engine's reader threads and a trainer thread can fire
    sites concurrently.  ``log`` records every applied event as
    ``(site, index, kind)`` for harness reporting.
    """

    def __init__(self, specs=(), *, seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self.log: list[tuple[str, int, str]] = []
        self._sites = frozenset(s.site for s in self.specs)

    def _rng(self, site: str, index: int) -> np.random.Generator:
        return np.random.default_rng(
            [self.seed, zlib.crc32(site.encode()), index])

    def next_index(self, site: str) -> int:
        """Advance and return ``site``'s event counter (0-based)."""
        with self._lock:
            idx = self._counters.get(site, 0)
            self._counters[site] = idx + 1
            return idx

    def _corrupt_file(self, path: str, spec: FaultSpec, index: int) -> None:
        size = os.path.getsize(path)
        if size == 0:
            return
        rng = self._rng(spec.site, index)
        offs = rng.integers(0, size, size=max(1, spec.nbytes))
        with open(path, "r+b") as f:
            for off in offs:
                f.seek(int(off))
                b = f.read(1)
                f.seek(int(off))
                # XOR with a nonzero byte: a guaranteed flip
                f.write(bytes([b[0] ^ int(rng.integers(1, 256))]))

    def _truncate_file(self, path: str, spec: FaultSpec) -> None:
        size = os.path.getsize(path)
        os.truncate(path, int(size * spec.frac))

    def fire(self, site: str, *, index: int | None = None,
             path: str | None = None) -> tuple[str, ...]:
        """Fire ``site``; apply every scheduled fault whose window covers
        the event index.  Returns the applied kinds (``"drop"`` is only
        reported — honoring it is the caller's contract).  Raises for
        ``kill`` (:class:`InjectedKill`, or ``os._exit(137)`` when hard)
        and ``fail`` (:class:`SnapshotCorruptError`)."""
        if site not in self._sites:
            # still count it: indices must not depend on the spec list
            if index is None:
                self.next_index(site)
            return ()
        if index is None:
            index = self.next_index(site)
        applied = []
        for spec in self.specs:
            if not spec.matches(site, index):
                continue
            applied.append(spec.kind)
            with self._lock:
                self.log.append((site, index, spec.kind))
            if spec.kind == "stall" or spec.kind == "delay":
                time.sleep(spec.delay_s)
            elif spec.kind == "corrupt":
                if path is None:
                    raise ValueError(
                        f"corrupt fault at {site}[{index}] needs a path")
                self._corrupt_file(path, spec, index)
            elif spec.kind == "truncate":
                if path is None:
                    raise ValueError(
                        f"truncate fault at {site}[{index}] needs a path")
                self._truncate_file(path, spec)
            elif spec.kind == "fail":
                raise SnapshotCorruptError(
                    f"injected failure at {site}[{index}]")
            elif spec.kind == "kill":
                if spec.hard:            # the real preemption story:
                    os._exit(137)        # no teardown, no atexit, SIGKILL
                raise InjectedKill(site, index)
        return tuple(applied)


# ---------------------------------------------------------------------------
# Installed-plan hooks: zero-cost when nothing is installed.
# ---------------------------------------------------------------------------
_ACTIVE: FaultPlan | None = None
_INSTALL_LOCK = threading.Lock()


class _Install:
    """Re-entrant installer: restores whatever plan was active before."""

    def __init__(self, plan: FaultPlan | None):
        self._plan = plan
        self._prev: FaultPlan | None = None

    def __enter__(self) -> FaultPlan | None:
        global _ACTIVE
        with _INSTALL_LOCK:
            self._prev = _ACTIVE
            _ACTIVE = self._plan
        return self._plan

    def __exit__(self, *exc) -> None:
        global _ACTIVE
        with _INSTALL_LOCK:
            _ACTIVE = self._prev


def install(plan: FaultPlan | None) -> _Install:
    """``with install(plan): ...`` — make ``plan`` the process-wide
    active plan for the block (``None`` disables injection inside).
    The runtime's :func:`fire` hooks consult the active plan only."""
    return _Install(plan)


def active() -> FaultPlan | None:
    return _ACTIVE


def fire(site: str, *, index: int | None = None,
         path: str | None = None) -> tuple[str, ...]:
    """Module-level hook the runtime calls at injection sites.  A no-op
    (and near-free) when no plan is installed."""
    plan = _ACTIVE
    if plan is None:
        return ()
    return plan.fire(site, index=index, path=path)
