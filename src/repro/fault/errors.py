"""Typed failure-path exceptions shared by the checkpoint store, the
serving engine and the fault-injection layer (DESIGN.md §11).

The hierarchy deliberately stays inside the builtin families the happy
path already raised (``ValueError`` / ``RuntimeError``), so pre-existing
callers that catch broadly keep working while recovery code can now
discriminate:

* :class:`SnapshotCorruptError` — the bytes are damaged: truncated file,
  flipped payload byte, missing meta key, digest mismatch.  Retryable
  when the source may heal (a publisher mid-write); fatal for a specific
  checkpoint slot, which is what rotation fallback skips past.
* :class:`FormatVersionError` — the bytes are intact but from a writer
  this build does not understand.  Never retried: time does not fix a
  version skew.
* :class:`StaleGenerationError` — a structurally valid snapshot that
  would move the serving engine *backwards* (its source generation is
  ≤ the live buffer's).  The publish is refused; the live buffer keeps
  serving.
* :class:`EngineOverloadedError` — admission control shed the query
  because the bounded queue is full.  The caller should back off; the
  engine stays healthy by design.
"""
from __future__ import annotations

__all__ = ["SnapshotCorruptError", "SnapshotDigestError",
           "FormatVersionError", "StaleGenerationError",
           "EngineOverloadedError", "InjectedKill"]


class SnapshotCorruptError(ValueError):
    """A checkpoint / φ snapshot whose bytes cannot be trusted:
    truncated archive, flipped payload byte, missing meta, digest
    mismatch.  ``ValueError`` ancestry keeps pre-typed callers working."""


class SnapshotDigestError(SnapshotCorruptError):
    """*Proven-permanent* corruption: the file parsed end to end but its
    content contradicts its own metadata (payload digest mismatch,
    shape-vs-meta skew).  Writers rename atomically (``_atomic_savez``),
    so a complete parse rules out the mid-write race that makes plain
    :class:`SnapshotCorruptError` worth retrying — retry logic must fail
    fast on this subclass (rotation fallback still skips the slot: the
    ``SnapshotCorruptError`` ancestry is what it catches)."""


class FormatVersionError(ValueError):
    """Structurally intact bytes from an unknown format version —
    permanent for this build, so retry logic must not retry it."""


class StaleGenerationError(ValueError):
    """A publish that would regress the serving engine's source
    generation (digest + monotonic-generation guard, DESIGN.md §11)."""


class EngineOverloadedError(RuntimeError):
    """Admission control shed this query: the bounded queue was full.
    Back off and retry; the engine is healthy and still serving."""


class InjectedKill(RuntimeError):
    """Raised by a soft ``kill`` fault (``FaultSpec(kind="kill",
    hard=False)``): the deterministic, in-process stand-in for a
    preemption.  Carries the site/index it fired at."""

    def __init__(self, site: str, index: int):
        super().__init__(f"injected kill at {site}[{index}]")
        self.site = site
        self.index = index
