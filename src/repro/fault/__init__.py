"""Deterministic fault injection + the typed failure-path exceptions
(DESIGN.md §11).  See :mod:`repro.fault.plan` for the fault model and
the list of runtime injection sites."""
from repro.fault.errors import (EngineOverloadedError, FormatVersionError,
                                InjectedKill, SnapshotCorruptError,
                                SnapshotDigestError, StaleGenerationError)
from repro.fault.plan import FaultPlan, FaultSpec, active, fire, install

__all__ = ["FaultPlan", "FaultSpec", "install", "active", "fire",
           "SnapshotCorruptError", "SnapshotDigestError",
           "FormatVersionError", "StaleGenerationError",
           "EngineOverloadedError", "InjectedKill"]
