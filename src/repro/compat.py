"""Version compatibility shims for the jax API surface we depend on.

The repo targets the modern ``jax.shard_map`` entry point (keyword
``check_vma``); older jax releases only ship
``jax.experimental.shard_map.shard_map`` (keyword ``check_rep``).  Both are
the same SPMD primitive — only the import path and the replication-check
keyword differ — so every internal user imports :func:`shard_map` from here.
The keyword is resolved by signature inspection, not import path: transition
releases exposed ``jax.shard_map`` while still spelling it ``check_rep``.
"""
from __future__ import annotations

import inspect

__all__ = ["shard_map"]

try:
    from jax import shard_map as _shard_map           # jax >= 0.6
except ImportError:                                   # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})
