"""Batched serving engine: static-batch prefill + decode loop.

The paper's system is a trainer, so serving is substrate: a minimal but
real engine that takes a batch of variable-length prompts, left-pads...
no — right-aligns via per-sequence positions: each sequence prefils its own
length (cache "len" is per-batch), then decodes greedily until max_tokens
or EOS.  Everything jit-compiled: one prefill call + one fori-style decode
loop with a fixed step function (the `decode_32k` dry-run shape is exactly
one iteration of this loop).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serve.serve_step import decode_step, init_cache

__all__ = ["generate"]


def generate(params, cfg: ModelConfig, prompts: list[list[int]], *,
             max_new_tokens: int = 16, eos_id: int = -1,
             temperature: float = 0.0, key=None,
             ring: bool = False) -> list[list[int]]:
    """Greedy/sampled continuation for a batch of variable-length prompts."""
    B = len(prompts)
    max_len = max(len(p) for p in prompts)
    S_max = max_len + max_new_tokens + 1
    key = jax.random.key(0) if key is None else key

    # pad prompts to a rectangle; track true lengths
    tok = np.zeros((B, max_len), np.int32)
    lens = np.zeros((B,), np.int32)
    for i, p in enumerate(prompts):
        tok[i, :len(p)] = p
        lens[i] = len(p)
    tokens = jnp.asarray(tok)
    lens = jnp.asarray(lens)

    cache = init_cache(cfg, B, S_max, ring=ring)

    # prefill the padded rectangle; padded positions write garbage into the
    # cache beyond each sequence's length, but "len" is then reset to the
    # true length so decode masks them out (kv_len masking).
    _, cache, _ = transformer.forward(
        params, cfg, {"tokens": tokens,
                      "pos": jnp.zeros((B,), jnp.int32)}, cache=cache)
    cache = _set_lens(cache, lens)

    last_tok = tokens[jnp.arange(B), lens - 1][:, None]
    out = [[] for _ in range(B)]
    done = np.zeros(B, bool)
    pos = lens - 1

    step = jax.jit(lambda p, t, q, c, k: decode_step(
        p, cfg, t, q, c, temperature=temperature, key=k))

    # re-decode the last prompt token to get the first continuation
    for it in range(max_new_tokens):
        key, sub = jax.random.split(key)
        cache_step = _set_lens(cache, pos)     # attend up to current pos
        nxt, _, cache = step(params, last_tok, pos, cache_step, sub)
        nxt_np = np.asarray(nxt[:, 0])
        for i in range(B):
            if not done[i]:
                if int(nxt_np[i]) == eos_id:
                    done[i] = True
                else:
                    out[i].append(int(nxt_np[i]))
        if done.all():
            break
        last_tok = nxt
        pos = pos + 1
    return out


def _set_lens(cache, lens):
    def fix(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "len":
            return jnp.broadcast_to(lens, leaf.shape).astype(leaf.dtype)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, cache)
