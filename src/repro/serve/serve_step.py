"""Serving steps: prefill (fill the cache) and decode (one token).

``decode_32k`` / ``long_500k`` dry-run shapes lower ``serve_step``: ONE new
token against a KV/SSM cache of ``seq_len`` (per spec).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig

__all__ = ["prefill", "decode_step", "make_decode_step", "init_cache"]

init_cache = transformer.init_cache


def prefill(params, cfg: ModelConfig, batch, cache):
    """Run the full prompt through the model, filling the cache."""
    logits, cache, _ = transformer.forward(params, cfg, batch, cache=cache)
    return logits, cache


def decode_step(params, cfg: ModelConfig, tokens, pos, cache, *,
                temperature: float = 0.0, key=None):
    """One decode step. tokens: (B,1) current token; pos: (B,) its index.

    Returns (next_tokens (B,1), logits (B,1,V), new_cache).
    """
    batch = {"tokens": tokens, "pos": pos}
    logits, cache, _ = transformer.forward(params, cfg, batch, cache=cache)
    if temperature > 0.0 and key is not None:
        nxt = jax.random.categorical(key, logits[:, -1] / temperature)
    else:
        nxt = jnp.argmax(logits[:, -1], axis=-1)
    return nxt[:, None].astype(jnp.int32), logits, cache


def make_decode_step(cfg: ModelConfig):
    def step(params, tokens, pos, cache):
        return decode_step(params, cfg, tokens, pos, cache)
    return step
