"""Online fold-in topic inference: the millions-of-users serving path.

DESIGN.md §10.  The trainer (``core/nomad.py``) owns the chain; serving
owns a *frozen* posterior-mean φ table.  Three pieces:

* :class:`PhiSnapshot` — an immutable, format-versioned φ table plus the
  hyperparameters and integrity digest needed to fold against it.
  Built from trained counts by :func:`snapshot_from_counts` (the same
  ``_phi_hat`` float ops as held-out evaluation) or loaded from the
  ``train/checkpoint.py:save_phi`` store.

* :func:`pack_docs` — ragged → padded: variable-length documents become
  a ``(D, L)`` tile (rows and columns bucketed to powers of two so the
  jit cache stays bounded) plus a validity mask.  Padded positions are
  provably inert under ``fold_in_batch``'s counter-mode RNG contract.

* :class:`LdaEngine` — double-buffered θ service.  ``publish`` builds
  the device-resident buffer *off* the serving path and installs it
  with one atomic reference swap (generation counter + content digest);
  ``query`` pins the buffer with a single attribute read, so a reader
  can never observe a torn or half-folded table even while a background
  ``NomadLDA.run(publish_every=...)`` ring keeps publishing.  Every
  answer carries the generation and digest it folded against, which is
  what ``launch/serve_check.py`` audits for torn reads.

Failure model (DESIGN.md §11): ``publish`` is the integrity gate — a
corrupt table raises :class:`SnapshotCorruptError`, a version skew
:class:`FormatVersionError`, and a snapshot whose source generation
(``meta["sweep"]``/``meta["generation"]``) would move the engine
*backwards* :class:`StaleGenerationError`; the live buffer keeps serving
through all three.  ``query`` runs behind admission control: a bounded
in-flight count sheds excess load (:class:`EngineOverloadedError`)
instead of queueing unboundedly, and a softer threshold degrades
answers (capped fold-in sweeps) before shedding starts — p99 stays
bounded because the engine refuses work it cannot finish in time.
:func:`fetch_snapshot` is the reader-side loader: bounded retry with
exponential backoff around transient damage (a publisher mid-write),
never around version skew.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.heldout import (_phi_hat, doc_fold_key, fold_in_batch,
                                theta_from_counts)
from repro.data.sharding import _pow2_ceil
from repro.kernels.fold_in import fold_in_fused
from repro.kernels.fused_sweep.ops import default_interpret
from repro.fault import fire as _fault_fire
from repro.fault.errors import (EngineOverloadedError, FormatVersionError,
                                SnapshotCorruptError, SnapshotDigestError,
                                StaleGenerationError)
from repro.train.checkpoint import (PHI_FORMAT_VERSION, load_phi, phi_digest,
                                    save_phi)

__all__ = ["PhiSnapshot", "snapshot_from_counts", "pack_docs",
           "TopicQuery", "TopicResult", "LdaEngine", "fetch_snapshot",
           "SnapshotCorruptError", "FormatVersionError",
           "StaleGenerationError", "EngineOverloadedError"]


# ---------------------------------------------------------------------------
# Snapshots
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PhiSnapshot:
    """A frozen φ table: ``phi`` is ``(J, T)`` f32, ``meta`` carries
    ``format_version``/``alpha``/``beta``/``J``/``T``/``digest`` (and any
    trainer-side extras, e.g. the sweep it was exported at)."""
    phi: np.ndarray
    meta: dict

    @property
    def alpha(self) -> float:
        return float(self.meta["alpha"])

    @property
    def beta(self) -> float:
        return float(self.meta["beta"])

    @property
    def digest(self) -> str:
        return self.meta["digest"]

    def save(self, path: str) -> None:
        save_phi(path, self.phi, self.meta)

    @classmethod
    def load(cls, path: str) -> "PhiSnapshot":
        phi, meta = load_phi(path)
        return cls(phi=phi, meta=meta)


def snapshot_from_counts(n_wt, n_t, *, alpha: float, beta: float,
                         extra_meta: dict | None = None) -> PhiSnapshot:
    """Freeze trained counts into a snapshot: φ̂ = (n_wt+β)/(n_t+Jβ),
    the identical float ops the held-out evaluator uses."""
    phi = np.asarray(_phi_hat(jnp.asarray(n_wt), jnp.asarray(n_t), beta),
                     np.float32)
    meta = dict(extra_meta or {})
    meta.update(format_version=PHI_FORMAT_VERSION,
                alpha=float(alpha), beta=float(beta),
                J=int(phi.shape[0]), T=int(phi.shape[1]),
                digest=phi_digest(phi))
    return PhiSnapshot(phi=phi, meta=meta)


def fetch_snapshot(path: str, *, retries: int = 3, backoff_s: float = 0.05,
                   max_backoff_s: float = 1.0,
                   sleep=time.sleep) -> PhiSnapshot:
    """Load a φ snapshot with bounded retry + exponential backoff
    (DESIGN.md §11) — the reader-side fetch a serving fleet points at a
    trainer's publish directory.

    Retried: ``FileNotFoundError`` (not published yet) and plain
    :class:`SnapshotCorruptError` (a publisher mid-write, a torn copy —
    transient by assumption, up to ``retries`` extra attempts, backoff
    doubling from ``backoff_s`` and capped at ``max_backoff_s``).
    **Never** retried: :class:`FormatVersionError` — a version skew is a
    deployment bug, and hammering the file cannot fix it — and
    :class:`SnapshotDigestError` — a digest/shape contradiction on a
    file that parsed end to end is proven-permanent damage (publishes
    rename atomically, so a complete parse rules out the mid-write
    race), and burning the backoff budget on it only delays the alarm.
    Each attempt fires the ``"serve.fetch"`` fault site (counter-indexed
    across calls), which is how the chaos harness makes the first N
    fetches fail deterministically."""
    if retries < 0:
        raise ValueError(f"retries must be >= 0, got {retries}")
    delay = backoff_s
    for attempt in range(retries + 1):
        try:
            _fault_fire("serve.fetch", path=path)
            return PhiSnapshot.load(path)
        except (FormatVersionError, SnapshotDigestError):
            raise
        except (FileNotFoundError, SnapshotCorruptError):
            if attempt == retries:
                raise
            sleep(delay)
            delay = min(delay * 2, max_backoff_s)
    raise AssertionError("unreachable")


# ---------------------------------------------------------------------------
# Ragged → padded batching
# ---------------------------------------------------------------------------
def pack_docs(docs, *, tile: int = 8):
    """Pack variable-length documents into a padded ``(D_pad, L)`` tile.

    ``L`` is the longest document rounded up to a multiple of ``tile``
    and then to a power-of-two tile count; ``D_pad`` is the doc count
    rounded to a power of two.  Both roundings bound the set of shapes
    the jitted fold-in kernel ever sees (same motivation as
    ``data/sharding.default_ragged_tile``: a handful of buckets instead
    of one compile per request).  Returns ``(word_ids, valid, n_real)``;
    padded positions and padded rows are all-False in ``valid`` and
    carry word id 0 — inert by `fold_in_batch`'s contract.
    """
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    docs = [np.asarray(d, np.int32).reshape(-1) for d in docs]
    if not docs:
        raise ValueError("pack_docs got an empty document list")
    n_real = len(docs)
    l_max = max(d.size for d in docs)
    n_tiles = _pow2_ceil(max(-(-l_max // tile), 1))
    L = n_tiles * tile
    D = _pow2_ceil(n_real)
    word_ids = np.zeros((D, L), np.int32)
    valid = np.zeros((D, L), bool)
    for i, d in enumerate(docs):
        word_ids[i, :d.size] = d
        valid[i, :d.size] = True
    return word_ids, valid, n_real


# ---------------------------------------------------------------------------
# Request / response types
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TopicQuery:
    """``docs``: variable-length token-id documents (empty docs allowed —
    their θ is the uniform α prior).  ``key``: base RNG key; document
    ``i`` of the query runs stream ``doc_fold_key(key, i)``, so a query
    over docs 0..D−1 is bit-reproducible by the serial ``fold_in`` under
    the same key.  ``sweeps`` overrides the engine default."""
    docs: tuple
    key: object = None
    sweeps: int | None = None


@dataclasses.dataclass(frozen=True)
class TopicResult:
    """θ rows for the query's documents plus the provenance needed to
    audit exactly which snapshot answered: generation + digest — and,
    under admission control, the load story (how many sweeps actually
    ran, whether this answer was degraded, cumulative shed/degraded
    counts at answer time)."""
    theta: np.ndarray        # (len(docs), T) f32, rows sum to 1
    n_td: np.ndarray         # (len(docs), T) int32 fold-in counts
    generation: int
    digest: str
    latency_s: float
    batch_shape: tuple       # padded (D_pad, L) actually swept
    sweeps_used: int = 0     # fold-in sweeps this answer ran
    degraded: bool = False   # True → sweeps were capped under overload
    shed_total: int = 0      # engine-lifetime queries shed so far
    degraded_total: int = 0  # engine-lifetime degraded answers so far


@dataclasses.dataclass(frozen=True)
class _Buffer:
    """One published φ buffer.  Immutable: a reader that grabbed this
    object sees a consistent (phi, alpha, generation, digest) forever,
    regardless of later publishes — the whole double-buffer protocol is
    `buf = self._buf` being a single atomic reference read."""
    phi: object              # device-resident (J, T) f32
    alpha: float
    generation: int
    digest: str
    meta: dict
    source: int | None = None  # trainer-side generation (meta sweep), the
                               #   monotonicity guard's comparison key


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("sweeps", "inner_mode", "interpret"))
def _theta_kernel(word_ids, valid, phi, alpha, doc_keys, sweeps,
                  inner_mode="scan", interpret=True):
    if inner_mode == "fused":
        n_td = fold_in_fused(word_ids, valid, phi, alpha, doc_keys,
                             sweeps, interpret=interpret)
    else:
        n_td = fold_in_batch(word_ids, valid, phi, alpha, doc_keys, sweeps)
    return n_td, theta_from_counts(n_td, alpha)


def _bucket_len(n: int, tile: int) -> int:
    """The padded row length ``pack_docs`` would give a lone ``n``-token
    document — the pow-2 length bucket ``query`` groups by."""
    return _pow2_ceil(max(-(-n // tile), 1)) * tile


class LdaEngine:
    """Double-buffered fold-in θ service.

    Thread-safety contract: ``publish`` may run concurrently with any
    number of ``query`` calls.  Publishers serialize on a lock; readers
    take no lock at all — they pin the current :class:`_Buffer` with one
    reference read and use only that object, so a concurrent publish can
    reorder *which* snapshot answered but never mix two snapshots inside
    one answer.

    Admission control (DESIGN.md §11): ``max_pending`` bounds concurrent
    in-flight queries — excess load raises
    :class:`EngineOverloadedError` (shedding) instead of queueing
    unboundedly, which is what keeps p99 bounded under a flood.
    ``degrade_pending`` is the softer threshold: above it, answers still
    complete but with fold-in sweeps capped at ``degraded_sweeps``
    (graceful degradation before shedding).  Both default to ``None`` —
    no admission control, the pre-§11 behavior.

    ``inner_mode`` picks the fold-in implementation: ``"scan"`` (the
    vmapped ``lax.scan`` reference) or ``"fused"`` (the Pallas kernel,
    ``kernels/fold_in`` — bit-identical per document, DESIGN.md §10a).
    ``interpret=None`` resolves to compiled-on-TPU / interpreted
    elsewhere.  Queries are length-bucketed: docs whose pow-2 padded
    length (what ``pack_docs`` would give them alone) exceeds 4x the
    batch's median bucket dispatch in their own sub-batch, so one long
    outlier cannot inflate every row's padded sweep work — while
    ordinary mixed-length batches still run as a single dispatch.
    """

    def __init__(self, snapshot: PhiSnapshot | None = None, *,
                 sweeps: int = 20, tile: int = 8, max_batch: int = 64,
                 default_key=None, max_pending: int | None = None,
                 degrade_pending: int | None = None,
                 degraded_sweeps: int = 4, inner_mode: str = "scan",
                 interpret: bool | None = None):
        if sweeps < 1:
            raise ValueError(f"sweeps must be >= 1, got {sweeps}")
        if inner_mode not in ("scan", "fused"):
            raise ValueError(
                f"inner_mode must be 'scan' or 'fused', got {inner_mode!r}")
        if max_batch < 1 or max_batch & (max_batch - 1):
            raise ValueError(
                f"max_batch must be a power of two (jit-cache bucketing), "
                f"got {max_batch}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if degrade_pending is not None and degrade_pending < 1:
            raise ValueError(
                f"degrade_pending must be >= 1, got {degrade_pending}")
        if degraded_sweeps < 1:
            raise ValueError(
                f"degraded_sweeps must be >= 1, got {degraded_sweeps}")
        self.sweeps = int(sweeps)
        self.tile = int(tile)
        self.max_batch = int(max_batch)
        self.inner_mode = inner_mode
        # Compiled on TPU, interpreted elsewhere (fused_sweep.ops) —
        # resolved once so every query hits the same jit bucket.
        self.interpret = (default_interpret() if interpret is None
                          else bool(interpret))
        self.max_pending = max_pending
        self.degrade_pending = degrade_pending
        self.degraded_sweeps = int(degraded_sweeps)
        self._default_key = (jax.random.key(0) if default_key is None
                             else default_key)
        self._publish_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._buf: _Buffer | None = None
        self._queries = 0
        self._pending = 0
        self._shed = 0
        self._degraded = 0
        self._rejected_publishes = 0
        self._max_pending_seen = 0
        if snapshot is not None:
            self.publish(snapshot)

    # -- publish side ------------------------------------------------------
    def _reject(self, exc: Exception):
        with self._stats_lock:
            self._rejected_publishes += 1
        raise exc

    def publish(self, snapshot: PhiSnapshot) -> int:
        """Install a new φ buffer; returns its generation.

        The integrity gate (DESIGN.md §11) — refuses, leaving the live
        buffer serving:

        * format-version mismatches (:class:`FormatVersionError`);
        * digest-mismatched tables (:class:`SnapshotCorruptError` — a
          corrupt φ must never reach readers);
        * geometry changes against the live buffer (``ValueError`` — a
          serving vocabulary cannot silently resize);
        * source-generation regressions (:class:`StaleGenerationError`):
          when both the live buffer's and the candidate's meta carry a
          trainer-side ordinal (``sweep``, else ``generation``), a
          candidate at or behind the live one is refused — a delayed or
          replayed publish cannot move readers backwards in time.

        The device transfer happens *before* the swap, so readers never
        wait on it.
        """
        ver = snapshot.meta.get("format_version")
        if ver != PHI_FORMAT_VERSION:
            self._reject(FormatVersionError(
                f"refusing φ snapshot format v{ver}; this engine serves "
                f"v{PHI_FORMAT_VERSION}"))
        phi = np.asarray(snapshot.phi, np.float32)
        if phi.ndim != 2:
            self._reject(SnapshotCorruptError(
                f"φ must be (J, T); got shape {phi.shape}"))
        digest = phi_digest(phi)
        if snapshot.meta.get("digest") not in (None, digest):
            self._reject(SnapshotCorruptError(
                "φ snapshot digest mismatch — refusing to serve a corrupt "
                "table"))
        src = snapshot.meta.get("sweep", snapshot.meta.get("generation"))
        src = None if src is None else int(src)
        phi_dev = jax.device_put(jnp.asarray(phi))
        jax.block_until_ready(phi_dev)
        with self._publish_lock:
            cur = self._buf
            if cur is not None and cur.phi.shape != phi.shape:
                self._reject(ValueError(
                    f"φ geometry change {cur.phi.shape} → {phi.shape}; "
                    f"drain and restart the engine to resize"))
            if (cur is not None and cur.source is not None
                    and src is not None and src <= cur.source):
                self._reject(StaleGenerationError(
                    f"φ snapshot source generation {src} would regress the "
                    f"live buffer's {cur.source}; refusing to move readers "
                    f"backwards"))
            gen = 1 if cur is None else cur.generation + 1
            self._buf = _Buffer(phi=phi_dev, alpha=snapshot.alpha,
                                generation=gen, digest=digest,
                                meta=dict(snapshot.meta), source=src)
        return gen

    @property
    def generation(self) -> int:
        buf = self._buf
        return 0 if buf is None else buf.generation

    # -- query side --------------------------------------------------------
    def _admit(self) -> bool:
        """Count this query in → whether it must run degraded.  Raises
        :class:`EngineOverloadedError` (shedding) when ``max_pending``
        concurrent queries are already in flight."""
        with self._stats_lock:
            pending = self._pending + 1
            if self.max_pending is not None and pending > self.max_pending:
                self._shed += 1
                raise EngineOverloadedError(
                    f"engine overloaded: {self._pending} queries in flight "
                    f"(max_pending={self.max_pending}); query shed — back "
                    f"off and retry")
            self._pending = pending
            self._max_pending_seen = max(self._max_pending_seen, pending)
            degraded = (self.degrade_pending is not None
                        and pending > self.degrade_pending)
            if degraded:
                self._degraded += 1
            return degraded

    def query(self, q: TopicQuery) -> TopicResult:
        buf = self._buf          # the one atomic read; pins the snapshot
        if buf is None:
            raise RuntimeError("LdaEngine has no published snapshot yet")
        t0 = time.perf_counter()
        docs = [np.asarray(d, np.int32).reshape(-1) for d in q.docs]
        if not docs:
            raise ValueError("TopicQuery carries no documents")
        J = buf.phi.shape[0]
        for i, d in enumerate(docs):
            if d.size and (int(d.min()) < 0 or int(d.max()) >= J):
                raise ValueError(
                    f"doc {i}: word ids out of range [0, {J}): "
                    f"[{d.min()}, {d.max()}]")
        key = self._default_key if q.key is None else q.key
        sweeps = self.sweeps if q.sweeps is None else int(q.sweeps)
        degraded = self._admit()
        if degraded:
            sweeps = min(sweeps, self.degraded_sweeps)
        try:
            T = buf.phi.shape[1]
            theta_out = np.empty((len(docs), T), np.float32)
            ntd_out = np.empty((len(docs), T), np.int32)
            shapes = []
            # Length-bucketed sub-batches: one outlier document must not
            # inflate L for every co-batched row (padded work is D_pad·L
            # per sweep).  Splitting is not free either — every group is
            # its own kernel dispatch — so only true outliers split off:
            # docs whose pow-2 length bucket stays within 4x the batch's
            # median bucket run as one group (padded to that group's
            # widest doc, the pre-split behaviour), and each bucket past
            # the cutoff dispatches on its own.  Per-doc bit-exactness
            # is unchanged: row RNG is keyed by the doc's *query* index
            # (batch-independent by the counter-mode contract), so the
            # grouping cannot perturb any row.
            blens = [_bucket_len(d.size, self.tile) for d in docs]
            cutoff = 4 * sorted(blens)[len(blens) // 2]
            main_L = max((b for b in blens if b <= cutoff), default=0)
            by_bucket: dict[int, list[int]] = {}
            for i, b in enumerate(blens):
                by_bucket.setdefault(b if b > cutoff else main_L,
                                     []).append(i)
            for _, idxs in sorted(by_bucket.items()):
                for lo in range(0, len(idxs), self.max_batch):
                    chunk = idxs[lo:lo + self.max_batch]
                    word_ids, valid, n_real = pack_docs(
                        [docs[i] for i in chunk], tile=self.tile)
                    # pad rows are all-invalid; their key index is inert
                    idx = np.asarray(
                        chunk + [chunk[-1]] * (word_ids.shape[0] - n_real),
                        np.int32)
                    doc_keys = jax.vmap(doc_fold_key, in_axes=(None, 0))(
                        key, jnp.asarray(idx))
                    n_td, theta = _theta_kernel(
                        jnp.asarray(word_ids), jnp.asarray(valid),
                        buf.phi, buf.alpha, doc_keys, sweeps,
                        inner_mode=self.inner_mode,
                        interpret=self.interpret)
                    jax.block_until_ready(theta)
                    theta_out[chunk] = np.asarray(theta)[:n_real]
                    ntd_out[chunk] = np.asarray(n_td)[:n_real]
                    shapes.append(word_ids.shape)
            with self._stats_lock:
                self._queries += 1
                shed_total, degraded_total = self._shed, self._degraded
        finally:
            with self._stats_lock:
                self._pending -= 1
        return TopicResult(
            theta=theta_out, n_td=ntd_out,
            generation=buf.generation, digest=buf.digest,
            latency_s=time.perf_counter() - t0,
            batch_shape=shapes[0] if len(shapes) == 1 else tuple(shapes),
            sweeps_used=sweeps, degraded=degraded,
            shed_total=shed_total, degraded_total=degraded_total)

    def stats(self) -> dict:
        """Engine-lifetime load/health counters (one consistent read)."""
        with self._stats_lock:
            return {
                "queries": self._queries,
                "pending": self._pending,
                "shed": self._shed,
                "degraded": self._degraded,
                "rejected_publishes": self._rejected_publishes,
                "max_pending_seen": self._max_pending_seen,
                "generation": self.generation,
            }
