"""Online fold-in topic inference: the millions-of-users serving path.

DESIGN.md §10.  The trainer (``core/nomad.py``) owns the chain; serving
owns a *frozen* posterior-mean φ table.  Three pieces:

* :class:`PhiSnapshot` — an immutable, format-versioned φ table plus the
  hyperparameters and integrity digest needed to fold against it.
  Built from trained counts by :func:`snapshot_from_counts` (the same
  ``_phi_hat`` float ops as held-out evaluation) or loaded from the
  ``train/checkpoint.py:save_phi`` store.

* :func:`pack_docs` — ragged → padded: variable-length documents become
  a ``(D, L)`` tile (rows and columns bucketed to powers of two so the
  jit cache stays bounded) plus a validity mask.  Padded positions are
  provably inert under ``fold_in_batch``'s counter-mode RNG contract.

* :class:`LdaEngine` — double-buffered θ service.  ``publish`` builds
  the device-resident buffer *off* the serving path and installs it
  with one atomic reference swap (generation counter + content digest);
  ``query`` pins the buffer with a single attribute read, so a reader
  can never observe a torn or half-folded table even while a background
  ``NomadLDA.run(publish_every=...)`` ring keeps publishing.  Every
  answer carries the generation and digest it folded against, which is
  what ``launch/serve_check.py`` audits for torn reads.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.heldout import (_phi_hat, doc_fold_key, fold_in_batch,
                                theta_from_counts)
from repro.data.sharding import _pow2_ceil
from repro.train.checkpoint import (PHI_FORMAT_VERSION, load_phi, phi_digest,
                                    save_phi)

__all__ = ["PhiSnapshot", "snapshot_from_counts", "pack_docs",
           "TopicQuery", "TopicResult", "LdaEngine"]


# ---------------------------------------------------------------------------
# Snapshots
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PhiSnapshot:
    """A frozen φ table: ``phi`` is ``(J, T)`` f32, ``meta`` carries
    ``format_version``/``alpha``/``beta``/``J``/``T``/``digest`` (and any
    trainer-side extras, e.g. the sweep it was exported at)."""
    phi: np.ndarray
    meta: dict

    @property
    def alpha(self) -> float:
        return float(self.meta["alpha"])

    @property
    def beta(self) -> float:
        return float(self.meta["beta"])

    @property
    def digest(self) -> str:
        return self.meta["digest"]

    def save(self, path: str) -> None:
        save_phi(path, self.phi, self.meta)

    @classmethod
    def load(cls, path: str) -> "PhiSnapshot":
        phi, meta = load_phi(path)
        return cls(phi=phi, meta=meta)


def snapshot_from_counts(n_wt, n_t, *, alpha: float, beta: float,
                         extra_meta: dict | None = None) -> PhiSnapshot:
    """Freeze trained counts into a snapshot: φ̂ = (n_wt+β)/(n_t+Jβ),
    the identical float ops the held-out evaluator uses."""
    phi = np.asarray(_phi_hat(jnp.asarray(n_wt), jnp.asarray(n_t), beta),
                     np.float32)
    meta = dict(extra_meta or {})
    meta.update(format_version=PHI_FORMAT_VERSION,
                alpha=float(alpha), beta=float(beta),
                J=int(phi.shape[0]), T=int(phi.shape[1]),
                digest=phi_digest(phi))
    return PhiSnapshot(phi=phi, meta=meta)


# ---------------------------------------------------------------------------
# Ragged → padded batching
# ---------------------------------------------------------------------------
def pack_docs(docs, *, tile: int = 8):
    """Pack variable-length documents into a padded ``(D_pad, L)`` tile.

    ``L`` is the longest document rounded up to a multiple of ``tile``
    and then to a power-of-two tile count; ``D_pad`` is the doc count
    rounded to a power of two.  Both roundings bound the set of shapes
    the jitted fold-in kernel ever sees (same motivation as
    ``data/sharding.default_ragged_tile``: a handful of buckets instead
    of one compile per request).  Returns ``(word_ids, valid, n_real)``;
    padded positions and padded rows are all-False in ``valid`` and
    carry word id 0 — inert by `fold_in_batch`'s contract.
    """
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    docs = [np.asarray(d, np.int32).reshape(-1) for d in docs]
    if not docs:
        raise ValueError("pack_docs got an empty document list")
    n_real = len(docs)
    l_max = max(d.size for d in docs)
    n_tiles = _pow2_ceil(max(-(-l_max // tile), 1))
    L = n_tiles * tile
    D = _pow2_ceil(n_real)
    word_ids = np.zeros((D, L), np.int32)
    valid = np.zeros((D, L), bool)
    for i, d in enumerate(docs):
        word_ids[i, :d.size] = d
        valid[i, :d.size] = True
    return word_ids, valid, n_real


# ---------------------------------------------------------------------------
# Request / response types
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TopicQuery:
    """``docs``: variable-length token-id documents (empty docs allowed —
    their θ is the uniform α prior).  ``key``: base RNG key; document
    ``i`` of the query runs stream ``doc_fold_key(key, i)``, so a query
    over docs 0..D−1 is bit-reproducible by the serial ``fold_in`` under
    the same key.  ``sweeps`` overrides the engine default."""
    docs: tuple
    key: object = None
    sweeps: int | None = None


@dataclasses.dataclass(frozen=True)
class TopicResult:
    """θ rows for the query's documents plus the provenance needed to
    audit exactly which snapshot answered: generation + digest."""
    theta: np.ndarray        # (len(docs), T) f32, rows sum to 1
    n_td: np.ndarray         # (len(docs), T) int32 fold-in counts
    generation: int
    digest: str
    latency_s: float
    batch_shape: tuple       # padded (D_pad, L) actually swept


@dataclasses.dataclass(frozen=True)
class _Buffer:
    """One published φ buffer.  Immutable: a reader that grabbed this
    object sees a consistent (phi, alpha, generation, digest) forever,
    regardless of later publishes — the whole double-buffer protocol is
    `buf = self._buf` being a single atomic reference read."""
    phi: object              # device-resident (J, T) f32
    alpha: float
    generation: int
    digest: str
    meta: dict


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("sweeps",))
def _theta_kernel(word_ids, valid, phi, alpha, doc_keys, sweeps):
    n_td = fold_in_batch(word_ids, valid, phi, alpha, doc_keys, sweeps)
    return n_td, theta_from_counts(n_td, alpha)


class LdaEngine:
    """Double-buffered fold-in θ service.

    Thread-safety contract: ``publish`` may run concurrently with any
    number of ``query`` calls.  Publishers serialize on a lock; readers
    take no lock at all — they pin the current :class:`_Buffer` with one
    reference read and use only that object, so a concurrent publish can
    reorder *which* snapshot answered but never mix two snapshots inside
    one answer.
    """

    def __init__(self, snapshot: PhiSnapshot | None = None, *,
                 sweeps: int = 20, tile: int = 8, max_batch: int = 64,
                 default_key=None):
        if sweeps < 1:
            raise ValueError(f"sweeps must be >= 1, got {sweeps}")
        if max_batch < 1 or max_batch & (max_batch - 1):
            raise ValueError(
                f"max_batch must be a power of two (jit-cache bucketing), "
                f"got {max_batch}")
        self.sweeps = int(sweeps)
        self.tile = int(tile)
        self.max_batch = int(max_batch)
        self._default_key = (jax.random.key(0) if default_key is None
                             else default_key)
        self._publish_lock = threading.Lock()
        self._buf: _Buffer | None = None
        self._queries = 0
        if snapshot is not None:
            self.publish(snapshot)

    # -- publish side ------------------------------------------------------
    def publish(self, snapshot: PhiSnapshot) -> int:
        """Install a new φ buffer; returns its generation.

        Refuses format-version mismatches, geometry changes against the
        live buffer (a serving vocabulary cannot silently resize), and
        digest-mismatched tables.  The device transfer happens *before*
        the swap, so readers never wait on it.
        """
        ver = snapshot.meta.get("format_version")
        if ver != PHI_FORMAT_VERSION:
            raise ValueError(
                f"refusing φ snapshot format v{ver}; this engine serves "
                f"v{PHI_FORMAT_VERSION}")
        phi = np.asarray(snapshot.phi, np.float32)
        if phi.ndim != 2:
            raise ValueError(f"φ must be (J, T); got shape {phi.shape}")
        digest = phi_digest(phi)
        if snapshot.meta.get("digest") not in (None, digest):
            raise ValueError("φ snapshot digest mismatch — refusing to "
                             "serve a corrupt table")
        phi_dev = jax.device_put(jnp.asarray(phi))
        jax.block_until_ready(phi_dev)
        with self._publish_lock:
            cur = self._buf
            if cur is not None and cur.phi.shape != phi.shape:
                raise ValueError(
                    f"φ geometry change {cur.phi.shape} → {phi.shape}; "
                    f"drain and restart the engine to resize")
            gen = 1 if cur is None else cur.generation + 1
            self._buf = _Buffer(phi=phi_dev, alpha=snapshot.alpha,
                                generation=gen, digest=digest,
                                meta=dict(snapshot.meta))
        return gen

    @property
    def generation(self) -> int:
        buf = self._buf
        return 0 if buf is None else buf.generation

    # -- query side --------------------------------------------------------
    def query(self, q: TopicQuery) -> TopicResult:
        buf = self._buf          # the one atomic read; pins the snapshot
        if buf is None:
            raise RuntimeError("LdaEngine has no published snapshot yet")
        t0 = time.perf_counter()
        docs = [np.asarray(d, np.int32).reshape(-1) for d in q.docs]
        if not docs:
            raise ValueError("TopicQuery carries no documents")
        J = buf.phi.shape[0]
        for i, d in enumerate(docs):
            if d.size and (int(d.min()) < 0 or int(d.max()) >= J):
                raise ValueError(
                    f"doc {i}: word ids out of range [0, {J}): "
                    f"[{d.min()}, {d.max()}]")
        key = self._default_key if q.key is None else q.key
        sweeps = self.sweeps if q.sweeps is None else int(q.sweeps)

        thetas, counts, shapes = [], [], []
        for lo in range(0, len(docs), self.max_batch):
            chunk = docs[lo:lo + self.max_batch]
            word_ids, valid, n_real = pack_docs(chunk, tile=self.tile)
            doc_keys = jax.vmap(doc_fold_key, in_axes=(None, 0))(
                key, jnp.arange(lo, lo + word_ids.shape[0],
                                dtype=jnp.int32))
            n_td, theta = _theta_kernel(jnp.asarray(word_ids),
                                        jnp.asarray(valid), buf.phi,
                                        buf.alpha, doc_keys, sweeps)
            jax.block_until_ready(theta)
            thetas.append(np.asarray(theta)[:n_real])
            counts.append(np.asarray(n_td)[:n_real])
            shapes.append(word_ids.shape)
        self._queries += 1
        return TopicResult(
            theta=np.concatenate(thetas, 0),
            n_td=np.concatenate(counts, 0),
            generation=buf.generation, digest=buf.digest,
            latency_s=time.perf_counter() - t0,
            batch_shape=shapes[0] if len(shapes) == 1 else tuple(shapes))
