from repro.models.config import ModelConfig  # noqa: F401
from repro.models.transformer import init_params, forward  # noqa: F401
