"""Mamba2 / SSD mixer (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: within a chunk of length Q the recurrence

    h_t = a_t · h_{t-1} + Δt_t · B_t ⊗ x_t,     y_t = C_t · h_t + D · x_t

is evaluated as a (masked, decay-weighted) attention-like quadratic form;
across chunks only the (H, P, N) state is carried by a ``lax.scan``.  This
is the memory-bounded formulation the Mamba2 paper uses on hardware —
(B, S, H, P, N) tensors never materialize.

Decode: single-step recurrence with an explicit (conv, ssm) state cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rmsnorm

CHUNK = 256


def ssm_init(key, cfg, dtype=jnp.float32) -> dict:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        # in_proj packs [z (di), xBC (di+2N), dt (H)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log)
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),   # softplus ≈ 0.12
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _split_proj(cfg, proj):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di:2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv along S. xBC: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _segsum_decay(log_a):
    """log_a: (..., Q).  L[i, j] = sum_{j < s <= i} log_a_s  (i >= j)."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    L = cs[..., :, None] - cs[..., None, :]              # (.., i, j)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, L, -jnp.inf)


def ssm_forward(p: dict, cfg, x: jax.Array, cache: dict | None = None):
    """x: (B,S,d) → (B,S,d).  cache = {"conv": (B,W-1,C), "ssm": (B,H,P,N)}."""
    B, S, _ = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, proj)

    new_cache = None
    if cache is None:
        xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    else:
        # decode (S==1) or cache-carrying prefill (S>1): conv uses the
        # stored W-1 history instead of zero padding.
        W = cfg.ssm_conv_width
        hist = jnp.concatenate([cache["conv"], xBC], axis=1)  # (B,W-1+S,C)
        conv_cache = hist[:, -(W - 1):, :]
        out = sum(hist[:, i:i + S, :] * p["conv_w"][i] for i in range(W))
        xBC = jax.nn.silu(out + p["conv_b"])

    xh = xBC[..., :di].reshape(B, S, H, P)
    Bmat = xBC[..., di:di + N]                            # (B,S,N)
    Cmat = xBC[..., di + N:]                              # (B,S,N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])                  # (B,S,H)
    A = -jnp.exp(p["A_log"])                              # (H,)
    log_a = dt * A                                        # (B,S,H) ≤ 0

    if cache is None:
        y, _ = _ssd_chunked(xh, Bmat, Cmat, dt, log_a, p["D"], H, P, N,
                            jnp.zeros((B, H, P, N), jnp.float32))
    elif S == 1:
        h = cache["ssm"]                                  # (B,H,P,N)
        a = jnp.exp(log_a[:, 0])                          # (B,H)
        inp = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0], Bmat[:, 0])
        h = a[..., None, None] * h + inp
        y = jnp.einsum("bhpn,bn->bhp", h, Cmat[:, 0])
        y = y + p["D"][None, :, None] * xh[:, 0]
        y = y.reshape(B, 1, di)
        new_cache = {"conv": conv_cache, "ssm": h}
    else:
        # cache-carrying prefill
        y, h = _ssd_chunked(xh, Bmat, Cmat, dt, log_a, p["D"], H, P, N,
                            cache["ssm"])
        new_cache = {"conv": conv_cache, "ssm": h}

    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], new_cache


def _ssd_chunked(xh, Bmat, Cmat, dt, log_a, D, H, P, N, h0):
    """Chunked SSD over full sequences.  Shapes: xh (B,S,H,P), B/C (B,S,N),
    dt/log_a (B,S,H); h0 (B,H,P,N) initial state.
    Returns (y (B,S,H*P), h_final)."""
    B, S = xh.shape[0], xh.shape[1]
    Q = min(CHUNK, S)
    assert S % Q == 0, "pad sequence to the SSD chunk size"
    nc = S // Q
    # chunk views: (B,nc,Q,...) → scan over nc
    r = lambda t: t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)
    xh_c, B_c, C_c = r(xh), r(Bmat), r(Cmat)
    dt_c, la_c = r(dt), r(log_a)

    def chunk_step(h, inp):
        xq, bq, cq, dtq, laq = inp                    # (B,Q,...)
        # intra-chunk quadratic form
        L = _segsum_decay(laq.transpose(0, 2, 1))     # (B,H,Q,Q)
        G = jnp.einsum("bin,bjn->bij", cq, bq)        # (B,Q,Q)
        M = G[:, None] * jnp.exp(L) * dtq.transpose(0, 2, 1)[:, :, None, :]
        y = jnp.einsum("bhij,bjhp->bihp", M, xq)      # (B,Q,H,P)
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(jnp.cumsum(laq, axis=1))   # (B,Q,H) prod_{s<=i} a
        y = y + jnp.einsum("bin,bih,bhpn->bihp", cq, decay_in, h)
        # state update
        total = decay_in[:, -1]                       # (B,H)
        decay_out = jnp.exp(jnp.cumsum(laq[:, ::-1], axis=1)[:, ::-1]
                            - laq)                    # prod_{j<s<=Q} a
        upd = jnp.einsum("bjh,bjhp,bjn->bhpn", dtq * decay_out, xq, bq)
        h = total[..., None, None] * h + upd
        return h, y

    h_final, ys = lax.scan(
        chunk_step, h0.astype(jnp.float32),
        (xh_c.astype(jnp.float32), B_c.astype(jnp.float32),
         C_c.astype(jnp.float32), dt_c, la_c))
    y = ys.swapaxes(0, 1).reshape(B, S, H, P)
    y = y + D[None, None, :, None] * xh
    return y.reshape(B, S, H * P).astype(xh.dtype), h_final


def init_ssm_cache(cfg, B: int, dtype=jnp.float32) -> dict:
    di, N = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((B, cfg.ssm_conv_width - 1, di + 2 * N), dtype),
        "ssm": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, N),
                         jnp.float32),
    }
