"""Architecture configuration for the assigned-architecture zoo.

One frozen dataclass covers all six families (dense / moe / ssm / hybrid /
audio / vlm); per-layer block layout is derived by :meth:`layer_kinds`.
Every field maps to a published architecture knob; configs cite sources in
``src/repro/configs/<arch>.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

__all__ = ["ModelConfig"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                  # query heads (0 → attention-free)
    num_kv_heads: int
    head_dim: int
    d_ff: int                       # dense-MLP hidden (per gate branch)
    vocab_size: int

    # --- attention flavour --------------------------------------------------
    rope_theta: float = 10_000.0
    qk_norm: bool = False           # qwen3
    attn_logit_softcap: float = 0.0   # gemma2 (0 = off)
    final_logit_softcap: float = 0.0  # gemma2 (0 = off)
    sliding_window: int = 0         # window size for local layers (0 = off)
    local_global_alternating: bool = False  # gemma2 layer pattern
    causal: bool = True             # False → encoder-only (hubert)
    activation: str = "swiglu"      # swiglu | geglu | gelu

    # --- MoE ------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0               # per-expert hidden
    first_k_dense: int = 0          # leading dense layers (deepseek-moe)
    router_aux_coef: float = 0.01   # load-balance loss weight

    # --- SSM (Mamba2 / SSD) -----------------------------------------------------
    ssm_state: int = 0              # d_state (0 = no ssm layers)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    attn_every: int = 0             # hybrid: attention block every k layers
                                    # (zamba2-style shared block)

    # --- modality frontends (stubs per spec) ------------------------------------
    modality: str = "text"          # text | audio_frames | image_patches
    frontend_tokens: int = 0        # patch/frame count prepended (vlm)
    frontend_dim: int = 0           # embedding dim delivered by the stub

    # --- misc -------------------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    source: str = ""                # citation

    # ------------------------------------------------------------------ helpers
    @property
    def attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> list[str]:
        """Mixer kind per layer: 'attn' | 'attn_local' | 'ssm'."""
        kinds = []
        for i in range(self.num_layers):
            if self.arch_type in ("ssm",):
                kinds.append("ssm")
            elif self.arch_type == "hybrid":
                if self.attn_every and (i + 1) % self.attn_every == 0:
                    kinds.append("attn")
                else:
                    kinds.append("ssm")
            elif self.local_global_alternating:
                kinds.append("attn_local" if i % 2 == 0 else "attn")
            elif self.sliding_window:
                kinds.append("attn_local")
            else:
                kinds.append("attn")
        return kinds

    def mlp_kinds(self) -> list[str]:
        """'moe' | 'dense' | 'none' per layer."""
        out = []
        for i in range(self.num_layers):
            if self.arch_type in ("ssm", "hybrid"):
                # mamba2 blocks have no MLP; zamba2's MLP lives in the
                # *shared* attention block (applied every attn_every layers)
                out.append("none")
            elif self.num_experts and i >= self.first_k_dense:
                out.append("moe")
            else:
                out.append("dense")
        return out

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md §5 policy)."""
        return (self.arch_type in ("ssm", "hybrid")
                or self.sliding_window > 0 or self.local_global_alternating)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    # ------------------------------------------------------------------ variants
    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = 0 if self.attention_free else min(self.num_heads, 4)
        n_kv = 0 if self.attention_free else min(
            self.num_kv_heads, max(1, n_heads // 2))
        changes = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=0 if self.attention_free else 32,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            first_k_dense=min(self.first_k_dense, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            attn_every=2 if self.attn_every else 0,
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend_tokens else 0,
            frontend_dim=d_model if self.frontend_dim else 0,
        )
        return dataclasses.replace(self, **changes)

    def with_long_context(self, window: int = 4096) -> "ModelConfig":
        """Sliding-window variant for long_500k on dense archs (DESIGN §5)."""
        if self.arch_type in ("ssm", "hybrid"):
            return self
        return dataclasses.replace(self, sliding_window=window,
                                   local_global_alternating=False,
                                   name=self.name + "-sw")

    # ------------------------------------------------------------------ sizing
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # unembed
        kinds, mlps = self.layer_kinds(), self.mlp_kinds()
        for kind, mlp in zip(kinds, mlps):
            if kind.startswith("attn"):
                q = self.num_heads * self.head_dim
                kv = self.num_kv_heads * self.head_dim
                n += d * q + 2 * d * kv + q * d       # qkv + o
                if self.qk_norm:
                    n += 2 * self.head_dim
            else:                                     # ssm (mamba2)
                di = self.d_inner
                # in_proj: d -> (2*di + 2*d_state + heads); out: di -> d
                n += d * (2 * di + 2 * self.ssm_state + self.ssm_heads)
                n += di * d
                n += self.ssm_conv_width * (di + 2 * self.ssm_state)
                n += 2 * self.ssm_heads               # A_log, dt_bias
            if mlp == "dense":
                gate = 2 if self.activation in ("swiglu", "geglu") else 1
                ff = self.d_ff
                n += d * ff * gate + ff * d
            elif mlp == "moe":
                gate = 2 if self.activation in ("swiglu", "geglu") else 1
                per = self.d_model * self.moe_d_ff * (gate + 1)
                n += self.num_experts * per
                n += self.num_shared_experts * per
                n += d * self.num_experts             # router
            n += 2 * d                                # 2 rmsnorm scales
        if self.arch_type == "hybrid" and self.attn_every:
            # one shared attention+MLP block (zamba2 design)
            q = self.num_heads * self.head_dim
            kv = self.num_kv_heads * self.head_dim
            gate = 2 if self.activation in ("swiglu", "geglu") else 1
            n += d * q + 2 * d * kv + q * d
            n += d * self.d_ff * gate + self.d_ff * d
            n += 2 * d
        n += d                                        # final norm
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        gate = 2 if self.activation in ("swiglu", "geglu") else 1
        per = self.d_model * self.moe_d_ff * (gate + 1)
        moe_layers = sum(1 for m in self.mlp_kinds() if m == "moe")
        inactive = moe_layers * (self.num_experts
                                 - self.experts_per_token) * per
        return full - inactive
