"""Mixture-of-Experts block (deepseek-moe fine-grained, kimi-k2 scale).

Capacity-based top-k routing with bucket dispatch (GShard/Switch family):

    router → top-k (weights, expert ids) per token
    dispatch: scatter tokens into per-expert capacity buckets (overflow drops)
    expert FFN: one batched einsum over the expert axis (MXU-friendly)
    combine: gather back, weight, sum over the k choices

Two execution paths share the dispatch/combine helpers:

* ``moe_forward`` — single-program; the expert axis is left to GSPMD (used
  by smoke tests and as the pjit fallback).
* ``moe_forward_ep`` — explicit expert parallelism under ``shard_map``:
  experts sharded over the 'model' axis, tokens chunked over the same axis,
  exchanged with two ``lax.all_to_all``s (dispatch + return).  Structurally
  the owner-computes pattern of the paper's nomad tokens (DESIGN.md §5):
  each expert's parameters are touched by exactly one device, and token
  activations travel to the owner.

Aux loss: the standard load-balance term (fraction-of-tokens ×
mean-router-prob × E), the MoE analogue of the paper's word-frequency
balancing concern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init

__all__ = ["moe_init", "moe_forward", "moe_forward_ep", "dispatch_indices"]


def moe_init(key, cfg, dtype=jnp.float32) -> dict:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    fscale = 1.0 / jnp.sqrt(f)
    p = {
        "router": dense_init(ks[0], d, E, dtype),
        "w_gate": (jax.random.normal(ks[1], (E, d, f)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d)) * fscale).astype(dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": dense_init(k1, d, fs, dtype),
                       "w_up": dense_init(k2, d, fs, dtype),
                       "w_down": dense_init(k3, fs, d, dtype)}
    return p


# ---------------------------------------------------------------------------
# Dispatch helpers (shared by both paths).
# ---------------------------------------------------------------------------
def dispatch_indices(experts: jax.Array, E: int, cap: int):
    """experts: (n, k) top-k ids.  Returns (dest, rank, keep):
    dest (n*k,) expert id (E for dropped), rank (n*k,) slot within expert.
    Rank = arrival order within each expert (stable), capacity-clipped."""
    flat = experts.reshape(-1)
    nk = flat.shape[0]
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(nk) - first
    rank = jnp.zeros((nk,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = rank < cap
    dest = jnp.where(keep, flat, E).astype(jnp.int32)
    return dest, jnp.minimum(rank, cap - 1), keep


def _router(p, cfg, x_flat):
    logits = x_flat @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, experts = lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balance auxiliary (Switch eq. 4-6)
    E = cfg.num_experts
    frac = jnp.zeros((E,)).at[experts.reshape(-1)].add(1.0) / experts.size
    mean_p = probs.mean(0)
    aux = E * jnp.sum(frac * mean_p)
    return weights, experts, aux


def _expert_ffn(bucket, p):
    """bucket: (E, C, d) → (E, C, d) through each expert's gated FFN."""
    h = jnp.einsum("ecd,edf->ecf", bucket, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", bucket, p["w_up"])
    h = jax.nn.silu(h) * u
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _shared_ffn(x, p):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def _dispatch_combine(p, cfg, x_flat, cap, ffn):
    """Route x_flat (n, d) through capacity buckets; ffn maps (E,C,d)→(E,C,d)."""
    n, d = x_flat.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    weights, experts, aux = _router(p, cfg, x_flat)
    dest, rank, keep = dispatch_indices(experts, E, cap)
    tok_idx = jnp.repeat(jnp.arange(n), k)
    bucket = jnp.zeros((E + 1, cap, d), x_flat.dtype)
    bucket = bucket.at[dest, rank].set(x_flat[tok_idx])
    y_bucket = ffn(bucket[:E])
    y_choice = y_bucket[jnp.minimum(dest, E - 1), rank]       # (n*k, d)
    y_choice = jnp.where(keep[:, None], y_choice, 0.0)
    y = jnp.zeros_like(x_flat).at[tok_idx].add(
        y_choice * weights.reshape(-1)[:, None])
    return y, aux


# ---------------------------------------------------------------------------
# Path 1: single-program (GSPMD handles any sharding).
# ---------------------------------------------------------------------------
def moe_forward(p: dict, cfg, x: jax.Array, *, capacity_factor: float = 1.25):
    """x: (B,S,d) → (y, aux_loss)."""
    B, S, d = x.shape
    n = B * S
    x_flat = x.reshape(n, d)
    cap = _capacity(n, cfg, capacity_factor)
    y, aux = _dispatch_combine(p, cfg, x_flat, cap, lambda b: _expert_ffn(b, p))
    if cfg.num_shared_experts:
        y = y + _shared_ffn(x_flat, p["shared"])
    return y.reshape(B, S, d), aux


def _capacity(n: int, cfg, factor: float) -> int:
    cap = int(n * cfg.experts_per_token / max(cfg.num_experts, 1) * factor)
    return max(8, min(cap, n))


# ---------------------------------------------------------------------------
# Path 2: explicit expert parallelism (inside shard_map over 'model').
# ---------------------------------------------------------------------------
def moe_forward_ep(p_local: dict, cfg, x_local: jax.Array, *,
                   model_axis: str, model_size: int,
                   capacity_factor: float = 1.25):
    """shard_map body.  x_local: (B_loc, S_loc, d) — tokens already chunked
    over the model axis; p_local experts sharded: w_* (E_loc, d, f).

    dispatch → all_to_all to expert owners → batched FFN → all_to_all back
    → combine.  Router weights are replicated.
    """
    B, S, d = x_local.shape
    n = B * S
    M = model_size
    E = cfg.num_experts
    E_loc = E // M
    x_flat = x_local.reshape(n, d)
    cap = _capacity(n, cfg, capacity_factor)
    cap = max(8, -(-cap // M) * M)  # divisible by M for even a2a splits

    weights, experts, aux = _router(
        {"router": p_local["router"]}, cfg, x_flat)
    dest, rank, keep = dispatch_indices(experts, E, cap)
    k = cfg.experts_per_token
    tok_idx = jnp.repeat(jnp.arange(n), k)
    bucket = jnp.zeros((E + 1, cap, d), x_flat.dtype)
    bucket = bucket.at[dest, rank].set(x_flat[tok_idx])
    bucket = bucket[:E].reshape(M, E_loc, cap, d)

    # ship token buckets to expert owners; receive (peer, E_loc, cap, d)
    recv = lax.all_to_all(bucket, model_axis, split_axis=0, concat_axis=0,
                          tiled=False)
    recv = recv.reshape(M, E_loc, cap, d).transpose(1, 0, 2, 3)
    recv = recv.reshape(E_loc, M * cap, d)

    y_loc = _expert_ffn(recv, {k_: p_local[k_]
                               for k_ in ("w_gate", "w_up", "w_down")})

    y_loc = y_loc.reshape(E_loc, M, cap, d).transpose(1, 0, 2, 3)
    back = lax.all_to_all(y_loc, model_axis, split_axis=0, concat_axis=0,
                          tiled=False)
    y_bucket = back.reshape(E, cap, d)

    y_choice = y_bucket[jnp.minimum(dest, E - 1), rank]
    y_choice = jnp.where(keep[:, None], y_choice, 0.0)
    y = jnp.zeros_like(x_flat).at[tok_idx].add(
        y_choice * weights.reshape(-1)[:, None])
    if cfg.num_shared_experts:
        y = y + _shared_ffn(x_flat, p_local["shared"])
    aux = lax.pmean(aux, model_axis)
    return y.reshape(B, S, d), aux
