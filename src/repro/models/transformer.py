"""Composable decoder/encoder transformer covering the 10 assigned archs.

Layer stack is organized into homogeneous **segments** (same mixer + MLP
kind) that are scanned with stacked parameters — one compiled layer body per
segment regardless of depth.  Heterogeneity is expressed as:

* per-layer flag arrays inside a segment (gemma2 local/global alternation);
* a short unstacked prefix (deepseek-moe's first dense layer);
* a *shared* attention block applied periodically inside the SSM scan
  (zamba2's shared-block design — the block reuses one parameter set).

Modalities (DESIGN §5): audio/vlm frontends are stubs per spec — the model
consumes precomputed frame/patch embeddings through a linear projection.

Decode: ``cache`` is a pytree mirroring the segment structure; prefill and
decode share the cache path (prefill writes S tokens at offset 0).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (embed_init, dense_init, mlp_forward,
                                 mlp_init, rmsnorm, softcap)

__all__ = ["init_params", "forward", "init_cache", "segments", "Segment"]


# ---------------------------------------------------------------------------
# Segment planning.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Segment:
    mixer: str            # 'attn' | 'ssm'
    mlp: str              # 'dense' | 'moe' | 'none'
    count: int
    local_flags: tuple    # per-layer sliding-window on/off (attn segments)
    shared_attn_every: int = 0   # hybrid: shared block cadence


def segments(cfg: ModelConfig) -> list[Segment]:
    if cfg.arch_type == "hybrid":
        return [Segment(mixer="ssm", mlp="dense", count=cfg.num_layers,
                        local_flags=(), shared_attn_every=cfg.attn_every)]
    kinds, mlps = cfg.layer_kinds(), cfg.mlp_kinds()
    segs: list[Segment] = []
    i = 0
    while i < cfg.num_layers:
        mixer = "ssm" if kinds[i] == "ssm" else "attn"
        mlp = mlps[i]
        j = i
        flags = []
        while j < cfg.num_layers and mlps[j] == mlp \
                and (("ssm" if kinds[j] == "ssm" else "attn") == mixer):
            flags.append(kinds[j] == "attn_local")
            j += 1
        segs.append(Segment(mixer=mixer, mlp=mlp, count=j - i,
                            local_flags=tuple(flags)))
        i = j
    return segs


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------
def _layer_init(key, cfg, seg: Segment, dtype):
    km, kf, kn = jax.random.split(key, 3)
    p: dict[str, Any] = {
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
    }
    if seg.mixer == "attn":
        p["mixer"] = attn_mod.attn_init(km, cfg, dtype)
    else:
        p["mixer"] = ssm_mod.ssm_init(km, cfg, dtype)
    if seg.mlp == "dense":
        p["mlp"] = mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    elif seg.mlp == "moe":
        p["mlp"] = moe_mod.moe_init(kf, cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size,
                                       dtype)
    if cfg.modality != "text":
        fd = cfg.frontend_dim or cfg.d_model
        params["frontend_proj"] = dense_init(keys[2], fd, cfg.d_model, dtype)

    segs = segments(cfg)
    seg_params = []
    for si, seg in enumerate(segs):
        lkeys = jax.random.split(jax.random.fold_in(keys[3], si), seg.count)
        stacked = jax.vmap(lambda k: _layer_init(k, cfg, seg, dtype))(lkeys)
        seg_params.append(stacked)
    params["segments"] = seg_params

    if cfg.arch_type == "hybrid" and cfg.attn_every:
        k_attn, k_mlp = jax.random.split(keys[4])
        params["shared_attn"] = {
            "norm": jnp.zeros((cfg.d_model,), dtype),
            "attn": attn_mod.attn_init(k_attn, cfg, dtype),
            "norm2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": mlp_init(k_mlp, cfg.d_model, cfg.d_ff, cfg.activation,
                            dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Cache.
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, B: int, S_max: int, dtype=jnp.float32,
               ring: bool = False):
    """ring=True: sliding-window attention segments use a window-sized ring
    buffer instead of an S_max cache (§Perf long-context decode).  Only
    applied to segments where every layer is local."""
    segs = segments(cfg)
    out = {"segments": []}
    for seg in segs:
        if seg.mixer == "attn":
            all_local = seg.local_flags and all(seg.local_flags)
            one = attn_mod.init_attn_cache(cfg, B, S_max, dtype,
                                           ring=ring and all_local)
        else:
            one = ssm_mod.init_ssm_cache(cfg, B, dtype)
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (seg.count,) + a.shape).copy(), one)
        out["segments"].append(stacked)
    if cfg.arch_type == "hybrid" and cfg.attn_every:
        n_apps = cfg.num_layers // cfg.attn_every
        one = attn_mod.init_attn_cache(cfg, B, S_max, dtype)
        out["shared_attn"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n_apps,) + a.shape).copy(), one)
    return out


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------
def _embed_inputs(params, cfg, batch, cache):
    d = cfg.d_model
    if cfg.modality == "audio_frames":
        x = batch["frames"] @ params["frontend_proj"]
        B, S = x.shape[:2]
        positions = jnp.arange(S)[None, :].repeat(B, 0)
    elif cfg.modality == "image_patches" and "patches" in batch:
        tok = params["embed"][batch["tokens"]]
        patches = batch["patches"] @ params["frontend_proj"]
        x = jnp.concatenate([patches, tok], axis=1)
        B, S = x.shape[:2]
        positions = jnp.arange(S)[None, :].repeat(B, 0)
    else:
        x = params["embed"][batch["tokens"]]
        B, S = x.shape[:2]
        if cache is not None and "pos" in batch:
            positions = batch["pos"][:, None] + jnp.arange(S)[None, :]
        else:
            positions = jnp.arange(S)[None, :].repeat(B, 0)
    return x, positions


def _mixer_apply(seg, cfg, lp, x, positions, cache_l, window,
                 attn_seq_sharding=None):
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if seg.mixer == "attn":
        if attn_seq_sharding is not None and h.shape[1] > 1:
            # context parallelism (§Perf pair-2 it.2): shard the sequence
            # over the model axis for attention — queries/scores split S;
            # GSPMD all-gathers the (small, GQA) K/V for the contraction.
            # Used when head counts don't divide the model axis.
            h = jax.lax.with_sharding_constraint(h, attn_seq_sharding)
        y, new_cache = _attn_with_window(lp["mixer"], cfg, h, window,
                                         positions, cache_l)
    else:
        y, new_cache = ssm_mod.ssm_forward(lp["mixer"], cfg, h, cache_l)
    return x + y, new_cache


def _attn_with_window(p, cfg, h, window, positions, cache_l):
    # attn_forward resolves local/global via a (possibly traced) window value
    cfg_local = cfg
    y, new_cache = attn_mod.attn_forward(
        p, cfg_local, h, local=window, positions=positions, cache=cache_l,
        norm_eps=cfg.norm_eps)
    return y, new_cache


def _mlp_apply(seg, cfg, lp, x, ep_ctx):
    if seg.mlp == "none":
        return x, 0.0
    h = rmsnorm(x, lp["norm2"], cfg.norm_eps)
    if seg.mlp == "dense":
        return x + mlp_forward(lp["mlp"], h, cfg.activation), 0.0
    # MoE
    if ep_ctx is not None:
        y, aux = ep_ctx(lp["mlp"], h)
    else:
        y, aux = moe_mod.moe_forward(lp["mlp"], cfg, h)
    return x + y, aux


def _run_segment(seg: Segment, cfg, stacked, x, positions, cache_seg,
                 shared_attn, shared_cache, ep_ctx, act_sharding=None,
                 layer_remat: bool = False, attn_seq_sharding=None):
    local_flags = jnp.asarray(
        [cfg.sliding_window if f else 0 for f in seg.local_flags]
        or [0] * seg.count, jnp.int32)
    apply_shared = jnp.asarray(
        [(i + 1) % seg.shared_attn_every == 0 if seg.shared_attn_every
         else False for i in range(seg.count)], bool)

    has_cache = cache_seg is not None

    def body(carry, xs):
        x, shared_cache, app_idx = carry
        lp, window, shared_flag, cache_l = xs
        if act_sharding is not None:
            # pin the layer-carry (and hence everything remat saves from
            # it) to the batch sharding — without this GSPMD is free to
            # replicate saved residuals across the data axis (§Perf it.1:
            # an 11 TB/device temp blow-up caught by the dry-run).
            x = jax.lax.with_sharding_constraint(x, act_sharding)
        x, new_cache = _mixer_apply(seg, cfg, lp, x, positions, cache_l,
                                    window, attn_seq_sharding)
        x, aux = _mlp_apply(seg, cfg, lp, x, ep_ctx)
        if act_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, act_sharding)

        if seg.shared_attn_every:
            def with_attn(x, shared_cache, app_idx):
                h = rmsnorm(x, shared_attn["norm"], cfg.norm_eps)
                if shared_cache is not None:
                    cache_one = jax.tree_util.tree_map(
                        lambda a: a[app_idx], shared_cache)
                else:
                    cache_one = None
                y, cache_new = attn_mod.attn_forward(
                    shared_attn["attn"], cfg, h, local=0,
                    positions=positions, cache=cache_one,
                    norm_eps=cfg.norm_eps)
                if shared_cache is not None:
                    shared_cache = jax.tree_util.tree_map(
                        lambda full, one: full.at[app_idx].set(one),
                        shared_cache, cache_new)
                x = x + y
                h2 = rmsnorm(x, shared_attn["norm2"], cfg.norm_eps)
                return x + mlp_forward(shared_attn["mlp"], h2,
                                       cfg.activation), shared_cache

            def without(x, shared_cache, app_idx):
                return x, shared_cache

            x, shared_cache = lax.cond(
                shared_flag,
                lambda op: with_attn(*op),
                lambda op: without(*op),
                (x, shared_cache, app_idx))
            app_idx = app_idx + shared_flag.astype(jnp.int32)

        return (x, shared_cache, app_idx), (new_cache, aux)

    if layer_remat and not has_cache:
        # per-layer remat (§Perf it.3): the scan saves only each layer's
        # input; everything inside the block is recomputed in backward.
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (stacked, local_flags, apply_shared, cache_seg)
    (x, shared_cache, _), (new_cache_seg, auxs) = lax.scan(
        body, (x, shared_cache, jnp.zeros((), jnp.int32)), xs)
    aux = auxs.sum() if seg.mlp == "moe" else 0.0
    return x, (new_cache_seg if has_cache else None), shared_cache, aux


def forward(params, cfg: ModelConfig, batch, *, cache=None, ep_ctx=None,
            return_hidden: bool = False, act_sharding=None,
            layer_remat: bool = False, attn_seq_sharding=None):
    """Returns (logits, new_cache, aux_loss).

    batch: {"tokens": (B,S)} (+"pos" (B,) for decode) | audio/vlm variants.
    cache: from init_cache (prefill/decode) or None (training).
    ep_ctx: optional callable (moe_params, x)->(y, aux) for expert-parallel
            execution (installed by the launcher under shard_map).
    return_hidden: skip the LM head — return final-norm hidden states
            (chunked-CE path computes the vocab projection itself).
    """
    x, positions = _embed_inputs(params, cfg, batch, cache)
    if cache is not None and "pos" in batch:
        positions = batch["pos"][:, None] + \
            jnp.arange(x.shape[1])[None, :]

    segs = segments(cfg)
    shared_attn = params.get("shared_attn")
    shared_cache = cache.get("shared_attn") if cache is not None else None
    new_cache = {"segments": []} if cache is not None else None
    aux_total = 0.0
    for si, seg in enumerate(segs):
        cache_seg = cache["segments"][si] if cache is not None else None
        x, new_seg_cache, shared_cache, aux = _run_segment(
            seg, cfg, params["segments"][si], x, positions, cache_seg,
            shared_attn, shared_cache, ep_ctx, act_sharding, layer_remat,
            attn_seq_sharding)
        if cache is not None:
            new_cache["segments"].append(new_seg_cache)
        aux_total = aux_total + aux
    if cache is not None and shared_cache is not None:
        new_cache["shared_attn"] = shared_cache

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, new_cache, aux_total
    head = params.get("lm_head")
    logits = x @ head if head is not None else x @ params["embed"].T
    logits = softcap(logits, cfg.final_logit_softcap)
    return logits, new_cache, aux_total
