"""Grouped-query attention with RoPE, qk-norm, soft-capping, sliding window.

Covers the attention flavours of the assigned archs: GQA (all), qk_norm
(qwen3), logit softcap + local/global alternation (gemma2), bidirectional
(hubert encoder), sliding-window long-context variant (DESIGN §5).

Memory discipline: queries are processed in chunks of ``Q_CHUNK`` via
``lax.scan`` so the (Sq, Sk) score matrix never materializes beyond one
chunk — pure-JAX flash-style attention, good enough for the 32k prefill
shapes (the paper's hot spot is the LDA sampler, not attention — no Pallas
kernel here by design, DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rmsnorm, softcap

Q_CHUNK = 1024


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (B,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                     # (B,S,1,half)
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def attn_init(key, cfg, dtype=jnp.float32) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * dh, dtype),
        "wk": dense_init(ks[1], d, hkv * dh, dtype),
        "wv": dense_init(ks[2], d, hkv * dh, dtype),
        "wo": dense_init(ks[3], hq * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), dtype)
        p["k_norm"] = jnp.zeros((dh,), dtype)
    return p


# ---------------------------------------------------------------------------
# Core scaled-dot-product with masking options (chunked over queries).
# ---------------------------------------------------------------------------
def _sdpa(q, k, v, *, causal: bool, window: int, q_offset,
          logit_cap: float, kv_len=None, kpos=None):
    """q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D); GQA broadcast; returns (B,Sq,Hq,D).

    q_offset: global position of q[0] (decode: the cache length).
    kv_len: number of valid cache entries (decode with preallocated cache).
    kpos: explicit absolute key positions (B,Sk) — ring-buffer caches where
          slot order ≠ position order (entries < 0 are invalid).
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, D) * scale

    if kpos is None:
        kpos_b = jnp.broadcast_to(jnp.arange(Sk)[None, :], (1, Sk))
        valid_k = (kpos_b < kv_len[:, None]) if kv_len is not None \
            else jnp.ones((1, Sk), bool)
    else:
        kpos_b = kpos
        valid_k = kpos_b >= 0

    def chunk_attn(q_chunk, qpos):
        # q_chunk: (B,C,Hkv,G,D); qpos: (B,C); scores (B,C,Hkv,G,Sk)
        s = jnp.einsum("bchgd,bkhd->bchgk", q_chunk.astype(jnp.float32),
                       k.astype(jnp.float32))
        s = softcap(s, logit_cap)
        mask = jnp.broadcast_to(valid_k[:, None, :],
                                (valid_k.shape[0], qpos.shape[1], Sk))
        if causal:
            mask = mask & (kpos_b[:, None, :] <= qpos[:, :, None])
        if window is not None:
            # window may be a traced per-layer value; 0 disables the band.
            win = jnp.asarray(window)
            mask = mask & ((win <= 0)
                           | (kpos_b[:, None, :] > (qpos[:, :, None] - win)))
        s = jnp.where(mask[:, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bchgk,bkhd->bchgd", p,
                          v.astype(jnp.float32)).astype(q.dtype)

    q_offset = jnp.broadcast_to(q_offset, (B,))
    if Sq <= Q_CHUNK:
        qpos = q_offset[:, None] + jnp.arange(Sq)[None, :]
        out = chunk_attn(qg, qpos)
    else:
        n_chunks = Sq // Q_CHUNK
        assert Sq % Q_CHUNK == 0, "pad sequence to the query chunk size"
        qc = qg.reshape(B, n_chunks, Q_CHUNK, Hkv, G, D)

        def body(_, qi):
            q_chunk, ci = qi
            qpos = (q_offset[:, None] + ci * Q_CHUNK
                    + jnp.arange(Q_CHUNK)[None, :])
            return None, chunk_attn(q_chunk, qpos)

        _, out = lax.scan(body, None,
                          (jnp.moveaxis(qc, 1, 0),
                           jnp.arange(n_chunks)))
        out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hkv, G, D)
    return out.reshape(B, Sq, Hq, D)


# ---------------------------------------------------------------------------
# Full block forward (projections + rope + cache handling).
# ---------------------------------------------------------------------------
def attn_forward(p: dict, cfg, x: jax.Array, *, local,
                 positions: jax.Array, cache: dict | None = None,
                 norm_eps: float = 1e-6):
    """x: (B,S,d).  cache: {"k","v": (B,S_max,Hkv,D), "len": (B,)} for decode.

    ``local``: sliding-window size for this layer (0/False = global; may be
    a traced per-layer value from a scanned flag array).

    Returns (y, new_cache).  Training/prefill: cache=None, positions (S,).
    Decode: S==1, positions (B,1) = current index, cache updated in place.
    """
    B, S, d = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, hq, dh)
    k = (x @ p["wk"]).reshape(B, S, hkv, dh)
    v = (x @ p["wv"]).reshape(B, S, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], norm_eps)
        k = rmsnorm(k, p["k_norm"], norm_eps)
    pos_b = positions if positions.ndim == 2 else positions[None, :]
    q = rope(q, pos_b, cfg.rope_theta)
    k = rope(k, pos_b, cfg.rope_theta)

    window = jnp.asarray(0 if local is False or local is None else local,
                         jnp.int32)
    new_cache = None
    if cache is None:
        off = positions[0] if positions.ndim == 1 else positions[:, 0]
        out = _sdpa(q, k, v, causal=cfg.causal, window=window,
                    q_offset=off, logit_cap=cfg.attn_logit_softcap)
    elif "slot_pos" in cache:
        # ring buffer (sliding-window archs): slot = pos % cache size.
        # Keys are cached post-RoPE; slot_pos holds absolute positions so
        # the causal/window masks survive wrap-around.  S must be 1.
        S_cache = cache["k"].shape[1]
        idx = cache["len"]                                   # (B,) abs pos
        slot = idx % S_cache
        k_cache = _batch_update(cache["k"], k, slot)
        v_cache = _batch_update(cache["v"], v, slot)
        slot_pos = jax.vmap(
            lambda sp, s_, i_: sp.at[s_].set(i_))(
                cache["slot_pos"], slot, idx.astype(jnp.int32))
        out = _sdpa(q, k_cache, v_cache, causal=cfg.causal, window=window,
                    q_offset=idx, logit_cap=cfg.attn_logit_softcap,
                    kpos=slot_pos)
        new_cache = {"k": k_cache, "v": v_cache, "len": idx + S,
                     "slot_pos": slot_pos}
    else:
        # decode: append this step's k/v at index cache["len"]
        idx = cache["len"]                                   # (B,)
        k_cache = _batch_update(cache["k"], k, idx)
        v_cache = _batch_update(cache["v"], v, idx)
        new_len = idx + S
        out = _sdpa(q, k_cache, v_cache, causal=cfg.causal, window=window,
                    q_offset=idx, logit_cap=cfg.attn_logit_softcap,
                    kv_len=new_len)
        new_cache = {"k": k_cache, "v": v_cache, "len": new_len}
    y = out.reshape(B, S, hq * dh) @ p["wo"]
    return y, new_cache


def _batch_update(cache: jax.Array, new: jax.Array,
                  idx: jax.Array) -> jax.Array:
    """Write new (B,S,...) into cache (B,S_max,...) at per-batch offset idx."""
    B, S = new.shape[0], new.shape[1]

    def upd(c, n, i):
        return lax.dynamic_update_slice(c, n.astype(c.dtype),
                                        (i,) + (0,) * (c.ndim - 1))
    return jax.vmap(upd)(cache, new, idx)


def init_attn_cache(cfg, B: int, S_max: int, dtype=jnp.float32,
                    ring: bool = False) -> dict:
    """ring=True (sliding-window archs): cache holds only ``window`` slots —
    the long_500k memory-term optimization (§Perf)."""
    S_cache = min(S_max, cfg.sliding_window) if ring and cfg.sliding_window \
        else S_max
    out = {
        "k": jnp.zeros((B, S_cache, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((B, S_cache, cfg.num_kv_heads, cfg.head_dim), dtype),
        "len": jnp.zeros((B,), jnp.int32),
    }
    if ring and cfg.sliding_window and S_cache < S_max:
        out["slot_pos"] = jnp.full((B, S_cache), -1, jnp.int32)
    return out
