"""Shared neural building blocks (pure-functional, param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm", "softcap", "dense_init", "mlp_init", "mlp_forward",
           "embed_init"]


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma2 logit soft-capping: cap·tanh(x/cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2, 2, (d_in, d_out),
                                        jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.truncated_normal(key, -2, 2, (vocab, d), jnp.float32)
            ).astype(dtype)


def mlp_init(key, d: int, d_ff: int, activation: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    gated = activation in ("swiglu", "geglu")
    p = {"w_up": dense_init(k1, d, d_ff, dtype),
         "w_down": dense_init(k2, d_ff, d, dtype)}
    if gated:
        p["w_gate"] = dense_init(k3, d, d_ff, dtype)
    return p


def _act(x: jax.Array, activation: str) -> jax.Array:
    if activation in ("swiglu", "silu"):
        return jax.nn.silu(x)
    return jax.nn.gelu(x)


def mlp_forward(p: dict, x: jax.Array, activation: str) -> jax.Array:
    up = x @ p["w_up"]
    if "w_gate" in p:
        up = _act(x @ p["w_gate"], activation) * up
    else:
        up = _act(up, activation)
    return up @ p["w_down"]
