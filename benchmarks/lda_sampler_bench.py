"""Paper Table 2 / Fig. 4c-d: per-token LDA sampling cost by method.

Runs one sweep of each LDA sampler on the same synthetic corpus and reports
µs/token plus the speedup over the naive dense reference (Fig. 4's y-axis
is 'speedup over the normal LDA implementation which takes O(T) time')."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row, time_fn
from repro.core import cgs
from repro.core.alias_lda import sweep_alias_lda
from repro.core.sparse_lda import sweep_sparse_lda
from repro.data import synthetic


def run(T: int = 64, num_docs: int = 300, seed: int = 0) -> list[str]:
    corpus, _, _ = synthetic.make_corpus(
        num_docs=num_docs, vocab_size=512, num_topics=T,
        mean_doc_len=50.0, seed=seed)
    alpha, beta = 50.0 / T, 0.01
    doc_ids = jnp.asarray(corpus.doc_ids)
    word_ids = jnp.asarray(corpus.word_ids)
    N = corpus.num_tokens

    dorder_np = corpus.doc_order()
    dorder = jnp.asarray(dorder_np)
    dbound = jnp.asarray(np.concatenate(
        [[True], corpus.doc_ids[dorder_np][1:]
         != corpus.doc_ids[dorder_np][:-1]]))
    worder_np = corpus.word_order()
    worder = jnp.asarray(worder_np)
    wbound = jnp.asarray(corpus.word_boundary(worder_np))

    state0 = cgs.init_state(corpus, T, jax.random.key(0))

    sweeps = {
        "reference_dense": jax.jit(lambda s: cgs.sweep_reference(
            s, doc_ids, word_ids, dorder, alpha, beta)),
        "fplda_word": jax.jit(lambda s: cgs.sweep_fplda_word(
            s, doc_ids, word_ids, worder, wbound, alpha, beta)),
        "fplda_doc": jax.jit(lambda s: cgs.sweep_fplda_doc(
            s, doc_ids, word_ids, dorder, dbound, alpha, beta)),
        "sparse_lda": jax.jit(lambda s: sweep_sparse_lda(
            s, doc_ids, word_ids, dorder, alpha, beta)),
        "alias_lda": jax.jit(lambda s: sweep_alias_lda(
            s, doc_ids, word_ids, dorder, alpha, beta)),
    }

    out = []
    base = None
    for name, fn in sweeps.items():
        t = time_fn(fn, state0, warmup=1, iters=3) / N
        if name == "reference_dense":
            base = t
        out.append(row(f"table2/{name}", t * 1e6,
                       f"speedup_vs_dense={base / t:.2f}x"))
    return out
