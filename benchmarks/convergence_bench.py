"""Paper Fig. 4a-b: convergence (log-likelihood vs iteration) per sampler.

All exact samplers must track each other per-iteration; AliasLDA (MH,
non-exact proposal) may lag slightly — exactly the paper's observation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row
from repro.core import cgs, likelihood
from repro.core.alias_lda import sweep_alias_lda
from repro.core.sparse_lda import sweep_sparse_lda
from repro.data import synthetic


def run(T: int = 32, iters: int = 8, seed: int = 0) -> list[str]:
    corpus, _, _ = synthetic.make_corpus(
        num_docs=200, vocab_size=256, num_topics=T, mean_doc_len=40.0,
        seed=seed)
    alpha, beta = 50.0 / T, 0.01
    doc_ids = jnp.asarray(corpus.doc_ids)
    word_ids = jnp.asarray(corpus.word_ids)
    dorder_np = corpus.doc_order()
    dorder = jnp.asarray(dorder_np)
    dbound = jnp.asarray(np.concatenate(
        [[True], corpus.doc_ids[dorder_np][1:]
         != corpus.doc_ids[dorder_np][:-1]]))
    worder_np = corpus.word_order()
    worder = jnp.asarray(worder_np)
    wbound = jnp.asarray(corpus.word_boundary(worder_np))

    sweeps = {
        "fplda_word": lambda s: cgs.sweep_fplda_word(
            s, doc_ids, word_ids, worder, wbound, alpha, beta),
        "fplda_doc": lambda s: cgs.sweep_fplda_doc(
            s, doc_ids, word_ids, dorder, dbound, alpha, beta),
        "sparse_lda": lambda s: sweep_sparse_lda(
            s, doc_ids, word_ids, dorder, alpha, beta),
        "alias_lda": lambda s: sweep_alias_lda(
            s, doc_ids, word_ids, dorder, alpha, beta),
    }

    out = []
    finals = {}
    for name, fn in sweeps.items():
        fn = jax.jit(fn)
        state = cgs.init_state(corpus, T, jax.random.key(7))
        lls = [likelihood.per_token_ll(state, alpha, beta)]
        for _ in range(iters):
            state = fn(state)
            lls.append(likelihood.per_token_ll(state, alpha, beta))
        finals[name] = lls[-1]
        traj = ";".join(f"{x:.3f}" for x in lls)
        out.append(row(f"fig4/{name}/final_ll_per_token", -lls[-1] * 1e6,
                       f"trajectory={traj}"))
    spread = max(finals.values()) - min(finals.values())
    out.append(row("fig4/exact_sampler_spread", spread * 1e6,
                   "exact samplers converge together" if spread < 0.2
                   else "WARN: samplers diverged"))
    return out
