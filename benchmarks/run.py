"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sections:
    table1/…   sampler complexity (paper Table 1)
    table2/…   LDA per-token cost by method (paper Table 2, Fig 4c-d)
    fig4/…     convergence per sampler (paper Fig 4a-b)
    fig5/…     multicore nomad scaling (paper Fig 5)
    kernels/…  Pallas kernel oracle checks
    sweep/…    scan vs fused vs nomad tokens/sec (writes BENCH_sweep.json)
    serve/…    fold-in θ-query latency/throughput (writes BENCH_serve.json)
    roofline/… (arch × shape × mesh) roofline terms from the dry-run

Besides the CSV, the sweep section records its numbers in
``BENCH_sweep.json`` at the repo root — the machine-readable perf
trajectory successive PRs diff against.

Env: REPRO_BENCH_FAST=1 skips the slow multi-device scaling section and
shrinks the sweep section's ring.
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    sections = []
    from benchmarks import (bucket_bench, convergence_bench, kernel_bench,
                            lda_sampler_bench, roofline_bench,
                            sampler_bench, serve_bench, sweep_bench)
    sections = [
        ("table1", sampler_bench.run),
        ("table2", lda_sampler_bench.run),
        ("fig4", convergence_bench.run),
        ("sec3.3", bucket_bench.run),
        ("kernels", kernel_bench.run),
        ("sweep", sweep_bench.run),
        ("serve", serve_bench.run),
        ("roofline", roofline_bench.run),
    ]
    if not os.environ.get("REPRO_BENCH_FAST"):
        from benchmarks import scaling_bench
        sections.append(("fig5", scaling_bench.run))

    print("name,us_per_call,derived")
    ok = True
    for name, fn in sections:
        try:
            for line in fn():
                print(line, flush=True)
        except Exception:  # noqa: BLE001
            ok = False
            print(f"{name}/ERROR,-1,{traceback.format_exc(limit=2)!r}",
                  flush=True)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
