"""Paper §3.3 ablation: SparseLDA's bucket-mass argument.

SparseLDA's use of LSearch is justified by the claim that "most mass of p_t
is contributed from the third (word-sparse) term", so the expensive dense
smoothing bucket is rarely entered.  We measure actual bucket hit rates
during sweeps — early (random z, diffuse counts) vs late (converged,
concentrated counts) — reproducing why the trick works and when it doesn't.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row
from repro.core import cgs
from repro.core.sparse_lda import sweep_sparse_lda
from repro.data import synthetic


def run(T: int = 64, seed: int = 0) -> list[str]:
    corpus, _, _ = synthetic.make_corpus(
        num_docs=200, vocab_size=512, num_topics=T, mean_doc_len=60.0,
        seed=seed)
    alpha, beta = 50.0 / T, 0.01
    doc_ids = jnp.asarray(corpus.doc_ids)
    word_ids = jnp.asarray(corpus.word_ids)
    order = jnp.asarray(corpus.doc_order())
    sweep = jax.jit(lambda s: sweep_sparse_lda(
        s, doc_ids, word_ids, order, alpha, beta,
        return_bucket_stats=True))

    state = cgs.init_state(corpus, T, jax.random.key(0))
    out = []
    for it in range(6):
        state, buckets = sweep(state)
        b = np.asarray(buckets)
        rates = [float((b == k).mean()) for k in range(3)]
        if it in (0, 5):
            tag = "first_sweep" if it == 0 else "converged"
            out.append(row(
                f"sec3.3/bucket_hit_rates/{tag}", rates[2] * 100,
                f"word_bucket={rates[2]:.3f};doc_bucket={rates[1]:.3f};"
                f"smoothing={rates[0]:.3f}"))
    word_rate = float((np.asarray(buckets) == 2).mean())
    out.append(row("sec3.3/word_bucket_dominates", word_rate * 100,
                   "paper's LSearch-justification holds"
                   if word_rate > 0.5 else "WARN: diffuse counts"))
    return out
