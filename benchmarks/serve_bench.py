"""Serving latency/throughput benchmark + ``BENCH_serve.json`` record.

Measures the :class:`repro.serve.lda_engine.LdaEngine` θ-query path —
the millions-of-users workload (DESIGN.md §10) — end to end, per query:
pack → device transfer → jitted multi-sweep fold-in → θ → host.  For
each inner mode ∈ {scan, fused} × batch size ∈ {1, 8, 64} it reports
**p50/p99 latency** (ms) and **docs/sec** over a fixed pool of
variable-length documents, plus a ``publish`` row (snapshot build +
atomic install) and an in-process ``refclock`` row (a fixed jitted
matmul) that prices the host/XLA speed at snapshot time.  The two inner
modes answer from the same snapshot/pool/keys, so their counts are also
cross-checked bit-for-bit (an ``ERROR`` row is emitted on divergence).

Like ``BENCH_sweep.json``, full-size runs maintain a **history** of
per-PR snapshots at the repo root (``{"history": [{"rev", "timing",
"entries"}]}``); a re-run at the same rev replaces its own snapshot.
``--check-regression`` (wired into ``tools/ci.sh --bench-smoke``) gates:

* per-batch docs/sec against the previous same-epoch snapshot — a row
  fails only if it regresses under both the raw ratio and the
  refclock-normalized ratio (default 40%, REPRO_SERVE_REGRESSION_PCT);
* the **batching canary**: docs/sec at batch=64 over batch=1 from the
  same snapshot — same process, so host noise cancels — must stay above
  the threshold ratio (default 1.3, REPRO_SERVE_CANARY_RATIO).  Batched
  serving that stops paying for itself is the structural failure this
  file exists to catch (e.g. an accidental per-doc recompile or a
  pack that stops bucketing shapes);
* the **overload row** (DESIGN.md §11): an engine behind admission
  control (``max_pending``/``degrade_pending``) under a thread flood —
  shed rate, degraded-answer fraction and degraded p99 — gated by
  ``_check_overload`` on within-entry invariants only (something shed,
  pending stayed bounded, every attempt accounted, degraded p99 within
  REPRO_SERVE_OVERLOAD_P99_RATIO × the entry's own p50).

Env: REPRO_BENCH_FAST=1 shrinks sizes/query counts and never touches
the committed history.  Interpret-free pure-JAX CPU numbers: structure,
not silicon.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.util import row

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO, "BENCH_serve.json")

BATCHES = (1, 8, 64)
INNER_MODES = ("scan", "fused")

# Timing-methodology epoch (see sweep_bench.TIMING_EPOCH): rows are only
# gated against a previous snapshot from the same epoch.
TIMING_EPOCH = "perquery-p50p99"


def _mk_engine(fast: bool, inner_mode: str = "scan"):
    import jax

    from repro.serve.lda_engine import LdaEngine, snapshot_from_counts

    J, T = (256, 16) if fast else (2048, 64)
    rng = np.random.default_rng(11)
    n_wt = rng.integers(0, 200, (J, T))
    snap = snapshot_from_counts(n_wt, n_wt.sum(0), alpha=50.0 / T,
                                beta=0.01)
    t0 = time.perf_counter()
    eng = LdaEngine(snap, sweeps=3 if fast else 5, tile=8, max_batch=64,
                    inner_mode=inner_mode)
    publish_s = time.perf_counter() - t0
    pool = [rng.integers(0, J, int(n)).astype(np.int32)
            for n in rng.geometric(1 / 20.0, size=64).clip(1, 64)]
    return eng, snap, pool, publish_s, (J, T), jax


def _refclock(jax_mod, phi) -> float:
    """Fixed jitted matmul, median-of-5: the host/XLA speed proxy rows
    are normalized by across snapshots (same role as sweep_bench's
    serial-scan baseline)."""
    import jax.numpy as jnp
    x = jnp.asarray(phi[:256, :16])
    f = jax_mod.jit(lambda a: (a @ a.T).sum())
    jax_mod.block_until_ready(f(x))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        jax_mod.block_until_ready(f(x))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[2]


def _measure(fast: bool) -> list[dict]:
    from repro.serve.lda_engine import TopicQuery
    import jax

    engines = {m: _mk_engine(fast, m) for m in INNER_MODES}
    _, snap, pool, publish_s, (J, T), jax_mod = engines["scan"]
    n_queries = 8 if fast else 40
    entries = [{"path": "publish", "J": J, "T": T,
                "publish_ms": publish_s * 1e3},
               {"path": "refclock", "ref_sec": _refclock(jax_mod, snap.phi)}]
    # parity witness: per inner mode, the counts of one probe query per
    # batch size — the modes share snapshot/pool/keys so these must be
    # bit-identical
    probe_ntd = {m: {} for m in INNER_MODES}
    for inner in INNER_MODES:
        eng = engines[inner][0]
        for b in BATCHES:
            def q(i):
                docs = tuple(pool[(i * b + j) % len(pool)]
                             for j in range(b))
                return eng.query(TopicQuery(docs=docs,
                                            key=jax.random.key(i % 4)))
            for i in range(n_queries):          # warm every length bucket
                q(i)                            # the rotation will hit
            lats, docs_done = [], 0
            t0 = time.perf_counter()
            for i in range(n_queries):
                res = q(i)
                lats.append(res.latency_s)
                docs_done += b
            wall = time.perf_counter() - t0
            probe_ntd[inner][b] = np.asarray(q(0).n_td)
            lats = np.sort(np.asarray(lats))
            entries.append({
                "path": "serve", "inner": inner, "batch": b,
                "J": J, "T": T,
                "sweeps": eng.sweeps, "queries": n_queries,
                "p50_ms": float(np.percentile(lats, 50) * 1e3),
                "p99_ms": float(np.percentile(lats, 99) * 1e3),
                "docs_per_sec": docs_done / wall,
            })
    parity_ok = all(
        np.array_equal(probe_ntd["scan"][b], probe_ntd["fused"][b])
        for b in BATCHES)
    entries.append({"path": "parity", "J": J, "T": T,
                    "modes": list(INNER_MODES), "batches": list(BATCHES),
                    "bit_identical": parity_ok})
    entries.append(_overload_entry(fast))
    return entries


def _overload_entry(fast: bool) -> dict:
    """Overload row (DESIGN.md §11): a fresh engine behind admission
    control under a thread flood — more concurrent readers than
    ``max_pending`` admits, so the engine must shed and degrade rather
    than queue.  Reports the shed rate, the degraded fraction of the
    answers that were admitted, and p50/p99 over them; every number the
    gate judges is a within-entry ratio from this one process, immune to
    host-speed drift between snapshots."""
    import threading

    import jax

    from repro.serve.lda_engine import (EngineOverloadedError, LdaEngine,
                                        TopicQuery, snapshot_from_counts)

    J, T = (256, 16) if fast else (1024, 32)
    max_pending, degrade_pending = 2, 1
    rng = np.random.default_rng(13)
    n_wt = rng.integers(0, 200, (J, T))
    snap = snapshot_from_counts(n_wt, n_wt.sum(0), alpha=50.0 / T,
                                beta=0.01)
    eng = LdaEngine(snap, sweeps=8, tile=8, max_batch=8,
                    max_pending=max_pending,
                    degrade_pending=degrade_pending, degraded_sweeps=2)
    docs = tuple(rng.integers(0, J, 12).astype(np.int32) for _ in range(3))
    # warm both jit variants (full + degraded sweep counts) so the flood
    # measures serving, not compilation
    eng.query(TopicQuery(docs=docs))
    eng.query(TopicQuery(docs=docs, sweeps=eng.degraded_sweeps))

    n_threads = 6 if fast else 8
    per_thread = 12 if fast else 25
    lock = threading.Lock()
    lats, deg_lats = [], []
    shed = [0] * n_threads

    def flood(tid):
        for i in range(per_thread):
            try:
                res = eng.query(TopicQuery(
                    docs=docs, key=jax.random.key(tid * 997 + i)))
            except EngineOverloadedError:
                shed[tid] += 1
                continue
            with lock:
                lats.append(res.latency_s)
                if res.degraded:
                    deg_lats.append(res.latency_s)

    threads = [threading.Thread(target=flood, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    stats = eng.stats()
    attempted = n_threads * per_thread
    lat_a = np.sort(np.asarray(lats if lats else [0.0]))
    deg_a = np.sort(np.asarray(deg_lats)) if deg_lats else None
    return {
        "path": "overload", "J": J, "T": T, "sweeps": eng.sweeps,
        "degraded_sweeps": eng.degraded_sweeps, "threads": n_threads,
        "attempted": attempted, "answered": len(lats),
        "shed": int(sum(shed)), "shed_rate": sum(shed) / attempted,
        "degraded_answers": len(deg_lats),
        "degraded_fraction": len(deg_lats) / max(len(lats), 1),
        "p50_ms": float(np.percentile(lat_a, 50) * 1e3),
        "p99_ms": float(np.percentile(lat_a, 99) * 1e3),
        "degraded_p99_ms": (float(np.percentile(deg_a, 99) * 1e3)
                            if deg_a is not None else 0.0),
        "max_pending": max_pending,
        "max_pending_seen": stats["max_pending_seen"],
        "accounted": len(lats) + sum(shed) == attempted,
    }


# ---------------------------------------------------------------------------
# History bookkeeping + regression gate (the BENCH_sweep.json pattern).
# ---------------------------------------------------------------------------
def _load_history() -> dict:
    if not os.path.exists(BENCH_JSON):
        return {"history": []}
    with open(BENCH_JSON) as f:
        return json.load(f)


def _git_rev() -> str:
    if os.environ.get("REPRO_BENCH_LABEL"):
        return os.environ["REPRO_BENCH_LABEL"]
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, cwd=REPO,
                             timeout=30)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def _ref_sec(entries: list[dict]) -> float:
    for e in entries:
        if e.get("path") == "refclock":
            return float(e.get("ref_sec", 0.0))
    return 0.0


def _check_canary(hist: list[dict]) -> list[str]:
    """Batching canary on the latest snapshot, per inner mode: docs/sec
    at batch=64 must exceed batch=1 by REPRO_SERVE_CANARY_RATIO (default
    1.3).  Both rows come from the same process seconds apart, so the
    ratio is immune to host-speed drift between snapshots.  Snapshots
    from before the inner-mode axis carry no ``inner`` field; those rows
    are the scan path."""
    ratio_min = float(os.environ.get("REPRO_SERVE_CANARY_RATIO", "1.3"))
    if not hist:
        return []
    out = []
    by_inner = {}
    for e in hist[-1]["entries"]:
        if e.get("path") == "serve":
            by_inner.setdefault(e.get("inner", "scan"), {})[
                e.get("batch")] = e
    for inner, rows in sorted(by_inner.items()):
        b1, b64 = rows.get(1), rows.get(max(BATCHES))
        if not b1 or not b64 or b1["docs_per_sec"] <= 0:
            continue
        ratio = b64["docs_per_sec"] / b1["docs_per_sec"]
        if ratio < ratio_min:
            out.append(
                f"serve canary [{inner}]: batch={max(BATCHES)} "
                f"({b64['docs_per_sec']:.0f} docs/s) is only {ratio:.2f}x "
                f"batch=1 ({b1['docs_per_sec']:.0f} docs/s, same process), "
                f"floor {ratio_min:.2f}x — batching stopped paying "
                f"({hist[-1]['rev']})")
    return out


def _check_parity(hist: list[dict]) -> list[str]:
    """The fused×scan parity witness recorded in the latest snapshot
    must hold — a bench run whose two inner modes diverged is reporting
    numbers for two different algorithms."""
    if not hist:
        return []
    return [
        f"serve parity: inner modes {e.get('modes')} diverged bit-wise "
        f"on batches {e.get('batches')} ({hist[-1]['rev']})"
        for e in hist[-1]["entries"]
        if e.get("path") == "parity" and not e.get("bit_identical", True)]


def _check_overload(hist: list[dict]) -> list[str]:
    """Overload gate on the latest snapshot (DESIGN.md §11): the flood
    must actually shed (admission control alive), in-flight queries must
    stay within the configured ``max_pending`` bound, every attempt must
    be accounted as answered-or-shed, and the degraded-answer p99 must
    stay within REPRO_SERVE_OVERLOAD_P99_RATIO (default 50) × the
    entry's own p50 — a degraded path that got *slower* than the median
    admitted query means shedding stopped protecting latency.  All
    within-entry ratios from one process: host drift between snapshots
    can't trip them.  Pre-overload snapshots carry no such row and are
    skipped."""
    ratio_cap = float(os.environ.get(
        "REPRO_SERVE_OVERLOAD_P99_RATIO", "50"))
    if not hist:
        return []
    out = []
    for e in hist[-1]["entries"]:
        if e.get("path") != "overload":
            continue
        tag = f"serve overload J{e['J']}T{e['T']}/th{e['threads']}"
        rev = hist[-1]["rev"]
        if e["shed"] <= 0:
            out.append(f"{tag}: the flood shed nothing — admission "
                       f"control is inert ({rev})")
        if e["max_pending_seen"] > e["max_pending"]:
            out.append(f"{tag}: max_pending_seen={e['max_pending_seen']} "
                       f"exceeded the configured bound {e['max_pending']} "
                       f"— the queue is no longer bounded ({rev})")
        if not e.get("accounted", True):
            out.append(f"{tag}: answered ({e['answered']}) + shed "
                       f"({e['shed']}) != attempted ({e['attempted']}) — "
                       f"queries vanished ({rev})")
        if (e["degraded_answers"] > 0
                and e["degraded_p99_ms"] > ratio_cap
                * max(e["p50_ms"], 1e-6)):
            out.append(
                f"{tag}: degraded p99 {e['degraded_p99_ms']:.1f}ms is "
                f"{e['degraded_p99_ms'] / max(e['p50_ms'], 1e-6):.0f}x "
                f"the entry's p50 {e['p50_ms']:.2f}ms (same process), "
                f"limit {ratio_cap:.0f}x ({rev})")
    return out


def check_regression(threshold: float | None = None) -> list[str]:
    """Compare the last two same-epoch snapshots' serve rows on docs/sec;
    a row fails only when it regresses past the threshold under every
    normalization (raw, and refclock-normalized — snapshots come from
    whatever machine produced them)."""
    if threshold is None:
        threshold = float(os.environ.get(
            "REPRO_SERVE_REGRESSION_PCT", "40")) / 100.0
    hist = _load_history()["history"]
    regressions = (_check_canary(hist) + _check_overload(hist)
                   + _check_parity(hist))
    if len(hist) < 2:
        return regressions
    if hist[-2].get("timing") != hist[-1].get("timing"):
        print(f"serve gate: timing epoch changed "
              f"({hist[-2].get('timing')} -> {hist[-1].get('timing')}); "
              f"pairwise row gate skipped, canary still active")
        return regressions
    ref_old, ref_new = _ref_sec(hist[-2]["entries"]), \
        _ref_sec(hist[-1]["entries"])
    # rows are keyed (batch, inner); pre-axis snapshots had no inner
    # field — their rows are the scan path
    prev = {(e.get("batch"), e.get("inner", "scan")): e
            for e in hist[-2]["entries"] if e.get("path") == "serve"}
    for e in hist[-1]["entries"]:
        if e.get("path") != "serve":
            continue
        inner = e.get("inner", "scan")
        old = prev.get((e.get("batch"), inner))
        if old is None or old["docs_per_sec"] <= 0:
            continue
        ratio = e["docs_per_sec"] / old["docs_per_sec"]
        if ref_old > 0 and ref_new > 0:
            # docs/sec · ref_sec cancels host speed at snapshot time
            ratio = max(ratio, (e["docs_per_sec"] * ref_new)
                        / (old["docs_per_sec"] * ref_old))
        if ratio < 1.0 - threshold:
            regressions.append(
                f"serve/{inner}/batch{e['batch']}: "
                f"{old['docs_per_sec']:.0f} -> "
                f"{e['docs_per_sec']:.0f} docs/s "
                f"({(1 - ratio) * 100:.0f}% drop under every "
                f"normalization, limit {threshold * 100:.0f}%; "
                f"{hist[-2]['rev']} -> {hist[-1]['rev']})")
    return regressions


def run() -> list[str]:
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    entries = _measure(fast)
    if not fast:
        # only full-size runs touch the committed trajectory; a re-run at
        # the same rev replaces its own snapshot
        data = _load_history()
        rev = _git_rev()
        snap = {"rev": rev, "timing": TIMING_EPOCH, "entries": entries}
        if data["history"] and data["history"][-1]["rev"] == rev:
            data["history"][-1] = snap
        else:
            data["history"].append(snap)
        with open(BENCH_JSON, "w") as f:
            json.dump(data, f, indent=1)

    out = []
    for e in entries:
        if e["path"] == "publish":
            out.append(row(f"serve/publish/J{e['J']}T{e['T']}",
                           e["publish_ms"] * 1e3,
                           f"publish_ms={e['publish_ms']:.2f}"))
        elif e["path"] == "refclock":
            out.append(row("serve/refclock", e["ref_sec"] * 1e6,
                           f"ref_sec={e['ref_sec']:.6f}"))
        elif e["path"] == "overload":
            out.append(row(
                f"serve/overload/J{e['J']}T{e['T']}/th{e['threads']}",
                e["p99_ms"] * 1e3,
                f"shed_rate={e['shed_rate']:.2f};"
                f"degraded_fraction={e['degraded_fraction']:.2f};"
                f"degraded_p99_ms={e['degraded_p99_ms']:.2f};"
                f"p50_ms={e['p50_ms']:.2f};"
                f"max_pending_seen={e['max_pending_seen']}"))
            if not e.get("accounted", True):
                # vanished queries must fail the smoke grep even though
                # the harness itself exits 0
                out.append(row(
                    f"serve/overload/J{e['J']}T{e['T']}/ERROR", -1.0,
                    "queries_unaccounted"))
        elif e["path"] == "parity":
            out.append(row("serve/parity/fusedxscan",
                           1.0 if e["bit_identical"] else -1.0,
                           f"bit_identical={e['bit_identical']}"))
            if not e["bit_identical"]:
                out.append(row("serve/parity/ERROR", -1.0,
                               "inner_modes_diverged"))
        else:
            out.append(row(
                f"serve/query/{e.get('inner', 'scan')}"
                f"/batch{e['batch']}/J{e['J']}T{e['T']}"
                f"/s{e['sweeps']}",
                e["p50_ms"] * 1e3,
                f"p50_ms={e['p50_ms']:.2f};p99_ms={e['p99_ms']:.2f};"
                f"docs_per_sec={e['docs_per_sec']:.1f}"))
    out.append(row("serve/json", 0.0,
                   ("skipped=fast_mode" if fast else
                    f"wrote={os.path.basename(BENCH_JSON)}")
                   + f";entries={len(entries)}"))
    return out


def main() -> None:
    if "--check-regression" in sys.argv:
        regs = check_regression()
        for r in regs:
            print(f"REGRESSION: {r}")
        if regs:
            sys.exit(1)
        hist = _load_history()["history"]
        print(f"serve regression gate OK ({len(hist)} snapshot(s) in "
              f"{os.path.basename(BENCH_JSON)})")
        return
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
