"""Paper Table 1: multinomial sampler complexity comparison.

Measures µs/op for init / generation / parameter-update of the four
samplers across a T sweep, and verifies the asymptotic *shape*: F+tree
update cost must stay flat-ish (log T) while BSearch/Alias updates grow
linearly.  Derived column reports the T=4096/T=256 cost ratio — ~1 for
log-time ops, ~16 for linear ones.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row, time_fn
from repro.core import samplers

T_SWEEP = [256, 1024, 4096]
N_DRAWS = 4096


def _mk_p(T):
    return jnp.asarray(np.random.default_rng(T).random(T).astype(np.float32)
                       + 0.01)


def run() -> list[str]:
    out = []
    results = {}
    for T in T_SWEEP:
        p = _mk_p(T)
        u = jnp.asarray(np.random.default_rng(1).random(N_DRAWS)
                        .astype(np.float32))
        ts = jnp.asarray(np.random.default_rng(2).integers(0, T, N_DRAWS)
                         .astype(np.int32))
        ds = jnp.asarray((np.random.default_rng(3).random(N_DRAWS) * 0.1)
                         .astype(np.float32))

        for name, (init, draw, update) in samplers.SAMPLERS.items():
            init_j = jax.jit(init)
            state = jax.block_until_ready(init_j(p))
            t_init = time_fn(init_j, p)

            draw_j = jax.jit(lambda st, uu: jax.vmap(
                lambda x: draw(st, x))(uu))
            t_draw = time_fn(draw_j, state, u) / N_DRAWS

            if name == "alias":
                # Θ(T) rebuild is the update (paper Table 1)
                upd_j = jax.jit(lambda pp: init(pp))
                t_upd = time_fn(upd_j, p)
            else:
                def many(st, ts, ds):
                    def body(st, td):
                        return update(st, td[0], td[1]), None
                    return jax.lax.scan(body, st, (ts, ds))[0]
                upd_j = jax.jit(many)
                t_upd = time_fn(upd_j, state, ts, ds) / N_DRAWS

            results[(name, T, "init")] = t_init
            results[(name, T, "draw")] = t_draw
            results[(name, T, "update")] = t_upd

    lo, hi = T_SWEEP[0], T_SWEEP[-1]
    for name in samplers.SAMPLERS:
        for op in ("init", "draw", "update"):
            us = results[(name, hi, op)] * 1e6
            ratio = results[(name, hi, op)] / max(results[(name, lo, op)],
                                                  1e-12)
            out.append(row(f"table1/{name}/{op}/T{hi}", us,
                           f"T{hi}/T{lo}_ratio={ratio:.2f}"))

    # End-to-end sweep throughput: the fused kernel vs the lax.scan sweep
    # over the same chain (the per-token composition of the ops above).
    # T=1024/4096 intentionally overlap kernel_bench's sweep so each CSV
    # section is self-contained; the cost is two repeated configs per run.
    from benchmarks.kernel_bench import fused_vs_scan_rows
    out.extend(fused_vs_scan_rows(T_SWEEP, prefix="table1"))
    return out
