"""Pallas kernel micro-benchmarks (interpret mode — semantics timing only;
the derived column reports the oracle-match rate which is the real check)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row, time_fn
from repro.core import cgs, ftree
from repro.data import synthetic
from repro.kernels.ftree_sample import ftree_sample
from repro.kernels.ftree_sample.ref import ftree_sample_ref
from repro.kernels.lda_scores import lda_scores_draw
from repro.kernels.lda_scores.ref import lda_scores_draw_ref

FUSED_T_SWEEP = [1024, 4096, 16384]


def fused_vs_scan_rows(T_sweep=FUSED_T_SWEEP, *, prefix: str = "kernels",
                       num_docs: int = 24, vocab: int = 80,
                       mean_len: float = 10.0) -> list[str]:
    """tokens/sec of the fused F+LDA sweep kernel vs the lax.scan sweep.

    Both run the identical Gibbs chain (parity is asserted in the derived
    column); interpret mode, so this measures dispatch/fusion structure, not
    TPU silicon — the roofline story lives in benchmarks/roofline_bench.py.
    """
    out = []
    for T in T_sweep:
        corpus, _, _ = synthetic.make_corpus(
            num_docs=num_docs, vocab_size=vocab, num_topics=16,
            mean_doc_len=mean_len, seed=T)
        n = corpus.num_tokens
        state = cgs.init_state(corpus, T, jax.random.key(0))
        doc_ids = jnp.asarray(corpus.doc_ids)
        word_ids = jnp.asarray(corpus.word_ids)
        order = jnp.asarray(corpus.word_order())
        boundary = jnp.asarray(corpus.word_boundary())
        alpha, beta = 50.0 / T, 0.01

        runs = {}
        tps = {}
        for backend in ("scan", "fused"):
            # jit both paths: the comparison is kernel structure, not
            # eager-dispatch overhead (lda_sampler_bench does the same).
            fn = jax.jit(lambda s, be=backend: cgs.sweep_fplda_word(
                s, doc_ids, word_ids, order, boundary, alpha, beta,
                backend=be))
            runs[backend] = jax.block_until_ready(fn(state))
            t = time_fn(fn, state, warmup=1, iters=3)
            tps[backend] = n / t
            out.append(row(f"{prefix}/fused_sweep/{backend}/T{T}",
                           t * 1e6 / n, f"tokens_per_sec={n / t:.0f}"))
        exact = bool(jnp.array_equal(runs["scan"].z, runs["fused"].z)
                     and jnp.array_equal(runs["scan"].n_t,
                                         runs["fused"].n_t))
        out.append(row(f"{prefix}/fused_sweep/speedup/T{T}", 0.0,
                       f"fused_over_scan={tps['fused'] / tps['scan']:.2f}x "
                       f"chain_exact={exact}"))
    return out


def run(T: int = 1024, n: int = 4096) -> list[str]:
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.random(T).astype(np.float32) + 0.01)
    F = ftree.build(p)
    u = jnp.asarray(rng.random(n).astype(np.float32))

    z_k = ftree_sample(F, u)
    z_r = ftree_sample_ref(F, u)
    match = float((np.asarray(z_k) == np.asarray(z_r)).mean())
    t = time_fn(lambda: ftree_sample(F, u), warmup=1, iters=3)
    out = [row("kernels/ftree_sample", t * 1e6 / n,
               f"oracle_match={match:.4f}")]

    ntd = jnp.asarray(rng.integers(0, 8, (n, T)).astype(np.int32))
    nwt = jnp.asarray(rng.integers(0, 20, (n, T)).astype(np.int32))
    nt = jnp.asarray(rng.integers(20, 500, T).astype(np.int32))
    kw = dict(alpha=0.05, beta=0.01, beta_bar=51.2)
    zk, nk = lda_scores_draw(ntd, nwt, nt, u, **kw)
    zr, nr = lda_scores_draw_ref(ntd, nwt, nt, u, **kw)
    match = float((np.asarray(zk) == np.asarray(zr)).mean())
    t = time_fn(lambda: lda_scores_draw(ntd, nwt, nt, u, **kw),
                warmup=1, iters=3)
    out.append(row("kernels/lda_scores_fused", t * 1e6 / n,
                   f"oracle_match={match:.4f}"))

    out.extend(fused_vs_scan_rows())
    return out
