"""Pallas kernel micro-benchmarks (interpret mode — semantics timing only;
the derived column reports the oracle-match rate which is the real check)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row, time_fn
from repro.core import ftree
from repro.kernels.ftree_sample import ftree_sample
from repro.kernels.ftree_sample.ref import ftree_sample_ref
from repro.kernels.lda_scores import lda_scores_draw
from repro.kernels.lda_scores.ref import lda_scores_draw_ref


def run(T: int = 1024, n: int = 4096) -> list[str]:
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.random(T).astype(np.float32) + 0.01)
    F = ftree.build(p)
    u = jnp.asarray(rng.random(n).astype(np.float32))

    z_k = ftree_sample(F, u)
    z_r = ftree_sample_ref(F, u)
    match = float((np.asarray(z_k) == np.asarray(z_r)).mean())
    t = time_fn(lambda: ftree_sample(F, u), warmup=1, iters=3)
    out = [row("kernels/ftree_sample", t * 1e6 / n,
               f"oracle_match={match:.4f}")]

    ntd = jnp.asarray(rng.integers(0, 8, (n, T)).astype(np.int32))
    nwt = jnp.asarray(rng.integers(0, 20, (n, T)).astype(np.int32))
    nt = jnp.asarray(rng.integers(20, 500, T).astype(np.int32))
    kw = dict(alpha=0.05, beta=0.01, beta_bar=51.2)
    zk, nk = lda_scores_draw(ntd, nwt, nt, u, **kw)
    zr, nr = lda_scores_draw_ref(ntd, nwt, nt, u, **kw)
    match = float((np.asarray(zk) == np.asarray(zr)).mean())
    t = time_fn(lambda: lda_scores_draw(ntd, nwt, nt, u, **kw),
                warmup=1, iters=3)
    out.append(row("kernels/lda_scores_fused", t * 1e6 / n,
                   f"oracle_match={match:.4f}"))
    return out
