"""Paper Fig. 5: multicore scaling of F+Nomad LDA.

Runs the distributed sweep on 1/2/4/8 faked host devices (subprocesses, so
the main process keeps one device) and reports tokens/s plus the LL
trajectory — convergence must be preserved while throughput scales.

On this 1-core container the *wall-clock* speedup is bounded by real
parallelism (≈1); what the benchmark proves is (a) identical convergence
across ring widths — the paper's asynchronous-correctness claim — and
(b) per-sweep work split into W cells with the imbalance reported by the
layout (the 'last reducer' exposure the paper attacks with asynchrony and
we attack with LPT balancing)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.util import row

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(devices=(1, 2, 4, 8)) -> list[str]:
    out = []
    lls = {}
    for n in devices:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env.pop("XLA_FLAGS", None)
        t0 = time.time()
        res = subprocess.run(
            [sys.executable, "-m", "repro.launch.lda_dist_check",
             str(n), "stoken", "1"],
            capture_output=True, text=True, env=env, timeout=900)
        wall = time.time() - t0
        if res.returncode != 0:
            out.append(row(f"fig5/nomad_{n}dev", -1.0,
                           "ERROR " + res.stderr[-200:]))
            continue
        rep = json.loads(res.stdout.strip().splitlines()[-1])
        n_swept = rep["n_tokens"] * (len(rep["ll"]) - 1)
        lls[n] = rep["ll"][-1]
        out.append(row(
            f"fig5/nomad_{n}dev", wall * 1e6 / max(n_swept, 1),
            f"final_ll={rep['ll'][-1]:.0f};imbalance="
            f"{rep['round_imbalance']:.2f};exact="
            f"{rep['n_td_mismatch'] + rep['n_wt_mismatch'] == 0}"))
    if len(lls) > 1:
        vals = list(lls.values())
        spread = (max(vals) - min(vals)) / abs(min(vals))
        out.append(row("fig5/convergence_spread_pct", spread * 100,
                       "ring width does not change convergence"
                       if spread < 0.05 else "WARN"))
    return out
