"""Roofline table from the dry-run reports (spec: ROOFLINE ANALYSIS).

Reads reports/dryrun/*.json, prints the three terms per (arch × shape ×
mesh), the dominant bottleneck, MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D
(MoE), and the useful-compute ratio MODEL_FLOPS / (chips × HLO_FLOPs)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.util import row
from repro.roofline.analysis import model_flops

REPORTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "reports", "dryrun")


def run() -> list[str]:
    out = []
    for path in sorted(glob.glob(os.path.join(REPORTS, "*.json"))):
        rep = json.load(open(path))
        tag = f"{rep['arch']}__{rep['shape']}__{rep['mesh']}"
        if "error" in rep:
            out.append(row(f"roofline/{tag}", -1.0, "ERROR"))
            continue
        if "skipped" in rep:
            out.append(row(f"roofline/{tag}", 0.0,
                           "SKIP:" + rep["skipped"][:60]))
            continue
        terms = rep["roofline_seconds"]
        mf = model_flops(rep["arch"], rep["shape"])
        hlo_global = rep["hlo_flops_per_device"] * rep["chips"]
        ratio = mf / hlo_global if hlo_global else 0.0
        dominant = rep["bottleneck"]
        out.append(row(
            f"roofline/{tag}", terms[dominant] * 1e6,
            f"bottleneck={dominant};compute={terms['compute']:.2e}s;"
            f"memory={terms['memory']:.2e}s;"
            f"collective={terms['collective']:.2e}s;"
            f"useful_flops_ratio={ratio:.2f}"))
    return out
