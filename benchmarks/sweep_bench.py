"""Sweep-throughput benchmark + the repo's machine-readable perf record.

Measures tokens/sec of the three sweep paths —

* serial ``cgs.sweep_fplda_word`` with ``backend="scan"`` vs ``"fused"``
  (the single-block fused kernel), in-process;
* the distributed nomad sweep (subprocesses on faked devices) for
  ``inner_mode`` ∈ {scan, fused} × ``B`` ∈ {W, 4W} — the block-queue ring
  with one fused ``pallas_call`` per round in fused mode —

and, besides the usual CSV rows, writes ``BENCH_sweep.json`` at the repo
root so successive PRs leave a diffable perf trajectory (interpret-mode
numbers: structure, not silicon).

Env: REPRO_BENCH_FAST=1 shrinks the nomad ring to 2 workers.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_fn

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO, "BENCH_sweep.json")

SERIAL_T = 1024


def _serial_entries(T: int = SERIAL_T) -> list[dict]:
    from repro.core import cgs
    from repro.data import synthetic

    corpus, _, _ = synthetic.make_corpus(
        num_docs=24, vocab_size=80, num_topics=16, mean_doc_len=10.0, seed=T)
    state = cgs.init_state(corpus, T, jax.random.key(0))
    doc_ids = jnp.asarray(corpus.doc_ids)
    word_ids = jnp.asarray(corpus.word_ids)
    order = jnp.asarray(corpus.word_order())
    boundary = jnp.asarray(corpus.word_boundary())
    alpha, beta = 50.0 / T, 0.01

    entries = []
    for backend in ("scan", "fused"):
        fn = jax.jit(lambda s, be=backend: cgs.sweep_fplda_word(
            s, doc_ids, word_ids, order, boundary, alpha, beta, backend=be))
        t = time_fn(fn, state, warmup=1, iters=3)
        entries.append({"path": "serial", "backend": backend, "T": T,
                        "n_tokens": int(corpus.num_tokens),
                        "tokens_per_sec": corpus.num_tokens / t})
    return entries


def _nomad_entries(W: int) -> list[dict]:
    entries = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    for inner_mode in ("scan", "fused"):
        for B in (W, 4 * W):
            res = subprocess.run(
                [sys.executable, "-m", "repro.launch.lda_dist_check",
                 str(W), "stoken", "1", inner_mode, str(B)],
                capture_output=True, text=True, env=env, timeout=900)
            if res.returncode != 0:
                raise RuntimeError(
                    f"lda_dist_check W={W} B={B} {inner_mode}: "
                    + res.stderr[-500:])
            rep = json.loads(res.stdout.strip().splitlines()[-1])
            entries.append({
                "path": "nomad", "backend": inner_mode, "B": B, "W": W,
                "T": 16, "k": rep["blocks_per_worker"],
                "n_tokens": rep["n_tokens"],
                "tokens_per_sec": rep["tokens_per_sec"],
                "exact": rep["n_td_mismatch"] + rep["n_wt_mismatch"]
                         + rep["n_t_mismatch"] == 0,
                "round_imbalance": rep["round_imbalance"],
            })
    return entries


def run() -> list[str]:
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    W = 2 if fast else 4
    entries = _serial_entries() + _nomad_entries(W)
    if not fast:
        # Only full-size runs may touch the committed perf trajectory —
        # the CI smoke's shrunken W=2 ring must not overwrite it.
        with open(BENCH_JSON, "w") as f:
            json.dump({"interpret_mode": True, "entries": entries}, f,
                      indent=1)

    out = []
    for e in entries:
        tag = (f"sweep/{e['path']}/{e['backend']}"
               + (f"/B{e['B']}W{e['W']}" if e["path"] == "nomad" else "")
               + f"/T{e['T']}")
        us = 1e6 / max(e["tokens_per_sec"], 1e-9)
        out.append(row(tag, us, f"tokens_per_sec={e['tokens_per_sec']:.0f}"))
    out.append(row("sweep/json", 0.0,
                   ("skipped=fast_mode" if fast else
                    f"wrote={os.path.basename(BENCH_JSON)}")
                   + f";entries={len(entries)}"))
    return out
