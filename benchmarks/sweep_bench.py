"""Sweep-throughput benchmark + the repo's machine-readable perf record.

Measures tokens/sec of the three sweep paths —

* serial ``cgs.sweep_fplda_word`` with ``backend="scan"`` vs ``"fused"``
  (the single-block fused kernel), in-process;
* the distributed nomad sweep (subprocesses on faked devices) for
  ``inner_mode`` ∈ {scan, fused} × ``B`` ∈ {W, 4W} × ``ring_mode`` ∈
  {barrier, pipelined} — the block-queue ring, with the pipelined
  schedule's early half-queue hop —

and, besides the usual CSV rows, maintains ``BENCH_sweep.json`` at the
repo root: a **history** of per-PR snapshots (``{"history": [{"rev",
"entries"}, ...]}``) so successive PRs leave a diffable perf trajectory
(interpret-mode numbers: structure, not silicon).  Full-size runs append
a snapshot; ``check_regression`` (also ``python -m benchmarks.sweep_bench
--check-regression``, wired into ``tools/ci.sh --bench-smoke``) compares
the last two snapshots' nomad rows and fails on a >30% tokens/sec drop.

Env: REPRO_BENCH_FAST=1 shrinks the nomad ring to 2 workers (and never
touches the committed history).  REPRO_BENCH_REGRESSION_PCT overrides the
regression threshold (default 30).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_fn

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO, "BENCH_sweep.json")

SERIAL_T = 1024


def _serial_entries(T: int = SERIAL_T) -> list[dict]:
    from repro.core import cgs
    from repro.data import synthetic

    corpus, _, _ = synthetic.make_corpus(
        num_docs=24, vocab_size=80, num_topics=16, mean_doc_len=10.0, seed=T)
    state = cgs.init_state(corpus, T, jax.random.key(0))
    doc_ids = jnp.asarray(corpus.doc_ids)
    word_ids = jnp.asarray(corpus.word_ids)
    order = jnp.asarray(corpus.word_order())
    boundary = jnp.asarray(corpus.word_boundary())
    alpha, beta = 50.0 / T, 0.01

    entries = []
    for backend in ("scan", "fused"):
        fn = jax.jit(lambda s, be=backend: cgs.sweep_fplda_word(
            s, doc_ids, word_ids, order, boundary, alpha, beta, backend=be))
        t = time_fn(fn, state, warmup=1, iters=3)
        entries.append({"path": "serial", "backend": backend, "T": T,
                        "n_tokens": int(corpus.num_tokens),
                        "tokens_per_sec": corpus.num_tokens / t})
    return entries


def _nomad_entries(W: int) -> list[dict]:
    entries = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    for inner_mode in ("scan", "fused"):
        for B in (W, 4 * W):
            for ring_mode in ("barrier", "pipelined"):
                res = subprocess.run(
                    [sys.executable, "-m", "repro.launch.lda_dist_check",
                     str(W), "stoken", "1", inner_mode, str(B), ring_mode],
                    capture_output=True, text=True, env=env, timeout=900)
                if res.returncode != 0:
                    raise RuntimeError(
                        f"lda_dist_check W={W} B={B} {inner_mode} "
                        f"{ring_mode}: " + res.stderr[-500:])
                rep = json.loads(res.stdout.strip().splitlines()[-1])
                entries.append({
                    "path": "nomad", "backend": inner_mode, "B": B, "W": W,
                    "ring_mode": ring_mode,
                    "T": 16, "k": rep["blocks_per_worker"],
                    "n_tokens": rep["n_tokens"],
                    "tokens_per_sec": rep["tokens_per_sec"],
                    "exact": rep["n_td_mismatch"] + rep["n_wt_mismatch"]
                             + rep["n_t_mismatch"] == 0,
                    "round_imbalance": rep["round_imbalance"],
                })
    return entries


# ---------------------------------------------------------------------------
# History bookkeeping + regression gate.
# ---------------------------------------------------------------------------
def _load_history() -> dict:
    """Read BENCH_sweep.json, migrating the pre-history single-snapshot
    format ({"entries": [...]}) into history[0]."""
    if not os.path.exists(BENCH_JSON):
        return {"interpret_mode": True, "history": []}
    with open(BENCH_JSON) as f:
        data = json.load(f)
    if "history" not in data:
        data = {"interpret_mode": data.get("interpret_mode", True),
                "history": [{"rev": "pre-history",
                             "entries": data.get("entries", [])}]}
    return data


def _git_rev() -> str:
    if os.environ.get("REPRO_BENCH_LABEL"):
        return os.environ["REPRO_BENCH_LABEL"]
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, cwd=REPO,
                             timeout=30)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def _nomad_key(e: dict) -> tuple:
    return (e.get("backend"), e.get("B"), e.get("W"),
            e.get("ring_mode", "barrier"))


def _serial_baseline(entries: list[dict]) -> float:
    for e in entries:
        if e.get("path") == "serial" and e.get("backend") == "scan":
            return float(e["tokens_per_sec"])
    return 0.0


def check_regression(threshold: float | None = None) -> list[str]:
    """Compare the last two history snapshots' nomad rows; return a list of
    human-readable regression messages (empty = gate passes).

    Rows are matched on (backend, B, W, ring_mode); rows without a
    predecessor (first snapshot, new configurations) are skipped.
    Snapshots come from whatever machine produced them, so a row fails
    only when it regresses both **raw** and **normalized** by its own
    snapshot's serial-scan tokens/sec (same run, same machine): a slower
    host drops raw but not normalized, a serial-path speedup drops
    normalized but not raw — only a real distributed-path slowdown drops
    both.  The threshold is a fraction (default 0.30, env
    REPRO_BENCH_REGRESSION_PCT=<percent> overrides).
    """
    if threshold is None:
        threshold = float(os.environ.get(
            "REPRO_BENCH_REGRESSION_PCT", "30")) / 100.0
    hist = _load_history()["history"]
    if len(hist) < 2:
        return []
    base_old = _serial_baseline(hist[-2]["entries"])
    base_new = _serial_baseline(hist[-1]["entries"])
    prev = {_nomad_key(e): e for e in hist[-2]["entries"]
            if e.get("path") == "nomad"}
    regressions = []
    for e in hist[-1]["entries"]:
        if e.get("path") != "nomad":
            continue
        old = prev.get(_nomad_key(e))
        if old is None or old["tokens_per_sec"] <= 0:
            continue
        ratio_raw = e["tokens_per_sec"] / old["tokens_per_sec"]
        ratio_norm = (((e["tokens_per_sec"] / base_new)
                       / (old["tokens_per_sec"] / base_old))
                      if base_old > 0 and base_new > 0 else ratio_raw)
        ratio = max(ratio_raw, ratio_norm)
        if ratio < 1.0 - threshold:
            regressions.append(
                f"nomad/{e['backend']}/B{e['B']}W{e['W']}/"
                f"{e.get('ring_mode', 'barrier')}: "
                f"{old['tokens_per_sec']:.0f} -> "
                f"{e['tokens_per_sec']:.0f} tok/s "
                f"({(1 - ratio_raw) * 100:.0f}% raw / "
                f"{(1 - ratio_norm) * 100:.0f}% serial-normalized drop, "
                f"limit {threshold * 100:.0f}%; "
                f"{hist[-2]['rev']} -> {hist[-1]['rev']})")
    return regressions


def run() -> list[str]:
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    W = 2 if fast else 4
    entries = _serial_entries() + _nomad_entries(W)
    if not fast:
        # Only full-size runs may touch the committed perf trajectory —
        # the CI smoke's shrunken W=2 ring must not overwrite it.  A
        # re-run at the same rev replaces its own snapshot instead of
        # growing the history.
        data = _load_history()
        rev = _git_rev()
        if data["history"] and data["history"][-1]["rev"] == rev:
            data["history"][-1] = {"rev": rev, "entries": entries}
        else:
            data["history"].append({"rev": rev, "entries": entries})
        with open(BENCH_JSON, "w") as f:
            json.dump(data, f, indent=1)

    out = []
    for e in entries:
        tag = (f"sweep/{e['path']}/{e['backend']}"
               + (f"/B{e['B']}W{e['W']}/{e['ring_mode']}"
                  if e["path"] == "nomad" else "")
               + f"/T{e['T']}")
        us = 1e6 / max(e["tokens_per_sec"], 1e-9)
        out.append(row(tag, us, f"tokens_per_sec={e['tokens_per_sec']:.0f}"))
        if e["path"] == "nomad" and not e["exact"]:
            # surface correctness in the smoke gate, not just the JSON:
            # an inexact distributed sweep must fail `ci.sh --bench-smoke`
            # (it greps for ERROR rows) even though the subprocess exited 0
            out.append(row(tag + "/ERROR", -1.0, "counts_inexact"))
    out.append(row("sweep/json", 0.0,
                   ("skipped=fast_mode" if fast else
                    f"wrote={os.path.basename(BENCH_JSON)}")
                   + f";entries={len(entries)}"))
    return out


def main() -> None:
    if "--check-regression" in sys.argv:
        regs = check_regression()
        for r in regs:
            print(f"REGRESSION: {r}")
        if regs:
            sys.exit(1)
        hist = _load_history()["history"]
        print(f"bench regression gate OK "
              f"({len(hist)} snapshot(s) in {os.path.basename(BENCH_JSON)})")
        return
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
