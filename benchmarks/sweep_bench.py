"""Sweep-throughput benchmark + the repo's machine-readable perf record.

Measures tokens/sec of the three sweep paths —

* serial ``cgs.sweep_fplda_word`` with ``backend="scan"`` vs ``"fused"``
  (the single-block fused kernel), in-process;
* serial fused ``r_mode`` = dense vs sparse at the same sub-T ``r_cap``
  over T ∈ {1024, 4096} (the doc-sparse r-bucket, DESIGN.md §7a): the
  corpus — hence ``r_cap`` — is fixed while T grows, so the sparse rows
  price the side-table walk the dense per-token recompaction avoids
  paying Θ(T) for;
* the distributed nomad sweep (subprocesses on faked devices) for
  ``inner_mode`` ∈ {scan, fused} × ``B`` ∈ {W, 4W, 16W} × ``ring_mode`` ∈
  {barrier, pipelined} × ``layout`` ∈ {dense, ragged} — the block-queue
  ring — plus one **doc-tiled** ragged-fused row (``doc_tile=8`` slab
  paging, DESIGN.md §7) and one **sparse-r** ragged-fused row
  (``r_mode=sparse`` at the layout's ``r_cap``); every nomad entry
  records the layout's
  ``pad_fraction``/``total_tiles`` and its ``doc_tile`` +
  ``ntd_vmem_bytes`` (doc-topic bytes the kernel keeps VMEM-resident) so
  the dense-padding blowup, the ragged fix and the doc-slab budget all
  stay visible in the trajectory;
* ingestion throughput (host-side layout-build tokens/sec): the
  monolithic in-memory ``build_layout`` vs the chunked
  ``CorpusStore.from_corpus`` + ``build_layout_from_store`` out-of-core
  pipeline (DESIGN.md §9), measured back-to-back in-process so their
  ratio cancels host speed; ``check_regression`` gates that ratio;
* recovery wall-clock (DESIGN.md §11): an uninterrupted run vs the full
  kill + corrupt-newest-slot + rotation-fallback-resume path
  (``launch/chaos_check --phase recovery``, both legs back-to-back in
  one subprocess after a shared warmup, so the overhead ratio is
  host-speed-immune); ``check_regression`` gates the ratio via
  ``_check_recovery`` —

and, besides the usual CSV rows, maintains ``BENCH_sweep.json`` at the
repo root: a **history** of per-PR snapshots (``{"history": [{"rev",
"entries"}, ...]}``) so successive PRs leave a diffable perf trajectory
(interpret-mode numbers: structure, not silicon).  Full-size runs append
a snapshot; ``check_regression`` (also ``python -m benchmarks.sweep_bench
--check-regression``, wired into ``tools/ci.sh --bench-smoke``) compares
the last two snapshots' nomad rows and fails on a >30% tokens/sec drop,
and additionally runs the **padding-blowup canary**: ragged nomad-fused
tokens/sec at B=4W must not fall below B=W by more than the canary
threshold, judged on the dedicated *interleaved* measurement
(``launch/lda_canary_check``, a ``"canary"`` entry in the snapshot)
whose ratio is immune to the cross-subprocess host-contention noise of
the per-config rows (``--skip-canary`` / REPRO_BENCH_SKIP_CANARY=1
disables; the dense rows are exempt — they *are* the documented blowup).

Env: REPRO_BENCH_FAST=1 shrinks the nomad ring to 2 workers and the combo
matrix to the fused hot path (and never touches the committed history).
REPRO_BENCH_REGRESSION_PCT overrides the regression threshold (default
30); REPRO_BENCH_CANARY_PCT the canary threshold (default 30 — see
``_check_canary`` for why interpret-mode grid-step overhead rules out
the tighter gate the padding math alone would allow);
REPRO_BENCH_INGEST_PCT the chunked-vs-monolithic ingestion threshold
(default 80 — see ``_check_ingest``); REPRO_BENCH_RECOVERY_PCT the
kill+fallback-resume overhead threshold (default 300 — see
``_check_recovery``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_fn

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO, "BENCH_sweep.json")

SERIAL_T = 1024


def _serial_entries(T: int = SERIAL_T) -> list[dict]:
    from repro.core import cgs
    from repro.data import synthetic

    corpus, _, _ = synthetic.make_corpus(
        num_docs=24, vocab_size=80, num_topics=16, mean_doc_len=10.0, seed=T)
    state = cgs.init_state(corpus, T, jax.random.key(0))
    doc_ids = jnp.asarray(corpus.doc_ids)
    word_ids = jnp.asarray(corpus.word_ids)
    order = jnp.asarray(corpus.word_order())
    boundary = jnp.asarray(corpus.word_boundary())
    alpha, beta = 50.0 / T, 0.01

    entries = []
    for backend in ("scan", "fused"):
        fn = jax.jit(lambda s, be=backend: cgs.sweep_fplda_word(
            s, doc_ids, word_ids, order, boundary, alpha, beta, backend=be))
        t = time_fn(fn, state, warmup=1, iters=3)
        entries.append({"path": "serial", "backend": backend, "T": T,
                        "n_tokens": int(corpus.num_tokens),
                        "tokens_per_sec": corpus.num_tokens / t})
    return entries


def _rbucket_entries(fast: bool = False) -> list[dict]:
    """Serial fused rows pricing the r-bucket draw (DESIGN.md §7a): dense
    (per-token Θ(T)-scan recompaction of the doc row) vs sparse (side
    tables maintained incrementally, Θ(r_cap) touched state) at the same
    sub-T capacity, over growing T on a fixed corpus.  Both rows share
    ``r_cap``, so they run the identical chain; the interpret-mode delta
    is the structural proxy for the paper's Θ(|T_d|) r-bucket claim —
    the sparse rows' per-token cost must stay flat in T."""
    from repro.core import cgs
    from repro.data import synthetic

    entries = []
    for T in (1024,) if fast else (1024, 4096):
        corpus, _, _ = synthetic.make_corpus(
            num_docs=24, vocab_size=80, num_topics=16, mean_doc_len=10.0,
            seed=1024)
        cap = max(1, min(T, int(corpus.doc_lengths().max(initial=1))))
        state = cgs.init_state(corpus, T, jax.random.key(0))
        doc_ids = jnp.asarray(corpus.doc_ids)
        word_ids = jnp.asarray(corpus.word_ids)
        order = jnp.asarray(corpus.word_order())
        boundary = jnp.asarray(corpus.word_boundary())
        alpha, beta = 50.0 / T, 0.01
        for r_mode in ("dense", "sparse"):
            fn = jax.jit(lambda s, rm=r_mode: cgs.sweep_fplda_word(
                s, doc_ids, word_ids, order, boundary, alpha, beta,
                backend="fused", r_mode=rm, r_cap=cap))
            t = time_fn(fn, state, warmup=1, iters=3)
            entries.append({"path": "rbucket", "backend": "fused", "T": T,
                            "r_mode": r_mode, "r_cap": cap,
                            "n_tokens": int(corpus.num_tokens),
                            "tokens_per_sec": corpus.num_tokens / t})
    return entries


def _ingest_entries(fast: bool = False) -> list[dict]:
    """Ingestion-throughput rows (DESIGN.md §9): host-side layout-build
    tokens/sec of the monolithic in-memory ``build_layout`` vs the
    chunked ``build_layout_from_store`` streaming the same corpus back
    from an on-disk ``CorpusStore`` (shard npz reads included).  The
    store is written once, outside the timed region — it is ingested
    once per corpus while layouts are rebuilt many times (updates,
    resharding) — and its one-time write throughput rides along on the
    chunked row as ``store_write_tokens_per_sec``.  Both builds run
    back-to-back in this process, so the chunked/monolithic ratio
    cancels host speed; ``check_regression`` gates that ratio via
    ``_check_ingest``.  The chunked run also asserts the two layouts
    came out byte-identical (``exact``); an inexact row is an ERROR in
    the smoke gate, same as an inexact nomad sweep."""
    import shutil
    import tempfile
    import time

    import numpy as np

    from repro.data import synthetic
    from repro.data.corpus_store import CorpusStore, build_layout_from_store
    from repro.data.sharding import build_layout

    T = 16
    num_docs = 192 if fast else 768
    corpus, _, _ = synthetic.make_corpus(
        num_docs=num_docs, vocab_size=256, num_topics=T,
        mean_doc_len=40.0, seed=7)
    kw = dict(n_workers=4, T=T, n_blocks=8, layout="ragged", doc_tile=8)
    reps = 2 if fast else 4

    def best(fn):
        times, out = [], None
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            times.append(time.perf_counter() - t0)
        return min(times), out

    t_mono, lay_mono = best(lambda: build_layout(corpus, **kw))
    n = int(corpus.num_tokens)

    d = tempfile.mkdtemp(prefix="ingest_bench_")
    try:
        t0 = time.perf_counter()
        store = CorpusStore.from_corpus(
            corpus, os.path.join(d, "store"), tokens_per_shard=1 << 12)
        t_write = time.perf_counter() - t0
        t_chunk, lay_chunk = best(
            lambda: build_layout_from_store(store, **kw))
    finally:
        shutil.rmtree(d, ignore_errors=True)
    exact = all(
        np.array_equal(getattr(lay_mono, f), getattr(lay_chunk, f))
        for f in ("canon_idx", "tok_wrd", "tok_slot", "cell_sizes"))
    return [
        {"path": "ingest", "backend": "monolithic", "T": T, "n_tokens": n,
         "num_docs": num_docs, "tokens_per_sec": n / t_mono, "exact": True},
        {"path": "ingest", "backend": "chunked", "T": T, "n_tokens": n,
         "num_docs": num_docs, "tokens_per_sec": n / t_chunk,
         "store_write_tokens_per_sec": n / t_write,
         "exact": bool(exact)},
    ]


def _recovery_entry(W: int, fast: bool = False) -> dict:
    """Run the timed kill + fallback-resume story (``chaos_check --phase
    recovery``, DESIGN.md §11) and return its bench entry.  The
    subprocess warms the compile once, then times an uninterrupted run
    and the full failure path — rotating checkpoints, newest slot
    corrupted, hard death at ``kill_at``, rebuild, fallback to the
    previous valid slot, finish — back-to-back, so ``overhead_ratio``
    cancels host speed the way the padding canary's interleaved
    measurement does."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    sweeps, kill_at = (4, 2) if fast else (6, 3)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.chaos_check",
         "--phase", "recovery", "--n-devices", str(W),
         "--sweeps", str(sweeps), "--kill-at", str(kill_at)],
        capture_output=True, text=True, env=env, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(f"chaos_check recovery W={W}: "
                           + res.stderr[-500:])
    rep = json.loads(res.stdout.strip().splitlines()[-1])
    return {"path": "recovery", "W": W, "sweeps": rep["sweeps"],
            "kill_at": rep["kill_at"],
            "straight_sec": rep["straight_sec"],
            "recovery_sec": rep["recovery_sec"],
            "overhead_ratio": rep["overhead_ratio"],
            "resumed_from_step": rep["resumed_from_step"],
            "fell_back": rep["fell_back"], "exact": rep["exact"]}


def _nomad_entries(W: int, fast: bool = False) -> list[dict]:
    entries = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)

    def one(inner_mode: str, B: int, ring_mode: str, layout: str,
            doc_tile: int = 0, r_mode: str = "dense") -> dict:
        res = subprocess.run(
            [sys.executable, "-m", "repro.launch.lda_dist_check",
             str(W), "stoken", "1", inner_mode, str(B), ring_mode,
             layout, str(doc_tile), r_mode],
            capture_output=True, text=True, env=env, timeout=900)
        if res.returncode != 0:
            raise RuntimeError(
                f"lda_dist_check W={W} B={B} {inner_mode} {ring_mode} "
                f"{layout} doc_tile={doc_tile} r_mode={r_mode}: "
                + res.stderr[-500:])
        rep = json.loads(res.stdout.strip().splitlines()[-1])
        return {
            "path": "nomad", "backend": inner_mode, "B": B,
            "W": W, "ring_mode": ring_mode, "layout": layout,
            "r_mode": r_mode, "r_cap": rep["r_cap"],
            "T": 16, "k": rep["blocks_per_worker"],
            "n_tokens": rep["n_tokens"],
            "tokens_per_sec": rep["tokens_per_sec"],
            "exact": rep["n_td_mismatch"] + rep["n_wt_mismatch"]
                     + rep["n_t_mismatch"] == 0,
            "round_imbalance": rep["round_imbalance"],
            "pad_fraction": rep["pad_fraction"],
            "total_tiles": rep["total_tiles"],
            "ref_sweep_sec": rep["ref_sweep_sec"],
            # doc-axis tiling of the doc-topic shard (DESIGN.md §7):
            # slab height (0 = whole shard) and the bytes the kernel
            # actually keeps VMEM-resident for n_td
            "doc_tile": rep["doc_tile"],
            "ntd_row_bytes": rep["ntd_row_bytes"],
            "ntd_vmem_bytes": rep["ntd_slab_bytes"],
        }

    # fast (CI smoke) keeps the matrix small but still covers both layouts
    # on the fused hot path, so the pad_fraction delta is always reported.
    inner_modes = ("fused",) if fast else ("scan", "fused")
    b_mults = (1, 4) if fast else (1, 4, 16)
    for layout in ("dense", "ragged"):
        for inner_mode in inner_modes:
            for B in (m * W for m in b_mults):
                for ring_mode in ("barrier", "pipelined"):
                    entries.append(one(inner_mode, B, ring_mode, layout))
    # one doc-tiled row (both in smoke and full runs): the ragged fused
    # hot path with (8, T) doc-topic slabs paged instead of the whole
    # (I_max, T) shard — interpret-mode numbers price the paging DMAs'
    # structural overhead next to the untiled twin above
    entries.append(one("fused", 4 * W, "pipelined", "ragged", doc_tile=8))
    # ... and one sparse-r row on the same hot path: the r-bucket draw
    # walking the per-doc side tables at the layout's r_cap (DESIGN.md
    # §7a), priced next to its dense twin above
    entries.append(one("fused", 4 * W, "pipelined", "ragged",
                       r_mode="sparse"))
    return entries


# Timing-methodology epoch of the snapshots this harness writes.  Rows are
# only gated against a previous snapshot from the SAME epoch: comparing
# e.g. median-of-6 rows against the pre-PR4 total-of-3 rows would gate a
# measurement change, not a perf change.
TIMING_EPOCH = "median6+ref"


# ---------------------------------------------------------------------------
# History bookkeeping + regression gate.
# ---------------------------------------------------------------------------
def _load_history() -> dict:
    """Read BENCH_sweep.json, migrating the pre-history single-snapshot
    format ({"entries": [...]}) into history[0]."""
    if not os.path.exists(BENCH_JSON):
        return {"interpret_mode": True, "history": []}
    with open(BENCH_JSON) as f:
        data = json.load(f)
    if "history" not in data:
        data = {"interpret_mode": data.get("interpret_mode", True),
                "history": [{"rev": "pre-history",
                             "entries": data.get("entries", [])}]}
    return data


def _git_rev() -> str:
    if os.environ.get("REPRO_BENCH_LABEL"):
        return os.environ["REPRO_BENCH_LABEL"]
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, cwd=REPO,
                             timeout=30)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def _nomad_key(e: dict) -> tuple:
    # pre-ragged snapshots carry no layout key: those rows are dense;
    # pre-doc-tiling snapshots carry no doc_tile key: those are untiled;
    # pre-sparse-r snapshots carry no r_mode key: those rows are dense-r
    return (e.get("backend"), e.get("B"), e.get("W"),
            e.get("ring_mode", "barrier"), e.get("layout", "dense"),
            e.get("doc_tile", 0), e.get("r_mode", "dense"))


def _serial_baseline(entries: list[dict]) -> float:
    for e in entries:
        if e.get("path") == "serial" and e.get("backend") == "scan":
            return float(e["tokens_per_sec"])
    return 0.0


def check_regression(threshold: float | None = None) -> list[str]:
    """Compare the last two history snapshots' nomad rows; return a list of
    human-readable regression messages (empty = gate passes).

    Rows are matched on (backend, B, W, ring_mode, layout); rows without
    a predecessor (first snapshot, new configurations) are skipped, and
    the pairwise gate only runs when both snapshots share the same
    ``timing`` methodology epoch (a methodology change is not a perf
    change).  Snapshots come from whatever machine produced them — and a
    shared host can be 2-3x slower for one whole subprocess than the
    next — so a row fails only when it regresses under **every**
    normalization available: raw, normalized by its snapshot's
    serial-scan tokens/sec (host speed at snapshot time), and normalized
    by the row's own in-process reference clock
    (``tokens_per_sec · ref_sweep_sec``, which cancels the contention of
    the very subprocess that produced the row).  The threshold is a
    fraction (default 0.30, env REPRO_BENCH_REGRESSION_PCT=<percent>
    overrides).
    """
    if threshold is None:
        threshold = float(os.environ.get(
            "REPRO_BENCH_REGRESSION_PCT", "30")) / 100.0
    hist = _load_history()["history"]
    regressions = (_check_canary(hist) + _check_ingest(hist)
                   + _check_recovery(hist))
    if len(hist) < 2:
        return regressions
    if hist[-2].get("timing") != hist[-1].get("timing"):
        print(f"bench gate: timing epoch changed "
              f"({hist[-2].get('timing', 'pre-median6')} -> "
              f"{hist[-1].get('timing', 'pre-median6')}); pairwise row "
              f"gate skipped for this window, canary still active")
        return regressions
    base_old = _serial_baseline(hist[-2]["entries"])
    base_new = _serial_baseline(hist[-1]["entries"])
    prev = {_nomad_key(e): e for e in hist[-2]["entries"]
            if e.get("path") == "nomad"}
    for e in hist[-1]["entries"]:
        if e.get("path") != "nomad":
            continue
        old = prev.get(_nomad_key(e))
        if old is None or old["tokens_per_sec"] <= 0:
            continue
        ratio_raw = e["tokens_per_sec"] / old["tokens_per_sec"]
        ratio_norm = (((e["tokens_per_sec"] / base_new)
                       / (old["tokens_per_sec"] / base_old))
                      if base_old > 0 and base_new > 0 else ratio_raw)
        ratio = max(ratio_raw, ratio_norm)
        if e.get("ref_sweep_sec", 0) > 0 and old.get("ref_sweep_sec", 0) > 0:
            ratio = max(ratio,
                        (e["tokens_per_sec"] * e["ref_sweep_sec"])
                        / (old["tokens_per_sec"] * old["ref_sweep_sec"]))
        if ratio < 1.0 - threshold:
            regressions.append(
                f"nomad/{e['backend']}/B{e['B']}W{e['W']}/"
                f"{e.get('ring_mode', 'barrier')}: "
                f"{old['tokens_per_sec']:.0f} -> "
                f"{e['tokens_per_sec']:.0f} tok/s "
                f"({(1 - ratio_raw) * 100:.0f}% raw / "
                f"{(1 - ratio_norm) * 100:.0f}% serial-normalized drop, "
                f"limit {threshold * 100:.0f}%; "
                f"{hist[-2]['rev']} -> {hist[-1]['rev']})")
    return regressions


def _canary_entry(W: int) -> dict:
    """Run the interleaved B=W vs B=4W ragged-fused canary measurement
    (``repro.launch.lda_canary_check``) and return its bench entry."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.lda_canary_check", str(W)],
        capture_output=True, text=True, env=env, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(f"lda_canary_check W={W}: " + res.stderr[-500:])
    rep = json.loads(res.stdout.strip().splitlines()[-1])
    return {"path": "canary", "W": W,
            "tokens_per_sec_w": rep["tokens_per_sec_w"],
            "tokens_per_sec_4w": rep["tokens_per_sec_4w"],
            "ratio_4w_over_w": rep["ratio_4w_over_w"]}


def _check_canary(hist: list[dict]) -> list[str]:
    """The padding-blowup canary: in the latest snapshot, ragged
    nomad-fused tokens/sec at B=4W must not fall more than the threshold
    (default 30%, REPRO_BENCH_CANARY_PCT) below B=W.

    This is the signal the dense layout silently tripped for two PRs —
    B is supposed to be a free scaling knob (DESIGN.md §4), and with the
    ragged tile streams the per-round slot count no longer grows with B.
    The gated ratio comes from the dedicated **interleaved** measurement
    (``lda_canary_check``: both configs alternate single sweeps in one
    process, so host contention cancels out of the ratio) — the separate
    per-config nomad rows carry far too much cross-subprocess timing
    noise for any tight gate.  The default threshold is 30%, not the 10%
    the padding math alone would allow: in interpret mode every extra
    grid step costs ~tens of µs of interpreter overhead (absent on real
    silicon), and on the toy canary corpus B=4W runs ~4x the grid steps
    of B=W, which measures as a stable ~15-30% ratio deficit
    (0.71-0.86 observed).  The dense-style blowup this canary exists to
    catch costs ≥50% at B=4W, so 30% cleanly separates the two; tighten
    via REPRO_BENCH_CANARY_PCT on a quiet host or compiled TPU.  Dense
    rows are exempt: their blowup is the documented failure mode the
    ragged layout avoids.  Skipped entirely with --skip-canary /
    REPRO_BENCH_SKIP_CANARY=1 (e.g. while bisecting an unrelated drop).
    """
    if os.environ.get("REPRO_BENCH_SKIP_CANARY"):
        return []
    threshold = float(os.environ.get("REPRO_BENCH_CANARY_PCT", "30")) / 100.0
    if not hist:
        return []
    out = []
    for e in hist[-1]["entries"]:
        if e.get("path") != "canary":
            continue
        ratio = e["ratio_4w_over_w"]
        if ratio < 1.0 - threshold:
            out.append(
                f"canary nomad/fused/ragged W={e['W']}: B=4W "
                f"({e['tokens_per_sec_4w']:.0f} tok/s) is "
                f"{(1 - ratio) * 100:.0f}% below B=W "
                f"({e['tokens_per_sec_w']:.0f} tok/s, interleaved), limit "
                f"{threshold * 100:.0f}% — the padding blowup is back "
                f"({hist[-1]['rev']})")
    return out


def _check_ingest(hist: list[dict]) -> list[str]:
    """Chunked-ingestion gate: in the latest snapshot, the chunked
    (``CorpusStore`` shard-stream) build's tokens/sec must not fall more
    than the threshold (default 80%, REPRO_BENCH_INGEST_PCT) below the
    monolithic in-memory build.  Both rows come from the same process
    back-to-back (``_ingest_entries``), so the ratio is immune to the
    host-speed drift that forces the nomad rows' multi-normalization
    dance — but the chunked path legitimately pays the per-shard npz
    reads + stream concatenation the monolithic build never does, which
    measures as a stable ~0.30-0.35 ratio at the bench sizes, hence the
    loose default (floor 0.2; a *structural* regression — e.g. an
    accidental O(shards²) concat — lands well below it).  Pre-ingest
    snapshots carry no ingest rows and are skipped."""
    threshold = float(os.environ.get("REPRO_BENCH_INGEST_PCT", "80")) / 100.0
    if not hist:
        return []
    rows = {e.get("backend"): e for e in hist[-1]["entries"]
            if e.get("path") == "ingest"}
    mono, chunk = rows.get("monolithic"), rows.get("chunked")
    if not mono or not chunk or mono["tokens_per_sec"] <= 0:
        return []
    ratio = chunk["tokens_per_sec"] / mono["tokens_per_sec"]
    if ratio < 1.0 - threshold:
        return [
            f"ingest: chunked store build ({chunk['tokens_per_sec']:.0f} "
            f"tok/s) is {(1 - ratio) * 100:.0f}% below the monolithic "
            f"build ({mono['tokens_per_sec']:.0f} tok/s, same process), "
            f"limit {threshold * 100:.0f}% ({hist[-1]['rev']})"]
    return []


def _check_recovery(hist: list[dict]) -> list[str]:
    """Recovery-overhead gate (DESIGN.md §11): in the latest snapshot,
    the kill + corrupt-newest-slot + fallback-resume wall-clock must not
    exceed the uninterrupted run by more than REPRO_BENCH_RECOVERY_PCT
    percent (default 300).  Both legs come from the same subprocess
    back-to-back after a shared warmup, so the ratio is immune to host
    drift; the generous default prices the recovery leg's honest extra
    work — it re-runs the killed sweeps plus per-sweep checkpoint IO and
    a second cold build — while still catching structural blowups (a
    resume that replays the whole chain from sweep 0, rotation-slot IO
    going quadratic).  A resume that failed to fall back, or an inexact
    recovered chain (also an ERROR row in the smoke grep), fails
    outright.  Pre-recovery snapshots carry no such row and skip."""
    threshold = float(os.environ.get(
        "REPRO_BENCH_RECOVERY_PCT", "300")) / 100.0
    if not hist:
        return []
    out = []
    for e in hist[-1]["entries"]:
        if e.get("path") != "recovery":
            continue
        tag = f"recovery W={e['W']}"
        ratio = e["overhead_ratio"]
        if ratio > 1.0 + threshold:
            out.append(
                f"{tag}: kill+fallback-resume took {e['recovery_sec']:.2f}s"
                f" vs {e['straight_sec']:.2f}s straight "
                f"({(ratio - 1) * 100:.0f}% overhead, same process, limit "
                f"{threshold * 100:.0f}%; {hist[-1]['rev']})")
        if not e.get("fell_back", True):
            out.append(f"{tag}: resume did not fall back past the "
                       f"corrupted newest slot ({hist[-1]['rev']})")
        if not e.get("exact", True):
            out.append(f"{tag}: recovered chain digest diverged from the "
                       f"uninterrupted run ({hist[-1]['rev']})")
    return out


def _pad_fraction_summary(entries: list[dict]) -> str | None:
    """One-line dense-vs-ragged pad_fraction comparison at the largest B
    both layouts ran (the number `tools/ci.sh --bench-smoke` prints)."""
    pads = {}
    for e in entries:
        # doc-tiled rows carry group-segment padding on top of the
        # layout's own — comparing them against dense would misstate the
        # blowup delta this line tracks
        if e.get("path") == "nomad" and "pad_fraction" in e \
                and not e.get("doc_tile"):
            pads.setdefault(e["B"], {})[e.get("layout", "dense")] = \
                e["pad_fraction"]
    both = [b for b, d in pads.items() if {"dense", "ragged"} <= set(d)]
    if not both:
        return None
    b = max(both)
    d, r = pads[b]["dense"], pads[b]["ragged"]
    return (f"pad_fraction@B={b}: dense={d:.3f} ragged={r:.3f} "
            f"delta={d - r:+.3f}")


def run() -> list[str]:
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    W = 2 if fast else 4
    entries = (_serial_entries() + _rbucket_entries(fast)
               + _ingest_entries(fast) + _nomad_entries(W, fast=fast))
    entries.append(_recovery_entry(W, fast=fast))
    if not os.environ.get("REPRO_BENCH_SKIP_CANARY"):
        # skipping the canary skips the measurement too, not just the
        # gate — and leaves no canary entry in the snapshot to be judged
        # by a later un-flagged --check-regression
        entries.append(_canary_entry(W))
    if not fast:
        # Only full-size runs may touch the committed perf trajectory —
        # the CI smoke's shrunken W=2 ring must not overwrite it.  A
        # re-run at the same rev replaces its own snapshot instead of
        # growing the history.
        data = _load_history()
        rev = _git_rev()
        snap = {"rev": rev, "timing": TIMING_EPOCH, "entries": entries}
        if data["history"] and data["history"][-1]["rev"] == rev:
            data["history"][-1] = snap
        else:
            data["history"].append(snap)
        with open(BENCH_JSON, "w") as f:
            json.dump(data, f, indent=1)

    out = []
    for e in entries:
        if e["path"] == "canary":
            out.append(row(
                f"sweep/canary/ragged_fused/W{e['W']}", 0.0,
                f"ratio_4w_over_w={e['ratio_4w_over_w']:.3f};"
                f"w={e['tokens_per_sec_w']:.0f};"
                f"4w={e['tokens_per_sec_4w']:.0f}"))
            continue
        if e["path"] == "recovery":
            out.append(row(
                f"sweep/recovery/W{e['W']}/s{e['sweeps']}k{e['kill_at']}",
                e["recovery_sec"] * 1e6,
                f"straight_sec={e['straight_sec']:.3f};"
                f"recovery_sec={e['recovery_sec']:.3f};"
                f"overhead_ratio={e['overhead_ratio']:.2f};"
                f"resumed_from_step={e['resumed_from_step']};"
                f"fell_back={e['fell_back']}"))
            if not (e.get("exact", True) and e.get("fell_back", True)):
                # a recovered chain that forked, or a resume that never
                # fell back past the corrupted slot, must fail the smoke
                # grep even though the subprocess exited 0
                out.append(row(f"sweep/recovery/W{e['W']}/ERROR", -1.0,
                               "chain_forked" if not e.get("exact", True)
                               else "no_fallback"))
            continue
        tag = (f"sweep/{e['path']}/{e['backend']}"
               + (f"/{e['r_mode']}/cap{e['r_cap']}"
                  if e["path"] == "rbucket" else "")
               + (f"/B{e['B']}W{e['W']}/{e['ring_mode']}/{e['layout']}"
                  + (f"/dt{e['doc_tile']}" if e.get("doc_tile") else "")
                  + ("/rsparse" if e.get("r_mode") == "sparse" else "")
                  if e["path"] == "nomad" else "")
               + f"/T{e['T']}")
        us = 1e6 / max(e["tokens_per_sec"], 1e-9)
        extra = f"tokens_per_sec={e['tokens_per_sec']:.0f}"
        if e["path"] == "nomad":
            extra += (f";pad_fraction={e['pad_fraction']:.3f}"
                      f";total_tiles={e['total_tiles']}"
                      f";ntd_vmem_bytes={e['ntd_vmem_bytes']}")
        elif e["path"] == "ingest":
            extra += f";num_docs={e['num_docs']};n_tokens={e['n_tokens']}"
            if "store_write_tokens_per_sec" in e:
                extra += (f";store_write_tokens_per_sec="
                          f"{e['store_write_tokens_per_sec']:.0f}")
        out.append(row(tag, us, extra))
        if not e.get("exact", True):
            # surface correctness in the smoke gate, not just the JSON:
            # an inexact distributed sweep (or a chunked layout build that
            # diverged from the monolithic one) must fail
            # `ci.sh --bench-smoke` (it greps for ERROR rows) even though
            # the subprocess exited 0
            out.append(row(
                tag + "/ERROR", -1.0,
                "layout_mismatch" if e["path"] == "ingest"
                else "counts_inexact"))
    pad_line = _pad_fraction_summary(entries)
    if pad_line:
        out.append(row("sweep/pad_fraction", 0.0, pad_line))
    out.append(row("sweep/json", 0.0,
                   ("skipped=fast_mode" if fast else
                    f"wrote={os.path.basename(BENCH_JSON)}")
                   + f";entries={len(entries)}"))
    return out


def main() -> None:
    if "--skip-canary" in sys.argv:
        os.environ["REPRO_BENCH_SKIP_CANARY"] = "1"
    if "--check-regression" in sys.argv:
        regs = check_regression()
        for r in regs:
            print(f"REGRESSION: {r}")
        if regs:
            sys.exit(1)
        hist = _load_history()["history"]
        print(f"bench regression gate OK "
              f"({len(hist)} snapshot(s) in {os.path.basename(BENCH_JSON)}"
              + (", canary skipped)"
                 if os.environ.get("REPRO_BENCH_SKIP_CANARY") else ")"))
        return
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
