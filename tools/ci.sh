#!/usr/bin/env bash
# CI gate: import-clean collection, fast kernel/sampler signal, then tier-1.
#
#   tools/ci.sh          # collection check + full tier-1 suite
#   tools/ci.sh --fast   # collection check + `-m "not slow"` subset only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== collection (all test modules must import cleanly) =="
python -m pytest -q --collect-only >/dev/null

echo "== fast signal: kernels + samplers (-m 'not slow') =="
python -m pytest -q -m "not slow"

if [[ "${1:-}" != "--fast" ]]; then
    # The fast subset already ran above; finish tier-1 with the remainder
    # instead of re-running everything.
    echo "== tier-1 remainder: slow suite (-m slow) =="
    python -m pytest -x -q -m "slow"
fi

echo "CI OK"
